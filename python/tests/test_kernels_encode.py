"""Pallas encode kernel vs oracle + the coding-theoretic properties the
CodedFedL aggregation relies on (paper §III-B, §III-E)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
from hypothesis import given
from hypothesis import strategies as st

from compile.kernels import encode, ref
from .conftest import assert_close


def _mk(rng, u, l, k):
    g = rng.normal(size=(u, l)).astype(np.float32)
    w = rng.uniform(size=(l,)).astype(np.float32)
    d = rng.normal(size=(l, k)).astype(np.float32)
    return tuple(map(jnp.asarray, (g, w, d)))


@given(
    u=st.integers(1, 64),
    l=st.integers(1, 64),
    k=st.integers(1, 48),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_ref_shape_sweep(u, l, k, seed):
    rng = np.random.default_rng(seed)
    g, w, d = _mk(rng, u, l, k)
    assert_close(encode(g, w, d), ref.encode_ref(g, w, d), rtol=1e-3,
                 atol=1e-3)


def test_zero_weight_hides_point(rng):
    """w_k = 0 rows must leave no trace in the parity data (never-processed
    points have pnr=1 => weight sqrt(1-1)=0 ... see paper §III-D)."""
    g, w, d = _mk(rng, 16, 24, 8)
    w = w.at[5].set(0.0)
    d_perturbed = d.at[5].add(100.0)
    assert_close(encode(g, w, d), encode(g, w, d_perturbed))


def test_linearity_in_payload(rng):
    g, w, d = _mk(rng, 8, 16, 4)
    d2 = jnp.asarray(np.random.default_rng(7).normal(size=d.shape),
                     jnp.float32)
    lhs = encode(g, w, d + 2.0 * d2)
    rhs = encode(g, w, d) + 2.0 * encode(g, w, d2)
    assert_close(lhs, rhs, rtol=1e-3, atol=1e-3)


def test_zero_padded_generator_rows_are_zero_parity(rng):
    """Padding G with zero rows yields zero parity rows — the runtime pads
    u* up to the compiled u_max this way (DESIGN.md §2)."""
    g, w, d = _mk(rng, 8, 16, 4)
    gp = jnp.concatenate([g, jnp.zeros((4, 16))]).astype(jnp.float32)
    out = np.asarray(encode(gp, w, d))
    assert_close(out[:8], encode(g, w, d))
    np.testing.assert_array_equal(out[8:], np.zeros((4, 4), np.float32))


def test_gtg_over_u_approaches_identity(rng):
    """WLLN approximation in eq. (31): G^T G / u -> I for large u."""
    l = 12
    for u, tol in [(200, 0.3), (20_000, 0.05)]:
        g = rng.normal(size=(u, l)).astype(np.float32)
        m = g.T @ g / u
        off = m - np.eye(l, dtype=np.float32)
        assert np.max(np.abs(off)) < tol, (u, np.max(np.abs(off)))


def test_composite_parity_equals_global_encode(rng):
    """Sum of local parities == global-G encode of the stacked dataset
    (paper eq. 20-21): the server-side aggregation identity."""
    q = 6
    parts = []
    gs, ws, ds = [], [], []
    for lj in (8, 16, 4):
        g, w, d = _mk(rng, 10, lj, q)
        gs.append(np.asarray(g))
        ws.append(np.asarray(w))
        ds.append(np.asarray(d))
        parts.append(np.asarray(encode(g, w, d)))
    composite = np.sum(parts, axis=0)
    g_glob = np.concatenate(gs, axis=1)
    w_glob = np.concatenate(ws)
    d_glob = np.concatenate(ds, axis=0)
    global_parity = (g_glob * w_glob[None, :]) @ d_glob
    np.testing.assert_allclose(composite, global_parity, rtol=1e-4,
                               atol=1e-4)
