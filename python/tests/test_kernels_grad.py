"""Pallas masked-gradient kernel vs oracle + autodiff ground truth."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given
from hypothesis import strategies as st

from compile.kernels import grad, matmul_t, ref, residual
from .conftest import assert_close


def _mk(rng, l, q, c):
    xhat = rng.normal(size=(l, q)).astype(np.float32)
    y = rng.normal(size=(l, c)).astype(np.float32)
    theta = rng.normal(size=(q, c)).astype(np.float32)
    mask = (rng.uniform(size=(l,)) < 0.7).astype(np.float32)
    return tuple(map(jnp.asarray, (xhat, y, theta, mask)))


@given(
    l=st.integers(1, 64),
    q=st.integers(1, 96),
    c=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_ref_shape_sweep(l, q, c, seed):
    rng = np.random.default_rng(seed)
    xhat, y, theta, mask = _mk(rng, l, q, c)
    assert_close(grad(xhat, y, theta, mask), ref.grad_ref(xhat, y, theta, mask),
                 rtol=1e-3, atol=1e-3)


def test_residual_stage(rng):
    xhat, y, theta, mask = _mk(rng, 48, 32, 4)
    assert_close(residual(xhat, y, theta, mask),
                 ref.residual_ref(xhat, y, theta, mask))


def test_matmul_t_stage(rng):
    xhat = jnp.asarray(rng.normal(size=(48, 32)).astype(np.float32))
    r = jnp.asarray(rng.normal(size=(48, 4)).astype(np.float32))
    assert_close(matmul_t(xhat, r), ref.matmul_t_ref(xhat, r), rtol=1e-3,
                 atol=1e-3)


def test_matches_autodiff(rng):
    """Kernel equals jax.grad of the masked squared loss (paper eq. 9)."""
    xhat, y, theta, mask = _mk(rng, 40, 24, 5)

    def loss(th):
        res = xhat @ th - y
        return 0.5 * jnp.sum(mask[:, None] * res * res)

    g_auto = jax.grad(loss)(theta)
    # autodiff of 0.5 * sum(m r^2) gives X^T diag(m) r exactly (m is 0/1)
    assert_close(grad(xhat, y, theta, mask), g_auto, rtol=1e-3, atol=1e-3)


def test_mask_zero_rows_do_not_contribute(rng):
    xhat, y, theta, _ = _mk(rng, 32, 16, 3)
    mask = np.zeros(32, np.float32)
    mask[:7] = 1.0
    g_full = grad(xhat, y, theta, jnp.asarray(mask))
    g_sub = ref.grad_ref(xhat[:7], y[:7], theta, jnp.ones(7))
    assert_close(g_full, g_sub, rtol=1e-3, atol=1e-3)


def test_zero_padding_is_exact(rng):
    """Zero rows of (X, Y) contribute exactly zero — the runtime relies on
    this to pad small workloads up to compiled shapes (DESIGN.md §2)."""
    xhat, y, theta, mask = _mk(rng, 24, 16, 3)
    xp = jnp.concatenate([xhat, jnp.zeros((8, 16))]).astype(jnp.float32)
    yp = jnp.concatenate([y, jnp.zeros((8, 3))]).astype(jnp.float32)
    mp = jnp.concatenate([mask, jnp.ones(8)]).astype(jnp.float32)
    assert_close(grad(xp, yp, theta, mp), grad(xhat, y, theta, mask),
                 rtol=1e-3, atol=1e-3)


def test_explicit_blocks(rng):
    xhat, y, theta, mask = _mk(rng, 64, 64, 4)
    out = grad(xhat, y, theta, mask, block_l=16, block_q=32)
    assert_close(out, ref.grad_ref(xhat, y, theta, mask), rtol=1e-3, atol=1e-3)


def test_zero_theta_gives_neg_xty(rng):
    xhat, y, _, _ = _mk(rng, 16, 8, 2)
    theta0 = jnp.zeros((8, 2))
    mask1 = jnp.ones(16)
    assert_close(grad(xhat, y, theta0, mask1), -(xhat.T @ y), rtol=1e-3,
                 atol=1e-3)
