"""Pallas RFF-embed kernel vs the pure-jnp oracle (paper eq. 18)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
from hypothesis import given
from hypothesis import strategies as st

from compile.kernels import ref, rff_embed
from .conftest import assert_close


def _mk(rng, b, d, q, dtype=np.float32):
    x = rng.normal(size=(b, d)).astype(dtype)
    omega = rng.normal(size=(d, q)).astype(dtype)
    delta = rng.uniform(0, 2 * np.pi, size=(q,)).astype(dtype)
    return jnp.asarray(x), jnp.asarray(omega), jnp.asarray(delta)


@given(
    b=st.integers(1, 96),
    d=st.integers(1, 48),
    q=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_ref_shape_sweep(b, d, q, seed):
    rng = np.random.default_rng(seed)
    x, omega, delta = _mk(rng, b, d, q)
    assert_close(rff_embed(x, omega, delta), ref.rff_embed_ref(x, omega, delta))


def test_matches_ref_paper_block_shapes(rng):
    # the 'default' preset shape: one embedding chunk
    x, omega, delta = _mk(rng, 200, 784, 512)
    assert_close(rff_embed(x, omega, delta), ref.rff_embed_ref(x, omega, delta))


def test_explicit_blocks(rng):
    x, omega, delta = _mk(rng, 64, 16, 64)
    out = rff_embed(x, omega, delta, block_b=16, block_q=32)
    assert_close(out, ref.rff_embed_ref(x, omega, delta))


def test_output_range_bounded(rng):
    # |sqrt(2/q) cos(.)| <= sqrt(2/q)
    x, omega, delta = _mk(rng, 32, 8, 50)
    out = np.asarray(rff_embed(x, omega, delta))
    assert np.all(np.abs(out) <= np.sqrt(2 / 50) + 1e-6)


def test_rbf_kernel_approximation(rng):
    """phi(v1) . phi(v2) ~= exp(-||v1-v2||^2 / (2 sigma^2)) — eq. (8)/(17)."""
    sigma = 2.0
    d, q = 8, 8192
    omega = rng.normal(scale=1.0 / sigma, size=(d, q)).astype(np.float32)
    delta = rng.uniform(0, 2 * np.pi, size=(q,)).astype(np.float32)
    v = rng.normal(size=(6, d)).astype(np.float32)
    phi = np.asarray(rff_embed(jnp.asarray(v), jnp.asarray(omega),
                               jnp.asarray(delta)))
    approx = phi @ phi.T
    sq = ((v[:, None, :] - v[None, :, :]) ** 2).sum(-1)
    exact = np.exp(-sq / (2 * sigma**2))
    np.testing.assert_allclose(approx, exact, atol=0.06)


def test_deterministic(rng):
    x, omega, delta = _mk(rng, 16, 8, 16)
    a = np.asarray(rff_embed(x, omega, delta))
    b = np.asarray(rff_embed(x, omega, delta))
    np.testing.assert_array_equal(a, b)
