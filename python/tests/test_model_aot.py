"""L2 model graphs + AOT lowering: shapes, HLO-text validity, manifest."""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.shapes import PRESETS
from compile.kernels import ref
from .conftest import assert_close


class TestModelGraphs:
    def test_embed_shapes(self, rng):
        x = jnp.asarray(rng.normal(size=(40, 32)), jnp.float32)
        om = jnp.asarray(rng.normal(size=(32, 64)), jnp.float32)
        de = jnp.asarray(rng.uniform(size=(64,)), jnp.float32)
        out = model.embed_fn(x, om, de)
        assert out.shape == (40, 64)

    def test_grad_shapes(self, rng):
        xh = jnp.asarray(rng.normal(size=(40, 64)), jnp.float32)
        y = jnp.asarray(rng.normal(size=(40, 10)), jnp.float32)
        th = jnp.zeros((64, 10), jnp.float32)
        m = jnp.ones((40,), jnp.float32)
        assert model.grad_fn(xh, y, th, m).shape == (64, 10)

    def test_encode_shapes(self, rng):
        g = jnp.asarray(rng.normal(size=(128, 40)), jnp.float32)
        w = jnp.ones((40,), jnp.float32)
        xh = jnp.asarray(rng.normal(size=(40, 64)), jnp.float32)
        y = jnp.asarray(rng.normal(size=(40, 10)), jnp.float32)
        xp, yp = model.encode_fn(g, w, xh, y)
        assert xp.shape == (128, 64) and yp.shape == (128, 10)

    def test_grad_fn_equals_oracle(self, rng):
        xh = jnp.asarray(rng.normal(size=(24, 16)), jnp.float32)
        y = jnp.asarray(rng.normal(size=(24, 3)), jnp.float32)
        th = jnp.asarray(rng.normal(size=(16, 3)), jnp.float32)
        m = jnp.ones((24,), jnp.float32)
        assert_close(model.grad_fn(xh, y, th, m),
                     ref.grad_ref(xh, y, th, m), rtol=1e-3, atol=1e-3)


class TestAotLowering:
    @pytest.mark.parametrize("kind", ["rff_embed", "grad", "encode",
                                      "predict"])
    def test_lower_tiny_artifacts(self, kind):
        s = PRESETS["tiny"]
        arts = [a for a in s.artifacts() if a["kind"] == kind]
        assert arts
        for a in arts:
            text = aot.lower_artifact(kind, s, a)
            assert "ENTRY" in text
            assert "HloModule" in text

    def test_hlo_text_has_no_serialized_proto_markers(self):
        s = PRESETS["tiny"]
        a = [x for x in s.artifacts() if x["kind"] == "grad"][0]
        text = aot.lower_artifact("grad", s, a)
        # text interchange: human-readable, starts with HloModule
        assert text.lstrip().startswith("HloModule")

    def test_build_writes_manifest(self, tmp_path):
        aot.build(str(tmp_path), ["tiny"])
        manifest = (tmp_path / "manifest.txt").read_text().strip().split("\n")
        files = set(os.listdir(tmp_path))
        assert len(manifest) == len(PRESETS["tiny"].artifacts())
        for line in manifest:
            fields = dict(kv.split("=", 1) for kv in line.split()[1:])
            assert fields["file"] in files

    def test_build_is_idempotent(self, tmp_path):
        aot.build(str(tmp_path), ["tiny"])
        mtimes = {f: os.path.getmtime(tmp_path / f)
                  for f in os.listdir(tmp_path) if f.endswith(".hlo.txt")}
        aot.build(str(tmp_path), ["tiny"])
        for f, t in mtimes.items():
            assert os.path.getmtime(tmp_path / f) == t


class TestShapePresets:
    @pytest.mark.parametrize("name", sorted(PRESETS))
    def test_artifact_dims_positive(self, name):
        s = PRESETS[name]
        for a in s.artifacts():
            for k, v in a.items():
                if k not in ("kind", "file"):
                    assert isinstance(v, int) and v > 0

    @pytest.mark.parametrize("name", sorted(PRESETS))
    def test_filenames_unique_and_parse(self, name):
        s = PRESETS[name]
        files = [a["file"] for a in s.artifacts()]
        assert len(files) == len(set(files))
        for f in files:
            assert f.endswith(".hlo.txt")
