"""Unit + property tests for tile-size selection."""

from __future__ import annotations

import pytest
from hypothesis import given
from hypothesis import strategies as st

from compile.kernels import tiling


class TestLargestDivisor:
    def test_exact(self):
        assert tiling.largest_divisor_leq(128, 128) == 128

    def test_smaller(self):
        assert tiling.largest_divisor_leq(200, 128) == 100

    def test_prime(self):
        assert tiling.largest_divisor_leq(97, 64) == 1

    def test_one(self):
        assert tiling.largest_divisor_leq(1, 128) == 1

    def test_target_below_one(self):
        assert tiling.largest_divisor_leq(10, 0) == 1

    def test_rejects_nonpositive(self):
        with pytest.raises(ValueError):
            tiling.largest_divisor_leq(0, 4)

    @given(st.integers(1, 10_000), st.integers(1, 512))
    def test_is_divisor_and_leq(self, n, t):
        d = tiling.largest_divisor_leq(n, t)
        assert n % d == 0
        assert 1 <= d <= max(t, 1)

    @given(st.integers(1, 2_000), st.integers(1, 256))
    def test_is_largest(self, n, t):
        d = tiling.largest_divisor_leq(n, t)
        for cand in range(d + 1, min(n, t) + 1):
            assert n % cand != 0


class TestBlockPickers:
    @given(st.integers(1, 4096), st.integers(1, 4096), st.integers(1, 16))
    def test_grad_blocks_divide_and_fit(self, l, q, c):
        bl, bq = tiling.grad_blocks(l, q, c)
        assert l % bl == 0 and q % bq == 0
        # If a smaller divisor exists, working set must fit the budget.
        if bq > 1:
            ws = 4 * (bl * bq + bl * c + bq * c)
            # bq was only kept this large because it fits (or it's forced):
            assert ws <= tiling.VMEM_BUDGET or bq == 1

    @given(st.integers(1, 2048), st.integers(1, 1024), st.integers(1, 4096))
    def test_rff_blocks_divide(self, b, d, q):
        bb, bq = tiling.rff_blocks(b, d, q)
        assert b % bb == 0 and q % bq == 0

    @given(st.integers(1, 4096), st.integers(1, 4096))
    def test_encode_blocks_divide(self, u, l):
        bu, bl = tiling.encode_blocks(u, l)
        assert u % bu == 0 and l % bl == 0

    def test_preferred_lane_kept(self):
        assert tiling.pick_block(1024, 128) == 128
        assert tiling.pick_block(512, 512) == 512
