"""Shared fixtures and hypothesis strategies for the kernel test suite."""

from __future__ import annotations

import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from hypothesis import HealthCheck, settings

# Pallas interpret mode is slow per-call; keep hypothesis example counts
# modest but meaningful, and silence the too-slow health check.
settings.register_profile(
    "kernels",
    max_examples=25,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)
settings.load_profile("kernels")


@pytest.fixture
def rng():
    return np.random.default_rng(0xC0DEDFED)


def assert_close(a, b, rtol=2e-4, atol=2e-4):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=rtol, atol=atol)
