"""AOT compile path: lower the L2 graphs to HLO *text* artifacts.

HLO text (not ``lowered.compiler_ir('hlo')`` protos, not ``.serialize()``)
is the interchange format: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 (what the published ``xla`` crate
binds) rejects; the text parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/README.md.

Usage:
    python -m compile.aot --out-dir ../artifacts --preset default [--preset paper ...]

Also writes ``manifest.txt`` — one line per artifact:
    <kind> file=<name> <dim>=<val> ...
which the Rust runtime parses to verify shape agreement at startup.
"""

from __future__ import annotations

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model
from .shapes import PRESETS, ShapeSet

F32 = "float32"


def _spec(*dims):
    import jax.numpy as jnp

    return jax.ShapeDtypeStruct(tuple(dims), jnp.float32)


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_artifact(kind: str, s: ShapeSet, a: dict) -> str:
    """Lower one artifact of ``kind`` at the shapes in ``a`` to HLO text."""
    if kind == "rff_embed":
        low = jax.jit(model.embed_fn).lower(
            _spec(a["b"], a["d"]), _spec(a["d"], a["q"]), _spec(a["q"]))
    elif kind == "grad":
        low = jax.jit(model.grad_fn).lower(
            _spec(a["l"], a["q"]), _spec(a["l"], a["c"]),
            _spec(a["q"], a["c"]), _spec(a["l"]))
    elif kind == "encode":
        low = jax.jit(model.encode_fn).lower(
            _spec(a["u"], a["l"]), _spec(a["l"]),
            _spec(a["l"], a["q"]), _spec(a["l"], a["c"]))
    elif kind == "predict":
        low = jax.jit(model.predict_fn).lower(
            _spec(a["b"], a["q"]), _spec(a["q"], a["c"]))
    else:
        raise ValueError(f"unknown artifact kind {kind!r}")
    return to_hlo_text(low)


def build(out_dir: str, presets: list[str]) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest_lines: list[str] = []
    seen: set[str] = set()
    for pname in presets:
        s = PRESETS[pname]
        for a in s.artifacts():
            fname = a["file"]
            dims = {k: v for k, v in a.items() if k not in ("kind", "file")}
            line = " ".join(
                [a["kind"], f"file={fname}"]
                + [f"{k}={v}" for k, v in sorted(dims.items())])
            if fname in seen:
                continue
            seen.add(fname)
            manifest_lines.append(line)
            path = os.path.join(out_dir, fname)
            if os.path.exists(path):
                print(f"[aot] keep   {fname}")
                continue
            text = lower_artifact(a["kind"], s, a)
            with open(path, "w") as f:
                f.write(text)
            print(f"[aot] wrote  {fname}  ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"[aot] manifest.txt: {len(manifest_lines)} artifacts")


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--preset", action="append", default=None,
                   help=f"one of {sorted(PRESETS)} (repeatable)")
    args = p.parse_args()
    presets = args.preset or ["tiny", "default"]
    build(args.out_dir, presets)


if __name__ == "__main__":
    main()
