"""Single source of truth for the AOT artifact shape presets.

The Rust coordinator resolves artifacts by these filenames
(``rust/src/runtime/registry.rs`` builds the same names from its config), so
changing a preset here must be matched there — the manifest emitted by
``aot.py`` lets the runtime verify agreement at startup.

Presets:
  default — reduced scale used by tests, examples and the stock benches:
            n=30 clients x 200-point local mini-batches (m=6000), q=512.
  paper   — the paper's §V-A scale: 400-point local mini-batches (m=12000),
            q=2000, u_max = 0.25 m rounded to a lane multiple.
  tiny    — smoke-test scale for CI-fast integration tests.
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ShapeSet:
    """All AOT-fixed dimensions for one experiment scale."""

    name: str
    d: int        # raw feature dim
    q: int        # RFF dim
    c: int        # classes
    l_client: int # local mini-batch rows per client
    u_max: int    # max parity rows processed by the MEC server
    b_embed: int  # row-block for embedding / prediction batches

    def artifacts(self) -> list[dict]:
        """The artifact list this shape set requires."""
        return [
            dict(kind="rff_embed", file=f"rff_embed_{self.b_embed}x{self.d}x{self.q}.hlo.txt",
                 b=self.b_embed, d=self.d, q=self.q),
            dict(kind="grad", file=f"grad_{self.l_client}x{self.q}x{self.c}.hlo.txt",
                 l=self.l_client, q=self.q, c=self.c),
            dict(kind="grad", file=f"grad_{self.u_max}x{self.q}x{self.c}.hlo.txt",
                 l=self.u_max, q=self.q, c=self.c),
            dict(kind="encode", file=f"encode_{self.u_max}x{self.l_client}x{self.q}x{self.c}.hlo.txt",
                 u=self.u_max, l=self.l_client, q=self.q, c=self.c),
            dict(kind="predict", file=f"predict_{self.b_embed}x{self.q}x{self.c}.hlo.txt",
                 b=self.b_embed, q=self.q, c=self.c),
        ]


PRESETS: dict[str, ShapeSet] = {
    "tiny": ShapeSet(name="tiny", d=32, q=64, c=10, l_client=40,
                     u_max=128, b_embed=40),
    "default": ShapeSet(name="default", d=784, q=512, c=10, l_client=200,
                        u_max=1536, b_embed=200),
    "paper": ShapeSet(name="paper", d=784, q=2000, c=10, l_client=400,
                      u_max=3072, b_embed=400),
}
