"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here written in
the most direct jnp form; pytest (python/tests) asserts allclose between the
kernel outputs (interpret mode) and these oracles across shape/dtype sweeps.
These functions are also what the kernels must *mean* — any optimisation of
the Pallas side is only legal while these stay the ground truth.
"""

from __future__ import annotations

import jax.numpy as jnp


def rff_embed_ref(x, omega, delta):
    """Random Fourier feature map, paper eq. (18).

    x:     [B, d] raw features
    omega: [d, q] frequency vectors (columns ~ N(0, I/sigma^2))
    delta: [q]    phases ~ Uniform(0, 2*pi]
    returns [B, q]: sqrt(2/q) * cos(x @ omega + delta)
    """
    q = omega.shape[1]
    return jnp.sqrt(2.0 / q).astype(x.dtype) * jnp.cos(x @ omega + delta[None, :])


def residual_ref(xhat, y, theta, mask):
    """Masked residual  diag(mask) @ (xhat @ theta - y)  -> [L, c]."""
    return mask[:, None] * (xhat @ theta - y)


def matmul_t_ref(xhat, r):
    """xhat^T @ r -> [q, c]."""
    return xhat.T @ r


def grad_ref(xhat, y, theta, mask):
    """Masked linear-regression gradient, paper eq. (7)/(10) numerator.

    g = xhat^T diag(mask) (xhat @ theta - y), *unnormalised*: the coordinator
    applies the 1/l or 1/((1-pnr_C) u) scaling (paper eqs. (28)-(30)).
    """
    return matmul_t_ref(xhat, residual_ref(xhat, y, theta, mask))


def encode_ref(g, w, data):
    """Weighted random linear encode, paper eq. (19): (g * w[None,:]) @ data.

    g:    [u, l] generator matrix (private to the client)
    w:    [l]    weight-matrix diagonal (sqrt of probability-of-no-return)
    data: [l, k] transformed features (k=q) or labels (k=c)
    returns [u, k] local parity block.
    """
    return (g * w[None, :]) @ data


def predict_ref(xhat, theta):
    """Model logits xhat @ theta -> [B, c]."""
    return xhat @ theta
