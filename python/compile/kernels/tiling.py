"""Tile-size selection shared by the Pallas kernels.

TPU-oriented sizing: the MXU is a 128x128 systolic array and VMEM is a
~16 MiB scratchpad per core, so we aim block dims at multiples of 128 (8 for
the sublane dim) and keep the working set of each grid step well under the
VMEM budget.  On this testbed kernels run under ``interpret=True`` (CPU), so
these choices shape the *lowered structure* (what DESIGN.md's perf model
estimates) rather than measured wallclock.
"""

from __future__ import annotations

# VMEM budget per grid step, in bytes (conservative half of 16 MiB so double
# buffering of in/out blocks fits).
VMEM_BUDGET = 8 * 1024 * 1024

# Preferred tile quanta for f32 on TPU: lane dim 128, sublane dim 8.
LANE = 128
SUBLANE = 8


def largest_divisor_leq(n: int, target: int) -> int:
    """Largest divisor of ``n`` that is <= ``target`` (>=1).

    Shapes in this project are AOT-fixed, so we can afford exact divisors and
    keep the kernels free of ragged-edge masking.
    """
    if n <= 0:
        raise ValueError(f"n must be positive, got {n}")
    if target < 1:
        target = 1
    best = 1
    d = 1
    while d * d <= n:
        if n % d == 0:
            lo, hi = d, n // d
            if lo <= target and lo > best:
                best = lo
            if hi <= target and hi > best:
                best = hi
        d += 1
    return best


def pick_block(n: int, preferred: int) -> int:
    """Pick a block size for a dimension of extent ``n``.

    Prefers the TPU-friendly ``preferred`` quantum when it divides ``n``;
    otherwise falls back to the largest divisor not exceeding it.
    """
    if n % preferred == 0:
        return preferred
    return largest_divisor_leq(n, preferred)


def grad_blocks(l: int, q: int, c: int) -> tuple[int, int]:
    """(block_l, block_q) for the residual/transpose-matmul gradient pair.

    Working set per grid step of the X^T R accumulation:
    X block  (bl, bq) + R block (bl, c) + out accumulator (bq, c), all f32.
    """
    bl = pick_block(l, LANE)
    bq = pick_block(q, 4 * LANE)
    # shrink bq until the working set fits the VMEM budget
    while bq > 1 and 4 * (bl * bq + bl * c + bq * c) > VMEM_BUDGET:
        bq = largest_divisor_leq(q, bq - 1)
    return bl, bq


def rff_blocks(b: int, d: int, q: int) -> tuple[int, int]:
    """(block_b, block_q) for the fused cos(X @ Omega + delta) kernel.

    Working set: X block (bb, d) + Omega block (d, bq) + out (bb, bq).
    """
    bb = pick_block(b, LANE)
    bq = pick_block(q, 4 * LANE)
    while bq > 1 and 4 * (bb * d + d * bq + bb * bq) > VMEM_BUDGET:
        bq = largest_divisor_leq(q, bq - 1)
    return bb, bq


def encode_blocks(u: int, l: int) -> tuple[int, int]:
    """(block_u, block_l) for the weighted-encode kernel."""
    bu = pick_block(u, LANE)
    bl = pick_block(l, LANE)
    return bu, bl
