"""L1 Pallas kernel: fused random Fourier feature embedding (paper eq. 18).

Computes  sqrt(2/q) * cos(X @ Omega + delta)  in one pass: the matmul feeds
the MXU, the bias-add / cos / scale run on the VPU over the same VMEM tile,
so the [B, q] intermediate never round-trips to HBM (on real TPU).  Here the
kernel is lowered with ``interpret=True`` so the identical HLO runs on the
CPU PJRT plugin (see DESIGN.md §Hardware-Adaptation).

Grid: (B/bb, q/bq).  Each step loads an X row-block [bb, d] and an Omega
column-block [d, bq], both staying VMEM-resident; d (raw feature dim, 784
for MNIST-like data) is small enough to keep un-tiled.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import tiling


def _rff_kernel(x_ref, omega_ref, delta_ref, o_ref, *, q_total: int):
    x = x_ref[...]            # [bb, d]
    omega = omega_ref[...]    # [d, bq]
    delta = delta_ref[...]    # [1, bq]
    acc = jnp.dot(x, omega, preferred_element_type=jnp.float32)
    scale = jnp.sqrt(2.0 / q_total).astype(acc.dtype)
    o_ref[...] = (scale * jnp.cos(acc + delta)).astype(o_ref.dtype)


def rff_embed(x, omega, delta, *, block_b: int | None = None,
              block_q: int | None = None):
    """Pallas RFF embedding: x [B,d], omega [d,q], delta [q] -> [B,q]."""
    b, d = x.shape
    d2, q = omega.shape
    assert d == d2, (d, d2)
    assert delta.shape == (q,), delta.shape
    bb, bq = tiling.rff_blocks(b, d, q)
    if block_b is not None:
        bb = block_b
    if block_q is not None:
        bq = block_q
    assert b % bb == 0 and q % bq == 0, (b, bb, q, bq)

    delta2 = delta.reshape(1, q)
    kernel = functools.partial(_rff_kernel, q_total=q)
    return pl.pallas_call(
        kernel,
        grid=(b // bb, q // bq),
        in_specs=[
            pl.BlockSpec((bb, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, bq), lambda i, j: (0, j)),
            pl.BlockSpec((1, bq), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bb, bq), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, q), x.dtype),
        interpret=True,
    )(x, omega, delta2)
