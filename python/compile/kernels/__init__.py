"""L1: Pallas kernels for CodedFedL compute hot-spots.

- rff.rff_embed     fused cos(X @ Omega + delta) feature map (paper eq. 18)
- grad.grad         masked regression gradient X^T diag(m) (X theta - Y)
- encode.encode     weighted random linear encoding (paper eq. 19)

All kernels run under ``interpret=True`` so the lowered HLO executes on the
CPU PJRT plugin; ``ref.py`` holds the pure-jnp oracles they are tested
against (python/tests/test_kernels_*.py).
"""

from .encode import encode
from .grad import grad, matmul_t, residual
from .rff import rff_embed
from . import ref

__all__ = ["encode", "grad", "matmul_t", "residual", "rff_embed", "ref"]
