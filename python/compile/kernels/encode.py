"""L1 Pallas kernel: weighted random linear encoding (paper eq. 19).

parity = (G ⊙ w[None, :]) @ D  for generator G [u, l], weights w [l] and
payload D [l, k] (transformed features, k=q, or labels, k=c).  The weight
multiply fuses into the same VMEM tile as the MXU matmul, so the weighted
generator never materialises in HBM.

Grid: (u/bu, l/bl) with accumulation over l-tiles into the [bu, k] output
block.  Encoding runs once per client before training (build path), but it
is still the largest single matmul in the system (u × l × q), hence a
first-class kernel rather than plain jnp.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import tiling


def _encode_kernel(g_ref, w_ref, d_ref, o_ref):
    j = pl.program_id(1)
    g = g_ref[...]  # [bu, bl]
    w = w_ref[...]  # [1, bl]
    d = d_ref[...]  # [bl, k]
    part = jnp.dot(g * w, d, preferred_element_type=jnp.float32)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = part.astype(o_ref.dtype)

    @pl.when(j > 0)
    def _acc():
        o_ref[...] = (o_ref[...] + part).astype(o_ref.dtype)


def encode(g, w, data, *, block_u: int | None = None,
           block_l: int | None = None):
    """Local parity block: (g * w[None, :]) @ data -> [u, k]."""
    u, l = g.shape
    l2, k = data.shape
    assert l == l2, (l, l2)
    assert w.shape == (l,)
    bu, bl = tiling.encode_blocks(u, l)
    if block_u is not None:
        bu = block_u
    if block_l is not None:
        bl = block_l
    assert u % bu == 0 and l % bl == 0

    return pl.pallas_call(
        _encode_kernel,
        grid=(u // bu, l // bl),
        in_specs=[
            pl.BlockSpec((bu, bl), lambda i, j: (i, j)),
            pl.BlockSpec((1, bl), lambda i, j: (0, j)),
            pl.BlockSpec((bl, k), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bu, k), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((u, k), data.dtype),
        interpret=True,
    )(g, w.reshape(1, l), data)
