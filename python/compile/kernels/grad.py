"""L1 Pallas kernels: masked linear-regression gradient (paper eqs. 7/10/28).

g = X^T · diag(mask) · (X·theta − Y)   over  X [L,q], Y [L,c], theta [q,c].

Two kernels, chained by the L2 graph (python/compile/model.py):

1. ``residual``:  R = diag(mask)(X·theta − Y)          grid over L-tiles
2. ``matmul_t``:  g = X^T · R  with accumulation       grid (q-tiles, L-tiles)

Splitting keeps every grid step's VMEM working set bounded regardless of q
(theta is [q, c] with c small, so it stays resident in step 1; step 2 streams
X twice-transposed tiles through the MXU and accumulates the [bq, c] output
block in VMEM).  The same pair serves client partial gradients and the
server-side coded gradient (mask ≡ 1 on parity data) — DESIGN.md §2.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import tiling


def _residual_kernel(x_ref, y_ref, theta_ref, mask_ref, o_ref):
    x = x_ref[...]          # [bl, q]
    y = y_ref[...]          # [bl, c]
    theta = theta_ref[...]  # [q, c]
    mask = mask_ref[...]    # [bl, 1]
    pred = jnp.dot(x, theta, preferred_element_type=jnp.float32)
    o_ref[...] = (mask * (pred - y)).astype(o_ref.dtype)


def residual(xhat, y, theta, mask, *, block_l: int | None = None):
    """R = diag(mask) (xhat @ theta - y) -> [L, c]."""
    l, q = xhat.shape
    c = y.shape[1]
    assert theta.shape == (q, c)
    assert mask.shape == (l,)
    bl = block_l or tiling.pick_block(l, tiling.LANE)
    assert l % bl == 0

    return pl.pallas_call(
        _residual_kernel,
        grid=(l // bl,),
        in_specs=[
            pl.BlockSpec((bl, q), lambda i: (i, 0)),
            pl.BlockSpec((bl, c), lambda i: (i, 0)),
            pl.BlockSpec((q, c), lambda i: (0, 0)),
            pl.BlockSpec((bl, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bl, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((l, c), xhat.dtype),
        interpret=True,
    )(xhat, y, theta, mask.reshape(l, 1))


def _matmul_t_kernel(x_ref, r_ref, o_ref):
    j = pl.program_id(1)
    x = x_ref[...]  # [bl, bq]
    r = r_ref[...]  # [bl, c]
    part = jnp.dot(x.T, r, preferred_element_type=jnp.float32)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = part.astype(o_ref.dtype)

    @pl.when(j > 0)
    def _acc():
        o_ref[...] = (o_ref[...] + part).astype(o_ref.dtype)


def matmul_t(xhat, r, *, block_l: int | None = None,
             block_q: int | None = None):
    """g = xhat^T @ r -> [q, c], accumulated over L tiles in VMEM."""
    l, q = xhat.shape
    c = r.shape[1]
    assert r.shape[0] == l
    bl, bq = tiling.grad_blocks(l, q, c)
    if block_l is not None:
        bl = block_l
    if block_q is not None:
        bq = block_q
    assert l % bl == 0 and q % bq == 0

    return pl.pallas_call(
        _matmul_t_kernel,
        grid=(q // bq, l // bl),
        in_specs=[
            pl.BlockSpec((bl, bq), lambda i, j: (j, i)),
            pl.BlockSpec((bl, c), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, c), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((q, c), xhat.dtype),
        interpret=True,
    )(xhat, r)


def grad(xhat, y, theta, mask, **kw):
    """Full masked gradient: xhat^T diag(mask) (xhat theta - y)."""
    r = residual(xhat, y, theta, mask,
                 block_l=kw.get("block_l"))
    return matmul_t(xhat, r, block_l=kw.get("block_l"),
                    block_q=kw.get("block_q"))
