"""L2: the CodedFedL compute graphs, written in JAX over the L1 kernels.

Each function here is one AOT unit: jitted, lowered once by ``aot.py`` to
HLO text, loaded and executed by the Rust runtime.  Python never runs on the
training path — these graphs are the *entire* numeric surface of the system:

  embed_fn    (X, Omega, delta)        -> X_hat          paper eq. (18)
  grad_fn     (X_hat, Y, theta, mask)  -> g (unnormalised) eqs. (7)/(10)/(28)
  encode_fn   (G, w, X_hat, Y)         -> (X_parity, Y_parity)  eq. (19)
  predict_fn  (X_hat, theta)           -> logits

Normalisations (1/l, 1/((1-pnr_C) u*), 1/m), the model update (5) and the
L2-regulariser term are applied by the Rust coordinator — they are O(q*c)
and keeping them out of the graphs lets one grad artifact serve clients and
server alike (DESIGN.md §6).
"""

from __future__ import annotations

from . import kernels


def embed_fn(x, omega, delta):
    """RFF feature map over one row-block; chunked over the dataset by L3."""
    return kernels.rff_embed(x, omega, delta)


def grad_fn(xhat, y, theta, mask):
    """Masked regression gradient  X^T diag(mask) (X theta - Y).

    The same graph computes a client's partial gradient over its sampled
    l*_j rows (mask selects them) and the server's coded gradient over the
    global parity dataset (mask selects the u* live parity rows).
    """
    return kernels.grad(xhat, y, theta, mask)


def encode_fn(g, w, xhat, y):
    """Local parity dataset (X_parity, Y_parity) = G diag(w) [X_hat | Y]."""
    xp = kernels.encode(g, w, xhat)
    yp = kernels.encode(g, w, y)
    return xp, yp


def predict_fn(xhat, theta):
    """Logits for evaluation; argmax happens in Rust (c is small)."""
    return kernels.ref.predict_ref(xhat, theta)
