//! End-to-end validation driver (DESIGN.md §4, EXPERIMENTS.md §E2E).
//!
//! Exercises every layer on a real small workload through the session
//! API: builds the default 30-client non-IID experiment with
//! `ExperimentBuilder`, runs all three schemes to completion on one
//! `Session`, streams the coded run's loss curve from the engine's
//! `RoundEvent`s, prints the accuracy curves, the gain table and the
//! privacy budget, and writes `e2e_results.txt`.
//!
//! ```sh
//! cargo run --release --example end_to_end              # ~2-3 min
//! EPOCHS=70 DELTA=0.2 cargo run --release --example end_to_end
//! ```

use std::fmt::Write as _;

use codedfedl::benchutil;
use codedfedl::coordinator::EventLog;
use codedfedl::metrics::GainRow;
use codedfedl::privacy;
use codedfedl::schemes::{CodedFedL, GreedyUncoded, NaiveUncoded};
use codedfedl::ExperimentBuilder;

fn main() -> anyhow::Result<()> {
    let epochs: usize = std::env::var("EPOCHS").ok().and_then(|s| s.parse().ok()).unwrap_or(20);
    let delta: f64 = std::env::var("DELTA").ok().and_then(|s| s.parse().ok()).unwrap_or(0.2);
    let psi: f64 = std::env::var("PSI").ok().and_then(|s| s.parse().ok()).unwrap_or(0.2);
    let session = ExperimentBuilder::new()
        .epochs(epochs)
        // paper decay shape (40/70, 65/70) scaled to the epoch budget
        .lr_decay_epochs(vec![epochs * 40 / 70, epochs * 65 / 70])
        .build()?;
    let cfg = session.config();
    let mut report = String::new();

    writeln!(report, "# CodedFedL end-to-end run")?;
    writeln!(
        report,
        "n={} d={} q={} c={} m={} iters={} delta={delta} psi={psi} seed={:#x}",
        cfg.clients,
        cfg.dim,
        cfg.q,
        cfg.classes,
        cfg.global_batch(),
        cfg.total_iters(),
        cfg.seed
    )?;

    let wall0 = std::time::Instant::now();
    let naive = session.run(&mut NaiveUncoded::new())?;
    let greedy = session.run(&mut GreedyUncoded::new(psi))?;
    // The coded run records the engine's per-round event stream — the same
    // stream the CLI progress printer and the tests consume.
    let mut events = EventLog::default();
    let coded = session.run_observed(&mut CodedFedL::new(delta), &mut events)?;
    writeln!(report, "executor wall time: {:.1} s", wall0.elapsed().as_secs_f64())?;
    writeln!(report, "measured smoothness L = {:.4}", session.setup().smoothness)?;

    // --- loss curve of the coded run (from RoundEvents) ---
    writeln!(report, "\n## loss curve (coded, every 5th iter)")?;
    for ev in events.events.iter().step_by(5) {
        writeln!(
            report,
            "iter {:>4}  sim {:>10.1} s  loss {:.5}  acc {:.4}",
            ev.iter, ev.clock, ev.loss, ev.acc
        )?;
    }
    if let (Some(t), Some(u)) = (coded.t_star, coded.u_star) {
        writeln!(
            report,
            "t* = {t:.2} s  u* = {u}  parity upload overhead = {:.1} s",
            coded.parity_overhead
        )?;
    }

    // --- accuracy vs simulated time (Fig. 4(c) shape) ---
    let hists = [&naive.history, &greedy.history, &coded.history];
    writeln!(
        report,
        "\n{}",
        benchutil::ascii_curves(
            "accuracy vs simulated MEC time",
            &hists,
            |p| p.sim_time,
            "seconds",
        )
    )?;

    // --- gain table (Tables II/III shape) ---
    writeln!(report, "## time-to-accuracy gains")?;
    let best = naive.history.best_accuracy();
    for frac in [0.9, 0.95, 0.99] {
        let row = GainRow::compute(frac * best, &naive.history, &greedy.history, &coded.history);
        writeln!(report, "{}", row.render())?;
    }

    // --- privacy budget of the shared parity (App. F) ---
    writeln!(report, "\n## privacy (eq. 62), u = u*")?;
    let u = coded.u_star.unwrap_or(64);
    let mut worst = 0.0f64;
    for cd in &session.setup().client_data {
        worst = worst.max(privacy::epsilon_mi_dp(&cd.xhat[0], u));
    }
    writeln!(report, "worst-case client ε = {worst:.4} bits at u = {u}")?;

    // sanity gates: this driver doubles as a smoke test
    anyhow::ensure!(
        coded.history.best_accuracy() > 0.5,
        "coded failed to learn (acc {})",
        coded.history.best_accuracy()
    );
    anyhow::ensure!(
        coded.history.total_sim_time() < naive.history.total_sim_time(),
        "coded must beat naive on simulated time"
    );
    anyhow::ensure!(
        events.events.len() == cfg.total_iters(),
        "one RoundEvent per round"
    );
    let losses: Vec<f64> = coded.history.points.iter().map(|p| p.train_loss).collect();
    anyhow::ensure!(
        losses.last().unwrap() < losses.first().unwrap(),
        "loss must decrease"
    );

    println!("{report}");
    std::fs::write("e2e_results.txt", &report)?;
    println!("(written to e2e_results.txt)");
    Ok(())
}
