//! Load-allocation deep dive (paper §III-C / §IV).
//!
//! Sweeps the coding redundancy δ over the paper's LTE fleet and shows how
//! the optimal deadline t* shrinks, prints the per-node load profile, and
//! demonstrates the AWGN closed form against the general optimizer.
//!
//! ```sh
//! cargo run --release --example load_allocation
//! ```

use codedfedl::allocation::{self, optimal_load, optimal_load_awgn, NodeSpec};
use codedfedl::conf::ExperimentConfig;
use codedfedl::delay::NodeParams;
use codedfedl::rng::Rng;
use codedfedl::topology::FleetSpec;

fn main() -> anyhow::Result<()> {
    let cfg = ExperimentConfig::default();
    let spec = FleetSpec::paper(cfg.clients, cfg.q, cfg.classes);
    let clients = spec.build_clients(&mut Rng::seed_from(cfg.seed).split(2));
    let server = spec.build_server();
    let m = cfg.global_batch() as f64;

    println!("=== deadline vs coding redundancy (m = {m}) ===");
    println!("{:>6} {:>10} {:>10} {:>12}", "delta", "u_cap", "t* (s)", "u* (rows)");
    let mut prev_t = f64::INFINITY;
    for delta in [0.05, 0.1, 0.15, 0.2, 0.25] {
        let u_cap = (delta * m).round();
        let mut nodes: Vec<NodeSpec> = clients
            .iter()
            .map(|p| NodeSpec { params: *p, max_load: cfg.local_batch as f64 })
            .collect();
        nodes.push(NodeSpec { params: server, max_load: u_cap });
        let alloc = allocation::solve(&nodes, m).map_err(|e| anyhow::anyhow!("{e}"))?;
        println!(
            "{delta:>6.2} {u_cap:>10.0} {:>10.2} {:>12.1}",
            alloc.t_star,
            alloc.u_star()
        );
        assert!(alloc.t_star <= prev_t + 1e-9, "t* must shrink as delta grows");
        prev_t = alloc.t_star;
    }

    println!("\n=== per-node profile at delta = 0.1 ===");
    let u_cap = 0.1 * m;
    let mut nodes: Vec<NodeSpec> = clients
        .iter()
        .map(|p| NodeSpec { params: *p, max_load: cfg.local_batch as f64 })
        .collect();
    nodes.push(NodeSpec { params: server, max_load: u_cap });
    let alloc = allocation::solve(&nodes, m).map_err(|e| anyhow::anyhow!("{e}"))?;
    println!(
        "{:<6} {:>9} {:>9} {:>11} {:>9} {:>9}",
        "node", "mu", "tau", "l*", "E[R]", "pnr"
    );
    for (j, node) in nodes.iter().enumerate() {
        let name = if j < clients.len() { format!("c{j:02}") } else { "srv".into() };
        println!(
            "{name:<6} {:>9.2} {:>9.2} {:>11.1} {:>9.1} {:>9.4}",
            node.params.mu,
            node.params.tau,
            alloc.loads[j],
            alloc.expected_returns[j],
            alloc.pnr[j]
        );
    }
    println!(
        "t* = {:.2} s, total E[R] = {:.1} (target {m})",
        alloc.t_star,
        alloc.total_expected_return()
    );

    println!("\n=== AWGN closed form vs general optimizer (p = 0 node) ===");
    let node = NodeParams { mu: 20.0, alpha: 2.0, tau: 0.4, p: 0.0 };
    println!("{:>8} {:>12} {:>12}", "t", "closed form", "golden sect");
    for t in [1.0, 2.0, 4.0, 8.0, 16.0] {
        let (l_cf, er_cf) = optimal_load_awgn(&node, t, 100.0);
        let (l_gs, er_gs) = optimal_load(&node, t, 100.0);
        println!("{t:>8.1} {l_cf:>7.2}/{er_cf:<7.2} {l_gs:>7.2}/{er_gs:<7.2}");
        assert!((er_cf - er_gs).abs() < 1e-6 * (1.0 + er_gs));
    }
    println!("closed form matches the optimizer on every deadline ✓");
    Ok(())
}
