//! Privacy accounting demo (paper Appendix F).
//!
//! Builds the default experiment's embedded client shards and reports each
//! client's ε-MI-DP budget for sharing its parity data at several coding
//! redundancies, illustrating the paper's observation that concentrated
//! features leak more.
//!
//! ```sh
//! cargo run --release --example privacy_budget
//! ```

use codedfedl::coding::{CodeSpec, GeneratorKind};
use codedfedl::privacy;
use codedfedl::tensor::Mat;
use codedfedl::ExperimentBuilder;

fn main() -> anyhow::Result<()> {
    let session = ExperimentBuilder::preset("tiny")?.epochs(1).build()?;

    println!("=== per-client ε-MI-DP for sharing parity data (eq. 62) ===");
    println!("{:>6} {:>12} {:>10} {:>10} {:>10}", "client", "f(Xhat)", "u=32", "u=64", "u=128");
    for (j, cd) in session.setup().client_data.iter().enumerate() {
        let xhat = &cd.xhat[0];
        let f = privacy::concentration_f(xhat);
        let eps: Vec<f64> = [32, 64, 128]
            .iter()
            .map(|&u| privacy::epsilon_mi_dp(xhat, u))
            .collect();
        println!(
            "{j:>6} {f:>12.4} {:>10.4} {:>10.4} {:>10.4}",
            eps[0], eps[1], eps[2]
        );
    }

    println!("\n=== concentration drives leakage ===");
    // Uniform-energy database: every point carries similar weight.
    let uniform = Mat::from_fn(64, 8, |r, c| (((r * 13 + c * 7) % 17) as f32 + 1.0) / 17.0);
    // Concentrated database: one dominant record in every feature.
    let concentrated = Mat::from_fn(64, 8, |r, _| if r == 0 { 10.0 } else { 0.01 });
    for (name, m) in [("uniform", &uniform), ("concentrated", &concentrated)] {
        let rep = privacy::report(m, 64, &CodeSpec::Dense, GeneratorKind::Normal);
        println!(
            "{name:<14} f = {:>8.4}  ε(u=64) = {} bits  [{}]",
            rep.f_stat,
            rep.epsilon_label(),
            rep.code
        );
    }
    println!("\nsmaller f ⇒ larger ε: vulnerable features need a bigger privacy budget.");

    println!("\n=== analysis scope ===");
    // Eq. (62) is a Gaussian-generator bound; the rateless GF(256) code
    // shares no real-valued parity rows, so the report says so explicitly
    // instead of printing a number the analysis does not support.
    let rateless = CodeSpec::Rateless { overhead: 0.5 };
    let rep = privacy::report(&uniform, 64, &rateless, GeneratorKind::Normal);
    println!("{:<28} ε = {}", rep.code, rep.epsilon_label());
    let rademacher = privacy::report(&uniform, 64, &CodeSpec::Dense, GeneratorKind::Rademacher);
    println!("{:<28} ε = {}", rademacher.code, rademacher.epsilon_label());
    Ok(())
}
