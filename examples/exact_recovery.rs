//! Exact-recovery aggregation: erasure-decode stragglers instead of
//! averaging around them.
//!
//! ```sh
//! cargo run --release --example exact_recovery
//! ```
//!
//! The paper's CodedFedL aggregates in *expectation*: the server's parity
//! gradient substitutes for whatever the stragglers would have sent, so
//! the update is unbiased but not the all-clients update. With
//! `recovery = exact` the coded scheme instead treats each client's
//! gradient block as a GF(256) source symbol: the server keeps
//! `ceil(n·overhead)` repair symbols, watches the round's arrival
//! timeline, stops as soon as the received subset is decodable, and
//! erasure-decodes the missing blocks — reproducing the all-arrived
//! aggregate gradient *bit for bit* on every round the code can absorb.
//!
//! This example trains the coded scheme under a dropout scenario in both
//! recovery modes and with both built-in codes, then re-runs the exact
//! mode at a different worker-thread count and checks the final model is
//! bit-identical: GF(256) decoding has no floating-point rounding, so the
//! exact path inherits the engine's thread-invariance guarantee wholesale.

use codedfedl::coding::{CodeSpec, RecoveryMode};
use codedfedl::schemes::SchemeSpec;
use codedfedl::sim::scenario::ScenarioSpec;
use codedfedl::tensor::Mat;
use codedfedl::ExperimentBuilder;

fn run_once(code: CodeSpec, recovery: RecoveryMode, threads: usize) -> anyhow::Result<(f64, f64, Mat)> {
    // The fixed seed pins the data, fleet and dropout realisation, so
    // every run below faces the same stragglers.
    let session = ExperimentBuilder::preset("tiny")?
        .epochs(8)
        .threads(threads)
        .scenario(ScenarioSpec::Dropout { rate: 0.2 })
        .code(code)
        .recovery(recovery)
        .build()?;
    let out = session.run_spec(SchemeSpec::Coded { delta: 0.3 })?;
    Ok((
        out.history.final_accuracy(),
        out.history.total_sim_time(),
        out.theta,
    ))
}

fn main() -> anyhow::Result<()> {
    let runs = [
        ("dense / expectation (paper)", CodeSpec::Dense, RecoveryMode::Expectation),
        ("dense / exact", CodeSpec::Dense, RecoveryMode::Exact),
        (
            "rateless / exact",
            CodeSpec::Rateless { overhead: 0.5 },
            RecoveryMode::Exact,
        ),
    ];

    println!(
        "{:<28} {:>10} {:>14}",
        "code / recovery", "final acc", "sim time (s)"
    );
    for (name, code, recovery) in runs {
        let (acc, sim_time, _) = run_once(code, recovery, 0)?;
        println!("{name:<28} {acc:>10.4} {sim_time:>14.1}");
    }

    // The exact path is all-integer once gradients are packed: GF(256)
    // decoding introduces no floating-point rounding, and the decoded
    // aggregate is refolded in a fixed client order. Re-running at a
    // different thread count must therefore reproduce the model to the
    // bit, straggler recovery and all.
    let (_, _, theta_a) = run_once(CodeSpec::Dense, RecoveryMode::Exact, 1)?;
    let (_, _, theta_b) = run_once(CodeSpec::Dense, RecoveryMode::Exact, 4)?;
    let identical = theta_a
        .as_slice()
        .iter()
        .zip(theta_b.as_slice())
        .all(|(a, b)| a.to_bits() == b.to_bits());
    anyhow::ensure!(identical, "exact-recovery model diverged across thread counts");
    println!("\nexact recovery at 1 and 4 threads: final models are bit-identical.");
    println!("decoding stragglers exactly keeps the update deterministic — only");
    println!("round latency depends on which clients arrived.");
    Ok(())
}
