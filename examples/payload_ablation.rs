//! Payload ablation: what a gradient uplink codec buys, and why.
//!
//! ```sh
//! cargo run --release --example payload_ablation
//! ```
//!
//! The `[comm]` communication model prices every delay leg by the bytes
//! it actually carries, so shrinking the uplink gradient does two things
//! at once: the load-allocation optimizer sees cheaper uplinks and moves
//! its optimal (deadline, load, redundancy) split, and every simulated
//! round gets cheaper on the clock. This example runs CodedFedL under
//! the three codecs plus the `payload = "fixed"` ablation control
//! (quantized folds, *unchanged* delays) and tabulates, per
//! configuration: the optimizer's (t*, u*), total simulated wall clock,
//! bytes on the wire and final accuracy — separating how much of the
//! speedup is repricing and how much (if any) accuracy the quantization
//! costs.

use codedfedl::comm::{CodecSpec, PayloadSpec, ScaleSpec};
use codedfedl::coordinator::EventLog;
use codedfedl::schemes::CodedFedL;
use codedfedl::ExperimentBuilder;

fn main() -> anyhow::Result<()> {
    let configs: [(&str, CodecSpec, PayloadSpec); 4] = [
        ("none (baseline)", CodecSpec::None, PayloadSpec::Auto),
        ("q8 (8-bit)", CodecSpec::Q8 { scale: ScaleSpec::Auto }, PayloadSpec::Auto),
        ("bitpack (4-bit)", CodecSpec::Bitpack, PayloadSpec::Auto),
        // Ablation control: quantize the folds but keep the pre-codec
        // fixed-size payload pricing — same clock as the baseline, so
        // any accuracy delta is pure quantization noise.
        ("q8 + fixed price", CodecSpec::Q8 { scale: ScaleSpec::Auto }, PayloadSpec::Fixed),
    ];

    println!(
        "{:<18} {:>8} {:>5} {:>12} {:>10} {:>10} {:>10}",
        "codec", "t* (s)", "u*", "wall (s)", "MB down", "MB up", "final acc"
    );
    let mut baseline_wall = None;
    for (name, codec, payload) in configs {
        let session = ExperimentBuilder::preset("tiny")?
            .epochs(12)
            .codec(codec)
            .payload(payload)
            .build()?;
        let mut log = EventLog::default();
        let out = session.run_observed(&mut CodedFedL::new(0.3), &mut log)?;
        let wall = out.history.total_sim_time();
        println!(
            "{:<18} {:>8.3} {:>5} {:>12.1} {:>10.2} {:>10.2} {:>10.4}",
            name,
            out.t_star.unwrap_or(f64::NAN),
            out.u_star.unwrap_or(0),
            wall,
            out.bytes_down_total as f64 / 1e6,
            out.bytes_up_total as f64 / 1e6,
            out.history.final_accuracy()
        );
        match baseline_wall {
            None => baseline_wall = Some(wall),
            Some(base) => println!(
                "{:<18} {:>8} {:>5} {:>11.1}%",
                "  vs baseline", "", "", 100.0 * (wall - base) / base
            ),
        }
    }
    println!(
        "\nThe lossy codecs lower t* (the optimizer waits less for cheap uplinks)\n\
         and the wall clock with it; the fixed-price ablation shows the folds\n\
         survive quantization with the clock pinned to the baseline."
    );
    Ok(())
}
