//! Degraded rounds: fault injection + deadlines and the degradation
//! ladder.
//!
//! ```sh
//! cargo run --release --example degraded_rounds
//! ```
//!
//! Real fleets crash mid-round, lose uplink packets and occasionally lose
//! the MEC unit's parity gradient — and latency SLOs force the server to
//! close rounds before every straggler reports. This example runs the
//! three schemes under increasingly hostile fault mixes with a quantile
//! deadline and tabulates, per scheme, how its rounds actually resolved:
//! the engine's degradation ladder (full → exact decode → parity
//! compensation → renormalised partial fold → documented skip) records
//! one rung per round, and the event stream carries achieved vs planned
//! participation. CodedFedL's parity gradient keeps rounds off the
//! partial/skip rungs that starve the uncoded schemes.

use codedfedl::coordinator::EventLog;
use codedfedl::schemes::SchemeSpec;
use codedfedl::sim::fault::{DeadlineSpec, FaultSpec};
use codedfedl::ExperimentBuilder;

fn main() -> anyhow::Result<()> {
    let mixes = [
        FaultSpec::None,
        FaultSpec::Crash { rate: 0.2 },
        FaultSpec::Mixed { crash: 0.2, link: 0.3, parity: 0.3 },
    ];
    let schemes = [
        SchemeSpec::NaiveUncoded,
        SchemeSpec::GreedyUncoded { psi: 0.2 },
        SchemeSpec::Coded { delta: 0.3 },
    ];

    println!(
        "{:<18} {:>5} {:>6} {:>7} {:>8} {:>5} {:>12} {:>10}",
        "faults / scheme", "full", "exact", "parity", "partial", "skip", "achieved", "final acc"
    );
    for faults in mixes {
        // One session per mix: every scheme below faces the same fault
        // realisation (the fault stream is scheme-independent) and the
        // same 80th-percentile round deadline.
        let session = ExperimentBuilder::preset("tiny")?
            .epochs(12)
            .faults(faults)
            .deadline(DeadlineSpec::Quantile { q: 0.8 })
            .build()?;
        println!("--- {} ---", faults.label());
        for spec in schemes {
            let mut log = EventLog::default();
            let mut scheme = spec.build();
            let out = session.run_observed(scheme.as_mut(), &mut log)?;
            let o = out.outcomes;
            // Achieved participation: what fraction of the planned
            // gradients actually entered the aggregates.
            let planned: usize = log.events.iter().map(|ev| ev.planned).sum();
            let arrived: usize = log.events.iter().map(|ev| ev.arrivals).sum();
            let achieved = if planned > 0 {
                arrived as f64 / planned as f64
            } else {
                0.0
            };
            println!(
                "{:<18} {:>5} {:>6} {:>7} {:>8} {:>5} {:>11.1}% {:>10.4}",
                spec.label(),
                o.full,
                o.exact_decode,
                o.parity,
                o.partial,
                o.skip,
                100.0 * achieved,
                out.history.final_accuracy()
            );
        }
    }
    Ok(())
}
