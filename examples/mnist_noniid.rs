//! Non-IID showcase (paper §V-B, Fig. 4(b)): greedy uncoded starves whole
//! classes under label-sorted sharding, while CodedFedL's parity gradient
//! keeps every class represented.
//!
//! ```sh
//! cargo run --release --example mnist_noniid           # reduced scale
//! EPOCHS=70 cargo run --release --example mnist_noniid # longer run
//! ```
//!
//! Uses the MNIST-like dataset (real MNIST IDX files are picked up
//! automatically if placed under `data/mnist/`).

use codedfedl::benchutil;
use codedfedl::metrics::accuracy;
use codedfedl::schemes::SchemeSpec;
use codedfedl::ExperimentBuilder;

fn main() -> anyhow::Result<()> {
    let epochs: usize = std::env::var("EPOCHS").ok().and_then(|s| s.parse().ok()).unwrap_or(16);
    let cfg = ExperimentBuilder::new().epochs(epochs).config().clone();

    let schemes = [
        SchemeSpec::NaiveUncoded,
        SchemeSpec::GreedyUncoded { psi: 0.2 },
        SchemeSpec::Coded { delta: 0.2 },
    ];
    let (session, results) = benchutil::run_experiment(&cfg, &schemes)?;
    let setup = session.setup();

    // --- which classes do the slowest clients own? ---
    println!("=== non-IID placement: classes owned by the 6 slowest clients ===");
    let mut order: Vec<usize> = (0..cfg.clients).collect();
    order.sort_by(|&a, &b| {
        setup.clients[b]
            .mean_delay(cfg.local_batch as f64)
            .total_cmp(&setup.clients[a].mean_delay(cfg.local_batch as f64))
    });
    for &j in order.iter().take(6) {
        // labels of client j's first mini-batch (one-hot rows → argmax)
        let classes: std::collections::BTreeSet<usize> =
            setup.client_data[j].y[0].argmax_rows().into_iter().collect();
        println!(
            "  client {j:02} (E[T] = {:>7.1} s) owns classes {:?}",
            setup.clients[j].mean_delay(cfg.local_batch as f64),
            classes
        );
    }

    // --- accuracy vs iteration (Fig. 4(b) shape) ---
    let hists: Vec<&codedfedl::metrics::History> =
        results.iter().map(|(_, r)| &r.history).collect();
    println!(
        "\n{}",
        benchutil::ascii_curves(
            "accuracy vs training iteration (Fig. 4(b) analogue)",
            &hists,
            |p| p.iter as f64,
            "iteration",
        )
    );

    // --- per-class recall under each scheme ---
    println!("=== per-class recall of the final models ===");
    let rt = session.runtime();
    print!("{:<18}", "scheme");
    for c in 0..cfg.classes {
        print!("  c{c}   ");
    }
    println!("  overall");
    for (scheme, out) in &results {
        let logits = rt.predict(&setup.test_xhat, &out.theta)?;
        let pred = logits.argmax_rows();
        print!("{:<18}", scheme.label());
        for c in 0..cfg.classes {
            let (mut hit, mut tot) = (0usize, 0usize);
            for (p, &l) in pred.iter().zip(&setup.test_labels) {
                if l as usize == c {
                    tot += 1;
                    hit += (*p == c) as usize;
                }
            }
            print!(" {:5.2}", hit as f64 / tot.max(1) as f64);
        }
        println!("   {:5.3}", accuracy(&logits, &setup.test_labels));
    }
    println!("\ngreedy's recall collapses on the classes owned by straggling clients;");
    println!("the coded gradient keeps them alive (paper §V-B).");
    Ok(())
}
