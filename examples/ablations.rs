//! Ablations over the design choices DESIGN.md calls out:
//!
//! 1. **Sharding**: non-IID (paper §V-A) vs IID — isolates *why* greedy
//!    uncoded fails: under IID sharding dropping stragglers costs little
//!    accuracy; under label-sorted sharding it starves whole classes.
//! 2. **Generator distribution**: Normal vs Rademacher ±1 — the paper
//!    allows both (§III-B); coded accuracy should be indistinguishable.
//! 3. **Weight matrix**: §III-D weighting vs naive all-ones weights — the
//!    weighting is what makes `E[g_M] ≈ g`; without it the parity gradient
//!    double-counts points that usually arrive.
//!
//! ```sh
//! cargo run --release --example ablations
//! ```

use codedfedl::data::shard;
use codedfedl::metrics::export;
use codedfedl::rng::Rng;
use codedfedl::schemes::{CodedFedL, GreedyUncoded, NaiveUncoded};
use codedfedl::ExperimentBuilder;

fn main() -> anyhow::Result<()> {
    let builder = ExperimentBuilder::preset("tiny")?.epochs(20);
    let cfg = builder.config().clone();

    // ---------- ablation 1: non-IID vs IID sharding -----------------
    // The library's setup always shards non-IID (the paper's setting);
    // the IID control reuses shard::iid_shards on the same generated
    // dataset to quantify the class-starvation effect directly.
    println!("=== ablation 1: greedy uncoded under non-IID vs IID sharding ===");
    let session = builder.clone().build()?;
    let noniid = session.run(&mut GreedyUncoded::new(0.4))?;
    let naive = session.run(&mut NaiveUncoded::new())?;

    // IID control: same client count and data volume, shuffled shards.
    // (Demonstrated via the library API on freshly generated data.)
    let iid_spec = codedfedl::data::synth::easy(cfg.dim);
    let mut data_rng = Rng::seed_from(cfg.seed).split(1);
    let all = codedfedl::data::synth::generate(
        &iid_spec,
        cfg.train_size + cfg.test_size,
        &mut data_rng,
    );
    let train = all.slice(0, cfg.train_size);
    let mut shard_rng = Rng::seed_from(cfg.seed).split(99);
    let iid = shard::iid_shards(&train, cfg.clients, &mut shard_rng);
    let iid_classes: Vec<usize> = iid
        .iter()
        .map(|s| {
            s.labels.iter().collect::<std::collections::HashSet<_>>().len()
        })
        .collect();
    let noniid_classes: Vec<usize> = (0..cfg.clients)
        .map(|j| {
            session.setup().client_data[j].y[0]
                .argmax_rows()
                .into_iter()
                .collect::<std::collections::HashSet<_>>()
                .len()
        })
        .collect();
    println!("classes per client, IID sharding:     {iid_classes:?}");
    println!("classes per client, non-IID sharding: {noniid_classes:?}");
    println!(
        "greedy(0.4) best acc {:.3} vs naive {:.3} under non-IID (gap {:.3})",
        noniid.history.best_accuracy(),
        naive.history.best_accuracy(),
        naive.history.best_accuracy() - noniid.history.best_accuracy()
    );
    assert!(iid_classes.iter().all(|&c| c >= 8), "IID shards keep all classes");
    assert!(
        noniid_classes.iter().all(|&c| c <= 2),
        "non-IID shards concentrate 1-2 classes"
    );

    // ---------- ablation 2: generator distribution ------------------
    println!("\n=== ablation 2: Normal vs Rademacher generator matrices ===");
    let mut accs = Vec::new();
    for generator in [
        codedfedl::coding::GeneratorKind::Normal,
        codedfedl::coding::GeneratorKind::Rademacher,
    ] {
        let session_g = builder.clone().generator(generator).build()?;
        let out = session_g.run(&mut CodedFedL::new(0.3))?;
        println!(
            "{generator:?}: best acc {:.3}, t* = {:.3} s",
            out.history.best_accuracy(),
            out.t_star.unwrap()
        );
        accs.push(out.history.best_accuracy());
    }
    let gap = (accs[0] - accs[1]).abs();
    println!("|Normal − Rademacher| accuracy gap: {gap:.3}");
    assert!(gap < 0.12, "generator distribution must not matter materially");

    // ---------- export -----------------------------------------------
    let csv = export::to_csv_string(&[&naive.history, &noniid.history]);
    std::fs::write("ablation_histories.csv", &csv)?;
    println!(
        "\nwrote ablation_histories.csv ({} rows)",
        csv.lines().count() - 1
    );
    Ok(())
}
