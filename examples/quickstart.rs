//! Quickstart: train CodedFedL on the tiny preset in a few seconds.
//!
//! ```sh
//! make artifacts                      # once
//! cargo run --release --example quickstart
//! ```
//!
//! Walks the full public API: config → runtime → setup → scheme run →
//! metrics.

use codedfedl::benchutil;
use codedfedl::conf::{ExperimentConfig, Scheme};

fn main() -> anyhow::Result<()> {
    // 1. Pick a scale. `tiny` matches the `tiny` AOT artifact preset.
    let cfg = ExperimentConfig { epochs: 40, ..ExperimentConfig::tiny() };
    println!(
        "CodedFedL quickstart: n={} clients, q={}, m={} per step",
        cfg.clients,
        cfg.q,
        cfg.global_batch()
    );

    // 2. Run naive uncoded vs CodedFedL on the same fleet + data.
    let schemes = [Scheme::NaiveUncoded, Scheme::Coded { delta: 0.3 }];
    let (setup, results) = benchutil::run_experiment(&cfg, &schemes)?;
    println!(
        "fleet: fastest client mu={:.2} pts/s, slowest mu={:.2} pts/s, smoothness L={:.3}",
        setup.clients.iter().map(|c| c.mu).fold(0.0, f64::max),
        setup.clients.iter().map(|c| c.mu).fold(f64::INFINITY, f64::min),
        setup.smoothness,
    );

    // 3. Inspect outcomes.
    for (scheme, out) in &results {
        println!("\n=== {} ===", scheme.label());
        if let (Some(t), Some(u)) = (out.t_star, out.u_star) {
            println!("deadline t* = {t:.3} s, redundancy u* = {u} parity rows/round");
        }
        for p in out.history.points.iter().step_by(4) {
            println!(
                "  iter {:>3}  sim {:>8.1} s  acc {:.3}  loss {:.4}",
                p.iter, p.sim_time, p.accuracy, p.train_loss
            );
        }
        println!(
            "  final acc {:.3} in {:.1} simulated s",
            out.history.final_accuracy(),
            out.history.total_sim_time()
        );
    }

    // 4. The headline comparison: simulated time per round.
    let naive_t = results[0].1.history.total_sim_time();
    let coded_t = results[1].1.history.total_sim_time();
    println!(
        "\ncoded/naive simulated-time ratio: {:.2}x faster",
        naive_t / coded_t
    );
    Ok(())
}
