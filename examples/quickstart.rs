//! Quickstart: train CodedFedL on the tiny preset in a few seconds.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! Walks the canonical public API: **ExperimentBuilder → Session →
//! Scheme runs → metrics**. One `Session` owns the shared data, fleet and
//! runtime; every scheme you run on it sees identical conditions, which is
//! what makes the comparison fair.

use codedfedl::schemes::{CodedFedL, NaiveUncoded};
use codedfedl::ExperimentBuilder;

fn main() -> anyhow::Result<()> {
    // 1. Build a session: pick a preset, override fields, `build()`.
    //    (Validation errors name the offending config field.)
    let session = ExperimentBuilder::preset("tiny")?.epochs(40).build()?;
    let cfg = session.config();
    println!(
        "CodedFedL quickstart: n={} clients, q={}, m={} per step ({} backend)",
        cfg.clients,
        cfg.q,
        cfg.global_batch(),
        session.runtime().backend_name(),
    );
    let setup = session.setup();
    println!(
        "fleet: fastest client mu={:.2} pts/s, slowest mu={:.2} pts/s, smoothness L={:.3}",
        setup.clients.iter().map(|c| c.mu).fold(0.0, f64::max),
        setup.clients.iter().map(|c| c.mu).fold(f64::INFINITY, f64::min),
        setup.smoothness,
    );

    // 2. Run naive uncoded vs CodedFedL on the same fleet + data. Schemes
    //    are plain structs implementing the `Scheme` trait — write your
    //    own and pass it to `session.run` the same way.
    let naive = session.run(&mut NaiveUncoded::new())?;
    let coded = session.run(&mut CodedFedL::new(0.3))?;

    // 3. Inspect outcomes.
    for out in [&naive, &coded] {
        println!("\n=== {} ===", out.history.label);
        if let (Some(t), Some(u)) = (out.t_star, out.u_star) {
            println!("deadline t* = {t:.3} s, redundancy u* = {u} parity rows/round");
        }
        for p in out.history.points.iter().step_by(4) {
            println!(
                "  iter {:>3}  sim {:>8.1} s  acc {:.3}  loss {:.4}",
                p.iter, p.sim_time, p.accuracy, p.train_loss
            );
        }
        println!(
            "  final acc {:.3} in {:.1} simulated s",
            out.history.final_accuracy(),
            out.history.total_sim_time()
        );
    }

    // 4. The headline comparison: simulated time per round.
    println!(
        "\ncoded/naive simulated-time ratio: {:.2}x faster",
        naive.history.total_sim_time() / coded.history.total_sim_time()
    );
    Ok(())
}
