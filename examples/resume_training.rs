//! Crash-consistent checkpointing: interrupt a run, resume it, and get
//! the *bit-identical* model the uninterrupted run would have produced.
//!
//! ```sh
//! cargo run --release --example resume_training
//! ```
//!
//! The coordinator snapshots its full training state — θ, the simulated
//! clock, the round index, every sequential RNG stream position, the
//! outcome histogram and the evaluated history — every `[checkpoint]
//! every` rounds and at graceful shutdown, always through an atomic
//! temp-file + fsync + rename write, so a crash mid-write can never
//! tear the file. `resume = "auto"` picks the snapshot back up.
//!
//! Three acts:
//! 1. the uninterrupted golden run;
//! 2. an "interrupted" run — half the schedule with checkpointing on,
//!    then `resume = "auto"` into the full schedule — which must land on
//!    the golden θ bit for bit;
//! 3. chaos: `faults = "server:rate=0.5"` kills-and-restarts the
//!    coordinator in-process mid-round, every other round on average,
//!    and the run *still* lands on the golden θ — kills cost replayed
//!    work, never a different answer.

use codedfedl::schemes::CodedFedL;
use codedfedl::sim::fault::FaultSpec;
use codedfedl::{ExperimentBuilder, ResumeSpec};

/// FNV-1a over θ's bits: equal hashes ⇒ bit-identical models.
fn theta_hash(theta: &codedfedl::tensor::Mat) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &v in theta.as_slice() {
        for b in v.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    }
    h
}

fn main() -> anyhow::Result<()> {
    let epochs = 8;
    let ckpt = std::env::temp_dir().join("resume_training_example.ckpt");
    let ckpt_path = ckpt.to_string_lossy().into_owned();
    let _ = std::fs::remove_file(&ckpt);

    // Act 1 — the uninterrupted run: the golden answer.
    let session = ExperimentBuilder::preset("tiny")?.epochs(epochs).build()?;
    let golden = session.run(&mut CodedFedL::new(0.3))?;
    println!(
        "golden run:      {} rounds, final acc {:.4}, theta {:016x}",
        golden.history.points.len(),
        golden.history.final_accuracy(),
        theta_hash(&golden.theta)
    );

    // Act 2 — the interrupted run: half the schedule with per-round
    // checkpointing (a real deployment would checkpoint every 50–1000
    // rounds; the snapshot cost is on the tracked bench surface as
    // `checkpoint::snapshot`). The graceful-shutdown snapshot is what
    // the resume picks up.
    let half = ExperimentBuilder::preset("tiny")?
        .epochs(epochs / 2)
        .checkpoint_every(1)
        .checkpoint_path(Some(ckpt_path.clone()))
        .build()?;
    half.run(&mut CodedFedL::new(0.3))?;
    println!("interrupted at epoch {} — checkpoint on disk: {ckpt_path}", epochs / 2);

    // …and the resumed run: `auto` finds the checkpoint (the config
    // fingerprint is verified — a snapshot from a *different* experiment
    // or scheme is rejected by name, never trained from) and finishes
    // the full schedule.
    let resumed_session = ExperimentBuilder::preset("tiny")?
        .epochs(epochs)
        .checkpoint_path(Some(ckpt_path.clone()))
        .resume(ResumeSpec::Auto)
        .build()?;
    let resumed = resumed_session.run(&mut CodedFedL::new(0.3))?;
    println!(
        "resumed run:     restarted at round {:?}, final acc {:.4}, theta {:016x}",
        resumed.resumed_from,
        resumed.history.final_accuracy(),
        theta_hash(&resumed.theta)
    );
    anyhow::ensure!(
        theta_hash(&resumed.theta) == theta_hash(&golden.theta),
        "resumed theta diverged from the uninterrupted run"
    );

    // Act 3 — chaos: the server fault kills the coordinator mid-round
    // (in-process) and recovery restores the latest snapshot and
    // replays. The kill draw rides its own RNG stream, so the realized
    // history is still the golden one, bit for bit.
    let _ = std::fs::remove_file(&ckpt);
    let chaotic_session = ExperimentBuilder::preset("tiny")?
        .epochs(epochs)
        .faults(FaultSpec::Server { rate: 0.5 })
        .checkpoint_every(1)
        .checkpoint_path(Some(ckpt_path.clone()))
        .build()?;
    let chaotic = chaotic_session.run(&mut CodedFedL::new(0.3))?;
    println!(
        "chaos run:       server killed mid-round ~every other round, theta {:016x}",
        theta_hash(&chaotic.theta)
    );
    anyhow::ensure!(
        theta_hash(&chaotic.theta) == theta_hash(&golden.theta),
        "server-kill recovery diverged from the uninterrupted run"
    );

    println!("all three runs produced the bit-identical model.");
    let _ = std::fs::remove_file(&ckpt);
    Ok(())
}
