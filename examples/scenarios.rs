//! Scenario comparison: naive / greedy / coded under non-stationary
//! networks.
//!
//! ```sh
//! cargo run --release --example scenarios
//! ```
//!
//! The paper evaluates a fixed fleet; real edge networks drop clients and
//! fade. This example runs the three schemes under the `static`,
//! `dropout` and `fading` scenarios (same data, same base fleet, and —
//! per scenario — the same network realisation for every scheme) and
//! tabulates final accuracy and simulated wall-clock. CodedFedL's fixed
//! deadline t* absorbs dropouts and fades that stretch the uncoded
//! schemes' waiting times, while its parity gradient keeps the update
//! direction honest when clients vanish mid-training.

use codedfedl::schemes::SchemeSpec;
use codedfedl::sim::scenario::ScenarioSpec;
use codedfedl::ExperimentBuilder;

fn main() -> anyhow::Result<()> {
    let scenarios = [
        ScenarioSpec::Static,
        ScenarioSpec::Dropout { rate: 0.2 },
        ScenarioSpec::Fading { depth: 0.6, period: 10.0 },
    ];
    let schemes = [
        SchemeSpec::NaiveUncoded,
        SchemeSpec::GreedyUncoded { psi: 0.2 },
        SchemeSpec::Coded { delta: 0.3 },
    ];

    println!(
        "{:<32} {:>10} {:>14} {:>8}",
        "scenario / scheme", "final acc", "sim time (s)", "t*"
    );
    for scenario in scenarios {
        // One session per scenario: every scheme below shares the data,
        // fleet AND the scenario's per-round realisation (fair comparison).
        let session = ExperimentBuilder::preset("tiny")?
            .epochs(12)
            .scenario(scenario)
            .build()?;
        println!("--- {} ---", scenario.label());
        let mut naive_time = None;
        for spec in schemes {
            let out = session.run_spec(spec)?;
            let t_star =
                out.t_star.map_or_else(|| "-".to_string(), |t| format!("{t:.2}"));
            println!(
                "{:<32} {:>10.4} {:>14.1} {:>8}",
                spec.label(),
                out.history.final_accuracy(),
                out.history.total_sim_time(),
                t_star
            );
            if spec == SchemeSpec::NaiveUncoded {
                naive_time = Some(out.history.total_sim_time());
            } else if let (SchemeSpec::Coded { .. }, Some(nt)) = (spec, naive_time) {
                println!(
                    "{:<32} coded finishes {:.1}x sooner than naive here",
                    "", nt / out.history.total_sim_time()
                );
            }
        }
    }
    Ok(())
}
