//! Chaos sweep (robustness): every scheme × scenario × fault mix ×
//! deadline mode runs a few rounds to completion — no panic, finite θ,
//! monotone clocks, one degradation-ladder rung recorded per round —
//! and stays bit-reproducible across thread counts for each SIMD policy.
//!
//! The sweep is seeded and deterministic: the fault stream is split off
//! the experiment root independently of the scheme, so every scheme in a
//! combo faces the identical fault realisation, and a combo that passes
//! once passes forever.

use codedfedl::coding::RecoveryMode;
use codedfedl::conf::ExperimentConfig;
use codedfedl::coordinator::EventLog;
use codedfedl::metrics::RoundOutcome;
use codedfedl::schemes::{CodedFedL, SchemeSpec};
use codedfedl::sim::fault::{DeadlineSpec, FaultSpec};
use codedfedl::sim::scenario::ScenarioSpec;
use codedfedl::tensor::SimdPolicy;
use codedfedl::{ExperimentBuilder, Session};

const SCENARIOS: [ScenarioSpec; 3] = [
    ScenarioSpec::Static,
    ScenarioSpec::Dropout { rate: 0.3 },
    ScenarioSpec::Burst { slow: 0.3, factor: 4.0 },
];

const FAULTS: [FaultSpec; 5] = [
    FaultSpec::None,
    FaultSpec::Crash { rate: 0.4 },
    FaultSpec::Link { rate: 0.4, retry: 1 },
    FaultSpec::Parity { rate: 0.5 },
    FaultSpec::Mixed { crash: 0.3, link: 0.3, parity: 0.5 },
];

const DEADLINES: [DeadlineSpec; 3] = [
    DeadlineSpec::None,
    DeadlineSpec::Quantile { q: 0.8 },
    DeadlineSpec::Fixed { t: 30.0 },
];

fn combo_session(scenario: ScenarioSpec, faults: FaultSpec, deadline: DeadlineSpec) -> Session {
    let cfg = ExperimentConfig {
        epochs: 2, // tiny: 2 steps/epoch → 4 rounds per run
        scenario,
        faults,
        deadline,
        ..ExperimentConfig::tiny()
    };
    ExperimentBuilder::from_config(cfg).build().unwrap()
}

/// Run one scheme on a combo session and assert the chaos invariants.
fn assert_survives(session: &Session, scheme: &mut dyn codedfedl::Scheme, tag: &str) {
    let mut log = EventLog::default();
    let out = session.run_observed(scheme, &mut log).unwrap();
    let total = session.config().total_iters();

    // θ is finite — the degradation ladder never produces NaN/∞.
    assert!(out.theta.as_slice().iter().all(|v| v.is_finite()), "{tag}: non-finite theta");
    // One ladder rung is recorded per round, evaluated or not.
    assert_eq!(out.outcomes.total(), total as u64, "{tag}: rung histogram");
    // With the default eval_every = 1 every round emits an event carrying
    // its rung, achieved ≤ planned participation, and finite telemetry.
    assert_eq!(log.events.len(), total, "{tag}: event count");
    let mut prev_clock = 0.0;
    for ev in &log.events {
        assert!(ev.arrivals <= ev.planned, "{tag}: iter {}", ev.iter);
        assert!(ev.loss.is_finite() && ev.acc.is_finite(), "{tag}: iter {}", ev.iter);
        // The simulated clock is monotone — a skipped round still charges
        // what the server actually waited, never negative time.
        assert!(ev.clock >= prev_clock, "{tag}: clock went backwards at iter {}", ev.iter);
        prev_clock = ev.clock;
        // The skip rung means *nothing* entered the aggregate.
        if ev.outcome == RoundOutcome::Skip {
            assert_eq!(ev.arrivals, 0, "{tag}: skip with arrivals at iter {}", ev.iter);
        }
    }
}

fn run_combo(scenario: ScenarioSpec, faults: FaultSpec, deadline: DeadlineSpec) {
    let session = combo_session(scenario, faults, deadline);
    let combo = format!("{} / {} / {}", scenario.label(), faults.label(), deadline.label());
    for spec in [
        SchemeSpec::NaiveUncoded,
        SchemeSpec::GreedyUncoded { psi: 0.2 },
        SchemeSpec::Coded { delta: 0.3 },
    ] {
        let mut scheme = spec.build();
        assert_survives(&session, scheme.as_mut(), &format!("{} / {combo}", spec.label()));
    }
    // Exact recovery rides the same session, exercising the decode rungs
    // of the ladder under loss.
    let mut exact = CodedFedL::new(0.3).with_recovery(RecoveryMode::Exact);
    assert_survives(&session, &mut exact, &format!("coded-exact / {combo}"));
}

#[test]
fn every_scheme_survives_every_fault_deadline_scenario_combo() {
    for scenario in SCENARIOS {
        for faults in FAULTS {
            for deadline in DEADLINES {
                run_combo(scenario, faults, deadline);
            }
        }
    }
}

#[test]
fn crash_rate_one_skips_every_round_and_leaves_theta_untouched() {
    // Satellite regression: zero clients ever return AND the parity unit
    // is lost — every scheme must take the documented skip rung every
    // round (θ stays exactly at its zero initialisation, no 0/0, no NaN).
    let session = combo_session(
        ScenarioSpec::Static,
        FaultSpec::Mixed { crash: 1.0, link: 0.0, parity: 1.0 },
        DeadlineSpec::None,
    );
    let total = session.config().total_iters() as u64;
    for spec in [
        SchemeSpec::NaiveUncoded,
        SchemeSpec::GreedyUncoded { psi: 0.2 },
        SchemeSpec::Coded { delta: 0.3 },
    ] {
        let mut log = EventLog::default();
        let mut scheme = spec.build();
        let out = session.run_observed(scheme.as_mut(), &mut log).unwrap();
        assert_eq!(out.outcomes.skip, total, "{}: not all rounds skipped", spec.label());
        assert_eq!(out.outcomes.degraded(), total, "{}", spec.label());
        assert!(
            out.theta.as_slice().iter().all(|&v| v == 0.0),
            "{}: theta moved on an all-skip run",
            spec.label()
        );
        // The clock still advances: the surviving downlink completions
        // price what the server waited before giving up on each round.
        assert!(log.events.iter().all(|ev| ev.arrivals == 0), "{}", spec.label());
        assert!(out.history.total_sim_time() > 0.0, "{}", spec.label());
    }

    // Crash alone (parity unit alive) lets the coded scheme climb off the
    // skip rung whenever the MEC unit makes t*: those rounds resolve as
    // parity compensation in expectation. No round can be full — zero of
    // the planned client gradients ever arrive — and θ stays finite
    // either way (the parity scale 1/((1-pnr)·u*) is finite by setup).
    let session = combo_session(
        ScenarioSpec::Static,
        FaultSpec::Crash { rate: 1.0 },
        DeadlineSpec::None,
    );
    let out = session.run_spec(SchemeSpec::Coded { delta: 0.3 }).unwrap();
    assert_eq!(out.outcomes.full, 0);
    assert_eq!(out.outcomes.exact_decode, 0);
    assert_eq!(out.outcomes.partial, 0);
    assert_eq!(out.outcomes.parity + out.outcomes.skip, total);
    assert!(out.theta.as_slice().iter().all(|v| v.is_finite()));
}

#[test]
fn degraded_runs_are_bit_reproducible_across_threads_and_simd() {
    // The heaviest combo: dropout scenario + mixed faults + quantile
    // deadline. For each SIMD policy, any thread count must reproduce the
    // serial run bit-for-bit — fault draws, deadline cuts and ladder
    // rungs included.
    let run = |threads: usize, simd: SimdPolicy| {
        let cfg = ExperimentConfig {
            epochs: 2,
            scenario: ScenarioSpec::Dropout { rate: 0.3 },
            faults: FaultSpec::Mixed { crash: 0.3, link: 0.3, parity: 0.5 },
            deadline: DeadlineSpec::Quantile { q: 0.8 },
            threads,
            simd,
            ..ExperimentConfig::tiny()
        };
        let session = ExperimentBuilder::from_config(cfg).build().unwrap();
        let mut log = EventLog::default();
        let out = session.run_observed(&mut CodedFedL::new(0.3), &mut log).unwrap();
        (out, log)
    };
    for simd in [SimdPolicy::Scalar, SimdPolicy::Auto] {
        let (serial, slog) = run(1, simd);
        let (parallel, plog) = run(4, simd);
        assert_eq!(serial.theta.as_slice(), parallel.theta.as_slice(), "{simd:?}");
        assert_eq!(serial.outcomes, parallel.outcomes, "{simd:?}");
        assert_eq!(slog.events, plog.events, "{simd:?}");
    }
}
