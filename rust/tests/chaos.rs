//! Chaos sweep (robustness): every scheme × scenario × fault mix ×
//! deadline mode runs a few rounds to completion — no panic, finite θ,
//! monotone clocks, one degradation-ladder rung recorded per round —
//! and stays bit-reproducible across thread counts for each SIMD policy.
//!
//! The sweep is seeded and deterministic: the fault stream is split off
//! the experiment root independently of the scheme, so every scheme in a
//! combo faces the identical fault realisation, and a combo that passes
//! once passes forever.
//!
//! `CODEDFEDL_FAULTS` (the CI chaos legs, e.g. `server:rate=0.2`)
//! overrides the fault mix of the sweep and the thread/SIMD
//! reproducibility test, so the whole suite re-runs under any injected
//! fault kind — including in-process coordinator kills.

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};

use codedfedl::coding::RecoveryMode;
use codedfedl::conf::ExperimentConfig;
use codedfedl::coordinator::{EventLog, RoundEvent};
use codedfedl::metrics::RoundOutcome;
use codedfedl::schemes::{CodedFedL, SchemeSpec};
use codedfedl::sim::fault::{DeadlineSpec, FaultSpec};
use codedfedl::sim::scenario::ScenarioSpec;
use codedfedl::tensor::SimdPolicy;
use codedfedl::{ExperimentBuilder, Session};

const SCENARIOS: [ScenarioSpec; 3] = [
    ScenarioSpec::Static,
    ScenarioSpec::Dropout { rate: 0.3 },
    ScenarioSpec::Burst { slow: 0.3, factor: 4.0 },
];

const FAULTS: [FaultSpec; 7] = [
    FaultSpec::None,
    FaultSpec::Crash { rate: 0.4 },
    FaultSpec::Link { rate: 0.4, retry: 1 },
    FaultSpec::Parity { rate: 0.5 },
    FaultSpec::Mixed { crash: 0.3, link: 0.3, parity: 0.5 },
    FaultSpec::Server { rate: 0.4 },
    FaultSpec::Corrupt { rate: 0.4 },
];

static UNIQ: AtomicUsize = AtomicUsize::new(0);

/// A collision-free scratch path (tests in this binary run concurrently).
fn tmp_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!(
        "codedfedl_chaos_{}_{}_{tag}",
        std::process::id(),
        UNIQ.fetch_add(1, Ordering::Relaxed)
    ))
}

/// CI fault override: when set, the sweep and the reproducibility test
/// face this fault mix instead of their built-in one.
fn env_faults() -> Option<FaultSpec> {
    match std::env::var("CODEDFEDL_FAULTS") {
        Ok(v) => Some(v.parse().expect("CODEDFEDL_FAULTS")),
        Err(_) => None,
    }
}

/// The realized round timeline: `server:` kills replay rounds, and each
/// replayed round re-emits its event, so the raw observer stream can
/// rewind. Keeping only the *last* emission per iteration (dropping
/// everything a rewind superseded) reconstructs the history the run
/// actually settled on.
fn realized(events: &[RoundEvent]) -> Vec<RoundEvent> {
    let mut out: Vec<RoundEvent> = Vec::new();
    for ev in events {
        while out.last().is_some_and(|last| last.iter >= ev.iter) {
            out.pop();
        }
        out.push(*ev);
    }
    out
}

const DEADLINES: [DeadlineSpec; 3] = [
    DeadlineSpec::None,
    DeadlineSpec::Quantile { q: 0.8 },
    DeadlineSpec::Fixed { t: 30.0 },
];

fn combo_session(scenario: ScenarioSpec, faults: FaultSpec, deadline: DeadlineSpec) -> Session {
    let cfg = ExperimentConfig {
        epochs: 2, // tiny: 2 steps/epoch → 4 rounds per run
        scenario,
        faults,
        deadline,
        ..ExperimentConfig::tiny()
    };
    ExperimentBuilder::from_config(cfg).build().unwrap()
}

/// Run one scheme on a combo session and assert the chaos invariants.
fn assert_survives(session: &Session, scheme: &mut dyn codedfedl::Scheme, tag: &str) {
    let mut log = EventLog::default();
    let out = session.run_observed(scheme, &mut log).unwrap();
    let total = session.config().total_iters();

    // θ is finite — the degradation ladder never produces NaN/∞.
    assert!(out.theta.as_slice().iter().all(|v| v.is_finite()), "{tag}: non-finite theta");
    // One ladder rung is recorded per round, evaluated or not (server
    // kills rewind the histogram along with everything else, so replays
    // never double-count).
    assert_eq!(out.outcomes.total(), total as u64, "{tag}: rung histogram");
    // With the default eval_every = 1 every round emits an event carrying
    // its rung, achieved ≤ planned participation, and finite telemetry.
    // Under `server:` kills the raw stream holds replays; the realized
    // timeline must still be exactly one event per round.
    let events = realized(&log.events);
    assert_eq!(events.len(), total, "{tag}: event count");
    let mut prev_clock = 0.0;
    for ev in &events {
        assert!(ev.arrivals <= ev.planned, "{tag}: iter {}", ev.iter);
        assert!(ev.loss.is_finite() && ev.acc.is_finite(), "{tag}: iter {}", ev.iter);
        // The simulated clock is monotone — a skipped round still charges
        // what the server actually waited, never negative time.
        assert!(ev.clock >= prev_clock, "{tag}: clock went backwards at iter {}", ev.iter);
        prev_clock = ev.clock;
        // The skip rung means *nothing* entered the aggregate.
        if ev.outcome == RoundOutcome::Skip {
            assert_eq!(ev.arrivals, 0, "{tag}: skip with arrivals at iter {}", ev.iter);
        }
    }
}

fn run_combo(scenario: ScenarioSpec, faults: FaultSpec, deadline: DeadlineSpec) {
    let session = combo_session(scenario, faults, deadline);
    let combo = format!("{} / {} / {}", scenario.label(), faults.label(), deadline.label());
    for spec in [
        SchemeSpec::NaiveUncoded,
        SchemeSpec::GreedyUncoded { psi: 0.2 },
        SchemeSpec::Coded { delta: 0.3 },
    ] {
        let mut scheme = spec.build();
        assert_survives(&session, scheme.as_mut(), &format!("{} / {combo}", spec.label()));
    }
    // Exact recovery rides the same session, exercising the decode rungs
    // of the ladder under loss.
    let mut exact = CodedFedL::new(0.3).with_recovery(RecoveryMode::Exact);
    assert_survives(&session, &mut exact, &format!("coded-exact / {combo}"));
}

#[test]
fn every_scheme_survives_every_fault_deadline_scenario_combo() {
    // A CI fault override collapses the fault axis to the injected mix —
    // the whole scenario × deadline grid re-runs under it.
    let fault_axis: Vec<FaultSpec> = match env_faults() {
        Some(f) => vec![f],
        None => FAULTS.to_vec(),
    };
    for scenario in SCENARIOS {
        for &faults in &fault_axis {
            for deadline in DEADLINES {
                run_combo(scenario, faults, deadline);
            }
        }
    }
}

#[test]
fn crash_rate_one_skips_every_round_and_leaves_theta_untouched() {
    // Satellite regression: zero clients ever return AND the parity unit
    // is lost — every scheme must take the documented skip rung every
    // round (θ stays exactly at its zero initialisation, no 0/0, no NaN).
    let session = combo_session(
        ScenarioSpec::Static,
        FaultSpec::Mixed { crash: 1.0, link: 0.0, parity: 1.0 },
        DeadlineSpec::None,
    );
    let total = session.config().total_iters() as u64;
    for spec in [
        SchemeSpec::NaiveUncoded,
        SchemeSpec::GreedyUncoded { psi: 0.2 },
        SchemeSpec::Coded { delta: 0.3 },
    ] {
        let mut log = EventLog::default();
        let mut scheme = spec.build();
        let out = session.run_observed(scheme.as_mut(), &mut log).unwrap();
        assert_eq!(out.outcomes.skip, total, "{}: not all rounds skipped", spec.label());
        assert_eq!(out.outcomes.degraded(), total, "{}", spec.label());
        assert!(
            out.theta.as_slice().iter().all(|&v| v == 0.0),
            "{}: theta moved on an all-skip run",
            spec.label()
        );
        // The clock still advances: the surviving downlink completions
        // price what the server waited before giving up on each round.
        assert!(log.events.iter().all(|ev| ev.arrivals == 0), "{}", spec.label());
        assert!(out.history.total_sim_time() > 0.0, "{}", spec.label());
    }

    // Crash alone (parity unit alive) lets the coded scheme climb off the
    // skip rung whenever the MEC unit makes t*: those rounds resolve as
    // parity compensation in expectation. No round can be full — zero of
    // the planned client gradients ever arrive — and θ stays finite
    // either way (the parity scale 1/((1-pnr)·u*) is finite by setup).
    let session = combo_session(
        ScenarioSpec::Static,
        FaultSpec::Crash { rate: 1.0 },
        DeadlineSpec::None,
    );
    let out = session.run_spec(SchemeSpec::Coded { delta: 0.3 }).unwrap();
    assert_eq!(out.outcomes.full, 0);
    assert_eq!(out.outcomes.exact_decode, 0);
    assert_eq!(out.outcomes.partial, 0);
    assert_eq!(out.outcomes.parity + out.outcomes.skip, total);
    assert!(out.theta.as_slice().iter().all(|v| v.is_finite()));
}

#[test]
fn degraded_runs_are_bit_reproducible_across_threads_and_simd() {
    // The heaviest combo: dropout scenario + mixed faults + quantile
    // deadline. For each SIMD policy, any thread count must reproduce the
    // serial run bit-for-bit — fault draws, deadline cuts and ladder
    // rungs included.
    let run = |threads: usize, simd: SimdPolicy| {
        let cfg = ExperimentConfig {
            epochs: 2,
            scenario: ScenarioSpec::Dropout { rate: 0.3 },
            faults: env_faults()
                .unwrap_or(FaultSpec::Mixed { crash: 0.3, link: 0.3, parity: 0.5 }),
            deadline: DeadlineSpec::Quantile { q: 0.8 },
            threads,
            simd,
            ..ExperimentConfig::tiny()
        };
        let session = ExperimentBuilder::from_config(cfg).build().unwrap();
        let mut log = EventLog::default();
        let out = session.run_observed(&mut CodedFedL::new(0.3), &mut log).unwrap();
        (out, log)
    };
    for simd in [SimdPolicy::Scalar, SimdPolicy::Auto] {
        let (serial, slog) = run(1, simd);
        let (parallel, plog) = run(4, simd);
        assert_eq!(serial.theta.as_slice(), parallel.theta.as_slice(), "{simd:?}");
        assert_eq!(serial.outcomes, parallel.outcomes, "{simd:?}");
        assert_eq!(slog.events, plog.events, "{simd:?}");
    }
}

#[test]
fn server_kills_replay_to_a_bit_identical_history() {
    // `server:rate=…` kills-and-restarts the coordinator mid-round from
    // its latest snapshot. The kill draw rides its own dedicated RNG
    // stream (excluded from `FaultPlan::is_active()`), so the realized
    // run must equal the fault-free run *bit for bit* — a kill costs
    // replayed work, never a different answer. Checked without
    // checkpointing (recovery restores the run-initial snapshot and
    // replays from round 0) and with per-round checkpointing (recovery
    // loses at most the interrupted round).
    let golden_session =
        combo_session(ScenarioSpec::Static, FaultSpec::None, DeadlineSpec::None);
    let mut glog = EventLog::default();
    let golden =
        golden_session.run_observed(&mut CodedFedL::new(0.3), &mut glog).unwrap();

    for rate in [0.4, 1.0] {
        for ckpt_every in [0usize, 1] {
            let tag = format!("server:rate={rate} ckpt_every={ckpt_every}");
            let ckpt = tmp_path("server.ckpt");
            let mut cfg = ExperimentConfig {
                epochs: 2,
                faults: FaultSpec::Server { rate },
                ..ExperimentConfig::tiny()
            };
            cfg.checkpoint_every = ckpt_every;
            if ckpt_every > 0 {
                cfg.checkpoint_path = Some(ckpt.to_string_lossy().into_owned());
            }
            let session = ExperimentBuilder::from_config(cfg).build().unwrap();
            let mut log = EventLog::default();
            let out = session.run_observed(&mut CodedFedL::new(0.3), &mut log).unwrap();
            assert_eq!(out.theta.as_slice(), golden.theta.as_slice(), "{tag}: theta");
            assert_eq!(out.outcomes, golden.outcomes, "{tag}: rung histogram");
            assert_eq!(out.history.points, golden.history.points, "{tag}: history");
            assert_eq!(realized(&log.events), glog.events, "{tag}: realized timeline");
            // rate = 1.0 kills every round at least once, so the raw
            // stream must visibly contain replays — proof the recovery
            // path actually ran rather than the draw never firing.
            if rate == 1.0 {
                assert!(log.events.len() > glog.events.len(), "{tag}: no replays seen");
            }
            let _ = std::fs::remove_file(&ckpt);
        }
    }
}

#[test]
fn corrupt_rate_one_excludes_every_gradient_and_stays_finite() {
    // Satellite regression: every client gradient is poisoned non-finite
    // every round. The fold must exclude them all — θ never sees a NaN.
    let session = combo_session(
        ScenarioSpec::Static,
        FaultSpec::Corrupt { rate: 1.0 },
        DeadlineSpec::None,
    );
    let total = session.config().total_iters() as u64;
    // Uncoded schemes fold client gradients only: with all of them
    // excluded, every round takes the documented skip rung and θ stays
    // exactly at its zero initialisation.
    for spec in [SchemeSpec::NaiveUncoded, SchemeSpec::GreedyUncoded { psi: 0.2 }] {
        let mut log = EventLog::default();
        let mut scheme = spec.build();
        let out = session.run_observed(scheme.as_mut(), &mut log).unwrap();
        assert_eq!(out.outcomes.skip, total, "{}: not all rounds skipped", spec.label());
        assert!(
            out.theta.as_slice().iter().all(|&v| v == 0.0),
            "{}: theta moved on an all-corrupt run",
            spec.label()
        );
        assert!(out.corrupted_total > 0, "{}", spec.label());
        let per_round: u64 = log.events.iter().map(|ev| ev.corrupted as u64).sum();
        assert_eq!(out.corrupted_total, per_round, "{}: corrupt accounting", spec.label());
        for ev in &log.events {
            assert_eq!(ev.arrivals, 0, "{}: iter {}", spec.label(), ev.iter);
            assert!(ev.corrupted > 0, "{}: iter {}", spec.label(), ev.iter);
            assert!(ev.loss.is_finite() && ev.acc.is_finite(), "{}", spec.label());
        }
    }
    // The coded scheme's server-side parity gradient is not a client
    // update, so it survives the purge: any round whose plan left
    // stragglers for the MEC unit to compensate resolves as parity
    // compensation; rounds that planned the full fleet (and so folded no
    // parity) fold nothing at all and take the skip rung. Either way no
    // round can be full and θ stays finite.
    let out = session.run_spec(SchemeSpec::Coded { delta: 0.3 }).unwrap();
    assert_eq!(out.outcomes.full, 0);
    assert_eq!(out.outcomes.exact_decode, 0);
    assert_eq!(out.outcomes.partial, 0);
    assert_eq!(out.outcomes.parity + out.outcomes.skip, total);
    assert!(out.corrupted_total > 0);
    assert!(out.theta.as_slice().iter().all(|v| v.is_finite()));
}
