//! Property-style equivalence tests for the blocked/parallel native
//! kernels (PR 2 tentpole, extended to the persistent worker pool in
//! PR 3 and to the SIMD microkernels in PR 4): every fast kernel is
//! pinned against the seed's serial reference implementation (ported
//! verbatim below) across awkward shapes — 0 rows, 1 column, sizes
//! straddling the register-tile width — and thread counts {1, 2, 4, 8},
//! and the pooled path is additionally pinned against an in-test
//! `std::thread::scope` driver replicating the pre-pool partitioning.
//!
//! Contract under test (see `rust/src/tensor` module docs): with the
//! scalar microkernel, `threads = 1` is **bit-for-bit** equal to the
//! serial reference; every other combination — other thread counts, or a
//! SIMD ISA's fused multiply-adds — must stay within 1e-4 max-abs-diff.
//! Each resolved ISA is additionally deterministic and thread-count
//! invariant (bitwise), which is tested directly.
//!
//! The sweeps run under the SIMD policy named by the `CODEDFEDL_SIMD`
//! env var (`scalar` | `auto`; default `auto`, the config default) — CI
//! runs this binary once per policy so the fallback path cannot rot.

use codedfedl::rng::Rng;
use codedfedl::runtime::native::NativeExec;
use codedfedl::schemes::CodedFedL;
use codedfedl::tensor::{gemm_into, gemm_pack_len, Isa, Mat, SimdPolicy};
use codedfedl::ExperimentBuilder;

fn randn(rows: usize, cols: usize, rng: &mut Rng) -> Mat {
    let mut m = Mat::zeros(rows, cols);
    rng.fill_normal_f32(m.as_mut_slice());
    m
}

/// Mask pattern mixing kept, dropped and fractional rows.
fn mask_for(l: usize) -> Vec<f32> {
    (0..l).map(|i| [1.0f32, 0.0, 0.5, 1.0][i % 4]).collect()
}

/// The SIMD policy this test binary sweeps under (CI matrix:
/// `CODEDFEDL_SIMD=scalar` / `auto`; unset behaves like the config
/// default, `auto`). A typo fails loudly rather than silently testing
/// the wrong path.
fn env_policy() -> SimdPolicy {
    match std::env::var("CODEDFEDL_SIMD") {
        Ok(v) => v.parse().expect("CODEDFEDL_SIMD"),
        Err(_) => SimdPolicy::Auto,
    }
}

/// Executor under test: `threads` workers, the env-selected SIMD policy.
fn exec(threads: usize) -> NativeExec {
    NativeExec::with_policy(threads, env_policy())
}

/// Assert equality under the documented contract: bit-for-bit when the
/// executor resolved the scalar ISA and runs one thread, ≤ 1e-4 otherwise.
fn assert_equiv(name: &str, ex: &NativeExec, threads: usize, got: &Mat, want: &Mat) {
    assert_eq!((got.rows(), got.cols()), (want.rows(), want.cols()), "{name}: shape");
    if ex.isa() == Isa::Scalar && threads == 1 {
        assert_eq!(
            got.as_slice(),
            want.as_slice(),
            "{name}: scalar threads=1 must be bit-for-bit equal to the serial reference"
        );
    } else {
        let d = got.max_abs_diff(want);
        assert!(
            d <= 1e-4,
            "{name}: threads={threads} isa={} diff {d} > 1e-4",
            ex.isa().name()
        );
    }
}

// ---------------------------------------------------------------------------
// Serial reference kernels: the seed implementation, ported verbatim.
// ---------------------------------------------------------------------------

/// Seed-native RFF embedding: `sqrt(2/q) · cos(x Ω + δ)` over `matmul_ref`.
fn ref_embed(x: &Mat, omega: &Mat, delta: &[f32]) -> Mat {
    let q = omega.cols();
    let xo = x.matmul_ref(omega);
    let scale = (2.0f32 / q as f32).sqrt();
    Mat::from_fn(x.rows(), q, |r, c| scale * (xo.get(r, c) + delta[c]).cos())
}

/// Seed-native masked gradient: full `matmul_ref`, separate mask pass,
/// zero-skipping accumulation.
fn ref_grad(xhat: &Mat, y: &Mat, theta: &Mat, mask: &[f32]) -> Mat {
    let (l, q) = (xhat.rows(), xhat.cols());
    let c = y.cols();
    let mut r = xhat.matmul_ref(theta);
    for i in 0..l {
        let m = mask[i];
        let rrow = &mut r.as_mut_slice()[i * c..(i + 1) * c];
        let yrow = y.row(i);
        for (rv, &yv) in rrow.iter_mut().zip(yrow) {
            *rv = m * (*rv - yv);
        }
    }
    let mut g = Mat::zeros(q, c);
    for i in 0..l {
        if mask[i] == 0.0 {
            continue;
        }
        let xrow = xhat.row(i);
        let rrow = r.row(i);
        let gs = g.as_mut_slice();
        for (k, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let grow = &mut gs[k * c..(k + 1) * c];
            for (gv, &rv) in grow.iter_mut().zip(rrow) {
                *gv += xv * rv;
            }
        }
    }
    g
}

/// Seed-native weighted encode with duplicated `g·w` products.
fn ref_encode(g: &Mat, w: &[f32], xhat: &Mat, y: &Mat, u_max: usize) -> (Mat, Mat) {
    let (u, l) = (g.rows(), g.cols());
    let (q, c) = (xhat.cols(), y.cols());
    let mut xp = Mat::zeros(u_max, q);
    let mut yp = Mat::zeros(u_max, c);
    for ui in 0..u {
        let grow = g.row(ui);
        let xrow_out = &mut xp.as_mut_slice()[ui * q..(ui + 1) * q];
        for li in 0..l {
            let gv = grow[li] * w[li];
            if gv == 0.0 {
                continue;
            }
            for (ov, &dv) in xrow_out.iter_mut().zip(xhat.row(li)) {
                *ov += gv * dv;
            }
        }
        let yrow_out = &mut yp.as_mut_slice()[ui * c..(ui + 1) * c];
        for li in 0..l {
            let gv = grow[li] * w[li];
            if gv == 0.0 {
                continue;
            }
            for (ov, &dv) in yrow_out.iter_mut().zip(y.row(li)) {
                *ov += gv * dv;
            }
        }
    }
    (xp, yp)
}

// ---------------------------------------------------------------------------
// The property sweeps.
// ---------------------------------------------------------------------------

/// (l, q, c) shapes: degenerate, tiny, tile-straddling, realistic, and one
/// large enough to clear the kernels' internal parallelism threshold so
/// `threads = 4` really exercises the scoped-thread path.
const GRAD_SHAPES: &[(usize, usize, usize)] = &[
    (0, 8, 3),
    (1, 1, 1),
    (5, 17, 1),
    (7, 16, 4),
    (13, 15, 10),
    (29, 33, 10),
    (40, 65, 7),
    (80, 100, 10),
];

#[test]
fn matmul_blocked_equals_reference_across_shapes_and_threads() {
    let mut rng = Rng::seed_from(101);
    for &(m, k, n) in
        &[(0usize, 5usize, 4usize), (1, 1, 1), (3, 17, 16), (9, 33, 31), (21, 8, 50), (60, 80, 20)]
    {
        let a = randn(m, k, &mut rng);
        let b = randn(k, n, &mut rng);
        let want = a.matmul_ref(&b);
        // Mat::matmul is the single-threaded *scalar* kernel — always
        // bit-for-bit reference-equal, whatever the SIMD policy.
        assert_eq!(a.matmul(&b).as_slice(), want.as_slice(), "Mat::matmul ({m},{k},{n})");
        // the threaded (and ISA-dispatched) path is exercised through
        // NativeExec::predict
        for threads in [1usize, 2, 4, 8] {
            let ex = exec(threads);
            let got = ex.predict(&a, &b);
            assert_equiv("predict", &ex, threads, &got, &want);
        }
    }
}

#[test]
fn grad_equals_reference_across_shapes_and_threads() {
    let mut rng = Rng::seed_from(102);
    for &(l, q, c) in GRAD_SHAPES {
        let xhat = randn(l, q, &mut rng);
        let y = randn(l, c, &mut rng);
        let theta = randn(q, c, &mut rng);
        let mask = mask_for(l);
        let want = ref_grad(&xhat, &y, &theta, &mask);
        for threads in [1usize, 2, 4, 8] {
            let ex = exec(threads);
            let got = ex.grad(&xhat, &y, &theta, &mask);
            assert_equiv("grad", &ex, threads, &got, &want);
        }
    }
}

#[test]
fn embed_equals_reference_across_shapes_and_threads() {
    let mut rng = Rng::seed_from(103);
    for &(n, d, q) in
        &[(0usize, 4usize, 8usize), (1, 1, 1), (6, 9, 17), (33, 16, 48), (40, 7, 65), (70, 40, 48)]
    {
        let x = randn(n, d, &mut rng);
        let omega = randn(d, q, &mut rng);
        let delta: Vec<f32> = (0..q).map(|_| rng.next_f32() * 6.28).collect();
        let want = ref_embed(&x, &omega, &delta);
        for threads in [1usize, 2, 4, 8] {
            let ex = exec(threads);
            let got = ex.embed(&x, &omega, &delta);
            assert_equiv("embed", &ex, threads, &got, &want);
        }
    }
}

#[test]
fn encode_equals_reference_across_shapes_and_threads() {
    let mut rng = Rng::seed_from(104);
    // (u, l, q, c, u_max)
    for &(u, l, q, c, u_max) in &[
        (0usize, 5usize, 8usize, 3usize, 4usize),
        (1, 1, 1, 1, 1),
        (3, 7, 17, 1, 5),
        (13, 10, 33, 10, 16),
        (40, 20, 65, 6, 64),
        (50, 40, 64, 8, 64),
    ] {
        let g = randn(u, l, &mut rng);
        let w: Vec<f32> = (0..l).map(|i| if i % 5 == 0 { 0.0 } else { rng.next_f32() }).collect();
        let xhat = randn(l, q, &mut rng);
        let y = randn(l, c, &mut rng);
        let (want_x, want_y) = ref_encode(&g, &w, &xhat, &y, u_max);
        for threads in [1usize, 2, 4, 8] {
            let ex = exec(threads);
            let (got_x, got_y) = ex.encode(&g, &w, &xhat, &y, u_max);
            assert_equiv("encode.x", &ex, threads, &got_x, &want_x);
            assert_equiv("encode.y", &ex, threads, &got_y, &want_y);
        }
    }
}

#[test]
fn grad_with_exact_zero_features_still_matches() {
    // The seed kernel skipped zero entries; the blocked kernel does not.
    // Adding `0.0 * r` terms must not change any bit of the result.
    let mut rng = Rng::seed_from(105);
    let mut xhat = randn(12, 20, &mut rng);
    for (i, v) in xhat.as_mut_slice().iter_mut().enumerate() {
        if i % 3 == 0 {
            *v = 0.0;
        }
    }
    let y = randn(12, 4, &mut rng);
    let theta = randn(20, 4, &mut rng);
    let mask = mask_for(12);
    let want = ref_grad(&xhat, &y, &theta, &mask);
    let ex = NativeExec::with_policy(1, env_policy());
    let got = ex.grad(&xhat, &y, &theta, &mask);
    assert_equiv("grad(sparse)", &ex, 1, &got, &want);
}

// ---------------------------------------------------------------------------
// Pool-era additions (PR 3): the persistent-pool path vs the pre-pool
// `std::thread::scope` driver vs the serial kernel, and worker reuse.
// ---------------------------------------------------------------------------

/// The pre-pool parallel driver, rebuilt in-test: balanced contiguous row
/// blocks, one `thread::scope` spawn per block, the blocked matmul per
/// block — exactly the partitioning `runtime::native` used before the
/// worker pool. The pool must reproduce it bit-for-bit.
fn scoped_predict(xhat: &Mat, theta: &Mat, threads: usize) -> Mat {
    let n = xhat.rows();
    let c = theta.cols();
    let mut out = Mat::zeros(n, c);
    if n == 0 || xhat.cols() == 0 || c == 0 {
        return out;
    }
    let t = threads.min(n).max(1);
    let (base, extra) = (n / t, n % t);
    std::thread::scope(|s| {
        let mut rest = out.as_mut_slice();
        let mut r0 = 0;
        for part in 0..t {
            let rows_here = base + usize::from(part < extra);
            let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(rows_here * c);
            rest = tail;
            s.spawn(move || {
                let block = xhat.rows_view(r0, rows_here).matmul(theta);
                chunk.copy_from_slice(block.as_slice());
            });
            r0 += rows_here;
        }
    });
    out
}

#[test]
fn pool_matches_scoped_threads_and_serial_bit_for_bit() {
    // Pinned to the scalar microkernel: the in-test thread::scope driver
    // runs the scalar Mat::matmul, and simd=scalar is the policy whose
    // bits must match the pre-pool (and pre-SIMD) backend exactly.
    let mut rng = Rng::seed_from(106);
    // Includes shapes above the internal parallelism threshold so the pool
    // dispatch (not just the inline part-0 path) really runs.
    for &(n, q, c) in &[(7usize, 16usize, 4usize), (40, 65, 7), (80, 100, 16), (128, 128, 10)] {
        let xhat = randn(n, q, &mut rng);
        let theta = randn(q, c, &mut rng);
        let serial = NativeExec::with_policy(1, SimdPolicy::Scalar).predict(&xhat, &theta);
        for threads in [1usize, 2, 8] {
            let pooled =
                NativeExec::with_policy(threads, SimdPolicy::Scalar).predict(&xhat, &theta);
            let scoped = scoped_predict(&xhat, &theta, threads);
            assert_eq!(
                pooled.as_slice(),
                serial.as_slice(),
                "predict({n}x{q}x{c}): pool at {threads} threads diverged from serial"
            );
            assert_eq!(
                pooled.as_slice(),
                scoped.as_slice(),
                "predict({n}x{q}x{c}): pool at {threads} threads diverged from thread::scope"
            );
        }
    }
}

#[test]
fn grad_is_pool_invariant_at_1_2_8_threads() {
    // The round loop's kernel: serial reference vs the pooled scalar
    // kernel at {1, 2, 8}, bit-for-bit (stronger than the documented 1e-4
    // bound — this is what keeps training histories thread-count
    // invariant and simd=scalar histories PR-3-identical).
    let mut rng = Rng::seed_from(107);
    for &(l, q, c) in &[(13usize, 15usize, 10usize), (40, 65, 7), (128, 128, 10)] {
        let xhat = randn(l, q, &mut rng);
        let y = randn(l, c, &mut rng);
        let theta = randn(q, c, &mut rng);
        let mask = mask_for(l);
        let want = ref_grad(&xhat, &y, &theta, &mask);
        for threads in [1usize, 2, 8] {
            let got =
                NativeExec::with_policy(threads, SimdPolicy::Scalar).grad(&xhat, &y, &theta, &mask);
            assert_eq!(
                got.as_slice(),
                want.as_slice(),
                "grad({l}x{q}x{c}) diverged from the serial reference at {threads} threads"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// SIMD-era additions (PR 4): the ISA-dispatched microkernel vs the
// matmul_ref oracle over awkward GEMM shapes, and per-ISA determinism.
// ---------------------------------------------------------------------------

/// (m, k, n) shapes chosen to hit every remainder path of the
/// microkernels: empty output, k = 0, single row, n < the 16-wide tile,
/// n % 16 ≠ 0, rows % GEMM_MR ≠ 0, and tile-aligned panels.
const GEMM_SHAPES: &[(usize, usize, usize)] = &[
    (0, 5, 7),
    (3, 0, 4),
    (1, 1, 1),
    (1, 64, 16),
    (2, 9, 3),
    (3, 17, 15),
    (5, 33, 16),
    (6, 20, 17),
    (7, 11, 47),
    (9, 40, 32),
    (13, 128, 10),
];

/// Seeded-random matmul vs `matmul_ref` over the awkward shapes, under
/// both policies: `scalar` must be bit-exact, the detected ISA must stay
/// within 1e-4 and be run-to-run deterministic.
#[test]
fn gemm_awkward_shapes_match_reference_under_both_policies() {
    let mut rng = Rng::seed_from(108);
    let run = |isa: Isa, a: &Mat, b: &Mat| {
        let mut out = Mat::zeros(a.rows(), b.cols());
        let mut pack = vec![0.0f32; gemm_pack_len(a.cols())];
        gemm_into(
            isa,
            a.as_slice(),
            b.as_slice(),
            out.as_mut_slice(),
            a.cols(),
            b.cols(),
            &mut pack,
        );
        out
    };
    let auto = Isa::detect(SimdPolicy::Auto);
    for &(m, k, n) in GEMM_SHAPES {
        let a = randn(m, k, &mut rng);
        let b = randn(k, n, &mut rng);
        let want = a.matmul_ref(&b);
        // simd = scalar: exact
        let scalar = run(Isa::Scalar, &a, &b);
        assert_eq!(scalar.as_slice(), want.as_slice(), "scalar ({m},{k},{n})");
        // simd = auto (whatever this host resolved): ≤ 1e-4 and
        // deterministic across repeated runs
        let fast = run(auto, &a, &b);
        let d = fast.max_abs_diff(&want);
        assert!(d <= 1e-4, "{} ({m},{k},{n}): diff {d} > 1e-4", auto.name());
        assert_eq!(
            fast.as_slice(),
            run(auto, &a, &b).as_slice(),
            "{} ({m},{k},{n}) is not deterministic",
            auto.name()
        );
    }
}

/// Whatever ISA `auto` resolves, thread counts must not change a bit:
/// an element's lane and op sequence depend only on its position, never
/// on the pool's row partition.
#[test]
fn auto_isa_is_thread_count_invariant_bitwise() {
    let mut rng = Rng::seed_from(109);
    let xhat = randn(96, 80, &mut rng);
    let y = randn(96, 10, &mut rng);
    let theta = randn(80, 10, &mut rng);
    let mask = mask_for(96);
    let delta = vec![0.25f32; 10];
    let base = NativeExec::with_policy(1, SimdPolicy::Auto);
    for threads in [2usize, 3, 8] {
        let ex = NativeExec::with_policy(threads, SimdPolicy::Auto);
        assert_eq!(ex.isa(), base.isa(), "auto must resolve identically in one process");
        assert_eq!(
            base.grad(&xhat, &y, &theta, &mask).as_slice(),
            ex.grad(&xhat, &y, &theta, &mask).as_slice(),
            "grad diverged at {threads} threads on {}",
            ex.isa().name()
        );
        assert_eq!(
            base.predict(&xhat, &theta).as_slice(),
            ex.predict(&xhat, &theta).as_slice(),
            "predict diverged at {threads} threads on {}",
            ex.isa().name()
        );
        assert_eq!(
            base.embed(&xhat, &theta, &delta).as_slice(),
            ex.embed(&xhat, &theta, &delta).as_slice(),
            "embed diverged at {threads} threads on {}",
            ex.isa().name()
        );
    }
}

#[test]
fn session_runs_reuse_pool_workers_with_stable_exec_count() {
    use std::collections::HashSet;
    use std::sync::Mutex;

    let session = ExperimentBuilder::preset("tiny")
        .unwrap()
        .epochs(2)
        .threads(3)
        .build()
        .unwrap();
    let rt = session.runtime();
    let pool = rt.worker_pool().expect("native backend");
    assert_eq!(pool.threads(), 3);
    let participant_ids = || {
        let seen = Mutex::new(HashSet::new());
        pool.run(3, &|_part, _scratch| {
            seen.lock().unwrap().insert(std::thread::current().id());
        });
        seen.into_inner().unwrap()
    };
    let workers_before = participant_ids();
    assert_eq!(workers_before.len(), 3, "3 parts must land on 3 distinct threads");

    // Two identical runs: the same parked workers service both (no
    // per-round thread churn) and the executor is invoked the exact same
    // number of times, producing the exact same model.
    let c0 = rt.exec_count();
    let r1 = session.run(&mut CodedFedL::new(0.3)).unwrap();
    let c1 = rt.exec_count();
    let r2 = session.run(&mut CodedFedL::new(0.3)).unwrap();
    let c2 = rt.exec_count();
    assert_eq!(c1 - c0, c2 - c1, "exec_count must be identical across identical runs");
    assert!(c1 > c0, "runs must actually execute kernels");
    assert_eq!(r1.theta.as_slice(), r2.theta.as_slice());

    let workers_after = participant_ids();
    assert_eq!(
        workers_before, workers_after,
        "Session::run must reuse the pool's parked workers, not spawn new ones"
    );
}
