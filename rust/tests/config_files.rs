//! The shipped config files must stay parseable and consistent with the
//! AOT shape presets they name.

use std::path::Path;

use codedfedl::conf::ExperimentConfig;

#[test]
fn default_config_parses_and_matches_preset() {
    let c = ExperimentConfig::from_file(Path::new("configs/default.toml")).unwrap();
    let d = ExperimentConfig::default();
    assert_eq!(c.clients, d.clients);
    assert_eq!(c.q, d.q);
    assert_eq!(c.local_batch, d.local_batch);
    assert_eq!(c.u_max, d.u_max);
    assert_eq!(c.lr_decay_epochs, d.lr_decay_epochs);
    assert_eq!(c.seed, d.seed);
    assert!((c.l2 - d.l2).abs() < 1e-12);
}

#[test]
fn paper_config_parses_and_matches_preset() {
    let c = ExperimentConfig::from_file(Path::new("configs/paper.toml")).unwrap();
    let p = ExperimentConfig::paper();
    assert_eq!(c.q, p.q);
    assert_eq!(c.local_batch, p.local_batch);
    assert_eq!(c.u_max, p.u_max);
    assert_eq!(c.train_size, p.train_size);
    assert_eq!(c.global_batch(), 12_000); // the paper's m
}

#[test]
fn missing_config_file_is_an_error() {
    assert!(ExperimentConfig::from_file(Path::new("configs/nope.toml")).is_err());
}
