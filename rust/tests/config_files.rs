//! The shipped config files must stay parseable and consistent with the
//! AOT shape presets they name, and config errors must name the offending
//! field and section.

use std::path::Path;

use codedfedl::conf::ExperimentConfig;

#[test]
fn default_config_parses_and_matches_preset() {
    let c = ExperimentConfig::from_file(Path::new("configs/default.toml")).unwrap();
    let d = ExperimentConfig::default();
    assert_eq!(c.clients, d.clients);
    assert_eq!(c.q, d.q);
    assert_eq!(c.local_batch, d.local_batch);
    assert_eq!(c.u_max, d.u_max);
    assert_eq!(c.lr_decay_epochs, d.lr_decay_epochs);
    assert_eq!(c.seed, d.seed);
    assert!((c.l2 - d.l2).abs() < 1e-12);
}

#[test]
fn paper_config_parses_and_matches_preset() {
    let c = ExperimentConfig::from_file(Path::new("configs/paper.toml")).unwrap();
    let p = ExperimentConfig::paper();
    assert_eq!(c.q, p.q);
    assert_eq!(c.local_batch, p.local_batch);
    assert_eq!(c.u_max, p.u_max);
    assert_eq!(c.train_size, p.train_size);
    assert_eq!(c.global_batch(), 12_000); // the paper's m
}

#[test]
fn example_config_parses_and_documents_every_key() {
    let c = ExperimentConfig::from_file(Path::new("configs/example.toml")).unwrap();
    assert_eq!(c.seed, 7);
    assert_eq!(c.clients, 10);
    assert_eq!(c.dataset, "fashion");
    assert_eq!(c.q, 128);
    assert_eq!(c.lr_decay_epochs, vec![10, 20]);
    // The example file exercises the whole schema: every known key of
    // every section appears in it (it is the reference documentation).
    let text = std::fs::read_to_string("configs/example.toml").unwrap();
    for key in [
        "seed", "clients", "dataset", "artifacts_dir", "train_size", "test_size", "dim", "q",
        "classes", "sigma", "local_batch", "steps_per_epoch", "epochs", "lr", "lr_decay",
        "lr_decay_epochs", "l2", "eval_every", "u_max", "generator", "code", "recovery",
        "threads", "simd", "kind", "tau_down", "tau_up", "p_down", "p_up", "deadline", "faults",
        "[checkpoint]", "every", "path", "resume", "[comm]", "codec", "payload",
    ] {
        assert!(text.contains(key), "example.toml is missing documented key {key}");
    }
}

#[test]
fn missing_config_file_is_an_error() {
    assert!(ExperimentConfig::from_file(Path::new("configs/nope.toml")).is_err());
}

#[test]
fn mistyped_key_error_names_field_and_section() {
    let dir = std::env::temp_dir().join("codedfedl_conf_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("bad_type.toml");
    std::fs::write(&path, "[training]\nepochs = \"many\"\n").unwrap();
    let err = ExperimentConfig::from_file(&path).unwrap_err().to_string();
    assert!(err.contains("epochs"), "error must name the field: {err}");
    assert!(err.contains("[training]"), "error must name the section: {err}");
}

#[test]
fn unknown_key_error_names_the_stray_field() {
    let dir = std::env::temp_dir().join("codedfedl_conf_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("typo.toml");
    std::fs::write(&path, "[model]\nsigmma = 3.0\n").unwrap();
    let err = ExperimentConfig::from_file(&path).unwrap_err().to_string();
    assert!(err.contains("sigmma"), "error must name the stray key: {err}");
    assert!(err.contains("sigma"), "error must list the known keys: {err}");
}
