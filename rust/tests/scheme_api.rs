//! Integration: the open `Scheme` trait and the observer event stream.
//!
//! Proves the API is actually open: a **third-party scheme defined in
//! this test file** (not in `src/`) runs end-to-end through
//! `Session::run`, a `RoundObserver` receives exactly one event per
//! round, `GreedyUncoded{psi: 0}` degenerates bit-for-bit to
//! `NaiveUncoded`, and the deprecated `run_scheme` shim still matches the
//! session path.

use anyhow::Result;

use codedfedl::coordinator::{EventLog, RoundEvent, RoundObserver};
use codedfedl::schemes::{
    GradRequest, GreedyUncoded, NaiveUncoded, RoundCtx, RoundPlan, Scheme, SchemeSpec,
};
use codedfedl::sim::scenario::ScenarioSpec;
use codedfedl::sim::RoundDelays;
use codedfedl::{ExperimentBuilder, Session};

/// The suite honours `CODEDFEDL_SCENARIO` (CI runs it once per built-in
/// scenario): the open-trait guarantees — one event per round,
/// greedy(ψ=0) ≡ naive bit-for-bit, shim parity — are scenario-invariant
/// because every scheme on a session sees the same network realisation.
fn env_scenario() -> ScenarioSpec {
    match std::env::var("CODEDFEDL_SCENARIO") {
        Ok(v) => v.parse().expect("CODEDFEDL_SCENARIO"),
        Err(_) => ScenarioSpec::Static,
    }
}

fn tiny_session(epochs: usize) -> Session {
    ExperimentBuilder::preset("tiny")
        .unwrap()
        .epochs(epochs)
        .scenario(env_scenario())
        .build()
        .unwrap()
}

/// A third-party policy the crate has never heard of: wait for nobody,
/// learn from the single fastest client each round, charge its delay.
struct FastestOnly;

impl Scheme for FastestOnly {
    fn label(&self) -> String {
        "fastest-only".into()
    }

    fn plan_round(&mut self, ctx: &RoundCtx, delays: &RoundDelays) -> Result<RoundPlan> {
        let (t_1, winners) = delays.kth_fastest(1).map_err(anyhow::Error::msg)?;
        let requests = winners
            .into_iter()
            .map(|j| GradRequest::full(j, ctx.setup.cfg.local_batch))
            .collect();
        Ok(RoundPlan { requests, round_time: t_1 })
    }
}

/// A do-nothing policy: no gradients, fixed round cost. The minimal
/// possible trait surface (`label` + `plan_round`).
struct Idle;

impl Scheme for Idle {
    fn label(&self) -> String {
        "idle".into()
    }

    fn plan_round(&mut self, _ctx: &RoundCtx, _delays: &RoundDelays) -> Result<RoundPlan> {
        Ok(RoundPlan { requests: vec![], round_time: 1.0 })
    }
}

#[test]
fn third_party_scheme_runs_with_observer() {
    let session = tiny_session(4);
    let total = session.config().total_iters();

    let mut events = EventLog::default();
    let out = session.run_observed(&mut FastestOnly, &mut events).unwrap();

    // One event per round, mirroring the recorded history exactly.
    assert_eq!(events.events.len(), total);
    assert_eq!(out.history.points.len(), total);
    for (ev, p) in events.events.iter().zip(&out.history.points) {
        assert_eq!(ev.iter, p.iter);
        assert_eq!(ev.clock, p.sim_time);
        assert_eq!(ev.acc, p.accuracy);
        assert_eq!(ev.loss, p.train_loss);
        assert_eq!(ev.arrivals, 1, "fastest-only aggregates one client per round");
        assert_eq!(ev.epoch, (ev.iter - 1) / session.config().steps_per_epoch);
    }
    // The gradient really ran: θ moved, and metrics stay well-formed.
    assert!(out.theta.as_slice().iter().any(|&v| v != 0.0));
    assert!((0.0..=1.0).contains(&out.history.best_accuracy()));
    assert!(out.history.points.iter().all(|p| p.train_loss.is_finite()));
    assert_eq!(out.history.label, "fastest-only");
    // Uncoded scheme: no deadline/redundancy to report.
    assert_eq!(out.t_star, None);
    assert_eq!(out.u_star, None);
}

#[test]
fn noop_scheme_compiles_and_runs_through_session_run() {
    let session = tiny_session(2);
    let out = session.run(&mut Idle).unwrap();
    assert_eq!(out.history.points.len(), session.config().total_iters());
    // No gradients ⇒ θ never moves; clock advances exactly 1 s per round.
    assert!(out.theta.as_slice().iter().all(|&v| v == 0.0));
    for (i, p) in out.history.points.iter().enumerate() {
        assert!((p.sim_time - (i + 1) as f64).abs() < 1e-12);
    }
}

#[test]
fn greedy_psi_zero_matches_naive_round_for_round() {
    // ψ = 0 keeps all n clients, and greedy executes winners in client
    // order — so the model trajectory must be bit-identical to naive's.
    // Only the simulated clock may differ (independent delay streams).
    let session = tiny_session(4);
    let naive = session.run(&mut NaiveUncoded::new()).unwrap();
    let greedy = session.run(&mut GreedyUncoded::new(0.0)).unwrap();

    assert_eq!(naive.theta.as_slice(), greedy.theta.as_slice());
    assert_eq!(naive.history.points.len(), greedy.history.points.len());
    for (pn, pg) in naive.history.points.iter().zip(&greedy.history.points) {
        assert_eq!(pn.accuracy, pg.accuracy);
        assert_eq!(pn.train_loss, pg.train_loss);
    }
}

#[test]
fn multiple_observers_see_the_same_stream() {
    struct Counter(usize);
    impl RoundObserver for Counter {
        fn on_round(&mut self, _: &RoundEvent) {
            self.0 += 1;
        }
    }
    let session = tiny_session(2);
    let mut log = EventLog::default();
    let mut count = Counter(0);
    session
        .run_with(&mut NaiveUncoded::new(), &mut [&mut log, &mut count])
        .unwrap();
    assert_eq!(log.events.len(), count.0);
    assert_eq!(count.0, session.config().total_iters());
}

#[test]
fn deprecated_run_scheme_shim_matches_session_run() {
    let session = tiny_session(2);
    #[allow(deprecated)]
    let via_shim = codedfedl::coordinator::run_scheme(
        session.setup(),
        session.runtime(),
        SchemeSpec::Coded { delta: 0.3 },
    )
    .unwrap();
    let via_session = session.run_spec(SchemeSpec::Coded { delta: 0.3 }).unwrap();
    assert_eq!(via_shim.theta.as_slice(), via_session.theta.as_slice());
    assert_eq!(via_shim.t_star, via_session.t_star);
    assert_eq!(
        via_shim.history.total_sim_time(),
        via_session.history.total_sim_time()
    );
}

#[test]
fn scheme_spec_parse_is_cli_stable() {
    // The CLI/TOML surface: bare names and key=value forms.
    assert_eq!(SchemeSpec::parse("naive").unwrap(), SchemeSpec::NaiveUncoded);
    assert_eq!(
        SchemeSpec::parse("coded:delta=0.1").unwrap(),
        SchemeSpec::Coded { delta: 0.1 }
    );
    assert_eq!(
        SchemeSpec::parse("greedy:psi=0.4").unwrap(),
        SchemeSpec::GreedyUncoded { psi: 0.4 }
    );
    assert!(SchemeSpec::parse("sneaky").is_err());
}
