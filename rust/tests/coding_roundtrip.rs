//! Erasure-coding round-trip and exact-recovery gates (the coding PR).
//!
//! 1. **Field axioms** — the GF(256) exp/log tables agree with a
//!    carry-less reference multiplier on every pair, inverses invert, and
//!    the usual axioms hold on a dense sample of triples.
//! 2. **Kernel oracle** — the ISA-dispatched row kernels ([`gf256::xor_row`],
//!    [`gf256::mul_acc_row`]) are bit-identical to the scalar reference
//!    loop under both SIMD policies, on tail-exercising odd lengths.
//! 3. **Round-trip** — both built-in codes encode and decode erasure
//!    subsets bit-exactly, and the encoded/decoded bytes hash identically
//!    under `SimdPolicy::Scalar` and `SimdPolicy::Auto` (GF(256) has no
//!    rounding, so SIMD must change nothing at all).
//! 4. **Acceptance criterion, engine-free** — folding erasure-*decoded*
//!    gradients reproduces the all-arrived aggregate gradient bit for bit.
//! 5. **Engine-level determinism** — `recovery = exact` training runs are
//!    reproducible across thread counts and within each SIMD policy for
//!    both codes, and the default dense/expectation path is bit-identical
//!    whether the knobs are left alone or set explicitly (backward
//!    compatibility with pre-PR histories).
//! 6. **CI matrix entry point** — `CODEDFEDL_CODING` (`dense` |
//!    `rateless`; default `dense`) selects the code for an end-to-end
//!    exact-recovery training smoke, which is how
//!    `.github/workflows/ci.yml` runs this file once per code.

use codedfedl::coding::{
    gf256, pack_byte_planes, unpack_byte_planes, Code, CodeSpec, DecodeScratch, GeneratorKind,
    RecoveryMode,
};
use codedfedl::rng::Rng;
use codedfedl::schemes::SchemeSpec;
use codedfedl::sim::scenario::ScenarioSpec;
use codedfedl::tensor::{Isa, Mat, SimdPolicy};
use codedfedl::{ExperimentBuilder, TrainOutcome};

/// Carry-less "Russian peasant" multiplier modulo 0x11D — the slow,
/// obviously-correct reference the table-driven [`gf256::mul`] must match.
fn mul_ref(a: u8, b: u8) -> u8 {
    let (mut a, mut b, mut p) = (a as u16, b as u16, 0u16);
    while b != 0 {
        if b & 1 != 0 {
            p ^= a;
        }
        a <<= 1;
        if a & 0x100 != 0 {
            a ^= 0x11D;
        }
        b >>= 1;
    }
    p as u8
}

/// FNV-1a over a byte pool — the golden-hash fingerprint the SIMD
/// policies are compared through.
fn pool_hash(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// FNV-1a over a run's bits (θ + history), matching
/// `tests/scenario_determinism.rs`.
fn run_hash(out: &TrainOutcome) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |bits: u64| {
        for b in bits.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    };
    for &v in out.theta.as_slice() {
        eat(v.to_bits() as u64);
    }
    for p in &out.history.points {
        eat(p.iter as u64);
        eat(p.sim_time.to_bits());
        eat(p.accuracy.to_bits());
        eat(p.train_loss.to_bits());
    }
    h
}

fn random_pool(n: usize, len: usize, seed: u64) -> Vec<u8> {
    let mut rng = Rng::seed_from(seed);
    (0..n * len).map(|_| rng.next_below(256) as u8).collect()
}

#[test]
fn gf256_tables_match_the_reference_multiplier_and_axioms_hold() {
    // Exhaustive: every product agrees with the carry-less reference.
    for a in 0..=255u8 {
        for b in 0..=255u8 {
            assert_eq!(gf256::mul(a, b), mul_ref(a, b), "mul({a}, {b})");
        }
    }
    // Every nonzero element inverts, and division round-trips.
    for a in 1..=255u8 {
        assert_eq!(gf256::mul(a, gf256::inv(a)), 1, "inv({a})");
        assert_eq!(gf256::div(gf256::mul(a, 0x53), 0x53), a);
    }
    // Axioms on a dense triple sample (stride keeps this fast in debug
    // builds while still covering high/low bits and the 0x11D carries).
    let sample: Vec<u8> = (0..=255u8).step_by(7).chain([1, 2, 254, 255]).collect();
    for &a in &sample {
        for &b in &sample {
            assert_eq!(gf256::add(a, b), a ^ b);
            assert_eq!(gf256::mul(a, b), gf256::mul(b, a));
            for &c in &sample {
                assert_eq!(
                    gf256::mul(a, gf256::mul(b, c)),
                    gf256::mul(gf256::mul(a, b), c),
                    "associativity at ({a}, {b}, {c})"
                );
                assert_eq!(
                    gf256::mul(a, gf256::add(b, c)),
                    gf256::add(gf256::mul(a, b), gf256::mul(a, c)),
                    "distributivity at ({a}, {b}, {c})"
                );
            }
        }
    }
    // Identities and absorbing zero.
    for a in 0..=255u8 {
        assert_eq!(gf256::mul(a, 1), a);
        assert_eq!(gf256::mul(a, 0), 0);
        assert_eq!(gf256::add(a, a), 0, "characteristic 2");
    }
}

#[test]
fn row_kernels_are_bit_identical_to_the_scalar_oracle() {
    // 1021 is odd and prime: every SIMD arm's remainder loop runs.
    let len = 1021usize;
    let src = random_pool(1, len, 11);
    let dst0 = random_pool(1, len, 12);
    for policy in [SimdPolicy::Scalar, SimdPolicy::Auto] {
        let isa = Isa::detect(policy);
        // xor_row vs the definition.
        let mut dst = dst0.clone();
        gf256::xor_row(isa, &src, &mut dst);
        for i in 0..len {
            assert_eq!(dst[i], dst0[i] ^ src[i], "xor_row[{i}] under {policy:?}");
        }
        // mul_acc_row vs the definition, across coefficient classes: the
        // zero row (no-op), the binary row (pure XOR lane) and general
        // table-driven coefficients.
        for coeff in [0u8, 1, 2, 0x53, 0xFF] {
            let mut dst = dst0.clone();
            gf256::mul_acc_row(isa, coeff, &src, &mut dst);
            for i in 0..len {
                let want = dst0[i] ^ gf256::mul(coeff, src[i]);
                assert_eq!(dst[i], want, "mul_acc_row[{i}] coeff {coeff:#x} under {policy:?}");
            }
        }
    }
    // scale_row is the in-place diagonal case.
    let mut row = src.clone();
    gf256::scale_row(0x1D, &mut row);
    for i in 0..len {
        assert_eq!(row[i], gf256::mul(0x1D, src[i]));
    }
}

/// Encode every repair of `code` over `pool` under `isa`.
fn encode_all(code: &dyn Code, isa: Isa, pool: &[u8], len: usize) -> Vec<u8> {
    let mut repairs = vec![0u8; code.repairs() * len];
    for r in 0..code.repairs() {
        code.encode_repair(isa, r, pool, len, &mut repairs[r * len..(r + 1) * len]);
    }
    repairs
}

#[test]
fn both_codes_round_trip_erasures_identically_under_every_simd_policy() {
    // 101 is odd (tail lanes), 12 sources is big enough for interesting
    // erasure patterns while keeping the debug-build sweep quick.
    let (n, len) = (12usize, 101usize);
    let truth = random_pool(n, len, 21);
    for spec in [CodeSpec::Dense, CodeSpec::Rateless { overhead: 0.5 }] {
        let code = spec.build(GeneratorKind::Normal, n, 0xC0DE);
        assert_eq!(code.sources(), n);
        assert_eq!(code.kind(), spec.kind());

        // Encoded repair bytes must be one golden pool regardless of ISA.
        let repairs_scalar = encode_all(&*code, Isa::Scalar, &truth, len);
        let repairs_auto = encode_all(&*code, Isa::detect(SimdPolicy::Auto), &truth, len);
        assert_eq!(
            pool_hash(&repairs_scalar),
            pool_hash(&repairs_auto),
            "{}: SIMD changed the encoded bytes",
            spec.label()
        );

        // Sweep singles (guaranteed decodable for both codes: dense rows
        // are all-nonzero, rateless row 0 is the full-degree spike) plus
        // every decodable pair; each decodable subset must reconstruct
        // the truth bit-for-bit under both policies.
        let mut scratch = DecodeScratch::new();
        let mut patterns: Vec<Vec<usize>> = (0..n).map(|j| vec![j]).collect();
        for a in 0..n {
            for b in a + 1..n {
                patterns.push(vec![a, b]);
            }
        }
        let mut decoded_some_pair = false;
        for drop in &patterns {
            let mut have = vec![true; n];
            for &j in drop {
                have[j] = false;
            }
            if drop.len() == 1 {
                assert!(
                    code.decodable(&have, code.repairs(), &mut scratch),
                    "{}: single erasure {drop:?} must be decodable",
                    spec.label()
                );
            } else if !code.decodable(&have, code.repairs(), &mut scratch) {
                continue;
            } else {
                decoded_some_pair = true;
            }
            let mut hashes = Vec::new();
            for policy in [SimdPolicy::Scalar, SimdPolicy::Auto] {
                let isa = Isa::detect(policy);
                let mut pool = truth.clone();
                for &j in drop {
                    pool[j * len..(j + 1) * len].fill(0);
                }
                code.decode_into(
                    isa,
                    &have,
                    code.repairs(),
                    len,
                    &mut pool,
                    &repairs_scalar,
                    &mut scratch,
                )
                .unwrap();
                assert_eq!(
                    pool,
                    truth,
                    "{}: decode not bit-exact (dropped {drop:?}, {policy:?})",
                    spec.label()
                );
                hashes.push(pool_hash(&pool));
            }
            assert_eq!(hashes[0], hashes[1], "{}: SIMD changed decoded bytes", spec.label());
        }
        assert!(decoded_some_pair, "{}: no pair erasure decodable at all", spec.label());
    }
}

#[test]
fn decoding_stragglers_reproduces_the_all_arrived_aggregate_bit_for_bit() {
    // The PR's acceptance criterion, demonstrated engine-free: pack n
    // client gradients, encode repairs, erase a decodable subset, decode,
    // unpack and fold — the aggregate must equal the fold of the original
    // gradients to the bit. GF(256) decoding is exact, the byte-plane
    // packing is a bitwise identity, and both folds run in index order,
    // so every f32 operation sees identical operands.
    let (n, q, c) = (10usize, 16usize, 5usize);
    let len = q * c * 4;
    let mut rng = Rng::seed_from(33);
    let grads: Vec<Mat> = (0..n)
        .map(|_| {
            let mut g = Mat::zeros(q, c);
            rng.fill_normal_f32(g.as_mut_slice());
            g
        })
        .collect();

    // The all-arrived aggregate (what a no-straggler round would fold).
    let mut truth_agg = Mat::zeros(q, c);
    for g in &grads {
        truth_agg.axpy(1.0, g);
    }

    for spec in [CodeSpec::Dense, CodeSpec::Rateless { overhead: 0.5 }] {
        let code = spec.build(GeneratorKind::Normal, n, 7);
        let isa = Isa::detect(SimdPolicy::Auto);
        let mut pool = vec![0u8; n * len];
        for (j, g) in grads.iter().enumerate() {
            pack_byte_planes(g.as_slice(), &mut pool[j * len..(j + 1) * len]);
        }
        let repairs = encode_all(&*code, isa, &pool, len);

        // Straggle a decodable subset (fall back to a single erasure,
        // which both codes always absorb).
        let mut scratch = DecodeScratch::new();
        let drop = [vec![2, 6], vec![4]]
            .into_iter()
            .find(|d| {
                let mut have = vec![true; n];
                for &j in d {
                    have[j] = false;
                }
                code.decodable(&have, code.repairs(), &mut scratch)
            })
            .expect("even a single erasure failed the decodability check");
        let mut have = vec![true; n];
        for &j in &drop {
            have[j] = false;
            pool[j * len..(j + 1) * len].fill(0);
        }
        code.decode_into(isa, &have, code.repairs(), len, &mut pool, &repairs, &mut scratch)
            .unwrap();

        // Fold the decoded fleet in index order and compare bits.
        let mut agg = Mat::zeros(q, c);
        let mut recon = Mat::zeros(q, c);
        for j in 0..n {
            unpack_byte_planes(&pool[j * len..(j + 1) * len], recon.as_mut_slice());
            agg.axpy(1.0, &recon);
        }
        let identical = agg
            .as_slice()
            .iter()
            .zip(truth_agg.as_slice())
            .all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(
            identical,
            "{}: decoded aggregate differs from the all-arrived fold (dropped {drop:?})",
            spec.label()
        );
    }
}

fn run_coded(
    code: CodeSpec,
    recovery: RecoveryMode,
    threads: usize,
    simd: SimdPolicy,
) -> TrainOutcome {
    ExperimentBuilder::preset("tiny")
        .unwrap()
        .epochs(2)
        .threads(threads)
        .simd(simd)
        .scenario(ScenarioSpec::Dropout { rate: 0.2 })
        .code(code)
        .recovery(recovery)
        .build()
        .unwrap()
        .run_spec(SchemeSpec::Coded { delta: 0.3 })
        .unwrap()
}

#[test]
fn exact_recovery_training_is_reproducible_across_threads_and_per_policy() {
    for spec in [CodeSpec::Dense, CodeSpec::Rateless { overhead: 0.5 }] {
        for simd in [SimdPolicy::Scalar, SimdPolicy::Auto] {
            let one = run_hash(&run_coded(spec, RecoveryMode::Exact, 1, simd));
            let rerun = run_hash(&run_coded(spec, RecoveryMode::Exact, 1, simd));
            let four = run_hash(&run_coded(spec, RecoveryMode::Exact, 4, simd));
            assert_eq!(one, rerun, "{}: exact rerun changed bits", spec.label());
            assert_eq!(one, four, "{}: thread count changed exact bits", spec.label());
        }
    }
    // The recovery knob is real: under dropout, decoding stragglers
    // exactly walks a different trajectory than the expectation parity
    // substitute (different aggregates *and* a different round clock).
    let expectation = run_hash(&run_coded(
        CodeSpec::Dense,
        RecoveryMode::Expectation,
        1,
        SimdPolicy::Scalar,
    ));
    let exact = run_hash(&run_coded(CodeSpec::Dense, RecoveryMode::Exact, 1, SimdPolicy::Scalar));
    assert_ne!(expectation, exact, "recovery mode left the run untouched");
}

#[test]
fn untouched_knobs_reproduce_the_papers_dense_expectation_run_exactly() {
    // Backward compatibility: a session that never mentions the new knobs
    // must be bit-identical to one that sets them to their defaults —
    // dense code, expectation recovery, the pre-PR behaviour.
    let implicit = ExperimentBuilder::preset("tiny")
        .unwrap()
        .epochs(2)
        .threads(1)
        .simd(SimdPolicy::Scalar)
        .build()
        .unwrap()
        .run_spec(SchemeSpec::Coded { delta: 0.3 })
        .unwrap();
    let explicit = ExperimentBuilder::preset("tiny")
        .unwrap()
        .epochs(2)
        .threads(1)
        .simd(SimdPolicy::Scalar)
        .code(CodeSpec::Dense)
        .recovery(RecoveryMode::Expectation)
        .build()
        .unwrap()
        .run_spec(SchemeSpec::Coded { delta: 0.3 })
        .unwrap();
    assert_eq!(
        run_hash(&implicit),
        run_hash(&explicit),
        "explicit defaults diverged from the untouched configuration"
    );
    assert_eq!(codedfedl::conf::ExperimentConfig::default().code, CodeSpec::Dense);
    assert_eq!(
        codedfedl::conf::ExperimentConfig::default().recovery,
        RecoveryMode::Expectation
    );
}

#[test]
fn env_selected_code_trains_exact_recovery_end_to_end() {
    // CI's coding matrix (`CODEDFEDL_CODING=dense|rateless`) lands here:
    // one full exact-recovery training run under dropout with the
    // env-selected code. Unset, the dense baseline runs.
    let spec: CodeSpec = match std::env::var("CODEDFEDL_CODING") {
        Ok(v) => v.parse().expect("CODEDFEDL_CODING"),
        Err(_) => CodeSpec::Dense,
    };
    let out = run_coded(spec, RecoveryMode::Exact, 2, SimdPolicy::Auto);
    assert!(out.t_star.unwrap() > 0.0, "{}: no load-allocation t*", spec.label());
    assert!(out.u_star.unwrap() > 0, "{}: no parity rows", spec.label());
    assert!(out.parity_overhead >= 0.0 && out.parity_overhead.is_finite());
    assert!(
        out.history.points.iter().all(|p| p.train_loss.is_finite()),
        "{}: exact-recovery training produced non-finite losses",
        spec.label()
    );
    assert!(!out.history.points.is_empty());
}
