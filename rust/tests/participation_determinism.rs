//! Determinism gates for the fleet scale-out (sampled participation,
//! sharded fleets, hierarchical aggregation).
//!
//! 1. **Full-fleet equivalence** — `sample:k=N` realises the identity
//!    roster and reproduces a `full` run bit-for-bit, for every scheme.
//! 2. **Sampled reproducibility** — sampled runs hash identically across
//!    reruns, thread counts and within each SIMD policy.
//! 3. **Shard invariance** — the shard arena size is storage granularity
//!    only: every `shard_size` yields the same bits on a mega-fleet.
//! 4. **Scheme independence** — the participation stream splits off the
//!    experiment root *after* the per-scheme streams, so every scheme
//!    tag derives the same roster base (the fair-comparison property).
//! 5. **Mega-fleet smoke** — a 10^5-client fleet trains sampled rounds
//!    and reproduces (the per-round cost bound lives in the alloc gate
//!    and the `fleet_scale` bench).
//! 6. **Hierarchical fold** — `hier:shard=1` partials are exactly the
//!    per-request products folded in plan order, so it must match the
//!    flat fold bit-for-bit; wider shards must be thread-invariant.
//! 7. **Config validation** — out-of-range rosters and exact-recovery ×
//!    sampling are rejected at build time with errors naming `[fleet]`.

use codedfedl::coding::RecoveryMode;
use codedfedl::rng::Rng;
use codedfedl::schemes::SchemeSpec;
use codedfedl::sim::scenario::SCENARIO_STREAM_TAG;
use codedfedl::tensor::SimdPolicy;
use codedfedl::topology::{AggregationMode, ParticipationSpec, PARTICIPATION_STREAM_TAG};
use codedfedl::{ExperimentBuilder, TrainOutcome};

const SCHEMES: [SchemeSpec; 3] = [
    SchemeSpec::NaiveUncoded,
    SchemeSpec::GreedyUncoded { psi: 0.2 },
    SchemeSpec::Coded { delta: 0.3 },
];

/// FNV-1a over the run's bits: θ plus every history point (same digest as
/// `tests/scenario_determinism.rs`).
fn run_hash(out: &TrainOutcome) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |bits: u64| {
        for b in bits.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    };
    for &v in out.theta.as_slice() {
        eat(v.to_bits() as u64);
    }
    for p in &out.history.points {
        eat(p.iter as u64);
        eat(p.sim_time.to_bits());
        eat(p.accuracy.to_bits());
        eat(p.train_loss.to_bits());
    }
    h
}

fn builder(participation: ParticipationSpec) -> ExperimentBuilder {
    ExperimentBuilder::preset("tiny")
        .unwrap()
        .epochs(2)
        .threads(1)
        .simd(SimdPolicy::Scalar)
        .participation(participation)
}

#[test]
fn sampling_the_whole_fleet_reproduces_full_bit_for_bit() {
    // tiny has 5 clients: `sample:k=5` draws the identity roster every
    // round, so the view, the loads and the sequential delay stream are
    // byte-identical to the untouched full-participation path.
    for spec in SCHEMES {
        let full = builder(ParticipationSpec::Full).build().unwrap().run_spec(spec).unwrap();
        let identity = builder(ParticipationSpec::Sample { k: 5 })
            .build()
            .unwrap()
            .run_spec(spec)
            .unwrap();
        assert_eq!(
            run_hash(&full),
            run_hash(&identity),
            "{}: sample:k=N diverged from full",
            spec.label()
        );
    }
    // …and a strict subsample genuinely changes the run (no inert path).
    let full = builder(ParticipationSpec::Full)
        .build()
        .unwrap()
        .run_spec(SchemeSpec::NaiveUncoded)
        .unwrap();
    let sampled = builder(ParticipationSpec::Sample { k: 3 })
        .build()
        .unwrap()
        .run_spec(SchemeSpec::NaiveUncoded)
        .unwrap();
    assert_ne!(run_hash(&full), run_hash(&sampled), "k=3 roster left naive untouched");
}

#[test]
fn sampled_runs_reproduce_across_threads_and_simd() {
    for spec in SCHEMES {
        for simd in [SimdPolicy::Scalar, SimdPolicy::Auto] {
            let run = |threads: usize| {
                builder(ParticipationSpec::Sample { k: 3 })
                    .threads(threads)
                    .simd(simd)
                    .build()
                    .unwrap()
                    .run_spec(spec)
                    .unwrap()
            };
            let one = run_hash(&run(1));
            let rerun = run_hash(&run(1));
            let four = run_hash(&run(4));
            assert_eq!(one, rerun, "{}: sampled rerun changed bits", spec.label());
            assert_eq!(one, four, "{}: thread count changed sampled bits", spec.label());
        }
    }
}

#[test]
fn shard_size_is_storage_granularity_only() {
    // A 200-client ladder fleet sampled at k=8: the roster and every
    // node's parameters are counter-based pure functions of global index,
    // so re-arranging the arenas cannot move a bit.
    let run = |shard_size: usize, spec: SchemeSpec| {
        builder(ParticipationSpec::Sample { k: 8 })
            .fleet_n(Some(200))
            .shard_size(shard_size)
            .build()
            .unwrap()
            .run_spec(spec)
            .unwrap()
    };
    for spec in [SchemeSpec::NaiveUncoded, SchemeSpec::Coded { delta: 0.3 }] {
        let golden = run_hash(&run(32, spec));
        for shard_size in [64, 256, 1024] {
            assert_eq!(
                golden,
                run_hash(&run(shard_size, spec)),
                "{}: shard_size={shard_size} changed the run",
                spec.label()
            );
        }
    }
}

#[test]
fn participation_stream_is_scheme_independent() {
    // The engine derives the roster base by splitting the participation
    // stream off the experiment root *after* the per-scheme delay/code
    // splits and the scenario split. `split` advances the root
    // identically for any label, so every scheme tag must reach the same
    // base — all schemes on a session face one participation realisation.
    let part_base = |seed: u64, tag: u64| {
        let mut root = Rng::seed_from(seed ^ 0x5EED_0000);
        let _ = root.split(tag);
        let _ = root.split(tag.wrapping_add(1000));
        let _ = root.split(SCENARIO_STREAM_TAG);
        root.split(PARTICIPATION_STREAM_TAG).next_u64()
    };
    let tags: Vec<u64> = SCHEMES.iter().map(|s| s.build().rng_tag()).collect();
    assert_eq!(tags.len(), 3);
    let reference = part_base(42, tags[0]);
    for &tag in &tags[1..] {
        assert_eq!(reference, part_base(42, tag), "tag {tag} derives a different roster base");
    }
    // Different experiments still draw different rosters.
    assert_ne!(reference, part_base(43, tags[0]));
}

#[test]
fn mega_fleet_sampled_run_trains_and_reproduces() {
    // 10^5 clients, 5 sampled per round: the lazily-built shard store
    // only materialises the handful of arenas the rosters touch, so this
    // completes at tiny-preset speed. Two independent sessions must agree
    // bit-for-bit — rosters, ladder nodes and data shards are all pure
    // functions of (seed, global index).
    let run = || {
        builder(ParticipationSpec::Sample { k: 5 })
            .epochs(1)
            .fleet_n(Some(100_000))
            .build()
            .unwrap()
            .run_spec(SchemeSpec::NaiveUncoded)
            .unwrap()
    };
    let a = run();
    assert!(!a.history.points.is_empty());
    assert!(a.history.points.iter().all(|p| p.train_loss.is_finite()));
    let mut prev = 0.0;
    for p in &a.history.points {
        assert!(p.sim_time > prev, "mega-fleet clock not increasing");
        prev = p.sim_time;
    }
    let b = run();
    assert_eq!(run_hash(&a), run_hash(&b), "mega-fleet run is not reproducible");
}

#[test]
fn hier_with_unit_shards_matches_the_flat_fold_bitwise() {
    // shard=1 partials are exactly round(scale·g) — the same per-element
    // operation sequence as the flat fold — so the histories must agree
    // bit-for-bit. This pins the hierarchical fold to the documented
    // plan-order arithmetic, not just to itself.
    for spec in SCHEMES {
        let flat = builder(ParticipationSpec::Full).build().unwrap().run_spec(spec).unwrap();
        let hier = builder(ParticipationSpec::Full)
            .aggregation(AggregationMode::Hier { shard: 1 })
            .build()
            .unwrap()
            .run_spec(spec)
            .unwrap();
        assert_eq!(
            run_hash(&flat),
            run_hash(&hier),
            "{}: hier:shard=1 diverged from flat",
            spec.label()
        );
    }
}

#[test]
fn hier_fold_is_thread_invariant_and_reproducible() {
    // Wider shards change the fold tree (allowed), but each partial is
    // owned by exactly one worker and both fold levels run in pinned
    // sequential orders — so bits must not move with the thread count,
    // under full and sampled participation alike.
    for participation in [ParticipationSpec::Full, ParticipationSpec::Sample { k: 3 }] {
        for spec in [SchemeSpec::NaiveUncoded, SchemeSpec::Coded { delta: 0.3 }] {
            let run = |threads: usize| {
                builder(participation)
                    .threads(threads)
                    .aggregation(AggregationMode::Hier { shard: 2 })
                    .build()
                    .unwrap()
                    .run_spec(spec)
                    .unwrap()
            };
            let serial = run_hash(&run(1));
            assert_eq!(
                serial,
                run_hash(&run(1)),
                "{} ({}): hier rerun changed bits",
                spec.label(),
                participation.label()
            );
            assert_eq!(
                serial,
                run_hash(&run(4)),
                "{} ({}): hier fold moved with the thread count",
                spec.label(),
                participation.label()
            );
        }
    }
}

#[test]
fn build_rejects_invalid_fleet_configs() {
    // Oversized roster: k > N names [fleet] and the accepted range.
    let e = builder(ParticipationSpec::Sample { k: 20 })
        .fleet_n(Some(10))
        .build()
        .map(|_| ())
        .unwrap_err()
        .to_string();
    assert!(e.contains("[fleet] participation"), "{e}");
    assert!(e.contains("1..=10"), "{e}");

    // Empty roster.
    let e = builder(ParticipationSpec::Sample { k: 0 }).build().map(|_| ()).unwrap_err().to_string();
    assert!(e.contains("k=0"), "{e}");

    // A fleet smaller than the data shards it must tile.
    let e = builder(ParticipationSpec::Full)
        .fleet_n(Some(3))
        .build()
        .map(|_| ())
        .unwrap_err()
        .to_string();
    assert!(e.contains("[fleet] n"), "{e}");

    // Exact recovery is defined over the full fixed fleet only.
    let e = builder(ParticipationSpec::Sample { k: 3 })
        .recovery(RecoveryMode::Exact)
        .build()
        .map(|_| ())
        .unwrap_err()
        .to_string();
    assert!(e.contains("exact recovery requires the full fixed fleet"), "{e}");
}
