//! The steady-state allocation gate (PR 3): once warm, a training round's
//! *compute path* — pack θ, run the round's client gradients as a batch
//! into held slots, run the parity gradient, fold, evaluate — performs
//! **zero** heap allocations on the native backend.
//!
//! The gate installs [`CountingAlloc`] as the process-global allocator
//! and measures the exact sequence of runtime calls
//! `coordinator::engine::run` issues per round, against the engine's own
//! buffer-reuse discipline (round-persistent panel, output slots and
//! logits). This file intentionally contains a **single** test: the
//! counters are process-global, so any concurrently running test would
//! pollute the measurement.
//!
//! Runs under the SIMD policy named by `CODEDFEDL_SIMD` (`scalar` |
//! `auto`; default `auto`) — CI runs it once per policy, so the SIMD
//! microkernels' A-operand packing (carved from the workers' persistent
//! scratch arenas) is held to the same zero-allocation contract as the
//! scalar path.

use codedfedl::benchutil::CountingAlloc;
use codedfedl::rng::Rng;
use codedfedl::runtime::GradJob;
use codedfedl::tensor::{Mat, SimdPolicy};
use codedfedl::ExperimentBuilder;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn env_policy() -> SimdPolicy {
    match std::env::var("CODEDFEDL_SIMD") {
        Ok(v) => v.parse().expect("CODEDFEDL_SIMD"),
        Err(_) => SimdPolicy::Auto,
    }
}

#[test]
fn steady_state_compute_path_allocates_zero_bytes() {
    // threads = 2 so the persistent pool (not just the inline part-0
    // path) services the dispatches being gated.
    let session = ExperimentBuilder::preset("tiny")
        .unwrap()
        .epochs(1)
        .threads(2)
        .simd(env_policy())
        .build()
        .unwrap();
    let rt = session.runtime();
    let setup = session.setup();
    let cfg = session.config();
    let (q, c, n) = (cfg.q, cfg.classes, cfg.clients);

    let mut rng = Rng::seed_from(9);
    let mut theta = Mat::zeros(q, c);
    rng.fill_normal_f32(theta.as_mut_slice());

    // Round-persistent state, mirroring coordinator::engine::run.
    let masks: Vec<Vec<f32>> = vec![vec![1.0f32; cfg.local_batch]; n];
    let jobs: Vec<GradJob> = (0..n)
        .map(|j| GradJob {
            xhat: &setup.client_data[j].xhat[0],
            y: &setup.client_data[j].y[0],
            mask: &masks[j],
        })
        .collect();
    let mut panel: Vec<f32> = Vec::new();
    let mut outs: Vec<Mat> = (0..n).map(|_| Mat::zeros(q, c)).collect();
    let mut agg = Mat::zeros(q, c);
    let mut eval_logits = Mat::zeros(setup.test_xhat.rows(), c);
    // Parity-shaped server gradient (CodedFedL's eq. 28 path).
    let u = 64usize;
    let mut parity_x = Mat::zeros(u, q);
    let mut parity_y = Mat::zeros(u, c);
    rng.fill_normal_f32(parity_x.as_mut_slice());
    rng.fill_normal_f32(parity_y.as_mut_slice());
    let parity_mask = vec![1.0f32; u];
    let mut parity_grad = Mat::zeros(q, c);

    let mut round = |theta: &Mat| {
        let prep = rt.prepare_theta_into(theta, &mut panel).unwrap();
        rt.grad_batch_into(&jobs, &prep, &mut outs).unwrap();
        agg.as_mut_slice().fill(0.0);
        for g in &outs {
            agg.axpy(1.0, g);
        }
        rt.grad_into(&parity_x, &parity_y, &prep, &parity_mask, &mut parity_grad)
            .unwrap();
        agg.axpy(0.5, &parity_grad);
        rt.predict_into(&setup.test_xhat, &prep, &mut eval_logits).unwrap();
    };

    // Two warm-up rounds grow every buffer and scratch arena to its
    // steady-state size…
    round(&theta);
    round(&theta);

    // …after which a round must acquire no memory at all.
    let (a0, b0) = (CountingAlloc::allocations(), CountingAlloc::bytes());
    round(&theta);
    let (a1, b1) = (CountingAlloc::allocations(), CountingAlloc::bytes());
    assert_eq!(
        a1 - a0,
        0,
        "warm compute path performed {} allocations ({} bytes)",
        a1 - a0,
        b1 - b0
    );
    assert_eq!(b1 - b0, 0, "warm compute path requested {} bytes", b1 - b0);

    // Sanity: the counter itself works (an allocation is visible).
    let before = CountingAlloc::allocations();
    let v = std::hint::black_box(vec![0u8; 4096]);
    assert!(CountingAlloc::allocations() > before, "counting allocator inert");
    drop(v);
}
