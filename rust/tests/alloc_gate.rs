//! The steady-state allocation gate (PR 3): once warm, a training round's
//! *compute path* — pack θ, run the round's client gradients as a batch
//! into held slots, run the parity gradient, fold, evaluate — performs
//! **zero** heap allocations on the native backend.
//!
//! The gate installs [`CountingAlloc`] as the process-global allocator
//! and measures the exact sequence of runtime calls
//! `coordinator::engine::run` issues per round, against the engine's own
//! buffer-reuse discipline (round-persistent panel, output slots and
//! logits). This file intentionally contains a **single** test: the
//! counters are process-global, so any concurrently running test would
//! pollute the measurement.
//!
//! Runs under the SIMD policy named by `CODEDFEDL_SIMD` (`scalar` |
//! `auto`; default `auto`) — CI runs it once per policy, so the SIMD
//! microkernels' A-operand packing (carved from the workers' persistent
//! scratch arenas) is held to the same zero-allocation contract as the
//! scalar path.
//!
//! The gate also covers the scenario-aware *decision* path (PR 5): the
//! per-round fleet-view reset, scenario modulation, per-leg timeline
//! sampling and CodedFedL's deadline-arrival scan
//! (`RoundDelays::arrivals_into`/`arrivals_iter`, which replaced the
//! per-round `Vec<bool>` allocation) run at zero warm-round allocations
//! under every built-in scenario. The scheme's `RoundPlan`/mask control
//! path stays outside the gate (a handful of pointer-sized entries per
//! round — see the engine module docs).
//!
//! The fleet-scale PR adds the sampled-participation decision path: the
//! counter-based roster draw (`ParticipationSampler`), the O(K) roster
//! view reset over a sharded mega-fleet (`FleetShards`), K-slot timeline
//! sampling and the streaming top-k arrival selection
//! (`RoundDelays::kth_fastest_into` + caller-owned `KthScratch`, which
//! greedy's round loop reuses) — all zero warm-round allocations, with
//! per-round cost independent of the fleet size N.
//!
//! And it covers the erasure-codec path (the coding PR): the full warm
//! pack → encode → erase → decode → refold cycle of `recovery = exact`
//! runs at zero allocations for **both** built-in codes, with every
//! decode buffer living in the caller-owned, pre-reserved
//! `DecodeScratch` — exactly the discipline `schemes::coded` relies on.
//!
//! The robustness PR adds the deadline+fault decision path: in-place
//! fault injection over the sampled trace (`FaultPlan::apply` — crash,
//! link-loss with retry re-pricing, parity loss), the quantile-deadline
//! selection (`kth_fastest_into` over the surviving arrivals) and the
//! trace truncation at the cut (`RoundTrace::close_at`) — all zero warm
//! allocations, so degraded rounds stay on the same gate as clean ones.
//!
//! The checkpoint PR adds the crash-recovery decision path: the per-round
//! corrupt-flag draw into a warm `Vec<bool>` (`FaultPlan::draw_corrupt`),
//! the counter-based server-kill draw (`Rng::indexed` — stateless by
//! construction, so replays can't disturb the sequential streams) and
//! the checkpoint-cadence test the engine runs every round. Warm
//! *non-checkpoint* rounds stay at zero allocations with checkpointing
//! enabled — only the rounds that actually write a snapshot pay for it.

use codedfedl::benchutil::CountingAlloc;
use codedfedl::coding::{pack_byte_planes, unpack_byte_planes, CodeSpec, DecodeScratch};
use codedfedl::rng::Rng;
use codedfedl::runtime::GradJob;
use codedfedl::sim::fault::FaultSpec;
use codedfedl::sim::scenario::{Scenario, ScenarioSpec};
use codedfedl::sim::timeline::RoundTrace;
use codedfedl::sim::KthScratch;
use codedfedl::tensor::{Isa, Mat, SimdPolicy};
use codedfedl::topology::{FleetShards, FleetView, ParticipationSampler, ParticipationSpec};
use codedfedl::ExperimentBuilder;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn env_policy() -> SimdPolicy {
    match std::env::var("CODEDFEDL_SIMD") {
        Ok(v) => v.parse().expect("CODEDFEDL_SIMD"),
        Err(_) => SimdPolicy::Auto,
    }
}

#[test]
fn steady_state_compute_path_allocates_zero_bytes() {
    // threads = 2 so the persistent pool (not just the inline part-0
    // path) services the dispatches being gated.
    let session = ExperimentBuilder::preset("tiny")
        .unwrap()
        .epochs(1)
        .threads(2)
        .simd(env_policy())
        .build()
        .unwrap();
    let rt = session.runtime();
    let setup = session.setup();
    let cfg = session.config();
    let (q, c, n) = (cfg.q, cfg.classes, cfg.clients);

    let mut rng = Rng::seed_from(9);
    let mut theta = Mat::zeros(q, c);
    rng.fill_normal_f32(theta.as_mut_slice());

    // Round-persistent state, mirroring coordinator::engine::run.
    let masks: Vec<Vec<f32>> = vec![vec![1.0f32; cfg.local_batch]; n];
    let jobs: Vec<GradJob> = (0..n)
        .map(|j| GradJob {
            xhat: &setup.client_data[j].xhat[0],
            y: &setup.client_data[j].y[0],
            mask: &masks[j],
        })
        .collect();
    let mut panel: Vec<f32> = Vec::new();
    let mut outs: Vec<Mat> = (0..n).map(|_| Mat::zeros(q, c)).collect();
    let mut agg = Mat::zeros(q, c);
    let mut eval_logits = Mat::zeros(setup.test_xhat.rows(), c);
    // Parity-shaped server gradient (CodedFedL's eq. 28 path).
    let u = 64usize;
    let mut parity_x = Mat::zeros(u, q);
    let mut parity_y = Mat::zeros(u, c);
    rng.fill_normal_f32(parity_x.as_mut_slice());
    rng.fill_normal_f32(parity_y.as_mut_slice());
    let parity_mask = vec![1.0f32; u];
    let mut parity_grad = Mat::zeros(q, c);

    let mut round = |theta: &Mat| {
        let prep = rt.prepare_theta_into(theta, &mut panel).unwrap();
        rt.grad_batch_into(&jobs, &prep, &mut outs).unwrap();
        agg.as_mut_slice().fill(0.0);
        for g in &outs {
            agg.axpy(1.0, g);
        }
        rt.grad_into(&parity_x, &parity_y, &prep, &parity_mask, &mut parity_grad)
            .unwrap();
        agg.axpy(0.5, &parity_grad);
        rt.predict_into(&setup.test_xhat, &prep, &mut eval_logits).unwrap();
    };

    // Two warm-up rounds grow every buffer and scratch arena to its
    // steady-state size…
    round(&theta);
    round(&theta);

    // …after which a round must acquire no memory at all.
    let (a0, b0) = (CountingAlloc::allocations(), CountingAlloc::bytes());
    round(&theta);
    let (a1, b1) = (CountingAlloc::allocations(), CountingAlloc::bytes());
    assert_eq!(
        a1 - a0,
        0,
        "warm compute path performed {} allocations ({} bytes)",
        a1 - a0,
        b1 - b0
    );
    assert_eq!(b1 - b0, 0, "warm compute path requested {} bytes", b1 - b0);

    // --- the scenario-aware decision path: per-round fleet-view reset +
    //     scenario modulation + per-leg timeline sampling + the coded
    //     scheme's arrival scan, then the same compute round — zero
    //     allocations once warm, under EVERY built-in scenario. ---
    let loads: Vec<f64> = vec![cfg.local_batch as f64; n];
    let mut arrived: Vec<bool> = Vec::new();
    for spec in [
        ScenarioSpec::Static,
        ScenarioSpec::Dropout { rate: 0.3 },
        ScenarioSpec::Fading { depth: 0.5, period: 7.0 },
        ScenarioSpec::Burst { slow: 0.3, factor: 4.0 },
    ] {
        let mut scenario = spec.build();
        let mut scen_rng = Rng::seed_from(31);
        let mut delay_rng = Rng::seed_from(32);
        let mut view = FleetView::from_base(&setup.client_links, setup.server);
        let mut trace = RoundTrace::with_capacity(n);

        // Warm-up rounds reach every buffer's steady-state size (the
        // trace/view/arrival capacities are fleet-sized by construction,
        // so two are plenty)…
        for r in 0..2 {
            view.reset_from(&setup.client_links, setup.server);
            scenario.begin_round(r, &mut view, &mut scen_rng);
            trace.sample_into(&view, &loads, 8.0, &mut delay_rng);
            trace.delays().arrivals_into(5.0, &mut arrived);
        }

        // …after which warm rounds must acquire no memory at all.
        let (a0, b0) = (CountingAlloc::allocations(), CountingAlloc::bytes());
        for r in 2..5 {
            view.reset_from(&setup.client_links, setup.server);
            scenario.begin_round(r, &mut view, &mut scen_rng);
            trace.sample_into(&view, &loads, 8.0, &mut delay_rng);
            trace.delays().arrivals_into(5.0, &mut arrived);
            let made_it = trace.delays().arrivals_iter(5.0).filter(|&a| a).count();
            std::hint::black_box(made_it);
            round(&theta);
        }
        let (a1, b1) = (CountingAlloc::allocations(), CountingAlloc::bytes());
        assert_eq!(
            a1 - a0,
            0,
            "scenario {}: warm rounds performed {} allocations ({} bytes)",
            spec.label(),
            a1 - a0,
            b1 - b0
        );
        assert_eq!(
            b1 - b0,
            0,
            "scenario {}: warm rounds requested {} bytes",
            spec.label(),
            b1 - b0
        );
    }

    // --- the deadline+fault decision path (robustness PR): sample the
    //     round trace, inject a mixed fault realisation in place, select
    //     the quantile deadline over the survivors and close the trace at
    //     the cut — the exact per-round sequence a degraded engine round
    //     runs before planning — zero allocations once warm. ---
    {
        let plan = FaultSpec::Mixed { crash: 0.2, link: 0.2, parity: 0.3 }.build();
        let mut fault_rng = Rng::seed_from(41);
        let mut delay_rng = Rng::seed_from(42);
        let mut view = FleetView::from_base(&setup.client_links, setup.server);
        let mut trace = RoundTrace::with_capacity(n);
        let mut scratch = KthScratch::default();
        let mut degraded_round = || {
            view.reset_from(&setup.client_links, setup.server);
            trace.sample_into(&view, &loads, 8.0, &mut delay_rng);
            plan.apply(&mut trace, &mut fault_rng);
            let k = trace.delays().present_count();
            if k > 0 {
                let kth = ((0.8 * k as f64).ceil() as usize).clamp(1, k);
                let (t, _) = trace.delays().kth_fastest_into(kth, &mut scratch).unwrap();
                trace.close_at(t);
            }
            let survivors = trace.delays().present_count();
            std::hint::black_box(survivors);
        };

        // Two warm rounds reach every buffer's steady-state capacity…
        degraded_round();
        degraded_round();

        // …after which a warm degraded round must acquire no memory.
        let (a0, b0) = (CountingAlloc::allocations(), CountingAlloc::bytes());
        for _ in 0..3 {
            degraded_round();
        }
        let (a1, b1) = (CountingAlloc::allocations(), CountingAlloc::bytes());
        assert_eq!(
            a1 - a0,
            0,
            "deadline+fault decision path performed {} allocations ({} bytes)",
            a1 - a0,
            b1 - b0
        );
        assert_eq!(
            b1 - b0,
            0,
            "deadline+fault decision path requested {} bytes",
            b1 - b0
        );
    }

    // --- the checkpoint+chaos decision path (crash-recovery PR): the
    //     per-round corrupt-flag draw into the engine's warm flag buffer,
    //     the stateless counter-based server-kill draw and the
    //     checkpoint-cadence modulo — everything a non-checkpoint warm
    //     round pays with `[checkpoint] every` and `corrupt:`/`server:`
    //     faults enabled — zero allocations once warm. ---
    {
        let plan = FaultSpec::Corrupt { rate: 0.3 }.build();
        let server_base = 0xFA17_5E11u64;
        let ckpt_every = 64usize; // no round below hits the cadence
        let mut fault_rng = Rng::seed_from(51);
        let mut delay_rng = Rng::seed_from(52);
        let mut view = FleetView::from_base(&setup.client_links, setup.server);
        let mut trace = RoundTrace::with_capacity(n);
        let mut flags: Vec<bool> = Vec::new();
        let mut recovery_round = |r: usize| {
            view.reset_from(&setup.client_links, setup.server);
            trace.sample_into(&view, &loads, 8.0, &mut delay_rng);
            plan.apply(&mut trace, &mut fault_rng);
            let corrupted = plan.draw_corrupt(&trace, &mut flags, &mut fault_rng);
            let killed = Rng::indexed(server_base, r as u64).next_f64() < 0.2;
            let snapshot_due = (r + 1) % ckpt_every == 0;
            std::hint::black_box((corrupted, killed, snapshot_due));
        };

        // Two warm rounds grow the flag buffer to the fleet size…
        recovery_round(0);
        recovery_round(1);

        // …after which a warm non-checkpoint round must acquire no memory.
        let (a0, b0) = (CountingAlloc::allocations(), CountingAlloc::bytes());
        for r in 2..5 {
            recovery_round(r);
        }
        let (a1, b1) = (CountingAlloc::allocations(), CountingAlloc::bytes());
        assert_eq!(
            a1 - a0,
            0,
            "checkpoint+chaos decision path performed {} allocations ({} bytes)",
            a1 - a0,
            b1 - b0
        );
        assert_eq!(
            b1 - b0,
            0,
            "checkpoint+chaos decision path requested {} bytes",
            b1 - b0
        );
    }

    // --- the fleet-scale decision path (million-client PR): counter-based
    //     roster draw over a sharded mega-fleet, O(K) roster view reset,
    //     per-leg sampling over the K slots only and greedy's streaming
    //     top-k arrival selection (`kth_fastest_into` + caller-owned
    //     scratch) — zero allocations once warm. Shard arenas are
    //     materialised up front (`build_all`): lazy builds are amortised
    //     cold-path allocations by design, not per-round cost. ---
    {
        let fleet_n = 10_000usize;
        let k_sample = 31usize;
        let sel_k = 8usize;
        let mut mega = setup.fleet_spec;
        mega.n = fleet_n;
        let mut shards = FleetShards::ladder(mega, 0xF1EE7, 512);
        shards.build_all();
        let mut sampler =
            ParticipationSampler::new(ParticipationSpec::Sample { k: k_sample }, fleet_n, 77);
        let mut delay_rng = Rng::seed_from(33);
        let mut view = FleetView::from_base(&setup.client_links, setup.server);
        let mut trace = RoundTrace::with_capacity(k_sample);
        let mut roster_loads: Vec<f64> = Vec::new();
        let mut scratch = KthScratch::default();
        let mut fleet_round = |r: usize| {
            let roster = sampler.draw(r);
            roster_loads.clear();
            roster_loads.extend(roster.iter().map(|&g| loads[g as usize % n]));
            view.reset_roster(&mut shards, roster, setup.server);
            trace.sample_into(&view, &roster_loads, 8.0, &mut delay_rng);
            let (t_k, winners) = trace.delays().kth_fastest_into(sel_k, &mut scratch).unwrap();
            std::hint::black_box((t_k, winners.len()));
        };

        // Two warm rounds reach every buffer's steady-state (K-sized)
        // capacity…
        fleet_round(0);
        fleet_round(1);

        // …after which warm sampled rounds must acquire no memory at all.
        let (a0, b0) = (CountingAlloc::allocations(), CountingAlloc::bytes());
        for r in 2..5 {
            fleet_round(r);
        }
        let (a1, b1) = (CountingAlloc::allocations(), CountingAlloc::bytes());
        assert_eq!(
            a1 - a0,
            0,
            "fleet-scale decision path performed {} allocations ({} bytes)",
            a1 - a0,
            b1 - b0
        );
        assert_eq!(
            b1 - b0,
            0,
            "fleet-scale decision path requested {} bytes",
            b1 - b0
        );
    }

    // --- the erasure-codec path: pack every client gradient into GF(256)
    //     byte planes, encode all repair symbols, erase a client, decode
    //     it back and refold the fleet. Once the pools and the
    //     DecodeScratch are reserved, a warm cycle must acquire no memory
    //     at all, for both built-in codes and under the gated ISA. ---
    let isa = Isa::detect(env_policy());
    let symbol_len = q * c * 4;
    let mut codec_agg = Mat::zeros(q, c);
    let mut recon = Mat::zeros(q, c);
    for spec in [CodeSpec::Dense, CodeSpec::Rateless { overhead: 0.5 }] {
        let code = spec.build(cfg.generator, n, 0xC0DE);
        let reps = code.repairs();
        let mut src = vec![0u8; n * symbol_len];
        let mut repairs = vec![0u8; reps * symbol_len];
        let mut have = vec![true; n];
        let mut scratch = DecodeScratch::new();
        scratch.reserve(reps, n, symbol_len);

        // One exact-recovery codec cycle over the engine's gradient slots,
        // straggling client `erase` (single erasures are decodable by
        // construction for both codes).
        let mut codec_round = |erase: usize| {
            for (j, g) in outs.iter().enumerate() {
                pack_byte_planes(g.as_slice(), &mut src[j * symbol_len..(j + 1) * symbol_len]);
            }
            for r in 0..reps {
                code.encode_repair(
                    isa,
                    r,
                    &src,
                    symbol_len,
                    &mut repairs[r * symbol_len..(r + 1) * symbol_len],
                );
            }
            for h in have.iter_mut() {
                *h = true;
            }
            have[erase] = false;
            src[erase * symbol_len..(erase + 1) * symbol_len].fill(0);
            assert!(code.decodable(&have, reps, &mut scratch));
            code.decode_into(isa, &have, reps, symbol_len, &mut src, &repairs, &mut scratch)
                .unwrap();
            codec_agg.as_mut_slice().fill(0.0);
            for j in 0..n {
                unpack_byte_planes(&src[j * symbol_len..(j + 1) * symbol_len], recon.as_mut_slice());
                codec_agg.axpy(1.0, &recon);
            }
        };

        // Two warm cycles touch every pool and scratch buffer…
        codec_round(0);
        codec_round(1 % n);

        // …after which a cycle must acquire no memory at all.
        let (a0, b0) = (CountingAlloc::allocations(), CountingAlloc::bytes());
        codec_round(2 % n);
        let (a1, b1) = (CountingAlloc::allocations(), CountingAlloc::bytes());
        assert_eq!(
            a1 - a0,
            0,
            "codec {}: warm cycle performed {} allocations ({} bytes)",
            spec.label(),
            a1 - a0,
            b1 - b0
        );
        assert_eq!(
            b1 - b0,
            0,
            "codec {}: warm cycle requested {} bytes",
            spec.label(),
            b1 - b0
        );
    }

    // --- the payload-codec path (`[comm]` PR): the full warm
    //     quantize → bitpack → unpack → dequantize transcode the engine
    //     runs over every uploaded gradient before the fold, through the
    //     gated ISA, against the engine's gradient slots. Once the
    //     CodecScratch is reserved, a warm transcode of the whole round's
    //     uploads must acquire no memory at all, for every codec. ---
    {
        use codedfedl::comm::{self, CodecSpec, ScaleSpec};
        for codec in [
            CodecSpec::Q8 { scale: ScaleSpec::Auto },
            CodecSpec::Q8 { scale: ScaleSpec::Fixed(0.01) },
            CodecSpec::Bitpack,
        ] {
            let mut scratch = comm::CodecScratch::default();
            scratch.reserve(c);
            let mut transcode_round = || {
                for g in outs.iter_mut() {
                    comm::transcode_mat(isa, codec, g, &mut scratch);
                }
            };

            // Two warm rounds reach the scratch buffers' steady state…
            transcode_round();
            transcode_round();

            // …after which a warm transcode must acquire no memory.
            let (a0, b0) = (CountingAlloc::allocations(), CountingAlloc::bytes());
            transcode_round();
            let (a1, b1) = (CountingAlloc::allocations(), CountingAlloc::bytes());
            assert_eq!(
                a1 - a0,
                0,
                "codec {}: warm transcode performed {} allocations ({} bytes)",
                codec.label(),
                a1 - a0,
                b1 - b0
            );
            assert_eq!(
                b1 - b0,
                0,
                "codec {}: warm transcode requested {} bytes",
                codec.label(),
                b1 - b0
            );
        }
    }

    // Sanity: the counter itself works (an allocation is visible).
    let before = CountingAlloc::allocations();
    let v = std::hint::black_box(vec![0u8; 4096]);
    assert!(CountingAlloc::allocations() > before, "counting allocator inert");
    drop(v);
}
