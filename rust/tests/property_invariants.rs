//! Property-based tests over the coordinator's analytical substrates
//! (proptest is unavailable offline; this is a seeded random-sweep driver
//! with the same spirit: hundreds of generated cases per invariant, with
//! the failing case's parameters printed by the assert messages).

use codedfedl::allocation::{expected_return, optimal_load, solve, NodeSpec};
use codedfedl::coding;
use codedfedl::conf::parse;
use codedfedl::delay::NodeParams;
use codedfedl::numerics::lambert_w_m1;
use codedfedl::rng::Rng;
use codedfedl::tensor::Mat;

/// Draw a random but valid node from the plausible MEC parameter ranges.
fn arb_node(rng: &mut Rng) -> NodeParams {
    NodeParams {
        mu: 0.05 + rng.next_f64() * 100.0,
        alpha: 0.2 + rng.next_f64() * 40.0,
        tau: rng.next_f64() * 20.0,
        p: rng.next_f64() * 0.95,
    }
}

#[test]
fn prop_cdf_is_a_cdf() {
    // 0 ≤ F ≤ 1, nondecreasing in t, for random nodes and loads.
    let mut rng = Rng::seed_from(101);
    for case in 0..300 {
        let n = arb_node(&mut rng);
        let ell = rng.next_f64() * 500.0;
        let scale = 0.2 + rng.next_f64();
        let mut prev = 0.0;
        for i in 0..40 {
            let t = (i as f64 + 1.0) * scale;
            let c = n.cdf(t, ell);
            assert!(
                (0.0..=1.0).contains(&c),
                "case {case}: cdf {c} out of range at {n:?}, ell={ell}, t={t}"
            );
            assert!(
                c >= prev - 1e-12,
                "case {case}: cdf not monotone at {n:?}, ell={ell}, t={t}"
            );
            prev = c;
        }
    }
}

#[test]
fn prop_cdf_decreasing_in_load() {
    let mut rng = Rng::seed_from(102);
    for case in 0..300 {
        let n = arb_node(&mut rng);
        let t = 2.0 * n.tau + 1.0 + rng.next_f64() * 50.0;
        let l1 = rng.next_f64() * 100.0;
        let l2 = l1 + rng.next_f64() * 100.0 + 1e-9;
        assert!(
            n.cdf(t, l1) >= n.cdf(t, l2) - 1e-12,
            "case {case}: more load should not complete earlier ({n:?}, t={t}, {l1} vs {l2})"
        );
    }
}

#[test]
fn prop_optimizer_dominates_grid() {
    // optimal_load's value must match-or-beat a dense grid scan.
    let mut rng = Rng::seed_from(103);
    for case in 0..60 {
        let n = arb_node(&mut rng);
        let t = 2.0 * n.tau + 0.5 + rng.next_f64() * 30.0;
        let cap = 1.0 + rng.next_f64() * 300.0;
        let (_, er) = optimal_load(&n, t, cap);
        let grid = (1..=800)
            .map(|i| expected_return(&n, t, cap * i as f64 / 800.0))
            .fold(0.0f64, f64::max);
        assert!(
            er >= grid - 1e-6 * (1.0 + grid),
            "case {case}: optimizer {er} < grid {grid} at {n:?}, t={t}, cap={cap}"
        );
    }
}

#[test]
fn prop_optimized_return_monotone_in_t() {
    let mut rng = Rng::seed_from(104);
    for case in 0..60 {
        let n = arb_node(&mut rng);
        let cap = 1.0 + rng.next_f64() * 200.0;
        let scale = 0.3 + rng.next_f64() * 0.5;
        let mut prev = -1.0;
        for i in 1..30 {
            let t = i as f64 * scale;
            let (_, er) = optimal_load(&n, t, cap);
            assert!(
                er >= prev - 1e-9,
                "case {case}: optimized return dipped at {n:?}, t={t}"
            );
            prev = er;
        }
    }
}

#[test]
fn prop_solve_hits_target_and_loads_feasible() {
    let mut rng = Rng::seed_from(105);
    for case in 0..25 {
        let n_clients = 2 + rng.next_below(8);
        let cap = 20.0 + rng.next_f64() * 80.0;
        let mut nodes: Vec<NodeSpec> = (0..n_clients)
            .map(|_| NodeSpec { params: arb_node(&mut rng), max_load: cap })
            .collect();
        // reliable fast server provides the feasibility slack
        nodes.push(NodeSpec {
            params: NodeParams { mu: 500.0, alpha: 50.0, tau: 0.01, p: 0.0 },
            max_load: cap * n_clients as f64,
        });
        let m = cap * n_clients as f64; // clients alone can't reach it
        match solve(&nodes, m) {
            Ok(alloc) => {
                assert!(
                    (alloc.total_expected_return() - m).abs() < 1e-3 * m,
                    "case {case}: E[R]={} != m={m}",
                    alloc.total_expected_return()
                );
                for (l, n) in alloc.loads.iter().zip(&nodes) {
                    assert!(*l >= -1e-9 && *l <= n.max_load + 1e-6, "case {case}");
                }
                for p in &alloc.pnr {
                    assert!((0.0..=1.0).contains(p), "case {case}: pnr {p}");
                }
            }
            Err(e) => panic!("case {case}: unexpectedly infeasible: {e}"),
        }
    }
}

#[test]
fn prop_lambert_w_inverts_everywhere() {
    let mut rng = Rng::seed_from(106);
    let e_inv = std::f64::consts::E.recip();
    for _ in 0..2000 {
        // log-uniform over (-1/e, 0)
        let x = -e_inv * rng.next_f64().max(1e-12).powf(3.0);
        let w = lambert_w_m1(x);
        assert!(w <= -1.0 + 1e-9, "W_-1({x}) = {w}");
        let back = w * w.exp();
        assert!(
            (back - x).abs() <= 1e-9 * x.abs().max(1e-300),
            "inversion failed: x={x}, w={w}, back={back}"
        );
    }
}

#[test]
fn prop_sampled_delay_consistent_with_cdf() {
    // Kolmogorov-style agreement between sampler and analytic CDF.
    let mut rng = Rng::seed_from(107);
    for _ in 0..5 {
        let n = NodeParams {
            mu: 1.0 + rng.next_f64() * 10.0,
            alpha: 0.5 + rng.next_f64() * 5.0,
            tau: 0.1 + rng.next_f64(),
            p: rng.next_f64() * 0.6,
        };
        let ell = 1.0 + rng.next_f64() * 20.0;
        let t = n.mean_delay(ell) * (0.5 + rng.next_f64());
        let trials = 40_000;
        let hits = (0..trials).filter(|_| n.sample_delay(ell, &mut rng) <= t).count();
        let emp = hits as f64 / trials as f64;
        let exact = n.cdf(t, ell);
        assert!(
            (emp - exact).abs() < 0.015,
            "sampler/cdf mismatch: {n:?} ell={ell} t={t}: {emp} vs {exact}"
        );
    }
}

#[test]
fn prop_weight_vector_squares_to_pnr() {
    let mut rng = Rng::seed_from(108);
    for _ in 0..200 {
        let ell = 1 + rng.next_below(100);
        let ell_star = rng.next_below(ell + 1);
        let pnr = rng.next_f64();
        let processed = coding::sample_processed(ell, ell_star, &mut rng);
        let w = coding::weight_vector(&processed, pnr);
        for (wi, pi) in w.iter().zip(&processed) {
            let expect = if *pi { pnr as f32 } else { 1.0 };
            assert!((wi * wi - expect).abs() < 1e-5);
        }
    }
}

#[test]
fn prop_parity_aggregation_linear() {
    // Σ encode_j == encode of concatenation — random shapes.
    let mut rng = Rng::seed_from(109);
    for _ in 0..50 {
        let u = 1 + rng.next_below(12);
        let k = 1 + rng.next_below(6);
        let n_clients = 1 + rng.next_below(4);
        let mut parts = Vec::new();
        let mut global = Mat::zeros(u, k);
        for _ in 0..n_clients {
            let l = 1 + rng.next_below(10);
            let mut g = Mat::zeros(u, l);
            rng.fill_normal_f32(g.as_mut_slice());
            let mut d = Mat::zeros(l, k);
            rng.fill_normal_f32(d.as_mut_slice());
            let part = g.matmul_ref(&d);
            global.axpy(1.0, &part);
            parts.push(part);
        }
        let agg = coding::aggregate_parity(&parts).unwrap();
        assert!(agg.max_abs_diff(&global) < 1e-4);
    }
}

#[test]
fn prop_conf_parser_roundtrip() {
    // print(parse(x)) == parse(print(parse(x))) over generated docs.
    let mut rng = Rng::seed_from(110);
    for _ in 0..100 {
        let mut text = String::from("[s]\n");
        let n_keys = 1 + rng.next_below(6);
        for k in 0..n_keys {
            match rng.next_below(4) {
                0 => text.push_str(&format!("k{k} = {}\n", rng.next_below(1000))),
                1 => text.push_str(&format!("k{k} = {:.6}\n", rng.next_f64() * 100.0)),
                2 => text.push_str(&format!("k{k} = \"v{}\"\n", rng.next_below(10))),
                _ => text.push_str(&format!(
                    "k{k} = [{}, {}]\n",
                    rng.next_below(10),
                    rng.next_below(10)
                )),
            }
        }
        let doc = parse(&text).expect("generated config must parse");
        assert_eq!(doc["s"].len(), n_keys);
    }
}
