//! Determinism + efficacy gates for the byte-accurate communication
//! model (`[comm]`).
//!
//! 1. **Identity** — `codec = "none"` (+ the default `payload = "auto"`)
//!    is bit-identical to the pre-codec fixed-payload pricing
//!    (`payload = "fixed"` *is* that pricing, by definition), for every
//!    scheme × scenario × SIMD policy.
//! 2. **Efficacy** — a q8 uplink demonstrably shifts the coded scheme's
//!    optimal (load, redundancy) split and reduces the simulated epoch
//!    wall clock versus `none`.
//! 3. **Accounting** — per-round `RoundEvent` bytes sum exactly to the
//!    `TrainOutcome` totals, and codecs order the uplink bytes
//!    `none > q8 > bitpack` while leaving the downlink untouched.
//! 4. **Kernel invariance** — the quantize/dequantize path is bit-exact
//!    across ISAs on engine-shaped gradients, and quantized runs stay
//!    reproducible and thread-invariant.
//! 5. **Ablation seam** — `q8` + `payload = "fixed"` quantizes the folds
//!    while keeping every simulated timestamp bit-identical to `none`.

use codedfedl::comm::{self, CodecSpec, PayloadSpec, ScaleSpec};
use codedfedl::coordinator::EventLog;
use codedfedl::rng::Rng;
use codedfedl::schemes::SchemeSpec;
use codedfedl::sim::scenario::ScenarioSpec;
use codedfedl::tensor::{Isa, Mat, SimdPolicy};
use codedfedl::{ExperimentBuilder, TrainOutcome};

const Q8: CodecSpec = CodecSpec::Q8 { scale: ScaleSpec::Auto };

/// FNV-1a over the run's bits: θ plus every history point (the same
/// fingerprint `tests/scenario_determinism.rs` pins its goldens with).
fn run_hash(out: &TrainOutcome) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |bits: u64| {
        for b in bits.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    };
    for &v in out.theta.as_slice() {
        eat(v.to_bits() as u64);
    }
    for p in &out.history.points {
        eat(p.iter as u64);
        eat(p.sim_time.to_bits());
        eat(p.accuracy.to_bits());
        eat(p.train_loss.to_bits());
    }
    h
}

fn run(
    scheme: SchemeSpec,
    scenario: ScenarioSpec,
    simd: SimdPolicy,
    threads: usize,
    codec: CodecSpec,
    payload: PayloadSpec,
) -> TrainOutcome {
    ExperimentBuilder::preset("tiny")
        .unwrap()
        .epochs(2)
        .threads(threads)
        .simd(simd)
        .scenario(scenario)
        .codec(codec)
        .payload(payload)
        .build()
        .unwrap()
        .run_spec(scheme)
        .unwrap()
}

fn run_coded(codec: CodecSpec, payload: PayloadSpec) -> TrainOutcome {
    run(
        SchemeSpec::Coded { delta: 0.3 },
        ScenarioSpec::Static,
        SimdPolicy::Scalar,
        1,
        codec,
        payload,
    )
}

#[test]
fn codec_none_is_bit_identical_to_fixed_payload_pricing() {
    // `payload = "fixed"` prices every leg exactly as the pre-codec
    // engine did; `codec = "none"` + `payload = "auto"` must land on the
    // same bits — for every scheme, scenario and SIMD policy. This is
    // the tentpole's identity gate: the default communication model
    // changes nothing.
    let schemes = [
        SchemeSpec::NaiveUncoded,
        SchemeSpec::GreedyUncoded { psi: 0.2 },
        SchemeSpec::Coded { delta: 0.3 },
    ];
    let scenarios = [ScenarioSpec::Static, ScenarioSpec::Dropout { rate: 0.3 }];
    for scheme in schemes {
        for scenario in scenarios {
            for simd in [SimdPolicy::Scalar, SimdPolicy::Auto] {
                let auto = run(scheme, scenario, simd, 1, CodecSpec::None, PayloadSpec::Auto);
                let fixed =
                    run(scheme, scenario, simd, 1, CodecSpec::None, PayloadSpec::Fixed);
                assert_eq!(
                    run_hash(&auto),
                    run_hash(&fixed),
                    "{} / {}: codec=none repriced the run",
                    scheme.label(),
                    scenario.label()
                );
            }
        }
    }
}

#[test]
fn q8_shifts_the_allocation_and_reduces_the_wall_clock() {
    let none = run_coded(CodecSpec::None, PayloadSpec::Auto);
    let q8 = run_coded(Q8, PayloadSpec::Auto);

    // The shrunken uplink reaches the optimizer: the optimal deadline
    // moves (and with it the (load, redundancy) split).
    let (t_none, t_q8) = (none.t_star.unwrap(), q8.t_star.unwrap());
    assert!(
        t_q8 < t_none,
        "cheaper uplink must lower the optimal deadline: q8 t*={t_q8} vs none t*={t_none}"
    );
    // …and the run's simulated wall clock drops with it (parity upload
    // is repriced too, so the totals — overhead included — must order).
    let (wall_none, wall_q8) =
        (none.history.total_sim_time(), q8.history.total_sim_time());
    assert!(
        wall_q8 < wall_none,
        "q8 wall clock {wall_q8} !< none wall clock {wall_none}"
    );
    // The quantized run still trains properly.
    assert!(q8.history.points.iter().all(|p| p.train_loss.is_finite()));
    assert!(q8.theta.as_slice().iter().all(|v| v.is_finite()));
    assert_ne!(run_hash(&none), run_hash(&q8), "q8 left the history untouched");
}

#[test]
fn bitpack_runs_end_to_end_and_is_reproducible() {
    let a = run_coded(CodecSpec::Bitpack, PayloadSpec::Auto);
    let b = run_coded(CodecSpec::Bitpack, PayloadSpec::Auto);
    assert_eq!(run_hash(&a), run_hash(&b), "bitpack run is not reproducible");
    assert!(a.history.points.iter().all(|p| p.train_loss.is_finite()));
    // 4-bit uploads are cheaper than 8-bit ones on the clock too.
    let q8 = run_coded(Q8, PayloadSpec::Auto);
    assert!(a.t_star.unwrap() < q8.t_star.unwrap());
}

#[test]
fn round_events_account_bytes_that_sum_to_the_totals() {
    let observed = |codec: CodecSpec| {
        let mut log = EventLog::default();
        let out = ExperimentBuilder::preset("tiny")
            .unwrap()
            .epochs(2)
            .threads(1)
            .simd(SimdPolicy::Scalar)
            .codec(codec)
            .build()
            .unwrap()
            .run_observed(
                &mut codedfedl::schemes::CodedFedL::new(0.3),
                &mut log,
            )
            .unwrap();
        (out, log)
    };
    let (none, log_none) = observed(CodecSpec::None);
    let (q8, log_q8) = observed(Q8);
    let (bp, log_bp) = observed(CodecSpec::Bitpack);

    // eval_every = 1 on tiny ⇒ every round is evaluated ⇒ the event
    // stream covers the whole run and must sum exactly to the totals.
    for (out, log) in [(&none, &log_none), (&q8, &log_q8), (&bp, &log_bp)] {
        let down: u64 = log.events.iter().map(|ev| ev.bytes_down).sum();
        let up: u64 = log.events.iter().map(|ev| ev.bytes_up).sum();
        assert_eq!(down, out.bytes_down_total, "downlink accounting drifted");
        assert_eq!(up, out.bytes_up_total, "uplink accounting drifted");
        assert!(out.bytes_down_total > 0 && out.bytes_up_total > 0);
    }
    // Codecs shrink the uplink (none > q8 > bitpack) and never touch the
    // θ broadcast. Totals are not directly comparable across codecs when
    // round counts differ — but tiny runs a fixed schedule, so they are.
    assert!(q8.bytes_up_total < none.bytes_up_total);
    assert!(bp.bytes_up_total < q8.bytes_up_total);
    let per_round_down = |log: &EventLog| log.events[0].bytes_down;
    assert_eq!(per_round_down(&log_none), per_round_down(&log_q8));
    assert_eq!(per_round_down(&log_none), per_round_down(&log_bp));
}

#[test]
fn quantize_is_isa_invariant_on_engine_shaped_gradients() {
    // The engine transcodes through the runtime's detected ISA; the
    // detected kernels must reproduce the scalar oracle bitwise on
    // engine-shaped (q × c) gradients, or per-machine histories fork.
    let detected = Isa::detect(SimdPolicy::Auto);
    let mut rng = Rng::seed_from(0xC0DEC);
    for codec in [Q8, CodecSpec::Bitpack] {
        let mut base = Mat::zeros(64, 10);
        rng.fill_normal_scaled_f32(base.as_mut_slice(), 0.37);
        let mut via_detected = base.clone();
        let mut via_scalar = base;
        let mut s1 = comm::CodecScratch::default();
        let mut s2 = comm::CodecScratch::default();
        comm::transcode_mat(detected, codec, &mut via_detected, &mut s1);
        comm::transcode_mat(Isa::Scalar, codec, &mut via_scalar, &mut s2);
        let a: Vec<u32> = via_detected.as_slice().iter().map(|v| v.to_bits()).collect();
        let b: Vec<u32> = via_scalar.as_slice().iter().map(|v| v.to_bits()).collect();
        assert_eq!(a, b, "{}: {} diverged from scalar", codec.label(), detected.name());
    }
}

#[test]
fn quantized_runs_are_thread_invariant() {
    let one = run(
        SchemeSpec::Coded { delta: 0.3 },
        ScenarioSpec::Static,
        SimdPolicy::Scalar,
        1,
        Q8,
        PayloadSpec::Auto,
    );
    let four = run(
        SchemeSpec::Coded { delta: 0.3 },
        ScenarioSpec::Static,
        SimdPolicy::Scalar,
        4,
        Q8,
        PayloadSpec::Auto,
    );
    assert_eq!(run_hash(&one), run_hash(&four), "threads changed the q8 history");
}

#[test]
fn fixed_payload_isolates_quantization_from_repricing() {
    // `q8` + `payload = "fixed"` is the ablation control: gradients are
    // quantized before the fold, but every leg keeps its pre-codec
    // price. The simulated clock must therefore match `none` timestamp
    // for timestamp, bit for bit, while the learned model differs.
    let none = run_coded(CodecSpec::None, PayloadSpec::Auto);
    let ablate = run_coded(Q8, PayloadSpec::Fixed);
    assert_eq!(none.history.points.len(), ablate.history.points.len());
    assert_eq!(none.t_star, ablate.t_star, "fixed payload moved the optimizer");
    for (a, b) in none.history.points.iter().zip(&ablate.history.points) {
        assert_eq!(
            a.sim_time.to_bits(),
            b.sim_time.to_bits(),
            "iter {}: fixed payload changed the clock",
            a.iter
        );
    }
    assert_ne!(
        none.theta.as_slice(),
        ablate.theta.as_slice(),
        "q8 quantization left θ untouched"
    );
    // And the round-trip error is bounded: the quantized model stays
    // close to the unquantized one (q8 steps are tiny at tiny scale).
    for (a, b) in none.theta.as_slice().iter().zip(ablate.theta.as_slice()) {
        assert!((a - b).abs() < 0.5, "quantized θ drifted: {a} vs {b}");
    }
}
