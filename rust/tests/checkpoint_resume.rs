//! Crash-consistent checkpoint/resume gates (robustness PR): the house
//! invariant is that **a run interrupted at any round and resumed is
//! bit-identical to the uninterrupted run** — θ bits, every history
//! point, the outcome histogram, everything.
//!
//! 1. **Resume-at-every-boundary equivalence** — for every scheme ×
//!    {static, dropout} × {faults none, crash:rate=0.3} × SIMD policy, a
//!    checkpointed run snapshots at every round boundary; resuming from
//!    *each* boundary (at 1 and 4 threads — resume is thread-invariant,
//!    like the histories themselves) reproduces the uninterrupted run's
//!    golden hash exactly. Checkpointing itself never moves a bit.
//! 2. **Schedule extension** — `resume = "auto"` continues a shorter
//!    (fewer-epochs) run into a longer schedule bit-identically: the
//!    config fingerprint deliberately excludes `epochs`, so truncation +
//!    resume is the supported interruption mechanism.
//! 3. **Rejection, never panic** — torn/truncated prefixes, bit flips,
//!    wrong magic, unknown versions, mismatched configs and mismatched
//!    schemes all surface named `CheckpointError`s through the engine's
//!    resume path ("expected one of …" style), and a missing `path:`
//!    file is a named io error.

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};

use codedfedl::conf::ExperimentConfig;
use codedfedl::coordinator::{RoundEvent, RoundObserver};
use codedfedl::schemes::{CodedFedL, Scheme, SchemeSpec};
use codedfedl::sim::fault::FaultSpec;
use codedfedl::sim::scenario::ScenarioSpec;
use codedfedl::tensor::SimdPolicy;
use codedfedl::{ExperimentBuilder, ResumeSpec, TrainOutcome};

static UNIQ: AtomicUsize = AtomicUsize::new(0);

/// A collision-free scratch path (tests in this binary run concurrently).
fn tmp_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!(
        "codedfedl_ckpt_{}_{}_{tag}",
        std::process::id(),
        UNIQ.fetch_add(1, Ordering::Relaxed)
    ))
}

/// FNV-1a over the run's bits: θ plus every history point — the same
/// golden-hash idiom `tests/scenario_determinism.rs` pins histories with.
fn run_hash(out: &TrainOutcome) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |bits: u64| {
        for b in bits.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    };
    for &v in out.theta.as_slice() {
        eat(v.to_bits() as u64);
    }
    for p in &out.history.points {
        eat(p.iter as u64);
        eat(p.sim_time.to_bits());
        eat(p.accuracy.to_bits());
        eat(p.train_loss.to_bits());
    }
    h
}

fn cfg_with(
    scenario: ScenarioSpec,
    faults: FaultSpec,
    threads: usize,
    simd: SimdPolicy,
) -> ExperimentConfig {
    ExperimentConfig {
        epochs: 2, // tiny: 2 steps/epoch → 4 rounds
        threads,
        simd,
        scenario,
        faults,
        ..ExperimentConfig::tiny()
    }
}

/// Build a scheme exactly like `Session::run_spec` does, so labels (and
/// therefore checkpoint scheme stamps) agree across runs.
fn build_scheme(cfg: &ExperimentConfig, spec: SchemeSpec) -> Box<dyn Scheme> {
    match spec {
        SchemeSpec::Coded { delta } => {
            Box::new(CodedFedL::new(delta).with_code(cfg.code).with_recovery(cfg.recovery))
        }
        other => other.build(),
    }
}

fn run(cfg: ExperimentConfig, spec: SchemeSpec) -> TrainOutcome {
    let session = ExperimentBuilder::from_config(cfg).build().unwrap();
    let mut scheme = build_scheme(session.config(), spec);
    session.run(scheme.as_mut()).unwrap()
}

/// Copies the live checkpoint file at every round boundary. When the
/// event for round `k` fires, the file on disk holds boundary `k − 1`
/// (the engine checkpoints *after* the event fan-out), so snatching on
/// events 2..=total captures boundaries 1..=total−1; the graceful final
/// checkpoint supplies boundary `total`.
struct BoundarySnatcher {
    src: PathBuf,
    dir: PathBuf,
    copied: Vec<(usize, PathBuf)>,
}

impl RoundObserver for BoundarySnatcher {
    fn on_round(&mut self, ev: &RoundEvent) {
        if ev.iter >= 2 {
            let b = ev.iter - 1;
            let dst = self.dir.join(format!("boundary_{b}.ckpt"));
            std::fs::copy(&self.src, &dst).expect("snatching the live checkpoint");
            self.copied.push((b, dst));
        }
    }
}

#[test]
fn resume_at_every_boundary_is_bit_identical_to_the_uninterrupted_run() {
    let schemes = [
        SchemeSpec::NaiveUncoded,
        SchemeSpec::GreedyUncoded { psi: 0.2 },
        SchemeSpec::Coded { delta: 0.3 },
    ];
    let scenarios = [ScenarioSpec::Static, ScenarioSpec::Dropout { rate: 0.3 }];
    let fault_mixes = [FaultSpec::None, FaultSpec::Crash { rate: 0.3 }];

    for spec in schemes {
        for scenario in scenarios {
            for faults in fault_mixes {
                for simd in [SimdPolicy::Scalar, SimdPolicy::Auto] {
                    let tag = format!(
                        "{} / {} / {} / {simd:?}",
                        spec.label(),
                        scenario.label(),
                        faults.label()
                    );

                    // The uninterrupted golden run.
                    let golden_out = run(cfg_with(scenario, faults, 1, simd), spec);
                    assert!(golden_out.resumed_from.is_none(), "{tag}");
                    let golden = run_hash(&golden_out);

                    // The same run with per-round checkpointing, capturing
                    // every boundary as it goes by.
                    let live = tmp_path("live.ckpt");
                    let dir = tmp_path("boundaries");
                    std::fs::create_dir_all(&dir).unwrap();
                    let mut cfg = cfg_with(scenario, faults, 1, simd);
                    let total = cfg.total_iters();
                    cfg.checkpoint_every = 1;
                    cfg.checkpoint_path = Some(live.to_string_lossy().into_owned());
                    let session = ExperimentBuilder::from_config(cfg).build().unwrap();
                    let mut scheme = build_scheme(session.config(), spec);
                    let mut snatcher = BoundarySnatcher {
                        src: live.clone(),
                        dir: dir.clone(),
                        copied: Vec::new(),
                    };
                    let ckpt_out =
                        session.run_observed(scheme.as_mut(), &mut snatcher).unwrap();
                    // Checkpointing is bit-inert: same golden hash.
                    assert_eq!(
                        run_hash(&ckpt_out),
                        golden,
                        "{tag}: checkpointing changed the history"
                    );
                    // The graceful-shutdown checkpoint is boundary `total`.
                    let final_b = dir.join(format!("boundary_{total}.ckpt"));
                    std::fs::copy(&live, &final_b).unwrap();
                    snatcher.copied.push((total, final_b));
                    assert_eq!(snatcher.copied.len(), total, "{tag}: missed a boundary");

                    // Resume from every boundary, at 1 and 4 threads: the
                    // resumed run must be the golden run, bit for bit.
                    for (b, path) in &snatcher.copied {
                        for threads in [1usize, 4] {
                            let mut rcfg = cfg_with(scenario, faults, threads, simd);
                            rcfg.resume =
                                ResumeSpec::Path(path.to_string_lossy().into_owned());
                            let out = run(rcfg, spec);
                            assert_eq!(
                                out.resumed_from,
                                Some(*b),
                                "{tag}: boundary {b}, {threads} threads"
                            );
                            assert_eq!(
                                run_hash(&out),
                                golden,
                                "{tag}: resume at boundary {b} ({threads} threads) \
                                 diverged from the uninterrupted run"
                            );
                            assert_eq!(
                                out.outcomes, golden_out.outcomes,
                                "{tag}: boundary {b} outcome histogram"
                            );
                        }
                    }

                    let _ = std::fs::remove_file(&live);
                    let _ = std::fs::remove_dir_all(&dir);
                }
            }
        }
    }
}

#[test]
fn auto_resume_continues_a_shorter_run_into_a_longer_schedule() {
    let ckpt = tmp_path("auto.ckpt");
    let ckpt_str = ckpt.to_string_lossy().into_owned();

    // The interrupted run: half the schedule, checkpointing on. Its
    // graceful-shutdown checkpoint lands at round total/2.
    let mut short = cfg_with(ScenarioSpec::Static, FaultSpec::None, 1, SimdPolicy::Scalar);
    short.epochs = 1;
    short.checkpoint_every = 1;
    short.checkpoint_path = Some(ckpt_str.clone());
    let short_total = short.total_iters();
    run(short, SchemeSpec::Coded { delta: 0.3 });

    // `resume = "auto"` picks the checkpoint up and finishes the full
    // schedule — bit-identical to never having stopped.
    let golden = run_hash(&run(
        cfg_with(ScenarioSpec::Static, FaultSpec::None, 1, SimdPolicy::Scalar),
        SchemeSpec::Coded { delta: 0.3 },
    ));
    let mut resumed = cfg_with(ScenarioSpec::Static, FaultSpec::None, 1, SimdPolicy::Scalar);
    resumed.checkpoint_path = Some(ckpt_str.clone());
    resumed.resume = ResumeSpec::Auto;
    let out = run(resumed, SchemeSpec::Coded { delta: 0.3 });
    assert_eq!(out.resumed_from, Some(short_total));
    assert_eq!(run_hash(&out), golden, "auto resume diverged from the uninterrupted run");

    // `auto` with no checkpoint on disk starts fresh — same golden run,
    // no resume round reported.
    let missing = tmp_path("never_written.ckpt");
    let mut fresh = cfg_with(ScenarioSpec::Static, FaultSpec::None, 1, SimdPolicy::Scalar);
    fresh.checkpoint_path = Some(missing.to_string_lossy().into_owned());
    fresh.resume = ResumeSpec::Auto;
    let out = run(fresh, SchemeSpec::Coded { delta: 0.3 });
    assert!(out.resumed_from.is_none());
    assert_eq!(run_hash(&out), golden, "auto-without-checkpoint is not a fresh run");

    let _ = std::fs::remove_file(&ckpt);
}

/// Run a session whose resume spec points at `path` and return the full
/// rendered error chain (the run must fail — that's asserted here).
fn resume_error(spec: SchemeSpec, seed: Option<u64>, path: &str) -> String {
    let mut cfg = cfg_with(ScenarioSpec::Static, FaultSpec::None, 1, SimdPolicy::Scalar);
    if let Some(s) = seed {
        cfg.seed = s;
    }
    cfg.resume = ResumeSpec::Path(path.to_string());
    let session = ExperimentBuilder::from_config(cfg).build().unwrap();
    let mut scheme = build_scheme(session.config(), spec);
    let err = session
        .run(scheme.as_mut())
        .expect_err("a bad checkpoint must be rejected, never trained from");
    format!("{err:#}")
}

#[test]
fn torn_and_mismatched_checkpoints_are_rejected_with_named_errors() {
    // A genuine checkpoint to corrupt: one short coded run.
    let ckpt = tmp_path("victim.ckpt");
    let mut cfg = cfg_with(ScenarioSpec::Static, FaultSpec::None, 1, SimdPolicy::Scalar);
    cfg.epochs = 1;
    cfg.checkpoint_every = 1;
    cfg.checkpoint_path = Some(ckpt.to_string_lossy().into_owned());
    run(cfg, SchemeSpec::Coded { delta: 0.3 });
    let bytes = std::fs::read(&ckpt).unwrap();
    let coded = SchemeSpec::Coded { delta: 0.3 };

    // Torn prefixes of every flavour: decode names the failure (a
    // truncated field or the CRC), the engine surfaces it, nothing panics.
    for cut in [0, 4, 9, 12, bytes.len() / 2, bytes.len() - 5, bytes.len() - 1] {
        let torn = tmp_path("torn.ckpt");
        std::fs::write(&torn, &bytes[..cut]).unwrap();
        let msg = resume_error(coded, None, &torn.to_string_lossy());
        assert!(
            msg.contains("truncated") || msg.contains("CRC mismatch"),
            "cut at {cut}: unhelpful error {msg:?}"
        );
        let _ = std::fs::remove_file(&torn);
    }

    // A single flipped bit mid-payload is caught by the CRC by name.
    let flipped = tmp_path("flipped.ckpt");
    let mut bad = bytes.clone();
    bad[bytes.len() / 2] ^= 0x01;
    std::fs::write(&flipped, &bad).unwrap();
    let msg = resume_error(coded, None, &flipped.to_string_lossy());
    assert!(msg.contains("CRC mismatch"), "bit flip: {msg:?}");

    // Wrong magic and unknown version carry "expected one of …" text.
    let mut bad = bytes.clone();
    bad[0] ^= 0x01;
    std::fs::write(&flipped, &bad).unwrap();
    let msg = resume_error(coded, None, &flipped.to_string_lossy());
    assert!(msg.contains("bad magic"), "magic: {msg:?}");
    let mut bad = bytes.clone();
    bad[8..12].copy_from_slice(&99u32.to_le_bytes());
    std::fs::write(&flipped, &bad).unwrap();
    let msg = resume_error(coded, None, &flipped.to_string_lossy());
    assert!(msg.contains("expected one of 1"), "version: {msg:?}");
    let _ = std::fs::remove_file(&flipped);

    // A different experiment config (seed) is a named fingerprint
    // mismatch — the checkpoint is intact, it's just not this run's.
    let msg = resume_error(coded, Some(0xD15EA5E), &ckpt.to_string_lossy());
    assert!(msg.contains("fingerprint"), "config mismatch: {msg:?}");

    // A different scheme is rejected by name even under the same config.
    let msg = resume_error(SchemeSpec::NaiveUncoded, None, &ckpt.to_string_lossy());
    assert!(msg.contains("scheme"), "scheme mismatch: {msg:?}");

    // `path:` to a missing file is a named io error, not a fresh start.
    let gone = tmp_path("missing.ckpt");
    let msg = resume_error(coded, None, &gone.to_string_lossy());
    assert!(msg.contains("checkpoint io"), "missing file: {msg:?}");

    let _ = std::fs::remove_file(&ckpt);
}
