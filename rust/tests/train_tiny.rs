//! Integration: full federated training on the tiny preset, all schemes,
//! through the Builder → Session → Scheme API. Asserts the paper's
//! qualitative claims at smoke scale plus exact reproducibility.

use codedfedl::benchutil;
use codedfedl::conf::ExperimentConfig;
use codedfedl::schemes::{CodedFedL, SchemeSpec};
use codedfedl::{ExperimentBuilder, Session};

fn tiny(epochs: usize) -> ExperimentConfig {
    ExperimentConfig { epochs, ..ExperimentConfig::tiny() }
}

fn tiny_session(epochs: usize) -> Session {
    ExperimentBuilder::preset("tiny").unwrap().epochs(epochs).build().unwrap()
}

#[test]
fn all_schemes_run_and_learn() {
    let cfg = tiny(30);
    let schemes = [
        SchemeSpec::NaiveUncoded,
        SchemeSpec::GreedyUncoded { psi: 0.2 },
        SchemeSpec::Coded { delta: 0.3 },
    ];
    let (_, results) = benchutil::run_experiment(&cfg, &schemes).unwrap();
    for (s, r) in &results {
        assert_eq!(r.history.points.len(), cfg.total_iters());
        // 10-class random = 0.1; require real learning signal.
        assert!(
            r.history.best_accuracy() > 0.25,
            "{} only reached {}",
            s.label(),
            r.history.best_accuracy()
        );
        // simulated clock is strictly increasing and positive
        let mut prev = 0.0;
        for p in &r.history.points {
            assert!(p.sim_time > prev);
            prev = p.sim_time;
        }
        // loss is finite (no divergence under the clamped lr)
        assert!(r.history.points.iter().all(|p| p.train_loss.is_finite()));
    }
}

#[test]
fn coded_round_time_is_deadline_and_faster_than_naive() {
    let cfg = tiny(8);
    let (_, results) = benchutil::run_experiment(
        &cfg,
        &[SchemeSpec::NaiveUncoded, SchemeSpec::Coded { delta: 0.3 }],
    )
    .unwrap();
    let naive = &results[0].1;
    let coded = &results[1].1;
    let t_star = coded.t_star.unwrap();
    assert!(t_star > 0.0);
    assert!(coded.u_star.unwrap() >= 1);
    // every coded round costs exactly t*
    let pts = &coded.history.points;
    for w in pts.windows(2) {
        let dt = w[1].sim_time - w[0].sim_time;
        assert!((dt - t_star).abs() < 1e-9, "round cost {dt} != t* {t_star}");
    }
    // per-iteration simulated cost must beat waiting for every straggler
    let naive_per_iter = naive.history.total_sim_time() / naive.history.points.len() as f64;
    let coded_per_iter =
        (coded.history.total_sim_time() - coded.parity_overhead) / pts.len() as f64;
    assert!(
        coded_per_iter < naive_per_iter,
        "coded {coded_per_iter} !< naive {naive_per_iter}"
    );
}

#[test]
fn runs_are_exactly_reproducible() {
    let run = || {
        let session = tiny_session(4);
        session.run(&mut CodedFedL::new(0.3)).unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.t_star, b.t_star);
    assert_eq!(a.theta.as_slice(), b.theta.as_slice());
    for (pa, pb) in a.history.points.iter().zip(&b.history.points) {
        assert_eq!(pa.accuracy, pb.accuracy);
        assert_eq!(pa.sim_time, pb.sim_time);
    }
}

#[test]
fn different_seeds_change_the_run() {
    let sa = tiny_session(3);
    let sb = ExperimentBuilder::preset("tiny").unwrap().epochs(3).seed(999).build().unwrap();
    let ra = sa.run_spec(SchemeSpec::NaiveUncoded).unwrap();
    let rb = sb.run_spec(SchemeSpec::NaiveUncoded).unwrap();
    assert_ne!(ra.theta.as_slice(), rb.theta.as_slice());
}

#[test]
fn greedy_discards_make_it_cheaper_per_round_than_naive() {
    let cfg = tiny(6);
    let (_, results) = benchutil::run_experiment(
        &cfg,
        &[SchemeSpec::NaiveUncoded, SchemeSpec::GreedyUncoded { psi: 0.4 }],
    )
    .unwrap();
    let naive_t = results[0].1.history.total_sim_time();
    let greedy_t = results[1].1.history.total_sim_time();
    assert!(greedy_t < naive_t, "greedy {greedy_t} !< naive {naive_t}");
}

#[test]
fn setup_smoothness_is_positive_and_lr_clamped() {
    let session = tiny_session(2);
    let setup = session.setup();
    let cfg = session.config();
    assert!(setup.smoothness > 0.0);
    let lr0 = setup.effective_lr(0);
    assert!(lr0 > 0.0 && lr0 <= cfg.lr);
    // decay still decays
    let last = setup.effective_lr(cfg.epochs.max(4));
    assert!(last <= lr0);
}
