//! Integration: full federated training on the tiny preset, all schemes,
//! through the Builder → Session → Scheme API. Asserts the paper's
//! qualitative claims at smoke scale plus exact reproducibility.
//!
//! Runs under the network scenario named by `CODEDFEDL_SCENARIO`
//! (any [`ScenarioSpec`] string; default `static`) — CI runs the suite
//! once per scenario, so every qualitative claim (coded's fixed t*,
//! monotone clocks, thread invariance, eval_every telemetry-only) holds
//! under client dropout too, not just the paper's stationary fleet.
//! Likewise under the participation named by `CODEDFEDL_PARTICIPATION`
//! (any [`ParticipationSpec`] string; default `full`) — CI runs the
//! suite under `sample:k=4` too, so the claims survive per-round
//! sampled rosters — and under the fault mix named by `CODEDFEDL_FAULTS`
//! (any [`FaultSpec`] string; default `none`), so they survive injected
//! client crashes as well — and under the uplink codec named by
//! `CODEDFEDL_CODEC` (any [`CodecSpec`] string; default `none`), so they
//! survive quantized gradients and repriced uplinks too.

use codedfedl::benchutil;
use codedfedl::comm::CodecSpec;
use codedfedl::conf::ExperimentConfig;
use codedfedl::schemes::{CodedFedL, SchemeSpec};
use codedfedl::sim::fault::FaultSpec;
use codedfedl::sim::scenario::ScenarioSpec;
use codedfedl::topology::ParticipationSpec;
use codedfedl::{ExperimentBuilder, Session};

fn env_scenario() -> ScenarioSpec {
    match std::env::var("CODEDFEDL_SCENARIO") {
        Ok(v) => v.parse().expect("CODEDFEDL_SCENARIO"),
        Err(_) => ScenarioSpec::Static,
    }
}

fn env_participation() -> ParticipationSpec {
    match std::env::var("CODEDFEDL_PARTICIPATION") {
        Ok(v) => v.parse().expect("CODEDFEDL_PARTICIPATION"),
        Err(_) => ParticipationSpec::Full,
    }
}

fn env_faults() -> FaultSpec {
    match std::env::var("CODEDFEDL_FAULTS") {
        Ok(v) => v.parse().expect("CODEDFEDL_FAULTS"),
        Err(_) => FaultSpec::None,
    }
}

fn env_codec() -> CodecSpec {
    match std::env::var("CODEDFEDL_CODEC") {
        Ok(v) => v.parse().expect("CODEDFEDL_CODEC"),
        Err(_) => CodecSpec::None,
    }
}

fn tiny(epochs: usize) -> ExperimentConfig {
    ExperimentConfig {
        epochs,
        scenario: env_scenario(),
        participation: env_participation(),
        faults: env_faults(),
        codec: env_codec(),
        ..ExperimentConfig::tiny()
    }
}

fn tiny_session(epochs: usize) -> Session {
    ExperimentBuilder::from_config(tiny(epochs)).build().unwrap()
}

#[test]
fn all_schemes_run_and_learn() {
    let cfg = tiny(30);
    let schemes = [
        SchemeSpec::NaiveUncoded,
        SchemeSpec::GreedyUncoded { psi: 0.2 },
        SchemeSpec::Coded { delta: 0.3 },
    ];
    let (_, results) = benchutil::run_experiment(&cfg, &schemes).unwrap();
    for (s, r) in &results {
        assert_eq!(r.history.points.len(), cfg.total_iters());
        // 10-class random = 0.1; require real learning signal.
        assert!(
            r.history.best_accuracy() > 0.25,
            "{} only reached {}",
            s.label(),
            r.history.best_accuracy()
        );
        // simulated clock is strictly increasing and positive
        let mut prev = 0.0;
        for p in &r.history.points {
            assert!(p.sim_time > prev);
            prev = p.sim_time;
        }
        // loss is finite (no divergence under the clamped lr)
        assert!(r.history.points.iter().all(|p| p.train_loss.is_finite()));
    }
}

#[test]
fn coded_round_time_is_deadline_and_faster_than_naive() {
    let cfg = tiny(8);
    let (_, results) = benchutil::run_experiment(
        &cfg,
        &[SchemeSpec::NaiveUncoded, SchemeSpec::Coded { delta: 0.3 }],
    )
    .unwrap();
    let naive = &results[0].1;
    let coded = &results[1].1;
    let t_star = coded.t_star.unwrap();
    assert!(t_star > 0.0);
    assert!(coded.u_star.unwrap() >= 1);
    // every coded round costs exactly t*
    let pts = &coded.history.points;
    for w in pts.windows(2) {
        let dt = w[1].sim_time - w[0].sim_time;
        assert!((dt - t_star).abs() < 1e-9, "round cost {dt} != t* {t_star}");
    }
    // per-iteration simulated cost must beat waiting for every straggler.
    // Only claimed under full participation and without injected faults:
    // a sampled naive round waits for k < n clients, and a crash-faulted
    // naive round waits only for the survivors — either can legitimately
    // undercut the full-fleet deadline t*.
    if env_participation() == ParticipationSpec::Full && env_faults() == FaultSpec::None {
        let naive_per_iter = naive.history.total_sim_time() / naive.history.points.len() as f64;
        let coded_per_iter =
            (coded.history.total_sim_time() - coded.parity_overhead) / pts.len() as f64;
        assert!(
            coded_per_iter < naive_per_iter,
            "coded {coded_per_iter} !< naive {naive_per_iter}"
        );
    }
}

#[test]
fn runs_are_exactly_reproducible() {
    let run = || {
        let session = tiny_session(4);
        session.run(&mut CodedFedL::new(0.3)).unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.t_star, b.t_star);
    assert_eq!(a.theta.as_slice(), b.theta.as_slice());
    for (pa, pb) in a.history.points.iter().zip(&b.history.points) {
        assert_eq!(pa.accuracy, pb.accuracy);
        assert_eq!(pa.sim_time, pb.sim_time);
    }
}

#[test]
fn thread_count_does_not_change_the_history() {
    // The native kernels partition output rows and the engine folds client
    // gradients in plan order, so any thread count must reproduce the
    // serial run bit-for-bit — for every scheme.
    let run = |threads: usize, spec: SchemeSpec| {
        ExperimentBuilder::preset("tiny")
            .unwrap()
            .epochs(3)
            .threads(threads)
            .scenario(env_scenario())
            .participation(env_participation())
            .faults(env_faults())
            .codec(env_codec())
            .build()
            .unwrap()
            .run_spec(spec)
            .unwrap()
    };
    for spec in [
        SchemeSpec::NaiveUncoded,
        SchemeSpec::GreedyUncoded { psi: 0.2 },
        SchemeSpec::Coded { delta: 0.3 },
    ] {
        let serial = run(1, spec);
        let parallel = run(4, spec);
        assert_eq!(
            serial.theta.as_slice(),
            parallel.theta.as_slice(),
            "{}: threads=4 diverged from serial",
            spec.label()
        );
        for (pa, pb) in serial.history.points.iter().zip(&parallel.history.points) {
            assert_eq!(pa.accuracy, pb.accuracy, "{}", spec.label());
            assert_eq!(pa.train_loss, pb.train_loss, "{}", spec.label());
        }
    }
}

#[test]
fn eval_every_samples_history_but_keeps_training_identical() {
    let run = |eval_every: usize| {
        ExperimentBuilder::preset("tiny")
            .unwrap()
            .epochs(4) // tiny: 2 steps/epoch → 8 iterations
            .eval_every(eval_every)
            .scenario(env_scenario())
            .participation(env_participation())
            .faults(env_faults())
            .codec(env_codec())
            .build()
            .unwrap()
            .run(&mut CodedFedL::new(0.3))
            .unwrap()
    };
    let dense = run(1);
    let sparse = run(3);
    // Sampled points carry their iteration; the final round is always there.
    let iters: Vec<usize> = sparse.history.points.iter().map(|p| p.iter).collect();
    assert_eq!(iters, vec![3, 6, 8]);
    assert_eq!(dense.history.points.len(), 8);
    // The probe is telemetry only: the trained model is unchanged…
    assert_eq!(dense.theta.as_slice(), sparse.theta.as_slice());
    // …and the sampled points agree exactly with the dense run's.
    for p in &sparse.history.points {
        let d = dense.history.points.iter().find(|q| q.iter == p.iter).unwrap();
        assert_eq!(p.accuracy, d.accuracy);
        assert_eq!(p.train_loss, d.train_loss);
        assert_eq!(p.sim_time, d.sim_time);
    }
}

#[test]
fn different_seeds_change_the_run() {
    let sa = tiny_session(3);
    let sb = ExperimentBuilder::from_config(tiny(3)).seed(999).build().unwrap();
    let ra = sa.run_spec(SchemeSpec::NaiveUncoded).unwrap();
    let rb = sb.run_spec(SchemeSpec::NaiveUncoded).unwrap();
    assert_ne!(ra.theta.as_slice(), rb.theta.as_slice());
}

#[test]
fn greedy_discards_make_it_cheaper_per_round_than_naive() {
    let cfg = tiny(6);
    let (_, results) = benchutil::run_experiment(
        &cfg,
        &[SchemeSpec::NaiveUncoded, SchemeSpec::GreedyUncoded { psi: 0.4 }],
    )
    .unwrap();
    let naive_t = results[0].1.history.total_sim_time();
    let greedy_t = results[1].1.history.total_sim_time();
    assert!(greedy_t < naive_t, "greedy {greedy_t} !< naive {naive_t}");
}

#[test]
fn setup_smoothness_is_positive_and_lr_clamped() {
    let session = tiny_session(2);
    let setup = session.setup();
    let cfg = session.config();
    assert!(setup.smoothness > 0.0);
    let lr0 = setup.effective_lr(0);
    assert!(lr0 > 0.0 && lr0 <= cfg.lr);
    // decay still decays
    let last = setup.effective_lr(cfg.epochs.max(4));
    assert!(last <= lr0);
}
