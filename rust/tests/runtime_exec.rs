//! Integration: the kernel runtime against Rust-side reference math.
//!
//! On the default (native) backend these tests pin the pure-Rust kernels
//! to the reference math; with `--features pjrt` (which requires the
//! `tiny` artifacts — `make artifacts`) the same suite proves the full
//! AOT bridge — python/jax/pallas → HLO text → PJRT compile → execute —
//! is numerically faithful, including the zero-padding policy.

use codedfedl::rng::Rng;
use codedfedl::runtime::{Runtime, RuntimeShapes};
use codedfedl::tensor::Mat;

const TINY: RuntimeShapes =
    RuntimeShapes { d: 32, q: 64, c: 10, l_client: 40, u_max: 128, b_embed: 40 };

fn runtime() -> Runtime {
    Runtime::load(std::path::Path::new("artifacts"), TINY)
        .expect("tiny artifacts missing — run `make artifacts`")
}

fn randn(rows: usize, cols: usize, rng: &mut Rng) -> Mat {
    let mut m = Mat::zeros(rows, cols);
    rng.fill_normal_f32(m.as_mut_slice());
    m
}

/// max |a-b| helper with a tolerance suited to f32 matmuls at these sizes.
fn assert_close(a: &Mat, b: &Mat, tol: f32) {
    let d = a.max_abs_diff(b);
    assert!(d <= tol, "max diff {d} > {tol}");
}

#[test]
fn embed_matches_reference() {
    let rt = runtime();
    let mut rng = Rng::seed_from(1);
    let x = randn(40, 32, &mut rng);
    let omega = randn(32, 64, &mut rng);
    let delta: Vec<f32> = (0..64).map(|_| rng.next_f32() * 6.28).collect();
    let out = rt.embed(&x, &omega, &delta).unwrap();
    // reference: sqrt(2/q) cos(x @ omega + delta)
    let xo = x.matmul_ref(&omega);
    let scale = (2.0f32 / 64.0).sqrt();
    let expect = Mat::from_fn(40, 64, |r, c| scale * (xo.get(r, c) + delta[c]).cos());
    assert_close(&out, &expect, 2e-5);
}

#[test]
fn embed_chunks_and_pads_ragged_input() {
    let rt = runtime();
    let mut rng = Rng::seed_from(2);
    // 100 rows with b_embed = 40: chunks 40/40/20(padded)
    let x = randn(100, 32, &mut rng);
    let omega = randn(32, 64, &mut rng);
    let delta = vec![0.5f32; 64];
    let full = rt.embed(&x, &omega, &delta).unwrap();
    assert_eq!(full.rows(), 100);
    // each row independent: row 95 must equal embedding of just that row
    let single = rt.embed(&x.rows_slice(95, 1), &omega, &delta).unwrap();
    let row_diff: f32 = full
        .row(95)
        .iter()
        .zip(single.row(0))
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f32::max);
    assert!(row_diff < 1e-6, "{row_diff}");
}

#[test]
fn grad_matches_reference() {
    let rt = runtime();
    let mut rng = Rng::seed_from(3);
    let xhat = randn(40, 64, &mut rng);
    let y = randn(40, 10, &mut rng);
    let theta = randn(64, 10, &mut rng);
    let mask: Vec<f32> = (0..40).map(|i| if i % 3 == 0 { 1.0 } else { 0.0 }).collect();
    let g = rt.grad(&xhat, &y, &theta, &mask).unwrap();
    // reference: xhat^T diag(mask) (xhat theta - y)
    let pred = xhat.matmul_ref(&theta);
    let mut resid = Mat::zeros(40, 10);
    for r in 0..40 {
        for c in 0..10 {
            resid.set(r, c, mask[r] * (pred.get(r, c) - y.get(r, c)));
        }
    }
    let xt = Mat::from_fn(64, 40, |r, c| xhat.get(c, r));
    let expect = xt.matmul_ref(&resid);
    assert_close(&g, &expect, 1e-3);
}

#[test]
fn grad_partial_rows_pad_exactly() {
    // 25 rows (< l_client = 40) must give the same gradient as the same 25
    // rows explicitly zero-padded by the caller.
    let rt = runtime();
    let mut rng = Rng::seed_from(4);
    let xhat = randn(25, 64, &mut rng);
    let y = randn(25, 10, &mut rng);
    let theta = randn(64, 10, &mut rng);
    let mask = vec![1.0f32; 25];
    let g_small = rt.grad(&xhat, &y, &theta, &mask).unwrap();
    let mut mask_p = mask.clone();
    mask_p.resize(40, 1.0); // even mask=1 on zero rows contributes 0
    let g_pad = rt
        .grad(&xhat.pad_rows(40), &y.pad_rows(40), &theta, &mask_p)
        .unwrap();
    assert_close(&g_small, &g_pad, 1e-4);
}

#[test]
fn grad_uses_server_shape_for_parity_rows() {
    let rt = runtime();
    let mut rng = Rng::seed_from(5);
    // 100 rows: between l_client=40 and u_max=128 → server executable.
    let xhat = randn(100, 64, &mut rng);
    let y = randn(100, 10, &mut rng);
    let theta = randn(64, 10, &mut rng);
    let g = rt.grad(&xhat, &y, &theta, &vec![1.0; 100]).unwrap();
    assert_eq!((g.rows(), g.cols()), (64, 10));
    // too many rows must fail loudly
    let big = randn(200, 64, &mut rng);
    let yb = randn(200, 10, &mut rng);
    assert!(rt.grad(&big, &yb, &theta, &vec![1.0; 200]).is_err());
}

#[test]
fn encode_matches_reference_and_pads_generator() {
    let rt = runtime();
    let mut rng = Rng::seed_from(6);
    let u = 100; // < u_max = 128: G zero-padded inside
    let g = randn(u, 40, &mut rng);
    let w: Vec<f32> = (0..40).map(|_| rng.next_f32()).collect();
    let xhat = randn(40, 64, &mut rng);
    let y = randn(40, 10, &mut rng);
    let (xp, yp) = rt.encode(&g, &w, &xhat, &y).unwrap();
    assert_eq!((xp.rows(), xp.cols()), (128, 64));
    assert_eq!((yp.rows(), yp.cols()), (128, 10));
    // reference on the live rows
    let gw = Mat::from_fn(u, 40, |r, c| g.get(r, c) * w[c]);
    let expect_x = gw.matmul_ref(&xhat);
    assert_close(&xp.rows_slice(0, u), &expect_x, 1e-3);
    // padded rows are exactly zero
    assert!(xp.rows_slice(u, 128 - u).as_slice().iter().all(|&v| v == 0.0));
    assert!(yp.rows_slice(u, 128 - u).as_slice().iter().all(|&v| v == 0.0));
}

#[test]
fn predict_matches_reference() {
    let rt = runtime();
    let mut rng = Rng::seed_from(7);
    let xhat = randn(90, 64, &mut rng); // ragged vs b_embed = 40
    let theta = randn(64, 10, &mut rng);
    let logits = rt.predict(&xhat, &theta).unwrap();
    let expect = xhat.matmul_ref(&theta);
    assert_close(&logits, &expect, 1e-3);
}

/// PJRT must fail fast when the manifest lacks the shapes the experiment
/// needs; the native backend is shape-generic and loads regardless.
#[cfg(feature = "pjrt")]
#[test]
fn runtime_rejects_missing_shapes() {
    let bad = RuntimeShapes { d: 31, ..TINY };
    let err = Runtime::load(std::path::Path::new("artifacts"), bad)
        .err()
        .expect("should fail")
        .to_string();
    assert!(err.contains("rff_embed"), "{err}");
}

#[cfg(not(feature = "pjrt"))]
#[test]
fn native_backend_loads_without_artifacts() {
    let rt = Runtime::load(std::path::Path::new("artifacts"), TINY).unwrap();
    assert_eq!(rt.backend_name(), "native");
    // Shape checks still bite at call level even though loading is lazy
    // about artifacts: the native backend enforces the same contract.
    let bad = Runtime::load(std::path::Path::new("nonexistent"), TINY).unwrap();
    assert_eq!(bad.backend_name(), "native");
}

#[test]
fn shape_validation_errors_are_loud() {
    let rt = runtime();
    let mut rng = Rng::seed_from(8);
    let xhat = randn(40, 63, &mut rng); // wrong q
    let y = randn(40, 10, &mut rng);
    let theta = randn(64, 10, &mut rng);
    assert!(rt.grad(&xhat, &y, &theta, &vec![1.0; 40]).is_err());
}
