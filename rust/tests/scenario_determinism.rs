//! Scenario determinism gates for the event-timeline refactor.
//!
//! 1. **Static equivalence** — the scenario-aware timeline path draws the
//!    *identical* RNG sequence and produces bit-identical delays to the
//!    pre-timeline [`RoundSampler`] (kept in-tree as the reference), and
//!    a `scenario = "static"` training run is a bit-reproducible golden
//!    history: two independently-built sessions hash identically, at
//!    every thread count.
//! 2. **Scenario reproducibility** — every built-in scenario yields the
//!    same bits across repeated runs, across thread counts, and within
//!    each SIMD policy.
//! 3. **Scenarios matter** — the non-static built-ins actually change
//!    the sampled rounds (no silently-inert scenario).
//! 4. **Asymmetric fleet end-to-end** — a `[fleet]`-configured session
//!    builds per-leg links, hands the optimizer matched-mean surrogates,
//!    trains, and reproduces bit-for-bit.

use codedfedl::conf::ExperimentConfig;
use codedfedl::rng::Rng;
use codedfedl::schemes::SchemeSpec;
use codedfedl::sim::scenario::{Scenario, ScenarioSpec};
use codedfedl::sim::timeline::RoundTrace;
use codedfedl::sim::{RoundDelays, RoundSampler};
use codedfedl::tensor::SimdPolicy;
use codedfedl::topology::{AsymLinkSpec, FleetSpec, FleetView, ParticipationSpec};
use codedfedl::{ExperimentBuilder, TrainOutcome};

/// Participation under test (`CODEDFEDL_PARTICIPATION`, default `full`) —
/// CI re-runs the whole suite under `sample:k=4`, so every reproducibility
/// gate here also pins the sampled-roster path.
fn env_participation() -> ParticipationSpec {
    match std::env::var("CODEDFEDL_PARTICIPATION") {
        Ok(v) => v.parse().expect("CODEDFEDL_PARTICIPATION"),
        Err(_) => ParticipationSpec::Full,
    }
}

const BUILT_INS: [ScenarioSpec; 4] = [
    ScenarioSpec::Static,
    ScenarioSpec::Dropout { rate: 0.3 },
    ScenarioSpec::Fading { depth: 0.6, period: 5.0 },
    ScenarioSpec::Burst { slow: 0.4, factor: 8.0 },
];

/// FNV-1a over the run's bits: θ plus every history point. Any change to
/// delay draws, participation or kernels shows up here.
fn run_hash(out: &TrainOutcome) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |bits: u64| {
        for b in bits.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    };
    for &v in out.theta.as_slice() {
        eat(v.to_bits() as u64);
    }
    for p in &out.history.points {
        eat(p.iter as u64);
        eat(p.sim_time.to_bits());
        eat(p.accuracy.to_bits());
        eat(p.train_loss.to_bits());
    }
    h
}

fn run(scenario: ScenarioSpec, threads: usize, simd: SimdPolicy) -> TrainOutcome {
    ExperimentBuilder::preset("tiny")
        .unwrap()
        .epochs(2)
        .threads(threads)
        .simd(simd)
        .scenario(scenario)
        .participation(env_participation())
        .build()
        .unwrap()
        .run_spec(SchemeSpec::Coded { delta: 0.3 })
        .unwrap()
}

#[test]
fn static_timeline_matches_pre_refactor_sampler_bitwise() {
    // The one-shot RoundSampler *is* the pre-refactor sampling code,
    // unchanged — bit-equality against it over many rounds proves the
    // static scenario's delay stream survived the per-leg refactor.
    let spec = FleetSpec::paper(8, 64, 10);
    let clients = spec.build_clients(&mut Rng::seed_from(4));
    let links = spec.build_links(&clients);
    let server = spec.build_server();
    let loads = vec![13.0; 8];

    let sampler = RoundSampler::new(&clients, server, loads.clone(), 40.0);
    let mut legacy_rng = Rng::seed_from(99);
    let mut legacy = RoundDelays::default();

    let mut scenario = ScenarioSpec::Static.build();
    let mut scen_rng = Rng::seed_from(1234); // static must never touch it
    let scen_probe = scen_rng.clone();
    let mut timeline_rng = Rng::seed_from(99);
    let mut view = FleetView::from_base(&links, server);
    let mut trace = RoundTrace::with_capacity(8);

    for round in 0..60 {
        sampler.sample_into(&mut legacy_rng, &mut legacy);
        view.reset_from(&links, server);
        scenario.begin_round(round, &mut view, &mut scen_rng);
        trace.sample_into(&view, &loads, 40.0, &mut timeline_rng);
        assert_eq!(trace.delays().server_t.to_bits(), legacy.server_t.to_bits());
        for (j, (a, b)) in trace.delays().client_t.iter().zip(&legacy.client_t).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "round {round}, client {j}");
        }
    }
    // The scenario stream was never consumed.
    let mut a = scen_rng;
    let mut b = scen_probe;
    assert_eq!(a.next_u64(), b.next_u64());
}

#[test]
fn static_golden_history_is_thread_invariant_and_reproducible() {
    // Two independently-built sessions (builder vs config value) must
    // produce the same golden hash, and the hash must not move with the
    // thread count. This pins `scenario = "static"` to one bit-exact
    // history per (seed, simd policy).
    let golden = run_hash(&run(ScenarioSpec::Static, 1, SimdPolicy::Scalar));
    let again = run_hash(&run(ScenarioSpec::Static, 1, SimdPolicy::Scalar));
    assert_eq!(golden, again, "same-config rebuild changed the static history");

    let threaded = run_hash(&run(ScenarioSpec::Static, 4, SimdPolicy::Scalar));
    assert_eq!(golden, threaded, "thread count changed the static history");

    let via_config = {
        let cfg = ExperimentConfig {
            epochs: 2,
            threads: 1,
            simd: SimdPolicy::Scalar,
            participation: env_participation(),
            ..ExperimentConfig::tiny()
        };
        let session = ExperimentBuilder::from_config(cfg).build().unwrap();
        session.run_spec(SchemeSpec::Coded { delta: 0.3 }).unwrap()
    };
    assert_eq!(
        golden,
        run_hash(&via_config),
        "config-built session diverged from the builder path"
    );
}

#[test]
fn every_builtin_scenario_is_reproducible_across_threads_and_simd() {
    for scenario in BUILT_INS {
        for simd in [SimdPolicy::Scalar, SimdPolicy::Auto] {
            let one = run_hash(&run(scenario, 1, simd));
            let rerun = run_hash(&run(scenario, 1, simd));
            let four = run_hash(&run(scenario, 4, simd));
            assert_eq!(one, rerun, "{}: rerun changed bits", scenario.label());
            assert_eq!(one, four, "{}: thread count changed bits", scenario.label());
        }
    }
}

#[test]
fn non_static_scenarios_change_the_sampled_rounds() {
    // Naive's round cost is the max present delay — any dropout, fade or
    // burst moves the simulated clock. A scenario that silently does
    // nothing would make these hashes collide with static.
    let run_naive = |scenario: ScenarioSpec| {
        ExperimentBuilder::preset("tiny")
            .unwrap()
            .epochs(4) // 8 rounds: a 0.3-rate dropout hits w.p. 1 - 0.7^40
            .threads(1)
            .simd(SimdPolicy::Scalar)
            .scenario(scenario)
            .participation(env_participation())
            .build()
            .unwrap()
            .run_spec(SchemeSpec::NaiveUncoded)
            .unwrap()
    };
    let static_hash = run_hash(&run_naive(ScenarioSpec::Static));
    for scenario in &BUILT_INS[1..] {
        let h = run_hash(&run_naive(*scenario));
        assert_ne!(h, static_hash, "{} left the run untouched", scenario.label());
    }
}

#[test]
fn asymmetric_fleet_runs_end_to_end_and_reproduces() {
    let cfg = ExperimentConfig {
        epochs: 2,
        fleet_asym: Some(AsymLinkSpec {
            tau_down: 1.0,
            tau_up: 2.5,
            p_down: 0.05,
            p_up: 0.2,
        }),
        ..ExperimentConfig::tiny()
    };
    let build = || ExperimentBuilder::from_config(cfg.clone()).build().unwrap();
    let session = build();
    let setup = session.setup();
    assert_eq!(setup.client_links.len(), cfg.clients);
    for (link, surrogate) in setup.client_links.iter().zip(&setup.clients) {
        assert!(link.tau_up > link.tau_down, "uplink multiplier not applied");
        assert_eq!((link.p_down, link.p_up), (0.05, 0.2));
        // The optimizer-facing surrogate preserves the mean comm delay.
        let mean_asym = link.tau_down / (1.0 - link.p_down) + link.tau_up / (1.0 - link.p_up);
        let mean_surrogate = 2.0 * surrogate.tau / (1.0 - surrogate.p);
        assert!((mean_asym - mean_surrogate).abs() < 1e-9);
    }

    let a = session.run_spec(SchemeSpec::Coded { delta: 0.3 }).unwrap();
    assert!(a.t_star.unwrap() > 0.0);
    assert!(a.history.points.iter().all(|p| p.train_loss.is_finite()));
    let b = build().run_spec(SchemeSpec::Coded { delta: 0.3 }).unwrap();
    assert_eq!(run_hash(&a), run_hash(&b), "asymmetric run is not reproducible");

    // And the asymmetry is real: the symmetric fleet trains on a
    // different simulated clock.
    let sym = ExperimentBuilder::from_config(ExperimentConfig {
        fleet_asym: None,
        ..cfg.clone()
    })
    .build()
    .unwrap()
    .run_spec(SchemeSpec::Coded { delta: 0.3 })
    .unwrap();
    assert_ne!(run_hash(&a), run_hash(&sym));
}
