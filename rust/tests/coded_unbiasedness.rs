//! Integration: the statistical heart of CodedFedL (paper §III-E).
//!
//! Eq. (30)–(32) claim the coded federated gradient `g_M` is a stochastic
//! approximation of the full gradient `g` over the entire distributed
//! dataset: `E[g_M] ≈ g`, with the approximation error vanishing as the
//! coding redundancy `u` grows (WLLN on `GᵀG/u`). This suite verifies the
//! claim *through the real pipeline* — weights from §III-D, parity from
//! the AOT encode artifact, gradients from the AOT grad artifact —
//! by averaging `g_M` over many simulated rounds.

use codedfedl::coding::{self, GeneratorKind};
use codedfedl::delay::NodeParams;
use codedfedl::rng::Rng;
use codedfedl::runtime::{Runtime, RuntimeShapes};
use codedfedl::tensor::Mat;

const TINY: RuntimeShapes =
    RuntimeShapes { d: 32, q: 64, c: 10, l_client: 40, u_max: 128, b_embed: 40 };

fn runtime() -> Runtime {
    Runtime::load(std::path::Path::new("artifacts"), TINY)
        .expect("tiny artifacts missing — run `make artifacts`")
}

fn randn(rows: usize, cols: usize, rng: &mut Rng) -> Mat {
    let mut m = Mat::zeros(rows, cols);
    rng.fill_normal_f32(m.as_mut_slice());
    m
}

struct Client {
    xhat: Mat,
    y: Mat,
    mask: Vec<f32>,
    weights: Vec<f32>,
    p_arrive: f64,
}

/// Build a 3-client toy federation with heterogeneous arrival
/// probabilities and partial processed subsets.
fn federation(rng: &mut Rng) -> (Vec<Client>, Mat) {
    let theta = randn(64, 10, rng);
    let clients = [(30usize, 0.85f64), (20, 0.6), (40, 0.35)]
        .iter()
        .map(|&(ell_star, p_arrive)| {
            let xhat = randn(40, 64, rng);
            let y = randn(40, 10, rng);
            let processed = coding::sample_processed(40, ell_star, rng);
            let weights = coding::weight_vector(&processed, 1.0 - p_arrive);
            let mask: Vec<f32> =
                processed.iter().map(|&b| if b { 1.0 } else { 0.0 }).collect();
            Client { xhat, y, mask, weights, p_arrive }
        })
        .collect();
    (clients, theta)
}

/// Full-batch reference gradient `Σ_j X̂_jᵀ(X̂_jθ − Y_j)` (unnormalised).
fn full_gradient(rt: &Runtime, clients: &[Client], theta: &Mat) -> Mat {
    let mut g = Mat::zeros(64, 10);
    for c in clients {
        let gj = rt.grad(&c.xhat, &c.y, theta, &vec![1.0; 40]).unwrap();
        g.axpy(1.0, &gj);
    }
    g
}

#[test]
fn coded_federated_gradient_is_unbiased() {
    let rt = runtime();
    let mut rng = Rng::seed_from(0xFED);
    let (clients, theta) = federation(&mut rng);
    let g_full = full_gradient(&rt, &clients, &theta);

    let u = 120usize; // large redundancy for a tight WLLN approximation
    let rounds = 300;
    let mut g_mean = Mat::zeros(64, 10);
    for _ in 0..rounds {
        let mut g_m = Mat::zeros(64, 10);
        // Fresh generator per round so the average integrates over G too.
        let mut xp_acc = Mat::zeros(128, 64);
        let mut yp_acc = Mat::zeros(128, 10);
        for c in &clients {
            let g = coding::generator_matrix(GeneratorKind::Normal, u, 40, &mut rng);
            let (xp, yp) = rt.encode(&g, &c.weights, &c.xhat, &c.y).unwrap();
            xp_acc.axpy(1.0, &xp);
            yp_acc.axpy(1.0, &yp);
        }
        // Coded gradient over the live u parity rows (server always
        // arrives in this experiment: pnr_C = 0), scaled by 1/u (eq. 28).
        let xp = xp_acc.rows_slice(0, u);
        let yp = yp_acc.rows_slice(0, u);
        let gc = rt.grad(&xp, &yp, &theta, &vec![1.0; u]).unwrap();
        g_m.axpy(1.0 / u as f32, &gc);
        // Uncoded gradients from the clients that arrive (eq. 29).
        for c in &clients {
            if rng.next_f64() < c.p_arrive {
                let gu = rt.grad(&c.xhat, &c.y, &theta, &c.mask).unwrap();
                g_m.axpy(1.0, &gu);
            }
        }
        g_mean.axpy(1.0 / rounds as f32, &g_m);
    }

    // Relative error of the round-averaged g_M against the full gradient.
    let mut diff = g_mean.clone();
    diff.axpy(-1.0, &g_full);
    let rel = diff.fro_norm() / g_full.fro_norm();
    assert!(
        rel < 0.08,
        "E[g_M] deviates from g by {:.1}% (paper eq. 30-32 unbiasedness)",
        rel * 100.0
    );
}

#[test]
fn coded_alone_recovers_weighted_gradient() {
    // With no clients arriving, E[g_C]/u ≈ X̂ᵀW²(X̂θ−Y) (eq. 31).
    let rt = runtime();
    let mut rng = Rng::seed_from(0xFED + 1);
    let (clients, theta) = federation(&mut rng);

    // reference: sum_j X̂ᵀ diag(w²) (X̂θ − Y) via the grad artifact with
    // mask = w² (exactly the masked-gradient semantics).
    let mut g_ref = Mat::zeros(64, 10);
    for c in &clients {
        let w2: Vec<f32> = c.weights.iter().map(|w| w * w).collect();
        let gj = rt.grad(&c.xhat, &c.y, &theta, &w2).unwrap();
        g_ref.axpy(1.0, &gj);
    }

    let u = 120usize;
    let rounds = 400;
    let mut g_mean = Mat::zeros(64, 10);
    for _ in 0..rounds {
        let mut xp_acc = Mat::zeros(128, 64);
        let mut yp_acc = Mat::zeros(128, 10);
        for c in &clients {
            let g = coding::generator_matrix(GeneratorKind::Rademacher, u, 40, &mut rng);
            let (xp, yp) = rt.encode(&g, &c.weights, &c.xhat, &c.y).unwrap();
            xp_acc.axpy(1.0, &xp);
            yp_acc.axpy(1.0, &yp);
        }
        let xp = xp_acc.rows_slice(0, u);
        let yp = yp_acc.rows_slice(0, u);
        let gc = rt.grad(&xp, &yp, &theta, &vec![1.0; u]).unwrap();
        g_mean.axpy(1.0 / (u as f32 * rounds as f32), &gc);
    }
    let mut diff = g_mean.clone();
    diff.axpy(-1.0, &g_ref);
    let rel = diff.fro_norm() / g_ref.fro_norm();
    assert!(
        rel < 0.08,
        "E[g_C]/u deviates from X̂ᵀW²(X̂θ−Y) by {:.1}% (eq. 31)",
        rel * 100.0
    );
}

#[test]
fn approximation_tightens_with_redundancy() {
    // Single round, fixed G-seed per u: larger u ⇒ smaller deviation of
    // g_C/u from its mean (variance ~ 1/u). Averaged over a few seeds to
    // damp luck.
    let rt = runtime();
    let mut rng = Rng::seed_from(0xFED + 2);
    let (clients, theta) = federation(&mut rng);
    let mut g_ref = Mat::zeros(64, 10);
    for c in &clients {
        let w2: Vec<f32> = c.weights.iter().map(|w| w * w).collect();
        let gj = rt.grad(&c.xhat, &c.y, &theta, &w2).unwrap();
        g_ref.axpy(1.0, &gj);
    }
    let mut err_at = |u: usize, seeds: u64| -> f64 {
        let mut total = 0.0;
        for s in 0..seeds {
            let mut rng = Rng::seed_from(0xABC + s);
            let mut xp_acc = Mat::zeros(128, 64);
            let mut yp_acc = Mat::zeros(128, 10);
            for c in &clients {
                let g = coding::generator_matrix(GeneratorKind::Normal, u, 40, &mut rng);
                let (xp, yp) = rt.encode(&g, &c.weights, &c.xhat, &c.y).unwrap();
                xp_acc.axpy(1.0, &xp);
                yp_acc.axpy(1.0, &yp);
            }
            let xp = xp_acc.rows_slice(0, u);
            let yp = yp_acc.rows_slice(0, u);
            let gc = rt.grad(&xp, &yp, &theta, &vec![1.0; u]).unwrap();
            let mut est = Mat::zeros(64, 10);
            est.axpy(1.0 / u as f32, &gc);
            est.axpy(-1.0, &g_ref);
            total += (est.fro_norm() / g_ref.fro_norm()) as f64;
        }
        total / seeds as f64
    };
    let e_small = err_at(8, 6);
    let e_large = err_at(120, 6);
    assert!(
        e_large < e_small,
        "error at u=120 ({e_large:.3}) must beat u=8 ({e_small:.3})"
    );
}
