//! Regenerates **Fig. 3(a)** and **Fig. 3(b)** (paper §IV): the piece-wise
//! concavity of the expected return in ℓ̃ and the monotonicity of the
//! optimized return in t, at the paper's illustration parameters
//! `p = 0.9, τ = √3, μ = 2, α = 20` (Fig. 3(a) uses `t = 10`).
//!
//! ```sh
//! cargo bench --bench fig3_expected_return
//! ```

use codedfedl::allocation::{expected_return, optimal_load};
use codedfedl::benchutil::bench;
use codedfedl::delay::NodeParams;

fn node() -> NodeParams {
    NodeParams { mu: 2.0, alpha: 20.0, tau: 3f64.sqrt(), p: 0.9 }
}

fn main() {
    let n = node();

    println!("=== Fig. 3(a): E[R_j(t; l)] vs l at t = 10 (piece-wise concave) ===");
    println!("{:>8} {:>12}", "l", "E[R]");
    let t = 10.0;
    let mut series = Vec::new();
    let lmax = n.mu * (t - 2.0 * n.tau); // beyond this the return is 0
    for i in 0..=60 {
        let ell = lmax * i as f64 / 60.0;
        let er = expected_return(&n, t, ell);
        series.push((ell, er));
        if i % 4 == 0 {
            println!("{ell:>8.3} {er:>12.5}");
        }
    }
    // breakpoints at l = mu (t - nu tau): annotate
    let nu_m = n.nu_max(t).bounded().expect("tau > 0 with t > 2tau");
    let bps: Vec<f64> = (2..=nu_m).map(|v| n.mu * (t - n.tau * v as f64)).collect();
    println!("concavity breakpoints (l = mu(t - nu*tau)): {bps:?}");
    // shape checks (the figure's claims)
    assert!(series.iter().all(|&(_, er)| er >= 0.0));
    let peak = series.iter().cloned().fold((0.0, 0.0), |a, b| if b.1 > a.1 { b } else { a });
    assert!(peak.1 > 0.0, "return must be positive somewhere");
    assert!(
        expected_return(&n, t, lmax * 0.999) < peak.1,
        "return decays after the peak"
    );

    println!("\n=== Fig. 3(b): E[R_j(t; l*(t))] vs t (monotone increasing) ===");
    println!("{:>8} {:>10} {:>12}", "t", "l*(t)", "E[R*]");
    let mut prev = -1.0;
    for i in 1..=40 {
        let t = 0.5 * i as f64;
        let (l, er) = optimal_load(&n, t, 50.0);
        if i % 2 == 0 {
            println!("{t:>8.2} {l:>10.3} {er:>12.5}");
        }
        assert!(er >= prev - 1e-9, "monotonicity violated at t={t}");
        prev = er;
    }
    println!("monotone ✓ (paper App. C)");

    println!("\n=== optimizer hot-path timings ===");
    bench("optimal_load (fig3 node, t=10)", 10, 200, || {
        std::hint::black_box(optimal_load(&node(), 10.0, 50.0));
    });
    bench("expected_return (single eval)", 10, 1000, || {
        std::hint::black_box(expected_return(&node(), 10.0, 7.0));
    });
}
