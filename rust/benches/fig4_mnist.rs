//! Regenerates **Fig. 4(a–c)** (paper §V-B, MNIST): accuracy vs wall-clock
//! and vs iteration for naive / greedy(ψ) / CodedFedL(δ), ψ, δ ∈ {0.1, 0.2}.
//!
//! ```sh
//! cargo bench --bench fig4_mnist              # reduced scale (EPOCHS=16)
//! EPOCHS=70 cargo bench --bench fig4_mnist    # paper iteration count
//! ```

mod fig_common;

fn main() {
    fig_common::run_figure("mnist", "Fig4/MNIST").expect("fig4 failed");
}
