//! Regenerates **Table II** (δ = ψ = 0.1) and **Table III** (δ = ψ = 0.2)
//! (paper §V-B): time-to-accuracy `t_γ` for naive / greedy / CodedFedL on
//! both dataset families, with the `t_U/t_C` and `t_G/t_C` gain columns.
//!
//! Targets γ are set relative to each run's achieved accuracy (the paper's
//! absolute 93.3 % / 82.8 % are MNIST-specific); the table's *shape* —
//! coded fastest, greedy never reaching the high target — is asserted.
//!
//! ```sh
//! cargo bench --bench table2_table3
//! EPOCHS=70 cargo bench --bench table2_table3
//! ```

mod fig_common;

use codedfedl::benchutil::run_experiment;
use codedfedl::metrics::GainRow;
use codedfedl::schemes::SchemeSpec as Scheme;

fn main() -> anyhow::Result<()> {
    for dataset in ["mnist", "fashion"] {
        let cfg = fig_common::config(dataset);
        println!(
            "\n##### dataset = {dataset} (n={}, m={}, {} iters) #####",
            cfg.clients,
            cfg.global_batch(),
            cfg.total_iters()
        );
        for (delta, psi, tag) in [(0.1, 0.1, "Table II"), (0.2, 0.2, "Table III")] {
            let schemes = [
                Scheme::NaiveUncoded,
                Scheme::GreedyUncoded { psi },
                Scheme::Coded { delta },
            ];
            let (_, results) = run_experiment(&cfg, &schemes)?;
            let naive = &results[0].1.history;
            let greedy = &results[1].1.history;
            let coded = &results[2].1.history;
            let best = naive.best_accuracy();

            println!("\n--- {tag} (δ=ψ={delta}) — naive best acc {best:.3} ---");
            // Two targets in the gradual-convergence region, mirroring the
            // paper's two rows per dataset (its γ sit at ≥44 naive rounds).
            // The >1 gain is asserted for the high target, where the paper's
            // mechanism (faster rounds dominate once convergence is
            // multi-round) must hold; the low target is informational — it
            // can be reached within a handful of rounds, where the one-time
            // parity upload still dominates (the Fig. 4(a) inset effect).
            // Gains are asserted at the 0.99·best target: like the paper's
            // γ (44+ naive rounds), it sits deep in the multi-round regime.
            // Lower targets are informational — naive can reach them within
            // a few rounds, where the one-time parity upload still dominates
            // (the Fig. 4(a) inset effect).
            for (frac, must_win) in [(0.99, true), (0.97, false), (0.95, false)] {
                let gamma = frac * best;
                let row = GainRow::compute(gamma, naive, greedy, coded);
                println!("{}", row.render());
                if must_win {
                    match (row.t_coded, row.gain_vs_naive()) {
                        (Some(_), Some(g)) => assert!(
                            g > 1.0,
                            "coded must reach γ={gamma:.3} before naive (gain {g:.2})"
                        ),
                        _ => println!(
                            "   (γ={gamma:.3} not reached within {} iters — \
                             run with EPOCHS=70 for the paper's budget)",
                            cfg.total_iters()
                        ),
                    }
                }
            }
            // Paper: "greedy uncoded never reaches the [high] target":
            let high = GainRow::compute(0.99 * best, naive, greedy, coded);
            if high.t_greedy.is_none() {
                println!("   greedy never reaches the high target (matches the paper's '—')");
            }
        }
    }
    Ok(())
}
