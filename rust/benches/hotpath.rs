//! Hot-path micro/meso benchmarks (DESIGN.md §7, EXPERIMENTS.md §Perf):
//! the L3 pieces that run every round, plus the kernel executors.
//!
//! ```sh
//! cargo bench --bench hotpath   # full run — overwrites the TRACKED baseline JSON
//! BENCH_SMOKE=1 BENCH_JSON=/tmp/smoke.json cargo bench --bench hotpath  # smoke: 1 warmup, 2 iters
//! ```
//!
//! Before timing anything the bench *verifies* every native kernel against
//! the `matmul_ref`-based oracles at 1 and 4 threads and exits non-zero on
//! divergence — the CI smoke job leans on this as a cheap end-to-end
//! kernel check. It also measures the steady-state round's compute-path
//! allocations under a counting global allocator and *fails* unless they
//! are zero (the `tests/alloc_gate.rs` contract, re-checked here so the
//! recorded baseline can never ship a regression). Results are written to
//! `BENCH_hotpath.json` (override the path with `BENCH_JSON`);
//! `rust/PERF.md` records the tracked baseline and how to diff against
//! it.

use codedfedl::allocation::{self, NodeSpec};
use codedfedl::benchutil::{bench, bench_iters, load_runtime, shapes_for, BenchReport, CountingAlloc};
use codedfedl::coding::{gf256, Code, CodeSpec, DecodeScratch};
use codedfedl::comm::{self, CodecSpec, ScaleSpec};
use codedfedl::conf::ExperimentConfig;
use codedfedl::coordinator::{checkpoint, EventLog};
use codedfedl::metrics::Point;
use codedfedl::rng::Rng;
use codedfedl::runtime::{GradJob, Runtime, RuntimeShapes};
use codedfedl::schemes::CodedFedL;
use codedfedl::sim::fault::{DeadlineSpec, FaultSpec};
use codedfedl::sim::timeline::RoundTrace;
use codedfedl::sim::KthScratch;
use codedfedl::tensor::{Isa, Mat, SimdPolicy};
use codedfedl::topology::{FleetShards, FleetSpec, FleetView, ParticipationSampler, ParticipationSpec};
use codedfedl::ExperimentBuilder;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn randn(rows: usize, cols: usize, rng: &mut Rng) -> Mat {
    let mut m = Mat::zeros(rows, cols);
    rng.fill_normal_f32(m.as_mut_slice());
    m
}

/// Pin every native kernel to its reference oracle before any timing is
/// recorded, under **both** SIMD policies. `simd = scalar, threads = 1`
/// must match bit-for-bit; every other combination (other thread counts,
/// the detected SIMD ISA's fused multiply-adds) is held to 1e-4.
fn verify_kernels() -> anyhow::Result<()> {
    let shapes = RuntimeShapes { d: 23, q: 65, c: 10, l_client: 37, u_max: 81, b_embed: 37 };
    let mut rng = Rng::seed_from(7);
    let x = randn(37, 23, &mut rng);
    let omega = randn(23, 65, &mut rng);
    let delta: Vec<f32> = (0..65).map(|_| rng.next_f32() * 6.28).collect();
    let xhat = randn(37, 65, &mut rng);
    let y = randn(37, 10, &mut rng);
    let theta = randn(65, 10, &mut rng);
    let mask: Vec<f32> = (0..37).map(|i| [1.0, 0.0, 0.5][i % 3]).collect();
    let g = randn(60, 37, &mut rng);
    let w: Vec<f32> = (0..37).map(|_| rng.next_f32()).collect();

    // oracles, via the naive reference matmul
    let scale = (2.0f32 / 65.0).sqrt();
    let xo = x.matmul_ref(&omega);
    let embed_want = Mat::from_fn(37, 65, |r, c| scale * (xo.get(r, c) + delta[c]).cos());
    let pred = xhat.matmul_ref(&theta);
    let resid = Mat::from_fn(37, 10, |r, c| mask[r] * (pred.get(r, c) - y.get(r, c)));
    let xt = Mat::from_fn(65, 37, |r, c| xhat.get(c, r));
    let grad_want = xt.matmul_ref(&resid);
    let gw = Mat::from_fn(60, 37, |r, c| g.get(r, c) * w[c]);
    let encode_x_want = gw.matmul_ref(&xhat);
    let encode_y_want = gw.matmul_ref(&y);

    for policy in [SimdPolicy::Scalar, SimdPolicy::Auto] {
        for threads in [1usize, 4] {
            let rt = Runtime::native_with(shapes, threads, policy);
            // embed/predict oracles share the scalar kernels' accumulation
            // order exactly, so simd=scalar at one thread is bit-exact;
            // the grad/encode oracles go through an explicit transpose /
            // pre-scaled generator — and any SIMD ISA uses fused
            // multiply-adds — so everything else gets the f32 budget.
            let exact = policy == SimdPolicy::Scalar && threads == 1;
            let checks = [
                ("embed", rt.embed(&x, &omega, &delta)?.max_abs_diff(&embed_want)),
                ("grad", rt.grad(&xhat, &y, &theta, &mask)?.max_abs_diff(&grad_want)),
                ("predict", rt.predict(&xhat, &theta)?.max_abs_diff(&pred)),
            ];
            let (xp, yp) = rt.encode(&g, &w, &xhat, &y)?;
            let enc = [
                ("encode.x", xp.rows_slice(0, 60).max_abs_diff(&encode_x_want)),
                ("encode.y", yp.rows_slice(0, 60).max_abs_diff(&encode_y_want)),
            ];
            for (name, diff) in checks.iter().chain(enc.iter()) {
                let bound = if exact && (*name == "embed" || *name == "predict") {
                    0.0
                } else {
                    1e-4
                };
                anyhow::ensure!(
                    *diff <= bound,
                    "kernel {name} diverged from oracle at {threads} threads \
                     (simd={}, isa={}): max|Δ| = {diff}",
                    policy,
                    rt.isa_name()
                );
            }
        }
    }
    println!("kernel oracle check passed (simd scalar+auto, threads 1, 4)");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    verify_kernels()?;

    let mut rng = Rng::seed_from(42);
    let mut report = BenchReport::new();

    // --- allocation optimizer (runs once per experiment, but its cost
    //     bounds how often deadlines could be re-optimized online) ---
    let cfg = ExperimentConfig::default();
    let spec = FleetSpec::paper(cfg.clients, cfg.q, cfg.classes);
    let clients = spec.build_clients(&mut rng);
    let m = cfg.global_batch() as f64;
    let mut nodes: Vec<NodeSpec> = clients
        .iter()
        .map(|p| NodeSpec { params: *p, max_load: cfg.local_batch as f64 })
        .collect();
    nodes.push(NodeSpec { params: spec.build_server(), max_load: 0.1 * m });
    let (wu, it) = bench_iters(3, 30);
    report.bench("allocation::solve", "31 nodes, paper fleet", 1, wu, it, || {
        std::hint::black_box(allocation::solve(&nodes, m).unwrap());
    });

    // --- kernel executors at the default artifact shapes ---
    let rt = load_runtime(&cfg)?;
    let threads = rt.threads();
    report.isa = rt.isa_name().to_string();
    println!("selected GEMM isa: {} ({} threads)", rt.isa_name(), threads);
    let s = shapes_for(&cfg);
    let xhat = randn(s.l_client, s.q, &mut rng);
    let y = randn(s.l_client, s.c, &mut rng);
    let theta = randn(s.q, s.c, &mut rng);
    let mask = vec![1.0f32; s.l_client];
    // grad = prediction + transpose-accumulate passes: 2·l·q·c madds.
    let grad_flops = |l: usize| (4 * l * s.q * s.c) as u64;
    let (wu, it) = bench_iters(3, 50);
    report.bench_flops(
        "runtime::grad",
        "client 200x512x10",
        threads,
        wu,
        it,
        grad_flops(s.l_client),
        || {
            std::hint::black_box(rt.grad(&xhat, &y, &theta, &mask).unwrap());
        },
    );

    // The same shape through the forced-scalar runtime: the tracked
    // SIMD-vs-scalar comparison row (PERF.md's speedup column).
    let rt_scalar = Runtime::native_with(s, threads, SimdPolicy::Scalar);
    let (wu, it) = bench_iters(3, 50);
    report.bench_flops(
        "runtime::grad",
        "client 200x512x10 simd=scalar",
        threads,
        wu,
        it,
        grad_flops(s.l_client),
        || {
            std::hint::black_box(rt_scalar.grad(&xhat, &y, &theta, &mask).unwrap());
        },
    );

    let xp = randn(s.u_max, s.q, &mut rng);
    let yp = randn(s.u_max, s.c, &mut rng);
    let ones = vec![1.0f32; s.u_max];
    let (wu, it) = bench_iters(3, 20);
    report.bench_flops(
        "runtime::grad",
        "server 1536x512x10",
        threads,
        wu,
        it,
        grad_flops(s.u_max),
        || {
            std::hint::black_box(rt.grad(&xp, &yp, &theta, &ones).unwrap());
        },
    );

    let g = randn(s.u_max, s.l_client, &mut rng);
    let w = vec![0.5f32; s.l_client];
    let (wu, it) = bench_iters(3, 20);
    let encode_flops = (2 * s.u_max * s.l_client * (s.q + s.c)) as u64;
    report.bench_flops("runtime::encode", "1536x200 -> parity", threads, wu, it, encode_flops, || {
        std::hint::black_box(rt.encode(&g, &w, &xhat, &y).unwrap());
    });

    let x_raw = randn(s.b_embed, s.d, &mut rng);
    let omega = randn(s.d, s.q, &mut rng);
    let delta = vec![0.3f32; s.q];
    let (wu, it) = bench_iters(3, 20);
    let embed_flops = (2 * s.b_embed * s.d * s.q) as u64;
    report.bench_flops("runtime::embed", "200x784 -> 200x512", threads, wu, it, embed_flops, || {
        std::hint::black_box(rt.embed(&x_raw, &omega, &delta).unwrap());
    });

    let test = randn(2000, s.q, &mut rng);
    let (wu, it) = bench_iters(3, 20);
    let predict_flops = (2 * 2000 * s.q * s.c) as u64;
    report.bench_flops("runtime::predict", "2000x512x10", threads, wu, it, predict_flops, || {
        std::hint::black_box(rt.predict(&test, &theta).unwrap());
    });

    // --- aggregation primitives ---
    let mut acc = Mat::zeros(s.q, s.c);
    let gmat = randn(s.q, s.c, &mut rng);
    let (wu, it) = bench_iters(10, 2000);
    report.bench_flops("Mat::axpy", "512x10 aggregate", 1, wu, it, (2 * s.q * s.c) as u64, || {
        acc.axpy(0.5, &gmat);
        std::hint::black_box(&acc);
    });

    // --- GF(256) erasure codec (coding::) ---
    {
        let isa = rt.isa().unwrap_or(Isa::Scalar);

        // Row kernels on a 1 MiB row — the byte-throughput primitives the
        // codec is built from.
        let row_len = 1usize << 20;
        let src_row: Vec<u8> = (0..row_len).map(|i| (i.wrapping_mul(31) >> 3) as u8).collect();
        let mut dst_row = vec![0u8; row_len];
        let (wu, it) = bench_iters(10, 200);
        report.bench_throughput(
            "gf256::xor_row",
            "1 MiB row",
            1,
            wu,
            it,
            Some(row_len as u64),
            None,
            || {
                gf256::xor_row(isa, &src_row, &mut dst_row);
                std::hint::black_box(&dst_row);
            },
        );
        let (wu, it) = bench_iters(10, 200);
        report.bench_throughput(
            "gf256::mul_acc_row",
            "1 MiB row, coeff=0x53",
            1,
            wu,
            it,
            Some(row_len as u64),
            None,
            || {
                gf256::mul_acc_row(isa, 0x53, &src_row, &mut dst_row);
                std::hint::black_box(&dst_row);
            },
        );

        // Full codec over the default gradient-block shape: one symbol is
        // one client's packed [q x c] f32 gradient (q·c·4 bytes).
        let n = cfg.clients;
        let len = s.q * s.c * 4;
        for spec in [CodeSpec::Dense, CodeSpec::Rateless { overhead: 0.5 }] {
            let code = spec.build(cfg.generator, n, 0xC0DE);
            let r = code.repairs();
            let mut pool = vec![0u8; n * len];
            for (i, b) in pool.iter_mut().enumerate() {
                *b = (i.wrapping_mul(131) >> 2) as u8;
            }
            let mut repairs = vec![0u8; r * len];
            let label = spec.label();

            // encode: all r repair symbols from the n source symbols
            let (wu, it) = bench_iters(3, 50);
            report.bench_throughput(
                &format!("coding::encode[{label}]"),
                &format!("{n}+{r} x {len} B"),
                1,
                wu,
                it,
                Some((r * len) as u64),
                Some(r as u64),
                || {
                    for rr in 0..r {
                        let out = &mut repairs[rr * len..(rr + 1) * len];
                        code.encode_repair(isa, rr, &pool, len, out);
                    }
                    std::hint::black_box(&repairs);
                },
            );

            // decode: pick the largest decodable erasure pattern from a
            // deterministic preference list (dense handles multi-erasure
            // w.h.p.; rateless row 0 guarantees any single erasure).
            let mut scratch = DecodeScratch::new();
            scratch.reserve(r, n, len);
            let truth = pool.clone();
            let drop = [vec![1, 4, 7], vec![2, 5], vec![3]]
                .into_iter()
                .find(|d| {
                    let mut have = vec![true; n];
                    for &j in d {
                        have[j] = false;
                    }
                    code.decodable(&have, r, &mut scratch)
                })
                .expect("single-erasure patterns are always decodable");
            let mut have = vec![true; n];
            for &j in &drop {
                have[j] = false;
            }
            let (wu, it) = bench_iters(3, 50);
            println!("codec {label}: decoding {} erased of {n}", drop.len());
            report.bench_throughput(
                &format!("coding::decode[{label}]"),
                &format!("{n}+{r} x {len} B"),
                1,
                wu,
                it,
                Some((drop.len() * len) as u64),
                Some(drop.len() as u64),
                || {
                    for &j in &drop {
                        pool[j * len..(j + 1) * len].fill(0);
                    }
                    code.decode_into(isa, &have, r, len, &mut pool, &repairs, &mut scratch)
                        .expect("pattern pre-checked decodable");
                    std::hint::black_box(&pool);
                },
            );
            anyhow::ensure!(
                pool == truth,
                "codec {label} decode diverged from the source pool after timing"
            );
        }
    }

    // --- comm payload codecs (schema 8): the uplink quantize / pack
    //     kernels the engine runs per arrived gradient under a lossy
    //     `[comm] codec`. Rows are sized at 256 Ki scalars (1 MiB of
    //     f32) so the numbers measure bandwidth, not loop overhead;
    //     throughput is accounted in *input* f32 bytes so none/q8/bitpack
    //     compare on the same denominator. ---
    {
        let isa = rt.isa().unwrap_or(Isa::Scalar);
        let q8 = CodecSpec::Q8 { scale: ScaleSpec::Auto };
        let row_len = 1usize << 18;
        let mut src = vec![0.0f32; row_len];
        let mut rng_row = Rng::seed_from(0xC077);
        rng_row.fill_normal_f32(&mut src);
        let in_bytes = (row_len * 4) as u64;
        let mut codes = vec![0u8; row_len];
        let mut packed = vec![0u8; comm::packed_len(row_len)];
        let mut back = vec![0.0f32; row_len];

        for codec in [q8, CodecSpec::Bitpack] {
            let pq = comm::quant_params(codec, &src);
            let op = format!("comm::quantize[{}]", codec.label());
            let (wu, it) = bench_iters(10, 200);
            report.bench_throughput(&op, "256 Ki f32 row", 1, wu, it, Some(in_bytes), None, || {
                comm::quantize_row(isa, codec, &src, pq, &mut codes);
                std::hint::black_box(&codes);
            });
            let op = format!("comm::dequantize[{}]", codec.label());
            let (wu, it) = bench_iters(10, 200);
            report.bench_throughput(&op, "256 Ki f32 row", 1, wu, it, Some(in_bytes), None, || {
                comm::dequantize_row(isa, &codes, pq, &mut back);
                std::hint::black_box(&back);
            });
        }
        // Nibble packing only runs under bitpack; re-quantize so every
        // code fits 4 bits before timing the byte shuffles.
        let pq = comm::quant_params(CodecSpec::Bitpack, &src);
        comm::quantize_row(isa, CodecSpec::Bitpack, &src, pq, &mut codes);
        let (wu, it) = bench_iters(10, 200);
        report.bench_throughput(
            "comm::pack_nibbles",
            "256 Ki codes",
            1,
            wu,
            it,
            Some(row_len as u64),
            None,
            || {
                comm::pack_nibbles(isa, &codes, &mut packed);
                std::hint::black_box(&packed);
            },
        );
        let (wu, it) = bench_iters(10, 200);
        report.bench_throughput(
            "comm::unpack_nibbles",
            "256 Ki codes",
            1,
            wu,
            it,
            Some(row_len as u64),
            None,
            || {
                comm::unpack_nibbles(isa, &packed, &mut codes);
                std::hint::black_box(&codes);
            },
        );
        // The engine's actual per-gradient call: transcode one q x c
        // gradient in place (quantize → [pack/unpack] → dequantize).
        let mut scratch = comm::CodecScratch::default();
        scratch.reserve(s.c);
        let mut grad = randn(s.q, s.c, &mut rng);
        let grad_bytes = (s.q * s.c * 4) as u64;
        for codec in [q8, CodecSpec::Bitpack] {
            let op = format!("comm::transcode[{}]", codec.label());
            let shape = format!("grad {}x{}", s.q, s.c);
            let (wu, it) = bench_iters(10, 500);
            report.bench_throughput(&op, &shape, 1, wu, it, Some(grad_bytes), None, || {
                comm::transcode_mat(isa, codec, &mut grad, &mut scratch);
                std::hint::black_box(&grad);
            });
        }
    }

    // --- one steady-state training round, pool warm (the per-round
    //     compute path the engine runs: pack θ, batch the n client
    //     gradients into held slots, fold, evaluate) ---
    let session = ExperimentBuilder::preset("tiny")?.epochs(1).build()?;
    {
        let rt = session.runtime();
        let setup = session.setup();
        let scfg = session.config();
        let (sq, sc, n) = (scfg.q, scfg.classes, scfg.clients);
        let theta = randn(sq, sc, &mut rng);
        let masks: Vec<Vec<f32>> = vec![vec![1.0f32; scfg.local_batch]; n];
        // Everything the warm loop touches is allocated up front, exactly
        // like coordinator::engine's round-persistent buffers.
        let jobs: Vec<GradJob> = (0..n)
            .map(|j| GradJob {
                xhat: &setup.client_data[j].xhat[0],
                y: &setup.client_data[j].y[0],
                mask: &masks[j],
            })
            .collect();
        let mut panel: Vec<f32> = Vec::new();
        let mut outs: Vec<Mat> = (0..n).map(|_| Mat::zeros(sq, sc)).collect();
        let mut agg = Mat::zeros(sq, sc);
        let mut logits = Mat::zeros(setup.test_xhat.rows(), sc);
        let mut round = || {
            let prep = rt.prepare_theta_into(&theta, &mut panel).unwrap();
            rt.grad_batch_into(&jobs, &prep, &mut outs).unwrap();
            agg.as_mut_slice().fill(0.0);
            for g in &outs {
                agg.axpy(1.0, g);
            }
            rt.predict_into(&setup.test_xhat, &prep, &mut logits).unwrap();
            std::hint::black_box(&agg);
        };
        // Warm the pool scratch arenas and every held buffer, then gate:
        // a steady-state round must not allocate on the compute path.
        round();
        round();
        let a0 = CountingAlloc::allocations();
        round();
        let allocs = CountingAlloc::allocations() - a0;
        report.allocs_per_round = Some(allocs);
        anyhow::ensure!(
            allocs == 0,
            "steady-state round allocated {allocs} times on the compute path \
             (the alloc_gate contract is broken)"
        );
        println!("steady-state round compute-path allocations: {allocs}");
        let (wu, it) = bench_iters(3, 50);
        report.bench(
            "full round steady",
            "tiny: 5 clients, warm pool",
            rt.threads(),
            wu,
            it,
            &mut round,
        );
    }

    // --- one full coded training epoch, end to end (tiny preset) ---
    let (wu, it) = bench_iters(1, 10);
    let epoch_threads = session.runtime().threads();
    report.bench("full coded epoch", "tiny: 5 clients x 2 steps", epoch_threads, wu, it, || {
        std::hint::black_box(session.run(&mut CodedFedL::new(0.3)).unwrap());
    });
    println!(
        "\n{} executions so far: {} ({} threads, isa {}) — per-round exec count drives L3 \
         overhead",
        session.runtime().backend_name(),
        session.runtime().exec_count(),
        session.runtime().threads(),
        session.runtime().isa_name(),
    );

    // --- codec epoch comparison (schema 8): the same coded epoch under
    //     q8 — the transcode overhead shows up in host time while the
    //     *simulated* clock and bytes on the wire drop (the tentpole's
    //     efficacy claim, re-checked on every bench run so the baseline
    //     can never ship a codec that stopped paying for itself). The
    //     tracked `bytes_per_round` is the default pipeline's (codec
    //     none) modelled wire bytes per round, down + up. ---
    {
        fn observe(
            codec: CodecSpec,
        ) -> anyhow::Result<(codedfedl::Session, codedfedl::TrainOutcome, EventLog)> {
            let session = ExperimentBuilder::preset("tiny")?.epochs(1).codec(codec).build()?;
            let mut log = EventLog::default();
            let out = session.run_observed(&mut CodedFedL::new(0.3), &mut log)?;
            Ok((session, out, log))
        }
        let (_, none_out, none_log) = observe(CodecSpec::None)?;
        let q8 = CodecSpec::Q8 { scale: ScaleSpec::Auto };
        let (q8_session, q8_out, _) = observe(q8)?;
        let rounds = none_log.events.len().max(1) as u64;
        report.bytes_per_round =
            Some((none_out.bytes_down_total + none_out.bytes_up_total) / rounds);
        println!(
            "codec epoch: none t*={:.3}s wall={:.1}s up={:.2} MB | q8 t*={:.3}s wall={:.1}s \
             up={:.2} MB",
            none_out.t_star.unwrap_or(f64::NAN),
            none_out.history.total_sim_time(),
            none_out.bytes_up_total as f64 / 1e6,
            q8_out.t_star.unwrap_or(f64::NAN),
            q8_out.history.total_sim_time(),
            q8_out.bytes_up_total as f64 / 1e6,
        );
        anyhow::ensure!(
            q8_out.history.total_sim_time() < none_out.history.total_sim_time()
                && q8_out.bytes_up_total < none_out.bytes_up_total,
            "q8 stopped beating codec=none on the simulated clock / wire bytes"
        );
        let threads = q8_session.runtime().threads();
        let (wu, it) = bench_iters(1, 10);
        report.bench("full coded epoch", "tiny: codec=q8", threads, wu, it, || {
            std::hint::black_box(q8_session.run(&mut CodedFedL::new(0.3)).unwrap());
        });
    }

    // --- degraded epoch: the fault + deadline decision path (schema 6).
    //     Mixed faults and an 80th-percentile deadline push rounds down
    //     the degradation ladder; the record carries the rung histogram
    //     and achieved participation so a perf diff can tell a genuinely
    //     faster run from one that silently skipped rounds. ---
    {
        let session = ExperimentBuilder::preset("tiny")?
            .epochs(1)
            .faults(FaultSpec::Mixed { crash: 0.2, link: 0.2, parity: 0.3 })
            .deadline(DeadlineSpec::Quantile { q: 0.8 })
            .build()?;
        let mut log = EventLog::default();
        let out = session.run_observed(&mut CodedFedL::new(0.3), &mut log)?;
        let planned: usize = log.events.iter().map(|ev| ev.planned).sum();
        let arrived: usize = log.events.iter().map(|ev| ev.arrivals).sum();
        let achieved = arrived as f64 / planned.max(1) as f64;
        println!(
            "degraded epoch rungs {:?}, achieved participation {:.1}%",
            out.outcomes.as_array(),
            100.0 * achieved
        );
        let shape = "tiny: mixed faults, q=0.8 deadline";
        let threads = session.runtime().threads();
        let (wu, it) = bench_iters(1, 10);
        let stats = bench(&format!("degraded::epoch ({shape})"), wu, it, || {
            std::hint::black_box(session.run(&mut CodedFedL::new(0.3)).unwrap());
        });
        report.record_degraded("degraded::epoch", shape, threads, &stats, &out.outcomes, achieved);
    }

    // --- fleet_scale: the sampled-round decision path vs fleet size N
    //     (schema 5). One iteration is everything the engine does per
    //     round besides gradient compute: the counter-based roster draw
    //     (sample:k=31), the O(K) roster view reset over the sharded
    //     ladder fleet, K-slot timeline sampling, and the streaming
    //     top-k arrival selection. rounds/s must stay flat as N grows —
    //     the cost tracks the roster size K, never N. Shard arenas are
    //     materialised up front (`build_all`): lazy builds are amortised
    //     cold-path cost by design, so the timed rounds are warm. ---
    {
        let base_links = spec.build_links(&clients);
        let server = spec.build_server();
        let loads: Vec<f64> = vec![cfg.local_batch as f64; cfg.clients];
        let k_sample = 31usize;
        let sel_k = 8usize;
        for fleet_n in [31usize, 1_000, 100_000] {
            let mut mega = spec;
            mega.n = fleet_n;
            let mut shards = FleetShards::ladder(mega, 0xF1EE7 ^ fleet_n as u64, 1024);
            shards.build_all();
            let mut sampler = ParticipationSampler::new(
                ParticipationSpec::Sample { k: k_sample.min(fleet_n) },
                fleet_n,
                0xBA5E ^ fleet_n as u64,
            );
            let mut delay_rng = Rng::seed_from(34);
            let mut view = FleetView::from_base(&base_links, server);
            let mut trace = RoundTrace::with_capacity(k_sample);
            let mut roster_loads: Vec<f64> = Vec::new();
            let mut scratch = KthScratch::default();
            let mut round = 0usize;
            let shape = format!("n={fleet_n} sample:k={k_sample} top{sel_k}");
            let (wu, it) = bench_iters(10, 2000);
            let stats = bench(&format!("fleet_scale::round ({shape})"), wu, it, || {
                let roster = sampler.draw(round);
                round += 1;
                roster_loads.clear();
                roster_loads.extend(roster.iter().map(|&g| loads[g as usize % cfg.clients]));
                view.reset_roster(&mut shards, roster, server);
                trace.sample_into(&view, &roster_loads, 8.0, &mut delay_rng);
                let (t_k, winners) =
                    trace.delays().kth_fastest_into(sel_k, &mut scratch).unwrap();
                std::hint::black_box((t_k, winners.len()));
            });
            report.record_fleet("fleet_scale::round", &shape, 1, &stats, fleet_n);
        }
    }

    // --- checkpoint snapshot latency (schema 7): what one periodic
    //     crash-consistent checkpoint costs the training loop — encode
    //     the full resumable state (θ, RNG streams, history) and persist
    //     it through io::atomic_write (temp + fsync + rename). The round
    //     itself stays 0-alloc; this is the price paid only on the
    //     `[checkpoint] every = R` boundary. ---
    {
        let snap = checkpoint::Snapshot {
            config_fingerprint: 0xC0FFEE,
            scheme_label: "codedfedl(delta=0.10)".to_string(),
            next_iter: 100,
            clock: 1234.5,
            theta_rows: s.q as u32,
            theta_cols: s.c as u32,
            theta: (0..s.q * s.c).map(|i| i as f32 * 0.001).collect(),
            delay_rng: [1, 2, 3, 4],
            code_rng: [5, 6, 7, 8],
            scenario_rng: [9, 10, 11, 12],
            fault_rng: [13, 14, 15, 16],
            outcomes: [90, 4, 3, 2, 1],
            corrupted_total: 0,
            bytes_down_total: 3_520_000,
            bytes_up_total: 3_520_000,
            history: (1..=100)
                .map(|i| Point {
                    iter: i,
                    sim_time: i as f64 * 12.0,
                    accuracy: 0.9,
                    train_loss: 0.1,
                })
                .collect(),
        };
        let ckpt_path = std::env::temp_dir().join("codedfedl_bench_snapshot.ckpt");
        let shape = format!("theta {}x{} + 100 pts", s.q, s.c);
        let (wu, it) = bench_iters(3, 50);
        report.bench("checkpoint::snapshot", &shape, 1, wu, it, || {
            checkpoint::write(&ckpt_path, &snap).unwrap();
        });
        // round-trip sanity: the timed artifact must load back bit-exactly
        let back = checkpoint::load(&ckpt_path)?;
        anyhow::ensure!(back == snap, "checkpoint round-trip diverged after timing");
        let _ = std::fs::remove_file(&ckpt_path);
    }

    let path = std::env::var("BENCH_JSON").unwrap_or_else(|_| "BENCH_hotpath.json".into());
    report.write_json(std::path::Path::new(&path))?;
    println!("wrote {path}");
    Ok(())
}
