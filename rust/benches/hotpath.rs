//! Hot-path micro/meso benchmarks (DESIGN.md §7, EXPERIMENTS.md §Perf):
//! the L3 pieces that run every round, plus the PJRT executors.
//!
//! ```sh
//! cargo bench --bench hotpath
//! ```

use codedfedl::allocation::{self, NodeSpec};
use codedfedl::benchutil::{bench, load_runtime, shapes_for};
use codedfedl::conf::ExperimentConfig;
use codedfedl::rng::Rng;
use codedfedl::schemes::CodedFedL;
use codedfedl::tensor::Mat;
use codedfedl::topology::FleetSpec;
use codedfedl::ExperimentBuilder;

fn randn(rows: usize, cols: usize, rng: &mut Rng) -> Mat {
    let mut m = Mat::zeros(rows, cols);
    rng.fill_normal_f32(m.as_mut_slice());
    m
}

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::seed_from(42);

    // --- allocation optimizer (runs once per experiment, but its cost
    //     bounds how often deadlines could be re-optimized online) ---
    let cfg = ExperimentConfig::default();
    let spec = FleetSpec::paper(cfg.clients, cfg.q, cfg.classes);
    let clients = spec.build_clients(&mut rng);
    let m = cfg.global_batch() as f64;
    let mut nodes: Vec<NodeSpec> = clients
        .iter()
        .map(|p| NodeSpec { params: *p, max_load: cfg.local_batch as f64 })
        .collect();
    nodes.push(NodeSpec { params: spec.build_server(), max_load: 0.1 * m });
    bench("allocation::solve (31 nodes, paper fleet)", 3, 30, || {
        std::hint::black_box(allocation::solve(&nodes, m).unwrap());
    });

    // --- PJRT executors at the default artifact shapes ---
    let rt = load_runtime(&cfg)?;
    let s = shapes_for(&cfg);
    let xhat = randn(s.l_client, s.q, &mut rng);
    let y = randn(s.l_client, s.c, &mut rng);
    let theta = randn(s.q, s.c, &mut rng);
    let mask = vec![1.0f32; s.l_client];
    bench("runtime::grad (client 200x512x10)", 3, 50, || {
        std::hint::black_box(rt.grad(&xhat, &y, &theta, &mask).unwrap());
    });

    let xp = randn(s.u_max, s.q, &mut rng);
    let yp = randn(s.u_max, s.c, &mut rng);
    let ones = vec![1.0f32; s.u_max];
    bench("runtime::grad (server 1536x512x10)", 3, 20, || {
        std::hint::black_box(rt.grad(&xp, &yp, &theta, &ones).unwrap());
    });

    let g = randn(s.u_max, s.l_client, &mut rng);
    let w = vec![0.5f32; s.l_client];
    bench("runtime::encode (1536x200 -> parity)", 3, 20, || {
        std::hint::black_box(rt.encode(&g, &w, &xhat, &y).unwrap());
    });

    let x_raw = randn(s.b_embed, s.d, &mut rng);
    let omega = randn(s.d, s.q, &mut rng);
    let delta = vec![0.3f32; s.q];
    bench("runtime::embed (200x784 -> 200x512)", 3, 20, || {
        std::hint::black_box(rt.embed(&x_raw, &omega, &delta).unwrap());
    });

    let test = randn(2000, s.q, &mut rng);
    bench("runtime::predict (2000x512x10)", 3, 20, || {
        std::hint::black_box(rt.predict(&test, &theta).unwrap());
    });

    // --- aggregation primitives ---
    let mut acc = Mat::zeros(s.q, s.c);
    let gmat = randn(s.q, s.c, &mut rng);
    bench("Mat::axpy (512x10 aggregate)", 10, 2000, || {
        acc.axpy(0.5, &gmat);
        std::hint::black_box(&acc);
    });

    // --- one full coded training round, end to end (tiny preset) ---
    let session = ExperimentBuilder::preset("tiny")?.epochs(1).build()?;
    bench("full coded epoch (tiny: 5 clients x 2 steps)", 1, 10, || {
        std::hint::black_box(session.run(&mut CodedFedL::new(0.3)).unwrap());
    });
    println!(
        "\n{} executions so far: {} (tiny runtime) — per-round exec count drives L3 overhead",
        session.runtime().backend_name(),
        session.runtime().exec_count.get()
    );
    Ok(())
}
