//! Regenerates **Fig. 5(a–c)** (paper §V-B, Fashion-MNIST): the harder
//! dataset family; same scheme grid as Fig. 4.
//!
//! ```sh
//! cargo bench --bench fig5_fashion
//! EPOCHS=70 cargo bench --bench fig5_fashion
//! ```

mod fig_common;

fn main() {
    fig_common::run_figure("fashion", "Fig5/Fashion").expect("fig5 failed");
}
