//! Shared driver for the Fig. 4 / Fig. 5 / Table II–III benches.
//!
//! Scale: benches default to a reduced run (EPOCHS=16) so the whole suite
//! completes in minutes on this CPU testbed; set `EPOCHS=70` (and
//! optionally `PRESET=paper`, after `python -m compile.aot --preset paper`)
//! for the paper's full §V-A scale. The *shape* claims (who wins, by
//! roughly what factor) are asserted programmatically either way.
#![allow(dead_code)] // each bench uses the subset it needs

use codedfedl::benchutil::{ascii_curves, run_experiment};
use codedfedl::conf::ExperimentConfig;
use codedfedl::coordinator::TrainOutcome;
use codedfedl::metrics::GainRow;
use codedfedl::schemes::SchemeSpec as Scheme;

pub fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

pub fn config(dataset: &str) -> ExperimentConfig {
    let mut cfg = match std::env::var("PRESET").as_deref() {
        Ok("paper") => ExperimentConfig::paper(),
        Ok("tiny") => ExperimentConfig::tiny(),
        _ => ExperimentConfig::default(),
    };
    cfg.epochs = env_usize("EPOCHS", 20);
    // Keep the paper's decay *shape* (steps at 40/70 and 65/70 of the run)
    // at any epoch budget — without decay the coded scheme's gradient-noise
    // floor never settles onto naive's plateau.
    cfg.lr_decay_epochs = vec![cfg.epochs * 40 / 70, cfg.epochs * 65 / 70];
    cfg.dataset = dataset.into();
    cfg
}

/// Run the full §V-B scheme grid for one dataset and print the three
/// panels of Fig. 4/5 plus the Table II/III rows.
pub fn run_figure(dataset: &str, title: &str) -> anyhow::Result<()> {
    let cfg = config(dataset);
    println!(
        "== {title}: n={} q={} m={} iters={} dataset={dataset} ==\n",
        cfg.clients,
        cfg.q,
        cfg.global_batch(),
        cfg.total_iters()
    );

    let schemes = [
        Scheme::NaiveUncoded,
        Scheme::Coded { delta: 0.1 },
        Scheme::Coded { delta: 0.2 },
        Scheme::GreedyUncoded { psi: 0.1 },
        Scheme::GreedyUncoded { psi: 0.2 },
    ];
    let (_, results) = run_experiment(&cfg, &schemes)?;
    let h = |i: usize| &results[i].1.history;

    // Panel (a): naive vs coded, accuracy vs simulated wall-clock,
    // with the parity-upload overhead highlighted.
    println!(
        "{}",
        ascii_curves(
            &format!("{title}(a): accuracy vs wall-clock — naive vs CodedFedL(δ)"),
            &[h(0), h(1), h(2)],
            |p| p.sim_time,
            "simulated seconds",
        )
    );
    for i in [1, 2] {
        let (s, r) = &results[i];
        println!(
            "   {}: parity upload overhead {:.1} s, t* = {:.2} s, u* = {}",
            s.label(),
            r.parity_overhead,
            r.t_star.unwrap(),
            r.u_star.unwrap()
        );
    }

    // Panel (b): accuracy vs iteration — all schemes.
    println!(
        "\n{}",
        ascii_curves(
            &format!("{title}(b): accuracy vs iteration — naive/greedy/coded"),
            &[h(0), h(3), h(4), h(1), h(2)],
            |p| p.iter as f64,
            "iteration",
        )
    );

    // Panel (c): accuracy vs wall-clock — all schemes.
    println!(
        "\n{}",
        ascii_curves(
            &format!("{title}(c): accuracy vs wall-clock — naive/greedy/coded"),
            &[h(0), h(3), h(4), h(1), h(2)],
            |p| p.sim_time,
            "simulated seconds",
        )
    );

    // Table rows (Tables II & III shape): targets relative to achieved
    // accuracy since absolute levels depend on the (synthetic) dataset.
    println!("\n=== gain rows (Table II: δ=ψ=0.1, Table III: δ=ψ=0.2) ===");
    let best = h(0).best_accuracy();
    for (coded_i, greedy_i, tag) in [(1, 3, "δ=ψ=0.1"), (2, 4, "δ=ψ=0.2")] {
        for frac in [0.99, 0.95] {
            let row = GainRow::compute(frac * best, h(0), h(greedy_i), h(coded_i));
            println!("[{tag}] {}", row.render());
        }
    }

    assert_figure_shape(&results);
    Ok(())
}

/// The qualitative claims of §V-B that must hold at any scale.
pub fn assert_figure_shape(results: &[(Scheme, TrainOutcome)]) {
    let naive = &results[0].1;
    let coded1 = &results[1].1;
    let coded2 = &results[2].1;
    let greedy2 = &results[4].1;

    // (1) CodedFedL total simulated time beats naive (straggler clipping).
    assert!(
        coded1.history.total_sim_time() < naive.history.total_sim_time(),
        "coded(0.1) {:.0}s !< naive {:.0}s",
        coded1.history.total_sim_time(),
        naive.history.total_sim_time()
    );
    // (2) More redundancy ⇒ faster rounds (t* shrinks).
    assert!(
        coded2.t_star.unwrap() <= coded1.t_star.unwrap() + 1e-9,
        "t*(δ=0.2) must be ≤ t*(δ=0.1)"
    );
    // (3) Coded's per-iteration accuracy tracks naive (stochastic
    //     approximation, eq. 30): final gap bounded.
    let gap = naive.history.best_accuracy() - coded1.history.best_accuracy();
    assert!(gap < 0.12, "coded under-tracks naive by {gap}");
    // (4) Greedy(0.2) under non-IID loses accuracy vs naive at equal
    //     iterations (class starvation).
    assert!(
        greedy2.history.best_accuracy() < naive.history.best_accuracy() - 0.02,
        "greedy(0.2) should trail naive under non-IID sharding"
    );
}
