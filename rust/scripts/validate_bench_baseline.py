#!/usr/bin/env python3
"""Validate the committed BENCH_hotpath.json baseline against a smoke run.

Usage: validate_bench_baseline.py <committed_baseline.json> <smoke_run.json>

Checks (coverage gates, not timing gates — smoke numbers are meaningless):
  * both documents parse and carry the current schema (8) with a
    well-formed, non-empty record list (op/shape/ns_per_iter/threads/iters
    plus the throughput fields — ``gflops`` (schema 3), the schema-4
    codec columns ``gbps``/``symbols_per_s``, and the schema-5 fleet
    columns ``n_clients``/``rounds_per_s`` — each a positive number or
    null — and the schema-6 robustness columns: ``rungs``, a 5-element
    degradation-ladder histogram ``[full, exact_decode, parity, partial,
    skip]`` of non-negative integers or null, and
    ``achieved_participation``, a fraction in [0, 1] or null);
  * ``fleet_scale`` records carry non-null ``n_clients``/``rounds_per_s``,
    and the committed baseline times the sampled-round decision path at
    two or more distinct fleet sizes, so the flat-cost-vs-N claim stays
    diffable;
  * ``degraded`` records carry non-null ``rungs``/``achieved_participation``
    (a perf diff on a faulted run must always see how its rounds resolved,
    so a "faster" run that silently skipped rounds is visible);
  * the committed baseline carries a ``checkpoint::snapshot`` latency row
    (schema 7: what one crash-consistent checkpoint — encode + atomic
    fsync'd write — costs the training loop), so the checkpoint path can
    never silently drop out of the tracked perf surface;
  * the committed baseline carries ``comm::`` payload-codec rows (schema 8:
    the quantize/dequantize/pack kernels behind ``[comm] codec``) and a
    positive top-level ``bytes_per_round`` (the default pipeline's modelled
    wire bytes per round — the denominator the codec rows shrink against),
    so the communication model can never silently drop out of the tracked
    perf surface;
  * both documents record a non-empty ``isa`` string (the GEMM microkernel
    the run resolved — ``scalar`` / ``avx2+fma`` / ``neon`` / ``pjrt``),
    so perf numbers are always attributable to an instruction set;
  * the committed baseline is a full-mode run (``smoke: false``) — smoke
    numbers must never be recorded as a baseline (rust/PERF.md);
  * the committed baseline records a measured, *zero* ``allocs_per_round``
    (the steady-state allocation-free contract of tests/alloc_gate.rs);
  * every (op, shape) pair in the committed baseline is covered by the
    smoke run, so a bench that silently stops running cannot leave a stale
    baseline row behind.

Advisory (printed as WARN, never fails the job — smoke timings are noisy
and run on whatever machine CI hands out): any shared (op, shape) whose
smoke throughput regressed more than 20% against the committed baseline
is flagged, so a real kernel regression leaves a visible trail in the log
next to the uploaded artifact.
"""

import json
import sys

SCHEMA = 8
RECORD_FIELDS = {
    "op": str,
    "shape": str,
    "ns_per_iter": (int, float),
    "threads": int,
    "iters": int,
}
# Per-record throughput columns: must be present, and a positive number
# or null (null = not meaningful for that op). n_clients/rounds_per_s are
# the schema-5 fleet_scale columns.
THROUGHPUT_FIELDS = ("gflops", "gbps", "symbols_per_s", "n_clients", "rounds_per_s")
# Ops whose records must carry the fleet columns non-null.
FLEET_OP_PREFIX = "fleet_scale"
# Ops whose records must carry the schema-6 robustness columns non-null.
DEGRADED_OP_PREFIX = "degraded"
# The schema-7 checkpoint latency row the committed baseline must carry.
CHECKPOINT_OP_PREFIX = "checkpoint"
# The schema-8 payload-codec kernel rows the committed baseline must carry.
COMM_OP_PREFIX = "comm"
# Number of degradation-ladder rungs in a ``rungs`` histogram.
RUNG_COUNT = 5
# Warn when a smoke run is this much slower than the committed baseline.
REGRESSION_WARN_RATIO = 1.20


def check_doc(doc, name, errors):
    """Schema-validate one report; returns its {(op, shape): record} map."""
    if doc.get("schema") != SCHEMA:
        errors.append(f"{name}: schema {doc.get('schema')!r} != {SCHEMA}")
    isa = doc.get("isa")
    if not isinstance(isa, str) or not isa:
        errors.append(f"{name}: isa must be a non-empty string, got {isa!r}")
    records = doc.get("records")
    if not isinstance(records, list) or not records:
        errors.append(f"{name}: records must be a non-empty list")
        return {}
    by_key = {}
    for i, rec in enumerate(records):
        for field, ty in RECORD_FIELDS.items():
            if not isinstance(rec.get(field), ty):
                errors.append(f"{name}: records[{i}].{field} is {rec.get(field)!r}, want {ty}")
        if isinstance(rec.get("ns_per_iter"), (int, float)) and rec["ns_per_iter"] <= 0:
            errors.append(f"{name}: records[{i}].ns_per_iter must be > 0")
        for field in THROUGHPUT_FIELDS:
            if field not in rec:
                errors.append(f"{name}: records[{i}] is missing the schema-{SCHEMA} {field} field")
            elif rec[field] is not None:
                if not isinstance(rec[field], (int, float)) or rec[field] <= 0:
                    errors.append(
                        f"{name}: records[{i}].{field} is {rec[field]!r}, want > 0 or null"
                    )
        if str(rec.get("op", "")).startswith(FLEET_OP_PREFIX):
            for field in ("n_clients", "rounds_per_s"):
                if rec.get(field) is None:
                    errors.append(
                        f"{name}: records[{i}] is a {FLEET_OP_PREFIX} row and must carry "
                        f"a non-null {field}"
                    )
        # Schema-6 robustness columns: rung histogram + achieved fraction.
        for field in ("rungs", "achieved_participation"):
            if field not in rec:
                errors.append(f"{name}: records[{i}] is missing the schema-{SCHEMA} {field} field")
        rungs = rec.get("rungs")
        if rungs is not None and (
            not isinstance(rungs, list)
            or len(rungs) != RUNG_COUNT
            or not all(isinstance(r, int) and r >= 0 for r in rungs)
        ):
            errors.append(
                f"{name}: records[{i}].rungs is {rungs!r}, want a {RUNG_COUNT}-element "
                f"list of non-negative integers or null"
            )
        achieved = rec.get("achieved_participation")
        if achieved is not None and (
            not isinstance(achieved, (int, float)) or not 0.0 <= achieved <= 1.0
        ):
            errors.append(
                f"{name}: records[{i}].achieved_participation is {achieved!r}, "
                f"want a fraction in [0, 1] or null"
            )
        if str(rec.get("op", "")).startswith(DEGRADED_OP_PREFIX):
            for field in ("rungs", "achieved_participation"):
                if rec.get(field) is None:
                    errors.append(
                        f"{name}: records[{i}] is a {DEGRADED_OP_PREFIX} row and must carry "
                        f"a non-null {field}"
                    )
        by_key[(rec.get("op"), rec.get("shape"))] = rec
    if len(by_key) != len(records):
        errors.append(f"{name}: duplicate (op, shape) records")
    return by_key


def warn_on_regressions(baseline, smoke):
    """Advisory throughput diff on shared keys; never fails the run."""
    warned = 0
    for key in sorted(set(baseline) & set(smoke), key=str):
        base_ns = baseline[key].get("ns_per_iter")
        smoke_ns = smoke[key].get("ns_per_iter")
        if not isinstance(base_ns, (int, float)) or not isinstance(smoke_ns, (int, float)):
            continue
        if base_ns <= 0 or smoke_ns <= 0:
            continue
        if smoke_ns > base_ns * REGRESSION_WARN_RATIO:
            warned += 1
            print(
                f"WARN: {key}: smoke run {smoke_ns:.0f} ns/iter is "
                f"{smoke_ns / base_ns:.2f}x the committed baseline ({base_ns:.0f} ns/iter) "
                f"— advisory only (smoke timings are noisy)",
                file=sys.stderr,
            )
    return warned


def main(baseline_path, smoke_path):
    errors = []
    with open(baseline_path) as f:
        baseline = json.load(f)
    with open(smoke_path) as f:
        smoke = json.load(f)

    baseline_recs = check_doc(baseline, "baseline", errors)
    smoke_recs = check_doc(smoke, "smoke run", errors)

    if baseline.get("smoke") is not False:
        errors.append("baseline: must be a full-mode run (smoke: false)")
    if baseline.get("allocs_per_round") != 0:
        errors.append(
            "baseline: allocs_per_round must be the measured value 0, got "
            f"{baseline.get('allocs_per_round')!r}"
        )
    for key in sorted(set(baseline_recs) - set(smoke_recs), key=str):
        errors.append(f"baseline record not covered by the smoke run: {key}")
    fleet_ns = {
        rec["n_clients"]
        for rec in baseline_recs.values()
        if str(rec.get("op", "")).startswith(FLEET_OP_PREFIX)
        and isinstance(rec.get("n_clients"), int)
    }
    if len(fleet_ns) < 2:
        errors.append(
            "baseline: expected fleet_scale records at >= 2 distinct fleet sizes "
            f"(rounds/s vs N), found n_clients = {sorted(fleet_ns)}"
        )
    if not any(
        str(op).startswith(CHECKPOINT_OP_PREFIX) for op, _shape in baseline_recs
    ):
        errors.append(
            f"baseline: expected a {CHECKPOINT_OP_PREFIX}::snapshot latency record "
            "(schema 7: the crash-consistent checkpoint cost must stay on the "
            "tracked perf surface)"
        )
    if not any(str(op).startswith(COMM_OP_PREFIX + "::") for op, _shape in baseline_recs):
        errors.append(
            f"baseline: expected {COMM_OP_PREFIX}:: payload-codec kernel records "
            "(schema 8: the [comm] quantize/pack path must stay on the tracked "
            "perf surface)"
        )
    bytes_per_round = baseline.get("bytes_per_round")
    if not isinstance(bytes_per_round, int) or bytes_per_round <= 0:
        errors.append(
            "baseline: bytes_per_round must be the measured positive wire-byte "
            f"count of the default pipeline (schema 8), got {bytes_per_round!r}"
        )

    if errors:
        for e in errors:
            print(f"FAIL: {e}", file=sys.stderr)
        return 1
    warned = warn_on_regressions(baseline_recs, smoke_recs)
    print(
        f"ok: baseline ({len(baseline_recs)} records, isa {baseline.get('isa')!r}) "
        f"schema-valid and fully covered by the smoke run ({len(smoke_recs)} records, "
        f"isa {smoke.get('isa')!r}); {warned} advisory throughput warning(s)"
    )
    return 0


if __name__ == "__main__":
    if len(sys.argv) != 3:
        sys.exit(__doc__)
    sys.exit(main(sys.argv[1], sys.argv[2]))
