#!/usr/bin/env python3
"""Validate the committed BENCH_hotpath.json baseline against a smoke run.

Usage: validate_bench_baseline.py <committed_baseline.json> <smoke_run.json>

Checks (coverage gates, not timing gates — smoke numbers are meaningless):
  * both documents parse and carry the current schema (2) with a
    well-formed, non-empty record list (op/shape/ns_per_iter/threads/iters);
  * the committed baseline is a full-mode run (``smoke: false``) — smoke
    numbers must never be recorded as a baseline (rust/PERF.md);
  * the committed baseline records a measured, *zero* ``allocs_per_round``
    (the steady-state allocation-free contract of tests/alloc_gate.rs);
  * every (op, shape) pair in the committed baseline is covered by the
    smoke run, so a bench that silently stops running cannot leave a stale
    baseline row behind.

Exits non-zero with one line per failure.
"""

import json
import sys

SCHEMA = 2
RECORD_FIELDS = {
    "op": str,
    "shape": str,
    "ns_per_iter": (int, float),
    "threads": int,
    "iters": int,
}


def check_doc(doc, name, errors):
    """Schema-validate one report; returns its (op, shape) set."""
    if doc.get("schema") != SCHEMA:
        errors.append(f"{name}: schema {doc.get('schema')!r} != {SCHEMA}")
    records = doc.get("records")
    if not isinstance(records, list) or not records:
        errors.append(f"{name}: records must be a non-empty list")
        return set()
    keys = set()
    for i, rec in enumerate(records):
        for field, ty in RECORD_FIELDS.items():
            if not isinstance(rec.get(field), ty):
                errors.append(f"{name}: records[{i}].{field} is {rec.get(field)!r}, want {ty}")
        if isinstance(rec.get("ns_per_iter"), (int, float)) and rec["ns_per_iter"] <= 0:
            errors.append(f"{name}: records[{i}].ns_per_iter must be > 0")
        keys.add((rec.get("op"), rec.get("shape")))
    if len(keys) != len(records):
        errors.append(f"{name}: duplicate (op, shape) records")
    return keys


def main(baseline_path, smoke_path):
    errors = []
    with open(baseline_path) as f:
        baseline = json.load(f)
    with open(smoke_path) as f:
        smoke = json.load(f)

    baseline_keys = check_doc(baseline, "baseline", errors)
    smoke_keys = check_doc(smoke, "smoke run", errors)

    if baseline.get("smoke") is not False:
        errors.append("baseline: must be a full-mode run (smoke: false)")
    if baseline.get("allocs_per_round") != 0:
        errors.append(
            "baseline: allocs_per_round must be the measured value 0, got "
            f"{baseline.get('allocs_per_round')!r}"
        )
    for key in sorted(baseline_keys - smoke_keys, key=str):
        errors.append(f"baseline record not covered by the smoke run: {key}")

    if errors:
        for e in errors:
            print(f"FAIL: {e}", file=sys.stderr)
        return 1
    print(
        f"ok: baseline ({len(baseline_keys)} records) schema-valid and fully "
        f"covered by the smoke run ({len(smoke_keys)} records)"
    )
    return 0


if __name__ == "__main__":
    if len(sys.argv) != 3:
        sys.exit(__doc__)
    sys.exit(main(sys.argv[1], sys.argv[2]))
