//! CodedFedL (paper §III): deadline-based aggregation with a coded
//! gradient from parity data compensating the missing stragglers.
//!
//! Two recovery modes (`[coding] recovery` / `--recovery`):
//!
//! * [`RecoveryMode::Expectation`] — the paper's scheme, unchanged: load
//!   allocation fixes `(t*, ℓ*_j, u*)` once (§III-C), every round costs
//!   exactly `t*`, and the real-valued parity-dataset gradient (eq. 28)
//!   compensates deadline-missing clients *in expectation* (eq. 31). Only
//!   the dense random generator has parity datasets, so this mode
//!   requires `code = "dense"`; its histories are bit-identical to every
//!   pre-trait run.
//! * [`RecoveryMode::Exact`] — the erasure-coded upgrade the paper cannot
//!   express. Client gradient blocks are the source symbols of a
//!   [`crate::coding::Code`] (byte planes over GF(256)); the server walks
//!   the round's event timeline and declares the round complete at the
//!   first instant the received subset — arrived uplinks plus the parity
//!   unit's repair symbols — is decodable, then reconstructs every
//!   missing gradient **bit-exactly** and folds the full-fleet aggregate
//!   in client-index order. When the subset is decodable the aggregate's
//!   bits equal the all-clients-arrived fold exactly; when it is not, the
//!   round degrades to the arrived partial sum (normalised by the rows
//!   that actually arrived).
//!
//! Exact-mode decode state (packed source/repair pools, the
//! [`DecodeScratch`] elimination workspace, the reconstruction buffer)
//! is allocated once in `prepare` and reused every round, keeping warm
//! rounds on the engine's 0-alloc compute-path gate.

use anyhow::{Context, Result};

use super::{GradRequest, RoundCost, RoundCtx, RoundExec, RoundPlan, Scheme, SchemeSetup, SchemeStats};
use crate::allocation::{self, NodeSpec};
use crate::coding::{
    self, pack_byte_planes, unpack_byte_planes, Code, CodeSpec, DecodeScratch, DenseRandomCode,
    RecoveryMode,
};
use crate::coordinator::FedSetup;
use crate::metrics::RoundOutcome;
use crate::rng::Rng;
use crate::runtime::Runtime;
use crate::sim::timeline::{Leg, LegEvent};
use crate::sim::RoundDelays;
use crate::tensor::{Isa, Mat};

/// State fixed before training (per global mini-batch parity).
struct CodedState {
    t_star: f64,
    u_star: usize,
    /// Per-client processed-subset masks (length `local_batch`, reused for
    /// every mini-batch of that client as §III-D fixes the subset).
    masks: Vec<Vec<f32>>,
    /// Per-step composite parity: `steps × (X̌ [u*, q], Y̌ [u*, c])`.
    parity: Vec<(Mat, Mat)>,
    /// All-ones mask over the `u*` parity rows, built once (the parity
    /// gradient includes every row every round).
    parity_mask: Vec<f32>,
    /// Reusable output buffer for the per-round parity gradient — keeps
    /// [`CodedFedL::aggregate`] free of compute-path allocations.
    parity_grad: Mat,
    /// `1 − P(T_C ≤ t*)` for the coded-gradient scale of eq. (28).
    pnr_server: f64,
    parity_overhead: f64,
}

/// Per-round decision recorded by exact-mode `plan_round` and consumed by
/// `aggregate` (the engine always calls them in that order).
#[derive(Clone, Copy, Debug, Default)]
struct ExactRound {
    decodable: bool,
    repairs_avail: usize,
}

/// State fixed before training in exact-recovery mode.
struct ExactState {
    t_star: f64,
    u_star: usize,
    parity_overhead: f64,
    code: Box<dyn Code>,
    isa: Isa,
    /// Bytes per source symbol: `q · classes · 4` (one packed gradient).
    symbol_len: usize,
    /// All-ones mask over `local_batch` rows, cloned into each request
    /// (exact mode reconstructs *full* gradients — no §III-D subsampling).
    full_mask: Vec<f32>,
    /// Arrival mask, rewritten by every `plan_round`.
    have: Vec<bool>,
    /// Packed source pool, `clients · symbol_len` bytes.
    src: Vec<u8>,
    /// Packed repair pool, `code.repairs() · symbol_len` bytes.
    repairs: Vec<u8>,
    /// Reconstruction buffer for one decoded gradient (`[q, c]`).
    recon: Mat,
    scratch: DecodeScratch,
    round: ExactRound,
}

/// The paper's scheme: load allocation fixes `(t*, ℓ*_j, u*)` once before
/// training (§III-C); each round costs exactly `t*`; deadline-missing
/// clients are compensated by the coded gradient over the parity data
/// (eq. 28) — or, under `recovery = exact`, reconstructed bit-exactly
/// from an erasure code over the gradient bytes (module docs).
pub struct CodedFedL {
    delta: f64,
    code: CodeSpec,
    recovery: RecoveryMode,
    state: Option<CodedState>,
    exact: Option<ExactState>,
}

impl CodedFedL {
    /// `delta` is the coding redundancy `u_max / m` in `(0, 1]`. Defaults
    /// to the paper's configuration: dense code, expectation recovery.
    pub fn new(delta: f64) -> Self {
        CodedFedL {
            delta,
            code: CodeSpec::Dense,
            recovery: RecoveryMode::Expectation,
            state: None,
            exact: None,
        }
    }

    /// Select the erasure code (`[coding] code` / `--code`).
    pub fn with_code(mut self, code: CodeSpec) -> Self {
        self.code = code;
        self
    }

    /// Select the recovery mode (`[coding] recovery` / `--recovery`).
    pub fn with_recovery(mut self, recovery: RecoveryMode) -> Self {
        self.recovery = recovery;
        self
    }

    pub fn delta(&self) -> f64 {
        self.delta
    }

    pub fn code(&self) -> CodeSpec {
        self.code
    }

    pub fn recovery(&self) -> RecoveryMode {
        self.recovery
    }

    fn state(&self) -> &CodedState {
        self.state.as_ref().expect("prepare() runs before any round")
    }

    fn plan_expectation(&mut self, ctx: &RoundCtx, delays: &RoundDelays) -> Result<RoundPlan> {
        let cs = self.state();
        // Uncoded part: clients that make the deadline (eq. 29) and have a
        // non-empty processed subset contribute their masked gradient.
        // Scenario-dropped clients carry infinite delays, so they simply
        // miss t* and the parity gradient compensates — exactly the
        // paper's straggler story. `arrivals_iter` keeps this per-round
        // decision free of the old `Vec<bool>` allocation. Per-client
        // state (the §III-D processed-subset masks) is indexed through
        // `ctx.data_shard`, so sampled rosters over a mega-fleet reuse the
        // mask of the data shard each slot trains on (identity on the
        // full fixed fleet).
        let requests = delays
            .arrivals_iter(cs.t_star)
            .enumerate()
            .filter(|&(j, arrived)| {
                arrived && cs.masks[ctx.data_shard(j)].iter().any(|&v| v > 0.0)
            })
            .map(|(j, _)| GradRequest {
                client: j,
                mask: cs.masks[ctx.data_shard(j)].clone(),
                scale: 1.0,
            })
            .collect();
        Ok(RoundPlan { requests, round_time: cs.t_star })
    }

    /// Exact mode: walk the round's time-sorted event stream — uplink
    /// arrivals reveal source symbols, the parity unit's completion
    /// reveals the repair symbols — and stop at the first instant the
    /// received subset is decodable. Decodable rounds request *every*
    /// client in index order (the engine's fold is then the all-arrived
    /// aggregate, which `aggregate` reproduces through the codec);
    /// undecodable rounds request only the arrived clients.
    fn plan_exact(&mut self, ctx: &RoundCtx) -> Result<RoundPlan> {
        // Config validation rejects `[fleet]` rosters with exact recovery
        // (the code is sized over the fixed fleet); this is the defensive
        // backstop for schemes constructed outside the builder.
        anyhow::ensure!(
            ctx.roster.is_none(),
            "exact recovery requires the full fixed fleet (got a sampled participation roster)"
        );
        let es = self.exact.as_mut().expect("prepare() runs before any round");
        let n = es.have.len();
        es.have.iter_mut().for_each(|h| *h = false);
        let mut missing = n;
        let mut repairs_avail = 0usize;
        let mut decodable = false;
        let mut done_at = f64::NAN;
        let mut last_finite = f64::NAN;
        for ev in ctx.trace.events() {
            let t = ev.time();
            if !t.is_finite() {
                // Dropped clients never deliver; they can only be decoded
                // around, not waited for.
                continue;
            }
            last_finite = if last_finite.is_nan() { t } else { last_finite.max(t) };
            match *ev {
                LegEvent::Client { client, leg: Leg::Uplink, .. } => {
                    if !es.have[client] {
                        es.have[client] = true;
                        missing -= 1;
                    }
                }
                LegEvent::ServerParity { .. } => repairs_avail = es.code.repairs(),
                // Downlink/compute completions change nothing the decoder
                // can see.
                LegEvent::Client { .. } => continue,
            }
            if missing <= repairs_avail
                && es.code.decodable(&es.have, repairs_avail, &mut es.scratch)
            {
                decodable = true;
                done_at = t;
                break;
            }
        }
        if !decodable {
            // The round ran its whole timeline without becoming decodable;
            // charge the last completion (or t* on an all-dropped round).
            done_at = if last_finite.is_finite() { last_finite } else { es.t_star };
        }
        es.round = ExactRound { decodable, repairs_avail };
        let requests = (0..n)
            .filter(|&j| decodable || es.have[j])
            .map(|j| GradRequest { client: j, mask: es.full_mask.clone(), scale: 1.0 })
            .collect();
        Ok(RoundPlan { requests, round_time: done_at })
    }

    /// Exact-mode aggregation: pack the planned gradients into byte
    /// planes, form the repair symbols, erase the sources that never
    /// arrived, decode them back, and refold the aggregate in client-index
    /// order. GF(256) decode is exact, so the refolded bits equal the
    /// all-arrived fold bit-for-bit.
    fn aggregate_exact(
        &mut self,
        ctx: &RoundCtx,
        plan: &RoundPlan,
        exec: &RoundExec,
        agg: &mut Mat,
    ) -> Result<RoundCost> {
        let es = self.exact.as_mut().expect("prepare() runs before any round");
        if !es.round.decodable {
            // Engine already folded the arrived full-batch gradients;
            // normalise by the rows that actually arrived (0 ⇒ the engine
            // falls back to m and the round is a pure decay step).
            let returned = (plan.requests.len() * ctx.setup.cfg.local_batch) as f32;
            return Ok(RoundCost {
                sim_seconds: plan.round_time,
                returned,
                outcome: RoundOutcome::PartialFold,
            });
        }
        let n = es.have.len();
        anyhow::ensure!(
            plan.requests.len() == n,
            "decodable exact round planned {} of {n} clients",
            plan.requests.len()
        );
        if es.have.iter().all(|&h| h) {
            // Everyone arrived: the engine's fold already is the
            // all-arrived aggregate; nothing to reconstruct.
            return Ok(RoundCost {
                sim_seconds: plan.round_time,
                returned: 0.0,
                outcome: RoundOutcome::Full,
            });
        }
        let grads = exec.planned_grads();
        let ExactState { code, isa, symbol_len, have, src, repairs, recon, scratch, round, .. } =
            es;
        let (isa, len) = (*isa, *symbol_len);
        // Sources: every planned gradient, packed. Encoding over the full
        // pool reproduces the parity the fleet's distributed encode would
        // have formed ahead of the round.
        for (j, g) in grads.iter().enumerate() {
            pack_byte_planes(g.as_slice(), &mut src[j * len..(j + 1) * len]);
        }
        for r in 0..code.repairs() {
            let (head, tail) = repairs.split_at_mut(r * len);
            let _ = head;
            code.encode_repair(isa, r, src, len, &mut tail[..len]);
        }
        // Erase what never arrived, then decode it back bit-exactly.
        for j in 0..n {
            if !have[j] {
                src[j * len..(j + 1) * len].fill(0);
            }
        }
        code.decode_into(isa, have, round.repairs_avail, len, src, repairs, scratch)
            .map_err(|e| anyhow::anyhow!("exact recovery failed: {e}"))
            .context("decoding missing client gradients")?;
        // Refold in client-index order — the same order the engine folded
        // the planned gradients, so arrived entries contribute identical
        // bits and decoded entries contribute the exact missing bits.
        agg.as_mut_slice().fill(0.0);
        for (j, g) in grads.iter().enumerate() {
            if have[j] {
                agg.axpy(1.0, g);
            } else {
                unpack_byte_planes(&src[j * len..(j + 1) * len], recon.as_mut_slice());
                agg.axpy(1.0, recon);
            }
        }
        Ok(RoundCost {
            sim_seconds: plan.round_time,
            returned: 0.0,
            outcome: RoundOutcome::ExactDecode,
        })
    }
}

impl Scheme for CodedFedL {
    fn label(&self) -> String {
        if self.code == CodeSpec::Dense && self.recovery == RecoveryMode::Expectation {
            // The paper's configuration keeps its historical label (and
            // history curves) unchanged.
            format!("coded(delta={})", self.delta)
        } else {
            format!(
                "coded(delta={},code={},recovery={})",
                self.delta,
                self.code.label(),
                self.recovery
            )
        }
    }

    fn rng_tag(&self) -> u64 {
        103
    }

    fn prepare(
        &mut self,
        setup: &FedSetup,
        rt: &Runtime,
        code_rng: &mut Rng,
    ) -> Result<SchemeSetup> {
        match self.recovery {
            RecoveryMode::Expectation => {
                anyhow::ensure!(
                    self.code == CodeSpec::Dense,
                    "{} has no expectation-mode parity datasets (set [coding] recovery = \"exact\")",
                    self.code.label()
                );
                let state = prepare_coded(setup, rt, self.delta, code_rng)?;
                let out = SchemeSetup {
                    client_loads: state
                        .masks
                        .iter()
                        .map(|m| m.iter().sum::<f32>() as f64)
                        .collect(),
                    server_load: state.u_star as f64,
                    clock_offset: state.parity_overhead,
                };
                self.state = Some(state);
                Ok(out)
            }
            RecoveryMode::Exact => {
                self.code
                    .validate()
                    .map_err(|e| anyhow::anyhow!("[coding] code: {e}"))?;
                let state = prepare_exact(setup, rt, self.delta, self.code, code_rng)?;
                let out = SchemeSetup {
                    // Exact mode reconstructs full gradients, so every
                    // client computes its whole local batch.
                    client_loads: vec![setup.cfg.local_batch as f64; setup.cfg.clients],
                    server_load: state.u_star as f64,
                    clock_offset: state.parity_overhead,
                };
                self.exact = Some(state);
                Ok(out)
            }
        }
    }

    fn plan_round(&mut self, ctx: &RoundCtx, delays: &RoundDelays) -> Result<RoundPlan> {
        match self.recovery {
            RecoveryMode::Expectation => self.plan_expectation(ctx, delays),
            RecoveryMode::Exact => self.plan_exact(ctx),
        }
    }

    fn aggregate(
        &mut self,
        ctx: &RoundCtx,
        delays: &RoundDelays,
        plan: &RoundPlan,
        exec: &RoundExec,
        agg: &mut Mat,
    ) -> Result<RoundCost> {
        if self.recovery == RecoveryMode::Exact {
            return self.aggregate_exact(ctx, plan, exec, agg);
        }
        let cs = self.state.as_mut().expect("prepare() runs before any round");
        // Coded part (eq. 28): gradient over this step's parity, scaled by
        // 1/((1−pnr_C)·u*), whenever the MEC unit itself makes t*. The
        // mask and output buffer are held in the scheme state, so the
        // round loop allocates nothing here.
        let parity_in = delays.server_t <= cs.t_star;
        if parity_in {
            let scale = 1.0 / ((1.0 - cs.pnr_server) as f32 * cs.u_star as f32);
            let CodedState { parity, parity_mask, parity_grad, .. } = cs;
            let (xp, yp) = &parity[ctx.step];
            exec.grad_into(xp, yp, parity_mask, parity_grad)
                .context("coded gradient over parity data")?;
            agg.axpy(scale, parity_grad);
        }
        // Every client made the deadline ⇒ the full planned aggregate;
        // else the parity gradient (when the MEC unit itself made t* —
        // server-side parity faults carry T_C = ∞ and fail the check)
        // compensates the stragglers in expectation; else the round is an
        // uncompensated partial fold.
        let outcome = if plan.requests.len() == ctx.participants() {
            RoundOutcome::Full
        } else if parity_in {
            RoundOutcome::ParityCompensation
        } else {
            RoundOutcome::PartialFold
        };
        // Every round costs exactly t*; the return is stochastically
        // complete (returned = 0.0 ⇒ engine normalises by m).
        Ok(RoundCost { sim_seconds: plan.round_time, returned: 0.0, outcome })
    }

    fn stats(&self) -> SchemeStats {
        match (&self.state, &self.exact) {
            (Some(cs), _) => SchemeStats {
                t_star: Some(cs.t_star),
                u_star: Some(cs.u_star),
                parity_overhead: cs.parity_overhead,
            },
            (None, Some(es)) => SchemeStats {
                t_star: Some(es.t_star),
                u_star: Some(es.u_star),
                parity_overhead: es.parity_overhead,
            },
            (None, None) => SchemeStats::default(),
        }
    }
}

/// The two-step load allocation of §III-C, shared by both recovery modes:
/// `(t*, per-client ℓ*, u*)` over the per-round mini-batch.
fn solve_allocation(
    setup: &FedSetup,
    delta: f64,
) -> Result<(f64, Vec<usize>, usize)> {
    let cfg = &setup.cfg;
    let m = setup.m();
    let u_cap = ((delta * m as f64).round() as usize).min(cfg.u_max);
    anyhow::ensure!(u_cap > 0, "delta {delta} gives zero parity rows");

    let mut nodes: Vec<NodeSpec> = setup
        .clients
        .iter()
        .map(|p| NodeSpec { params: *p, max_load: cfg.local_batch as f64 })
        .collect();
    nodes.push(NodeSpec { params: setup.server, max_load: u_cap as f64 });
    let alloc = allocation::solve(&nodes, m as f64)
        .map_err(|e| anyhow::anyhow!("load allocation failed: {e}"))?;

    // Integer loads; pnr re-evaluated at the rounded load for exactness.
    let ell_star: Vec<usize> = alloc.loads[..cfg.clients]
        .iter()
        .map(|&l| (l.floor() as usize).min(cfg.local_batch))
        .collect();
    let u_star = (alloc.u_star().floor() as usize).clamp(1, u_cap);
    Ok((alloc.t_star, ell_star, u_star))
}

/// One-time parity upload overhead (Fig. 4(a) inset): clients upload in
/// parallel; the clock pays the slowest client's total upload across all
/// `steps_per_epoch` parity sets.
fn parity_upload_overhead(setup: &FedSetup, u_star: usize) -> f64 {
    setup
        .clients
        .iter()
        .map(|cl| {
            setup.fleet_spec.parity_upload_secs(cl, u_star) * setup.cfg.steps_per_epoch as f64
        })
        .fold(0.0, f64::max)
}

/// Load allocation (§III-C) + weight matrices (§III-D) + per-step parity
/// datasets (§III-B) for expectation mode. The generator draws run
/// through [`DenseRandomCode`] — the paper's dense code behind the
/// [`Code`] trait — and are byte-for-byte the historical sequence.
fn prepare_coded(
    setup: &FedSetup,
    rt: &Runtime,
    delta: f64,
    rng: &mut Rng,
) -> Result<CodedState> {
    let cfg = &setup.cfg;
    let (t_star, ell_star, u_star) = solve_allocation(setup, delta)?;
    let pnr_server = 1.0 - setup.server.cdf(t_star, u_star as f64);
    anyhow::ensure!(
        pnr_server < 1.0,
        "server never returns by t* — parameters are inconsistent"
    );

    // --- per-client processed subsets + weight vectors (§III-D) ---
    let mut masks = Vec::with_capacity(cfg.clients);
    let mut weights = Vec::with_capacity(cfg.clients);
    for (j, client) in setup.clients.iter().enumerate() {
        let processed = coding::sample_processed(cfg.local_batch, ell_star[j], rng);
        let pnr1 = if ell_star[j] > 0 {
            1.0 - client.cdf(t_star, ell_star[j] as f64)
        } else {
            1.0
        };
        weights.push(coding::weight_vector(&processed, pnr1));
        masks.push(processed.iter().map(|&b| if b { 1.0 } else { 0.0 }).collect());
    }

    // --- distributed encoding per global mini-batch (§V-A) ---
    let dense = DenseRandomCode::expectation(cfg.generator, cfg.clients);
    let mut parity: Vec<(Mat, Mat)> = Vec::with_capacity(cfg.steps_per_epoch);
    for step in 0..cfg.steps_per_epoch {
        let mut xp_acc: Option<Mat> = None;
        let mut yp_acc: Option<Mat> = None;
        for j in 0..cfg.clients {
            let g = dense.generator_matrix(u_star, cfg.local_batch, rng);
            let cd = &setup.client_data[j];
            let (xp, yp) = rt
                .encode(&g, &weights[j], &cd.xhat[step], &cd.y[step])
                .with_context(|| format!("encoding client {j}, step {step}"))?;
            match (&mut xp_acc, &mut yp_acc) {
                (Some(xa), Some(ya)) => {
                    xa.axpy(1.0, &xp);
                    ya.axpy(1.0, &yp);
                }
                _ => {
                    xp_acc = Some(xp);
                    yp_acc = Some(yp);
                }
            }
        }
        // Trim parity to the live u* rows (encode pads G to u_max with
        // zero rows, whose parity is exactly zero).
        let xp = xp_acc.unwrap().rows_slice(0, u_star);
        let yp = yp_acc.unwrap().rows_slice(0, u_star);
        parity.push((xp, yp));
    }

    let parity_overhead = parity_upload_overhead(setup, u_star);

    Ok(CodedState {
        t_star,
        u_star,
        masks,
        parity,
        parity_mask: vec![1.0; u_star],
        parity_grad: Mat::zeros(cfg.q, cfg.classes),
        pnr_server,
        parity_overhead,
    })
}

/// Exact-mode preparation: the same §III-C allocation (for `u*`, `t*` and
/// the parity-unit load), then a seeded [`Code`] over the fleet's
/// gradient shards and every persistent decode buffer, sized for the
/// worst case so warm rounds never allocate.
fn prepare_exact(
    setup: &FedSetup,
    rt: &Runtime,
    delta: f64,
    spec: CodeSpec,
    rng: &mut Rng,
) -> Result<ExactState> {
    let cfg = &setup.cfg;
    let (t_star, _ell_star, u_star) = solve_allocation(setup, delta)?;
    anyhow::ensure!(cfg.clients > 0, "exact recovery needs at least one client");

    // The code's coefficient rows are drawn from the scheme's private
    // stream — reproducible per (seed, scheme tag), independent of the
    // delay draws.
    let code = spec.build(cfg.generator, cfg.clients, rng.next_u64());
    let isa = rt.isa().unwrap_or(Isa::Scalar);
    let symbol_len = cfg.q * cfg.classes * 4;
    let n = cfg.clients;
    let r = code.repairs();
    let mut scratch = DecodeScratch::new();
    scratch.reserve(r, n, symbol_len);

    Ok(ExactState {
        t_star,
        u_star,
        parity_overhead: parity_upload_overhead(setup, u_star),
        code,
        isa,
        symbol_len,
        full_mask: vec![1.0; cfg.local_batch],
        have: vec![false; n],
        src: vec![0u8; n * symbol_len],
        repairs: vec![0u8; r * symbol_len],
        recon: Mat::zeros(cfg.q, cfg.classes),
        scratch,
        round: ExactRound::default(),
    })
}
