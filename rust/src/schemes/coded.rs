//! CodedFedL (paper §III): deadline-based aggregation with a coded
//! gradient from parity data compensating the missing stragglers.

use anyhow::{Context, Result};

use super::{GradRequest, RoundCost, RoundCtx, RoundExec, RoundPlan, Scheme, SchemeSetup, SchemeStats};
use crate::allocation::{self, NodeSpec};
use crate::coding;
use crate::coordinator::FedSetup;
use crate::rng::Rng;
use crate::runtime::Runtime;
use crate::sim::RoundDelays;
use crate::tensor::Mat;

/// State fixed before training (per global mini-batch parity).
struct CodedState {
    t_star: f64,
    u_star: usize,
    /// Per-client processed-subset masks (length `local_batch`, reused for
    /// every mini-batch of that client as §III-D fixes the subset).
    masks: Vec<Vec<f32>>,
    /// Per-step composite parity: `steps × (X̌ [u*, q], Y̌ [u*, c])`.
    parity: Vec<(Mat, Mat)>,
    /// All-ones mask over the `u*` parity rows, built once (the parity
    /// gradient includes every row every round).
    parity_mask: Vec<f32>,
    /// Reusable output buffer for the per-round parity gradient — keeps
    /// [`CodedFedL::aggregate`] free of compute-path allocations.
    parity_grad: Mat,
    /// `1 − P(T_C ≤ t*)` for the coded-gradient scale of eq. (28).
    pnr_server: f64,
    parity_overhead: f64,
}

/// The paper's scheme: load allocation fixes `(t*, ℓ*_j, u*)` once before
/// training (§III-C); each round costs exactly `t*`; deadline-missing
/// clients are compensated by the coded gradient over the parity data
/// (eq. 28), keeping the aggregate a stochastic approximation of the full
/// gradient (eq. 30).
pub struct CodedFedL {
    delta: f64,
    state: Option<CodedState>,
}

impl CodedFedL {
    /// `delta` is the coding redundancy `u_max / m` in `(0, 1]`.
    pub fn new(delta: f64) -> Self {
        CodedFedL { delta, state: None }
    }

    pub fn delta(&self) -> f64 {
        self.delta
    }

    fn state(&self) -> &CodedState {
        self.state.as_ref().expect("prepare() runs before any round")
    }
}

impl Scheme for CodedFedL {
    fn label(&self) -> String {
        format!("coded(delta={})", self.delta)
    }

    fn rng_tag(&self) -> u64 {
        103
    }

    fn prepare(
        &mut self,
        setup: &FedSetup,
        rt: &Runtime,
        code_rng: &mut Rng,
    ) -> Result<SchemeSetup> {
        let state = prepare_coded(setup, rt, self.delta, code_rng)?;
        let out = SchemeSetup {
            client_loads: state
                .masks
                .iter()
                .map(|m| m.iter().sum::<f32>() as f64)
                .collect(),
            server_load: state.u_star as f64,
            clock_offset: state.parity_overhead,
        };
        self.state = Some(state);
        Ok(out)
    }

    fn plan_round(&mut self, _ctx: &RoundCtx, delays: &RoundDelays) -> Result<RoundPlan> {
        let cs = self.state();
        // Uncoded part: clients that make the deadline (eq. 29) and have a
        // non-empty processed subset contribute their masked gradient.
        // Scenario-dropped clients carry infinite delays, so they simply
        // miss t* and the parity gradient compensates — exactly the
        // paper's straggler story. `arrivals_iter` keeps this per-round
        // decision free of the old `Vec<bool>` allocation.
        let requests = delays
            .arrivals_iter(cs.t_star)
            .enumerate()
            .filter(|&(j, arrived)| arrived && cs.masks[j].iter().any(|&v| v > 0.0))
            .map(|(j, _)| GradRequest { client: j, mask: cs.masks[j].clone(), scale: 1.0 })
            .collect();
        Ok(RoundPlan { requests, round_time: cs.t_star })
    }

    fn aggregate(
        &mut self,
        ctx: &RoundCtx,
        delays: &RoundDelays,
        plan: &RoundPlan,
        exec: &RoundExec,
        agg: &mut Mat,
    ) -> Result<RoundCost> {
        let cs = self.state.as_mut().expect("prepare() runs before any round");
        // Coded part (eq. 28): gradient over this step's parity, scaled by
        // 1/((1−pnr_C)·u*), whenever the MEC unit itself makes t*. The
        // mask and output buffer are held in the scheme state, so the
        // round loop allocates nothing here.
        if delays.server_t <= cs.t_star {
            let scale = 1.0 / ((1.0 - cs.pnr_server) as f32 * cs.u_star as f32);
            let CodedState { parity, parity_mask, parity_grad, .. } = cs;
            let (xp, yp) = &parity[ctx.step];
            exec.grad_into(xp, yp, parity_mask, parity_grad)
                .context("coded gradient over parity data")?;
            agg.axpy(scale, parity_grad);
        }
        // Every round costs exactly t*; the return is stochastically
        // complete (returned = 0.0 ⇒ engine normalises by m).
        Ok(RoundCost { sim_seconds: plan.round_time, returned: 0.0 })
    }

    fn stats(&self) -> SchemeStats {
        match &self.state {
            Some(cs) => SchemeStats {
                t_star: Some(cs.t_star),
                u_star: Some(cs.u_star),
                parity_overhead: cs.parity_overhead,
            },
            None => SchemeStats::default(),
        }
    }
}

/// Load allocation (§III-C) + weight matrices (§III-D) + per-step parity
/// datasets (§III-B).
fn prepare_coded(
    setup: &FedSetup,
    rt: &Runtime,
    delta: f64,
    rng: &mut Rng,
) -> Result<CodedState> {
    let cfg = &setup.cfg;
    let m = setup.m();
    let u_cap = ((delta * m as f64).round() as usize).min(cfg.u_max);
    anyhow::ensure!(u_cap > 0, "delta {delta} gives zero parity rows");

    // --- two-step load allocation over the per-round mini-batch ---
    let mut nodes: Vec<NodeSpec> = setup
        .clients
        .iter()
        .map(|p| NodeSpec { params: *p, max_load: cfg.local_batch as f64 })
        .collect();
    nodes.push(NodeSpec { params: setup.server, max_load: u_cap as f64 });
    let alloc = allocation::solve(&nodes, m as f64)
        .map_err(|e| anyhow::anyhow!("load allocation failed: {e}"))?;
    let t_star = alloc.t_star;

    // Integer loads; pnr re-evaluated at the rounded load for exactness.
    let ell_star: Vec<usize> = alloc.loads[..cfg.clients]
        .iter()
        .map(|&l| (l.floor() as usize).min(cfg.local_batch))
        .collect();
    let u_star = (alloc.u_star().floor() as usize).clamp(1, u_cap);
    let pnr_server = 1.0 - setup.server.cdf(t_star, u_star as f64);
    anyhow::ensure!(
        pnr_server < 1.0,
        "server never returns by t* — parameters are inconsistent"
    );

    // --- per-client processed subsets + weight vectors (§III-D) ---
    let mut masks = Vec::with_capacity(cfg.clients);
    let mut weights = Vec::with_capacity(cfg.clients);
    for (j, client) in setup.clients.iter().enumerate() {
        let processed = coding::sample_processed(cfg.local_batch, ell_star[j], rng);
        let pnr1 = if ell_star[j] > 0 {
            1.0 - client.cdf(t_star, ell_star[j] as f64)
        } else {
            1.0
        };
        weights.push(coding::weight_vector(&processed, pnr1));
        masks.push(processed.iter().map(|&b| if b { 1.0 } else { 0.0 }).collect());
    }

    // --- distributed encoding per global mini-batch (§V-A) ---
    let mut parity: Vec<(Mat, Mat)> = Vec::with_capacity(cfg.steps_per_epoch);
    for step in 0..cfg.steps_per_epoch {
        let mut xp_acc: Option<Mat> = None;
        let mut yp_acc: Option<Mat> = None;
        for j in 0..cfg.clients {
            let g = coding::generator_matrix(cfg.generator, u_star, cfg.local_batch, rng);
            let cd = &setup.client_data[j];
            let (xp, yp) = rt
                .encode(&g, &weights[j], &cd.xhat[step], &cd.y[step])
                .with_context(|| format!("encoding client {j}, step {step}"))?;
            match (&mut xp_acc, &mut yp_acc) {
                (Some(xa), Some(ya)) => {
                    xa.axpy(1.0, &xp);
                    ya.axpy(1.0, &yp);
                }
                _ => {
                    xp_acc = Some(xp);
                    yp_acc = Some(yp);
                }
            }
        }
        // Trim parity to the live u* rows (encode pads G to u_max with
        // zero rows, whose parity is exactly zero).
        let xp = xp_acc.unwrap().rows_slice(0, u_star);
        let yp = yp_acc.unwrap().rows_slice(0, u_star);
        parity.push((xp, yp));
    }

    // One-time parity upload overhead (Fig. 4(a) inset): clients upload in
    // parallel; the clock pays the slowest client's total upload across
    // all steps_per_epoch parity sets.
    let parity_overhead = setup
        .clients
        .iter()
        .map(|cl| {
            setup.fleet_spec.parity_upload_secs(cl, u_star) * cfg.steps_per_epoch as f64
        })
        .fold(0.0, f64::max);

    Ok(CodedState {
        t_star,
        u_star,
        masks,
        parity,
        parity_mask: vec![1.0; u_star],
        parity_grad: Mat::zeros(cfg.q, cfg.classes),
        pnr_server,
        parity_overhead,
    })
}
