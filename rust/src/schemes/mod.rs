//! Pluggable aggregation schemes (paper §V-A "Schemes", and every scheme
//! the paper never imagined).
//!
//! The training engine ([`crate::coordinator::engine`]) is scheme-agnostic:
//! it owns the virtual MEC clock, delay sampling, PJRT gradient execution,
//! the learning-rate schedule and history/observer plumbing. Everything a
//! waiting/aggregation policy decides goes through the [`Scheme`] trait:
//!
//! 1. [`Scheme::prepare`] — one-time work before round 1 (load allocation,
//!    parity encoding, …), returning the per-node loads that drive delay
//!    sampling plus any one-time clock overhead.
//! 2. [`Scheme::plan_round`] — given this round's sampled delays, which
//!    client gradients to execute (with per-point masks and scales) and
//!    what the round costs on the simulated clock.
//! 3. [`Scheme::aggregate`] — finalize the round: run any extra gradients
//!    (e.g. CodedFedL's parity gradient) through the [`RoundExec`] handle
//!    and price the round as a [`RoundCost`].
//!
//! The built-in schemes live in submodules: [`NaiveUncoded`],
//! [`GreedyUncoded`] and [`CodedFedL`]. Third-party schemes only need
//! `label` + `plan_round`; every other hook has a sensible default (full
//! local batches, no parity, cost = the planned round time). See
//! `rust/tests/scheme_api.rs` for a complete out-of-crate implementation.
//!
//! [`SchemeSpec`] is the closed, serialisable description used by the CLI,
//! TOML files and benches (`"coded:delta=0.1"` ↔ `SchemeSpec::Coded`);
//! [`SchemeSpec::build`] turns it into a boxed trait object.

mod coded;
mod greedy;
mod naive;

pub use coded::CodedFedL;
pub use greedy::GreedyUncoded;
pub use naive::NaiveUncoded;

use anyhow::Result;

use crate::conf::ExperimentConfig;
use crate::coordinator::FedSetup;
use crate::metrics::RoundOutcome;
use crate::rng::Rng;
use crate::runtime::{PreparedTheta, Runtime};
use crate::sim::timeline::RoundTrace;
use crate::sim::RoundDelays;
use crate::tensor::Mat;

/// What a scheme's one-time [`Scheme::prepare`] hands back to the engine.
#[derive(Clone, Debug)]
pub struct SchemeSetup {
    /// Per-client processed load `ℓ̃_j` per round (drives compute-delay
    /// sampling). Length must equal the client count.
    pub client_loads: Vec<f64>,
    /// Server-side parity load `u` per round (0 for uncoded schemes).
    pub server_load: f64,
    /// One-time simulated overhead (seconds) charged to the clock before
    /// round 1 — e.g. CodedFedL's parity upload.
    pub clock_offset: f64,
}

impl SchemeSetup {
    /// The uncoded default: every client processes its full local batch,
    /// the server computes nothing, nothing is uploaded up front.
    pub fn uncoded(cfg: &ExperimentConfig) -> Self {
        SchemeSetup {
            client_loads: vec![cfg.local_batch as f64; cfg.clients],
            server_load: 0.0,
            clock_offset: 0.0,
        }
    }
}

/// Immutable per-round context handed to the scheme hooks.
pub struct RoundCtx<'a> {
    /// 0-based global iteration.
    pub iter: usize,
    /// 0-based epoch (`iter / steps_per_epoch`).
    pub epoch: usize,
    /// Mini-batch index within the epoch (`iter % steps_per_epoch`).
    pub step: usize,
    /// The shared experiment state (fleet, shards, config).
    pub setup: &'a FedSetup,
    /// This round's full event timeline — ordered per-leg completion
    /// events per client (downlink → compute → uplink) plus the server's
    /// parity completion, after scenario modulation. The
    /// [`RoundDelays`] passed alongside the hooks is the same trace's
    /// totals view; schemes that only wait on totals can ignore this.
    pub trace: &'a RoundTrace,
    /// This round's participation roster: `None` when the full fixed
    /// fleet participates (slot index == global client index — the
    /// historical behaviour), `Some(roster)` when the engine sampled a
    /// k-of-N roster. `roster[slot]` is the global fleet index of the
    /// client in delay/request slot `slot`; rosters are sorted ascending
    /// and duplicate-free. Schemes index per-client state through
    /// [`RoundCtx::data_shard`] so they stay correct under sampling.
    pub roster: Option<&'a [u32]>,
}

impl RoundCtx<'_> {
    /// Number of clients participating this round (the slot count —
    /// `delays.client_t.len()` sees the same value).
    pub fn participants(&self) -> usize {
        match self.roster {
            Some(r) => r.len(),
            None => self.setup.cfg.clients,
        }
    }

    /// Global fleet index of the client in delay/request slot `slot`.
    pub fn fleet_index(&self, slot: usize) -> usize {
        match self.roster {
            Some(r) => r[slot] as usize,
            None => slot,
        }
    }

    /// Training data shard backing slot `slot`. Mega-fleets tile the
    /// `cfg.clients` data shards across the N simulated nodes
    /// (`shard = fleet_index % cfg.clients`), so per-shard state built at
    /// prepare time (masks, loads) stays valid for any roster.
    pub fn data_shard(&self, slot: usize) -> usize {
        self.fleet_index(slot) % self.setup.cfg.clients
    }
}

/// One client gradient the engine executes on the scheme's behalf.
#[derive(Clone, Debug)]
pub struct GradRequest {
    /// Participant slot index in `0..ctx.participants()` (equal to the
    /// global client index when the full fleet participates).
    pub client: usize,
    /// Per-point mask over the client's `local_batch` rows (1.0 = include).
    pub mask: Vec<f32>,
    /// Weight of this gradient in the round aggregate.
    pub scale: f32,
}

impl GradRequest {
    /// A full-batch, unit-scale request (the uncoded common case).
    pub fn full(client: usize, local_batch: usize) -> Self {
        GradRequest { client, mask: vec![1.0; local_batch], scale: 1.0 }
    }
}

/// What to execute this round. Requests run in the order given; keep that
/// order independent of the delay draw (e.g. sorted by client index) if
/// you want bit-identical aggregates across waiting policies — f32
/// addition is not associative.
#[derive(Clone, Debug, Default)]
pub struct RoundPlan {
    pub requests: Vec<GradRequest>,
    /// Simulated wall-clock this round costs under the scheme's waiting
    /// policy (the default [`Scheme::aggregate`] charges exactly this).
    pub round_time: f64,
}

/// The priced outcome of one aggregated round.
#[derive(Clone, Copy, Debug)]
pub struct RoundCost {
    /// Simulated seconds added to the experiment clock.
    pub sim_seconds: f64,
    /// Aggregate data return `m̂` used as the normalisation denominator of
    /// eq. (30). `0.0` means "stochastically complete" and the engine
    /// falls back to the global batch size `m` (naive/coded semantics).
    pub returned: f32,
    /// Which degradation-ladder rung resolved the aggregate (see
    /// `coordinator::engine`). The engine downgrades this to
    /// [`RoundOutcome::Skip`] itself when a degraded-mode round folded
    /// nothing, so schemes only report how *their* aggregation resolved.
    pub outcome: RoundOutcome,
}

/// Execution handle passed to [`Scheme::aggregate`]: lets a scheme run
/// extra gradients against the round's prepared θ (CodedFedL's parity
/// gradient; a hybrid scheme's server-side correction; …).
pub struct RoundExec<'a> {
    rt: &'a Runtime,
    theta: &'a PreparedTheta<'a>,
    grads: &'a [Mat],
}

impl<'a> RoundExec<'a> {
    pub(crate) fn new(rt: &'a Runtime, theta: &'a PreparedTheta<'a>, grads: &'a [Mat]) -> Self {
        RoundExec { rt, theta, grads }
    }

    /// The gradients the engine computed for this round's
    /// [`RoundPlan::requests`], in plan order (`planned_grads()[i]` is
    /// request `i`'s masked gradient, already scaled into `agg` but held
    /// unscaled here). Exact-recovery aggregation reads these to encode
    /// the arrived shards without re-running any gradient.
    pub fn planned_grads(&self) -> &[Mat] {
        self.grads
    }

    /// Masked gradient `X̂ᵀ diag(mask) (X̂θ − Y)` over arbitrary data
    /// against this round's θ.
    pub fn grad(&self, xhat: &Mat, y: &Mat, mask: &[f32]) -> Result<Mat> {
        self.rt.grad_prepared(xhat, y, self.theta, mask)
    }

    /// [`RoundExec::grad`] into a caller-owned `out` (`[q, c]`,
    /// overwritten). Schemes that hold their output buffer across rounds
    /// (e.g. CodedFedL's parity gradient) keep the round loop free of
    /// compute-path allocations this way.
    pub fn grad_into(&self, xhat: &Mat, y: &Mat, mask: &[f32], out: &mut Mat) -> Result<()> {
        self.rt.grad_into(xhat, y, self.theta, mask, out)
    }

    /// The underlying runtime, for schemes that need more than `grad`.
    pub fn runtime(&self) -> &Runtime {
        self.rt
    }
}

/// Reported scheme internals surfaced on
/// [`crate::coordinator::TrainOutcome`] (all optional).
#[derive(Clone, Copy, Debug, Default)]
pub struct SchemeStats {
    /// Optimal deadline t* (CodedFedL).
    pub t_star: Option<f64>,
    /// Redundancy u* — parity rows processed per round (CodedFedL).
    pub u_star: Option<usize>,
    /// One-time parity upload overhead already charged to the clock.
    pub parity_overhead: f64,
}

/// An open aggregation policy. Implementations decide who the server
/// waits for, how arrivals are combined, and what each round costs on the
/// virtual MEC clock; the engine does everything else.
pub trait Scheme {
    /// Human-readable label used for history curves and logs.
    fn label(&self) -> String;

    /// Tag splitting this scheme's RNG streams (delays, generators) off
    /// the experiment seed, so schemes see i.i.d. but reproducible draws.
    /// The built-ins pin the historical tags (101/102/103); the default
    /// derives a stable tag from the label.
    fn rng_tag(&self) -> u64 {
        fnv1a(self.label().as_bytes())
    }

    /// One-time preparation before training. `code_rng` is this scheme's
    /// private generator stream (used by CodedFedL for processed-subset
    /// sampling and generator matrices).
    fn prepare(
        &mut self,
        setup: &FedSetup,
        rt: &Runtime,
        code_rng: &mut Rng,
    ) -> Result<SchemeSetup> {
        let _ = (rt, code_rng);
        Ok(SchemeSetup::uncoded(&setup.cfg))
    }

    /// Decide this round's gradient requests and its simulated cost from
    /// the sampled delays.
    fn plan_round(&mut self, ctx: &RoundCtx, delays: &RoundDelays) -> Result<RoundPlan>;

    /// Finalize the round: optionally run extra gradients through `exec`
    /// and fold them into `agg` (the scaled sum of the planned client
    /// gradients), then price the round. The default charges the planned
    /// `round_time` and declares a stochastically complete return.
    fn aggregate(
        &mut self,
        ctx: &RoundCtx,
        delays: &RoundDelays,
        plan: &RoundPlan,
        exec: &RoundExec,
        agg: &mut Mat,
    ) -> Result<RoundCost> {
        let _ = (ctx, delays, exec, agg);
        Ok(RoundCost {
            sim_seconds: plan.round_time,
            returned: 0.0,
            outcome: RoundOutcome::Full,
        })
    }

    /// Scheme internals worth reporting (deadline, redundancy, overheads).
    fn stats(&self) -> SchemeStats {
        SchemeStats::default()
    }
}

/// FNV-1a, for the default label-derived RNG tag.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Closed, serialisable description of the built-in schemes — the form the
/// CLI, TOML files and benches speak. `parse` accepts `naive`,
/// `greedy[:psi=ψ]` and `coded[:delta=δ]`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SchemeSpec {
    /// Server waits for *all* client updates.
    NaiveUncoded,
    /// Server waits for the first `(1-ψ)·n` client updates.
    GreedyUncoded { psi: f64 },
    /// CodedFedL with redundancy `δ = u_max / m`.
    Coded { delta: f64 },
}

impl SchemeSpec {
    pub fn label(&self) -> String {
        match self {
            SchemeSpec::NaiveUncoded => "naive".into(),
            SchemeSpec::GreedyUncoded { psi } => format!("greedy(psi={psi})"),
            SchemeSpec::Coded { delta } => format!("coded(delta={delta})"),
        }
    }

    /// Parse a scheme string: `naive`, `greedy`, `greedy:psi=0.2`,
    /// `coded`, `coded:delta=0.1`.
    pub fn parse(s: &str) -> Result<SchemeSpec, String> {
        let (name, params) = match s.split_once(':') {
            Some((n, p)) => (n.trim(), Some(p)),
            None => (s.trim(), None),
        };
        let kv = |expected_key: &str, default: f64| -> Result<f64, String> {
            let Some(p) = params else { return Ok(default) };
            let (k, v) = p
                .split_once('=')
                .ok_or_else(|| format!("scheme {name:?}: expected {expected_key}=<value>, got {p:?}"))?;
            if k.trim() != expected_key {
                return Err(format!(
                    "scheme {name:?}: unknown parameter {:?} (expected {expected_key})",
                    k.trim()
                ));
            }
            v.trim()
                .parse::<f64>()
                .map_err(|e| format!("scheme {name:?}: {expected_key}: {e}"))
        };
        match name {
            "naive" => match params {
                None => Ok(SchemeSpec::NaiveUncoded),
                Some(p) => Err(format!("scheme \"naive\" takes no parameters, got {p:?}")),
            },
            "greedy" => Ok(SchemeSpec::GreedyUncoded { psi: kv("psi", 0.1)? }),
            "coded" => Ok(SchemeSpec::Coded { delta: kv("delta", 0.1)? }),
            other => Err(format!(
                "unknown scheme {other:?} (expected naive | greedy[:psi=ψ] | coded[:delta=δ])"
            )),
        }
    }

    /// Instantiate the described scheme.
    pub fn build(&self) -> Box<dyn Scheme> {
        match *self {
            SchemeSpec::NaiveUncoded => Box::new(NaiveUncoded::new()),
            SchemeSpec::GreedyUncoded { psi } => Box::new(GreedyUncoded::new(psi)),
            SchemeSpec::Coded { delta } => Box::new(CodedFedL::new(delta)),
        }
    }
}

impl std::str::FromStr for SchemeSpec {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        SchemeSpec::parse(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_labels() {
        assert_eq!(SchemeSpec::NaiveUncoded.label(), "naive");
        assert_eq!(SchemeSpec::GreedyUncoded { psi: 0.1 }.label(), "greedy(psi=0.1)");
        assert_eq!(SchemeSpec::Coded { delta: 0.2 }.label(), "coded(delta=0.2)");
    }

    #[test]
    fn spec_parse_roundtrip() {
        assert_eq!(SchemeSpec::parse("naive").unwrap(), SchemeSpec::NaiveUncoded);
        assert_eq!(
            SchemeSpec::parse("greedy").unwrap(),
            SchemeSpec::GreedyUncoded { psi: 0.1 }
        );
        assert_eq!(
            SchemeSpec::parse("greedy:psi=0.25").unwrap(),
            SchemeSpec::GreedyUncoded { psi: 0.25 }
        );
        assert_eq!(
            SchemeSpec::parse("coded:delta=0.3").unwrap(),
            SchemeSpec::Coded { delta: 0.3 }
        );
        assert_eq!("coded".parse::<SchemeSpec>().unwrap(), SchemeSpec::Coded { delta: 0.1 });
    }

    #[test]
    fn spec_parse_rejects_garbage() {
        assert!(SchemeSpec::parse("fancy").is_err());
        assert!(SchemeSpec::parse("naive:psi=0.1").is_err());
        assert!(SchemeSpec::parse("greedy:delta=0.1").is_err());
        assert!(SchemeSpec::parse("coded:delta=lots").is_err());
        let e = SchemeSpec::parse("greedy:psi").unwrap_err();
        assert!(e.contains("psi"), "{e}");
    }

    #[test]
    fn built_schemes_carry_matching_labels_and_tags() {
        let specs = [
            SchemeSpec::NaiveUncoded,
            SchemeSpec::GreedyUncoded { psi: 0.2 },
            SchemeSpec::Coded { delta: 0.3 },
        ];
        let mut tags = Vec::new();
        for spec in specs {
            let scheme = spec.build();
            assert_eq!(scheme.label(), spec.label());
            tags.push(scheme.rng_tag());
        }
        // Historical stream tags, pinned for seed-for-seed reproducibility
        // with the pre-trait trainer.
        assert_eq!(tags, vec![101, 102, 103]);
    }

    #[test]
    fn default_rng_tag_is_stable_and_label_dependent() {
        struct Custom(&'static str);
        impl Scheme for Custom {
            fn label(&self) -> String {
                self.0.into()
            }
            fn plan_round(&mut self, _: &RoundCtx, _: &RoundDelays) -> Result<RoundPlan> {
                Ok(RoundPlan::default())
            }
        }
        assert_eq!(Custom("a").rng_tag(), Custom("a").rng_tag());
        assert_ne!(Custom("a").rng_tag(), Custom("b").rng_tag());
    }
}
