//! Greedy uncoded aggregation: wait for the fastest `(1−ψ)n` clients.

use anyhow::Result;

use super::{GradRequest, RoundCost, RoundCtx, RoundExec, RoundPlan, Scheme};
use crate::metrics::RoundOutcome;
use crate::sim::{KthScratch, RoundDelays};
use crate::tensor::Mat;

/// The paper's straggler-dropping baseline (§V-A): each round the server
/// keeps only the fastest `k = (1−ψ)n` updates, so the round costs the
/// k-th order statistic and the stragglers' gradients are *discarded* —
/// which is what starves whole classes under non-IID sharding (§V-B).
#[derive(Clone, Debug)]
pub struct GreedyUncoded {
    psi: f64,
    /// Reused top-k selection buffers — keeps the warm round loop free of
    /// selection allocations at any fleet size.
    scratch: KthScratch,
}

impl GreedyUncoded {
    /// `psi` is the drop fraction in `[0, 1)`; `psi = 0` degenerates to
    /// naive uncoded (same aggregate, same per-round winners set).
    pub fn new(psi: f64) -> Self {
        GreedyUncoded { psi, scratch: KthScratch::default() }
    }

    pub fn psi(&self) -> f64 {
        self.psi
    }

    fn k(&self, n: usize) -> usize {
        (((1.0 - self.psi) * n as f64).round() as usize).clamp(1, n)
    }
}

impl Scheme for GreedyUncoded {
    fn label(&self) -> String {
        format!("greedy(psi={})", self.psi)
    }

    fn rng_tag(&self) -> u64 {
        102
    }

    fn plan_round(&mut self, ctx: &RoundCtx, delays: &RoundDelays) -> Result<RoundPlan> {
        let cfg = &ctx.setup.cfg;
        // Scenario-dropped clients carry infinite delays: they sort after
        // every finite one and can never be winners, so k is clamped to
        // the clients actually reachable this round (no-op under the
        // static scenario). A round with nobody reachable contributes
        // nothing — the built-in scenarios guarantee at least one client.
        let present = delays.present_count();
        if present == 0 {
            return Ok(RoundPlan { requests: Vec::new(), round_time: 0.0 });
        }
        // k is a fraction of this round's participant slots (== n on the
        // full fixed fleet); the streaming selection touches each arrival
        // once instead of sorting the whole fleet.
        let (t_k, winners) = delays
            .kth_fastest_into(self.k(ctx.participants()).min(present), &mut self.scratch)
            .map_err(anyhow::Error::msg)?;
        // The selection returns winners sorted by arrival; requests run in
        // client order, not arrival order: the aggregate's f32 rounding
        // then depends only on the winner *set*, making greedy(ψ=0)
        // bit-identical to naive on the same setup. This is a deliberate
        // low-bit deviation from the pre-trait trainer, which summed
        // winners in arrival order; delay draws, winner sets and round
        // times are unchanged.
        let mut requests: Vec<GradRequest> = winners
            .iter()
            .map(|&j| GradRequest::full(j, cfg.local_batch))
            .collect();
        requests.sort_unstable_by_key(|r| r.client);
        Ok(RoundPlan { requests, round_time: t_k })
    }

    fn aggregate(
        &mut self,
        ctx: &RoundCtx,
        _delays: &RoundDelays,
        plan: &RoundPlan,
        _exec: &RoundExec,
        _agg: &mut Mat,
    ) -> Result<RoundCost> {
        // Normalise by the *actual* aggregate return (1−ψ)m — greedy's
        // discards are real data loss, not stochastic shortfall.
        let returned = (plan.requests.len() * ctx.setup.cfg.local_batch) as f32;
        // Greedy *plans* to fold only k winners: reaching its own k is its
        // full outcome; fewer (deadline/fault losses past the plan) is a
        // partial fold.
        let outcome = if plan.requests.len() >= self.k(ctx.participants()) {
            RoundOutcome::Full
        } else {
            RoundOutcome::PartialFold
        };
        Ok(RoundCost { sim_seconds: plan.round_time, returned, outcome })
    }
}
