//! Naive uncoded aggregation: wait for every client, every round.

use anyhow::Result;

use super::{GradRequest, RoundCtx, RoundPlan, Scheme};
use crate::sim::RoundDelays;

/// The paper's baseline (§V-A): the server waits for all `n` updates, so a
/// round costs `max_j T_j` — one straggler prices the whole fleet. The
/// aggregate is stochastically complete, so the default
/// [`Scheme::aggregate`] (cost = planned time, denominator = m) applies
/// as-is; this is also the minimal-surface reference implementation of the
/// trait: `label` + `plan_round` and nothing else.
#[derive(Clone, Copy, Debug, Default)]
pub struct NaiveUncoded;

impl NaiveUncoded {
    pub fn new() -> Self {
        NaiveUncoded
    }
}

impl Scheme for NaiveUncoded {
    fn label(&self) -> String {
        "naive".into()
    }

    fn rng_tag(&self) -> u64 {
        101
    }

    fn plan_round(&mut self, ctx: &RoundCtx, delays: &RoundDelays) -> Result<RoundPlan> {
        let cfg = &ctx.setup.cfg;
        let requests = (0..cfg.clients)
            .map(|j| GradRequest::full(j, cfg.local_batch))
            .collect();
        Ok(RoundPlan { requests, round_time: delays.max_client_time() })
    }
}
