//! Naive uncoded aggregation: wait for every reachable client, every
//! round.

use anyhow::Result;

use super::{GradRequest, RoundCost, RoundCtx, RoundExec, RoundPlan, Scheme};
use crate::metrics::RoundOutcome;
use crate::sim::RoundDelays;
use crate::tensor::Mat;

/// The paper's baseline (§V-A): the server waits for all `n` updates, so a
/// round costs `max_j T_j` — one straggler prices the whole fleet. Under a
/// non-static scenario, clients the round dropped (infinite delay) are
/// excluded: the server knows they are unreachable, waits only for the
/// present ones, and normalises by the data that actually returned — on
/// the default `static` scenario that denominator is exactly `m`,
/// reproducing the historical behaviour bit-for-bit.
#[derive(Clone, Copy, Debug, Default)]
pub struct NaiveUncoded;

impl NaiveUncoded {
    pub fn new() -> Self {
        NaiveUncoded
    }
}

impl Scheme for NaiveUncoded {
    fn label(&self) -> String {
        "naive".into()
    }

    fn rng_tag(&self) -> u64 {
        101
    }

    fn plan_round(&mut self, ctx: &RoundCtx, delays: &RoundDelays) -> Result<RoundPlan> {
        let cfg = &ctx.setup.cfg;
        // Iterate the round's participant slots (== `cfg.clients` on the
        // full fixed fleet, k under sampled participation).
        let requests = (0..ctx.participants())
            .filter(|&j| delays.is_present(j))
            .map(|j| GradRequest::full(j, cfg.local_batch))
            .collect();
        Ok(RoundPlan { requests, round_time: delays.max_client_time() })
    }

    fn aggregate(
        &mut self,
        ctx: &RoundCtx,
        _delays: &RoundDelays,
        plan: &RoundPlan,
        _exec: &RoundExec,
        _agg: &mut Mat,
    ) -> Result<RoundCost> {
        // Normalise by the actual aggregate return: with everyone present
        // this is exactly m (identical to the historical m-denominator);
        // under scenario dropout the absent clients' data really is
        // missing from the round, mirroring greedy's discard pricing.
        let returned = (plan.requests.len() * ctx.setup.cfg.local_batch) as f32;
        let outcome = if plan.requests.len() == ctx.participants() {
            RoundOutcome::Full
        } else {
            RoundOutcome::PartialFold
        };
        Ok(RoundCost { sim_seconds: plan.round_time, returned, outcome })
    }
}
