//! Samplers for the distributions the paper's models need:
//! normal (generator matrices, RFF frequencies), exponential (stochastic
//! compute time, eq. 11), geometric (retransmission counts, eq. 13),
//! Rademacher (the paper's ±1 generator alternative) and uniform phases.

use super::Rng;

impl Rng {
    /// Standard normal via Box–Muller (both values used through the cache
    /// in [`NormalSource`]; this single-value form regenerates each call).
    pub fn next_normal(&mut self) -> f64 {
        // Draw u in (0,1] to avoid ln(0).
        let u = 1.0 - self.next_f64();
        let v = self.next_f64();
        (-2.0 * u.ln()).sqrt() * (2.0 * std::f64::consts::PI * v).cos()
    }

    /// Exponential with rate `lambda` (mean `1/lambda`).
    pub fn next_exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        let u = 1.0 - self.next_f64();
        -u.ln() / lambda
    }

    /// Geometric number of trials until first success, support `{1, 2, …}`,
    /// success probability `1 - p_fail` (the paper parameterises links by
    /// the erasure probability `p_j`; `P(N = x) = p^(x-1) (1-p)`, eq. 13).
    pub fn next_geometric_trials(&mut self, p_fail: f64) -> u64 {
        debug_assert!((0.0..1.0).contains(&p_fail));
        if p_fail == 0.0 {
            return 1;
        }
        // Inverse CDF: N = ceil(ln(1-u) / ln(p_fail)).
        let u = self.next_f64();
        let n = ((1.0 - u).ln() / p_fail.ln()).ceil();
        n.max(1.0) as u64
    }

    /// Rademacher ±1.
    pub fn next_rademacher(&mut self) -> f32 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Fill a buffer with i.i.d. standard normals (f32).
    pub fn fill_normal_f32(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.next_normal() as f32;
        }
    }

    /// Fill a buffer with i.i.d. normals scaled by `sigma` (f32).
    pub fn fill_normal_scaled_f32(&mut self, out: &mut [f32], sigma: f64) {
        for v in out.iter_mut() {
            *v = (self.next_normal() * sigma) as f32;
        }
    }

    /// Fill with i.i.d. Rademacher ±1 (f32).
    pub fn fill_rademacher_f32(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.next_rademacher();
        }
    }

    /// Fill with `Uniform(0, 2π]` phases (f32) for the RFF map (eq. 18).
    pub fn fill_uniform_phase_f32(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = ((1.0 - self.next_f64()) * 2.0 * std::f64::consts::PI) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn moments(xs: &[f64]) -> (f64, f64) {
        let n = xs.len() as f64;
        let mean = xs.iter().sum::<f64>() / n;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
        (mean, var)
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from(1);
        let xs: Vec<f64> = (0..50_000).map(|_| r.next_normal()).collect();
        let (m, v) = moments(&xs);
        assert!(m.abs() < 0.02, "mean {m}");
        assert!((v - 1.0).abs() < 0.03, "var {v}");
    }

    #[test]
    fn exponential_moments() {
        let mut r = Rng::seed_from(2);
        let lam = 2.5;
        let xs: Vec<f64> = (0..50_000).map(|_| r.next_exponential(lam)).collect();
        let (m, v) = moments(&xs);
        assert!((m - 1.0 / lam).abs() < 0.01, "mean {m}");
        assert!((v - 1.0 / (lam * lam)).abs() < 0.02, "var {v}");
    }

    #[test]
    fn exponential_nonnegative() {
        let mut r = Rng::seed_from(3);
        assert!((0..1000).all(|_| r.next_exponential(0.1) >= 0.0));
    }

    #[test]
    fn geometric_mean_matches() {
        let mut r = Rng::seed_from(4);
        let p_fail = 0.3;
        let xs: Vec<f64> = (0..50_000)
            .map(|_| r.next_geometric_trials(p_fail) as f64)
            .collect();
        let (m, _) = moments(&xs);
        let expect = 1.0 / (1.0 - p_fail);
        assert!((m - expect).abs() < 0.02, "mean {m} vs {expect}");
        assert!(xs.iter().all(|&x| x >= 1.0));
    }

    #[test]
    fn geometric_reliable_link_is_one_shot() {
        let mut r = Rng::seed_from(5);
        assert!((0..100).all(|_| r.next_geometric_trials(0.0) == 1));
    }

    #[test]
    fn rademacher_balanced() {
        let mut r = Rng::seed_from(6);
        let sum: f32 = (0..40_000).map(|_| r.next_rademacher()).sum();
        assert!(sum.abs() < 600.0, "sum {sum}");
    }

    #[test]
    fn phases_in_range() {
        let mut r = Rng::seed_from(7);
        let mut buf = vec![0.0f32; 1000];
        r.fill_uniform_phase_f32(&mut buf);
        assert!(buf
            .iter()
            .all(|&p| p > 0.0 && p <= 2.0 * std::f32::consts::PI + 1e-6));
    }
}
