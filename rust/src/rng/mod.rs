//! Deterministic pseudo-randomness for every stochastic object in the
//! system: generator matrices `G_j`, RFF frequencies/phases, non-IID shard
//! permutations and the per-round delay draws.
//!
//! The `rand` crate is unavailable in this offline environment, so the
//! substrate is built in-tree: SplitMix64 for seeding/stream-splitting and
//! xoshiro256** as the workhorse generator (public-domain algorithms by
//! Blackman & Vigna). Every consumer derives its stream from a single
//! experiment seed via [`Rng::split`], so runs are exactly reproducible.

mod dist; // samplers are inherent methods on `Rng`

/// SplitMix64 step — used for seeding and stream derivation.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** pseudo-random generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed from a single `u64` via SplitMix64 (never yields the all-zero
    /// state).
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream tagged by `label`.
    ///
    /// Children with distinct labels (or from distinct parents) are
    /// statistically independent for all practical purposes; the scheme is
    /// `child_seed = splitmix64(parent_draw ^ label)`.
    pub fn split(&mut self, label: u64) -> Rng {
        let base = self.next_u64() ^ label.wrapping_mul(0xA24B_AED4_963E_E407);
        Rng::seed_from(base)
    }

    /// Counter-based (indexable) stream derivation: the generator for
    /// `(base, index)` is a pure function of its arguments — no parent
    /// state is consumed — so any index's stream can be constructed
    /// directly without replaying the indices before it. Used for
    /// per-round participation draws, where round `r`'s roster must be
    /// reachable in O(1) at any fleet size.
    ///
    /// The mixing is one SplitMix64 step over `base` xor a
    /// Weyl-multiplied `index` (the same odd constant [`Rng::split`]
    /// uses), feeding the usual four-draw seeding, so distinct indices
    /// land in statistically independent states.
    pub fn indexed(base: u64, index: u64) -> Rng {
        let mut sm = base ^ index.wrapping_mul(0xA24B_AED4_963E_E407);
        Rng::seed_from(splitmix64(&mut sm))
    }

    /// The generator's current internal state, for checkpointing. A
    /// generator rebuilt by [`Rng::from_state`] continues the stream from
    /// exactly this position.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator at a previously captured [`Rng::state`]
    /// position. The caller owns validity: an all-zero state never occurs
    /// in practice (seeding forbids it) but would yield a stuck stream.
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        ((self.next_u64() >> 11) as f64) * (1.0 / ((1u64 << 53) as f64))
    }

    /// Uniform in `[0, 1)` as `f32`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection-free enough for
    /// our n << 2^64 use).
    #[inline]
    pub fn next_below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % (n as u64)) as usize
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A uniformly random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::seed_from(42);
        let mut b = Rng::seed_from(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from(1);
        let mut b = Rng::seed_from(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn split_streams_are_independent_and_reproducible() {
        let mut root1 = Rng::seed_from(7);
        let mut root2 = Rng::seed_from(7);
        let mut c1 = root1.split(11);
        let mut c2 = root2.split(11);
        for _ in 0..32 {
            assert_eq!(c1.next_u64(), c2.next_u64());
        }
        let mut d1 = root1.split(12);
        let matches = (0..64).filter(|_| c1.next_u64() == d1.next_u64()).count();
        assert!(matches < 2);
    }

    #[test]
    fn indexed_streams_are_pure_functions_of_base_and_index() {
        // Same (base, index) ⇒ identical stream, regardless of what else
        // was constructed in between (no hidden parent state).
        let mut a = Rng::indexed(0xFEED, 17);
        let _unrelated = Rng::indexed(0xFEED, 3);
        let mut b = Rng::indexed(0xFEED, 17);
        for _ in 0..32 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // Distinct indices (and distinct bases) decorrelate.
        let mut c = Rng::indexed(0xFEED, 18);
        let mut d = Rng::indexed(0xFEED ^ 1, 17);
        let mut e = Rng::indexed(0xFEED, 17);
        let same_idx = (0..64).filter(|_| e.next_u64() == c.next_u64()).count();
        assert!(same_idx < 2);
        let mut f = Rng::indexed(0xFEED, 17);
        let same_base = (0..64).filter(|_| f.next_u64() == d.next_u64()).count();
        assert!(same_base < 2);
    }

    #[test]
    fn state_roundtrip_resumes_the_stream_exactly() {
        let mut a = Rng::seed_from(77);
        for _ in 0..13 {
            a.next_u64();
        }
        let snap = a.state();
        let ahead: Vec<u64> = (0..32).map(|_| a.next_u64()).collect();
        let mut resumed = Rng::from_state(snap);
        let resumed_ahead: Vec<u64> = (0..32).map(|_| resumed.next_u64()).collect();
        assert_eq!(ahead, resumed_ahead);
    }

    #[test]
    fn uniform_unit_interval() {
        let mut r = Rng::seed_from(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn next_below_in_range_and_covers() {
        let mut r = Rng::seed_from(9);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            let v = r.next_below(7);
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::seed_from(5);
        let p = r.permutation(100);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
