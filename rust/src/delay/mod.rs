//! The paper's MEC compute + communication delay substrate (§II-B).
//!
//! Per node `j` the one-epoch execution time is (eqs. 11–14)
//!
//! ```text
//! T_j = ℓ̃/μ  +  Exp(αμ/ℓ̃)  +  τ · (N_down + N_up)
//! ```
//!
//! with `N_down, N_up ~ Geometric(1 - p)` i.i.d. retransmission counts.
//! This module provides exact CDF/mean formulas (Theorem eq. 42 and eq. 15)
//! used by the allocation optimizer, and samplers used by the virtual-clock
//! round simulator. The MEC server's computing unit uses the same model
//! with server-grade parameters (§III-C).
//!
//! τ is **per-leg and payload-priced**: each leg's per-packet time is
//! `b_leg / (ηW)` where `b_leg` is the *modelled bytes that leg actually
//! carries* — the θ broadcast on the downlink, the (possibly
//! codec-compressed) gradient on the uplink — not a fixed shared packet
//! size. The `[comm]` payload model ([`crate::comm::PayloadModel`],
//! applied in [`crate::topology::FleetSpec::apply_payload`]) scales the
//! two legs' τs independently; with the default `codec = "none"` both
//! scales are exactly 1.0 and the arithmetic below is bit-identical to
//! the historical fixed-payload pricing.

pub mod asymmetric;

use crate::rng::Rng;

/// One epoch delay decomposed into its §II-B legs: the downlink wait for
/// θ (`τ_d·N_down`), the deterministic + stochastic compute parts, and
/// the uplink wait for the gradient (`τ_u·N_up`). Produced by
/// [`NodeParams::sample_legs`] / [`asymmetric::AsymNodeParams::sample_legs`]
/// and consumed by the round timeline ([`crate::sim::timeline`]), which
/// turns the legs into ordered completion events.
///
/// The raw draws (`N_down`, `N_up`, the exponential compute part) are
/// stored instead of pre-summed times so [`DelayLegs::total`] can
/// reproduce the historical one-shot `sample_delay` arithmetic
/// bit-for-bit — f64 addition is not associative, and seeded histories
/// are pinned on the old grouping.
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub struct DelayLegs {
    /// Downlink retransmission count `N_down ≥ 1` (eq. 13).
    pub n_down: u64,
    /// Uplink retransmission count `N_up ≥ 1`.
    pub n_up: u64,
    /// Deterministic compute time `ℓ̃/μ` (eq. 11).
    pub compute_det: f64,
    /// Stochastic compute draw `~ Exp(αμ/ℓ̃)` (0 when `ℓ̃ = 0`).
    pub compute_stoch: f64,
    /// Downlink per-packet time (τ in the reciprocal model).
    pub tau_down: f64,
    /// Uplink per-packet time.
    pub tau_up: f64,
}

impl DelayLegs {
    /// Time to receive θ: `τ_d · N_down`.
    pub fn downlink_time(&self) -> f64 {
        self.tau_down * self.n_down as f64
    }

    /// Time to deliver the gradient: `τ_u · N_up`.
    pub fn uplink_time(&self) -> f64 {
        self.tau_up * self.n_up as f64
    }

    /// Local compute time (deterministic + stochastic parts).
    pub fn compute_time(&self) -> f64 {
        self.compute_det + self.compute_stoch
    }

    /// Total epoch delay `T` (eq. 11). With reciprocal links
    /// (`tau_down` bitwise equal to `tau_up`) this evaluates the
    /// historical `det + stoch + τ·(N_down + N_up)` grouping exactly, so
    /// legs-based sampling reproduces pre-timeline delay draws
    /// bit-for-bit; per-leg τs use the asymmetric grouping
    /// `det + stoch + τ_d·N_down + τ_u·N_up`.
    pub fn total(&self) -> f64 {
        if self.tau_down.to_bits() == self.tau_up.to_bits() {
            self.compute_det
                + self.compute_stoch
                + self.tau_down * (self.n_down + self.n_up) as f64
        } else {
            self.compute_det + self.compute_stoch + self.downlink_time() + self.uplink_time()
        }
    }
}

/// Retransmission budget implied by a deadline `t` (Theorem / eq. 42):
/// the shape [`NodeParams::nu_max`] hands the CDF, replacing the old
/// `Option<u64>` whose `Some(u64::MAX)` sentinel leaked τ = 0 semantics
/// to every caller.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NuMax {
    /// `τ = 0`: links are free, the retransmission count never binds and
    /// only the compute legs limit completion.
    Unbounded,
    /// The largest feasible total `ν_m ≥ 2` with `t − τ·ν_m > 0` and
    /// `t − τ·(ν_m + 1) ≤ 0`.
    Bounded(u64),
    /// Even `ν = 2` (one downlink + one uplink packet) cannot complete:
    /// `t ≤ 2τ`.
    Infeasible,
}

impl NuMax {
    /// The bound, when one exists (`Unbounded`/`Infeasible` ⇒ `None`).
    pub fn bounded(self) -> Option<u64> {
        match self {
            NuMax::Bounded(v) => Some(v),
            _ => None,
        }
    }
}

/// Stochastic parameters of one node (client or MEC computing unit).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NodeParams {
    /// Deterministic data-processing rate μ (data points / second).
    pub mu: f64,
    /// Compute-to-memory-access ratio α (> 0); the stochastic compute part
    /// is `Exp(αμ/ℓ̃)`, i.e. mean `ℓ̃/(αμ)`.
    pub alpha: f64,
    /// Per-packet transmission time τ = b / (ηW) seconds, where `b` is
    /// the leg's modelled payload bytes. In the symmetric reciprocal
    /// model one τ serves both legs (equal payloads); under a `[comm]`
    /// codec the fleet prices each leg's τ from the bytes it carries
    /// (see [`crate::delay::asymmetric::AsymNodeParams`]).
    pub tau: f64,
    /// Wireless erasure probability `p ∈ [0, 1)`; `p = 0` models the AWGN
    /// special case (one reliable transmission).
    pub p: f64,
}

impl NodeParams {
    pub fn validate(&self) -> Result<(), String> {
        if !(self.mu > 0.0) {
            return Err(format!("mu must be > 0, got {}", self.mu));
        }
        if !(self.alpha > 0.0) {
            return Err(format!("alpha must be > 0, got {}", self.alpha));
        }
        if !(self.tau >= 0.0) {
            return Err(format!("tau must be >= 0, got {}", self.tau));
        }
        if !(0.0..1.0).contains(&self.p) {
            return Err(format!("p must be in [0,1), got {}", self.p));
        }
        Ok(())
    }

    /// Mean epoch delay, eq. (15):
    /// `E[T] = (ℓ̃/μ)(1 + 1/α) + 2τ/(1-p)`.
    pub fn mean_delay(&self, ell: f64) -> f64 {
        (ell / self.mu) * (1.0 + 1.0 / self.alpha) + 2.0 * self.tau / (1.0 - self.p)
    }

    /// Retransmission budget at deadline `t`: `Bounded(ν_m)` with
    /// `t - τ ν_m > 0` and `t - τ(ν_m + 1) ≤ 0`; `Infeasible` when even
    /// `ν = 2` (one down + one up) cannot complete, i.e. `t ≤ 2τ`; and
    /// `Unbounded` when `τ = 0` (free links — the count never binds).
    pub fn nu_max(&self, t: f64) -> NuMax {
        if self.tau == 0.0 {
            return if t > 0.0 { NuMax::Unbounded } else { NuMax::Infeasible };
        }
        let x = t / self.tau;
        // ν_m = ceil(x) - 1, adjusted for exact multiples.
        let nu = if (x - x.round()).abs() < 1e-12 {
            x.round() as i64 - 1
        } else {
            x.floor() as i64
        };
        if nu >= 2 {
            NuMax::Bounded(nu as u64)
        } else {
            NuMax::Infeasible
        }
    }

    /// Exact CDF `P(T ≤ t)` for processed load `ℓ̃` (Theorem / eq. 42).
    ///
    /// `ℓ̃ = 0` is the limit where compute time vanishes and only the two
    /// communication legs remain.
    pub fn cdf(&self, t: f64, ell: f64) -> f64 {
        assert!(ell >= 0.0);
        if t <= 0.0 {
            return 0.0;
        }
        let nu_m = match self.nu_max(t) {
            NuMax::Infeasible => return 0.0,
            NuMax::Unbounded => {
                // τ = 0, pure compute: P(ℓ/μ + Exp(αμ/ℓ) ≤ t).
                let det = ell / self.mu;
                if t <= det {
                    return 0.0;
                }
                if ell == 0.0 {
                    return 1.0;
                }
                let gamma = self.alpha * self.mu / ell;
                return 1.0 - (-(gamma) * (t - det)).exp();
            }
            NuMax::Bounded(v) => v,
        };
        let det = ell / self.mu;
        let q = 1.0 - self.p;
        let mut sum = 0.0;
        // P(N_com = ν) = (ν-1)(1-p)² p^(ν-2), ν ≥ 2 (NB(2, 1-p)).
        let mut pmf_tail = q * q; // p^(ν-2) factor accumulates below
        for nu in 2..=nu_m {
            let slack = t - det - self.tau * nu as f64;
            if slack <= 0.0 {
                // Larger ν only shrinks slack further.
                break;
            }
            let h = (nu - 1) as f64 * pmf_tail;
            let f = if ell == 0.0 {
                1.0
            } else {
                let gamma = self.alpha * self.mu / ell;
                1.0 - (-gamma * slack).exp()
            };
            sum += h * f;
            pmf_tail *= self.p;
            if pmf_tail < 1e-300 {
                break;
            }
        }
        sum.clamp(0.0, 1.0)
    }

    /// Draw one epoch's per-leg delays for processed load `ℓ̃`
    /// (eqs. 11–14). The RNG sequence — the exponential compute draw
    /// (skipped at `ℓ̃ = 0`), then the downlink and uplink retransmission
    /// counts — is exactly the historical [`NodeParams::sample_delay`]
    /// sequence, so legs-based and one-shot sampling are interchangeable
    /// without perturbing seeded runs.
    pub fn sample_legs(&self, ell: f64, rng: &mut Rng) -> DelayLegs {
        let compute_det = ell / self.mu;
        let compute_stoch = if ell == 0.0 {
            0.0
        } else {
            rng.next_exponential(self.alpha * self.mu / ell)
        };
        let n_down = rng.next_geometric_trials(self.p);
        let n_up = rng.next_geometric_trials(self.p);
        DelayLegs {
            n_down,
            n_up,
            compute_det,
            compute_stoch,
            tau_down: self.tau,
            tau_up: self.tau,
        }
    }

    /// Draw one epoch delay `T` for processed load `ℓ̃` (eqs. 11–14): the
    /// sum over the sampled legs ([`DelayLegs::total`], which preserves
    /// the historical summation order bit-for-bit).
    pub fn sample_delay(&self, ell: f64, rng: &mut Rng) -> f64 {
        self.sample_legs(ell, rng).total()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn node() -> NodeParams {
        NodeParams { mu: 2.0, alpha: 20.0, tau: 3f64.sqrt(), p: 0.9 }
    }

    #[test]
    fn validate_catches_bad_params() {
        assert!(node().validate().is_ok());
        assert!(NodeParams { mu: 0.0, ..node() }.validate().is_err());
        assert!(NodeParams { alpha: -1.0, ..node() }.validate().is_err());
        assert!(NodeParams { p: 1.0, ..node() }.validate().is_err());
        assert!(NodeParams { tau: -0.1, ..node() }.validate().is_err());
    }

    #[test]
    fn nu_max_brackets_t() {
        let n = node();
        // paper: ν_m satisfies t - τν_m > 0 and t - τ(ν_m+1) <= 0.
        for &t in &[3.5, 5.2, 10.0, 17.32, 100.0] {
            match n.nu_max(t) {
                NuMax::Bounded(nu) => {
                    assert!(t - n.tau * nu as f64 > 0.0);
                    assert!(t - n.tau * (nu + 1) as f64 <= 1e-9);
                }
                NuMax::Infeasible => assert!(t <= 2.0 * n.tau + 1e-12),
                NuMax::Unbounded => panic!("tau > 0 can never be Unbounded"),
            }
        }
    }

    #[test]
    fn nu_max_tau_zero_is_unbounded_not_a_sentinel() {
        // Regression: τ = 0 used to smuggle `Some(u64::MAX)` through the
        // Option shape; it is now an explicit variant the CDF handles.
        let n = NodeParams { mu: 2.0, alpha: 2.0, tau: 0.0, p: 0.0 };
        assert_eq!(n.nu_max(1.0), NuMax::Unbounded);
        assert_eq!(n.nu_max(1e-9), NuMax::Unbounded);
        assert_eq!(n.nu_max(0.0), NuMax::Infeasible);
        assert_eq!(n.nu_max(-3.0), NuMax::Infeasible);
        assert_eq!(NuMax::Unbounded.bounded(), None);
        assert_eq!(NuMax::Bounded(5).bounded(), Some(5));
        assert_eq!(NuMax::Infeasible.bounded(), None);

        // CDF at τ = 0 stays the pure shifted-exponential compute law.
        let ell = 4.0;
        let det = ell / n.mu;
        assert_eq!(n.cdf(det, ell), 0.0);
        assert_eq!(n.cdf(0.0, ell), 0.0);
        let gamma = n.alpha * n.mu / ell;
        for &dt in &[0.5, 1.0, 3.0] {
            let exact = 1.0 - (-gamma * dt).exp();
            assert!((n.cdf(det + dt, ell) - exact).abs() < 1e-12);
        }
        // Zero load over free links completes instantly after t = 0.
        assert_eq!(n.cdf(0.5, 0.0), 1.0);
    }

    #[test]
    fn sample_legs_total_reproduces_sample_delay_bitwise() {
        let n = node();
        let mut rng_legs = Rng::seed_from(77);
        let mut rng_one = Rng::seed_from(77);
        for i in 0..200 {
            let ell = (i % 7) as f64;
            let legs = n.sample_legs(ell, &mut rng_legs);
            let one = n.sample_delay(ell, &mut rng_one);
            assert_eq!(legs.total().to_bits(), one.to_bits(), "ell={ell}");
            assert!(legs.n_down >= 1 && legs.n_up >= 1);
            assert!(legs.downlink_time() > 0.0 && legs.uplink_time() > 0.0);
            // The legs decompose the total (up to f64 re-association).
            let parts = legs.downlink_time() + legs.compute_time() + legs.uplink_time();
            let tol = 1e-12 * legs.total().abs().max(1.0);
            assert!((parts - legs.total()).abs() <= tol);
        }
    }

    #[test]
    fn cdf_zero_before_two_packets() {
        let n = node();
        assert_eq!(n.cdf(2.0 * n.tau, 1.0), 0.0);
        assert_eq!(n.cdf(0.0, 1.0), 0.0);
        assert_eq!(n.cdf(-5.0, 1.0), 0.0);
    }

    #[test]
    fn cdf_monotone_in_t_and_decreasing_in_ell() {
        let n = node();
        let mut prev = 0.0;
        for i in 1..200 {
            let t = i as f64 * 0.5;
            let c = n.cdf(t, 10.0);
            assert!(c >= prev - 1e-12, "t={t}");
            assert!((0.0..=1.0).contains(&c));
            prev = c;
        }
        // more load => later completion
        assert!(n.cdf(30.0, 5.0) >= n.cdf(30.0, 20.0));
    }

    #[test]
    fn cdf_matches_monte_carlo() {
        let n = NodeParams { mu: 2.0, alpha: 2.0, tau: 1.0, p: 0.3 };
        let mut rng = Rng::seed_from(11);
        let ell = 6.0;
        for &t in &[4.0, 6.0, 9.0] {
            let trials = 60_000;
            let hits = (0..trials)
                .filter(|_| n.sample_delay(ell, &mut rng) <= t)
                .count();
            let emp = hits as f64 / trials as f64;
            let exact = n.cdf(t, ell);
            assert!(
                (emp - exact).abs() < 0.01,
                "t={t}: empirical {emp} vs exact {exact}"
            );
        }
    }

    #[test]
    fn mean_matches_monte_carlo() {
        let n = NodeParams { mu: 4.0, alpha: 2.0, tau: 0.5, p: 0.1 };
        let mut rng = Rng::seed_from(12);
        let ell = 8.0;
        let trials = 60_000;
        let sum: f64 = (0..trials).map(|_| n.sample_delay(ell, &mut rng)).sum();
        let emp = sum / trials as f64;
        let exact = n.mean_delay(ell);
        assert!((emp - exact).abs() / exact < 0.02, "{emp} vs {exact}");
    }

    #[test]
    fn awgn_cdf_shape() {
        // p = 0: exactly ν = 2 packets, shifted exponential beyond 2τ + ℓ/μ.
        let n = NodeParams { mu: 2.0, alpha: 2.0, tau: 1.0, p: 0.0 };
        let ell = 4.0;
        let det = ell / n.mu + 2.0 * n.tau;
        assert_eq!(n.cdf(det, ell), 0.0);
        let gamma = n.alpha * n.mu / ell;
        for &dt in &[0.5, 1.0, 3.0] {
            let exact = 1.0 - (-gamma * dt).exp();
            assert!((n.cdf(det + dt, ell) - exact).abs() < 1e-12);
        }
    }

    #[test]
    fn zero_load_is_comm_only() {
        let n = NodeParams { mu: 2.0, alpha: 2.0, tau: 1.0, p: 0.0 };
        assert_eq!(n.cdf(2.0001, 0.0), 1.0);
        assert_eq!(n.cdf(1.9999, 0.0), 0.0);
    }
}
