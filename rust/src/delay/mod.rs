//! The paper's MEC compute + communication delay substrate (§II-B).
//!
//! Per node `j` the one-epoch execution time is (eqs. 11–14)
//!
//! ```text
//! T_j = ℓ̃/μ  +  Exp(αμ/ℓ̃)  +  τ · (N_down + N_up)
//! ```
//!
//! with `N_down, N_up ~ Geometric(1 - p)` i.i.d. retransmission counts.
//! This module provides exact CDF/mean formulas (Theorem eq. 42 and eq. 15)
//! used by the allocation optimizer, and samplers used by the virtual-clock
//! round simulator. The MEC server's computing unit uses the same model
//! with server-grade parameters (§III-C).

pub mod asymmetric;

use crate::rng::Rng;

/// Stochastic parameters of one node (client or MEC computing unit).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NodeParams {
    /// Deterministic data-processing rate μ (data points / second).
    pub mu: f64,
    /// Compute-to-memory-access ratio α (> 0); the stochastic compute part
    /// is `Exp(αμ/ℓ̃)`, i.e. mean `ℓ̃/(αμ)`.
    pub alpha: f64,
    /// Per-packet transmission time τ = b / (ηW) seconds.
    pub tau: f64,
    /// Wireless erasure probability `p ∈ [0, 1)`; `p = 0` models the AWGN
    /// special case (one reliable transmission).
    pub p: f64,
}

impl NodeParams {
    pub fn validate(&self) -> Result<(), String> {
        if !(self.mu > 0.0) {
            return Err(format!("mu must be > 0, got {}", self.mu));
        }
        if !(self.alpha > 0.0) {
            return Err(format!("alpha must be > 0, got {}", self.alpha));
        }
        if !(self.tau >= 0.0) {
            return Err(format!("tau must be >= 0, got {}", self.tau));
        }
        if !(0.0..1.0).contains(&self.p) {
            return Err(format!("p must be in [0,1), got {}", self.p));
        }
        Ok(())
    }

    /// Mean epoch delay, eq. (15):
    /// `E[T] = (ℓ̃/μ)(1 + 1/α) + 2τ/(1-p)`.
    pub fn mean_delay(&self, ell: f64) -> f64 {
        (ell / self.mu) * (1.0 + 1.0 / self.alpha) + 2.0 * self.tau / (1.0 - self.p)
    }

    /// Largest retransmission total `ν_m` with `t - τ ν_m > 0` and
    /// `t - τ(ν_m + 1) ≤ 0`; `None` when even `ν = 2` (one down + one up)
    /// cannot complete, i.e. `t ≤ 2τ`.
    pub fn nu_max(&self, t: f64) -> Option<u64> {
        if self.tau == 0.0 {
            // No communication cost: unbounded ν is meaningless; model as
            // "links are free" and signal with a large sentinel of 2.
            return if t > 0.0 { Some(u64::MAX) } else { None };
        }
        let x = t / self.tau;
        // ν_m = ceil(x) - 1, adjusted for exact multiples.
        let nu = if (x - x.round()).abs() < 1e-12 {
            x.round() as i64 - 1
        } else {
            x.floor() as i64
        };
        if nu >= 2 {
            Some(nu as u64)
        } else {
            None
        }
    }

    /// Exact CDF `P(T ≤ t)` for processed load `ℓ̃` (Theorem / eq. 42).
    ///
    /// `ℓ̃ = 0` is the limit where compute time vanishes and only the two
    /// communication legs remain.
    pub fn cdf(&self, t: f64, ell: f64) -> f64 {
        assert!(ell >= 0.0);
        if t <= 0.0 {
            return 0.0;
        }
        if self.tau == 0.0 {
            // Pure compute: P(ℓ/μ + Exp(αμ/ℓ) ≤ t).
            let det = ell / self.mu;
            if t <= det {
                return 0.0;
            }
            if ell == 0.0 {
                return 1.0;
            }
            let gamma = self.alpha * self.mu / ell;
            return 1.0 - (-(gamma) * (t - det)).exp();
        }
        let Some(nu_m) = self.nu_max(t) else {
            return 0.0;
        };
        let det = ell / self.mu;
        let q = 1.0 - self.p;
        let mut sum = 0.0;
        // P(N_com = ν) = (ν-1)(1-p)² p^(ν-2), ν ≥ 2 (NB(2, 1-p)).
        let mut pmf_tail = q * q; // p^(ν-2) factor accumulates below
        for nu in 2..=nu_m {
            let slack = t - det - self.tau * nu as f64;
            if slack <= 0.0 {
                // Larger ν only shrinks slack further.
                break;
            }
            let h = (nu - 1) as f64 * pmf_tail;
            let f = if ell == 0.0 {
                1.0
            } else {
                let gamma = self.alpha * self.mu / ell;
                1.0 - (-gamma * slack).exp()
            };
            sum += h * f;
            pmf_tail *= self.p;
            if pmf_tail < 1e-300 {
                break;
            }
        }
        sum.clamp(0.0, 1.0)
    }

    /// Draw one epoch delay `T` for processed load `ℓ̃` (eqs. 11–14).
    pub fn sample_delay(&self, ell: f64, rng: &mut Rng) -> f64 {
        let det = ell / self.mu;
        let stoch = if ell == 0.0 {
            0.0
        } else {
            rng.next_exponential(self.alpha * self.mu / ell)
        };
        let n_down = rng.next_geometric_trials(self.p);
        let n_up = rng.next_geometric_trials(self.p);
        det + stoch + self.tau * (n_down + n_up) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn node() -> NodeParams {
        NodeParams { mu: 2.0, alpha: 20.0, tau: 3f64.sqrt(), p: 0.9 }
    }

    #[test]
    fn validate_catches_bad_params() {
        assert!(node().validate().is_ok());
        assert!(NodeParams { mu: 0.0, ..node() }.validate().is_err());
        assert!(NodeParams { alpha: -1.0, ..node() }.validate().is_err());
        assert!(NodeParams { p: 1.0, ..node() }.validate().is_err());
        assert!(NodeParams { tau: -0.1, ..node() }.validate().is_err());
    }

    #[test]
    fn nu_max_brackets_t() {
        let n = node();
        // paper: ν_m satisfies t - τν_m > 0 and t - τ(ν_m+1) <= 0.
        for &t in &[3.5, 5.2, 10.0, 17.32, 100.0] {
            if let Some(nu) = n.nu_max(t) {
                assert!(t - n.tau * nu as f64 > 0.0);
                assert!(t - n.tau * (nu + 1) as f64 <= 1e-9);
            } else {
                assert!(t <= 2.0 * n.tau + 1e-12);
            }
        }
    }

    #[test]
    fn cdf_zero_before_two_packets() {
        let n = node();
        assert_eq!(n.cdf(2.0 * n.tau, 1.0), 0.0);
        assert_eq!(n.cdf(0.0, 1.0), 0.0);
        assert_eq!(n.cdf(-5.0, 1.0), 0.0);
    }

    #[test]
    fn cdf_monotone_in_t_and_decreasing_in_ell() {
        let n = node();
        let mut prev = 0.0;
        for i in 1..200 {
            let t = i as f64 * 0.5;
            let c = n.cdf(t, 10.0);
            assert!(c >= prev - 1e-12, "t={t}");
            assert!((0.0..=1.0).contains(&c));
            prev = c;
        }
        // more load => later completion
        assert!(n.cdf(30.0, 5.0) >= n.cdf(30.0, 20.0));
    }

    #[test]
    fn cdf_matches_monte_carlo() {
        let n = NodeParams { mu: 2.0, alpha: 2.0, tau: 1.0, p: 0.3 };
        let mut rng = Rng::seed_from(11);
        let ell = 6.0;
        for &t in &[4.0, 6.0, 9.0] {
            let trials = 60_000;
            let hits = (0..trials)
                .filter(|_| n.sample_delay(ell, &mut rng) <= t)
                .count();
            let emp = hits as f64 / trials as f64;
            let exact = n.cdf(t, ell);
            assert!(
                (emp - exact).abs() < 0.01,
                "t={t}: empirical {emp} vs exact {exact}"
            );
        }
    }

    #[test]
    fn mean_matches_monte_carlo() {
        let n = NodeParams { mu: 4.0, alpha: 2.0, tau: 0.5, p: 0.1 };
        let mut rng = Rng::seed_from(12);
        let ell = 8.0;
        let trials = 60_000;
        let sum: f64 = (0..trials).map(|_| n.sample_delay(ell, &mut rng)).sum();
        let emp = sum / trials as f64;
        let exact = n.mean_delay(ell);
        assert!((emp - exact).abs() / exact < 0.02, "{emp} vs {exact}");
    }

    #[test]
    fn awgn_cdf_shape() {
        // p = 0: exactly ν = 2 packets, shifted exponential beyond 2τ + ℓ/μ.
        let n = NodeParams { mu: 2.0, alpha: 2.0, tau: 1.0, p: 0.0 };
        let ell = 4.0;
        let det = ell / n.mu + 2.0 * n.tau;
        assert_eq!(n.cdf(det, ell), 0.0);
        let gamma = n.alpha * n.mu / ell;
        for &dt in &[0.5, 1.0, 3.0] {
            let exact = 1.0 - (-gamma * dt).exp();
            assert!((n.cdf(det + dt, ell) - exact).abs() < 1e-12);
        }
    }

    #[test]
    fn zero_load_is_comm_only() {
        let n = NodeParams { mu: 2.0, alpha: 2.0, tau: 1.0, p: 0.0 };
        assert_eq!(n.cdf(2.0001, 0.0), 1.0);
        assert_eq!(n.cdf(1.9999, 0.0), 0.0);
    }
}
