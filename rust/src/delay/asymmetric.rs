//! Asymmetric downlink/uplink delay model (paper footnote 1: "easy to
//! address" generalisation; §VI future work).
//!
//! The symmetric model assumes reciprocal links; here the two legs have
//! independent packet times and erasure probabilities:
//!
//! ```text
//! T = ℓ̃/μ + Exp(αμ/ℓ̃) + τ_d·N_d + τ_u·N_u,
//! N_d ~ Geometric(1−p_d),  N_u ~ Geometric(1−p_u)  (independent)
//! ```
//!
//! The exact CDF generalises the Theorem's single negative-binomial series
//! to a truncated double series over `(ν_d, ν_u)`.
//!
//! This is also the shape the `[comm]` payload model produces: a codec
//! that shrinks the uplink gradient scales `τ_u` below `τ_d` even on an
//! otherwise-reciprocal fleet ([`crate::topology::FleetSpec::apply_payload`]),
//! and the allocation layer then sees each client through
//! [`AsymNodeParams::reciprocal_surrogate`].

use crate::rng::Rng;

use super::{DelayLegs, NodeParams};

/// Node with direction-dependent link parameters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AsymNodeParams {
    pub mu: f64,
    pub alpha: f64,
    pub tau_down: f64,
    pub tau_up: f64,
    pub p_down: f64,
    pub p_up: f64,
}

impl AsymNodeParams {
    /// The reciprocal special case — must agree with [`NodeParams`].
    pub fn symmetric(n: &NodeParams) -> Self {
        AsymNodeParams {
            mu: n.mu,
            alpha: n.alpha,
            tau_down: n.tau,
            tau_up: n.tau,
            p_down: n.p,
            p_up: n.p,
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        if !(self.mu > 0.0) || !(self.alpha > 0.0) {
            return Err("mu and alpha must be > 0".into());
        }
        if self.tau_down < 0.0 || self.tau_up < 0.0 {
            return Err("tau must be >= 0".into());
        }
        if !(0.0..1.0).contains(&self.p_down) || !(0.0..1.0).contains(&self.p_up) {
            return Err("p must be in [0,1)".into());
        }
        Ok(())
    }

    /// Mean delay: `(ℓ̃/μ)(1+1/α) + τ_d/(1−p_d) + τ_u/(1−p_u)` —
    /// the asymmetric version of eq. (15).
    pub fn mean_delay(&self, ell: f64) -> f64 {
        (ell / self.mu) * (1.0 + 1.0 / self.alpha)
            + self.tau_down / (1.0 - self.p_down)
            + self.tau_up / (1.0 - self.p_up)
    }

    /// Exact CDF `P(T ≤ t)` via the truncated double geometric series.
    pub fn cdf(&self, t: f64, ell: f64) -> f64 {
        if t <= 0.0 {
            return 0.0;
        }
        let det = ell / self.mu;
        let gamma = if ell > 0.0 { self.alpha * self.mu / ell } else { f64::INFINITY };
        let qd = 1.0 - self.p_down;
        let qu = 1.0 - self.p_up;
        let mut sum = 0.0;
        let mut pd_pow = qd; // P(N_d = a) = p_d^(a-1) q_d
        let mut a = 1u64;
        loop {
            let t_after_down = t - det - self.tau_down * a as f64;
            if t_after_down - self.tau_up <= 0.0 || pd_pow < 1e-14 {
                // either no room for even one uplink packet, or negligible
                // tail mass
                if self.tau_down > 0.0 || a > 1 {
                    break;
                }
            }
            let mut pu_pow = qu;
            let mut b = 1u64;
            loop {
                let slack = t_after_down - self.tau_up * b as f64;
                if slack <= 0.0 || pu_pow < 1e-14 {
                    break;
                }
                let f = if gamma.is_infinite() {
                    1.0
                } else {
                    1.0 - (-gamma * slack).exp()
                };
                sum += pd_pow * pu_pow * f;
                pu_pow *= self.p_up;
                b += 1;
                if self.tau_up == 0.0 && b > 64 {
                    break; // free uplink: geometric tail is tiny past 64
                }
            }
            pd_pow *= self.p_down;
            a += 1;
            if self.tau_down == 0.0 && a > 64 {
                break;
            }
        }
        sum.clamp(0.0, 1.0)
    }

    /// Sample one epoch's per-leg delays. The RNG sequence (exponential
    /// compute draw, downlink count, uplink count) matches both the
    /// historical asymmetric `sample_delay` and — through
    /// [`AsymNodeParams::symmetric`] — [`NodeParams::sample_legs`], so a
    /// reciprocal-link fleet sampled through this model reproduces the
    /// base model's draws bit-for-bit.
    pub fn sample_legs(&self, ell: f64, rng: &mut Rng) -> DelayLegs {
        let compute_det = ell / self.mu;
        let compute_stoch = if ell == 0.0 {
            0.0
        } else {
            rng.next_exponential(self.alpha * self.mu / ell)
        };
        let n_down = rng.next_geometric_trials(self.p_down);
        let n_up = rng.next_geometric_trials(self.p_up);
        DelayLegs {
            n_down,
            n_up,
            compute_det,
            compute_stoch,
            tau_down: self.tau_down,
            tau_up: self.tau_up,
        }
    }

    /// Sample one epoch delay: the sum over the sampled legs.
    pub fn sample_delay(&self, ell: f64, rng: &mut Rng) -> f64 {
        self.sample_legs(ell, rng).total()
    }

    /// Symmetric surrogate with the same *mean* communication delay:
    /// `p = (p_d + p_u)/2` and τ chosen so `2τ/(1−p)` equals
    /// `τ_d/(1−p_d) + τ_u/(1−p_u)`. The load-allocation optimizer
    /// (`crate::allocation`) speaks the reciprocal model of the Theorem;
    /// under a `[fleet]`-configured asymmetric fleet each client is
    /// represented there by this surrogate while the round simulator
    /// keeps the exact per-leg model. Only meaningful for genuinely
    /// asymmetric links — the symmetric case should use the original
    /// [`NodeParams`] unchanged (round-tripping through the surrogate
    /// can flip the last ulp of τ).
    pub fn reciprocal_surrogate(&self) -> NodeParams {
        let p = 0.5 * (self.p_down + self.p_up);
        let mean_comm =
            self.tau_down / (1.0 - self.p_down) + self.tau_up / (1.0 - self.p_up);
        NodeParams { mu: self.mu, alpha: self.alpha, tau: 0.5 * (1.0 - p) * mean_comm, p }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn symmetric_case_matches_base_model() {
        let base = NodeParams { mu: 3.0, alpha: 2.0, tau: 0.8, p: 0.25 };
        let asym = AsymNodeParams::symmetric(&base);
        let ell = 7.0;
        for &t in &[2.0, 4.0, 8.0, 16.0] {
            let a = asym.cdf(t, ell);
            let b = base.cdf(t, ell);
            assert!((a - b).abs() < 1e-9, "t={t}: asym {a} vs base {b}");
        }
        assert!((asym.mean_delay(ell) - base.mean_delay(ell)).abs() < 1e-12);
    }

    #[test]
    fn cdf_matches_monte_carlo() {
        let n = AsymNodeParams {
            mu: 2.0,
            alpha: 2.0,
            tau_down: 0.5,
            tau_up: 1.5,
            p_down: 0.4,
            p_up: 0.1,
        };
        let mut rng = Rng::seed_from(21);
        let ell = 4.0;
        for &t in &[4.0, 6.0, 10.0] {
            let trials = 60_000;
            let hits = (0..trials).filter(|_| n.sample_delay(ell, &mut rng) <= t).count();
            let emp = hits as f64 / trials as f64;
            let exact = n.cdf(t, ell);
            assert!((emp - exact).abs() < 0.01, "t={t}: {emp} vs {exact}");
        }
    }

    #[test]
    fn slower_uplink_shifts_the_distribution() {
        let fast = AsymNodeParams {
            mu: 2.0, alpha: 2.0, tau_down: 0.5, tau_up: 0.5, p_down: 0.1, p_up: 0.1,
        };
        let slow = AsymNodeParams { tau_up: 3.0, ..fast };
        assert!(slow.mean_delay(5.0) > fast.mean_delay(5.0));
        assert!(slow.cdf(6.0, 5.0) < fast.cdf(6.0, 5.0));
    }

    #[test]
    fn symmetric_sample_legs_match_base_model_bitwise() {
        let base = NodeParams { mu: 3.0, alpha: 2.0, tau: 0.8, p: 0.25 };
        let asym = AsymNodeParams::symmetric(&base);
        let mut rng_a = Rng::seed_from(5);
        let mut rng_b = Rng::seed_from(5);
        for i in 0..200 {
            let ell = (i % 5) as f64;
            let a = asym.sample_delay(ell, &mut rng_a);
            let b = base.sample_delay(ell, &mut rng_b);
            assert_eq!(a.to_bits(), b.to_bits(), "ell={ell}");
        }
    }

    #[test]
    fn reciprocal_surrogate_preserves_mean_delay() {
        let asym = AsymNodeParams {
            mu: 2.0,
            alpha: 2.0,
            tau_down: 0.5,
            tau_up: 1.5,
            p_down: 0.4,
            p_up: 0.1,
        };
        let sur = asym.reciprocal_surrogate();
        sur.validate().unwrap();
        assert_eq!(sur.mu, asym.mu);
        assert_eq!(sur.alpha, asym.alpha);
        assert!((sur.p - 0.25).abs() < 1e-12);
        for &ell in &[0.0, 3.0, 11.0] {
            assert!(
                (sur.mean_delay(ell) - asym.mean_delay(ell)).abs() < 1e-12,
                "ell={ell}"
            );
        }
    }

    #[test]
    fn validate_rejects_bad() {
        let ok = AsymNodeParams {
            mu: 1.0, alpha: 1.0, tau_down: 0.1, tau_up: 0.1, p_down: 0.0, p_up: 0.0,
        };
        assert!(ok.validate().is_ok());
        assert!(AsymNodeParams { mu: 0.0, ..ok }.validate().is_err());
        assert!(AsymNodeParams { p_up: 1.0, ..ok }.validate().is_err());
        assert!(AsymNodeParams { tau_down: -1.0, ..ok }.validate().is_err());
    }
}
