//! Tiny command-line parser (clap is unavailable offline).
//!
//! Model: `binary <subcommand> [--key value]... [--flag]...`. Subcommands
//! and options are declared up front so `--help` output and unknown-option
//! errors are first-class.

use std::collections::BTreeMap;

/// Declared option (all options take a value unless `is_flag`).
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// Parsed arguments for one subcommand.
#[derive(Clone, Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }

    pub fn parse_f64(&self, name: &str) -> Result<Option<f64>, String> {
        self.get(name)
            .map(|v| v.parse::<f64>().map_err(|e| format!("--{name}: {e}")))
            .transpose()
    }

    pub fn parse_usize(&self, name: &str) -> Result<Option<usize>, String> {
        self.get(name)
            .map(|v| v.parse::<usize>().map_err(|e| format!("--{name}: {e}")))
            .transpose()
    }

    pub fn parse_u64(&self, name: &str) -> Result<Option<u64>, String> {
        self.get(name)
            .map(|v| v.parse::<u64>().map_err(|e| format!("--{name}: {e}")))
            .transpose()
    }
}

/// A subcommand declaration.
#[derive(Clone, Debug)]
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
}

/// Parse `argv` (without the program name) against `commands`.
/// Returns `(command name, args)` or a user-facing error/help string.
pub fn parse_argv(
    commands: &[Command],
    argv: &[String],
) -> Result<(&'static str, Args), String> {
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "-h" || argv[0] == "help" {
        return Err(usage(commands));
    }
    let cmd = commands
        .iter()
        .find(|c| c.name == argv[0])
        .ok_or_else(|| format!("unknown command {:?}\n\n{}", argv[0], usage(commands)))?;

    let mut args = Args::default();
    for o in &cmd.opts {
        if let Some(d) = o.default {
            args.values.insert(o.name.to_string(), d.to_string());
        }
    }
    let mut i = 1;
    while i < argv.len() {
        let tok = &argv[i];
        if tok == "--help" || tok == "-h" {
            return Err(cmd_usage(cmd));
        }
        let name = tok
            .strip_prefix("--")
            .ok_or_else(|| format!("expected --option, got {tok:?}\n\n{}", cmd_usage(cmd)))?;
        let spec = cmd
            .opts
            .iter()
            .find(|o| o.name == name)
            .ok_or_else(|| format!("unknown option --{name}\n\n{}", cmd_usage(cmd)))?;
        if spec.is_flag {
            args.flags.insert(name.to_string(), true);
            i += 1;
        } else {
            let val = argv
                .get(i + 1)
                .ok_or_else(|| format!("--{name} needs a value"))?;
            args.values.insert(name.to_string(), val.clone());
            i += 2;
        }
    }
    Ok((cmd.name, args))
}

/// Top-level usage text.
pub fn usage(commands: &[Command]) -> String {
    let mut s = String::from("codedfedl — CodedFedL (JSAC 2020) reproduction\n\nCommands:\n");
    for c in commands {
        s.push_str(&format!("  {:<14} {}\n", c.name, c.about));
    }
    s.push_str("\nUse `<command> --help` for options.");
    s
}

fn cmd_usage(cmd: &Command) -> String {
    let mut s = format!("{} — {}\n\nOptions:\n", cmd.name, cmd.about);
    for o in &cmd.opts {
        let kind = if o.is_flag { "" } else { " <value>" };
        let def = o
            .default
            .map(|d| format!(" [default: {d}]"))
            .unwrap_or_default();
        s.push_str(&format!("  --{}{kind:<10} {}{def}\n", o.name, o.help));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmds() -> Vec<Command> {
        vec![Command {
            name: "train",
            about: "run training",
            opts: vec![
                OptSpec { name: "scheme", help: "scheme", default: Some("coded"), is_flag: false },
                OptSpec { name: "delta", help: "redundancy", default: None, is_flag: false },
                OptSpec { name: "full", help: "paper scale", default: None, is_flag: true },
            ],
        }]
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_values_defaults_flags() {
        let (name, a) = parse_argv(&cmds(), &sv(&["train", "--delta", "0.1", "--full"])).unwrap();
        assert_eq!(name, "train");
        assert_eq!(a.get("scheme"), Some("coded"));
        assert_eq!(a.parse_f64("delta").unwrap(), Some(0.1));
        assert!(a.flag("full"));
        assert!(!a.flag("absent"));
    }

    #[test]
    fn unknown_command_and_option() {
        assert!(parse_argv(&cmds(), &sv(&["nope"])).is_err());
        assert!(parse_argv(&cmds(), &sv(&["train", "--bogus", "1"])).is_err());
    }

    #[test]
    fn missing_value_is_error() {
        assert!(parse_argv(&cmds(), &sv(&["train", "--delta"])).is_err());
    }

    #[test]
    fn help_is_err_with_usage() {
        let e = parse_argv(&cmds(), &sv(&["--help"])).unwrap_err();
        assert!(e.contains("Commands"));
        let e2 = parse_argv(&cmds(), &sv(&["train", "--help"])).unwrap_err();
        assert!(e2.contains("Options"));
    }

    #[test]
    fn bad_number_reported() {
        let (_, a) = parse_argv(&cmds(), &sv(&["train", "--delta", "abc"])).unwrap();
        assert!(a.parse_f64("delta").is_err());
    }
}
