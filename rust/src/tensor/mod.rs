//! Row-major `f32` matrix (and borrowed [`MatView`] row blocks) used across
//! the coordinator and the native compute backend.
//!
//! Since the pure-Rust `runtime::native` backend became the default, this
//! module *is* the training hot path. The GEMM microkernels live in the
//! [`gemm`] submodule: a runtime-ISA-dispatched [`gemm_into`] (scalar /
//! AVX2+FMA / NEON, selected once per runtime via [`SimdPolicy`] →
//! [`Isa`]) plus the scalar register-tile loop that doubles as the
//! always-available fallback and the determinism oracle. There is exactly
//! **one** row-slice matmul implementation: [`Mat::matmul`] delegates to
//! [`MatView::matmul_into`], which calls the shared kernel — every other
//! matmul in the tree (the `runtime::native` kernels included) goes
//! through the same entry points. [`MatView`] provides zero-copy
//! row-block access so per-round slicing never clones buffers, and
//! [`Mat::matmul_ref`] is the naive reference oracle the fast kernels are
//! tested against (and what the AOT/PJRT artifacts execute when the
//! `pjrt` feature is enabled).
//!
//! Determinism contract: [`Mat::matmul`] / [`MatView::matmul`] always run
//! the *scalar* kernel, which accumulates every output element over `k`
//! in ascending order with plain (non-fused) f32 adds — the exact
//! sequence `matmul_ref` performs — so for finite inputs blocked and
//! reference results are bit-for-bit identical, not merely close.
//! (`matmul_ref` skips `a == 0` terms; with non-finite operands those
//! skipped `0·inf` products would differ, so the guarantee is stated for
//! finite data — the only kind training produces.) SIMD execution is
//! opt-in per call site through [`gemm_into`]'s `Isa` parameter: the
//! native backend threads its runtime-detected ISA into every kernel, and
//! `simd = "scalar"` pins those call sites to this same bit-exact path
//! (see the [`gemm`] module docs for the SIMD determinism contract). The
//! parallel drivers in `runtime::native` partition *output rows* across
//! threads, which preserves per-element order — and therefore bitwise
//! results — for every thread count, under every ISA.

pub mod gemm;

pub use gemm::{gemm_into, gemm_pack_len, saxpy_into, Isa, SimdPolicy, GEMM_MR};
pub(crate) use gemm::{matmul_rows_into, MM_TILE};

use std::fmt;

/// Dense row-major matrix of `f32`.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Mat[{}x{}]", self.rows, self.cols)
    }
}

impl Mat {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Build from an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer/shape mismatch");
        Mat { rows, cols, data }
    }

    /// Build by evaluating `f(r, c)` at every position.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Mat { rows, cols, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Row-major backing slice.
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    /// Borrow one row.
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Copy of rows `[start, start+n)` as a new matrix. Prefer
    /// [`Mat::rows_view`] on hot paths — it borrows instead of cloning.
    pub fn rows_slice(&self, start: usize, n: usize) -> Mat {
        assert!(start + n <= self.rows, "row slice out of bounds");
        Mat {
            rows: n,
            cols: self.cols,
            data: self.data[start * self.cols..(start + n) * self.cols].to_vec(),
        }
    }

    /// Zero-copy view of the whole matrix.
    pub fn view(&self) -> MatView<'_> {
        MatView { rows: self.rows, cols: self.cols, data: &self.data }
    }

    /// Zero-copy view of rows `[start, start+n)` (the borrowed counterpart
    /// of [`Mat::rows_slice`]).
    pub fn rows_view(&self, start: usize, n: usize) -> MatView<'_> {
        assert!(start + n <= self.rows, "row view out of bounds");
        MatView {
            rows: n,
            cols: self.cols,
            data: &self.data[start * self.cols..(start + n) * self.cols],
        }
    }

    /// Copy of the rows at `idx` (gather), in order.
    pub fn gather_rows(&self, idx: &[usize]) -> Mat {
        let mut out = Vec::with_capacity(idx.len() * self.cols);
        for &r in idx {
            out.extend_from_slice(self.row(r));
        }
        Mat { rows: idx.len(), cols: self.cols, data: out }
    }

    /// Zero-pad (or truncate-check) to `rows` rows. Padding rows are exact
    /// no-ops for gradients/parity (zero rows contribute zero).
    pub fn pad_rows(&self, rows: usize) -> Mat {
        assert!(rows >= self.rows, "pad_rows cannot shrink ({} -> {rows})", self.rows);
        let mut data = self.data.clone();
        data.resize(rows * self.cols, 0.0);
        Mat { rows, cols: self.cols, data }
    }

    /// Vertical stack of `mats` (all with equal `cols`).
    pub fn vstack(mats: &[&Mat]) -> Mat {
        assert!(!mats.is_empty());
        let cols = mats[0].cols;
        let rows: usize = mats.iter().map(|m| m.rows).sum();
        let mut data = Vec::with_capacity(rows * cols);
        for m in mats {
            assert_eq!(m.cols, cols, "vstack col mismatch");
            data.extend_from_slice(&m.data);
        }
        Mat { rows, cols, data }
    }

    /// `self += alpha * other` (element-wise). Hot path of aggregation.
    pub fn axpy(&mut self, alpha: f32, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += alpha * b;
        }
    }

    /// `self *= alpha`.
    pub fn scale(&mut self, alpha: f32) {
        for a in self.data.iter_mut() {
            *a *= alpha;
        }
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|v| v * v).sum::<f32>().sqrt()
    }

    /// Index of the max element in each row (argmax over columns).
    pub fn argmax_rows(&self) -> Vec<usize> {
        (0..self.rows)
            .map(|r| {
                let row = self.row(r);
                let mut best = 0usize;
                for (i, &v) in row.iter().enumerate() {
                    if v > row[best] {
                        best = i;
                    }
                }
                best
            })
            .collect()
    }

    /// Max absolute element-wise difference against `other`.
    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }

    /// Dense matmul `self · other` via the blocked kernel (single-threaded;
    /// the parallel drivers live in `runtime::native`). Bit-for-bit equal to
    /// [`Mat::matmul_ref`] on finite inputs — see the module docs for the
    /// determinism contract.
    pub fn matmul(&self, other: &Mat) -> Mat {
        self.view().matmul(other)
    }

    /// [`Mat::matmul`] into a caller-owned destination (overwritten): the
    /// allocation-free form hot loops hold a reusable `out` for. Panics if
    /// `out` is not `[self.rows, other.cols]`.
    pub fn matmul_into(&self, other: &Mat, out: &mut Mat) {
        self.view().matmul_into(other, out)
    }

    /// Naive reference matmul — the test/diagnostic *oracle* the blocked
    /// [`Mat::matmul`] (the default native-backend hot path) is pinned
    /// against. Only the optional `pjrt` backend bypasses both in favour of
    /// the AOT XLA artifacts.
    pub fn matmul_ref(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self.get(i, k);
                if a == 0.0 {
                    continue;
                }
                for j in 0..other.cols {
                    out.data[i * other.cols + j] += a * other.get(k, j);
                }
            }
        }
        out
    }
}

/// Borrowed, zero-copy row-block view of a [`Mat`] (same row-major layout).
///
/// Produced by [`Mat::view`] / [`Mat::rows_view`]. The blocked
/// [`Mat::matmul`] runs through it, and it is the row-block API offered to
/// schemes and tooling that would otherwise reach for the cloning
/// [`Mat::rows_slice`]. (The per-round θ reuse has its own zero-copy
/// path: the borrowed `runtime::PreparedTheta`.)
#[derive(Clone, Copy, PartialEq)]
pub struct MatView<'a> {
    rows: usize,
    cols: usize,
    data: &'a [f32],
}

impl fmt::Debug for MatView<'_> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "MatView[{}x{}]", self.rows, self.cols)
    }
}

impl<'a> MatView<'a> {
    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Row-major backing slice (borrowed from the parent [`Mat`]).
    pub fn as_slice(&self) -> &'a [f32] {
        self.data
    }

    /// Borrow one row.
    pub fn row(&self, r: usize) -> &'a [f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Materialise the view as an owned matrix.
    pub fn to_mat(&self) -> Mat {
        Mat { rows: self.rows, cols: self.cols, data: self.data.to_vec() }
    }

    /// Dense matmul `self · other` via the blocked kernel (bit-for-bit
    /// equal to [`Mat::matmul_ref`]).
    pub fn matmul(&self, other: &Mat) -> Mat {
        let mut out = Mat::zeros(self.rows, other.cols);
        self.matmul_into(other, &mut out);
        out
    }

    /// [`MatView::matmul`] into a caller-owned destination (overwritten;
    /// same bit-for-bit contract). Panics on shape mismatch.
    pub fn matmul_into(&self, other: &Mat, out: &mut Mat) {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        assert_eq!(
            (out.rows, out.cols),
            (self.rows, other.cols),
            "matmul_into: out shape mismatch"
        );
        out.data.fill(0.0);
        matmul_rows_into(self.data, &other.data, &mut out.data, self.cols, other.cols);
    }
}

/// `cols` rounded up to the register-tile width of the blocked matmul.
/// When a `b`-operand's row stride is a multiple of the tile, the kernel
/// runs pure register tiles — no remainder columns, so accumulators stay
/// in registers across the whole `k` loop instead of re-loading the
/// output row every step (the win is large for narrow outputs like the
/// `c = 10` class dimension).
pub fn tile_padded_cols(cols: usize) -> usize {
    match cols % MM_TILE {
        0 => cols,
        r => cols + (MM_TILE - r),
    }
}

/// Pack `m` (`[rows, cols]`) into a tile-aligned panel `[rows, c_pad]`
/// with zero-filled tail columns, reusing `out`'s capacity (steady-state
/// callers pay no allocation). Returns `c_pad = tile_padded_cols(cols)`.
///
/// The padded columns never change the real outputs: every per-element
/// accumulation reads only the first `cols` entries of each packed row in
/// the same ascending-`k` order as the unpacked kernel, so results stay
/// bit-identical (see the module docs).
pub fn pack_tile_panel(m: &Mat, out: &mut Vec<f32>) -> usize {
    let (rows, cols) = (m.rows, m.cols);
    let c_pad = tile_padded_cols(cols);
    out.clear();
    out.resize(rows * c_pad, 0.0);
    if cols == 0 {
        return c_pad;
    }
    for (src, dst) in m.data.chunks_exact(cols).zip(out.chunks_exact_mut(c_pad)) {
        dst[..cols].copy_from_slice(src);
    }
    c_pad
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_get_set() {
        let mut m = Mat::zeros(2, 3);
        assert_eq!(m.get(1, 2), 0.0);
        m.set(1, 2, 5.0);
        assert_eq!(m.get(1, 2), 5.0);
        assert_eq!(m.rows(), 2);
        assert_eq!(m.cols(), 3);
    }

    #[test]
    fn from_fn_row_major() {
        let m = Mat::from_fn(2, 2, |r, c| (r * 10 + c) as f32);
        assert_eq!(m.as_slice(), &[0.0, 1.0, 10.0, 11.0]);
    }

    #[test]
    fn rows_slice_and_gather() {
        let m = Mat::from_fn(4, 2, |r, _| r as f32);
        let s = m.rows_slice(1, 2);
        assert_eq!(s.as_slice(), &[1.0, 1.0, 2.0, 2.0]);
        let g = m.gather_rows(&[3, 0]);
        assert_eq!(g.as_slice(), &[3.0, 3.0, 0.0, 0.0]);
    }

    #[test]
    fn pad_rows_appends_zeros() {
        let m = Mat::from_vec(1, 2, vec![1.0, 2.0]);
        let p = m.pad_rows(3);
        assert_eq!(p.as_slice(), &[1.0, 2.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    #[should_panic(expected = "pad_rows cannot shrink")]
    fn pad_rows_rejects_shrink() {
        Mat::zeros(3, 1).pad_rows(2);
    }

    #[test]
    fn vstack_concatenates() {
        let a = Mat::from_vec(1, 2, vec![1.0, 2.0]);
        let b = Mat::from_vec(2, 2, vec![3.0, 4.0, 5.0, 6.0]);
        let v = Mat::vstack(&[&a, &b]);
        assert_eq!(v.rows(), 3);
        assert_eq!(v.as_slice(), &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn axpy_scale_norm() {
        let mut a = Mat::from_vec(1, 2, vec![1.0, 2.0]);
        let b = Mat::from_vec(1, 2, vec![10.0, 10.0]);
        a.axpy(0.5, &b);
        assert_eq!(a.as_slice(), &[6.0, 7.0]);
        a.scale(2.0);
        assert_eq!(a.as_slice(), &[12.0, 14.0]);
        let n = Mat::from_vec(1, 2, vec![3.0, 4.0]).fro_norm();
        assert!((n - 5.0).abs() < 1e-6);
    }

    #[test]
    fn argmax_rows_ties_pick_first() {
        let m = Mat::from_vec(2, 3, vec![0.0, 5.0, 5.0, 9.0, 1.0, 2.0]);
        assert_eq!(m.argmax_rows(), vec![1, 0]);
    }

    #[test]
    fn matmul_ref_small() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul_ref(&b);
        assert_eq!(c.as_slice(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn blocked_matmul_matches_reference_bitwise() {
        // Shapes straddling the MM_TILE boundary, plus degenerate ones.
        for (m, k, n) in [
            (0, 3, 4),
            (1, 1, 1),
            (3, 5, MM_TILE),
            (4, 7, MM_TILE + 3),
            (5, 2, MM_TILE - 1),
            (7, 33, 2 * MM_TILE + 5),
            (2, 0, 3),
            (2, 3, 0),
        ] {
            let a = Mat::from_fn(m, k, |r, c| ((r * 31 + c * 17) % 13) as f32 * 0.37 - 2.0);
            let b = Mat::from_fn(k, n, |r, c| ((r * 7 + c * 29) % 11) as f32 * 0.53 - 1.5);
            let fast = a.matmul(&b);
            let oracle = a.matmul_ref(&b);
            assert_eq!((fast.rows(), fast.cols()), (m, n));
            assert_eq!(fast.as_slice(), oracle.as_slice(), "({m},{k},{n})");
        }
    }

    #[test]
    fn views_borrow_without_cloning() {
        let m = Mat::from_fn(4, 3, |r, c| (r * 3 + c) as f32);
        let v = m.rows_view(1, 2);
        assert_eq!((v.rows(), v.cols()), (2, 3));
        assert_eq!(v.as_slice(), &m.as_slice()[3..9]);
        assert_eq!(v.row(1), m.row(2));
        assert_eq!(v.to_mat().as_slice(), m.rows_slice(1, 2).as_slice());
        // view-based matmul equals the owned path
        let b = Mat::from_fn(3, 5, |r, c| (r + c) as f32 * 0.5);
        assert_eq!(
            v.matmul(&b).as_slice(),
            m.rows_slice(1, 2).matmul_ref(&b).as_slice()
        );
        assert_eq!(m.view().rows(), 4);
    }

    #[test]
    #[should_panic(expected = "row view out of bounds")]
    fn rows_view_rejects_overrun() {
        Mat::zeros(3, 2).rows_view(2, 2);
    }

    #[test]
    fn matmul_into_reuses_buffer_and_matches() {
        let a = Mat::from_fn(3, 5, |r, c| (r * 5 + c) as f32 * 0.3 - 1.0);
        let b = Mat::from_fn(5, 4, |r, c| (r + 2 * c) as f32 * 0.7 - 2.0);
        let mut out = Mat::from_fn(3, 4, |_, _| 99.0); // stale contents must vanish
        a.matmul_into(&b, &mut out);
        assert_eq!(out.as_slice(), a.matmul_ref(&b).as_slice());
        // second use of the same buffer
        a.matmul_into(&b, &mut out);
        assert_eq!(out.as_slice(), a.matmul_ref(&b).as_slice());
    }

    #[test]
    #[should_panic(expected = "matmul_into: out shape mismatch")]
    fn matmul_into_rejects_wrong_out_shape() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(3, 4);
        let mut out = Mat::zeros(2, 5);
        a.matmul_into(&b, &mut out);
    }

    #[test]
    fn tile_padding_rounds_up_to_tile() {
        assert_eq!(tile_padded_cols(0), 0);
        assert_eq!(tile_padded_cols(1), MM_TILE);
        assert_eq!(tile_padded_cols(10), MM_TILE);
        assert_eq!(tile_padded_cols(MM_TILE), MM_TILE);
        assert_eq!(tile_padded_cols(MM_TILE + 1), 2 * MM_TILE);
    }

    #[test]
    fn packed_panel_zero_fills_tails_and_reuses_capacity() {
        let m = Mat::from_fn(4, 10, |r, c| (r * 10 + c) as f32);
        let mut panel = Vec::new();
        let c_pad = pack_tile_panel(&m, &mut panel);
        assert_eq!(c_pad, MM_TILE);
        assert_eq!(panel.len(), 4 * MM_TILE);
        for r in 0..4 {
            assert_eq!(&panel[r * c_pad..r * c_pad + 10], m.row(r));
            assert!(panel[r * c_pad + 10..(r + 1) * c_pad].iter().all(|&v| v == 0.0));
        }
        // repacking a same-shape matrix reuses the buffer
        let cap = panel.capacity();
        let m2 = Mat::from_fn(4, 10, |r, c| -((r + c) as f32));
        pack_tile_panel(&m2, &mut panel);
        assert_eq!(panel.capacity(), cap);
        assert_eq!(&panel[..10], m2.row(0));
        // a packed row × tile-aligned matmul matches the unpadded kernel
        let v = Mat::from_fn(1, 4, |_, c| 0.5 * c as f32 + 0.1);
        let want = v.matmul(&m2); // [1, 10] — the panel now holds m2
        let mut got_pad = vec![0.0f32; c_pad];
        matmul_rows_into(v.as_slice(), &panel, &mut got_pad, 4, c_pad);
        assert_eq!(&got_pad[..10], want.as_slice());
    }

    #[test]
    fn max_abs_diff_works() {
        let a = Mat::from_vec(1, 2, vec![1.0, 2.0]);
        let b = Mat::from_vec(1, 2, vec![1.5, 1.0]);
        assert!((a.max_abs_diff(&b) - 1.0).abs() < 1e-6);
    }
}
