//! Minimal row-major `f32` matrix used across the coordinator.
//!
//! The heavy math lives in the AOT-compiled XLA artifacts; this type only
//! needs cheap construction, slicing into row blocks, zero-padding (which is
//! *exact* for the CodedFedL math — see DESIGN.md §2) and a few O(n)
//! reductions used by aggregation and metrics.

use std::fmt;

/// Dense row-major matrix of `f32`.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Mat[{}x{}]", self.rows, self.cols)
    }
}

impl Mat {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Build from an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer/shape mismatch");
        Mat { rows, cols, data }
    }

    /// Build by evaluating `f(r, c)` at every position.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Mat { rows, cols, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Row-major backing slice.
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    /// Borrow one row.
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Copy of rows `[start, start+n)` as a new matrix.
    pub fn rows_slice(&self, start: usize, n: usize) -> Mat {
        assert!(start + n <= self.rows, "row slice out of bounds");
        Mat {
            rows: n,
            cols: self.cols,
            data: self.data[start * self.cols..(start + n) * self.cols].to_vec(),
        }
    }

    /// Copy of the rows at `idx` (gather), in order.
    pub fn gather_rows(&self, idx: &[usize]) -> Mat {
        let mut out = Vec::with_capacity(idx.len() * self.cols);
        for &r in idx {
            out.extend_from_slice(self.row(r));
        }
        Mat { rows: idx.len(), cols: self.cols, data: out }
    }

    /// Zero-pad (or truncate-check) to `rows` rows. Padding rows are exact
    /// no-ops for gradients/parity (zero rows contribute zero).
    pub fn pad_rows(&self, rows: usize) -> Mat {
        assert!(rows >= self.rows, "pad_rows cannot shrink ({} -> {rows})", self.rows);
        let mut data = self.data.clone();
        data.resize(rows * self.cols, 0.0);
        Mat { rows, cols: self.cols, data }
    }

    /// Vertical stack of `mats` (all with equal `cols`).
    pub fn vstack(mats: &[&Mat]) -> Mat {
        assert!(!mats.is_empty());
        let cols = mats[0].cols;
        let rows: usize = mats.iter().map(|m| m.rows).sum();
        let mut data = Vec::with_capacity(rows * cols);
        for m in mats {
            assert_eq!(m.cols, cols, "vstack col mismatch");
            data.extend_from_slice(&m.data);
        }
        Mat { rows, cols, data }
    }

    /// `self += alpha * other` (element-wise). Hot path of aggregation.
    pub fn axpy(&mut self, alpha: f32, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += alpha * b;
        }
    }

    /// `self *= alpha`.
    pub fn scale(&mut self, alpha: f32) {
        for a in self.data.iter_mut() {
            *a *= alpha;
        }
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|v| v * v).sum::<f32>().sqrt()
    }

    /// Index of the max element in each row (argmax over columns).
    pub fn argmax_rows(&self) -> Vec<usize> {
        (0..self.rows)
            .map(|r| {
                let row = self.row(r);
                let mut best = 0usize;
                for (i, &v) in row.iter().enumerate() {
                    if v > row[best] {
                        best = i;
                    }
                }
                best
            })
            .collect()
    }

    /// Max absolute element-wise difference against `other`.
    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }

    /// Naive reference matmul — used only in tests/diagnostics, never on the
    /// training hot path (that goes through XLA).
    pub fn matmul_ref(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self.get(i, k);
                if a == 0.0 {
                    continue;
                }
                for j in 0..other.cols {
                    out.data[i * other.cols + j] += a * other.get(k, j);
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_get_set() {
        let mut m = Mat::zeros(2, 3);
        assert_eq!(m.get(1, 2), 0.0);
        m.set(1, 2, 5.0);
        assert_eq!(m.get(1, 2), 5.0);
        assert_eq!(m.rows(), 2);
        assert_eq!(m.cols(), 3);
    }

    #[test]
    fn from_fn_row_major() {
        let m = Mat::from_fn(2, 2, |r, c| (r * 10 + c) as f32);
        assert_eq!(m.as_slice(), &[0.0, 1.0, 10.0, 11.0]);
    }

    #[test]
    fn rows_slice_and_gather() {
        let m = Mat::from_fn(4, 2, |r, _| r as f32);
        let s = m.rows_slice(1, 2);
        assert_eq!(s.as_slice(), &[1.0, 1.0, 2.0, 2.0]);
        let g = m.gather_rows(&[3, 0]);
        assert_eq!(g.as_slice(), &[3.0, 3.0, 0.0, 0.0]);
    }

    #[test]
    fn pad_rows_appends_zeros() {
        let m = Mat::from_vec(1, 2, vec![1.0, 2.0]);
        let p = m.pad_rows(3);
        assert_eq!(p.as_slice(), &[1.0, 2.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    #[should_panic(expected = "pad_rows cannot shrink")]
    fn pad_rows_rejects_shrink() {
        Mat::zeros(3, 1).pad_rows(2);
    }

    #[test]
    fn vstack_concatenates() {
        let a = Mat::from_vec(1, 2, vec![1.0, 2.0]);
        let b = Mat::from_vec(2, 2, vec![3.0, 4.0, 5.0, 6.0]);
        let v = Mat::vstack(&[&a, &b]);
        assert_eq!(v.rows(), 3);
        assert_eq!(v.as_slice(), &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn axpy_scale_norm() {
        let mut a = Mat::from_vec(1, 2, vec![1.0, 2.0]);
        let b = Mat::from_vec(1, 2, vec![10.0, 10.0]);
        a.axpy(0.5, &b);
        assert_eq!(a.as_slice(), &[6.0, 7.0]);
        a.scale(2.0);
        assert_eq!(a.as_slice(), &[12.0, 14.0]);
        let n = Mat::from_vec(1, 2, vec![3.0, 4.0]).fro_norm();
        assert!((n - 5.0).abs() < 1e-6);
    }

    #[test]
    fn argmax_rows_ties_pick_first() {
        let m = Mat::from_vec(2, 3, vec![0.0, 5.0, 5.0, 9.0, 1.0, 2.0]);
        assert_eq!(m.argmax_rows(), vec![1, 0]);
    }

    #[test]
    fn matmul_ref_small() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul_ref(&b);
        assert_eq!(c.as_slice(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn max_abs_diff_works() {
        let a = Mat::from_vec(1, 2, vec![1.0, 2.0]);
        let b = Mat::from_vec(1, 2, vec![1.5, 1.0]);
        assert!((a.max_abs_diff(&b) - 1.0).abs() < 1e-6);
    }
}
