//! The GEMM microkernels behind every matmul in the native backend, with
//! runtime ISA dispatch.
//!
//! Three implementations of one contract sit behind [`gemm_into`]:
//!
//! * **scalar** — the autovectorisable register-tile loop
//!   ([`matmul_rows_into`], unchanged from the pre-SIMD backend). This is
//!   the always-available fallback *and* the determinism oracle: its
//!   per-element accumulation order is exactly [`super::Mat::matmul_ref`]'s,
//!   so results are bit-for-bit reference-equal on finite inputs.
//! * **AVX2+FMA** (`x86_64`) — an explicit `std::arch` microkernel:
//!   `GEMM_MR × MM_TILE` (4×16) register block, two 8-lane accumulators
//!   per row held across the whole `k` loop, one fused multiply-add per
//!   lane per step. The A-operand rows are packed `k`-major into a
//!   caller-provided scratch panel so the inner loop reads A contiguously.
//! * **NEON** (`aarch64`) — the same 4×16 block as four 4-lane
//!   accumulators per row (`vfmaq_n_f32`).
//!
//! ## Selection: [`SimdPolicy`] → [`Isa`]
//!
//! Callers pick a *policy* (`auto` detects the best ISA once, `scalar`
//! forces the fallback) and resolve it to an [`Isa`] **once** — the
//! runtime does this at construction (`[runtime] simd`, CLI `--simd`) —
//! then pass the resolved ISA to every kernel call. Detection uses
//! `is_x86_feature_detected!` / `is_aarch64_feature_detected!`, so a
//! binary built for a generic target still uses AVX2 on hosts that have
//! it, and degrades to scalar anywhere else.
//!
//! ## Determinism contract
//!
//! * `Isa::Scalar` is bit-identical to the pre-SIMD backend for every
//!   shape and thread count (it *is* that code).
//! * Each SIMD ISA is deterministic: for a fixed ISA, every output
//!   element accumulates over `k` in ascending order in a fixed lane with
//!   fused multiply-adds (tail columns: non-fused scalar ops), so results
//!   are reproducible run-to-run *and* thread-count invariant — which
//!   rows share a `GEMM_MR` block changes only which kernel computes an
//!   element, never its operation sequence.
//! * SIMD results differ from scalar only by FMA rounding: validated
//!   against `matmul_ref` within 1e-4 in `tests/kernel_equivalence.rs`
//!   and the hotpath bench oracles.
//!
//! The column tail (`n % MM_TILE`) *accumulates* into the output (which
//! callers keep zeroed), while full tiles are overwritten — the exact
//! contract of the scalar kernel, so the two are interchangeable at every
//! call site.

/// Width of the register tile of the blocked matmul: the accumulator
/// array held in vector registers across the whole `k` loop, so the
/// output row is loaded/stored once per tile instead of once per `k`.
/// Shared by all ISAs (2×8 AVX2 lanes, 4×4 NEON lanes, a 16-wide scalar
/// accumulator array) and by the θ-panel padding
/// ([`super::tile_padded_cols`]).
pub(crate) const MM_TILE: usize = 16;

/// Rows per register block of the SIMD microkernels. Row blocks of
/// `GEMM_MR` share each B tile load across `GEMM_MR` fused multiply-adds;
/// leftover rows run a 1×[`MM_TILE`] kernel with an identical per-element
/// operation sequence.
pub const GEMM_MR: usize = 4;

/// Scratch floats [`gemm_into`] needs to pack a `GEMM_MR`-row A block for
/// a `k`-deep product. Callers that may pass ≥ `GEMM_MR` rows to a SIMD
/// ISA must hand `gemm_into` a pack buffer at least this long (the
/// native backend carves it from the worker's persistent scratch arena);
/// single-row calls may pass an empty slice.
pub fn gemm_pack_len(k: usize) -> usize {
    GEMM_MR * k
}

/// How the experiment selects the matmul microkernel (config
/// `[runtime] simd`, CLI `--simd`, builder `.simd(...)`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SimdPolicy {
    /// Detect the best ISA for this host at runtime-construction time
    /// (AVX2+FMA on x86_64, NEON on aarch64, scalar anywhere else).
    #[default]
    Auto,
    /// Force the scalar fallback — bit-identical to the pre-SIMD backend
    /// for every thread count (the reproducibility anchor).
    Scalar,
}

impl SimdPolicy {
    /// The config-file spelling (`"auto"` / `"scalar"`).
    pub fn as_str(self) -> &'static str {
        match self {
            SimdPolicy::Auto => "auto",
            SimdPolicy::Scalar => "scalar",
        }
    }
}

impl std::str::FromStr for SimdPolicy {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "auto" => Ok(SimdPolicy::Auto),
            "scalar" => Ok(SimdPolicy::Scalar),
            other => Err(format!("unknown simd policy {other:?} (expected auto or scalar)")),
        }
    }
}

impl std::fmt::Display for SimdPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// The instruction set a resolved kernel dispatch targets. Resolved from
/// a [`SimdPolicy`] exactly once (at `Runtime`/`NativeExec` construction)
/// via [`Isa::detect`]; every kernel call then branches on the copy it is
/// handed — no per-call feature detection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    /// The autovectorisable fallback loop — always available, and the
    /// bit-for-bit determinism oracle.
    Scalar,
    /// `x86_64` AVX2 + FMA (8-lane f32, fused multiply-add).
    Avx2Fma,
    /// `aarch64` NEON (4-lane f32, fused multiply-add).
    Neon,
}

impl Isa {
    /// Resolve `policy` against this host's CPU features. `Scalar` always
    /// resolves to [`Isa::Scalar`]; `Auto` probes the feature flags once.
    pub fn detect(policy: SimdPolicy) -> Isa {
        match policy {
            SimdPolicy::Scalar => Isa::Scalar,
            SimdPolicy::Auto => detect_auto(),
        }
    }

    /// Telemetry string for bench reports and logs.
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2Fma => "avx2+fma",
            Isa::Neon => "neon",
        }
    }
}

impl std::fmt::Display for Isa {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Whether this host can run the AVX2+FMA kernels. The detector macro
/// caches its CPUID probe in an atomic, so re-checking per dispatch is a
/// load-and-test — cheap enough to make the public entry points safe
/// against hand-constructed [`Isa`] values (see [`gemm_into`]).
#[cfg(target_arch = "x86_64")]
#[inline]
fn avx2_fma_available() -> bool {
    std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
}

/// Whether this host can run the NEON kernels (cached probe; see
/// [`avx2_fma_available`]).
#[cfg(target_arch = "aarch64")]
#[inline]
fn neon_available() -> bool {
    std::arch::is_aarch64_feature_detected!("neon")
}

#[cfg(target_arch = "x86_64")]
fn detect_auto() -> Isa {
    if avx2_fma_available() {
        Isa::Avx2Fma
    } else {
        Isa::Scalar
    }
}

#[cfg(target_arch = "aarch64")]
fn detect_auto() -> Isa {
    if neon_available() {
        Isa::Neon
    } else {
        Isa::Scalar
    }
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn detect_auto() -> Isa {
    Isa::Scalar
}

/// `out = a · b` through the ISA-dispatched microkernel: `a` is row-major
/// `rows×k` (`rows = a.len() / k`), `b` is `k×n`, `out` is `rows×n` with
/// **zeroed tail columns** (`n % MM_TILE`; full tiles are overwritten,
/// the tail is accumulated into — the scalar kernel's historical
/// contract). `pack` is the A-block packing scratch: at least
/// [`gemm_pack_len`]`(k)` floats whenever a SIMD ISA may see
/// ≥ [`GEMM_MR`] rows; ignored by `Isa::Scalar` and by single-row calls.
///
/// `Isa::Scalar` is bit-for-bit [`super::Mat::matmul_ref`]-equal on
/// finite inputs; SIMD ISAs are deterministic and thread-count invariant,
/// within 1e-4 of the reference (see the module docs).
pub fn gemm_into(
    isa: Isa,
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    k: usize,
    n: usize,
    pack: &mut [f32],
) {
    if k == 0 || n == 0 {
        return;
    }
    match isa {
        Isa::Scalar => matmul_rows_into(a, b, out, k, n),
        // The guards re-verify the (cached) CPU probe so a
        // hand-constructed Isa value — `Isa`'s variants are public, and
        // this is a safe fn — can never reach an unsupported kernel.
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2Fma if avx2_fma_available() => {
            check_gemm_bounds(a, b, out, k, n, pack);
            // Safety: bounds checked above; the guard verified the ISA.
            unsafe { gemm_avx2(a, b, out, k, n, pack) }
        }
        #[cfg(target_arch = "aarch64")]
        Isa::Neon if neon_available() => {
            check_gemm_bounds(a, b, out, k, n, pack);
            // Safety: bounds checked above; the guard verified the ISA.
            unsafe { gemm_neon(a, b, out, k, n, pack) }
        }
        // An ISA this build has no kernel for, or this host lacks (only
        // reachable via hand-constructed Isa values — Isa::detect never
        // produces one): degrade to the scalar oracle, never fault.
        #[allow(unreachable_patterns)]
        _ => matmul_rows_into(a, b, out, k, n),
    }
}

/// `y[i] += alpha · x[i]`, ascending `i`, ISA-dispatched. The SIMD forms
/// use fused multiply-adds on the 8-/4-lane body and plain mul-add on the
/// tail; `Isa::Scalar` is the historical plain loop, bit-identical to the
/// pre-SIMD backend. Deterministic for a fixed ISA (lane assignment
/// depends only on the element index).
pub fn saxpy_into(isa: Isa, alpha: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len(), "saxpy_into: length mismatch");
    match isa {
        Isa::Scalar => {
            for (yv, &xv) in y.iter_mut().zip(x) {
                *yv += alpha * xv;
            }
        }
        // Guarded like gemm_into: cached probe, so hand-constructed Isa
        // values degrade to the scalar loop instead of faulting.
        #[cfg(target_arch = "x86_64")]
        // Safety: guard verified the ISA; slices share one checked length.
        Isa::Avx2Fma if avx2_fma_available() => unsafe { saxpy_avx2(alpha, x, y) },
        #[cfg(target_arch = "aarch64")]
        // Safety: guard verified the ISA; slices share one checked length.
        Isa::Neon if neon_available() => unsafe { saxpy_neon(alpha, x, y) },
        #[allow(unreachable_patterns)]
        _ => {
            for (yv, &xv) in y.iter_mut().zip(x) {
                *yv += alpha * xv;
            }
        }
    }
}

/// Core of the scalar blocked matmul (and the fallback/oracle path of
/// [`gemm_into`]): `out = a · b`, where `a` is `r×k`, `b` is `k×n` and
/// `out` is the `r×n` destination with zeroed tail columns. Runs a fixed
/// [`MM_TILE`]-wide register tile over the output columns with the `k`
/// loop innermost-but-one, so the hot loop is a pure `acc[t] += av * b[t]`
/// sweep `chunks_exact` exposes to the autovectoriser.
///
/// Per output element the products are accumulated over `k` in ascending
/// order with individual f32 adds — exactly [`super::Mat::matmul_ref`]'s
/// order — so the result is bit-for-bit identical to the reference.
/// Callers parallelise by splitting `a`/`out` into disjoint row blocks
/// (see `runtime::native`), which keeps that guarantee for any thread
/// count.
pub(crate) fn matmul_rows_into(a: &[f32], b: &[f32], out: &mut [f32], k: usize, n: usize) {
    if k == 0 || n == 0 {
        return;
    }
    debug_assert_eq!(a.len() % k, 0, "a is not whole rows");
    debug_assert_eq!(out.len() % n, 0, "out is not whole rows");
    debug_assert_eq!(a.len() / k, out.len() / n, "a/out row count mismatch");
    debug_assert_eq!(b.len(), k * n, "b shape mismatch");
    for (arow, orow) in a.chunks_exact(k).zip(out.chunks_exact_mut(n)) {
        let mut j = 0;
        let mut tiles = orow.chunks_exact_mut(MM_TILE);
        for otile in &mut tiles {
            let mut acc = [0.0f32; MM_TILE];
            for (kk, &av) in arow.iter().enumerate() {
                let btile = &b[kk * n + j..kk * n + j + MM_TILE];
                for (av_acc, &bv) in acc.iter_mut().zip(btile) {
                    *av_acc += av * bv;
                }
            }
            otile.copy_from_slice(&acc);
            j += MM_TILE;
        }
        // Column remainder (< MM_TILE wide): same ascending-k accumulation,
        // scalar form, into the still-zero tail of the output row.
        let tail = tiles.into_remainder();
        if !tail.is_empty() {
            for (kk, &av) in arow.iter().enumerate() {
                let btail = &b[kk * n + j..(kk + 1) * n];
                for (ov, &bv) in tail.iter_mut().zip(btail) {
                    *ov += av * bv;
                }
            }
        }
    }
}

/// Shared precondition checks for the unsafe SIMD paths. These guard raw
/// pointer arithmetic, so they are real asserts — they must not compile
/// out of release builds.
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
fn check_gemm_bounds(a: &[f32], b: &[f32], out: &[f32], k: usize, n: usize, pack: &[f32]) {
    assert_eq!(a.len() % k, 0, "gemm: a is not whole rows");
    let rows = a.len() / k;
    assert_eq!(out.len(), rows * n, "gemm: out shape mismatch");
    assert_eq!(b.len(), k * n, "gemm: b shape mismatch");
    assert!(
        rows < GEMM_MR || pack.len() >= gemm_pack_len(k),
        "gemm: pack scratch too small ({} < {}) for {rows} rows",
        pack.len(),
        gemm_pack_len(k)
    );
}

/// Scalar accumulation of one row's column tail (`j0..n`), shared by the
/// SIMD paths. Ascending `k`, plain mul-add — deterministic, and the
/// same op sequence for every ISA and row partition.
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
fn scalar_col_tail(arow: &[f32], b: &[f32], tail: &mut [f32], n: usize, j0: usize) {
    for (kk, &av) in arow.iter().enumerate() {
        let btail = &b[kk * n + j0..kk * n + j0 + tail.len()];
        for (ov, &bv) in tail.iter_mut().zip(btail) {
            *ov += av * bv;
        }
    }
}

/// Pack a `GEMM_MR`-row block of `a` (rows `r0..r0+GEMM_MR`, row stride
/// `k`) `k`-major into `pack`: `pack[kk*GEMM_MR + r] = a[(r0+r)*k + kk]`,
/// so the microkernel's broadcast loads walk contiguous memory.
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
fn pack_a_block(a: &[f32], r0: usize, k: usize, pack: &mut [f32]) {
    for r in 0..GEMM_MR {
        let arow = &a[(r0 + r) * k..(r0 + r + 1) * k];
        for (kk, &av) in arow.iter().enumerate() {
            pack[kk * GEMM_MR + r] = av;
        }
    }
}

// ---------------------------------------------------------------------------
// AVX2 + FMA (x86_64)
// ---------------------------------------------------------------------------

/// The 4×16 AVX2+FMA GEMM driver. Full `GEMM_MR`-row blocks run the
/// packed 4×16 microkernel; leftover rows run the 1×16 kernel (identical
/// per-element op sequence); the `n % MM_TILE` column tail accumulates
/// through [`scalar_col_tail`].
///
/// Safety: caller must have verified the slice bounds
/// ([`check_gemm_bounds`]) and that the host supports AVX2 and FMA.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[target_feature(enable = "fma")]
unsafe fn gemm_avx2(a: &[f32], b: &[f32], out: &mut [f32], k: usize, n: usize, pack: &mut [f32]) {
    use std::arch::x86_64::*;

    let rows = a.len() / k;
    let n_tiles = n - n % MM_TILE;
    let bp = b.as_ptr();
    let op = out.as_mut_ptr();
    let mut r0 = 0;
    while r0 + GEMM_MR <= rows {
        let mut j = 0;
        // Packing only pays where the vector kernel reads it; a fully
        // sub-tile output (n < MM_TILE) goes straight to the scalar tail.
        if n_tiles > 0 {
            pack_a_block(a, r0, k, pack);
            let pp = pack.as_ptr();
            while j < n_tiles {
                let mut acc = [[_mm256_setzero_ps(); 2]; GEMM_MR];
                for kk in 0..k {
                    let b0 = _mm256_loadu_ps(bp.add(kk * n + j));
                    let b1 = _mm256_loadu_ps(bp.add(kk * n + j + 8));
                    for (r, arow_acc) in acc.iter_mut().enumerate() {
                        let av = _mm256_broadcast_ss(&*pp.add(kk * GEMM_MR + r));
                        arow_acc[0] = _mm256_fmadd_ps(av, b0, arow_acc[0]);
                        arow_acc[1] = _mm256_fmadd_ps(av, b1, arow_acc[1]);
                    }
                }
                for (r, arow_acc) in acc.iter().enumerate() {
                    let orow = op.add((r0 + r) * n + j);
                    _mm256_storeu_ps(orow, arow_acc[0]);
                    _mm256_storeu_ps(orow.add(8), arow_acc[1]);
                }
                j += MM_TILE;
            }
        }
        if j < n {
            for r in 0..GEMM_MR {
                let row = r0 + r;
                // Tail slice re-derived from the same raw pointer every
                // SIMD store went through, so no fresh `out` reborrow
                // invalidates it mid-loop.
                let tail = std::slice::from_raw_parts_mut(op.add(row * n + j), n - j);
                scalar_col_tail(&a[row * k..(row + 1) * k], b, tail, n, j);
            }
        }
        r0 += GEMM_MR;
    }
    while r0 < rows {
        let arow = &a[r0 * k..(r0 + 1) * k];
        let ap = arow.as_ptr();
        let mut j = 0;
        while j < n_tiles {
            let mut acc0 = _mm256_setzero_ps();
            let mut acc1 = _mm256_setzero_ps();
            for kk in 0..k {
                let av = _mm256_broadcast_ss(&*ap.add(kk));
                acc0 = _mm256_fmadd_ps(av, _mm256_loadu_ps(bp.add(kk * n + j)), acc0);
                acc1 = _mm256_fmadd_ps(av, _mm256_loadu_ps(bp.add(kk * n + j + 8)), acc1);
            }
            let orow = op.add(r0 * n + j);
            _mm256_storeu_ps(orow, acc0);
            _mm256_storeu_ps(orow.add(8), acc1);
            j += MM_TILE;
        }
        if j < n {
            let tail = std::slice::from_raw_parts_mut(op.add(r0 * n + j), n - j);
            scalar_col_tail(arow, b, tail, n, j);
        }
        r0 += 1;
    }
}

/// AVX2+FMA `y += alpha·x`: 8-lane fused body, plain mul-add tail.
///
/// Safety: caller must have verified `x.len() == y.len()` and that the
/// host supports AVX2 and FMA.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[target_feature(enable = "fma")]
unsafe fn saxpy_avx2(alpha: f32, x: &[f32], y: &mut [f32]) {
    use std::arch::x86_64::*;

    let len = y.len();
    let body = len - len % 8;
    let av = _mm256_set1_ps(alpha);
    let xp = x.as_ptr();
    let yp = y.as_mut_ptr();
    let mut i = 0;
    while i < body {
        let yv = _mm256_fmadd_ps(av, _mm256_loadu_ps(xp.add(i)), _mm256_loadu_ps(yp.add(i)));
        _mm256_storeu_ps(yp.add(i), yv);
        i += 8;
    }
    for (yv, &xv) in y[body..].iter_mut().zip(&x[body..]) {
        *yv += alpha * xv;
    }
}

// ---------------------------------------------------------------------------
// NEON (aarch64)
// ---------------------------------------------------------------------------

/// The 4×16 NEON GEMM driver: four 4-lane accumulators per row, fused
/// multiply-adds (`vfmaq_n_f32`), same block structure and determinism
/// contract as [`gemm_avx2`].
///
/// Safety: caller must have verified the slice bounds
/// ([`check_gemm_bounds`]) and that the host supports NEON.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn gemm_neon(a: &[f32], b: &[f32], out: &mut [f32], k: usize, n: usize, pack: &mut [f32]) {
    use std::arch::aarch64::*;

    let rows = a.len() / k;
    let n_tiles = n - n % MM_TILE;
    let bp = b.as_ptr();
    let op = out.as_mut_ptr();
    let mut r0 = 0;
    while r0 + GEMM_MR <= rows {
        let mut j = 0;
        // Packing only pays where the vector kernel reads it; a fully
        // sub-tile output (n < MM_TILE) goes straight to the scalar tail.
        if n_tiles > 0 {
            pack_a_block(a, r0, k, pack);
            let pp = pack.as_ptr();
            while j < n_tiles {
                let mut acc = [[vdupq_n_f32(0.0); 4]; GEMM_MR];
                for kk in 0..k {
                    let b0 = vld1q_f32(bp.add(kk * n + j));
                    let b1 = vld1q_f32(bp.add(kk * n + j + 4));
                    let b2 = vld1q_f32(bp.add(kk * n + j + 8));
                    let b3 = vld1q_f32(bp.add(kk * n + j + 12));
                    for (r, arow_acc) in acc.iter_mut().enumerate() {
                        let av = *pp.add(kk * GEMM_MR + r);
                        arow_acc[0] = vfmaq_n_f32(arow_acc[0], b0, av);
                        arow_acc[1] = vfmaq_n_f32(arow_acc[1], b1, av);
                        arow_acc[2] = vfmaq_n_f32(arow_acc[2], b2, av);
                        arow_acc[3] = vfmaq_n_f32(arow_acc[3], b3, av);
                    }
                }
                for (r, arow_acc) in acc.iter().enumerate() {
                    let orow = op.add((r0 + r) * n + j);
                    vst1q_f32(orow, arow_acc[0]);
                    vst1q_f32(orow.add(4), arow_acc[1]);
                    vst1q_f32(orow.add(8), arow_acc[2]);
                    vst1q_f32(orow.add(12), arow_acc[3]);
                }
                j += MM_TILE;
            }
        }
        if j < n {
            for r in 0..GEMM_MR {
                let row = r0 + r;
                // Tail slice re-derived from the SIMD stores' raw pointer
                // (see gemm_avx2).
                let tail = std::slice::from_raw_parts_mut(op.add(row * n + j), n - j);
                scalar_col_tail(&a[row * k..(row + 1) * k], b, tail, n, j);
            }
        }
        r0 += GEMM_MR;
    }
    while r0 < rows {
        let arow = &a[r0 * k..(r0 + 1) * k];
        let ap = arow.as_ptr();
        let mut j = 0;
        while j < n_tiles {
            let mut acc = [vdupq_n_f32(0.0); 4];
            for kk in 0..k {
                let av = *ap.add(kk);
                acc[0] = vfmaq_n_f32(acc[0], vld1q_f32(bp.add(kk * n + j)), av);
                acc[1] = vfmaq_n_f32(acc[1], vld1q_f32(bp.add(kk * n + j + 4)), av);
                acc[2] = vfmaq_n_f32(acc[2], vld1q_f32(bp.add(kk * n + j + 8)), av);
                acc[3] = vfmaq_n_f32(acc[3], vld1q_f32(bp.add(kk * n + j + 12)), av);
            }
            let orow = op.add(r0 * n + j);
            vst1q_f32(orow, acc[0]);
            vst1q_f32(orow.add(4), acc[1]);
            vst1q_f32(orow.add(8), acc[2]);
            vst1q_f32(orow.add(12), acc[3]);
            j += MM_TILE;
        }
        if j < n {
            let tail = std::slice::from_raw_parts_mut(op.add(r0 * n + j), n - j);
            scalar_col_tail(arow, b, tail, n, j);
        }
        r0 += 1;
    }
}

/// NEON `y += alpha·x`: 4-lane fused body, plain mul-add tail.
///
/// Safety: caller must have verified `x.len() == y.len()` and that the
/// host supports NEON.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn saxpy_neon(alpha: f32, x: &[f32], y: &mut [f32]) {
    use std::arch::aarch64::*;

    let len = y.len();
    let body = len - len % 4;
    let xp = x.as_ptr();
    let yp = y.as_mut_ptr();
    let mut i = 0;
    while i < body {
        let yv = vfmaq_n_f32(vld1q_f32(yp.add(i)), vld1q_f32(xp.add(i)), alpha);
        vst1q_f32(yp.add(i), yv);
        i += 4;
    }
    for (yv, &xv) in y[body..].iter_mut().zip(&x[body..]) {
        *yv += alpha * xv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Mat;

    fn seeded(rows: usize, cols: usize, salt: usize) -> Mat {
        Mat::from_fn(rows, cols, |r, c| {
            ((r * 31 + c * 17 + salt * 7) % 23) as f32 * 0.29 - 3.0
        })
    }

    /// Drive [`gemm_into`] like the native kernels do: zeroed out, a pack
    /// buffer sized by [`gemm_pack_len`].
    fn run_gemm(isa: Isa, a: &Mat, b: &Mat) -> Mat {
        let mut out = Mat::zeros(a.rows(), b.cols());
        let mut pack = vec![0.0f32; gemm_pack_len(a.cols())];
        gemm_into(
            isa,
            a.as_slice(),
            b.as_slice(),
            out.as_mut_slice(),
            a.cols(),
            b.cols(),
            &mut pack,
        );
        out
    }

    /// Shapes covering: empty, k = 0, single row, n < MM_TILE, tile
    /// remainders, row-block remainders (rows % GEMM_MR ≠ 0), and a
    /// realistic panel shape.
    const SHAPES: &[(usize, usize, usize)] = &[
        (0, 3, 4),
        (2, 0, 3),
        (2, 3, 0),
        (1, 1, 1),
        (1, 64, 16),
        (3, 5, MM_TILE),
        (4, 7, MM_TILE + 3),
        (5, 2, MM_TILE - 1),
        (6, 33, 2 * MM_TILE + 5),
        (7, 9, 48),
        (9, 128, 10),
    ];

    #[test]
    fn policy_parses_and_displays() {
        assert_eq!("auto".parse::<SimdPolicy>().unwrap(), SimdPolicy::Auto);
        assert_eq!("scalar".parse::<SimdPolicy>().unwrap(), SimdPolicy::Scalar);
        assert_eq!(SimdPolicy::Auto.to_string(), "auto");
        assert_eq!(SimdPolicy::default(), SimdPolicy::Auto);
        let e = "fast".parse::<SimdPolicy>().unwrap_err();
        assert!(e.contains("fast") && e.contains("scalar"), "{e}");
    }

    #[test]
    fn scalar_policy_always_resolves_scalar() {
        assert_eq!(Isa::detect(SimdPolicy::Scalar), Isa::Scalar);
        // auto resolves to *something* this host supports; its name is a
        // non-empty telemetry string either way.
        assert!(!Isa::detect(SimdPolicy::Auto).name().is_empty());
    }

    #[test]
    fn scalar_gemm_is_bitwise_reference_equal() {
        // The scalar path's own unit contract. The full seeded-random
        // awkward-shape sweep — scalar exact AND the detected ISA within
        // 1e-4 / deterministic — lives in tests/kernel_equivalence.rs
        // (one copy, per the documented contract), so it is not
        // duplicated here.
        for &(m, k, n) in SHAPES {
            let a = seeded(m, k, 1);
            let b = seeded(k, n, 2);
            let got = run_gemm(Isa::Scalar, &a, &b);
            assert_eq!(got.as_slice(), a.matmul_ref(&b).as_slice(), "({m},{k},{n})");
        }
    }

    #[test]
    fn unsupported_isa_degrades_to_scalar_not_a_fault() {
        // Isa's variants are public: a hand-constructed SIMD value on a
        // host/build without that ISA must run the scalar fallback
        // (bitwise), never execute unsupported instructions.
        let supported = Isa::detect(SimdPolicy::Auto);
        let a = seeded(5, 9, 8);
        let b = seeded(9, 20, 9);
        let want = a.matmul_ref(&b);
        for isa in [Isa::Avx2Fma, Isa::Neon] {
            if isa == supported {
                continue; // genuinely available here — covered elsewhere
            }
            assert_eq!(run_gemm(isa, &a, &b).as_slice(), want.as_slice(), "{}", isa.name());
            let x = [0.5f32; 11];
            let mut y_fallback = [1.0f32; 11];
            let mut y_scalar = [1.0f32; 11];
            saxpy_into(isa, 0.3, &x, &mut y_fallback);
            saxpy_into(Isa::Scalar, 0.3, &x, &mut y_scalar);
            assert_eq!(y_fallback, y_scalar, "{}", isa.name());
        }
    }

    #[test]
    fn gemm_is_row_partition_invariant() {
        // Splitting the A/out rows at any point (as the pool's balanced
        // partition does) must not change a single bit — rows grouped
        // into GEMM_MR blocks and remainder rows share one per-element
        // op sequence.
        let isa = Isa::detect(SimdPolicy::Auto);
        let (m, k, n) = (11usize, 37usize, 26usize);
        let a = seeded(m, k, 5);
        let b = seeded(k, n, 6);
        let whole = run_gemm(isa, &a, &b);
        for split in [1usize, 3, 4, 7, 10] {
            let mut out = Mat::zeros(m, n);
            let mut pack = vec![0.0f32; gemm_pack_len(k)];
            let (top, bottom) = out.as_mut_slice().split_at_mut(split * n);
            gemm_into(isa, &a.as_slice()[..split * k], b.as_slice(), top, k, n, &mut pack);
            gemm_into(isa, &a.as_slice()[split * k..], b.as_slice(), bottom, k, n, &mut pack);
            assert_eq!(out.as_slice(), whole.as_slice(), "split at {split}");
        }
    }

    #[test]
    fn saxpy_matches_scalar_loop() {
        let isa = Isa::detect(SimdPolicy::Auto);
        for len in [0usize, 1, 2, 7, 8, 9, 10, 31, 64] {
            let x: Vec<f32> = (0..len).map(|i| (i as f32) * 0.37 - 2.0).collect();
            let mut y_simd: Vec<f32> = (0..len).map(|i| (i as f32) * -0.11 + 1.0).collect();
            let mut y_ref = y_simd.clone();
            saxpy_into(isa, 0.7, &x, &mut y_simd);
            for (yv, &xv) in y_ref.iter_mut().zip(&x) {
                *yv += 0.7 * xv;
            }
            for (s, r) in y_simd.iter().zip(&y_ref) {
                assert!((s - r).abs() <= 1e-5, "len {len}: {s} vs {r}");
            }
            // scalar dispatch is the plain loop, bitwise
            let mut y_scalar: Vec<f32> = (0..len).map(|i| (i as f32) * -0.11 + 1.0).collect();
            saxpy_into(Isa::Scalar, 0.7, &x, &mut y_scalar);
            assert_eq!(y_scalar, y_ref);
        }
    }

    #[test]
    #[should_panic(expected = "saxpy_into: length mismatch")]
    fn saxpy_rejects_length_mismatch() {
        let x = [1.0f32; 3];
        let mut y = [0.0f32; 4];
        saxpy_into(Isa::Scalar, 1.0, &x, &mut y);
    }
}
