//! A counting global allocator for the allocation gates.
//!
//! [`CountingAlloc`] forwards every request to the system allocator while
//! counting calls and bytes in relaxed atomics. It is compiled
//! unconditionally (a few instructions, zero cost unless installed) so
//! that *out-of-crate* binaries — the `alloc_gate` integration test and
//! the `hotpath` bench, which are separate crates and cannot see
//! `#[cfg(test)]` items — can install it:
//!
//! ```ignore
//! use codedfedl::benchutil::CountingAlloc;
//!
//! #[global_allocator]
//! static ALLOC: CountingAlloc = CountingAlloc;
//!
//! let a0 = CountingAlloc::allocations();
//! run_warm_round();
//! assert_eq!(CountingAlloc::allocations() - a0, 0);
//! ```
//!
//! Counters are process-global: measurements are only meaningful when
//! nothing else allocates concurrently (keep gated measurements in a
//! binary with a single test, as `tests/alloc_gate.rs` does).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static BYTES: AtomicU64 = AtomicU64::new(0);

/// System-allocator wrapper that counts allocation calls and bytes.
/// Install with `#[global_allocator]`; read with the associated fns.
pub struct CountingAlloc;

impl CountingAlloc {
    /// Allocation calls (alloc / alloc_zeroed / realloc) since process
    /// start. Frees are not counted: the gates care about *acquiring*
    /// memory on the hot path.
    pub fn allocations() -> u64 {
        ALLOCS.load(Ordering::Relaxed)
    }

    /// Bytes requested by the counted calls since process start.
    pub fn bytes() -> u64 {
        BYTES.load(Ordering::Relaxed)
    }
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}
