//! Shared harness for the benches and examples: a small timing framework
//! (criterion is unavailable offline — this provides warmup + median/MAD),
//! a machine-readable [`BenchReport`] (the tracked `BENCH_hotpath.json`
//! baseline future PRs diff against — see `rust/PERF.md`), the
//! [`CountingAlloc`] allocation gate, one-call experiment runners, and
//! ASCII renderings of the paper's figures.

mod count_alloc;

pub use count_alloc::CountingAlloc;

use std::path::Path;
use std::time::Instant;

use anyhow::Result;

use crate::conf::ExperimentConfig;
use crate::coordinator::TrainOutcome;
use crate::experiment::{ExperimentBuilder, Session};
use crate::metrics::{History, OutcomeCounts};
use crate::runtime::{Runtime, RuntimeShapes};
use crate::schemes::SchemeSpec;

/// Timing summary of one benchmark target.
#[derive(Clone, Copy, Debug)]
pub struct TimingStats {
    pub iters: usize,
    pub median_ns: f64,
    pub mean_ns: f64,
    /// Median absolute deviation — robust spread.
    pub mad_ns: f64,
}

impl TimingStats {
    pub fn line(&self, name: &str) -> String {
        fn fmt(ns: f64) -> String {
            if ns >= 1e9 {
                format!("{:.3} s", ns / 1e9)
            } else if ns >= 1e6 {
                format!("{:.3} ms", ns / 1e6)
            } else if ns >= 1e3 {
                format!("{:.3} µs", ns / 1e3)
            } else {
                format!("{ns:.0} ns")
            }
        }
        format!(
            "{name:<44} median {:>12}  mean {:>12}  mad {:>10}  (n={})",
            fmt(self.median_ns),
            fmt(self.mean_ns),
            fmt(self.mad_ns),
            self.iters
        )
    }
}

/// Time `f` with warmup; prints and returns the stats.
pub fn bench(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> TimingStats {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let mut devs: Vec<f64> = samples.iter().map(|s| (s - median).abs()).collect();
    devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let stats = TimingStats {
        iters,
        median_ns: median,
        mean_ns: mean,
        mad_ns: devs[devs.len() / 2],
    };
    println!("{}", stats.line(name));
    stats
}

/// True when the `BENCH_SMOKE` env var is set (and not `0`): benches run a
/// fast smoke pass — 1 warmup, 2 iters — so CI can exercise the harness
/// and the kernel oracle checks without paying full measurement time.
pub fn smoke_mode() -> bool {
    std::env::var("BENCH_SMOKE").map(|v| !v.is_empty() && v != "0").unwrap_or(false)
}

/// `(warmup, iters)` honouring [`smoke_mode`].
pub fn bench_iters(warmup: usize, iters: usize) -> (usize, usize) {
    if smoke_mode() {
        (1, 2)
    } else {
        (warmup, iters)
    }
}

/// One machine-readable benchmark record (a row of `BENCH_hotpath.json`).
#[derive(Clone, Debug)]
pub struct BenchRecord {
    /// Operation name, e.g. `runtime::grad`.
    pub op: String,
    /// Shape/workload label, e.g. `client 200x512x10`.
    pub shape: String,
    /// Median nanoseconds per iteration.
    pub ns_per_iter: f64,
    /// Worker-thread count the op ran with.
    pub threads: usize,
    /// Timed iterations behind the median.
    pub iters: usize,
    /// Achieved throughput in GFLOP/s (`flops / ns_per_iter`), for ops
    /// with a known multiply-add count (schema 3). `None` for composite
    /// targets (full rounds/epochs) whose flop count is not meaningful.
    pub gflops: Option<f64>,
    /// Achieved byte throughput in GB/s (`bytes / ns_per_iter`), for the
    /// GF(256) row kernels and codec ops (schema 4). `None` elsewhere.
    pub gbps: Option<f64>,
    /// Achieved coded symbols per second, for the erasure codec's
    /// encode/decode ops (schema 4). `None` elsewhere.
    pub symbols_per_s: Option<f64>,
    /// Simulated fleet size N behind a `fleet_scale` row (schema 5):
    /// the per-round decision path is timed at several N to pin that its
    /// cost depends on the roster size K, not on N. `None` elsewhere.
    pub n_clients: Option<usize>,
    /// Achieved decision-path rounds per second (`1e9 / ns_per_iter`),
    /// recorded on `fleet_scale` rows (schema 5). `None` elsewhere.
    pub rounds_per_s: Option<f64>,
    /// Degradation-ladder rung histogram of the training run behind a
    /// `degraded` row (schema 6), in [`OutcomeCounts::as_array`] order:
    /// `[full, exact_decode, parity, partial, skip]`. `None` elsewhere.
    pub rungs: Option<[u64; 5]>,
    /// Achieved-participation fraction (arrived / planned gradients) of
    /// the run behind a `degraded` row (schema 6). `None` elsewhere.
    pub achieved_participation: Option<f64>,
}

/// Collects [`TimingStats`] into the tracked-baseline JSON the perf
/// workflow uploads and `rust/PERF.md` records. Serialisation is
/// hand-rolled (serde is unavailable offline); all strings are ASCII
/// op/shape labels we control.
#[derive(Clone, Debug, Default)]
pub struct BenchReport {
    pub records: Vec<BenchRecord>,
    /// Heap allocations measured across one warm steady-state round's
    /// compute path (see the hotpath bench). `None` when the run did not
    /// measure it; the committed baseline must record `Some(0)` — the
    /// allocation-free contract of `tests/alloc_gate.rs`.
    pub allocs_per_round: Option<u64>,
    /// The GEMM microkernel ISA the run's runtime resolved
    /// (`Runtime::isa_name()`: `scalar` / `avx2+fma` / `neon` / `pjrt`)
    /// — required non-empty by the schema-3 baseline validator so perf
    /// numbers are always attributable to an instruction set.
    pub isa: String,
    /// Modelled wire bytes per round of the default pipeline (codec
    /// `none`, down + up) on the tiny preset (schema 8): the tracked
    /// denominator the `[comm]` codec rows shrink against. `None` when
    /// the run did not measure it.
    pub bytes_per_round: Option<u64>,
}

impl BenchReport {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append a record for an already-timed op, without a flop count.
    pub fn record(&mut self, op: &str, shape: &str, threads: usize, stats: &TimingStats) {
        self.record_flops(op, shape, threads, stats, None);
    }

    /// Append a record for an already-timed op; `flops` (multiply-adds
    /// counted as 2 floating-point ops) yields the record's GFLOP/s.
    pub fn record_flops(
        &mut self,
        op: &str,
        shape: &str,
        threads: usize,
        stats: &TimingStats,
        flops: Option<u64>,
    ) {
        self.records.push(BenchRecord {
            op: op.to_string(),
            shape: shape.to_string(),
            ns_per_iter: stats.median_ns,
            threads,
            iters: stats.iters,
            // flops/ns ≡ GFLOP/s
            gflops: flops.map(|f| f as f64 / stats.median_ns),
            gbps: None,
            symbols_per_s: None,
            n_clients: None,
            rounds_per_s: None,
            rungs: None,
            achieved_participation: None,
        });
    }

    /// Append a `fleet_scale` record (schema 5): one per-round
    /// decision-path iteration over an `n_clients`-client fleet. Derives
    /// rounds/s from the median so the baseline can assert the
    /// throughput stays flat as N grows.
    pub fn record_fleet(
        &mut self,
        op: &str,
        shape: &str,
        threads: usize,
        stats: &TimingStats,
        n_clients: usize,
    ) {
        self.records.push(BenchRecord {
            op: op.to_string(),
            shape: shape.to_string(),
            ns_per_iter: stats.median_ns,
            threads,
            iters: stats.iters,
            gflops: None,
            gbps: None,
            symbols_per_s: None,
            n_clients: Some(n_clients),
            // 1e9 ns/s ÷ ns/round ≡ rounds/s
            rounds_per_s: Some(1e9 / stats.median_ns),
            rungs: None,
            achieved_participation: None,
        });
    }

    /// Append a `degraded` record (schema 6): a training run under fault
    /// injection and/or a round deadline, annotated with how its rounds
    /// resolved (the degradation-ladder rung histogram) and the fraction
    /// of planned gradients that actually arrived — so a perf diff can
    /// tell a genuinely faster run from one that silently skipped rounds.
    pub fn record_degraded(
        &mut self,
        op: &str,
        shape: &str,
        threads: usize,
        stats: &TimingStats,
        outcomes: &OutcomeCounts,
        achieved_participation: f64,
    ) {
        self.records.push(BenchRecord {
            op: op.to_string(),
            shape: shape.to_string(),
            ns_per_iter: stats.median_ns,
            threads,
            iters: stats.iters,
            gflops: None,
            gbps: None,
            symbols_per_s: None,
            n_clients: None,
            rounds_per_s: None,
            rungs: Some(outcomes.as_array()),
            achieved_participation: Some(achieved_participation),
        });
    }

    /// Append a record for an already-timed coding op: `bytes` processed
    /// per iteration yields GB/s, `symbols` per iteration yields symbols/s
    /// (schema 4's codec throughput columns).
    pub fn record_throughput(
        &mut self,
        op: &str,
        shape: &str,
        threads: usize,
        stats: &TimingStats,
        bytes: Option<u64>,
        symbols: Option<u64>,
    ) {
        self.records.push(BenchRecord {
            op: op.to_string(),
            shape: shape.to_string(),
            ns_per_iter: stats.median_ns,
            threads,
            iters: stats.iters,
            gflops: None,
            // bytes/ns ≡ GB/s; symbols/ns · 1e9 ≡ symbols/s
            gbps: bytes.map(|b| b as f64 / stats.median_ns),
            symbols_per_s: symbols.map(|s| s as f64 * 1e9 / stats.median_ns),
            n_clients: None,
            rounds_per_s: None,
            rungs: None,
            achieved_participation: None,
        });
    }

    /// [`BenchReport::bench`] for a coding op with known per-iteration
    /// byte and/or symbol counts: records GB/s and symbols/s alongside
    /// the timing.
    #[allow(clippy::too_many_arguments)] // bench() plus two throughput counts
    pub fn bench_throughput(
        &mut self,
        op: &str,
        shape: &str,
        threads: usize,
        warmup: usize,
        iters: usize,
        bytes: Option<u64>,
        symbols: Option<u64>,
        f: impl FnMut(),
    ) -> TimingStats {
        let stats = bench(&format!("{op} ({shape})"), warmup, iters, f);
        self.record_throughput(op, shape, threads, &stats, bytes, symbols);
        stats
    }

    /// Time `f` via [`bench`] (printing the human-readable line) and
    /// append the result. `warmup`/`iters` are taken as given — pass them
    /// through [`bench_iters`] first if smoke mode should apply.
    pub fn bench(
        &mut self,
        op: &str,
        shape: &str,
        threads: usize,
        warmup: usize,
        iters: usize,
        f: impl FnMut(),
    ) -> TimingStats {
        let stats = bench(&format!("{op} ({shape})"), warmup, iters, f);
        self.record(op, shape, threads, &stats);
        stats
    }

    /// [`BenchReport::bench`] for an op with a known flop count: records
    /// achieved GFLOP/s alongside the timing.
    #[allow(clippy::too_many_arguments)] // bench() plus one flop count
    pub fn bench_flops(
        &mut self,
        op: &str,
        shape: &str,
        threads: usize,
        warmup: usize,
        iters: usize,
        flops: u64,
        f: impl FnMut(),
    ) -> TimingStats {
        let stats = bench(&format!("{op} ({shape})"), warmup, iters, f);
        self.record_flops(op, shape, threads, &stats, Some(flops));
        stats
    }

    /// The report as a JSON document.
    pub fn to_json(&self) -> String {
        fn esc(s: &str) -> String {
            s.replace('\\', "\\\\").replace('"', "\\\"")
        }
        let mut out = String::from("{\n  \"schema\": 8,\n");
        out.push_str(&format!("  \"smoke\": {},\n", smoke_mode()));
        out.push_str(&format!("  \"isa\": \"{}\",\n", esc(&self.isa)));
        match self.allocs_per_round {
            Some(n) => out.push_str(&format!("  \"allocs_per_round\": {n},\n")),
            None => out.push_str("  \"allocs_per_round\": null,\n"),
        }
        match self.bytes_per_round {
            Some(n) => out.push_str(&format!("  \"bytes_per_round\": {n},\n")),
            None => out.push_str("  \"bytes_per_round\": null,\n"),
        }
        out.push_str("  \"records\": [\n");
        fn opt(v: Option<f64>) -> String {
            match v {
                Some(x) => format!("{x:.3}"),
                None => "null".to_string(),
            }
        }
        for (i, r) in self.records.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"op\": \"{}\", \"shape\": \"{}\", \"ns_per_iter\": {:.1}, \
                 \"threads\": {}, \"iters\": {}, \"gflops\": {}, \"gbps\": {}, \
                 \"symbols_per_s\": {}, \"n_clients\": {}, \"rounds_per_s\": {}, \
                 \"rungs\": {}, \"achieved_participation\": {}}}{}\n",
                esc(&r.op),
                esc(&r.shape),
                r.ns_per_iter,
                r.threads,
                r.iters,
                opt(r.gflops),
                opt(r.gbps),
                opt(r.symbols_per_s),
                match r.n_clients {
                    Some(n) => n.to_string(),
                    None => "null".to_string(),
                },
                opt(r.rounds_per_s),
                match r.rungs {
                    Some(h) => format!("[{}, {}, {}, {}, {}]", h[0], h[1], h[2], h[3], h[4]),
                    None => "null".to_string(),
                },
                opt(r.achieved_participation),
                if i + 1 == self.records.len() { "" } else { "," }
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Write the JSON report to `path` atomically ([`crate::io::atomic_write`]:
    /// temp file + fsync + rename), so an interrupted bench run can never
    /// leave a torn baseline for the validator to misread.
    pub fn write_json(&self, path: &Path) -> Result<()> {
        crate::io::atomic_write(path, self.to_json().as_bytes())
            .map_err(|e| anyhow::anyhow!("writing bench report {path:?}: {e}"))
    }
}

/// Derive the runtime shape set from an experiment config (thin re-export
/// of [`crate::experiment::shapes_for`] for bench ergonomics).
pub fn shapes_for(cfg: &ExperimentConfig) -> RuntimeShapes {
    crate::experiment::shapes_for(cfg)
}

/// Load the runtime for a config.
pub fn load_runtime(cfg: &ExperimentConfig) -> Result<Runtime> {
    crate::experiment::load_runtime(cfg)
}

/// Build a [`Session`] for `cfg` and run each scheme spec on it (shared
/// data/fleet — the paper's fair-comparison setup in one call).
pub fn run_experiment(
    cfg: &ExperimentConfig,
    schemes: &[SchemeSpec],
) -> Result<(Session, Vec<(SchemeSpec, TrainOutcome)>)> {
    let session = ExperimentBuilder::from_config(cfg.clone()).build()?;
    let mut out = Vec::with_capacity(schemes.len());
    for &s in schemes {
        eprintln!("[run] scheme {} ...", s.label());
        let r = session.run_spec(s)?;
        eprintln!(
            "[run]   final acc {:.3}  sim time {:.1} h  ({} iters)",
            r.history.final_accuracy(),
            r.history.total_sim_time() / 3600.0,
            r.history.points.len()
        );
        out.push((s, r));
    }
    Ok((session, out))
}

/// ASCII plot of several histories: accuracy vs a chosen x-axis.
pub fn ascii_curves(
    title: &str,
    histories: &[&History],
    x_of: impl Fn(&crate::metrics::Point) -> f64,
    x_label: &str,
) -> String {
    const W: usize = 72;
    const H: usize = 20;
    let mut xmax = 0.0f64;
    let mut ymax = 0.0f64;
    for h in histories {
        for p in &h.points {
            xmax = xmax.max(x_of(p));
            ymax = ymax.max(p.accuracy);
        }
    }
    if xmax <= 0.0 {
        xmax = 1.0;
    }
    ymax = (ymax * 1.05).min(1.0).max(0.1);
    let mut grid = vec![vec![b' '; W]; H];
    let marks = [b'*', b'o', b'+', b'x', b'#', b'@'];
    for (hi, h) in histories.iter().enumerate() {
        for p in &h.points {
            let xi = ((x_of(p) / xmax) * (W - 1) as f64).round() as usize;
            let yi = ((p.accuracy / ymax) * (H - 1) as f64).round() as usize;
            let row = H - 1 - yi.min(H - 1);
            grid[row][xi.min(W - 1)] = marks[hi % marks.len()];
        }
    }
    let mut s = format!("{title}\n");
    for (i, row) in grid.iter().enumerate() {
        let yv = ymax * (H - 1 - i) as f64 / (H - 1) as f64;
        s.push_str(&format!("{:5.2} |{}\n", yv, String::from_utf8_lossy(row)));
    }
    s.push_str(&format!("      +{}\n", "-".repeat(W)));
    s.push_str(&format!("       0 … {xmax:.3e}  ({x_label})\n"));
    for (hi, h) in histories.iter().enumerate() {
        s.push_str(&format!("       {} = {}\n", marks[hi % marks.len()] as char, h.label));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Point;

    #[test]
    fn bench_returns_sane_stats() {
        let s = bench("noop", 1, 5, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(s.iters, 5);
        assert!(s.median_ns >= 0.0);
        assert!(s.mean_ns >= 0.0);
    }

    #[test]
    fn bench_report_serialises_records() {
        let mut rep = BenchReport::new();
        rep.isa = "avx2+fma".to_string();
        let stats = TimingStats { iters: 5, median_ns: 1234.5, mean_ns: 1300.0, mad_ns: 10.0 };
        rep.record_flops("runtime::grad", "client 200x512x10", 4, &stats, Some(2_469));
        rep.record("full coded epoch", "tiny", 1, &stats);
        // codec row: 2469 bytes and 2 symbols per iteration
        rep.record_throughput("coding::encode", "dense 10+5", 1, &stats, Some(2_469), Some(2));
        // fleet row: one sampled-round decision path over 100k clients
        rep.record_fleet("fleet_scale::round", "n=100000 sample:k=31", 1, &stats, 100_000);
        // degraded row: a faulted run that resolved 3 rounds full, 1 via
        // parity compensation — with 87.5% of planned gradients arrived
        let outcomes = OutcomeCounts { full: 3, parity: 1, ..Default::default() };
        rep.record_degraded("degraded::epoch", "tiny mixed", 1, &stats, &outcomes, 0.875);
        let json = rep.to_json();
        assert!(json.contains("\"schema\": 8"), "{json}");
        assert!(json.contains("\"isa\": \"avx2+fma\""), "{json}");
        assert!(json.contains("\"op\": \"runtime::grad\""), "{json}");
        assert!(json.contains("\"shape\": \"client 200x512x10\""), "{json}");
        assert!(json.contains("\"ns_per_iter\": 1234.5"), "{json}");
        assert!(json.contains("\"threads\": 4"), "{json}");
        // 2469 flops / 1234.5 ns = 2.000 GFLOP/s; composite rows get null
        assert!(json.contains("\"gflops\": 2.000"), "{json}");
        assert!(json.contains("\"gflops\": null"), "{json}");
        // 2469 bytes / 1234.5 ns = 2.000 GB/s; 2 symbols / 1234.5 ns =
        // 1_620_089 symbols/s; non-codec rows carry null
        assert!(json.contains("\"gbps\": 2.000"), "{json}");
        assert!(json.contains("\"symbols_per_s\": 1620089."), "{json}");
        assert!(json.contains("\"gbps\": null"), "{json}");
        assert!(json.contains("\"symbols_per_s\": null"), "{json}");
        // fleet rows carry N and rounds/s (1e9 / 1234.5 ns); others null
        assert!(json.contains("\"n_clients\": 100000"), "{json}");
        assert!(json.contains("\"rounds_per_s\": 810044."), "{json}");
        assert!(json.contains("\"n_clients\": null"), "{json}");
        assert!(json.contains("\"rounds_per_s\": null"), "{json}");
        // degraded rows carry the rung histogram ([full, exact_decode,
        // parity, partial, skip]) and achieved participation; others null
        assert!(json.contains("\"rungs\": [3, 0, 1, 0, 0]"), "{json}");
        assert!(json.contains("\"achieved_participation\": 0.875"), "{json}");
        assert!(json.contains("\"rungs\": null"), "{json}");
        assert!(json.contains("\"achieved_participation\": null"), "{json}");
        // unmeasured allocation gate serialises as null…
        assert!(json.contains("\"allocs_per_round\": null"), "{json}");
        assert!(json.contains("\"bytes_per_round\": null"), "{json}");
        // a trailing comma between consecutive records, none after the last
        assert_eq!(json.matches("},\n").count(), 4, "{json}");
        // …and a measured one as the number
        rep.allocs_per_round = Some(0);
        rep.bytes_per_round = Some(7_040_000);
        let json = rep.to_json();
        assert!(json.contains("\"allocs_per_round\": 0"), "{json}");
        assert!(json.contains("\"bytes_per_round\": 7040000"), "{json}");
    }

    #[test]
    fn ascii_curves_renders() {
        let mut h = History::new("demo");
        for i in 1..=10 {
            h.push(Point {
                iter: i,
                sim_time: i as f64,
                accuracy: i as f64 / 10.0,
                train_loss: 0.0,
            });
        }
        let s = ascii_curves("T", &[&h], |p| p.sim_time, "s");
        assert!(s.contains("demo"));
        assert!(s.contains('*'));
        assert!(s.lines().count() > 20);
    }

    #[test]
    fn shapes_match_config() {
        let cfg = ExperimentConfig::tiny();
        let s = shapes_for(&cfg);
        assert_eq!(s.q, cfg.q);
        assert_eq!(s.l_client, cfg.local_batch);
        assert_eq!(s.u_max, cfg.u_max);
    }
}
