//! Shared harness for the benches and examples: a small timing framework
//! (criterion is unavailable offline — this provides warmup + median/MAD),
//! one-call experiment runners, and ASCII renderings of the paper's
//! figures.

use std::time::Instant;

use anyhow::Result;

use crate::conf::ExperimentConfig;
use crate::coordinator::TrainOutcome;
use crate::experiment::{ExperimentBuilder, Session};
use crate::metrics::History;
use crate::runtime::{Runtime, RuntimeShapes};
use crate::schemes::SchemeSpec;

/// Timing summary of one benchmark target.
#[derive(Clone, Copy, Debug)]
pub struct TimingStats {
    pub iters: usize,
    pub median_ns: f64,
    pub mean_ns: f64,
    /// Median absolute deviation — robust spread.
    pub mad_ns: f64,
}

impl TimingStats {
    pub fn line(&self, name: &str) -> String {
        fn fmt(ns: f64) -> String {
            if ns >= 1e9 {
                format!("{:.3} s", ns / 1e9)
            } else if ns >= 1e6 {
                format!("{:.3} ms", ns / 1e6)
            } else if ns >= 1e3 {
                format!("{:.3} µs", ns / 1e3)
            } else {
                format!("{ns:.0} ns")
            }
        }
        format!(
            "{name:<44} median {:>12}  mean {:>12}  mad {:>10}  (n={})",
            fmt(self.median_ns),
            fmt(self.mean_ns),
            fmt(self.mad_ns),
            self.iters
        )
    }
}

/// Time `f` with warmup; prints and returns the stats.
pub fn bench(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> TimingStats {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let mut devs: Vec<f64> = samples.iter().map(|s| (s - median).abs()).collect();
    devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let stats = TimingStats {
        iters,
        median_ns: median,
        mean_ns: mean,
        mad_ns: devs[devs.len() / 2],
    };
    println!("{}", stats.line(name));
    stats
}

/// Derive the runtime shape set from an experiment config (thin re-export
/// of [`crate::experiment::shapes_for`] for bench ergonomics).
pub fn shapes_for(cfg: &ExperimentConfig) -> RuntimeShapes {
    crate::experiment::shapes_for(cfg)
}

/// Load the runtime for a config.
pub fn load_runtime(cfg: &ExperimentConfig) -> Result<Runtime> {
    crate::experiment::load_runtime(cfg)
}

/// Build a [`Session`] for `cfg` and run each scheme spec on it (shared
/// data/fleet — the paper's fair-comparison setup in one call).
pub fn run_experiment(
    cfg: &ExperimentConfig,
    schemes: &[SchemeSpec],
) -> Result<(Session, Vec<(SchemeSpec, TrainOutcome)>)> {
    let session = ExperimentBuilder::from_config(cfg.clone()).build()?;
    let mut out = Vec::with_capacity(schemes.len());
    for &s in schemes {
        eprintln!("[run] scheme {} ...", s.label());
        let r = session.run_spec(s)?;
        eprintln!(
            "[run]   final acc {:.3}  sim time {:.1} h  ({} iters)",
            r.history.final_accuracy(),
            r.history.total_sim_time() / 3600.0,
            r.history.points.len()
        );
        out.push((s, r));
    }
    Ok((session, out))
}

/// ASCII plot of several histories: accuracy vs a chosen x-axis.
pub fn ascii_curves(
    title: &str,
    histories: &[&History],
    x_of: impl Fn(&crate::metrics::Point) -> f64,
    x_label: &str,
) -> String {
    const W: usize = 72;
    const H: usize = 20;
    let mut xmax = 0.0f64;
    let mut ymax = 0.0f64;
    for h in histories {
        for p in &h.points {
            xmax = xmax.max(x_of(p));
            ymax = ymax.max(p.accuracy);
        }
    }
    if xmax <= 0.0 {
        xmax = 1.0;
    }
    ymax = (ymax * 1.05).min(1.0).max(0.1);
    let mut grid = vec![vec![b' '; W]; H];
    let marks = [b'*', b'o', b'+', b'x', b'#', b'@'];
    for (hi, h) in histories.iter().enumerate() {
        for p in &h.points {
            let xi = ((x_of(p) / xmax) * (W - 1) as f64).round() as usize;
            let yi = ((p.accuracy / ymax) * (H - 1) as f64).round() as usize;
            let row = H - 1 - yi.min(H - 1);
            grid[row][xi.min(W - 1)] = marks[hi % marks.len()];
        }
    }
    let mut s = format!("{title}\n");
    for (i, row) in grid.iter().enumerate() {
        let yv = ymax * (H - 1 - i) as f64 / (H - 1) as f64;
        s.push_str(&format!("{:5.2} |{}\n", yv, String::from_utf8_lossy(row)));
    }
    s.push_str(&format!("      +{}\n", "-".repeat(W)));
    s.push_str(&format!("       0 … {xmax:.3e}  ({x_label})\n"));
    for (hi, h) in histories.iter().enumerate() {
        s.push_str(&format!("       {} = {}\n", marks[hi % marks.len()] as char, h.label));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Point;

    #[test]
    fn bench_returns_sane_stats() {
        let s = bench("noop", 1, 5, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(s.iters, 5);
        assert!(s.median_ns >= 0.0);
        assert!(s.mean_ns >= 0.0);
    }

    #[test]
    fn ascii_curves_renders() {
        let mut h = History::new("demo");
        for i in 1..=10 {
            h.push(Point {
                iter: i,
                sim_time: i as f64,
                accuracy: i as f64 / 10.0,
                train_loss: 0.0,
            });
        }
        let s = ascii_curves("T", &[&h], |p| p.sim_time, "s");
        assert!(s.contains("demo"));
        assert!(s.contains('*'));
        assert!(s.lines().count() > 20);
    }

    #[test]
    fn shapes_match_config() {
        let cfg = ExperimentConfig::tiny();
        let s = shapes_for(&cfg);
        assert_eq!(s.q, cfg.q);
        assert_eq!(s.l_client, cfg.local_batch);
        assert_eq!(s.u_max, cfg.u_max);
    }
}
