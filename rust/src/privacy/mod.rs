//! ε-mutual-information differential privacy accounting for sharing local
//! parity datasets (paper Appendix F, eq. 62).
//!
//! For a Gaussian generator matrix, sharing `u` parity rows of client `j`'s
//! database `X̂^(j)` leaks at most
//!
//! ```text
//! ε_j = ½ log₂(1 + u / f²(X̂^(j)))        [bits]
//! f(X̂) = min_k₂ sqrt( Σ_k₁ x_{k₁}(k₂)² − max_k₃ x_{k₃}(k₂)² )
//! ```
//!
//! `f` measures how concentrated the data is along its most vulnerable
//! feature: concentrated ⇒ small `f` ⇒ larger leakage.
//!
//! The bound is derived for a *Gaussian* dense generator matrix. For other
//! code/generator combinations ([`CodeSpec::Rateless`], Rademacher
//! generators) eq. (62) does not apply, and [`report`] marks the budget as
//! not applicable instead of printing a number the analysis does not
//! support.

use crate::coding::{CodeSpec, GeneratorKind};
use crate::tensor::Mat;

/// The feature-concentration statistic `f(X̂)` of eq. (62).
///
/// Returns 0 when some feature's energy is concentrated in a single data
/// point (maximal vulnerability).
pub fn concentration_f(xhat: &Mat) -> f64 {
    assert!(xhat.rows() > 0 && xhat.cols() > 0, "empty database");
    let mut min_val = f64::INFINITY;
    for k2 in 0..xhat.cols() {
        let mut sum_sq = 0.0f64;
        let mut max_sq = 0.0f64;
        for k1 in 0..xhat.rows() {
            let v = xhat.get(k1, k2) as f64;
            let sq = v * v;
            sum_sq += sq;
            max_sq = max_sq.max(sq);
        }
        min_val = min_val.min((sum_sq - max_sq).max(0.0));
    }
    min_val.sqrt()
}

/// ε-MI-DP privacy budget (bits) for sharing `u` parity rows, eq. (62).
///
/// Returns `f64::INFINITY` when `f(X̂) = 0` (a single point dominates some
/// feature, so any parity row leaks unboundedly under this bound).
pub fn epsilon_mi_dp(xhat: &Mat, u: usize) -> f64 {
    let f = concentration_f(xhat);
    if f == 0.0 {
        return f64::INFINITY;
    }
    0.5 * (1.0 + u as f64 / (f * f)).log2()
}

/// Whether the eq. (62) ε-MI-DP analysis applies to this code/generator
/// combination: it is derived for the dense code with a Gaussian (normal)
/// generator matrix only.
pub fn applicable(code: &CodeSpec, generator: GeneratorKind) -> bool {
    matches!(code, CodeSpec::Dense) && matches!(generator, GeneratorKind::Normal)
}

/// Per-client privacy report used by the `privacy_budget` example and the
/// privacy section of EXPERIMENTS.md.
///
/// `epsilon_bits` is `None` when the Gaussian analysis does not cover the
/// labelled code (see [`applicable`]); `code` records which code/generator
/// the report was computed for.
#[derive(Clone, Debug)]
pub struct PrivacyReport {
    pub f_stat: f64,
    /// ε budget in bits, or `None` when eq. (62) is not applicable.
    pub epsilon_bits: Option<f64>,
    pub u: usize,
    /// Label of the code/generator the report describes, e.g.
    /// `"dense/normal"` or `"rateless(overhead=0.5)/rademacher"`.
    pub code: String,
}

impl PrivacyReport {
    /// Render the ε column: the budget in bits, or an explicit
    /// not-applicable marker for non-Gaussian codes.
    pub fn epsilon_label(&self) -> String {
        match self.epsilon_bits {
            Some(e) => format!("{e:.4}"),
            None => "n/a (analysis not applicable)".to_string(),
        }
    }
}

/// Build a [`PrivacyReport`] for sharing `u` parity rows of `xhat` under
/// the given code and generator. The ε bound is only filled in for the
/// dense/normal combination eq. (62) covers.
pub fn report(xhat: &Mat, u: usize, code: &CodeSpec, generator: GeneratorKind) -> PrivacyReport {
    let epsilon_bits = applicable(code, generator).then(|| epsilon_mi_dp(xhat, u));
    PrivacyReport {
        f_stat: concentration_f(xhat),
        epsilon_bits,
        u,
        code: format!("{}/{}", code.label(), generator.as_str()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f_stat_hand_computed() {
        // col0: sum_sq = 1+4+9=14, max_sq = 9 -> 5
        // col1: sum_sq = 0.25+0.25+0.25 = 0.75, max_sq = 0.25 -> 0.5
        let m = Mat::from_vec(3, 2, vec![1.0, 0.5, 2.0, 0.5, 3.0, 0.5]);
        let f = concentration_f(&m);
        assert!((f - 0.5f64.sqrt()).abs() < 1e-9, "{f}");
    }

    #[test]
    fn concentrated_feature_leaks_everything() {
        // one point owns all the energy of column 1
        let m = Mat::from_vec(2, 2, vec![1.0, 5.0, 1.0, 0.0]);
        assert_eq!(concentration_f(&m), 0.0);
        assert!(epsilon_mi_dp(&m, 10).is_infinite());
    }

    #[test]
    fn epsilon_grows_with_u() {
        let m = Mat::from_fn(20, 4, |r, c| ((r + c) % 5) as f32 / 5.0 + 0.1);
        let e1 = epsilon_mi_dp(&m, 10);
        let e2 = epsilon_mi_dp(&m, 100);
        assert!(e2 > e1, "{e2} !> {e1}");
        assert!(e1 > 0.0);
    }

    #[test]
    fn uniform_data_leaks_little() {
        // paper: "when raw data distribution is uniform in feature space,
        // very little information is leaked" — epsilon shrinks as rows grow.
        let small = Mat::from_fn(10, 4, |r, c| (((r * 7 + c * 3) % 10) as f32 + 1.0) / 10.0);
        let big = Mat::from_fn(1000, 4, |r, c| (((r * 7 + c * 3) % 10) as f32 + 1.0) / 10.0);
        assert!(epsilon_mi_dp(&big, 50) < epsilon_mi_dp(&small, 50));
    }

    #[test]
    fn report_labels_the_code_and_gates_epsilon_on_applicability() {
        let m = Mat::from_fn(20, 4, |r, c| ((r + c) % 5) as f32 / 5.0 + 0.1);

        let gaussian = report(&m, 10, &CodeSpec::Dense, GeneratorKind::Normal);
        assert_eq!(gaussian.code, "dense/normal");
        let eps = gaussian.epsilon_bits.expect("dense/normal is covered by eq. 62");
        assert!((eps - epsilon_mi_dp(&m, 10)).abs() < 1e-12);
        assert_eq!(gaussian.epsilon_label(), format!("{eps:.4}"));

        let rateless = report(&m, 10, &CodeSpec::Rateless { overhead: 0.5 }, GeneratorKind::Normal);
        assert!(rateless.epsilon_bits.is_none());
        assert!(rateless.code.starts_with("rateless"), "{}", rateless.code);
        assert!(rateless.epsilon_label().contains("not applicable"));

        let rademacher = report(&m, 10, &CodeSpec::Dense, GeneratorKind::Rademacher);
        assert!(rademacher.epsilon_bits.is_none());
        assert_eq!(rademacher.code, "dense/rademacher");
        // f(X̂) is a property of the data alone — reported either way.
        assert!(rademacher.f_stat > 0.0);
    }

    #[test]
    fn applicability_covers_exactly_the_gaussian_dense_case() {
        assert!(applicable(&CodeSpec::Dense, GeneratorKind::Normal));
        assert!(!applicable(&CodeSpec::Dense, GeneratorKind::Rademacher));
        assert!(!applicable(&CodeSpec::Rateless { overhead: 1.0 }, GeneratorKind::Normal));
    }

    #[test]
    fn epsilon_formula_value() {
        // f^2 = 3 for a column of four 1.0 entries (4 - 1); single column.
        let m = Mat::from_vec(4, 1, vec![1.0; 4]);
        let eps = epsilon_mi_dp(&m, 6);
        assert!((eps - 0.5 * (1.0f64 + 2.0).log2()).abs() < 1e-12);
    }
}
