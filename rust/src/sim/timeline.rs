//! The per-round event timeline: what happens between "sent θ" and
//! "gradient arrived".
//!
//! The one-shot sampler collapses a client's round into a single scalar
//! `T_j`; the timeline keeps the §II-B legs — downlink wait, compute,
//! uplink wait — as *ordered completion events* on the round clock
//! (`t = 0` = the server broadcasts θ), plus the MEC computing unit's
//! parity completion. Schemes and observers can therefore reason about
//! partial progress (who has θ by the deadline? whose gradient is in
//! flight?) instead of only totals.
//!
//! [`RoundTrace`] is the reusable per-round record: [`RoundTrace::sample_into`]
//! draws every leg through the fleet's per-leg link models
//! ([`crate::delay::asymmetric::AsymNodeParams::sample_legs`]) in client
//! order then the server — the *identical* RNG sequence as the one-shot
//! [`crate::sim::RoundSampler`], with per-client totals that match it
//! bit-for-bit ([`crate::delay::DelayLegs::total`]). The totals are kept
//! in an embedded [`RoundDelays`] ([`RoundTrace::delays`]) so
//! `arrivals`/`kth_fastest` and every existing scheme work unchanged on
//! top of the trace.
//!
//! Everything is buffer-reused: once warm, a round's trace (legs, totals,
//! sorted events) is rebuilt with **zero** heap allocations
//! (`tests/alloc_gate.rs` pins this under every built-in scenario).

use super::RoundDelays;
use crate::delay::DelayLegs;
use crate::rng::Rng;
use crate::topology::FleetView;

/// One leg of a client's round trip.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Leg {
    /// The client received θ (`τ_d · N_down` after broadcast).
    Downlink,
    /// The client finished its local gradient computation.
    Compute,
    /// The client's gradient reached the server (the client's total `T_j`).
    Uplink,
}

/// One completion event on the round clock.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LegEvent {
    /// Client `client` finished `leg` at `time`.
    Client { client: usize, leg: Leg, time: f64 },
    /// The MEC computing unit finished the coded/parity gradient (`T_C`).
    ServerParity { time: f64 },
}

impl LegEvent {
    /// The event's instant on the round clock.
    pub fn time(&self) -> f64 {
        match *self {
            LegEvent::Client { time, .. } => time,
            LegEvent::ServerParity { time } => time,
        }
    }

    /// The client index, when the event belongs to a client.
    pub fn client(&self) -> Option<usize> {
        match *self {
            LegEvent::Client { client, .. } => Some(client),
            LegEvent::ServerParity { .. } => None,
        }
    }
}

/// The sampled timeline of one training round. Construct once
/// ([`RoundTrace::with_capacity`]) and refill every round with
/// [`RoundTrace::sample_into`]; all buffers are reused.
#[derive(Clone, Debug, Default)]
pub struct RoundTrace {
    /// Per-client sampled legs (meaningful only where `present`).
    legs: Vec<DelayLegs>,
    /// Which clients were available this round (scenario dropouts absent).
    present: Vec<bool>,
    /// Per-client totals + server total — the cheap view schemes consume.
    delays: RoundDelays,
    /// All leg-completion events, ordered by time.
    events: Vec<LegEvent>,
}

impl RoundTrace {
    pub fn new() -> Self {
        Self::default()
    }

    /// A trace with buffers pre-sized for an `n`-client fleet, so even
    /// round 1 samples without growing.
    pub fn with_capacity(n: usize) -> Self {
        RoundTrace {
            legs: Vec::with_capacity(n),
            present: Vec::with_capacity(n),
            delays: RoundDelays { client_t: Vec::with_capacity(n), server_t: 0.0 },
            events: Vec::with_capacity(3 * n + 1),
        }
    }

    /// Sample one round against the (scenario-modulated) fleet view.
    ///
    /// RNG order is the reproducibility contract: clients in index order
    /// (each drawing compute-exponential, downlink count, uplink count),
    /// then the server — exactly the [`crate::sim::RoundSampler`]
    /// sequence. Clients the view marks unavailable draw nothing and
    /// carry `T_j = ∞`.
    pub fn sample_into(
        &mut self,
        view: &FleetView,
        client_loads: &[f64],
        server_load: f64,
        rng: &mut Rng,
    ) {
        assert_eq!(
            view.len(),
            client_loads.len(),
            "fleet view and load vector disagree on the client count"
        );
        self.legs.clear();
        self.present.clear();
        self.delays.client_t.clear();
        self.events.clear();
        for (j, (link, &load)) in view.clients.iter().zip(client_loads).enumerate() {
            if !view.available[j] {
                self.legs.push(DelayLegs::default());
                self.present.push(false);
                self.delays.client_t.push(f64::INFINITY);
                continue;
            }
            let legs = link.sample_legs(load, rng);
            let t_down = legs.downlink_time();
            let t_compute = t_down + legs.compute_time();
            let total = legs.total();
            self.events.push(LegEvent::Client { client: j, leg: Leg::Downlink, time: t_down });
            self.events.push(LegEvent::Client { client: j, leg: Leg::Compute, time: t_compute });
            self.events.push(LegEvent::Client { client: j, leg: Leg::Uplink, time: total });
            self.legs.push(legs);
            self.present.push(true);
            self.delays.client_t.push(total);
        }
        self.delays.server_t = view.server.sample_delay(server_load, rng);
        self.events.push(LegEvent::ServerParity { time: self.delays.server_t });
        // sort_unstable is in-place (no allocation on the warm path); ties
        // keep a deterministic order for a given input sequence.
        self.events.sort_unstable_by(|a, b| a.time().total_cmp(&b.time()));
    }

    /// The round's totals — the view every waiting policy consumes.
    pub fn delays(&self) -> &RoundDelays {
        &self.delays
    }

    /// All leg-completion events this round, ordered by time
    /// (`3 × present clients + 1` entries).
    pub fn events(&self) -> &[LegEvent] {
        &self.events
    }

    /// Client `j`'s sampled legs, `None` when the scenario dropped it.
    pub fn legs(&self, j: usize) -> Option<DelayLegs> {
        if self.present[j] {
            Some(self.legs[j])
        } else {
            None
        }
    }

    /// Whether client `j` was available this round.
    pub fn is_present(&self, j: usize) -> bool {
        self.present[j]
    }

    /// Number of clients in the sampled round.
    pub fn num_clients(&self) -> usize {
        self.present.len()
    }

    /// The MEC computing unit's parity-completion time `T_C`.
    pub fn server_time(&self) -> f64 {
        self.delays.server_t
    }

    // ---- fault-injection mutators (`sim::fault`) -------------------------
    //
    // All of these rewrite the already-sampled trace in place — removal is
    // `Vec::retain` (order-preserving, allocation-free) and re-pricing
    // overwrites the event's time — so the warm-round zero-allocation gate
    // holds on the faulted path too. Removals keep the events sorted;
    // after re-pricing the caller runs [`RoundTrace::resort_events`] once.

    /// Client `j` crashed mid-round: it received θ (the downlink event
    /// stays) but its compute leg never completes, so the compute and
    /// uplink events vanish and its total becomes `∞`.
    pub fn fail_compute(&mut self, j: usize) {
        self.present[j] = false;
        self.delays.client_t[j] = f64::INFINITY;
        self.events.retain(|ev| {
            !matches!(*ev,
                LegEvent::Client { client, leg, .. } if client == j && leg != Leg::Downlink)
        });
    }

    /// Client `j`'s uplink payload was lost: the client did the work
    /// (downlink and compute events stay) but no gradient reaches the
    /// server — the uplink event vanishes and its total becomes `∞`.
    pub fn fail_uplink(&mut self, j: usize) {
        self.present[j] = false;
        self.delays.client_t[j] = f64::INFINITY;
        self.events.retain(|ev| {
            !matches!(*ev,
                LegEvent::Client { client, leg: Leg::Uplink, .. } if client == j)
        });
    }

    /// Client `j`'s gradient was redelivered late (retry + backoff): move
    /// its uplink event and total to `t`. The sampled legs keep their
    /// original values — `legs(j).total()` is the fault-free delivery
    /// time, `delays().client_t[j]` the re-priced one. Call
    /// [`RoundTrace::resort_events`] once after the last re-price.
    pub fn reprice_uplink(&mut self, j: usize, t: f64) {
        self.delays.client_t[j] = t;
        for ev in self.events.iter_mut() {
            if let LegEvent::Client { client, leg: Leg::Uplink, time } = ev {
                if *client == j {
                    *time = t;
                }
            }
        }
    }

    /// The MEC unit's parity gradient was lost server-side: the parity
    /// event vanishes and `T_C` becomes `∞` (it fails every deadline
    /// comparison, so the coded schemes see no parity this round).
    pub fn fail_parity(&mut self) {
        self.delays.server_t = f64::INFINITY;
        self.events.retain(|ev| !matches!(ev, LegEvent::ServerParity { .. }));
    }

    /// Restore the events' time order after re-pricing (in-place
    /// `sort_unstable`, no allocation).
    pub fn resort_events(&mut self) {
        self.events.sort_unstable_by(|a, b| a.time().total_cmp(&b.time()));
    }

    /// Close the round at deadline `t`: every client whose gradient has
    /// not arrived by `t` is treated as absent (`T_j = ∞`), a parity
    /// gradient finishing after `t` is unavailable, and events after `t`
    /// are dropped — the coordinator's deadline mode sees only what the
    /// server had in hand when the round ended.
    pub fn close_at(&mut self, t: f64) {
        for (j, ct) in self.delays.client_t.iter_mut().enumerate() {
            if *ct > t {
                *ct = f64::INFINITY;
                self.present[j] = false;
            }
        }
        if self.delays.server_t > t {
            self.delays.server_t = f64::INFINITY;
        }
        self.events.retain(|ev| ev.time() <= t);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::RoundSampler;
    use crate::topology::FleetSpec;

    fn fleet(n: usize) -> (FleetSpec, Vec<crate::delay::NodeParams>) {
        let spec = FleetSpec::paper(n, 64, 10);
        let clients = spec.build_clients(&mut Rng::seed_from(3));
        (spec, clients)
    }

    #[test]
    fn totals_match_one_shot_sampler_bitwise() {
        let (spec, clients) = fleet(6);
        let links = spec.build_links(&clients);
        let server = spec.build_server();
        let loads = vec![17.0; 6];

        let sampler = RoundSampler::new(&clients, server, loads.clone(), 30.0);
        let mut rng_a = Rng::seed_from(42);
        let mut rng_b = Rng::seed_from(42);
        let mut legacy = RoundDelays::default();
        let view = FleetView::from_base(&links, server);
        let mut trace = RoundTrace::with_capacity(6);
        for round in 0..40 {
            sampler.sample_into(&mut rng_a, &mut legacy);
            trace.sample_into(&view, &loads, 30.0, &mut rng_b);
            assert_eq!(trace.delays().server_t.to_bits(), legacy.server_t.to_bits());
            for (a, b) in trace.delays().client_t.iter().zip(&legacy.client_t) {
                assert_eq!(a.to_bits(), b.to_bits(), "round {round}");
            }
        }
    }

    #[test]
    fn events_are_ordered_and_legs_consistent() {
        let (spec, clients) = fleet(4);
        let links = spec.build_links(&clients);
        let server = spec.build_server();
        let view = FleetView::from_base(&links, server);
        let mut trace = RoundTrace::with_capacity(4);
        trace.sample_into(&view, &[9.0; 4], 20.0, &mut Rng::seed_from(8));

        let events = trace.events();
        assert_eq!(events.len(), 3 * 4 + 1);
        for w in events.windows(2) {
            assert!(w[0].time() <= w[1].time());
        }
        for j in 0..4 {
            assert!(trace.is_present(j));
            let legs = trace.legs(j).unwrap();
            // The uplink event carries the client's total delay.
            assert_eq!(legs.total(), trace.delays().client_t[j]);
            // Per-client leg order: downlink ≤ compute-done ≤ total.
            assert!(legs.downlink_time() <= legs.downlink_time() + legs.compute_time());
            assert!(legs.downlink_time() + legs.compute_time() <= legs.total() + 1e-12);
        }
        assert_eq!(trace.num_clients(), 4);
        assert_eq!(trace.server_time(), trace.delays().server_t);
        assert!(events.iter().any(|e| e.client().is_none()));
    }

    #[test]
    fn unavailable_clients_draw_nothing_and_carry_infinity() {
        let (spec, clients) = fleet(3);
        let links = spec.build_links(&clients);
        let server = spec.build_server();
        let loads = [5.0; 3];

        let mut view = FleetView::from_base(&links, server);
        view.available[1] = false;
        let mut trace = RoundTrace::with_capacity(3);
        trace.sample_into(&view, &loads, 10.0, &mut Rng::seed_from(4));
        assert!(!trace.is_present(1));
        assert!(trace.legs(1).is_none());
        assert!(trace.delays().client_t[1].is_infinite());
        assert_eq!(trace.events().len(), 3 * 2 + 1);
        assert_eq!(trace.delays().present_count(), 2);

        // The dropped client consumes no RNG: clients 0 and 2 must draw
        // what they would if the fleet were just the two of them.
        let two_links = [links[0], links[2]];
        let two_view = FleetView::from_base(&two_links, server);
        let mut two = RoundTrace::with_capacity(2);
        two.sample_into(&two_view, &[5.0; 2], 10.0, &mut Rng::seed_from(4));
        assert_eq!(
            two.delays().client_t[0].to_bits(),
            trace.delays().client_t[0].to_bits()
        );
        assert_eq!(
            two.delays().client_t[1].to_bits(),
            trace.delays().client_t[2].to_bits()
        );
        assert_eq!(two.delays().server_t.to_bits(), trace.delays().server_t.to_bits());
    }
}
