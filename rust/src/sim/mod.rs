//! Virtual-clock MEC round simulator.
//!
//! Each training round we *sample* every node's epoch delay from the
//! paper's stochastic models (§II-B) to decide (a) which gradients arrive
//! and (b) how much simulated wall-clock the round costs under each
//! scheme's waiting policy. Gradients themselves are really computed
//! through the runtime's executors — the clock is virtual, the math is
//! not (DESIGN.md §6).
//!
//! The simulation is layered:
//!
//! * [`timeline`] — the per-round event timeline: every client's ordered
//!   leg-completion events (downlink → compute → uplink) plus the MEC
//!   unit's parity completion, recorded in a reusable
//!   [`timeline::RoundTrace`] whose [`RoundDelays`] is a cheap totals
//!   view every waiting policy consumes.
//! * [`scenario`] — pluggable per-round network behaviour: a
//!   [`scenario::Scenario`] modulates the round's
//!   [`crate::topology::FleetView`] (dropouts, fading, compute bursts)
//!   before the timeline samples it. `static` — the default — is
//!   bit-identical to the fixed-fleet behaviour below.
//! * [`fault`] — seeded fault injection on the sampled trace: crashes
//!   (compute leg never completes), uplink payload loss (with optional
//!   retry + backoff re-pricing) and server-side parity loss, drawn from
//!   their own RNG stream so they compose with every scenario and scheme
//!   ([`fault::FaultSpec`] / [`fault::FaultPlan`]). [`fault::DeadlineSpec`]
//!   describes when the coordinator closes each round.
//! * [`RoundSampler`] — the direct fixed-fleet sampler (the pre-timeline
//!   path, kept as the static reference and for code that needs totals
//!   only).
//!
//! A client a scenario marks unavailable — or a fault removes — carries
//! `T_j = ∞` in [`RoundDelays`]: it never arrives by any deadline, sorts
//! after every finite delay, and is excluded from the waiting policies'
//! pricing.

pub mod fault;
pub mod scenario;
pub mod timeline;

pub use fault::{DeadlineSpec, FaultPlan, FaultSpec};
pub use scenario::{Scenario, ScenarioSpec};
pub use timeline::{Leg, LegEvent, RoundTrace};

use crate::delay::NodeParams;
use crate::rng::Rng;

/// Sampled per-round delays for the client fleet.
#[derive(Clone, Debug, Default)]
pub struct RoundDelays {
    /// Per-client total time `T_j` for its processed load this round
    /// (`f64::INFINITY` for clients the round's scenario dropped).
    pub client_t: Vec<f64>,
    /// The MEC computing unit's time `T_C` for the coded gradient.
    pub server_t: f64,
}

impl RoundDelays {
    /// Which clients made a deadline `t`. Allocates a fresh `Vec` — on
    /// per-round paths prefer [`RoundDelays::arrivals_iter`] or
    /// [`RoundDelays::arrivals_into`].
    pub fn arrivals(&self, t: f64) -> Vec<bool> {
        self.arrivals_iter(t).collect()
    }

    /// Allocation-free view of [`RoundDelays::arrivals`]: per-client
    /// "made the deadline `t`" flags in client-index order.
    pub fn arrivals_iter(&self, t: f64) -> impl Iterator<Item = bool> + '_ {
        self.client_t.iter().map(move |&tt| tt <= t)
    }

    /// [`RoundDelays::arrivals`] into a caller-owned buffer (cleared and
    /// refilled; capacity reused across rounds).
    pub fn arrivals_into(&self, t: f64, out: &mut Vec<bool>) {
        out.clear();
        out.extend(self.arrivals_iter(t));
    }

    /// Whether client `j` is reachable this round (scenario dropouts
    /// carry an infinite delay).
    pub fn is_present(&self, j: usize) -> bool {
        self.client_t[j].is_finite()
    }

    /// Number of clients reachable this round.
    pub fn present_count(&self) -> usize {
        self.client_t.iter().filter(|t| t.is_finite()).count()
    }

    /// Completion time when waiting for *all* reachable clients (naive
    /// uncoded). Scenario-dropped clients are not waited for — the server
    /// knows they are gone this round — so only finite delays price the
    /// round; 0 when no client is reachable.
    pub fn max_client_time(&self) -> f64 {
        self.client_t
            .iter()
            .filter(|t| t.is_finite())
            .cloned()
            .fold(0.0, f64::max)
    }

    /// Completion time when waiting for the fastest `k` clients (greedy
    /// uncoded): the k-th order statistic. Also returns the indices of
    /// those clients, sorted fastest-first.
    ///
    /// Allocates fresh scratch and a fresh winners `Vec` per call — on
    /// per-round paths prefer [`RoundDelays::kth_fastest_into`] with a
    /// round-persistent [`KthScratch`].
    pub fn kth_fastest(&self, k: usize) -> Result<(f64, Vec<usize>), String> {
        let mut scratch = KthScratch::default();
        let (t, winners) = self.kth_fastest_into(k, &mut scratch)?;
        Ok((t, winners.to_vec()))
    }

    /// [`RoundDelays::kth_fastest`] as a streaming O(n log k) scan into
    /// caller-owned scratch: a bounded max-heap of the `k` fastest
    /// `(delay, index)` pairs replaces the full-fleet index sort, so the
    /// greedy selection path neither allocates once warm nor pays
    /// O(n log n) on fleets where k ≪ n. The returned winners slice
    /// borrows the scratch and is sorted fastest-first, ties broken by
    /// client index — bit-identical to the stable full sort this
    /// replaces.
    ///
    /// Total order via [`f64::total_cmp`], so a NaN delay (a buggy custom
    /// delay model, say) sorts last instead of panicking mid-run; an
    /// out-of-range `k` is a recoverable `Err`, not a panic, because `k`
    /// may come straight from user-facing scheme parameters.
    pub fn kth_fastest_into<'s>(
        &self,
        k: usize,
        scratch: &'s mut KthScratch,
    ) -> Result<(f64, &'s [usize]), String> {
        let n = self.client_t.len();
        if k == 0 || k > n {
            return Err(format!("kth_fastest: k={k} out of range 1..={n}"));
        }
        // `a` is strictly worse (slower, or same delay at a higher index)
        // than `b` — the heap keeps the worst of the current k at its root.
        fn worse(a: (f64, usize), b: (f64, usize)) -> bool {
            match a.0.total_cmp(&b.0) {
                std::cmp::Ordering::Equal => a.1 > b.1,
                ord => ord == std::cmp::Ordering::Greater,
            }
        }
        let KthScratch { heap, winners } = scratch;
        heap.clear();
        heap.reserve(k);
        for (j, &t) in self.client_t.iter().enumerate() {
            if heap.len() < k {
                // Grow phase: sift the new entry up.
                heap.push((t, j));
                let mut i = heap.len() - 1;
                while i > 0 {
                    let parent = (i - 1) / 2;
                    if !worse(heap[i], heap[parent]) {
                        break;
                    }
                    heap.swap(i, parent);
                    i = parent;
                }
            } else if worse(heap[0], (t, j)) {
                // Candidate beats the current worst: replace the root and
                // sift it down.
                heap[0] = (t, j);
                let mut i = 0;
                loop {
                    let (l, r) = (2 * i + 1, 2 * i + 2);
                    let mut m = i;
                    if l < k && worse(heap[l], heap[m]) {
                        m = l;
                    }
                    if r < k && worse(heap[r], heap[m]) {
                        m = r;
                    }
                    if m == i {
                        break;
                    }
                    heap.swap(i, m);
                    i = m;
                }
            }
        }
        // Keys are unique by index, so the unstable in-place sort (no
        // allocation) reproduces the stable order exactly.
        heap.sort_unstable_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        winners.clear();
        winners.extend(heap.iter().map(|&(_, j)| j));
        Ok((heap[k - 1].0, winners.as_slice()))
    }
}

/// Caller-owned scratch for [`RoundDelays::kth_fastest_into`]: the bounded
/// max-heap of candidate `(delay, index)` pairs plus the winners buffer.
/// Hold one per scheme (or per selection site) and reuse it every round —
/// after the first call at a given `k` no further allocations occur.
#[derive(Clone, Debug, Default)]
pub struct KthScratch {
    heap: Vec<(f64, usize)>,
    winners: Vec<usize>,
}

/// Samples rounds for a fixed fleet + per-node loads. Borrows the fleet
/// (one per experiment, owned by `FedSetup`) instead of cloning it per
/// scheme run.
pub struct RoundSampler<'a> {
    clients: &'a [NodeParams],
    server: NodeParams,
    /// Per-client processed load `ℓ̃_j` (drives both the deterministic and
    /// stochastic compute parts).
    pub client_loads: Vec<f64>,
    /// Server parity load `u`.
    pub server_load: f64,
}

impl<'a> RoundSampler<'a> {
    pub fn new(
        clients: &'a [NodeParams],
        server: NodeParams,
        client_loads: Vec<f64>,
        server_load: f64,
    ) -> Self {
        assert_eq!(clients.len(), client_loads.len());
        RoundSampler { clients, server, client_loads, server_load }
    }

    /// Sample one round's delays.
    pub fn sample(&self, rng: &mut Rng) -> RoundDelays {
        let mut out =
            RoundDelays { client_t: Vec::with_capacity(self.clients.len()), server_t: 0.0 };
        self.sample_into(rng, &mut out);
        out
    }

    /// [`RoundSampler::sample`] into a caller-owned `RoundDelays` (cleared
    /// and refilled; capacity reused across rounds). Draws the same RNG
    /// sequence as `sample` — clients in index order, then the server —
    /// so the two are interchangeable without perturbing reproducibility.
    pub fn sample_into(&self, rng: &mut Rng, out: &mut RoundDelays) {
        out.client_t.clear();
        out.client_t.extend(
            self.clients
                .iter()
                .zip(&self.client_loads)
                .map(|(c, &l)| c.sample_delay(l, rng)),
        );
        out.server_t = self.server.sample_delay(self.server_load, rng);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fleet() -> (Vec<NodeParams>, NodeParams) {
        let clients = (0..4)
            .map(|j| NodeParams {
                mu: 10.0 / (j as f64 + 1.0),
                alpha: 2.0,
                tau: 0.1,
                p: 0.1,
            })
            .collect();
        let server = NodeParams { mu: 1000.0, alpha: 100.0, tau: 0.01, p: 0.0 };
        (clients, server)
    }

    #[test]
    fn sample_shapes_and_positivity() {
        let (c, s) = fleet();
        let sampler = RoundSampler::new(&c, s, vec![5.0; 4], 20.0);
        let mut rng = Rng::seed_from(1);
        let d = sampler.sample(&mut rng);
        assert_eq!(d.client_t.len(), 4);
        assert!(d.client_t.iter().all(|&t| t > 0.0));
        assert!(d.server_t > 0.0);
    }

    #[test]
    fn sample_into_matches_sample_and_reuses_capacity() {
        let (c, s) = fleet();
        let sampler = RoundSampler::new(&c, s, vec![5.0; 4], 20.0);
        let mut rng_a = Rng::seed_from(3);
        let mut rng_b = Rng::seed_from(3);
        let mut reused = RoundDelays { client_t: Vec::new(), server_t: 0.0 };
        for _ in 0..10 {
            let fresh = sampler.sample(&mut rng_a);
            sampler.sample_into(&mut rng_b, &mut reused);
            assert_eq!(fresh.client_t, reused.client_t);
            assert_eq!(fresh.server_t, reused.server_t);
        }
    }

    #[test]
    fn arrivals_match_threshold() {
        let d = RoundDelays { client_t: vec![1.0, 3.0, 2.0], server_t: 0.5 };
        assert_eq!(d.arrivals(2.0), vec![true, false, true]);
        assert_eq!(d.max_client_time(), 3.0);
    }

    #[test]
    fn arrivals_iter_and_into_match_arrivals() {
        let d = RoundDelays { client_t: vec![1.0, 3.0, 2.0, f64::INFINITY], server_t: 0.5 };
        let vec_form = d.arrivals(2.5);
        assert_eq!(d.arrivals_iter(2.5).collect::<Vec<bool>>(), vec_form);
        let mut buf = vec![true; 1]; // stale contents + wrong length
        d.arrivals_into(2.5, &mut buf);
        assert_eq!(buf, vec_form);
        assert_eq!(buf, vec![true, false, true, false]);
    }

    #[test]
    fn dropped_clients_are_absent_everywhere() {
        // A scenario-dropped client (T = ∞) never arrives, never prices
        // the round, and sorts after every finite delay.
        let d = RoundDelays {
            client_t: vec![4.0, f64::INFINITY, 2.0],
            server_t: 0.0,
        };
        assert!(!d.is_present(1));
        assert!(d.is_present(0) && d.is_present(2));
        assert_eq!(d.present_count(), 2);
        assert_eq!(d.max_client_time(), 4.0);
        assert_eq!(d.arrivals(1e12), vec![true, false, true]);
        let (t2, winners) = d.kth_fastest(2).unwrap();
        assert_eq!(t2, 4.0);
        assert_eq!(winners, vec![2, 0]);
        // All dropped: nothing to wait for.
        let none = RoundDelays { client_t: vec![f64::INFINITY; 2], server_t: 0.0 };
        assert_eq!(none.present_count(), 0);
        assert_eq!(none.max_client_time(), 0.0);
    }

    #[test]
    fn kth_fastest_order_statistic() {
        let d = RoundDelays { client_t: vec![5.0, 1.0, 3.0, 2.0], server_t: 0.0 };
        let (t, winners) = d.kth_fastest(2).unwrap();
        assert_eq!(t, 2.0);
        assert_eq!(winners, vec![1, 3]);
        let (t_all, _) = d.kth_fastest(4).unwrap();
        assert_eq!(t_all, 5.0);
    }

    #[test]
    fn kth_fastest_rejects_out_of_range_k() {
        let d = RoundDelays { client_t: vec![1.0], server_t: 0.0 };
        assert!(d.kth_fastest(0).is_err());
        assert!(d.kth_fastest(2).is_err());
        let msg = d.kth_fastest(2).unwrap_err();
        assert!(msg.contains("k=2"), "{msg}");
    }

    #[test]
    fn kth_fastest_into_matches_wrapper_for_every_k_and_reuses_scratch() {
        // Random delays with deliberate ties: the streaming heap must
        // reproduce the stable full sort's winners exactly, for every k,
        // out of one reused scratch.
        let mut rng = Rng::seed_from(77);
        let mut scratch = KthScratch::default();
        for trial in 0..20 {
            let n = 1 + (trial % 13);
            let client_t: Vec<f64> = (0..n)
                .map(|_| (rng.next_below(5) as f64) * 0.5)
                .collect();
            let d = RoundDelays { client_t, server_t: 0.0 };
            for k in 1..=n {
                let (t_ref, w_ref) = d.kth_fastest(k).unwrap();
                let (t, w) = d.kth_fastest_into(k, &mut scratch).unwrap();
                assert_eq!(t.to_bits(), t_ref.to_bits(), "n={n} k={k}");
                assert_eq!(w, &w_ref[..], "n={n} k={k}");
            }
        }
    }

    #[test]
    fn kth_fastest_into_breaks_ties_by_client_index() {
        let d = RoundDelays { client_t: vec![1.0, 1.0, 0.5, 1.0], server_t: 0.0 };
        let mut scratch = KthScratch::default();
        let (t, w) = d.kth_fastest_into(3, &mut scratch).unwrap();
        assert_eq!(t, 1.0);
        assert_eq!(w, &[2, 0, 1]);
    }

    #[test]
    fn kth_fastest_survives_nan_delays() {
        // total_cmp sorts NaN after every finite delay: the finite clients
        // win, and no panic reaches the training loop.
        let d = RoundDelays { client_t: vec![2.0, f64::NAN, 1.0], server_t: 0.0 };
        let (t, winners) = d.kth_fastest(2).unwrap();
        assert_eq!(t, 2.0);
        assert_eq!(winners, vec![2, 0]);
    }

    #[test]
    fn zero_load_clients_are_comm_bound() {
        let (c, s) = fleet();
        let sampler = RoundSampler::new(&c, s, vec![0.0; 4], 0.0);
        let mut rng = Rng::seed_from(2);
        for _ in 0..50 {
            let d = sampler.sample(&mut rng);
            for (t, cl) in d.client_t.iter().zip(&c) {
                assert!(*t >= 2.0 * cl.tau);
            }
        }
    }
}
