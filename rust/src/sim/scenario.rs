//! Pluggable per-round network scenarios.
//!
//! The paper evaluates a *stationary* fleet (§V-A): every round draws
//! from the same per-node distributions. Real edge networks are not
//! stationary — clients churn, links fade, compute throttles — and the
//! related work (stochastic/time-varying coded FL) lives in exactly that
//! regime. A [`Scenario`] opens that axis: at the top of every round it
//! modulates the round's [`FleetView`] (a working copy of the base
//! fleet) before the timeline samples it. Schemes are oblivious — they
//! keep consuming [`crate::sim::RoundDelays`]; a dropped client simply
//! carries `T_j = ∞`.
//!
//! Built-ins ([`ScenarioSpec`], the CLI/TOML-facing parser in the style
//! of [`crate::schemes::SchemeSpec`]):
//!
//! * `static` — no modulation; **bit-identical** to the pre-scenario
//!   fixed-fleet behaviour (it never touches the scenario RNG stream).
//! * `dropout:rate=…` — each client is unavailable each round with the
//!   given probability (at least one client is always kept).
//! * `fading:depth=…,period=…` — deterministic sinusoidal modulation of
//!   every link's τ and p over rounds (slow large-scale fading).
//! * `burst:slow=…,factor=…` — each client's compute rate μ dips by
//!   `factor` with probability `slow` per round (thermal throttling,
//!   background load).
//!
//! Determinism: scenarios draw only from the dedicated stream the engine
//! hands them (tag [`SCENARIO_STREAM_TAG`], split off the experiment
//! seed *independently of the scheme*), so every scheme on a session
//! faces the same network realisation — the fair-comparison property the
//! paper's evaluation relies on — and runs are reproducible across
//! thread counts and SIMD policies (`tests/scenario_determinism.rs`).

use std::f64::consts::PI;

use crate::rng::Rng;
use crate::topology::FleetView;

/// Tag of the RNG stream scenarios draw from. The engine splits it off
/// the experiment root *after* the per-scheme delay/code streams and with
/// a scheme-independent label: pre-scenario streams keep their exact
/// historical sequences, and every scheme sees the same scenario draws.
pub const SCENARIO_STREAM_TAG: u64 = 0x5CE4_A210;

/// A per-round network behaviour. Implementations mutate the round's
/// [`FleetView`] in place; the engine resets the view to the base fleet
/// before every call, so modulation never accumulates unless the
/// scenario tracks state itself.
///
/// Contract: draw randomness only from `rng` (reproducibility); do not
/// allocate in steady state — the warm-round zero-allocation gate
/// (`tests/alloc_gate.rs`) runs every built-in scenario; and keep **at
/// least one client available** every round. The waiting policies treat
/// an empty round as costing zero simulated time (there is nobody to
/// wait for), so a scenario that blacks out the whole fleet for a
/// stretch of rounds would let training advance on a free clock —
/// a silently wrong experiment, not an error. [`DropoutScenario`] shows
/// the deterministic keep-one fallback.
pub trait Scenario {
    /// Human-readable label for logs and reports.
    fn label(&self) -> String;

    /// Modulate `view` for round `round` (0-based global iteration).
    fn begin_round(&mut self, round: usize, view: &mut FleetView, rng: &mut Rng);

    /// Whether [`Scenario::begin_round`] may mutate the view at all.
    /// Defaults to `true`; a scenario that provably never touches the
    /// view (the static fleet) returns `false`, letting the engine skip
    /// the per-round view reset entirely — the reset exists only to undo
    /// modulation, so skipping it for a non-perturbing scenario is
    /// trivially bit-identical.
    fn perturbs_fleet(&self) -> bool {
        true
    }
}

/// The fixed fleet of the paper (§V-A): no modulation, no RNG use.
#[derive(Clone, Copy, Debug, Default)]
pub struct StaticScenario;

impl Scenario for StaticScenario {
    fn label(&self) -> String {
        "static".into()
    }

    fn begin_round(&mut self, _round: usize, _view: &mut FleetView, _rng: &mut Rng) {}

    fn perturbs_fleet(&self) -> bool {
        false
    }
}

/// Per-round client unavailability: each client drops with probability
/// `rate`, independently per round. If every client would drop, the
/// deterministic fallback keeps client `round % n` — a round with nobody
/// reachable would stall every waiting policy.
#[derive(Clone, Copy, Debug)]
pub struct DropoutScenario {
    pub rate: f64,
}

impl Scenario for DropoutScenario {
    fn label(&self) -> String {
        format!("dropout(rate={})", self.rate)
    }

    fn begin_round(&mut self, round: usize, view: &mut FleetView, rng: &mut Rng) {
        for a in view.available.iter_mut() {
            if rng.next_f64() < self.rate {
                *a = false;
            }
        }
        let n = view.available.len();
        if n > 0 && view.available.iter().all(|&a| !a) {
            view.available[round % n] = true;
        }
    }
}

/// Slow sinusoidal link fading: round `r` scales every client's per-leg
/// τ and erasure probability by `1 + depth·sin(2π r / period)` (p capped
/// below 1). Deterministic — uses no randomness.
#[derive(Clone, Copy, Debug)]
pub struct FadingScenario {
    pub depth: f64,
    pub period: f64,
}

/// Erasure probabilities stay strictly below 1 under fading.
const P_FADE_CAP: f64 = 0.99;

impl Scenario for FadingScenario {
    fn label(&self) -> String {
        format!("fading(depth={},period={})", self.depth, self.period)
    }

    fn begin_round(&mut self, round: usize, view: &mut FleetView, _rng: &mut Rng) {
        let f = 1.0 + self.depth * (2.0 * PI * round as f64 / self.period).sin();
        for c in view.clients.iter_mut() {
            // Both legs scale by the same factor, so reciprocal links stay
            // bitwise-reciprocal (and keep the symmetric total grouping).
            c.tau_down *= f;
            c.tau_up *= f;
            c.p_down = (c.p_down * f).min(P_FADE_CAP);
            c.p_up = (c.p_up * f).min(P_FADE_CAP);
        }
    }
}

/// Per-round compute-rate dips: each client's μ is divided by `factor`
/// with probability `slow` (modelling thermal throttling or background
/// load bursts).
#[derive(Clone, Copy, Debug)]
pub struct BurstScenario {
    pub slow: f64,
    pub factor: f64,
}

impl Scenario for BurstScenario {
    fn label(&self) -> String {
        format!("burst(slow={},factor={})", self.slow, self.factor)
    }

    fn begin_round(&mut self, _round: usize, view: &mut FleetView, rng: &mut Rng) {
        for c in view.clients.iter_mut() {
            if rng.next_f64() < self.slow {
                c.mu /= self.factor;
            }
        }
    }
}

/// Closed, serialisable description of the built-in scenarios — the form
/// the CLI (`--scenario`), TOML files (`[scenario] kind = …`) and tests
/// speak. `parse` accepts `static`, `dropout[:rate=r]`,
/// `fading[:depth=d,period=T]` and `burst[:slow=s,factor=f]`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ScenarioSpec {
    /// The paper's fixed fleet (default; bit-identical to pre-scenario runs).
    Static,
    /// Per-round client unavailability with the given probability.
    Dropout { rate: f64 },
    /// Sinusoidal τ/p modulation over rounds.
    Fading { depth: f64, period: f64 },
    /// Per-round compute-rate dips.
    Burst { slow: f64, factor: f64 },
}

impl ScenarioSpec {
    pub fn label(&self) -> String {
        match self {
            ScenarioSpec::Static => "static".into(),
            ScenarioSpec::Dropout { rate } => format!("dropout(rate={rate})"),
            ScenarioSpec::Fading { depth, period } => {
                format!("fading(depth={depth},period={period})")
            }
            ScenarioSpec::Burst { slow, factor } => {
                format!("burst(slow={slow},factor={factor})")
            }
        }
    }

    /// Parse a scenario string: `static`, `dropout`, `dropout:rate=0.2`,
    /// `fading:depth=0.5,period=20`, `burst:slow=0.1,factor=4`, …
    /// Unknown names, unknown parameters and out-of-range values are
    /// errors naming the offender and the accepted forms.
    pub fn parse(s: &str) -> Result<ScenarioSpec, String> {
        let (name, params) = match s.split_once(':') {
            Some((n, p)) => (n.trim(), Some(p)),
            None => (s.trim(), None),
        };
        // Comma-separated key=value list against a (key, default) table.
        let kvs = |allowed: &[(&str, f64)]| -> Result<Vec<f64>, String> {
            let mut vals: Vec<f64> = allowed.iter().map(|&(_, d)| d).collect();
            let Some(p) = params else { return Ok(vals) };
            for part in p.split(',') {
                let part = part.trim();
                let (k, v) = part.split_once('=').ok_or_else(|| {
                    format!("scenario {name:?}: expected key=value, got {part:?}")
                })?;
                let idx = allowed
                    .iter()
                    .position(|&(key, _)| key == k.trim())
                    .ok_or_else(|| {
                        let keys: Vec<&str> = allowed.iter().map(|&(key, _)| key).collect();
                        format!(
                            "scenario {name:?}: unknown parameter {:?} (expected {})",
                            k.trim(),
                            keys.join(", ")
                        )
                    })?;
                vals[idx] = v
                    .trim()
                    .parse::<f64>()
                    .map_err(|e| format!("scenario {name:?}: {}: {e}", k.trim()))?;
            }
            Ok(vals)
        };
        let spec = match name {
            "static" => match params {
                None => ScenarioSpec::Static,
                Some(p) => {
                    return Err(format!("scenario \"static\" takes no parameters, got {p:?}"))
                }
            },
            "dropout" => {
                let v = kvs(&[("rate", 0.1)])?;
                ScenarioSpec::Dropout { rate: v[0] }
            }
            "fading" => {
                let v = kvs(&[("depth", 0.5), ("period", 20.0)])?;
                ScenarioSpec::Fading { depth: v[0], period: v[1] }
            }
            "burst" => {
                let v = kvs(&[("slow", 0.1), ("factor", 4.0)])?;
                ScenarioSpec::Burst { slow: v[0], factor: v[1] }
            }
            other => {
                return Err(format!(
                    "unknown scenario {other:?} (expected static | dropout[:rate=r] | \
                     fading[:depth=d,period=T] | burst[:slow=s,factor=f])"
                ))
            }
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Range-check the parameters (also called by the config validator,
    /// since specs can be built directly). Errors follow the house
    /// `… out of range (expected one of …)` style shared with
    /// [`crate::sim::fault::FaultSpec`] and
    /// [`crate::sim::fault::DeadlineSpec`].
    pub fn validate(&self) -> Result<(), String> {
        match *self {
            ScenarioSpec::Static => Ok(()),
            ScenarioSpec::Dropout { rate } => {
                if !(0.0..1.0).contains(&rate) {
                    return Err(format!(
                        "scenario \"dropout\": rate={rate} out of range (expected one of [0,1))"
                    ));
                }
                Ok(())
            }
            ScenarioSpec::Fading { depth, period } => {
                if !(0.0..1.0).contains(&depth) {
                    return Err(format!(
                        "scenario \"fading\": depth={depth} out of range (expected one of [0,1))"
                    ));
                }
                if !(period > 0.0) {
                    return Err(format!(
                        "scenario \"fading\": period={period} out of range (expected one of \
                         period > 0)"
                    ));
                }
                Ok(())
            }
            ScenarioSpec::Burst { slow, factor } => {
                if !(0.0..=1.0).contains(&slow) {
                    return Err(format!(
                        "scenario \"burst\": slow={slow} out of range (expected one of [0,1])"
                    ));
                }
                if !(factor >= 1.0) {
                    return Err(format!(
                        "scenario \"burst\": factor={factor} out of range (expected one of \
                         factor >= 1)"
                    ));
                }
                Ok(())
            }
        }
    }

    /// Instantiate the described scenario.
    pub fn build(&self) -> Box<dyn Scenario> {
        match *self {
            ScenarioSpec::Static => Box::new(StaticScenario),
            ScenarioSpec::Dropout { rate } => Box::new(DropoutScenario { rate }),
            ScenarioSpec::Fading { depth, period } => {
                Box::new(FadingScenario { depth, period })
            }
            ScenarioSpec::Burst { slow, factor } => Box::new(BurstScenario { slow, factor }),
        }
    }
}

impl std::str::FromStr for ScenarioSpec {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        ScenarioSpec::parse(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::FleetSpec;

    fn view(n: usize) -> (Vec<crate::delay::asymmetric::AsymNodeParams>, FleetView) {
        let spec = FleetSpec::paper(n, 64, 10);
        let clients = spec.build_clients(&mut Rng::seed_from(2));
        let links = spec.build_links(&clients);
        let server = spec.build_server();
        let v = FleetView::from_base(&links, server);
        (links, v)
    }

    #[test]
    fn spec_parse_roundtrip_and_defaults() {
        assert_eq!(ScenarioSpec::parse("static").unwrap(), ScenarioSpec::Static);
        assert_eq!(
            ScenarioSpec::parse("dropout").unwrap(),
            ScenarioSpec::Dropout { rate: 0.1 }
        );
        assert_eq!(
            ScenarioSpec::parse("dropout:rate=0.25").unwrap(),
            ScenarioSpec::Dropout { rate: 0.25 }
        );
        assert_eq!(
            ScenarioSpec::parse("fading:depth=0.3,period=12").unwrap(),
            ScenarioSpec::Fading { depth: 0.3, period: 12.0 }
        );
        assert_eq!(
            ScenarioSpec::parse("fading:period=8").unwrap(),
            ScenarioSpec::Fading { depth: 0.5, period: 8.0 }
        );
        assert_eq!(
            "burst:slow=0.2,factor=8".parse::<ScenarioSpec>().unwrap(),
            ScenarioSpec::Burst { slow: 0.2, factor: 8.0 }
        );
    }

    #[test]
    fn spec_parse_rejects_garbage() {
        assert!(ScenarioSpec::parse("chaos").is_err());
        assert!(ScenarioSpec::parse("static:rate=0.1").is_err());
        assert!(ScenarioSpec::parse("dropout:frequency=0.1").is_err());
        assert!(ScenarioSpec::parse("dropout:rate=lots").is_err());
        assert!(ScenarioSpec::parse("dropout:rate=1.5").is_err());
        assert!(ScenarioSpec::parse("fading:depth=2").is_err());
        assert!(ScenarioSpec::parse("fading:period=0").is_err());
        assert!(ScenarioSpec::parse("burst:factor=0.5").is_err());
        let e = ScenarioSpec::parse("dropout:frequency=0.1").unwrap_err();
        assert!(e.contains("frequency") && e.contains("rate"), "{e}");
        // Out-of-range errors follow the house "expected one of" style
        // shared with the fault/deadline parsers.
        let e = ScenarioSpec::parse("dropout:rate=1.5").unwrap_err();
        assert!(e.contains("rate=1.5") && e.contains("expected one of"), "{e}");
        let e = ScenarioSpec::parse("fading:period=0").unwrap_err();
        assert!(e.contains("period=0") && e.contains("expected one of"), "{e}");
        // NaN parameters are out of range, not silently accepted.
        assert!(ScenarioSpec::Dropout { rate: f64::NAN }.validate().is_err());
    }

    #[test]
    fn built_scenarios_carry_matching_labels() {
        for spec in [
            ScenarioSpec::Static,
            ScenarioSpec::Dropout { rate: 0.2 },
            ScenarioSpec::Fading { depth: 0.5, period: 20.0 },
            ScenarioSpec::Burst { slow: 0.1, factor: 4.0 },
        ] {
            assert_eq!(spec.build().label(), spec.label());
        }
    }

    #[test]
    fn only_static_reports_a_non_perturbing_fleet() {
        assert!(!StaticScenario.perturbs_fleet());
        assert!(DropoutScenario { rate: 0.1 }.perturbs_fleet());
        assert!(FadingScenario { depth: 0.5, period: 8.0 }.perturbs_fleet());
        assert!(BurstScenario { slow: 0.1, factor: 4.0 }.perturbs_fleet());
        assert!(!ScenarioSpec::Static.build().perturbs_fleet());
    }

    #[test]
    fn static_scenario_touches_nothing() {
        let (links, mut v) = view(4);
        let before = v.clone();
        let mut rng = Rng::seed_from(7);
        let probe = rng.clone();
        StaticScenario.begin_round(3, &mut v, &mut rng);
        assert_eq!(v.clients, before.clients);
        assert_eq!(v.available, before.available);
        // …and the RNG stream is untouched (bit-identity contract).
        let mut a = rng;
        let mut b = probe;
        assert_eq!(a.next_u64(), b.next_u64());
        let _ = links;
    }

    #[test]
    fn dropout_always_keeps_at_least_one_client() {
        let (_, mut v) = view(5);
        let mut sc = DropoutScenario { rate: 0.999 };
        let mut rng = Rng::seed_from(11);
        for round in 0..50 {
            v.available.iter_mut().for_each(|a| *a = true);
            sc.begin_round(round, &mut v, &mut rng);
            assert!(v.available.iter().any(|&a| a), "round {round}");
        }
    }

    #[test]
    fn fading_modulates_links_periodically_and_keeps_reciprocity() {
        let (links, mut v) = view(3);
        let mut sc = FadingScenario { depth: 0.5, period: 8.0 };
        let mut rng = Rng::seed_from(1);
        // Quarter period: sin = 1, links degrade by exactly 1 + depth.
        sc.begin_round(2, &mut v, &mut rng);
        for (c, l) in v.clients.iter().zip(&links) {
            assert!((c.tau_up / l.tau_up - 1.5).abs() < 1e-12);
            assert_eq!(c.tau_down.to_bits(), c.tau_up.to_bits(), "reciprocal links stay so");
            assert!(c.p_down <= P_FADE_CAP && c.p_down >= l.p_down);
        }
        // Round 0: sin = 0, no modulation.
        let (links2, mut v2) = view(3);
        sc.begin_round(0, &mut v2, &mut rng);
        for (c, l) in v2.clients.iter().zip(&links2) {
            assert_eq!(c.tau_up.to_bits(), l.tau_up.to_bits());
        }
    }

    #[test]
    fn burst_slows_compute_only() {
        let (links, mut v) = view(6);
        let mut sc = BurstScenario { slow: 1.0, factor: 4.0 };
        let mut rng = Rng::seed_from(9);
        sc.begin_round(0, &mut v, &mut rng);
        for (c, l) in v.clients.iter().zip(&links) {
            assert!((c.mu - l.mu / 4.0).abs() < 1e-12);
            assert_eq!(c.tau_up.to_bits(), l.tau_up.to_bits());
            assert_eq!(c.p_down, l.p_down);
        }
    }
}
