//! Seeded, scheme-independent fault injection on the round timeline.
//!
//! Scenarios ([`crate::sim::scenario`]) model *clean* network variation:
//! a dropped client is known-gone before the round starts and nobody
//! waits for it. Faults model the ugly middle: a client that dies *after*
//! receiving θ (its compute leg never completes), an uplink whose payload
//! is lost after the client did the work (optionally re-priced by a
//! modelled retry + backoff), and the MEC unit's parity gradient failing
//! server-side. The related erasure-centric FL work (arXiv:2007.03273)
//! motivates treating these erasures — not mere slowness — as the
//! first-class failure model; the engine's degradation ladder
//! ([`crate::coordinator::engine`]) is what absorbs them.
//!
//! A [`FaultSpec`] is the CLI/TOML-facing description (`--faults`,
//! `[faults] kind = …`, [`crate::ExperimentBuilder::faults`]); a built
//! [`FaultPlan`] mutates each sampled [`RoundTrace`] *after* scenario
//! modulation and leg sampling, so faults compose with every scenario and
//! every scheme: schemes keep consuming the trace/delay view and simply
//! observe fewer (or later) arrivals.
//!
//! Determinism: a plan draws only from the dedicated stream the engine
//! splits at [`FAULT_STREAM_TAG`] — appended after every historical
//! stream, so pre-fault runs keep their exact sequences — and an inactive
//! plan (`faults = none` or all rates zero) never touches the RNG at all,
//! keeping `faults = none` bit-identical to pre-fault behaviour.

use crate::rng::Rng;
use crate::sim::timeline::RoundTrace;

/// Tag of the RNG stream fault plans draw from. Split off the experiment
/// root after the scenario and participation streams (scheme-independent,
/// like theirs): every scheme on a session faces the same fault
/// realisation, and all pre-fault streams keep their historical
/// sequences.
pub const FAULT_STREAM_TAG: u64 = 0xFA17_0001;

/// Tag of the counter-based stream deciding coordinator (server) kills
/// for `faults = server:rate=…`. Split off the experiment root after
/// every other stream; only its base is consumed (`Rng::indexed(base,
/// round)` reaches any round in O(1)), so a restarted coordinator
/// re-derives the exact kill schedule without replaying rounds.
pub const SERVER_FAULT_STREAM_TAG: u64 = 0xFA17_5E11;

/// Closed, serialisable description of the built-in fault mixes — the
/// form the CLI (`--faults`), TOML files (`[faults] kind = …`) and tests
/// speak. `parse` accepts `none`, `crash[:rate=r]`,
/// `link[:rate=r,retry=n]`, `parity[:rate=r]`,
/// `mixed[:crash=a,link=b,parity=c]`, `server[:rate=r]` (in-process
/// coordinator kill-and-restart, driving the checkpoint recovery path)
/// and `corrupt[:rate=r]` (non-finite client gradients, excluded by the
/// engine fold).
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub enum FaultSpec {
    /// No injection (default; bit-identical to pre-fault runs — the
    /// fault RNG stream is never touched).
    #[default]
    None,
    /// Each present client dies mid-round with the given probability:
    /// it received θ but its compute leg never completes, so neither a
    /// compute nor an uplink event reaches the server.
    Crash { rate: f64 },
    /// Each surviving uplink loses its payload with the given
    /// probability. `retry` > 0 models retransmission: each of up to
    /// `retry` attempts redelivers with probability `1 - rate`, pricing
    /// one backoff + one retransmit (two uplink durations) per failed
    /// attempt onto the timeline; if all attempts fail the gradient is
    /// lost.
    Link { rate: f64, retry: usize },
    /// The MEC unit's parity gradient is lost server-side with the given
    /// probability (the coded schemes see no parity completion that
    /// round).
    Parity { rate: f64 },
    /// All three at once: crash, single-attempt link loss and parity
    /// loss with independent probabilities.
    Mixed { crash: f64, link: f64, parity: f64 },
    /// The *coordinator* dies mid-round with the given probability and is
    /// restarted in-process from its latest snapshot
    /// ([`crate::coordinator::checkpoint`]). Draws come from a dedicated
    /// counter-based stream — never the sequential fault stream — and the
    /// recovery invariant makes the realized history bit-identical to
    /// `faults = none`, which is exactly what chaos tests assert.
    Server { rate: f64 },
    /// Each arrived client gradient is replaced by non-finite garbage
    /// with the given probability (a poisoned or bit-rotted update). The
    /// engine excludes non-finite updates from the fold before
    /// aggregation and counts them on
    /// [`crate::coordinator::RoundEvent::corrupted`].
    Corrupt { rate: f64 },
}

impl FaultSpec {
    pub fn label(&self) -> String {
        match self {
            FaultSpec::None => "none".into(),
            FaultSpec::Crash { rate } => format!("crash(rate={rate})"),
            FaultSpec::Link { rate, retry } => format!("link(rate={rate},retry={retry})"),
            FaultSpec::Parity { rate } => format!("parity(rate={rate})"),
            FaultSpec::Mixed { crash, link, parity } => {
                format!("mixed(crash={crash},link={link},parity={parity})")
            }
            FaultSpec::Server { rate } => format!("server(rate={rate})"),
            FaultSpec::Corrupt { rate } => format!("corrupt(rate={rate})"),
        }
    }

    /// Parse a fault string: `none`, `crash`, `crash:rate=0.3`,
    /// `link:rate=0.2,retry=2`, `parity:rate=0.5`,
    /// `mixed:crash=0.1,link=0.1,parity=0.2`, … Unknown kinds, unknown
    /// parameters and out-of-range values are errors naming the offender
    /// and the accepted forms.
    pub fn parse(s: &str) -> Result<FaultSpec, String> {
        let (name, params) = match s.split_once(':') {
            Some((n, p)) => (n.trim(), Some(p)),
            None => (s.trim(), None),
        };
        // Comma-separated key=value list against a (key, default) table.
        let kvs = |allowed: &[(&str, f64)]| -> Result<Vec<f64>, String> {
            let mut vals: Vec<f64> = allowed.iter().map(|&(_, d)| d).collect();
            let Some(p) = params else { return Ok(vals) };
            for part in p.split(',') {
                let part = part.trim();
                let (k, v) = part.split_once('=').ok_or_else(|| {
                    format!("faults {name:?}: expected key=value, got {part:?}")
                })?;
                let idx = allowed
                    .iter()
                    .position(|&(key, _)| key == k.trim())
                    .ok_or_else(|| {
                        let keys: Vec<&str> = allowed.iter().map(|&(key, _)| key).collect();
                        format!(
                            "faults {name:?}: unknown parameter {:?} (expected one of {})",
                            k.trim(),
                            keys.join(", ")
                        )
                    })?;
                vals[idx] = v
                    .trim()
                    .parse::<f64>()
                    .map_err(|e| format!("faults {name:?}: {}: {e}", k.trim()))?;
            }
            Ok(vals)
        };
        let spec = match name {
            "none" => match params {
                None => FaultSpec::None,
                Some(p) => {
                    return Err(format!("faults \"none\" takes no parameters, got {p:?}"))
                }
            },
            "crash" => {
                let v = kvs(&[("rate", 0.1)])?;
                FaultSpec::Crash { rate: v[0] }
            }
            "link" => {
                let v = kvs(&[("rate", 0.1), ("retry", 0.0)])?;
                if v[1] < 0.0 || v[1].fract() != 0.0 || v[1] > 64.0 {
                    return Err(format!(
                        "faults \"link\": retry must be an integer in 0..=64, got {}",
                        v[1]
                    ));
                }
                FaultSpec::Link { rate: v[0], retry: v[1] as usize }
            }
            "parity" => {
                let v = kvs(&[("rate", 0.1)])?;
                FaultSpec::Parity { rate: v[0] }
            }
            "mixed" => {
                let v = kvs(&[("crash", 0.1), ("link", 0.1), ("parity", 0.1)])?;
                FaultSpec::Mixed { crash: v[0], link: v[1], parity: v[2] }
            }
            "server" => {
                let v = kvs(&[("rate", 0.1)])?;
                FaultSpec::Server { rate: v[0] }
            }
            "corrupt" => {
                let v = kvs(&[("rate", 0.1)])?;
                FaultSpec::Corrupt { rate: v[0] }
            }
            other => {
                return Err(format!(
                    "unknown faults kind {other:?} (expected one of none | crash[:rate=r] | \
                     link[:rate=r,retry=n] | parity[:rate=r] | \
                     mixed[:crash=a,link=b,parity=c] | server[:rate=r] | corrupt[:rate=r])"
                ))
            }
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Range-check the parameters (also called by the config validator,
    /// since specs can be built directly). Rates are probabilities —
    /// rate 1.0 is legal and forces the fault every round (the empty-round
    /// regression path).
    pub fn validate(&self) -> Result<(), String> {
        fn rate(kind: &str, param: &str, v: f64) -> Result<(), String> {
            if !(0.0..=1.0).contains(&v) {
                return Err(format!(
                    "faults {kind:?}: {param}={v} out of range (expected one of [0,1])"
                ));
            }
            Ok(())
        }
        match *self {
            FaultSpec::None => Ok(()),
            FaultSpec::Crash { rate: r } => rate("crash", "rate", r),
            FaultSpec::Link { rate: r, retry: _ } => rate("link", "rate", r),
            FaultSpec::Parity { rate: r } => rate("parity", "rate", r),
            FaultSpec::Mixed { crash, link, parity } => {
                rate("mixed", "crash", crash)?;
                rate("mixed", "link", link)?;
                rate("mixed", "parity", parity)
            }
            FaultSpec::Server { rate: r } => rate("server", "rate", r),
            FaultSpec::Corrupt { rate: r } => rate("corrupt", "rate", r),
        }
    }

    /// Instantiate the per-round injection plan.
    pub fn build(&self) -> FaultPlan {
        let inactive = FaultPlan {
            crash_rate: 0.0,
            link_rate: 0.0,
            link_retries: 0,
            parity_rate: 0.0,
            server_rate: 0.0,
            corrupt_rate: 0.0,
        };
        match *self {
            FaultSpec::None => inactive,
            FaultSpec::Crash { rate } => FaultPlan { crash_rate: rate, ..inactive },
            FaultSpec::Link { rate, retry } => {
                FaultPlan { link_rate: rate, link_retries: retry, ..inactive }
            }
            FaultSpec::Parity { rate } => FaultPlan { parity_rate: rate, ..inactive },
            FaultSpec::Mixed { crash, link, parity } => FaultPlan {
                crash_rate: crash,
                link_rate: link,
                parity_rate: parity,
                ..inactive
            },
            FaultSpec::Server { rate } => FaultPlan { server_rate: rate, ..inactive },
            FaultSpec::Corrupt { rate } => FaultPlan { corrupt_rate: rate, ..inactive },
        }
    }
}

impl std::str::FromStr for FaultSpec {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        FaultSpec::parse(s)
    }
}

/// A built fault mix, applied to every sampled round trace.
///
/// Draw order is the reproducibility contract: present clients in index
/// order (crash draw; survivors draw link loss, then one draw per retry
/// attempt until redelivery), then one server parity draw. An inactive
/// plan returns before the first draw, so `faults = none` never touches
/// the RNG stream. Allocation-free: every mutation is an in-place
/// retain/overwrite on the trace's reused buffers (the warm-round gate in
/// `tests/alloc_gate.rs` pins this).
#[derive(Clone, Copy, Debug)]
pub struct FaultPlan {
    crash_rate: f64,
    link_rate: f64,
    link_retries: usize,
    parity_rate: f64,
    server_rate: f64,
    corrupt_rate: f64,
}

impl FaultPlan {
    /// Whether the plan can ever perturb the realized training history
    /// (any trace- or gradient-level rate positive). `server_rate` is
    /// deliberately excluded: coordinator kills are recovered
    /// bit-identically, so they must not flip the engine into degraded
    /// mode — `faults = server:…` histories equal `faults = none` ones.
    pub fn is_active(&self) -> bool {
        self.crash_rate > 0.0
            || self.link_rate > 0.0
            || self.parity_rate > 0.0
            || self.corrupt_rate > 0.0
    }

    /// Per-round probability that the coordinator is killed mid-round
    /// (drawn by the engine from the counter-based
    /// [`SERVER_FAULT_STREAM_TAG`] stream, not by this plan).
    pub fn server_rate(&self) -> f64 {
        self.server_rate
    }

    /// Per-gradient corruption probability (drawn via
    /// [`FaultPlan::draw_corrupt`]).
    pub fn corrupt_rate(&self) -> f64 {
        self.corrupt_rate
    }

    /// Draw this round's gradient-corruption flags: one draw per present
    /// client in slot-index order (scheme-independent, like every fault
    /// draw), written into the engine's reused `flags` buffer. Returns
    /// the number of flagged clients. A zero corrupt rate returns before
    /// the first draw, so other fault mixes keep their exact historical
    /// streams.
    pub fn draw_corrupt(
        &self,
        trace: &RoundTrace,
        flags: &mut Vec<bool>,
        rng: &mut Rng,
    ) -> usize {
        flags.clear();
        flags.resize(trace.num_clients(), false);
        if self.corrupt_rate <= 0.0 {
            return 0;
        }
        let mut n = 0;
        for j in 0..trace.num_clients() {
            if trace.is_present(j) && rng.next_f64() < self.corrupt_rate {
                flags[j] = true;
                n += 1;
            }
        }
        n
    }

    /// Inject this round's faults into a freshly sampled `trace`.
    pub fn apply(&self, trace: &mut RoundTrace, rng: &mut Rng) {
        if !self.is_active() {
            return;
        }
        let mut repriced = false;
        for j in 0..trace.num_clients() {
            if !trace.is_present(j) {
                continue;
            }
            if self.crash_rate > 0.0 && rng.next_f64() < self.crash_rate {
                trace.fail_compute(j);
                continue;
            }
            if self.link_rate > 0.0 && rng.next_f64() < self.link_rate {
                let mut delivered = false;
                for attempt in 1..=self.link_retries {
                    if rng.next_f64() >= self.link_rate {
                        // Redelivered: each failed attempt cost one backoff
                        // plus one retransmission — two uplink durations.
                        let legs = trace.legs(j).expect("present client has legs");
                        let t = legs.total() + attempt as f64 * 2.0 * legs.uplink_time();
                        trace.reprice_uplink(j, t);
                        repriced = true;
                        delivered = true;
                        break;
                    }
                }
                if !delivered {
                    trace.fail_uplink(j);
                }
            }
        }
        if self.parity_rate > 0.0 && rng.next_f64() < self.parity_rate {
            trace.fail_parity();
        }
        if repriced {
            // Removals preserve the sorted event order; only re-priced
            // uplinks can move an event later.
            trace.resort_events();
        }
    }
}

/// When the coordinator closes each round (`[training] deadline = …`,
/// `--deadline`, [`crate::ExperimentBuilder::deadline`]). Outside `none`
/// the engine truncates the sampled trace at the deadline and resolves
/// the aggregate through its degradation ladder
/// ([`crate::coordinator::engine`]).
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub enum DeadlineSpec {
    /// No deadline: every scheme's own waiting policy prices the round
    /// (default; bit-identical to pre-deadline runs).
    #[default]
    None,
    /// Close the round once a `q`-fraction of the present clients have
    /// arrived (the ⌈q·k⌉-th order statistic of this round's delays).
    Quantile { q: f64 },
    /// Close the round at a fixed simulated time `t` (seconds).
    Fixed { t: f64 },
}

impl DeadlineSpec {
    pub fn label(&self) -> String {
        match self {
            DeadlineSpec::None => "none".into(),
            DeadlineSpec::Quantile { q } => format!("quantile(q={q})"),
            DeadlineSpec::Fixed { t } => format!("fixed(t={t})"),
        }
    }

    /// Parse a deadline string: `none`, `quantile:q=0.8`, `fixed:t=30`.
    pub fn parse(s: &str) -> Result<DeadlineSpec, String> {
        let (name, params) = match s.split_once(':') {
            Some((n, p)) => (n.trim(), Some(p)),
            None => (s.trim(), None),
        };
        let one = |key: &str, default: f64| -> Result<f64, String> {
            let Some(p) = params else { return Ok(default) };
            let part = p.trim();
            let (k, v) = part.split_once('=').ok_or_else(|| {
                format!("deadline {name:?}: expected key=value, got {part:?}")
            })?;
            if k.trim() != key {
                return Err(format!(
                    "deadline {name:?}: unknown parameter {:?} (expected one of {key})",
                    k.trim()
                ));
            }
            v.trim()
                .parse::<f64>()
                .map_err(|e| format!("deadline {name:?}: {key}: {e}"))
        };
        let spec = match name {
            "none" => match params {
                None => DeadlineSpec::None,
                Some(p) => {
                    return Err(format!("deadline \"none\" takes no parameters, got {p:?}"))
                }
            },
            "quantile" => DeadlineSpec::Quantile { q: one("q", 0.9)? },
            "fixed" => DeadlineSpec::Fixed { t: one("t", 30.0)? },
            other => {
                return Err(format!(
                    "unknown deadline {other:?} (expected one of none | quantile[:q=0.9] | \
                     fixed[:t=30])"
                ))
            }
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Range-check the parameters (also called by the config validator).
    pub fn validate(&self) -> Result<(), String> {
        match *self {
            DeadlineSpec::None => Ok(()),
            DeadlineSpec::Quantile { q } => {
                if !(q > 0.0 && q <= 1.0) {
                    return Err(format!(
                        "deadline \"quantile\": q={q} out of range (expected one of (0,1])"
                    ));
                }
                Ok(())
            }
            DeadlineSpec::Fixed { t } => {
                if !(t > 0.0) {
                    return Err(format!(
                        "deadline \"fixed\": t={t} out of range (expected one of t > 0)"
                    ));
                }
                Ok(())
            }
        }
    }
}

impl std::str::FromStr for DeadlineSpec {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        DeadlineSpec::parse(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{FleetSpec, FleetView};

    fn sampled_trace(n: usize, seed: u64) -> RoundTrace {
        let spec = FleetSpec::paper(n, 64, 10);
        let clients = spec.build_clients(&mut Rng::seed_from(2));
        let links = spec.build_links(&clients);
        let server = spec.build_server();
        let view = FleetView::from_base(&links, server);
        let mut trace = RoundTrace::with_capacity(n);
        trace.sample_into(&view, &vec![9.0; n], 20.0, &mut Rng::seed_from(seed));
        trace
    }

    #[test]
    fn spec_parse_roundtrip_and_defaults() {
        assert_eq!(FaultSpec::parse("none").unwrap(), FaultSpec::None);
        assert_eq!(FaultSpec::parse("crash").unwrap(), FaultSpec::Crash { rate: 0.1 });
        assert_eq!(
            FaultSpec::parse("crash:rate=0.3").unwrap(),
            FaultSpec::Crash { rate: 0.3 }
        );
        assert_eq!(
            FaultSpec::parse("link:rate=0.2,retry=2").unwrap(),
            FaultSpec::Link { rate: 0.2, retry: 2 }
        );
        assert_eq!(
            FaultSpec::parse("parity:rate=0.5").unwrap(),
            FaultSpec::Parity { rate: 0.5 }
        );
        assert_eq!(
            "mixed:crash=0.1,link=0.2,parity=0.3".parse::<FaultSpec>().unwrap(),
            FaultSpec::Mixed { crash: 0.1, link: 0.2, parity: 0.3 }
        );
        // Rate 1.0 is legal: the empty-round regression knob.
        assert_eq!(
            FaultSpec::parse("crash:rate=1").unwrap(),
            FaultSpec::Crash { rate: 1.0 }
        );
        assert_eq!(
            FaultSpec::parse("server:rate=0.2").unwrap(),
            FaultSpec::Server { rate: 0.2 }
        );
        assert_eq!(FaultSpec::parse("server").unwrap(), FaultSpec::Server { rate: 0.1 });
        assert_eq!(
            "corrupt:rate=1".parse::<FaultSpec>().unwrap(),
            FaultSpec::Corrupt { rate: 1.0 }
        );
    }

    #[test]
    fn spec_parse_rejects_garbage() {
        assert!(FaultSpec::parse("meteor").is_err());
        assert!(FaultSpec::parse("none:rate=0.1").is_err());
        assert!(FaultSpec::parse("crash:probability=0.1").is_err());
        assert!(FaultSpec::parse("crash:rate=lots").is_err());
        assert!(FaultSpec::parse("crash:rate=1.5").is_err());
        assert!(FaultSpec::parse("crash:rate=-0.1").is_err());
        assert!(FaultSpec::parse("link:retry=1.5").is_err());
        assert!(FaultSpec::parse("link:retry=-1").is_err());
        assert!(FaultSpec::parse("mixed:link=2").is_err());
        assert!(FaultSpec::parse("server:rate=1.5").is_err());
        assert!(FaultSpec::parse("corrupt:rate=-0.2").is_err());
        let e = FaultSpec::parse("meteor").unwrap_err();
        assert!(e.contains("server[:rate=r]") && e.contains("corrupt[:rate=r]"), "{e}");
        let e = FaultSpec::parse("crash:probability=0.1").unwrap_err();
        assert!(e.contains("probability") && e.contains("rate"), "{e}");
        let e = FaultSpec::parse("meteor").unwrap_err();
        assert!(e.contains("expected one of"), "{e}");
        let e = FaultSpec::parse("crash:rate=1.5").unwrap_err();
        assert!(e.contains("rate") && e.contains("expected one of"), "{e}");
    }

    #[test]
    fn deadline_parse_roundtrip_and_rejects_out_of_range() {
        assert_eq!(DeadlineSpec::parse("none").unwrap(), DeadlineSpec::None);
        assert_eq!(
            DeadlineSpec::parse("quantile:q=0.8").unwrap(),
            DeadlineSpec::Quantile { q: 0.8 }
        );
        assert_eq!(DeadlineSpec::parse("quantile").unwrap(), DeadlineSpec::Quantile { q: 0.9 });
        assert_eq!("fixed:t=25".parse::<DeadlineSpec>().unwrap(), DeadlineSpec::Fixed { t: 25.0 });
        assert!(DeadlineSpec::parse("soonish").is_err());
        assert!(DeadlineSpec::parse("quantile:q=0").is_err());
        assert!(DeadlineSpec::parse("quantile:q=1.2").is_err());
        assert!(DeadlineSpec::parse("fixed:t=0").is_err());
        assert!(DeadlineSpec::parse("fixed:t=-3").is_err());
        assert!(DeadlineSpec::parse("none:q=1").is_err());
        let e = DeadlineSpec::parse("quantile:q=0").unwrap_err();
        assert!(e.contains("q=0") && e.contains("expected one of"), "{e}");
        let e = DeadlineSpec::parse("soonish").unwrap_err();
        assert!(e.contains("expected one of"), "{e}");
    }

    #[test]
    fn labels_roundtrip() {
        for spec in [
            FaultSpec::None,
            FaultSpec::Crash { rate: 0.3 },
            FaultSpec::Link { rate: 0.2, retry: 2 },
            FaultSpec::Parity { rate: 0.5 },
            FaultSpec::Mixed { crash: 0.1, link: 0.2, parity: 0.3 },
        ] {
            assert!(!spec.label().is_empty());
        }
        assert_eq!(FaultSpec::Crash { rate: 0.3 }.label(), "crash(rate=0.3)");
        assert_eq!(FaultSpec::Server { rate: 0.2 }.label(), "server(rate=0.2)");
        assert_eq!(FaultSpec::Corrupt { rate: 0.4 }.label(), "corrupt(rate=0.4)");
        assert_eq!(DeadlineSpec::Quantile { q: 0.8 }.label(), "quantile(q=0.8)");
    }

    #[test]
    fn server_faults_are_inactive_for_the_trace_but_expose_their_rate() {
        let plan = FaultSpec::Server { rate: 0.7 }.build();
        assert!(!plan.is_active(), "server kills must not flip degraded mode");
        assert_eq!(plan.server_rate(), 0.7);
        // apply() is a no-op that never touches the RNG, so the realized
        // trace history equals faults = none.
        let mut trace = sampled_trace(4, 31);
        let before = trace.clone();
        let mut rng = Rng::seed_from(5);
        let probe = rng.clone();
        plan.apply(&mut trace, &mut rng);
        assert_eq!(trace.delays().client_t, before.delays().client_t);
        let (mut a, mut b) = (rng, probe);
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn corrupt_draws_flag_present_clients_only() {
        let plan = FaultSpec::Corrupt { rate: 1.0 }.build();
        assert!(plan.is_active());
        assert_eq!(plan.corrupt_rate(), 1.0);
        let trace = sampled_trace(6, 37);
        let mut flags = Vec::new();
        let n = plan.draw_corrupt(&trace, &mut flags, &mut Rng::seed_from(3));
        assert_eq!(n, trace.delays().present_count());
        for j in 0..6 {
            assert_eq!(flags[j], trace.is_present(j));
        }
        // Zero rate: flags cleared, RNG untouched.
        let zero = FaultSpec::Crash { rate: 0.5 }.build();
        let mut rng = Rng::seed_from(9);
        let probe = rng.clone();
        assert_eq!(zero.draw_corrupt(&trace, &mut flags, &mut rng), 0);
        assert!(flags.iter().all(|&f| !f));
        let (mut a, mut b) = (rng, probe);
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn inactive_plan_never_touches_the_rng() {
        let mut trace = sampled_trace(4, 7);
        let before = trace.clone();
        let mut rng = Rng::seed_from(5);
        let probe = rng.clone();
        FaultSpec::None.build().apply(&mut trace, &mut rng);
        FaultSpec::Crash { rate: 0.0 }.build().apply(&mut trace, &mut rng);
        assert_eq!(trace.delays().client_t, before.delays().client_t);
        assert_eq!(trace.events().len(), before.events().len());
        let mut a = rng;
        let mut b = probe;
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn crash_rate_one_removes_every_arrival() {
        let mut trace = sampled_trace(5, 11);
        let mut rng = Rng::seed_from(3);
        FaultSpec::Crash { rate: 1.0 }.build().apply(&mut trace, &mut rng);
        assert_eq!(trace.delays().present_count(), 0);
        for j in 0..5 {
            assert!(!trace.is_present(j));
            assert!(trace.delays().client_t[j].is_infinite());
        }
        // Crashed clients still received θ: downlink events survive, the
        // compute/uplink legs never complete. Parity is untouched.
        assert_eq!(trace.events().len(), 5 + 1);
        assert!(trace.server_time().is_finite());
    }

    #[test]
    fn link_loss_without_retry_drops_only_the_uplink() {
        let mut trace = sampled_trace(5, 13);
        let events_before = trace.events().len();
        let mut rng = Rng::seed_from(9);
        FaultSpec::Link { rate: 1.0, retry: 0 }.build().apply(&mut trace, &mut rng);
        // Every payload lost, but downlink + compute events survive.
        assert_eq!(trace.delays().present_count(), 0);
        assert_eq!(trace.events().len(), events_before - 5);
        assert!(trace.server_time().is_finite());
    }

    #[test]
    fn link_retry_reprices_the_uplink_with_backoff() {
        // Over several seeds some client must get its payload through on a
        // retry; every re-priced delay must be total + k·2·uplink for an
        // attempt count k within the retry budget.
        let plan = FaultSpec::Link { rate: 0.5, retry: 3 }.build();
        let mut saw_reprice = false;
        for seed in 0..8u64 {
            let base = sampled_trace(6, 17);
            let mut trace = base.clone();
            plan.apply(&mut trace, &mut Rng::seed_from(seed));
            for j in 0..6 {
                if !trace.is_present(j) {
                    continue;
                }
                let legs = base.legs(j).expect("present in base");
                let t = trace.delays().client_t[j];
                let t0 = legs.total();
                if t > t0 {
                    saw_reprice = true;
                    let extra = t - t0;
                    let unit = 2.0 * legs.uplink_time();
                    let k = (extra / unit).round();
                    assert!(
                        (1.0..=3.0).contains(&k),
                        "client {j}: extra {extra}, unit {unit}"
                    );
                    assert!((extra - k * unit).abs() < 1e-9);
                } else {
                    assert_eq!(t.to_bits(), t0.to_bits(), "unfaulted client {j} unchanged");
                }
            }
            // Events stay time-ordered after the resort.
            for w in trace.events().windows(2) {
                assert!(w[0].time() <= w[1].time());
            }
        }
        assert!(saw_reprice, "no uplink re-priced across 8 seeds");
    }

    #[test]
    fn parity_fault_removes_the_server_event() {
        let mut trace = sampled_trace(3, 19);
        let mut rng = Rng::seed_from(1);
        FaultSpec::Parity { rate: 1.0 }.build().apply(&mut trace, &mut rng);
        assert!(trace.server_time().is_infinite());
        assert!(trace.events().iter().all(|e| e.client().is_some()));
        // Clients untouched.
        assert_eq!(trace.delays().present_count(), 3);
    }

    #[test]
    fn fault_draws_are_reproducible() {
        let mut a = sampled_trace(8, 23);
        let mut b = sampled_trace(8, 23);
        let plan = FaultSpec::Mixed { crash: 0.3, link: 0.3, parity: 0.5 }.build();
        plan.apply(&mut a, &mut Rng::seed_from(77));
        plan.apply(&mut b, &mut Rng::seed_from(77));
        assert_eq!(a.delays().client_t, b.delays().client_t);
        assert_eq!(a.delays().server_t.to_bits(), b.delays().server_t.to_bits());
        assert_eq!(a.events().len(), b.events().len());
    }

    #[test]
    fn close_at_truncates_trace_and_events() {
        let mut trace = sampled_trace(6, 29);
        let t = trace.delays().client_t.iter().cloned().fold(0.0, f64::max) * 0.5;
        trace.close_at(t);
        for j in 0..6 {
            let ct = trace.delays().client_t[j];
            assert!(ct <= t || ct.is_infinite());
            assert_eq!(trace.is_present(j), ct.is_finite());
        }
        assert!(trace.events().iter().all(|e| e.time() <= t));
        assert!(trace.delays().server_t <= t || trace.delays().server_t.is_infinite());
    }
}
