//! Convergence-analysis helpers (paper Appendix E).
//!
//! Under the simplifying assumption `GᵀG/u = I`, CodedFedL is SGD with an
//! unbiased gradient whose variance is bounded by `B = Σ_j B_j` (eq. 58)
//! and whose full objective is `L`-smooth with `L = (1/m) Σ_j L_j²`
//! (eq. 59, `L_j` = max singular value of `X̂^(j)`). The paper's bound:
//!
//! ```text
//! E[f(θ̄)] − min f ≤ R √(2B / r_max) + L R² / r_max            (eq. 60)
//! r_max(ε) = O( R² · max(2B/ε², L/ε) )
//! ```

use crate::tensor::Mat;

/// Estimate the largest singular value of `X` by power iteration on
/// `XᵀX` (returns σ_max, i.e. the square root of the top eigenvalue).
pub fn max_singular_value(x: &Mat, iters: usize) -> f64 {
    let (n, d) = (x.rows(), x.cols());
    assert!(n > 0 && d > 0);
    let mut v = vec![1.0f64 / (d as f64).sqrt(); d];
    let mut lambda = 0.0f64;
    for _ in 0..iters {
        // w = X^T (X v)
        let mut xv = vec![0.0f64; n];
        for i in 0..n {
            let row = x.row(i);
            let mut s = 0.0f64;
            for (j, &rv) in row.iter().enumerate() {
                s += rv as f64 * v[j];
            }
            xv[i] = s;
        }
        let mut w = vec![0.0f64; d];
        for i in 0..n {
            let row = x.row(i);
            let s = xv[i];
            for (j, &rv) in row.iter().enumerate() {
                w[j] += rv as f64 * s;
            }
        }
        let norm = w.iter().map(|a| a * a).sum::<f64>().sqrt();
        if norm == 0.0 {
            return 0.0;
        }
        lambda = norm;
        for (vj, wj) in v.iter_mut().zip(&w) {
            *vj = wj / norm;
        }
    }
    lambda.sqrt()
}

/// Smoothness constant `L = (1/m) Σ_j L_j²` from per-client top singular
/// values (eq. 59).
pub fn smoothness_l(sigma_max: &[f64], m: usize) -> f64 {
    assert!(m > 0);
    sigma_max.iter().map(|s| s * s).sum::<f64>() / m as f64
}

/// Suboptimality bound after `r_max` iterations (eq. 60).
pub fn suboptimality_bound(r: f64, b: f64, l: f64, r_max: usize) -> f64 {
    assert!(r_max > 0);
    r * (2.0 * b / r_max as f64).sqrt() + l * r * r / r_max as f64
}

/// Iteration complexity to reach error `ε` (paper: `O(R² max(2B/ε², L/ε))`).
pub fn iteration_complexity(r: f64, b: f64, l: f64, eps: f64) -> f64 {
    assert!(eps > 0.0);
    r * r * (2.0 * b / (eps * eps)).max(l / eps)
}

/// The constant learning rate the analysis prescribes:
/// `μ = 1 / (L + 1/γ)`, `γ = √(2R²/(B·r_max))`.
pub fn prescribed_lr(r: f64, b: f64, l: f64, r_max: usize) -> f64 {
    let gamma = (2.0 * r * r / (b * r_max as f64)).sqrt();
    1.0 / (l + 1.0 / gamma)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn power_iteration_diagonal() {
        // X = diag(3, 1) => sigma_max = 3.
        let x = Mat::from_vec(2, 2, vec![3.0, 0.0, 0.0, 1.0]);
        let s = max_singular_value(&x, 50);
        assert!((s - 3.0).abs() < 1e-6, "{s}");
    }

    #[test]
    fn power_iteration_rank_one() {
        // X = u v^T with |u| = 2, |v| = 5 ⇒ σ = 10.
        let x = Mat::from_fn(4, 25, |_, _| 0.0);
        let mut x = x;
        for i in 0..4 {
            for j in 0..25 {
                x.set(i, j, 1.0); // u = ones(4) (norm 2), v = ones(25) (norm 5)
            }
        }
        let s = max_singular_value(&x, 20);
        assert!((s - 10.0).abs() < 1e-6, "{s}");
    }

    #[test]
    fn power_iteration_zero_matrix() {
        assert_eq!(max_singular_value(&Mat::zeros(3, 3), 10), 0.0);
    }

    #[test]
    fn smoothness_formula() {
        assert!((smoothness_l(&[2.0, 3.0], 13) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn bound_decreases_in_iterations() {
        let b1 = suboptimality_bound(1.0, 4.0, 2.0, 10);
        let b2 = suboptimality_bound(1.0, 4.0, 2.0, 1000);
        assert!(b2 < b1);
    }

    #[test]
    fn complexity_regimes() {
        // variance-dominated when 2B/eps^2 > L/eps
        let r = iteration_complexity(2.0, 10.0, 1.0, 0.1);
        assert!((r - 4.0 * 2000.0).abs() < 1e-9);
        // smoothness-dominated for tiny B
        let r2 = iteration_complexity(2.0, 1e-9, 5.0, 0.1);
        assert!((r2 - 4.0 * 50.0).abs() < 1e-6);
    }

    #[test]
    fn lr_positive_and_sane() {
        let lr = prescribed_lr(1.0, 4.0, 2.0, 100);
        assert!(lr > 0.0 && lr < 1.0);
    }
}
