//! Export of convergence histories: CSV (for external plotting) and
//! markdown tables (for EXPERIMENTS.md-style reports).

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use super::{GainRow, History};

/// Write several histories into one long-format CSV:
/// `scheme,iter,sim_time_s,accuracy,train_loss`.
pub fn write_csv(path: &Path, histories: &[&History]) -> Result<()> {
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating {path:?}"))?;
    writeln!(f, "scheme,iter,sim_time_s,accuracy,train_loss")?;
    for h in histories {
        for p in &h.points {
            writeln!(
                f,
                "{},{},{:.6},{:.6},{:.6}",
                h.label, p.iter, p.sim_time, p.accuracy, p.train_loss
            )?;
        }
    }
    Ok(())
}

/// Render histories to the same CSV format as a string (for tests /
/// stdout piping).
pub fn to_csv_string(histories: &[&History]) -> String {
    let mut s = String::from("scheme,iter,sim_time_s,accuracy,train_loss\n");
    for h in histories {
        for p in &h.points {
            s.push_str(&format!(
                "{},{},{:.6},{:.6},{:.6}\n",
                h.label, p.iter, p.sim_time, p.accuracy, p.train_loss
            ));
        }
    }
    s
}

/// Render evaluated round events — one line per [`RoundEvent`] — with the
/// communication model's bytes-on-wire columns:
/// `scheme,iter,sim_time_s,accuracy,train_loss,bytes_down,bytes_up`.
///
/// This is a separate long-format CSV from [`to_csv_string`] on purpose:
/// the history CSV's shape is pinned by downstream plotting scripts, while
/// byte accounting rides on the observer event stream (`[comm]`).
pub fn round_csv_string(label: &str, events: &[crate::coordinator::RoundEvent]) -> String {
    let mut s = String::from("scheme,iter,sim_time_s,accuracy,train_loss,bytes_down,bytes_up\n");
    for ev in events {
        s.push_str(&format!(
            "{},{},{:.6},{:.6},{:.6},{},{}\n",
            label, ev.iter, ev.clock, ev.acc, ev.loss, ev.bytes_down, ev.bytes_up
        ));
    }
    s
}

/// Write [`round_csv_string`]'s format to `path`.
pub fn write_round_csv(
    path: &Path,
    label: &str,
    events: &[crate::coordinator::RoundEvent],
) -> Result<()> {
    std::fs::write(path, round_csv_string(label, events))
        .with_context(|| format!("writing {path:?}"))
}

/// Markdown gain table in the paper's Table II/III layout.
pub fn gain_table_markdown(rows: &[GainRow]) -> String {
    let mut s = String::from(
        "| γ (%) | t_U (h) | t_G (h) | t_C (h) | t_U/t_C | t_G/t_C |\n\
         |---|---|---|---|---|---|\n",
    );
    let h = |t: Option<f64>| {
        t.map(|x| format!("{:.2}", x / 3600.0)).unwrap_or_else(|| "—".into())
    };
    let g = |x: Option<f64>| x.map(|v| format!("{v:.1}×")).unwrap_or_else(|| "—".into());
    for r in rows {
        s.push_str(&format!(
            "| {:.1} | {} | {} | {} | {} | {} |\n",
            r.gamma * 100.0,
            h(r.t_naive),
            h(r.t_greedy),
            h(r.t_coded),
            g(r.gain_vs_naive()),
            g(r.gain_vs_greedy()),
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Point;

    fn hist() -> History {
        let mut h = History::new("coded(delta=0.1)");
        h.push(Point { iter: 1, sim_time: 10.0, accuracy: 0.5, train_loss: 1.0 });
        h.push(Point { iter: 2, sim_time: 20.0, accuracy: 0.75, train_loss: 0.5 });
        h
    }

    #[test]
    fn csv_format() {
        let h = hist();
        let s = to_csv_string(&[&h]);
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 3);
        assert_eq!(lines[0], "scheme,iter,sim_time_s,accuracy,train_loss");
        assert!(lines[1].starts_with("coded(delta=0.1),1,10.000000,0.500000"));
    }

    #[test]
    fn csv_roundtrips_to_file() {
        let h = hist();
        let dir = std::env::temp_dir().join("codedfedl_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("hist.csv");
        write_csv(&path, &[&h]).unwrap();
        let back = std::fs::read_to_string(&path).unwrap();
        assert_eq!(back, to_csv_string(&[&h]));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn round_csv_carries_bytes_columns() {
        let ev = crate::coordinator::RoundEvent {
            iter: 3,
            epoch: 0,
            step: 2,
            clock: 42.5,
            arrivals: 28,
            planned: 30,
            outcome: crate::metrics::RoundOutcome::Full,
            corrupted: 0,
            loss: 0.25,
            acc: 0.875,
            bytes_down: 10_560_000,
            bytes_up: 4_752_000,
        };
        let s = round_csv_string("coded(delta=0.1)", &[ev]);
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(
            lines[0],
            "scheme,iter,sim_time_s,accuracy,train_loss,bytes_down,bytes_up"
        );
        assert_eq!(
            lines[1],
            "coded(delta=0.1),3,42.500000,0.875000,0.250000,10560000,4752000"
        );
    }

    #[test]
    fn markdown_table_shapes() {
        let naive = hist();
        let row = GainRow::compute(0.7, &naive, &naive, &naive);
        let md = gain_table_markdown(&[row]);
        assert!(md.contains("| 70.0 |"));
        assert!(md.contains("1.0×"));
        assert_eq!(md.lines().count(), 3);
    }

    #[test]
    fn markdown_handles_missing() {
        let naive = hist();
        let row = GainRow::compute(0.99, &naive, &naive, &naive); // unreachable
        let md = gain_table_markdown(&[row]);
        assert!(md.contains("—"));
    }
}
