//! Training telemetry: accuracy, convergence histories, time-to-accuracy
//! extraction and the gain tables of the paper's §V-B (Tables II/III).

pub mod export;

use crate::tensor::Mat;

/// Classification accuracy of `logits [n, c]` against integer labels.
pub fn accuracy(logits: &Mat, labels: &[u8]) -> f64 {
    assert_eq!(logits.rows(), labels.len());
    assert!(!labels.is_empty());
    let pred = logits.argmax_rows();
    let hits = pred
        .iter()
        .zip(labels)
        .filter(|(p, l)| **p == **l as usize)
        .count();
    hits as f64 / labels.len() as f64
}

/// One recorded evaluation point.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Point {
    /// 1-based training iteration.
    pub iter: usize,
    /// Cumulative *simulated* MEC wall-clock (seconds), including any
    /// one-time overheads (parity upload).
    pub sim_time: f64,
    /// Test accuracy in [0, 1].
    pub accuracy: f64,
    /// Training objective (regularised squared loss) if recorded.
    pub train_loss: f64,
}

/// A scheme's convergence history.
#[derive(Clone, Debug, Default)]
pub struct History {
    pub label: String,
    pub points: Vec<Point>,
}

impl History {
    pub fn new(label: impl Into<String>) -> Self {
        History { label: label.into(), points: Vec::new() }
    }

    pub fn push(&mut self, p: Point) {
        debug_assert!(
            self.points.last().map_or(true, |last| p.sim_time >= last.sim_time),
            "sim_time must be monotone"
        );
        self.points.push(p);
    }

    /// First simulated time at which accuracy `gamma` is reached
    /// (`t_γ` of §V-B), or `None` if never.
    pub fn time_to_accuracy(&self, gamma: f64) -> Option<f64> {
        self.points.iter().find(|p| p.accuracy >= gamma).map(|p| p.sim_time)
    }

    /// First iteration at which accuracy `gamma` is reached.
    pub fn iters_to_accuracy(&self, gamma: f64) -> Option<usize> {
        self.points.iter().find(|p| p.accuracy >= gamma).map(|p| p.iter)
    }

    pub fn final_accuracy(&self) -> f64 {
        self.points.last().map_or(0.0, |p| p.accuracy)
    }

    /// Best accuracy over the run (robust to late-stage noise).
    pub fn best_accuracy(&self) -> f64 {
        self.points.iter().map(|p| p.accuracy).fold(0.0, f64::max)
    }

    pub fn total_sim_time(&self) -> f64 {
        self.points.last().map_or(0.0, |p| p.sim_time)
    }
}

/// Which rung of the engine's degradation ladder resolved a round's
/// aggregate (see `coordinator::engine`). Ordered best → worst: the
/// engine records exactly one outcome per round, and experiments report
/// the histogram ([`OutcomeCounts`]) next to achieved participation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoundOutcome {
    /// Every planned gradient arrived; the aggregate is the full planned
    /// sum (also the only outcome outside degraded mode).
    Full,
    /// Some planned gradients were missing but the erasure code decoded
    /// them exactly from the arrived subset + parity.
    ExactDecode,
    /// The coded scheme compensated for stragglers with the parity
    /// gradient in expectation (the paper's operating mode).
    ParityCompensation,
    /// A renormalized partial fold over the arrivals that beat the
    /// deadline — unbiased per-sample scaling, reduced participation.
    PartialFold,
    /// Nothing usable arrived: the round was skipped. Theta is unchanged
    /// and the round still advances the simulated clock.
    Skip,
}

impl RoundOutcome {
    /// Stable index into [`OutcomeCounts`]' rung histogram.
    pub fn rung(self) -> usize {
        match self {
            RoundOutcome::Full => 0,
            RoundOutcome::ExactDecode => 1,
            RoundOutcome::ParityCompensation => 2,
            RoundOutcome::PartialFold => 3,
            RoundOutcome::Skip => 4,
        }
    }

    /// Short stable label (bench reports, CLI telemetry).
    pub fn label(self) -> &'static str {
        match self {
            RoundOutcome::Full => "full",
            RoundOutcome::ExactDecode => "exact_decode",
            RoundOutcome::ParityCompensation => "parity",
            RoundOutcome::PartialFold => "partial",
            RoundOutcome::Skip => "skip",
        }
    }
}

/// Per-run histogram of [`RoundOutcome`] rungs, accumulated by the engine
/// for every training round (not just evaluated ones).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OutcomeCounts {
    pub full: u64,
    pub exact_decode: u64,
    pub parity: u64,
    pub partial: u64,
    pub skip: u64,
}

impl OutcomeCounts {
    pub fn record(&mut self, outcome: RoundOutcome) {
        match outcome {
            RoundOutcome::Full => self.full += 1,
            RoundOutcome::ExactDecode => self.exact_decode += 1,
            RoundOutcome::ParityCompensation => self.parity += 1,
            RoundOutcome::PartialFold => self.partial += 1,
            RoundOutcome::Skip => self.skip += 1,
        }
    }

    /// Total rounds recorded.
    pub fn total(&self) -> u64 {
        self.full + self.exact_decode + self.parity + self.partial + self.skip
    }

    /// Rounds that resolved below the top (full-participation) rung.
    pub fn degraded(&self) -> u64 {
        self.exact_decode + self.parity + self.partial + self.skip
    }

    /// The histogram as a fixed rung-indexed array
    /// (`[full, exact_decode, parity, partial, skip]` — schema-6 bench
    /// column order).
    pub fn as_array(&self) -> [u64; 5] {
        [self.full, self.exact_decode, self.parity, self.partial, self.skip]
    }
}

/// One row of Table II/III: target accuracy + per-scheme times + gains.
#[derive(Clone, Debug)]
pub struct GainRow {
    pub gamma: f64,
    pub t_naive: Option<f64>,
    pub t_greedy: Option<f64>,
    pub t_coded: Option<f64>,
}

impl GainRow {
    pub fn compute(
        gamma: f64,
        naive: &History,
        greedy: &History,
        coded: &History,
    ) -> GainRow {
        GainRow {
            gamma,
            t_naive: naive.time_to_accuracy(gamma),
            t_greedy: greedy.time_to_accuracy(gamma),
            t_coded: coded.time_to_accuracy(gamma),
        }
    }

    /// `t_γ^U / t_γ^C` — the paper's naive-over-coded gain.
    pub fn gain_vs_naive(&self) -> Option<f64> {
        Some(self.t_naive? / self.t_coded?)
    }

    /// `t_γ^G / t_γ^C` — the paper's greedy-over-coded gain.
    pub fn gain_vs_greedy(&self) -> Option<f64> {
        Some(self.t_greedy? / self.t_coded?)
    }

    /// Render like the paper's tables (times in hours).
    pub fn render(&self) -> String {
        fn hours(t: Option<f64>) -> String {
            t.map(|s| format!("{:9.2}", s / 3600.0)).unwrap_or_else(|| format!("{:>9}", "—"))
        }
        fn gain(g: Option<f64>) -> String {
            g.map(|x| format!("{x:6.1}x")).unwrap_or_else(|| format!("{:>7}", "—"))
        }
        format!(
            "γ={:5.1}% | t_U={} h | t_G={} h | t_C={} h | U/C {} | G/C {}",
            self.gamma * 100.0,
            hours(self.t_naive),
            hours(self.t_greedy),
            hours(self.t_coded),
            gain(self.gain_vs_naive()),
            gain(self.gain_vs_greedy()),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_counts_hits() {
        // logits argmax: [1, 0, 2]; labels [1, 2, 2] => 2/3
        let logits = Mat::from_vec(
            3,
            3,
            vec![0.0, 9.0, 1.0, 8.0, 2.0, 3.0, 0.1, 0.2, 0.9],
        );
        let acc = accuracy(&logits, &[1, 2, 2]);
        assert!((acc - 2.0 / 3.0).abs() < 1e-12);
    }

    fn hist(label: &str, pts: &[(usize, f64, f64)]) -> History {
        let mut h = History::new(label);
        for &(i, t, a) in pts {
            h.push(Point { iter: i, sim_time: t, accuracy: a, train_loss: 0.0 });
        }
        h
    }

    #[test]
    fn time_to_accuracy_first_crossing() {
        let h = hist("x", &[(1, 10.0, 0.5), (2, 20.0, 0.8), (3, 30.0, 0.7), (4, 40.0, 0.9)]);
        assert_eq!(h.time_to_accuracy(0.8), Some(20.0));
        assert_eq!(h.iters_to_accuracy(0.8), Some(2));
        assert_eq!(h.time_to_accuracy(0.95), None);
        assert_eq!(h.final_accuracy(), 0.9);
        assert_eq!(h.best_accuracy(), 0.9);
        assert_eq!(h.total_sim_time(), 40.0);
    }

    #[test]
    fn gain_rows() {
        let naive = hist("n", &[(1, 100.0, 0.9)]);
        let greedy = hist("g", &[(1, 300.0, 0.9)]);
        let coded = hist("c", &[(1, 50.0, 0.9)]);
        let row = GainRow::compute(0.9, &naive, &greedy, &coded);
        assert_eq!(row.gain_vs_naive(), Some(2.0));
        assert_eq!(row.gain_vs_greedy(), Some(6.0));
        let s = row.render();
        assert!(s.contains("2.0x") && s.contains("6.0x"), "{s}");
    }

    #[test]
    fn outcome_counts_record_and_summarise() {
        let mut c = OutcomeCounts::default();
        for o in [
            RoundOutcome::Full,
            RoundOutcome::Full,
            RoundOutcome::ExactDecode,
            RoundOutcome::ParityCompensation,
            RoundOutcome::PartialFold,
            RoundOutcome::Skip,
        ] {
            c.record(o);
        }
        assert_eq!(c.total(), 6);
        assert_eq!(c.degraded(), 4);
        assert_eq!(c.as_array(), [2, 1, 1, 1, 1]);
        // rung indices match the histogram order
        for (i, o) in [
            RoundOutcome::Full,
            RoundOutcome::ExactDecode,
            RoundOutcome::ParityCompensation,
            RoundOutcome::PartialFold,
            RoundOutcome::Skip,
        ]
        .into_iter()
        .enumerate()
        {
            assert_eq!(o.rung(), i);
        }
        assert_eq!(RoundOutcome::Skip.label(), "skip");
    }

    #[test]
    fn gain_row_handles_unreached_target() {
        let naive = hist("n", &[(1, 100.0, 0.9)]);
        let greedy = hist("g", &[(1, 300.0, 0.5)]); // never reaches
        let coded = hist("c", &[(1, 50.0, 0.9)]);
        let row = GainRow::compute(0.9, &naive, &greedy, &coded);
        assert_eq!(row.t_greedy, None);
        assert_eq!(row.gain_vs_greedy(), None);
        assert!(row.render().contains("—"));
    }
}
