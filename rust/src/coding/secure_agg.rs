//! Secure aggregation of local parity datasets (paper §VI future work,
//! after Bonawitz et al. [53]).
//!
//! Each ordered client pair `(i, j)`, `i < j`, derives a shared mask
//! `M_ij` from a pairwise seed; client `i` ships `X̌^(i) + Σ_{j>i} M_ij −
//! Σ_{j<i} M_ji`, so the server's sum telescopes to the exact composite
//! parity `Σ_j X̌^(j)` while every individual upload is statistically
//! masked. Dropouts are handled by the survivors re-sharing the pairwise
//! seeds they held with the dropped client so the server can subtract the
//! orphaned masks (the standard seed-recovery path).

use crate::rng::Rng;
use crate::tensor::Mat;

/// Deterministic pairwise seed for clients `(i, j)` under a session seed.
/// Symmetric: both endpoints derive the same stream.
fn pair_seed(session: u64, i: usize, j: usize) -> u64 {
    let (a, b) = if i < j { (i, j) } else { (j, i) };
    session
        ^ (a as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ (b as u64).wrapping_mul(0xC2B2_AE3D_27D4_EB4F)
}

/// The pairwise mask `M_ij` (shape `rows × cols`) for `i < j`.
fn pair_mask(session: u64, i: usize, j: usize, rows: usize, cols: usize) -> Mat {
    let mut rng = Rng::seed_from(pair_seed(session, i, j));
    let mut m = Mat::zeros(rows, cols);
    rng.fill_normal_f32(m.as_mut_slice());
    m
}

/// Mask client `i`'s parity block for secure upload.
///
/// `n` is the total number of participating clients. The masking is
/// self-cancelling over the full set: `Σ_i masked_i = Σ_i parity_i`.
pub fn mask_parity(session: u64, i: usize, n: usize, parity: &Mat) -> Mat {
    let mut out = parity.clone();
    for j in 0..n {
        if j == i {
            continue;
        }
        let (lo, hi) = if i < j { (i, j) } else { (j, i) };
        let m = pair_mask(session, lo, hi, parity.rows(), parity.cols());
        // convention: the lower index adds, the higher subtracts
        out.axpy(if i == lo { 1.0 } else { -1.0 }, &m);
    }
    out
}

/// Server-side aggregation of masked uploads from the clients in `alive`
/// (indices into the original cohort of `n`). For every pair with exactly
/// one live endpoint, the orphaned mask is reconstructed from the
/// recovered pairwise seed and subtracted — the dropout-recovery path.
pub fn aggregate_masked(
    session: u64,
    n: usize,
    alive: &[usize],
    masked: &[Mat],
) -> Mat {
    assert_eq!(alive.len(), masked.len());
    assert!(!masked.is_empty(), "no uploads to aggregate");
    let rows = masked[0].rows();
    let cols = masked[0].cols();
    let mut sum = Mat::zeros(rows, cols);
    for m in masked {
        sum.axpy(1.0, m);
    }
    let is_alive = {
        let mut v = vec![false; n];
        for &a in alive {
            v[a] = true;
        }
        v
    };
    // Cancel masks whose peer dropped out.
    for &i in alive {
        for j in 0..n {
            if j == i || is_alive[j] {
                continue;
            }
            let (lo, hi) = if i < j { (i, j) } else { (j, i) };
            let m = pair_mask(session, lo, hi, rows, cols);
            // the live endpoint contributed +m (if lo) or −m (if hi);
            // remove that contribution
            sum.axpy(if i == lo { -1.0 } else { 1.0 }, &m);
        }
    }
    sum
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parities(n: usize, rows: usize, cols: usize) -> Vec<Mat> {
        (0..n)
            .map(|i| {
                let mut rng = Rng::seed_from(1000 + i as u64);
                let mut m = Mat::zeros(rows, cols);
                rng.fill_normal_f32(m.as_mut_slice());
                m
            })
            .collect()
    }

    #[test]
    fn full_cohort_sum_is_exact() {
        let n = 5;
        let ps = parities(n, 6, 4);
        let mut expect = Mat::zeros(6, 4);
        for p in &ps {
            expect.axpy(1.0, p);
        }
        let masked: Vec<Mat> = (0..n).map(|i| mask_parity(7, i, n, &ps[i])).collect();
        let alive: Vec<usize> = (0..n).collect();
        let sum = aggregate_masked(7, n, &alive, &masked);
        assert!(sum.max_abs_diff(&expect) < 1e-3, "{}", sum.max_abs_diff(&expect));
    }

    #[test]
    fn individual_upload_is_masked() {
        let ps = parities(3, 6, 4);
        let masked = mask_parity(7, 0, 3, &ps[0]);
        // masked upload must differ substantially from the raw parity
        assert!(masked.max_abs_diff(&ps[0]) > 0.5);
    }

    #[test]
    fn dropout_recovery_restores_survivor_sum() {
        let n = 6;
        let ps = parities(n, 5, 3);
        let masked: Vec<Mat> = (0..n).map(|i| mask_parity(11, i, n, &ps[i])).collect();
        // clients 2 and 4 drop out
        let alive: Vec<usize> = vec![0, 1, 3, 5];
        let uploads: Vec<Mat> = alive.iter().map(|&i| masked[i].clone()).collect();
        let sum = aggregate_masked(11, n, &alive, &uploads);
        let mut expect = Mat::zeros(5, 3);
        for &i in &alive {
            expect.axpy(1.0, &ps[i]);
        }
        assert!(sum.max_abs_diff(&expect) < 1e-3, "{}", sum.max_abs_diff(&expect));
    }

    #[test]
    fn pair_seed_is_symmetric() {
        assert_eq!(pair_seed(3, 1, 4), pair_seed(3, 4, 1));
        assert_ne!(pair_seed(3, 1, 4), pair_seed(3, 1, 5));
        assert_ne!(pair_seed(3, 1, 4), pair_seed(4, 1, 4));
    }

    #[test]
    fn single_client_cohort_is_identity() {
        let ps = parities(1, 2, 2);
        let masked = mask_parity(9, 0, 1, &ps[0]);
        assert_eq!(masked.as_slice(), ps[0].as_slice());
    }
}
