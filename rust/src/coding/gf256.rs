//! GF(256) field arithmetic and erasure-coding row kernels.
//!
//! The rateless/exact-recovery coding layer works over the finite field
//! GF(2⁸) with the primitive polynomial `x⁸ + x⁴ + x³ + x² + 1` (0x11D,
//! the RFC 6330 / Reed–Solomon convention, generator α = 2). Addition is
//! XOR; multiplication goes through compile-time exp/log tables, so every
//! operation is exact — erasure decode reproduces the encoded bytes
//! bit-for-bit, on every thread count and under every SIMD policy.
//!
//! The symbol-row kernels ([`xor_row`], [`mul_acc_row`], [`scale_row`])
//! follow the `tensor::gemm` dispatch discipline: the caller resolves an
//! [`Isa`] once (at scheme/bench construction) and every call branches on
//! the copy it is handed. SIMD arms are feature-guarded so a
//! hand-constructed [`Isa`] degrades to the scalar oracle instead of
//! faulting, and the scalar loop is the bit-for-bit reference — trivially
//! so here, since XOR and table lookups carry no rounding. Coefficient-1
//! rows (the bulk of an LT/Raptor code, per the RFC 6330 errata's
//! binary-row observation) take the pure-XOR lane; general coefficients
//! run the scalar table loop, which only appears on the few dense rows of
//! elimination and of the dense baseline code.

use crate::tensor::Isa;

/// exp/log tables for GF(256) under 0x11D, built at compile time. `EXP`
/// is doubled (`EXP[i + 255] = EXP[i]`) so `mul` needs no modular
/// reduction: `log a + log b ≤ 508 < 510`.
const fn build_tables() -> ([u8; 512], [u8; 256]) {
    let mut exp = [0u8; 512];
    let mut log = [0u8; 256];
    let mut x: u16 = 1;
    let mut i = 0;
    while i < 255 {
        exp[i] = x as u8;
        log[x as usize] = i as u8;
        x <<= 1;
        if x & 0x100 != 0 {
            x ^= 0x11D;
        }
        i += 1;
    }
    let mut j = 0;
    while j < 255 {
        exp[255 + j] = exp[j];
        j += 1;
    }
    (exp, log)
}

const TABLES: ([u8; 512], [u8; 256]) = build_tables();
static GF_EXP: [u8; 512] = TABLES.0;
static GF_LOG: [u8; 256] = TABLES.1;

/// Field addition (= subtraction): XOR.
#[inline]
pub fn add(a: u8, b: u8) -> u8 {
    a ^ b
}

/// Field multiplication via the exp/log tables.
#[inline]
pub fn mul(a: u8, b: u8) -> u8 {
    if a == 0 || b == 0 {
        return 0;
    }
    GF_EXP[GF_LOG[a as usize] as usize + GF_LOG[b as usize] as usize]
}

/// Multiplicative inverse. Panics on 0, which has none.
#[inline]
pub fn inv(a: u8) -> u8 {
    assert!(a != 0, "gf256: inverse of zero");
    GF_EXP[255 - GF_LOG[a as usize] as usize]
}

/// Field division `a / b`. Panics when `b = 0`.
#[inline]
pub fn div(a: u8, b: u8) -> u8 {
    mul(a, inv(b))
}

/// Whether this host can run the AVX2 XOR lanes (cached CPUID probe, so
/// re-checking per dispatch is a load-and-test — the same safety net
/// `tensor::gemm` uses against hand-constructed [`Isa`] values).
#[cfg(target_arch = "x86_64")]
#[inline]
fn avx2_available() -> bool {
    std::arch::is_x86_feature_detected!("avx2")
}

/// Whether this host can run the NEON XOR lanes (cached probe).
#[cfg(target_arch = "aarch64")]
#[inline]
fn neon_available() -> bool {
    std::arch::is_aarch64_feature_detected!("neon")
}

/// `dst[i] ^= src[i]` — the coefficient-1 row update, and the hot loop of
/// the whole coding layer. Bit-identical across ISAs (XOR has no rounding);
/// the SIMD arms exist purely for throughput.
pub fn xor_row(isa: Isa, src: &[u8], dst: &mut [u8]) {
    assert_eq!(src.len(), dst.len(), "gf256::xor_row: length mismatch");
    match isa {
        Isa::Scalar => xor_row_scalar(src, dst),
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2Fma if avx2_available() => {
            // Safety: lengths asserted equal above; the guard verified AVX2.
            unsafe { xor_row_avx2(src, dst) }
        }
        #[cfg(target_arch = "aarch64")]
        Isa::Neon if neon_available() => {
            // Safety: lengths asserted equal above; the guard verified NEON.
            unsafe { xor_row_neon(src, dst) }
        }
        // An ISA this build has no kernel for, or this host lacks: degrade
        // to the scalar oracle, never fault.
        #[allow(unreachable_patterns)]
        _ => xor_row_scalar(src, dst),
    }
}

/// `dst[i] ^= coeff · src[i]` over GF(256). `coeff = 0` is a no-op,
/// `coeff = 1` takes the [`xor_row`] SIMD lane; general coefficients run
/// the scalar table loop (rare by construction — see the module docs).
pub fn mul_acc_row(isa: Isa, coeff: u8, src: &[u8], dst: &mut [u8]) {
    match coeff {
        0 => {}
        1 => xor_row(isa, src, dst),
        c => {
            assert_eq!(src.len(), dst.len(), "gf256::mul_acc_row: length mismatch");
            let log_c = GF_LOG[c as usize] as usize;
            for (d, &s) in dst.iter_mut().zip(src) {
                if s != 0 {
                    *d ^= GF_EXP[log_c + GF_LOG[s as usize] as usize];
                }
            }
        }
    }
}

/// `row[i] *= coeff` in place (pivot normalisation). `coeff` must be
/// nonzero — scaling a row to zero is never a valid elimination step.
pub fn scale_row(coeff: u8, row: &mut [u8]) {
    assert!(coeff != 0, "gf256::scale_row: zero coefficient");
    if coeff == 1 {
        return;
    }
    let log_c = GF_LOG[coeff as usize] as usize;
    for v in row.iter_mut() {
        if *v != 0 {
            *v = GF_EXP[log_c + GF_LOG[*v as usize] as usize];
        }
    }
}

fn xor_row_scalar(src: &[u8], dst: &mut [u8]) {
    for (d, &s) in dst.iter_mut().zip(src) {
        *d ^= s;
    }
}

/// Safety: caller guarantees `src.len() == dst.len()` and AVX2 support.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn xor_row_avx2(src: &[u8], dst: &mut [u8]) {
    use std::arch::x86_64::*;
    let n = dst.len();
    let mut i = 0;
    while i + 32 <= n {
        let a = _mm256_loadu_si256(dst.as_ptr().add(i) as *const __m256i);
        let b = _mm256_loadu_si256(src.as_ptr().add(i) as *const __m256i);
        _mm256_storeu_si256(dst.as_mut_ptr().add(i) as *mut __m256i, _mm256_xor_si256(a, b));
        i += 32;
    }
    while i < n {
        *dst.get_unchecked_mut(i) ^= *src.get_unchecked(i);
        i += 1;
    }
}

/// Safety: caller guarantees `src.len() == dst.len()` and NEON support.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn xor_row_neon(src: &[u8], dst: &mut [u8]) {
    use std::arch::aarch64::*;
    let n = dst.len();
    let mut i = 0;
    while i + 16 <= n {
        let a = vld1q_u8(dst.as_ptr().add(i));
        let b = vld1q_u8(src.as_ptr().add(i));
        vst1q_u8(dst.as_mut_ptr().add(i), veorq_u8(a, b));
        i += 16;
    }
    while i < n {
        *dst.get_unchecked_mut(i) ^= *src.get_unchecked(i);
        i += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::tensor::SimdPolicy;

    #[test]
    fn tables_are_a_bijection() {
        for a in 1..=255u8 {
            assert_eq!(GF_EXP[GF_LOG[a as usize] as usize], a);
        }
        for i in 0..255usize {
            assert_eq!(GF_LOG[GF_EXP[i] as usize] as usize, i);
            assert_eq!(GF_EXP[i + 255], GF_EXP[i], "doubled table at {i}");
        }
        assert_eq!(mul(2, 0x80), 0x1D, "0x11D reduction (alpha^8 = 0x1D)");
    }

    #[test]
    fn addition_is_xor_and_self_inverse() {
        let mut rng = Rng::seed_from(10);
        for _ in 0..1000 {
            let a = rng.next_below(256) as u8;
            let b = rng.next_below(256) as u8;
            assert_eq!(add(a, b), a ^ b);
            assert_eq!(add(add(a, b), b), a);
        }
    }

    #[test]
    fn multiplication_axioms_hold() {
        // Commutativity + identity + annihilator exhaustively…
        for a in 0..=255u8 {
            assert_eq!(mul(a, 1), a);
            assert_eq!(mul(1, a), a);
            assert_eq!(mul(a, 0), 0);
            for b in 0..=255u8 {
                assert_eq!(mul(a, b), mul(b, a), "commutativity at ({a}, {b})");
            }
        }
        // …associativity and distributivity over a random sweep.
        let mut rng = Rng::seed_from(11);
        for _ in 0..50_000 {
            let a = rng.next_below(256) as u8;
            let b = rng.next_below(256) as u8;
            let c = rng.next_below(256) as u8;
            assert_eq!(mul(mul(a, b), c), mul(a, mul(b, c)), "assoc ({a},{b},{c})");
            assert_eq!(
                mul(a, add(b, c)),
                add(mul(a, b), mul(a, c)),
                "distrib ({a},{b},{c})"
            );
        }
    }

    #[test]
    fn every_nonzero_element_has_an_inverse() {
        for a in 1..=255u8 {
            let ia = inv(a);
            assert_eq!(mul(a, ia), 1, "inv({a}) = {ia}");
            assert_eq!(div(a, a), 1);
        }
    }

    #[test]
    #[should_panic(expected = "inverse of zero")]
    fn zero_has_no_inverse() {
        inv(0);
    }

    #[test]
    fn row_kernels_match_the_scalar_oracle() {
        // 1031 is odd and > one SIMD lane, so body + tail are both hit.
        let mut rng = Rng::seed_from(12);
        let len = 1031;
        let src: Vec<u8> = (0..len).map(|_| rng.next_below(256) as u8).collect();
        let base: Vec<u8> = (0..len).map(|_| rng.next_below(256) as u8).collect();
        let detected = Isa::detect(SimdPolicy::Auto);
        for coeff in [0u8, 1, 2, 7, 0x53, 0xFE, 0xFF] {
            let mut scalar = base.clone();
            let mut simd = base.clone();
            mul_acc_row(Isa::Scalar, coeff, &src, &mut scalar);
            mul_acc_row(detected, coeff, &src, &mut simd);
            assert_eq!(scalar, simd, "mul_acc_row diverged at coeff {coeff}");
            // The scalar result is also the mathematical reference.
            for i in 0..len {
                assert_eq!(scalar[i], base[i] ^ mul(coeff, src[i]));
            }
        }
        let mut scalar = base.clone();
        let mut simd = base.clone();
        xor_row(Isa::Scalar, &src, &mut scalar);
        xor_row(detected, &src, &mut simd);
        assert_eq!(scalar, simd);
    }

    #[test]
    fn unsupported_isa_degrades_to_scalar_not_a_fault() {
        // A hand-constructed ISA the host may not support must still give
        // the scalar answer (the guards re-verify the CPU probe).
        let src = vec![0xA5u8; 97];
        for isa in [Isa::Avx2Fma, Isa::Neon] {
            let mut dst = vec![0x0Fu8; 97];
            xor_row(isa, &src, &mut dst);
            assert!(dst.iter().all(|&v| v == 0xAA));
        }
    }

    #[test]
    fn scale_row_matches_elementwise_mul() {
        let mut rng = Rng::seed_from(13);
        let row: Vec<u8> = (0..257).map(|_| rng.next_below(256) as u8).collect();
        for coeff in [1u8, 3, 0x1D, 0xFF] {
            let mut scaled = row.clone();
            scale_row(coeff, &mut scaled);
            for (s, &r) in scaled.iter().zip(&row) {
                assert_eq!(*s, mul(coeff, r));
            }
        }
    }
}
