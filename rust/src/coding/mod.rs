//! Distributed encoding bookkeeping (paper §III-B, §III-D).
//!
//! Clients privately draw generator matrices `G_j ∈ R^{u×ℓ_j}` (standard
//! normal or Rademacher ±1, both zero-mean unit-variance as the paper
//! requires), weight their data with `W_j = diag(w_j)` built from the
//! probabilities of no return, and ship parity data to the server. The
//! parity *computation* itself runs through the AOT encode artifact
//! (L1 `encode` kernel); this module owns generation of `G_j`, the weight
//! vectors, the composite aggregation, and the `GᵀG/u → I` diagnostic that
//! justifies the unbiasedness approximation (eq. 31).

pub mod code;
pub mod gf256;
pub mod secure_agg;

pub use code::{
    pack_byte_planes, unpack_byte_planes, Code, CodeKind, CodeSpec, DecodeScratch,
    DenseRandomCode, RatelessCode, RecoveryMode,
};

use anyhow::Result;

use crate::rng::Rng;
use crate::tensor::Mat;

/// Distribution of the generator-matrix entries (paper §III-B offers both).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GeneratorKind {
    /// i.i.d. standard normal — required by the privacy analysis (App. F).
    Normal,
    /// i.i.d. Rademacher ±1 (`Bernoulli(1/2)` over `{−1, +1}`).
    Rademacher,
}

impl GeneratorKind {
    /// The lowercase name [`FromStr`](std::str::FromStr) accepts.
    pub fn as_str(&self) -> &'static str {
        match self {
            GeneratorKind::Normal => "normal",
            GeneratorKind::Rademacher => "rademacher",
        }
    }
}

impl std::str::FromStr for GeneratorKind {
    type Err = String;
    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        match s.trim().to_ascii_lowercase().as_str() {
            "normal" => Ok(GeneratorKind::Normal),
            "rademacher" => Ok(GeneratorKind::Rademacher),
            other => Err(format!(
                "unknown generator kind {other:?} (expected one of normal | rademacher)"
            )),
        }
    }
}

/// Draw client `j`'s private generator matrix `G_j` of shape `[u, ell]`.
pub fn generator_matrix(kind: GeneratorKind, u: usize, ell: usize, rng: &mut Rng) -> Mat {
    let mut m = Mat::zeros(u, ell);
    match kind {
        GeneratorKind::Normal => rng.fill_normal_f32(m.as_mut_slice()),
        GeneratorKind::Rademacher => rng.fill_rademacher_f32(m.as_mut_slice()),
    }
    m
}

/// Weight-vector construction (paper §III-D).
///
/// For the `ℓ*` points the client will process each round the weight is
/// `√pnr₁` where `pnr₁ = 1 − P(T_j ≤ t*)`; the remaining `ℓ_j − ℓ*` points
/// are never evaluated (`pnr₂ = 1`, weight 1). `processed` marks the
/// sampled subset.
pub fn weight_vector(processed: &[bool], pnr1: f64) -> Vec<f32> {
    assert!(
        (0.0..=1.0).contains(&pnr1),
        "pnr must be a probability, got {pnr1}"
    );
    let w_proc = (pnr1 as f32).sqrt();
    processed
        .iter()
        .map(|&p| if p { w_proc } else { 1.0 })
        .collect()
}

/// Uniformly sample which `ell_star` of the client's `ell` points it will
/// process each round (paper §III-D: "samples ℓ*_j data points uniformly
/// and randomly"; the subset is fixed across rounds and hidden from the
/// server).
pub fn sample_processed(ell: usize, ell_star: usize, rng: &mut Rng) -> Vec<bool> {
    assert!(ell_star <= ell, "ell_star {ell_star} > ell {ell}");
    let perm = rng.permutation(ell);
    let mut mask = vec![false; ell];
    for &i in perm.iter().take(ell_star) {
        mask[i] = true;
    }
    mask
}

/// Sum local parity blocks into the composite global parity dataset
/// (paper eq. 20): `X̌ = Σ_j X̌^(j)`, `Y̌ = Σ_j Y̌^(j)`.
///
/// Every part must share part 0's shape; a mismatch is reported as an
/// error naming the offending part instead of panicking mid-`axpy`.
pub fn aggregate_parity(parts: &[Mat]) -> Result<Mat> {
    anyhow::ensure!(!parts.is_empty(), "no parity blocks to aggregate");
    let (rows, cols) = (parts[0].rows(), parts[0].cols());
    for (i, p) in parts.iter().enumerate().skip(1) {
        anyhow::ensure!(
            p.rows() == rows && p.cols() == cols,
            "parity part {i} has shape [{}, {}], expected [{rows}, {cols}] like part 0",
            p.rows(),
            p.cols()
        );
    }
    let mut acc = parts[0].clone();
    for p in &parts[1..] {
        acc.axpy(1.0, p);
    }
    Ok(acc)
}

/// Diagnostic for the WLLN approximation of eq. (31): largest absolute
/// deviation of `GᵀG / u` from the identity. Shrinks as `O(1/√u)`.
pub fn gtg_identity_deviation(g: &Mat) -> f32 {
    let u = g.rows() as f32;
    let ell = g.cols();
    let mut max_dev = 0.0f32;
    for i in 0..ell {
        for j in i..ell {
            let mut dot = 0.0f32;
            for r in 0..g.rows() {
                dot += g.get(r, i) * g.get(r, j);
            }
            let target = if i == j { 1.0 } else { 0.0 };
            max_dev = max_dev.max((dot / u - target).abs());
        }
    }
    max_dev
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn generator_kinds_have_unit_variance() {
        let mut rng = Rng::seed_from(1);
        for kind in [GeneratorKind::Normal, GeneratorKind::Rademacher] {
            let g = generator_matrix(kind, 200, 100, &mut rng);
            let n = (g.rows() * g.cols()) as f64;
            let mean: f64 = g.as_slice().iter().map(|&v| v as f64).sum::<f64>() / n;
            let var: f64 =
                g.as_slice().iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / n;
            assert!(mean.abs() < 0.03, "{kind:?} mean {mean}");
            assert!((var - 1.0).abs() < 0.05, "{kind:?} var {var}");
        }
    }

    #[test]
    fn rademacher_entries_are_pm_one() {
        let mut rng = Rng::seed_from(2);
        let g = generator_matrix(GeneratorKind::Rademacher, 10, 10, &mut rng);
        assert!(g.as_slice().iter().all(|&v| v == 1.0 || v == -1.0));
    }

    #[test]
    fn generator_kind_parses() {
        assert_eq!("normal".parse::<GeneratorKind>().unwrap(), GeneratorKind::Normal);
        assert_eq!(
            "rademacher".parse::<GeneratorKind>().unwrap(),
            GeneratorKind::Rademacher
        );
        // Case variants parse, like every other spec in the crate…
        assert_eq!("Normal".parse::<GeneratorKind>().unwrap(), GeneratorKind::Normal);
        assert_eq!(
            " RADEMACHER ".parse::<GeneratorKind>().unwrap(),
            GeneratorKind::Rademacher
        );
        // …and the rejection lists the valid options.
        let e = "gauss".parse::<GeneratorKind>().unwrap_err();
        assert!(e.contains("expected one of"), "{e}");
        assert!(e.contains("normal") && e.contains("rademacher"), "{e}");
    }

    #[test]
    fn weight_vector_follows_section_iii_d() {
        let processed = vec![true, false, true];
        let w = weight_vector(&processed, 0.25);
        assert_eq!(w, vec![0.5, 1.0, 0.5]);
    }

    #[test]
    #[should_panic(expected = "pnr must be a probability")]
    fn weight_vector_validates_pnr() {
        weight_vector(&[true], 1.5);
    }

    #[test]
    fn sample_processed_counts() {
        let mut rng = Rng::seed_from(3);
        let mask = sample_processed(50, 20, &mut rng);
        assert_eq!(mask.len(), 50);
        assert_eq!(mask.iter().filter(|&&b| b).count(), 20);
    }

    #[test]
    fn aggregate_parity_sums() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![10.0, 20.0, 30.0, 40.0]);
        let s = aggregate_parity(&[a, b]).unwrap();
        assert_eq!(s.as_slice(), &[11.0, 22.0, 33.0, 44.0]);
    }

    #[test]
    fn aggregate_parity_names_the_mismatched_part() {
        let a = Mat::zeros(2, 2);
        let b = Mat::zeros(2, 2);
        let c = Mat::zeros(3, 2);
        let e = aggregate_parity(&[a, b, c]).unwrap_err().to_string();
        assert!(e.contains("part 2"), "error must name the offending part: {e}");
        assert!(e.contains("[3, 2]") && e.contains("[2, 2]"), "{e}");
        assert!(aggregate_parity(&[]).is_err());
    }

    #[test]
    fn gtg_deviation_shrinks_with_u() {
        let mut rng = Rng::seed_from(4);
        let small = generator_matrix(GeneratorKind::Normal, 50, 8, &mut rng);
        let large = generator_matrix(GeneratorKind::Normal, 5000, 8, &mut rng);
        let d_small = gtg_identity_deviation(&small);
        let d_large = gtg_identity_deviation(&large);
        assert!(
            d_large < d_small,
            "dev(u=5000) {d_large} !< dev(u=50) {d_small}"
        );
        assert!(d_large < 0.1, "{d_large}");
    }
}
