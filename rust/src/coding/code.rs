//! The pluggable erasure-code layer: [`Code`] trait, [`CodeSpec`] /
//! [`RecoveryMode`] descriptions, and the two built-in codes.
//!
//! The paper's parity dataset compensates stragglers only *in expectation*
//! (eq. 31): the server can never reconstruct the exact full-fleet
//! gradient from a partial arrival set. This module adds the machinery
//! that can. A [`Code`] is **systematic** over client shards: source
//! symbol `j` is client `j`'s quantized gradient block (its f32 entries
//! split into byte planes by [`pack_byte_planes`]), and each repair symbol
//! is a GF(256) linear combination of the sources with a fixed, seeded
//! coefficient row. When the round's arrival subset is decodable,
//! [`Code::decode_into`] reconstructs every missing source **bit-exactly**
//! — GF(256) arithmetic has no rounding — which is what powers
//! `recovery = exact` in [`crate::schemes::CodedFedL`].
//!
//! Two implementations ship:
//!
//! * [`DenseRandomCode`] — the paper's dense random generator, refactored
//!   behind the trait. Its real-valued expectation-mode path (generator
//!   matrices for parity *datasets*) is reached through
//!   [`DenseRandomCode::generator_matrix`]; its exact-mode byte-level
//!   coefficients are dense uniform nonzero GF(256) entries (an MDS-like
//!   random code: any `k ≤ repairs` erasures decode with probability
//!   `≈ 1 − k/256`).
//! * [`RatelessCode`] — an LT/Raptor-style systematic fountain code with
//!   a seeded ideal-soliton degree distribution and binary (coefficient-1)
//!   rows, so encode and most of decode are pure XOR (SNIPPETS' RFC 6330
//!   binary-row observation). Decoding is *inactivation* style: a belief-
//!   propagation peeling pass resolves degree-1 equations for free, and
//!   only the stubborn residual falls back to GF(256) Gauss–Jordan.
//!
//! All decode state lives in a caller-owned [`DecodeScratch`], so warm
//! rounds run the full pack → encode → decode cycle with zero heap
//! allocations (see `tests/alloc_gate.rs`).

use std::fmt;

use super::{gf256, GeneratorKind};
use crate::rng::Rng;
use crate::tensor::{Isa, Mat};

/// Which built-in code family a [`Code`] instance belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CodeKind {
    /// Dense random linear code (the paper's generator, §III-B).
    Dense,
    /// Systematic LT/Raptor-style fountain code.
    Rateless,
}

impl CodeKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            CodeKind::Dense => "dense",
            CodeKind::Rateless => "rateless",
        }
    }
}

/// Closed, serialisable description of the built-in codes — the form the
/// CLI, TOML files and benches speak (`"rateless:overhead=0.5"` ↔
/// `CodeSpec::Rateless { overhead: 0.5 }`), mirroring
/// [`crate::schemes::SchemeSpec`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CodeSpec {
    /// Dense random linear code (the paper's baseline).
    Dense,
    /// Rateless fountain code; `overhead` is the repair budget as a
    /// fraction of the source count (`repairs = ⌈overhead · n⌉`).
    Rateless { overhead: f64 },
}

impl Default for CodeSpec {
    fn default() -> Self {
        CodeSpec::Dense
    }
}

impl CodeSpec {
    pub const DEFAULT_OVERHEAD: f64 = 0.5;

    pub fn kind(&self) -> CodeKind {
        match self {
            CodeSpec::Dense => CodeKind::Dense,
            CodeSpec::Rateless { .. } => CodeKind::Rateless,
        }
    }

    pub fn label(&self) -> String {
        match self {
            CodeSpec::Dense => "dense".into(),
            CodeSpec::Rateless { overhead } => format!("rateless(overhead={overhead})"),
        }
    }

    /// Parse a code string: `dense`, `rateless`, `rateless:overhead=0.5`.
    /// Case-insensitive, like every other spec parser in the crate.
    pub fn parse(s: &str) -> Result<CodeSpec, String> {
        let lower = s.trim().to_ascii_lowercase();
        let (name, params) = match lower.split_once(':') {
            Some((n, p)) => (n.trim(), Some(p)),
            None => (lower.as_str(), None),
        };
        match name {
            "dense" => match params {
                None => Ok(CodeSpec::Dense),
                Some(p) => Err(format!("code \"dense\" takes no parameters, got {p:?}")),
            },
            "rateless" => {
                let overhead = match params {
                    None => Self::DEFAULT_OVERHEAD,
                    Some(p) => {
                        let (k, v) = p.split_once('=').ok_or_else(|| {
                            format!("code \"rateless\": expected overhead=<value>, got {p:?}")
                        })?;
                        if k.trim() != "overhead" {
                            return Err(format!(
                                "code \"rateless\": unknown parameter {:?} (expected overhead)",
                                k.trim()
                            ));
                        }
                        v.trim()
                            .parse::<f64>()
                            .map_err(|e| format!("code \"rateless\": overhead: {e}"))?
                    }
                };
                Ok(CodeSpec::Rateless { overhead })
            }
            other => Err(format!(
                "unknown code {other:?} (expected one of dense | rateless[:overhead=ρ])"
            )),
        }
    }

    /// Reject parameter values no code can be built from.
    pub fn validate(&self) -> Result<(), String> {
        match *self {
            CodeSpec::Dense => Ok(()),
            CodeSpec::Rateless { overhead } => {
                if !overhead.is_finite() || overhead <= 0.0 || overhead > 4.0 {
                    Err(format!("rateless overhead must be in (0, 4], got {overhead}"))
                } else {
                    Ok(())
                }
            }
        }
    }

    /// Instantiate the described code over `sources` client shards, with
    /// coefficient rows drawn deterministically from `seed`.
    pub fn build(&self, generator: GeneratorKind, sources: usize, seed: u64) -> Box<dyn Code> {
        match *self {
            CodeSpec::Dense => {
                // Half the fleet in repairs: the dense random code decodes
                // any ≤ repairs erasures with high probability, matching
                // the straggler regime the paper targets.
                let repairs = (sources + 1) / 2;
                Box::new(DenseRandomCode::new(generator, sources, repairs, seed))
            }
            CodeSpec::Rateless { overhead } => Box::new(RatelessCode::new(sources, overhead, seed)),
        }
    }
}

impl std::str::FromStr for CodeSpec {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        CodeSpec::parse(s)
    }
}

impl fmt::Display for CodeSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.label())
    }
}

/// How `schemes::coded` turns arrivals into an aggregate.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RecoveryMode {
    /// The paper's mode: a real-valued parity-dataset gradient compensates
    /// missing stragglers in expectation (eq. 28/31).
    #[default]
    Expectation,
    /// Watch the arrival stream, stop as soon as the received subset is
    /// decodable, and reconstruct the full-fleet gradient bit-exactly.
    Exact,
}

impl RecoveryMode {
    pub fn as_str(&self) -> &'static str {
        match self {
            RecoveryMode::Expectation => "expectation",
            RecoveryMode::Exact => "exact",
        }
    }
}

impl std::str::FromStr for RecoveryMode {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.trim().to_ascii_lowercase().as_str() {
            "expectation" => Ok(RecoveryMode::Expectation),
            "exact" => Ok(RecoveryMode::Exact),
            other => Err(format!(
                "unknown recovery mode {other:?} (expected one of expectation | exact)"
            )),
        }
    }
}

impl fmt::Display for RecoveryMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Caller-owned decoder workspace. [`DecodeScratch::reserve`] sizes every
/// buffer for the worst case once (all sources missing, every repair in
/// play), after which [`Code::decodable`] / [`Code::decode_into`] never
/// allocate — the warm-round 0-alloc gate depends on this.
#[derive(Default)]
pub struct DecodeScratch {
    /// Coefficient matrix over the missing columns, `eqs × k` row-major.
    a: Vec<u8>,
    /// Aliasing-free copy of the current pivot's coefficient row.
    pivot_a: Vec<u8>,
    /// Symbol-valued right-hand sides, `eqs × symbol_len` row-major.
    rhs: Vec<u8>,
    /// Missing source indices (the unknown columns, ascending).
    miss: Vec<usize>,
    /// Per-equation count of live nonzero coefficients (peeling driver).
    nz: Vec<usize>,
    /// Equations already spent as a peel step or a pivot.
    consumed: Vec<bool>,
    /// Column → pivot equation (`usize::MAX` while unsolved).
    pivot_of: Vec<usize>,
    /// Columns resolved by the peeling pass.
    solved: Vec<bool>,
}

impl DecodeScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Grow every buffer to hold a `max_eqs`-equation, `max_sources`-column
    /// system over `symbol_len`-byte symbols. Idempotent; call once with
    /// the worst case before entering an allocation-gated loop.
    pub fn reserve(&mut self, max_eqs: usize, max_sources: usize, symbol_len: usize) {
        reserve_to(&mut self.a, max_eqs * max_sources);
        reserve_to(&mut self.pivot_a, max_sources);
        reserve_to(&mut self.rhs, max_eqs * symbol_len);
        reserve_to(&mut self.miss, max_sources);
        reserve_to(&mut self.nz, max_eqs);
        reserve_to(&mut self.consumed, max_eqs);
        reserve_to(&mut self.pivot_of, max_sources);
        reserve_to(&mut self.solved, max_sources);
    }
}

fn reserve_to<T>(v: &mut Vec<T>, cap: usize) {
    if v.capacity() < cap {
        v.reserve(cap - v.len());
    }
}

/// An erasure code over client shards.
///
/// A code is systematic: the `sources()` source symbols are the client
/// blocks themselves, and `repairs()` extra symbols are GF(256) linear
/// combinations `repair_r = Σ_j coeff(r, j) · source_j` (byte-wise, over
/// the packed planes). Implementations fix the coefficient structure at
/// construction (seeded, deterministic); encode, decodability and decode
/// are provided generically on top of [`Code::coeff`], with sparse codes
/// free to override [`Code::encode_repair`] for XOR-only throughput.
pub trait Code {
    /// Which family this code belongs to (drives reporting and privacy
    /// applicability).
    fn kind(&self) -> CodeKind;

    /// Human-readable label (`"dense"`, `"rateless(overhead=0.5)"`).
    fn label(&self) -> String;

    /// Number of source symbols (= clients).
    fn sources(&self) -> usize;

    /// Number of repair symbols this instance carries.
    fn repairs(&self) -> usize;

    /// GF(256) coefficient of `source` in repair row `repair`.
    fn coeff(&self, repair: usize, source: usize) -> u8;

    /// Encode repair row `repair` over the packed source pool
    /// (`sources() · symbol_len` bytes, source `j` at `j · symbol_len`)
    /// into `out` (`symbol_len` bytes, overwritten).
    fn encode_repair(&self, isa: Isa, repair: usize, sources: &[u8], symbol_len: usize, out: &mut [u8]) {
        assert_eq!(out.len(), symbol_len, "encode_repair: bad output length");
        assert_eq!(
            sources.len(),
            self.sources() * symbol_len,
            "encode_repair: bad source pool length"
        );
        out.fill(0);
        for j in 0..self.sources() {
            let row = &sources[j * symbol_len..(j + 1) * symbol_len];
            gf256::mul_acc_row(isa, self.coeff(repair, j), row, out);
        }
    }

    /// Whether the arrival subset `have` plus the first `repairs_avail`
    /// repair symbols determine every missing source (full column rank of
    /// the erasure system). Allocation-free once `scratch` is reserved.
    fn decodable(&self, have: &[bool], repairs_avail: usize, scratch: &mut DecodeScratch) -> bool {
        let n = self.sources();
        assert_eq!(have.len(), n, "decodable: bad arrival mask length");
        let eqs = repairs_avail.min(self.repairs());
        scratch.miss.clear();
        scratch.miss.extend((0..n).filter(|&j| !have[j]));
        let k = scratch.miss.len();
        if k == 0 {
            return true;
        }
        if eqs < k {
            return false;
        }
        // Plain Gaussian elimination on the eqs × k erasure matrix: the
        // subset is decodable iff every column gets a pivot. Row ops over
        // GF(256) preserve column rank, so this agrees exactly with the
        // peel + Gauss–Jordan path `decode_into` runs.
        scratch.a.clear();
        scratch.a.resize(eqs * k, 0);
        for e in 0..eqs {
            for t in 0..k {
                scratch.a[e * k + t] = self.coeff(e, scratch.miss[t]);
            }
        }
        let mut rank = 0usize;
        for col in 0..k {
            let Some(r) = (rank..eqs).find(|&r| scratch.a[r * k + col] != 0) else {
                return false;
            };
            if r != rank {
                for t in 0..k {
                    scratch.a.swap(rank * k + t, r * k + t);
                }
            }
            let p = scratch.a[rank * k + col];
            for r2 in rank + 1..eqs {
                let v = scratch.a[r2 * k + col];
                if v == 0 {
                    continue;
                }
                let f = gf256::div(v, p);
                for t in col..k {
                    let pv = scratch.a[rank * k + t];
                    scratch.a[r2 * k + t] ^= gf256::mul(f, pv);
                }
            }
            rank += 1;
        }
        true
    }

    /// Reconstruct every missing source bit-exactly from the arrivals and
    /// the first `repairs_avail` repair symbols.
    ///
    /// `sources` is the packed pool; rows with `have[j] = true` hold the
    /// arrived bytes on entry, and rows with `have[j] = false` are
    /// overwritten with the decoded bytes. `repairs` holds repair row `r`
    /// at `r · symbol_len`. Errors when the subset is not decodable.
    /// Inactivation decoding: a peeling pass resolves degree-1 equations
    /// (the common case for [`RatelessCode`]), then GF(256) Gauss–Jordan
    /// finishes the residual. Deterministic — pivot choice is by index —
    /// and allocation-free once `scratch` is reserved.
    fn decode_into(
        &self,
        isa: Isa,
        have: &[bool],
        repairs_avail: usize,
        symbol_len: usize,
        sources: &mut [u8],
        repairs: &[u8],
        scratch: &mut DecodeScratch,
    ) -> Result<(), String> {
        let n = self.sources();
        assert_eq!(have.len(), n, "decode_into: bad arrival mask length");
        assert_eq!(sources.len(), n * symbol_len, "decode_into: bad source pool length");
        let eqs = repairs_avail.min(self.repairs());
        assert!(
            repairs.len() >= eqs * symbol_len,
            "decode_into: repair pool holds {} bytes, need {}",
            repairs.len(),
            eqs * symbol_len
        );
        scratch.miss.clear();
        scratch.miss.extend((0..n).filter(|&j| !have[j]));
        let k = scratch.miss.len();
        if k == 0 {
            return Ok(());
        }
        if eqs < k {
            return Err(format!(
                "undecodable: {k} sources missing, only {eqs} repair symbols available"
            ));
        }
        let len = symbol_len;

        // System setup: A over the missing columns, rhs = repair symbol
        // minus (= plus, in GF(2^8)) the arrived sources' contributions.
        scratch.a.clear();
        scratch.a.resize(eqs * k, 0);
        scratch.nz.clear();
        scratch.nz.resize(eqs, 0);
        scratch.consumed.clear();
        scratch.consumed.resize(eqs, false);
        scratch.solved.clear();
        scratch.solved.resize(k, false);
        scratch.pivot_of.clear();
        scratch.pivot_of.resize(k, usize::MAX);
        scratch.rhs.clear();
        scratch.rhs.resize(eqs * len, 0);
        for e in 0..eqs {
            let mut cnt = 0usize;
            for t in 0..k {
                let co = self.coeff(e, scratch.miss[t]);
                scratch.a[e * k + t] = co;
                if co != 0 {
                    cnt += 1;
                }
            }
            scratch.nz[e] = cnt;
            let rhs_row = &mut scratch.rhs[e * len..(e + 1) * len];
            rhs_row.copy_from_slice(&repairs[e * len..(e + 1) * len]);
            for j in 0..n {
                if have[j] {
                    let row = &sources[j * len..(j + 1) * len];
                    gf256::mul_acc_row(isa, self.coeff(e, j), row, rhs_row);
                }
            }
        }

        // Peeling pass: any equation left with a single unknown yields
        // that source directly; substituting it may expose new degree-1
        // equations. For a fountain code in its working regime this pass
        // resolves nearly everything with XOR-only row ops.
        loop {
            let Some(e) = (0..eqs).find(|&e| !scratch.consumed[e] && scratch.nz[e] == 1) else {
                break;
            };
            let c = (0..k)
                .find(|&t| scratch.a[e * k + t] != 0)
                .expect("nz = 1 equation with no live coefficient");
            let co = scratch.a[e * k + c];
            let m = scratch.miss[c];
            {
                let dst = &mut sources[m * len..(m + 1) * len];
                dst.copy_from_slice(&scratch.rhs[e * len..(e + 1) * len]);
                gf256::scale_row(gf256::inv(co), dst);
            }
            scratch.consumed[e] = true;
            scratch.solved[c] = true;
            scratch.a[e * k + c] = 0;
            scratch.nz[e] = 0;
            for e2 in 0..eqs {
                let f = scratch.a[e2 * k + c];
                if f == 0 {
                    continue;
                }
                let src = &sources[m * len..(m + 1) * len];
                gf256::mul_acc_row(isa, f, src, &mut scratch.rhs[e2 * len..(e2 + 1) * len]);
                scratch.a[e2 * k + c] = 0;
                scratch.nz[e2] -= 1;
            }
        }

        // Inactivation residual: Gauss–Jordan over whatever peeling left.
        // Pivot selection is first-by-index, so the elimination sequence —
        // and therefore every intermediate byte — is deterministic.
        for c in 0..k {
            if scratch.solved[c] {
                continue;
            }
            let Some(e) = (0..eqs).find(|&e| !scratch.consumed[e] && scratch.a[e * k + c] != 0)
            else {
                return Err(format!(
                    "undecodable: erasure system is rank-deficient at missing source {}",
                    scratch.miss[c]
                ));
            };
            scratch.consumed[e] = true;
            scratch.pivot_of[c] = e;
            let p = scratch.a[e * k + c];
            if p != 1 {
                let ip = gf256::inv(p);
                for t in 0..k {
                    let v = scratch.a[e * k + t];
                    scratch.a[e * k + t] = gf256::mul(ip, v);
                }
                gf256::scale_row(ip, &mut scratch.rhs[e * len..(e + 1) * len]);
            }
            scratch.pivot_a.clear();
            scratch.pivot_a.extend_from_slice(&scratch.a[e * k..(e + 1) * k]);
            for e2 in 0..eqs {
                if e2 == e {
                    continue;
                }
                let f = scratch.a[e2 * k + c];
                if f == 0 {
                    continue;
                }
                for t in 0..k {
                    let pv = scratch.pivot_a[t];
                    scratch.a[e2 * k + t] ^= gf256::mul(f, pv);
                }
                let (dst, src) = row_pair_mut(&mut scratch.rhs, len, e2, e);
                gf256::mul_acc_row(isa, f, src, dst);
            }
        }

        // Jordan elimination leaves each pivot row as a unit vector, so
        // its rhs *is* the missing source.
        for c in 0..k {
            if scratch.solved[c] {
                continue;
            }
            let e = scratch.pivot_of[c];
            let m = scratch.miss[c];
            sources[m * len..(m + 1) * len].copy_from_slice(&scratch.rhs[e * len..(e + 1) * len]);
        }
        Ok(())
    }
}

/// Disjoint mutable views of rows `i` and `j` (`i ≠ j`) of a row-major
/// byte pool, for same-buffer row updates during elimination.
fn row_pair_mut(buf: &mut [u8], len: usize, i: usize, j: usize) -> (&mut [u8], &mut [u8]) {
    assert_ne!(i, j, "row_pair_mut: aliasing rows");
    if i < j {
        let (lo, hi) = buf.split_at_mut(j * len);
        (&mut lo[i * len..(i + 1) * len], &mut hi[..len])
    } else {
        let (lo, hi) = buf.split_at_mut(i * len);
        (&mut hi[..len], &mut lo[j * len..(j + 1) * len])
    }
}

/// The paper's dense random generator behind the [`Code`] trait.
///
/// Expectation mode keeps the real-valued machinery: per-client generator
/// matrices come from [`DenseRandomCode::generator_matrix`] (exactly the
/// historical `coding::generator_matrix` draw — bit-for-bit, preserving
/// pre-PR histories). Exact mode uses the byte-level side: `repairs`
/// coefficient rows of i.i.d. uniform *nonzero* GF(256) entries, drawn
/// once from `seed`.
pub struct DenseRandomCode {
    generator: GeneratorKind,
    sources: usize,
    repairs: usize,
    /// `repairs × sources` row-major, all entries nonzero.
    coeffs: Vec<u8>,
}

impl DenseRandomCode {
    pub fn new(generator: GeneratorKind, sources: usize, repairs: usize, seed: u64) -> Self {
        let mut rng = Rng::seed_from(seed);
        let coeffs = (0..repairs * sources)
            .map(|_| (rng.next_below(255) + 1) as u8)
            .collect();
        DenseRandomCode { generator, sources, repairs, coeffs }
    }

    /// Expectation-mode instance: no byte-level repair rows (and no RNG
    /// consumed — the real-valued draw order of pre-PR runs is sacred).
    pub fn expectation(generator: GeneratorKind, sources: usize) -> Self {
        DenseRandomCode { generator, sources, repairs: 0, coeffs: Vec::new() }
    }

    pub fn generator(&self) -> GeneratorKind {
        self.generator
    }

    /// Draw a real-valued generator matrix `G_j ∈ R^{u×ℓ}` for the parity
    /// *dataset* path (paper §III-B) — the historical
    /// [`super::generator_matrix`] draw, unchanged.
    pub fn generator_matrix(&self, u: usize, ell: usize, rng: &mut Rng) -> Mat {
        super::generator_matrix(self.generator, u, ell, rng)
    }
}

impl Code for DenseRandomCode {
    fn kind(&self) -> CodeKind {
        CodeKind::Dense
    }

    fn label(&self) -> String {
        "dense".into()
    }

    fn sources(&self) -> usize {
        self.sources
    }

    fn repairs(&self) -> usize {
        self.repairs
    }

    fn coeff(&self, repair: usize, source: usize) -> u8 {
        self.coeffs[repair * self.sources + source]
    }
}

/// Systematic LT/Raptor-style fountain code over GF(256) byte planes.
///
/// Repair rows carry **binary** coefficients, so every encode/peel row op
/// is a pure XOR lane. Row 0 is the full-degree sum of all sources (any
/// single erasure peels immediately); rows 1.. draw their degree from the
/// ideal soliton distribution and their neighbours from a seeded
/// permutation — fully deterministic given `(sources, overhead, seed)`.
pub struct RatelessCode {
    sources: usize,
    overhead: f64,
    /// Sparse rows: `(source index, coefficient)`, ascending by index.
    rows: Vec<Vec<(usize, u8)>>,
}

impl RatelessCode {
    pub fn new(sources: usize, overhead: f64, seed: u64) -> Self {
        assert!(sources > 0, "rateless code needs at least one source");
        assert!(
            overhead.is_finite() && overhead > 0.0,
            "rateless overhead must be positive, got {overhead}"
        );
        let repairs = ((sources as f64 * overhead).ceil() as usize).max(1);
        let mut rng = Rng::seed_from(seed);
        let mut rows = Vec::with_capacity(repairs);
        rows.push((0..sources).map(|j| (j, 1u8)).collect());
        let n = sources as f64;
        for _ in 1..repairs {
            // Ideal soliton: P(1) = 1/n, P(d) = 1/(d(d−1)) for 2 ≤ d ≤ n.
            let u = rng.next_f64();
            let d = if u < 1.0 / n {
                1
            } else {
                ((1.0 / (1.0 - (u - 1.0 / n))).ceil() as usize).clamp(2, sources)
            };
            let perm = rng.permutation(sources);
            let mut row: Vec<(usize, u8)> = perm[..d].iter().map(|&j| (j, 1u8)).collect();
            row.sort_unstable_by_key(|&(j, _)| j);
            rows.push(row);
        }
        RatelessCode { sources, overhead, rows }
    }

    pub fn overhead(&self) -> f64 {
        self.overhead
    }
}

impl Code for RatelessCode {
    fn kind(&self) -> CodeKind {
        CodeKind::Rateless
    }

    fn label(&self) -> String {
        format!("rateless(overhead={})", self.overhead)
    }

    fn sources(&self) -> usize {
        self.sources
    }

    fn repairs(&self) -> usize {
        self.rows.len()
    }

    fn coeff(&self, repair: usize, source: usize) -> u8 {
        match self.rows[repair].binary_search_by_key(&source, |&(j, _)| j) {
            Ok(i) => self.rows[repair][i].1,
            Err(_) => 0,
        }
    }

    /// Sparse override: touch only the row's neighbours (XOR-only, since
    /// every live coefficient is 1).
    fn encode_repair(&self, isa: Isa, repair: usize, sources: &[u8], symbol_len: usize, out: &mut [u8]) {
        assert_eq!(out.len(), symbol_len, "encode_repair: bad output length");
        assert_eq!(
            sources.len(),
            self.sources * symbol_len,
            "encode_repair: bad source pool length"
        );
        out.fill(0);
        for &(j, co) in &self.rows[repair] {
            let row = &sources[j * symbol_len..(j + 1) * symbol_len];
            gf256::mul_acc_row(isa, co, row, out);
        }
    }
}

/// Split `values` into byte planes inside `out` (`4 · values.len()` bytes):
/// plane `p` of value `i` lands at `p · values.len() + i`. Lossless — the
/// little-endian f32 bit patterns are preserved exactly, so pack → decode
/// → unpack is a bitwise identity. Plane-major layout keeps each plane
/// contiguous for the XOR lanes.
pub fn pack_byte_planes(values: &[f32], out: &mut [u8]) {
    let n = values.len();
    assert_eq!(out.len(), 4 * n, "pack_byte_planes: need 4 bytes per value");
    for (i, v) in values.iter().enumerate() {
        let b = v.to_le_bytes();
        out[i] = b[0];
        out[n + i] = b[1];
        out[2 * n + i] = b[2];
        out[3 * n + i] = b[3];
    }
}

/// Inverse of [`pack_byte_planes`].
pub fn unpack_byte_planes(planes: &[u8], out: &mut [f32]) {
    let n = out.len();
    assert_eq!(planes.len(), 4 * n, "unpack_byte_planes: need 4 bytes per value");
    for (i, v) in out.iter_mut().enumerate() {
        *v = f32::from_le_bytes([planes[i], planes[n + i], planes[2 * n + i], planes[3 * n + i]]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn random_pool(n: usize, len: usize, seed: u64) -> Vec<u8> {
        let mut rng = Rng::seed_from(seed);
        (0..n * len).map(|_| rng.next_below(256) as u8).collect()
    }

    fn encode_all(code: &dyn Code, pool: &[u8], len: usize) -> Vec<u8> {
        let mut repairs = vec![0u8; code.repairs() * len];
        for r in 0..code.repairs() {
            let out = &mut repairs[r * len..(r + 1) * len];
            code.encode_repair(Isa::Scalar, r, pool, len, out);
        }
        repairs
    }

    fn roundtrip(code: &dyn Code, drop: &[usize], len: usize) {
        let n = code.sources();
        let truth = random_pool(n, len, 77);
        let repairs = encode_all(code, &truth, len);
        let mut have = vec![true; n];
        let mut pool = truth.clone();
        for &j in drop {
            have[j] = false;
            pool[j * len..(j + 1) * len].fill(0);
        }
        let mut scratch = DecodeScratch::new();
        assert!(code.decodable(&have, code.repairs(), &mut scratch));
        code.decode_into(Isa::Scalar, &have, code.repairs(), len, &mut pool, &repairs, &mut scratch)
            .unwrap();
        assert_eq!(pool, truth, "decode is not bit-exact (dropped {drop:?})");
    }

    #[test]
    fn dense_code_round_trips_every_drop_pattern_it_claims() {
        // 6 sources, 3 repairs. Single erasures are *guaranteed* decodable
        // (every coefficient is nonzero); larger subsets decode whenever
        // the rank check accepts them — sweep all pairs and triples and
        // round-trip exactly those, requiring the accept rate a random
        // GF(256) code delivers. 53 is odd (tail-exercising).
        let code = DenseRandomCode::new(GeneratorKind::Normal, 6, 3, 42);
        roundtrip(&code, &[], 53);
        for a in 0..6 {
            roundtrip(&code, &[a], 53);
        }
        let mut scratch = DecodeScratch::new();
        let (mut tried, mut ok) = (0usize, 0usize);
        for a in 0..6 {
            for b in a + 1..6 {
                for extra in [None, Some((b + 1) % 6)] {
                    let mut drop = vec![a, b];
                    if let Some(c) = extra {
                        if drop.contains(&c) {
                            continue;
                        }
                        drop.push(c);
                        drop.sort_unstable();
                    }
                    tried += 1;
                    let mut have = vec![true; 6];
                    for &j in &drop {
                        have[j] = false;
                    }
                    if code.decodable(&have, 3, &mut scratch) {
                        ok += 1;
                        roundtrip(&code, &drop, 53);
                    }
                }
            }
        }
        // Random nonzero coefficients make singular submatrices rare
        // (≈ 1/255 per subset); demand a decisive majority decodes.
        assert!(ok * 10 >= tried * 8, "only {ok}/{tried} subsets decodable");
    }

    #[test]
    fn rateless_code_round_trips_decodable_subsets() {
        let code = RatelessCode::new(10, 0.5, 7);
        assert_eq!(code.sources(), 10);
        assert_eq!(code.repairs(), 5);
        let mut scratch = DecodeScratch::new();
        // Any single erasure peels off row 0 (the full-degree row).
        for j in 0..10 {
            let mut have = vec![true; 10];
            have[j] = false;
            assert!(code.decodable(&have, 5, &mut scratch), "single erasure {j}");
            roundtrip(&code, &[j], 31);
        }
        // Sweep all pairs; decode exactly the decodable ones.
        let mut decodable_pairs = 0;
        for a in 0..10 {
            for b in a + 1..10 {
                let mut have = vec![true; 10];
                have[a] = false;
                have[b] = false;
                if code.decodable(&have, 5, &mut scratch) {
                    decodable_pairs += 1;
                    roundtrip(&code, &[a, b], 31);
                }
            }
        }
        assert!(decodable_pairs > 0, "soliton rows decode no pair at all");
    }

    #[test]
    fn undecodable_subsets_are_rejected_not_mis_decoded() {
        let code = DenseRandomCode::new(GeneratorKind::Normal, 4, 2, 1);
        let mut scratch = DecodeScratch::new();
        let have = vec![false, false, false, true]; // 3 missing > 2 repairs
        assert!(!code.decodable(&have, 2, &mut scratch));
        let len = 8;
        let mut pool = vec![0u8; 4 * len];
        let repairs = vec![0u8; 2 * len];
        let err = code
            .decode_into(Isa::Scalar, &have, 2, len, &mut pool, &repairs, &mut scratch)
            .unwrap_err();
        assert!(err.contains("undecodable"), "{err}");
        // Zero repairs available: nothing missing is fine, anything else not.
        assert!(code.decodable(&[true; 4], 0, &mut scratch));
        assert!(!code.decodable(&[true, true, true, false], 0, &mut scratch));
    }

    #[test]
    fn codes_are_deterministic_in_their_seed() {
        let a = DenseRandomCode::new(GeneratorKind::Normal, 8, 4, 9);
        let b = DenseRandomCode::new(GeneratorKind::Normal, 8, 4, 9);
        let c = DenseRandomCode::new(GeneratorKind::Normal, 8, 4, 10);
        assert_eq!(a.coeffs, b.coeffs);
        assert_ne!(a.coeffs, c.coeffs);
        assert!(a.coeffs.iter().all(|&v| v != 0), "dense rows must be all-nonzero");

        let ra = RatelessCode::new(12, 0.5, 3);
        let rb = RatelessCode::new(12, 0.5, 3);
        assert_eq!(ra.rows, rb.rows);
        assert_eq!(ra.rows[0].len(), 12, "row 0 is the full-degree spike");
    }

    #[test]
    fn pack_unpack_is_a_bitwise_identity() {
        let values = [0.0f32, -0.0, 1.5, -3.25e-12, f32::MIN_POSITIVE, 1.0e30, -7.0];
        let mut planes = vec![0u8; 4 * values.len()];
        pack_byte_planes(&values, &mut planes);
        let mut back = vec![0.0f32; values.len()];
        unpack_byte_planes(&planes, &mut back);
        for (a, b) in values.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // Plane-major: first plane holds every value's low byte.
        assert_eq!(planes[2], 1.5f32.to_le_bytes()[0]);
    }

    #[test]
    fn code_spec_parses_case_insensitively_with_helpful_errors() {
        assert_eq!(CodeSpec::parse("dense").unwrap(), CodeSpec::Dense);
        assert_eq!(CodeSpec::parse("Dense").unwrap(), CodeSpec::Dense);
        assert_eq!(
            CodeSpec::parse("rateless").unwrap(),
            CodeSpec::Rateless { overhead: CodeSpec::DEFAULT_OVERHEAD }
        );
        assert_eq!(
            CodeSpec::parse("RATELESS:overhead=0.75").unwrap(),
            CodeSpec::Rateless { overhead: 0.75 }
        );
        let e = CodeSpec::parse("fountain").unwrap_err();
        assert!(e.contains("expected one of"), "{e}");
        assert!(e.contains("dense") && e.contains("rateless"), "{e}");
        assert!(CodeSpec::parse("dense:overhead=1").is_err());
        assert!(CodeSpec::parse("rateless:rho=1").is_err());
        assert!(CodeSpec::parse("rateless:overhead=lots").is_err());
        assert!(CodeSpec::Rateless { overhead: 0.0 }.validate().is_err());
        assert!(CodeSpec::Rateless { overhead: f64::NAN }.validate().is_err());
        assert!(CodeSpec::Rateless { overhead: 0.5 }.validate().is_ok());
        assert_eq!(CodeSpec::default(), CodeSpec::Dense);
        assert_eq!(CodeSpec::Rateless { overhead: 0.5 }.to_string(), "rateless(overhead=0.5)");
    }

    #[test]
    fn recovery_mode_parses_case_insensitively() {
        assert_eq!("expectation".parse::<RecoveryMode>().unwrap(), RecoveryMode::Expectation);
        assert_eq!("Exact".parse::<RecoveryMode>().unwrap(), RecoveryMode::Exact);
        assert_eq!(RecoveryMode::default(), RecoveryMode::Expectation);
        let e = "precise".parse::<RecoveryMode>().unwrap_err();
        assert!(e.contains("expected one of"), "{e}");
        assert_eq!(RecoveryMode::Exact.to_string(), "exact");
    }

    #[test]
    fn spec_build_matches_kind_and_source_count() {
        let d = CodeSpec::Dense.build(GeneratorKind::Normal, 10, 5);
        assert_eq!(d.kind(), CodeKind::Dense);
        assert_eq!(d.sources(), 10);
        assert_eq!(d.repairs(), 5);
        let r = CodeSpec::Rateless { overhead: 0.5 }.build(GeneratorKind::Normal, 10, 5);
        assert_eq!(r.kind(), CodeKind::Rateless);
        assert_eq!(r.repairs(), 5);
        assert_eq!(r.label(), "rateless(overhead=0.5)");
    }

    #[test]
    fn reserved_scratch_survives_repeated_use() {
        let code = DenseRandomCode::new(GeneratorKind::Normal, 6, 3, 5);
        let len = 16;
        let mut scratch = DecodeScratch::new();
        scratch.reserve(3, 6, len);
        let truth = random_pool(6, len, 3);
        let repairs = encode_all(&code, &truth, len);
        for drop in [vec![1], vec![0, 4], vec![2, 3, 5]] {
            let mut have = vec![true; 6];
            let mut pool = truth.clone();
            for &j in &drop {
                have[j] = false;
                pool[j * len..(j + 1) * len].fill(0);
            }
            // Single erasures always decode; the larger patterns do
            // whenever this seed's random submatrices are regular.
            if drop.len() > 1 && !code.decodable(&have, 3, &mut scratch) {
                continue;
            }
            assert!(code.decodable(&have, 3, &mut scratch));
            code.decode_into(Isa::Scalar, &have, 3, len, &mut pool, &repairs, &mut scratch)
                .unwrap();
            assert_eq!(pool, truth);
        }
    }
}
