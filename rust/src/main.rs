//! `codedfedl` — leader binary: train federated schemes on the simulated
//! wireless MEC fleet, inspect load allocation, and report privacy budgets.
//!
//! Run `codedfedl --help` for commands. The heavy lifting lives in the
//! library (`rust/src/`); this file is argument plumbing only: it layers
//! an [`ExperimentBuilder`], parses scheme strings with
//! [`SchemeSpec::parse`] and consumes the engine's [`RoundEvent`] stream
//! for progress output.

use anyhow::Result;

use codedfedl::allocation::{self, NodeSpec};
use codedfedl::benchutil;
use codedfedl::cli::{parse_argv, Args, Command, OptSpec};
use codedfedl::conf::ExperimentConfig;
use codedfedl::coordinator::{checkpoint, ResumeSpec, RoundEvent, RoundObserver};
use codedfedl::metrics::GainRow;
use codedfedl::schemes::{CodedFedL, Scheme, SchemeSpec};
use codedfedl::topology::FleetSpec;
use codedfedl::ExperimentBuilder;

fn commands() -> Vec<Command> {
    let common = vec![
        OptSpec { name: "config", help: "TOML config file", default: None, is_flag: false },
        OptSpec { name: "seed", help: "root RNG seed", default: None, is_flag: false },
        OptSpec { name: "epochs", help: "override epochs", default: None, is_flag: false },
        OptSpec { name: "preset", help: "tiny|default|paper", default: Some("default"), is_flag: false },
        OptSpec {
            name: "threads",
            help: "native worker threads (0 = all cores)",
            default: None,
            is_flag: false,
        },
        OptSpec {
            name: "eval-every",
            help: "evaluate every k rounds (final round always)",
            default: None,
            is_flag: false,
        },
        OptSpec {
            name: "simd",
            help: "GEMM microkernel policy: auto (detect AVX2/NEON) | scalar (bit-exact)",
            default: None,
            is_flag: false,
        },
        OptSpec {
            name: "scenario",
            help: "network scenario: static | dropout[:rate=r] | fading[:depth=d,period=T] | burst[:slow=s,factor=f]",
            default: None,
            is_flag: false,
        },
        OptSpec {
            name: "faults",
            help: "fault injection: none | crash[:rate=r] | link[:rate=r,retry=n] | parity[:rate=r] | mixed[:crash=a,link=b,parity=c]",
            default: None,
            is_flag: false,
        },
        OptSpec {
            name: "deadline",
            help: "round deadline: none | quantile[:q=0.9] | fixed[:t=30] (degradation ladder past the cut)",
            default: None,
            is_flag: false,
        },
        OptSpec {
            name: "fleet-n",
            help: "simulated fleet size N (>= clients; data shards tile the training shards)",
            default: None,
            is_flag: false,
        },
        OptSpec {
            name: "participation",
            help: "per-round participation: full | sample:k=K (seeded k-of-N roster)",
            default: None,
            is_flag: false,
        },
        OptSpec {
            name: "shard-size",
            help: "clients per lazily-built fleet shard arena (storage granularity only)",
            default: None,
            is_flag: false,
        },
        OptSpec {
            name: "aggregation",
            help: "gradient fold: flat (sequential) | hier:shard=S (per-shard partial sums)",
            default: None,
            is_flag: false,
        },
        OptSpec {
            name: "code",
            help: "erasure code for the coded scheme: dense | rateless[:overhead=ρ]",
            default: None,
            is_flag: false,
        },
        OptSpec {
            name: "recovery",
            help: "coded straggler recovery: expectation (paper) | exact (erasure decode)",
            default: None,
            is_flag: false,
        },
        OptSpec {
            name: "checkpoint-every",
            help: "write a crash-consistent checkpoint every k rounds (0 = off)",
            default: None,
            is_flag: false,
        },
        OptSpec {
            name: "checkpoint-path",
            help: "checkpoint file (default: checkpoint_<scheme-tag>.ckpt under the artifacts dir)",
            default: None,
            is_flag: false,
        },
        OptSpec {
            name: "resume",
            help: "resume from a checkpoint: off | auto (if the file exists) | path:<file>",
            default: None,
            is_flag: false,
        },
        OptSpec {
            name: "codec",
            help: "gradient uplink codec: none | q8[:scale=auto|<sigma>] | bitpack (reprices uplinks, quantizes folds)",
            default: None,
            is_flag: false,
        },
        OptSpec {
            name: "payload",
            help: "payload pricing: auto (derive from codec) | fixed (pre-codec sizes) | scale:down=..,up=..,parity=..",
            default: None,
            is_flag: false,
        },
    ];
    vec![
        Command {
            name: "train",
            about: "train one scheme (naive | greedy[:psi=ψ] | coded[:delta=δ]) end to end",
            opts: [
                common.clone(),
                vec![
                    OptSpec { name: "scheme", help: "naive|greedy|coded, or e.g. coded:delta=0.2", default: Some("coded"), is_flag: false },
                    OptSpec { name: "delta", help: "coding redundancy u_max/m", default: Some("0.1"), is_flag: false },
                    OptSpec { name: "psi", help: "greedy drop fraction", default: Some("0.1"), is_flag: false },
                ],
            ]
            .concat(),
        },
        Command {
            name: "compare",
            about: "run naive vs greedy vs coded on one setup; print gain table",
            opts: [
                common.clone(),
                vec![
                    OptSpec { name: "delta", help: "coding redundancy", default: Some("0.1"), is_flag: false },
                    OptSpec { name: "psi", help: "greedy drop fraction", default: Some("0.1"), is_flag: false },
                    OptSpec { name: "gamma", help: "target accuracy for the gain row", default: None, is_flag: false },
                ],
            ]
            .concat(),
        },
        Command {
            name: "allocate",
            about: "solve the two-step load allocation for the paper fleet and print (t*, ℓ*, u*)",
            opts: [
                common.clone(),
                vec![OptSpec { name: "delta", help: "coding redundancy", default: Some("0.1"), is_flag: false }],
            ]
            .concat(),
        },
        Command {
            name: "outage",
            about: "outage-constrained deadline: min t with P(R(t) < (1-eps)m) <= eta (§VI extension)",
            opts: [
                common.clone(),
                vec![
                    OptSpec { name: "delta", help: "coding redundancy", default: Some("0.1"), is_flag: false },
                    OptSpec { name: "eps", help: "allowed return shortfall fraction", default: Some("0.1"), is_flag: false },
                    OptSpec { name: "eta", help: "outage probability bound", default: Some("0.05"), is_flag: false },
                ],
            ]
            .concat(),
        },
        Command {
            name: "info",
            about: "print the resolved experiment configuration",
            opts: common,
        },
    ]
}

/// Layer preset → config file → flag overrides into a builder.
fn builder_from(args: &Args) -> Result<ExperimentBuilder> {
    let mut b = match args.get("config") {
        Some(path) => ExperimentBuilder::from_file(std::path::Path::new(path))?,
        None => ExperimentBuilder::preset(args.get_or("preset", "default"))?,
    };
    if let Some(seed) = args.parse_u64("seed").map_err(anyhow::Error::msg)? {
        b = b.seed(seed);
    }
    if let Some(e) = args.parse_usize("epochs").map_err(anyhow::Error::msg)? {
        b = b.epochs(e);
    }
    if let Some(t) = args.parse_usize("threads").map_err(anyhow::Error::msg)? {
        b = b.threads(t);
    }
    if let Some(k) = args.parse_usize("eval-every").map_err(anyhow::Error::msg)? {
        b = b.eval_every(k);
    }
    if let Some(s) = args.get("simd") {
        b = b.simd(s.parse().map_err(anyhow::Error::msg)?);
    }
    if let Some(s) = args.get("scenario") {
        b = b.scenario(s.parse().map_err(anyhow::Error::msg)?);
    }
    if let Some(s) = args.get("faults") {
        b = b.faults(s.parse().map_err(anyhow::Error::msg)?);
    }
    if let Some(s) = args.get("deadline") {
        b = b.deadline(s.parse().map_err(anyhow::Error::msg)?);
    }
    if let Some(n) = args.parse_usize("fleet-n").map_err(anyhow::Error::msg)? {
        b = b.fleet_n(Some(n));
    }
    if let Some(s) = args.get("participation") {
        b = b.participation(s.parse().map_err(anyhow::Error::msg)?);
    }
    if let Some(s) = args.parse_usize("shard-size").map_err(anyhow::Error::msg)? {
        b = b.shard_size(s);
    }
    if let Some(s) = args.get("aggregation") {
        b = b.aggregation(s.parse().map_err(anyhow::Error::msg)?);
    }
    if let Some(s) = args.get("code") {
        b = b.code(s.parse().map_err(anyhow::Error::msg)?);
    }
    if let Some(s) = args.get("recovery") {
        b = b.recovery(s.parse().map_err(anyhow::Error::msg)?);
    }
    if let Some(k) = args.parse_usize("checkpoint-every").map_err(anyhow::Error::msg)? {
        b = b.checkpoint_every(k);
    }
    if let Some(p) = args.get("checkpoint-path") {
        b = b.checkpoint_path(Some(p.to_string()));
    }
    if let Some(s) = args.get("resume") {
        b = b.resume(s.parse().map_err(anyhow::Error::msg)?);
    }
    if let Some(s) = args.get("codec") {
        b = b.codec(s.parse().map_err(anyhow::Error::msg)?);
    }
    if let Some(s) = args.get("payload") {
        b = b.payload(s.parse().map_err(anyhow::Error::msg)?);
    }
    Ok(b)
}

fn config_from(args: &Args) -> Result<ExperimentConfig> {
    Ok(builder_from(args)?.config().clone())
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, args) = match parse_argv(&commands(), &argv) {
        Ok(x) => x,
        Err(msg) => {
            eprintln!("{msg}");
            let help = argv.first().map(|s| s.as_str()) == Some("--help");
            std::process::exit(if help { 0 } else { 2 });
        }
    };
    if let Err(e) = dispatch(cmd, &args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(cmd: &str, args: &Args) -> Result<()> {
    match cmd {
        "train" => cmd_train(args),
        "compare" => cmd_compare(args),
        "allocate" => cmd_allocate(args),
        "outage" => cmd_outage(args),
        "info" => {
            println!("{:#?}", config_from(args)?);
            Ok(())
        }
        _ => unreachable!("cli validated"),
    }
}

/// Streams engine round events to stdout every `stride` iterations — the
/// CLI's view of the same [`RoundEvent`] stream tests and benches consume.
struct ProgressPrinter {
    stride: usize,
}

impl RoundObserver for ProgressPrinter {
    fn on_round(&mut self, ev: &RoundEvent) {
        if ev.iter % self.stride == 0 || ev.iter == 1 {
            // Degraded rounds (faults/deadline) tag the ladder rung that
            // resolved the aggregate; full rounds stay on the old format.
            let rung = if ev.outcome == codedfedl::metrics::RoundOutcome::Full {
                String::new()
            } else {
                format!("  [{}]", ev.outcome.label())
            };
            println!(
                "iter {:>5}  sim {:>10.1} s  acc {:.4}  loss {:.5}  ({}/{} arrivals){rung}",
                ev.iter, ev.clock, ev.acc, ev.loss, ev.arrivals, ev.planned
            );
        }
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let delta = args.parse_f64("delta").map_err(anyhow::Error::msg)?.unwrap_or(0.1);
    let psi = args.parse_f64("psi").map_err(anyhow::Error::msg)?.unwrap_or(0.1);
    let raw = args.get_or("scheme", "coded");
    let mut spec = SchemeSpec::parse(raw).map_err(anyhow::Error::msg)?;
    // Bare scheme names take their parameter from --delta/--psi; the
    // `name:key=value` form is self-contained.
    if !raw.contains(':') {
        match &mut spec {
            SchemeSpec::GreedyUncoded { psi: p } => *p = psi,
            SchemeSpec::Coded { delta: d } => *d = delta,
            SchemeSpec::NaiveUncoded => {}
        }
    }

    let session = builder_from(args)?.build()?;
    let total = session.config().total_iters();
    println!("scheme: {}", spec.label());
    // The coded scheme picks up `[coding] code` / `recovery` (and the
    // --code/--recovery flags) from the session config, like `run_spec`.
    let cfg = session.config();
    let mut scheme: Box<dyn Scheme> = match spec {
        SchemeSpec::Coded { delta } => {
            Box::new(CodedFedL::new(delta).with_code(cfg.code).with_recovery(cfg.recovery))
        }
        other => other.build(),
    };
    // Surface the checkpoint situation before the first round so operators
    // can tell a resumed run from a fresh one (the engine itself performs
    // the actual restore and re-validates the file).
    let ckpt_path = cfg
        .checkpoint_path
        .clone()
        .unwrap_or_else(|| checkpoint::default_path(&cfg.artifacts_dir, scheme.rng_tag()));
    match &cfg.resume {
        ResumeSpec::Off => {
            if cfg.checkpoint_every > 0 {
                println!(
                    "checkpoint: writing {ckpt_path} every {} rounds (fresh start)",
                    cfg.checkpoint_every
                );
            }
        }
        spec => {
            let peek_path = match spec {
                ResumeSpec::Path(p) => p.clone(),
                _ => ckpt_path.clone(),
            };
            match checkpoint::load(std::path::Path::new(&peek_path)) {
                Ok(snap) => println!(
                    "checkpoint: resuming from {peek_path} at round {} (sim clock {:.1} s)",
                    snap.next_iter, snap.clock
                ),
                Err(_) if *spec == ResumeSpec::Auto => {
                    println!("checkpoint: no usable checkpoint at {peek_path}; starting fresh");
                }
                // `path:<p>` resume with a bad file: let the engine fail
                // with the named CheckpointError instead of pre-judging.
                Err(_) => {}
            }
        }
    }
    let mut progress = ProgressPrinter { stride: (total / 20).max(1) };
    let out = session.run_observed(scheme.as_mut(), &mut progress)?;
    if let Some(r) = out.resumed_from {
        println!("resumed at round {r}: earlier rounds restored from the checkpoint");
    }
    if let (Some(t), Some(u)) = (out.t_star, out.u_star) {
        println!("t* = {t:.2} s   u* = {u}   parity overhead = {:.1} s", out.parity_overhead);
    }
    println!("final accuracy {:.4}", out.history.final_accuracy());
    println!(
        "bytes on wire: {:.1} MB down, {:.1} MB up (codec {})",
        out.bytes_down_total as f64 / 1e6,
        out.bytes_up_total as f64 / 1e6,
        session.config().codec.label()
    );
    Ok(())
}

fn cmd_compare(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let delta = args.parse_f64("delta").map_err(anyhow::Error::msg)?.unwrap_or(0.1);
    let psi = args.parse_f64("psi").map_err(anyhow::Error::msg)?.unwrap_or(0.1);
    let schemes = [
        SchemeSpec::NaiveUncoded,
        SchemeSpec::GreedyUncoded { psi },
        SchemeSpec::Coded { delta },
    ];
    let (_, results) = benchutil::run_experiment(&cfg, &schemes)?;
    let naive = &results[0].1.history;
    let greedy = &results[1].1.history;
    let coded = &results[2].1.history;

    println!(
        "{}",
        benchutil::ascii_curves(
            "accuracy vs simulated time",
            &[naive, greedy, coded],
            |p| p.sim_time,
            "seconds",
        )
    );
    let gamma = args
        .parse_f64("gamma")
        .map_err(anyhow::Error::msg)?
        .unwrap_or_else(|| 0.95 * naive.best_accuracy());
    println!("{}", GainRow::compute(gamma, naive, greedy, coded).render());
    Ok(())
}

fn cmd_outage(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let delta = args.parse_f64("delta").map_err(anyhow::Error::msg)?.unwrap_or(0.1);
    let eps = args.parse_f64("eps").map_err(anyhow::Error::msg)?.unwrap_or(0.1);
    let eta = args.parse_f64("eta").map_err(anyhow::Error::msg)?.unwrap_or(0.05);
    let spec = FleetSpec::paper(cfg.clients, cfg.q, cfg.classes);
    let mut rng = codedfedl::rng::Rng::seed_from(cfg.seed).split(2);
    let clients = spec.build_clients(&mut rng);
    let m = cfg.global_batch() as f64;
    let mut nodes: Vec<NodeSpec> = clients
        .iter()
        .map(|p| NodeSpec { params: *p, max_load: cfg.local_batch as f64 })
        .collect();
    nodes.push(NodeSpec { params: spec.build_server(), max_load: (delta * m).round() });

    // Expected-return solve for comparison.
    let mean = allocation::solve(&nodes, m).map_err(|e| anyhow::anyhow!(e.to_string()))?;
    println!("expected-return deadline: t* = {:.3} s (E[R] = m)", mean.t_star);

    let sol = allocation::outage::solve_outage(&nodes, m, eps, eta)
        .ok_or_else(|| anyhow::anyhow!("outage target infeasible for this fleet"))?;
    println!(
        "outage-constrained:       t* = {:.3} s  (P(R < {:.0}) = {:.4} <= eta {eta})",
        sol.t_star,
        (1.0 - eps) * m,
        sol.outage
    );
    println!(
        "guarding the {:.0}% tail costs {:+.1}% deadline vs the mean target",
        eta * 100.0,
        100.0 * (sol.t_star - mean.t_star) / mean.t_star
    );
    Ok(())
}

fn cmd_allocate(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let delta = args.parse_f64("delta").map_err(anyhow::Error::msg)?.unwrap_or(0.1);
    let spec = FleetSpec::paper(cfg.clients, cfg.q, cfg.classes);
    let mut rng = codedfedl::rng::Rng::seed_from(cfg.seed).split(2);
    let clients = spec.build_clients(&mut rng);
    let m = cfg.global_batch() as f64;
    let u_cap = (delta * m).round();
    let mut nodes: Vec<NodeSpec> = clients
        .iter()
        .map(|p| NodeSpec { params: *p, max_load: cfg.local_batch as f64 })
        .collect();
    nodes.push(NodeSpec { params: spec.build_server(), max_load: u_cap });
    let alloc = allocation::solve(&nodes, m).map_err(|e| anyhow::anyhow!(e.to_string()))?;
    println!("m = {m}   δ = {delta}   u_cap = {u_cap}");
    println!("t* = {:.3} s   u* = {:.1}", alloc.t_star, alloc.u_star());
    println!("{:<6} {:>10} {:>12} {:>10} {:>8}", "node", "l*", "E[R]", "pnr", "tau(s)");
    for (j, ((l, er), p)) in alloc
        .loads
        .iter()
        .zip(&alloc.expected_returns)
        .zip(&alloc.pnr)
        .enumerate()
    {
        let tau = nodes[j].params.tau;
        let name = if j < clients.len() { format!("c{j:02}") } else { "srv".into() };
        println!("{name:<6} {l:>10.1} {er:>12.2} {p:>10.4} {tau:>8.2}");
    }
    println!("total E[R] = {:.2} (target m = {m})", alloc.total_expected_return());
    Ok(())
}
