//! # CodedFedL
//!
//! Production reproduction of *“Coded Computing for Low-Latency Federated
//! Learning over Wireless Edge Networks”* (Prakash et al., IEEE JSAC 2020).
//!
//! The crate is the **Layer-3 coordinator** of a three-layer stack:
//!
//! * **L1** — Pallas kernels (RFF embed, masked regression gradient, parity
//!   encode) authored in `python/compile/kernels/`, lowered once.
//! * **L2** — JAX graphs composing those kernels
//!   (`python/compile/model.py`), AOT-exported to HLO text in `artifacts/`.
//! * **L3** — this crate: the wireless-MEC delay substrate, the
//!   load-allocation optimizer, the distributed-encoding bookkeeping and the
//!   coded federated training loop, all executing the L2 artifacts through
//!   the PJRT C API (`xla` crate). Python never runs on the training path.
//!
//! See `DESIGN.md` for the full system inventory and experiment index, and
//! `EXPERIMENTS.md` for paper-vs-measured results.

pub mod allocation;
pub mod benchutil;
pub mod cli;
pub mod coding;
pub mod conf;
pub mod convergence;
pub mod coordinator;
pub mod data;
pub mod delay;
pub mod metrics;
pub mod numerics;
pub mod privacy;
pub mod rng;
pub mod runtime;
pub mod sim;
pub mod tensor;
pub mod topology;
