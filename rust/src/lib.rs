//! # CodedFedL
//!
//! Production reproduction of *“Coded Computing for Low-Latency Federated
//! Learning over Wireless Edge Networks”* (Prakash et al., IEEE JSAC 2020).
//!
//! ## The session API
//!
//! Everything hangs off three pieces:
//!
//! 1. **[`ExperimentBuilder`]** — layer a config (preset, TOML file,
//!    typed overrides; every validation error names the offending field)
//!    and `build()` a session.
//! 2. **[`Session`]** — owns the one-time shared state: the
//!    [`coordinator::FedSetup`] (fleet, non-IID shards, RFF-embedded
//!    data, test set) and the kernel [`runtime::Runtime`]. Run any number
//!    of schemes on it; they all see identical data and delay statistics,
//!    which is what makes the paper's comparisons fair.
//! 3. **[`schemes::Scheme`]** — the open aggregation-policy trait. The
//!    paper's three policies ship in [`schemes`] ([`schemes::NaiveUncoded`],
//!    [`schemes::GreedyUncoded`], [`schemes::CodedFedL`]); new policies
//!    implement `label` + `plan_round` (plus optional `prepare` /
//!    `aggregate` hooks) and plug in without touching the engine.
//!
//! ```no_run
//! use codedfedl::{ExperimentBuilder, schemes::{CodedFedL, NaiveUncoded}};
//!
//! let session = ExperimentBuilder::preset("tiny")?.epochs(8).build()?;
//! let naive = session.run(&mut NaiveUncoded::new())?;
//! let coded = session.run(&mut CodedFedL::new(0.3))?;
//! println!(
//!     "coded is {:.1}x faster on the simulated clock",
//!     naive.history.total_sim_time() / coded.history.total_sim_time()
//! );
//! # anyhow::Ok(())
//! ```
//!
//! Per round the engine ([`coordinator::engine`]) samples the wireless MEC
//! delay model, asks the scheme which gradients to execute, really runs
//! them through the runtime, applies the update of eq. (5), and emits one
//! [`coordinator::RoundEvent`] to every registered
//! [`coordinator::RoundObserver`] — the CLI progress printer, benches and
//! tests all consume that same stream.
//!
//! ## Network scenarios and the round timeline
//!
//! Delay sampling is an *event timeline*, not one scalar per client: each
//! round records every client's ordered leg completions (downlink wait →
//! compute → uplink wait) plus the MEC unit's parity completion in a
//! [`sim::timeline::RoundTrace`], whose totals feed the familiar
//! [`sim::RoundDelays`] view. On top sits a pluggable
//! [`sim::scenario::Scenario`] (`[scenario]` config / `--scenario` /
//! [`ExperimentBuilder::scenario`]): `static` (default, bit-identical to
//! the fixed-fleet §V-A setting), `dropout:rate=…` (per-round client
//! unavailability), `fading:depth=…,period=…` (round-varying τ/p) and
//! `burst:slow=…,factor=…` (compute-rate dips). Every scheme on a session
//! sees the same scenario realisation, so comparisons stay fair, and all
//! scenarios are deterministic across thread counts and SIMD policies.
//! The `[fleet]` section additionally opens asymmetric downlink/uplink
//! links (per-leg τ multipliers and erasure probabilities) sampled
//! exactly by the timeline, with the allocation optimizer seeing each
//! client's matched-mean reciprocal surrogate.
//!
//! ## Faults, deadlines and degraded rounds
//!
//! Orthogonal to scenarios, [`sim::fault`] (`[faults]` config /
//! `--faults` / [`ExperimentBuilder::faults`]) injects seeded client
//! crashes, uplink losses (optionally retried with modelled backoff) and
//! server-side parity loss into the sampled timeline, and `[training]
//! deadline` / `--deadline` / [`ExperimentBuilder::deadline`] closes
//! each round at a fixed or quantile wall-clock cut. The engine then
//! resolves every round through an explicit **degradation ladder** —
//! exact decode → parity compensation → renormalised partial fold →
//! documented skip — never panicking and never producing NaN, and
//! reports the rung per round ([`metrics::RoundOutcome`] on
//! [`coordinator::RoundEvent`], histogrammed in
//! [`coordinator::TrainOutcome::outcomes`]) so experiments can plot
//! achieved vs planned participation. Fault draws use a dedicated RNG
//! stream, so `faults = "none"` + `deadline = "none"` histories are
//! bit-for-bit the historical ones. See `examples/degraded_rounds.rs`.
//!
//! ## Crash recovery
//!
//! The coordinator itself is restartable mid-run
//! ([`coordinator::checkpoint`]): `[checkpoint] every = R` /
//! `--checkpoint-every` writes a versioned, CRC-guarded snapshot of the
//! full training state — θ, the simulated clock, the round index, every
//! sequential RNG stream position, the outcome histogram and the
//! evaluated history — every `R` rounds and at graceful shutdown, always
//! through [`io::atomic_write`] (temp file + fsync + rename) so a crash
//! mid-write can never tear the file. `[checkpoint] resume = "auto" |
//! "path:<p>" | "off"` / `--resume` restores the engine loop mid-run;
//! torn, truncated, corrupted or mismatched-config checkpoints are
//! rejected with named [`coordinator::CheckpointError`]s, never panics.
//! The house invariant, proved by `tests/checkpoint_resume.rs` across
//! schemes × scenarios × faults × thread counts × SIMD policies: a run
//! interrupted at any round and resumed is **bit-identical** to the
//! uninterrupted run. The fault kind `server:rate=…` kills-and-restarts
//! the coordinator in-process from its latest snapshot so chaos tests
//! drive the recovery path, and `corrupt:rate=…` injects non-finite
//! client gradients that the fold excludes before aggregation (counted
//! on [`coordinator::RoundEvent::corrupted`] /
//! [`coordinator::TrainOutcome::corrupted_total`]). See
//! `examples/resume_training.rs`.
//!
//! ## Communication model
//!
//! Payload bytes are a first-class modelled quantity ([`comm`]): a
//! [`comm::PayloadModel`] prices the three wire transfers — θ downlink
//! broadcast, gradient uplink, one-shot parity upload — and the fleet
//! builder folds its per-leg byte scales into every client's packet
//! times, so the round timeline *and* the allocation optimizer both see
//! what the wire actually carries (compression shifts the optimal
//! (load, redundancy) split). `[comm] codec` / `--codec` /
//! [`ExperimentBuilder::codec`] selects the uplink codec: `none`
//! (default — 32-bit scalars, every seeded history bit-identical),
//! `q8[:scale=auto|σ]` (per-row affine int8 quantization) or `bitpack`
//! (4-bit nibble-packed codes). The engine transcodes each arrived
//! gradient through the codec before the fold (quantize → pack → unpack
//! → dequantize, ISA-dispatched and bit-exact across SIMD policies —
//! the kernels use no FMA), and reports per-round bytes on the wire on
//! [`coordinator::RoundEvent`] and totals on
//! [`coordinator::TrainOutcome`]. `[comm] payload` decouples pricing
//! from transcoding (`fixed` keeps historical pricing under any codec).
//! See `examples/payload_ablation.rs` and `tests/payload_determinism.rs`.
//!
//! ## Erasure coding and exact recovery
//!
//! The coded scheme's straggler tolerance is pluggable ([`coding`]): a
//! [`coding::Code`] treats each client's gradient block as a GF(256)
//! source symbol and fixes a deterministic, seeded set of repair symbols
//! — [`coding::DenseRandomCode`] (the paper's dense generator) or
//! [`coding::RatelessCode`] (a systematic LT-style fountain code with
//! XOR-dominant sparse rows). `[coding] code` / `--code` /
//! [`ExperimentBuilder::code`] selects the code, and `[coding] recovery`
//! / `--recovery` / [`ExperimentBuilder::recovery`] selects how rounds
//! complete: `expectation` (default) keeps the paper's unbiased
//! expectation aggregate bit-for-bit, while `exact` watches the round
//! timeline, stops as soon as the arrived subset is decodable, and
//! erasure-decodes the missing client gradients — reproducing the
//! all-arrived aggregate exactly (GF(256) arithmetic has no rounding).
//! The field kernels ([`coding::gf256`]) dispatch through the same
//! runtime [`tensor::Isa`] as the GEMM microkernel; decode scratch lives
//! in caller-owned buffers so warm rounds stay allocation-free. See
//! `examples/exact_recovery.rs`.
//!
//! ## The stack
//!
//! The crate is the **Layer-3 coordinator** of a three-layer stack:
//!
//! * **L1** — Pallas kernels (RFF embed, masked regression gradient,
//!   parity encode) authored in `python/compile/kernels/`, lowered once.
//! * **L2** — JAX graphs composing those kernels
//!   (`python/compile/model.py`), AOT-exported to HLO text in `artifacts/`.
//! * **L3** — this crate: the wireless-MEC delay substrate, the
//!   load-allocation optimizer, the distributed-encoding bookkeeping and
//!   the coded federated training loop. With `--features pjrt` the L2
//!   artifacts execute through the PJRT C API (`xla` bindings); by default
//!   [`runtime::native`] provides pure-Rust implementations of the same
//!   kernel contracts — cache-blocked, multi-threaded, and bit-identical
//!   across thread counts — so the whole system builds, tests and trains
//!   fast offline.
//!
//! ## Performance
//!
//! The native backend is the measured hot path: every matmul bottoms out
//! in an **ISA-dispatched GEMM microkernel** ([`tensor::gemm_into`] —
//! explicit AVX2+FMA on x86_64 and NEON on aarch64, 4×16 register
//! blocks, with the scalar register-tile loop as the always-available
//! fallback and determinism oracle), kernels dispatch onto a
//! **persistent worker pool** ([`runtime::pool`], spawned once per
//! [`Session`], workers parked between jobs), θ is packed once per round
//! into a tile-aligned panel shared by every kernel call (SIMD A-operand
//! packs live in the workers' persistent scratch arenas), and the engine
//! reuses all per-round buffers — a warm training round performs zero
//! heap allocations on the compute path (`tests/alloc_gate.rs`). See
//! `rust/PERF.md` for the kernel/dispatch/threading/allocation design,
//! the tracked `BENCH_hotpath.json` baseline (schema 8: per-op GFLOP/s,
//! codec GB/s + symbols/s, the selected ISA, fleet-scale rounds/s, the
//! degraded-run rung histogram + achieved participation, the checkpoint
//! snapshot latency, and the payload pipeline's bytes-per-round +
//! quantize/pack GB/s rows; `cargo bench --bench hotpath`), and how
//! to compare runs across PRs.
//!
//! Knobs: thread count comes from `[runtime] threads` / `--threads` /
//! [`ExperimentBuilder::threads`] (0 = all cores) and never changes
//! results. The microkernel comes from `[runtime] simd` / `--simd` /
//! [`ExperimentBuilder::simd`]: `auto` (default) detects the best ISA
//! once per session — deterministic and thread-count invariant for a
//! fixed host, within 1e-4 of scalar (fused multiply-adds round
//! differently); `scalar` pins the bit-exact fallback, reproducing
//! pre-SIMD histories exactly — use it when comparing training runs
//! across machines with different ISAs. `[training] eval_every` thins
//! the per-round evaluation probe without touching the training math.
//!
//! See `DESIGN.md` for the full system inventory and experiment index,
//! `EXPERIMENTS.md` for paper-vs-measured results, and
//! `examples/quickstart.rs` for the canonical Builder → Session → Scheme
//! walkthrough.

pub mod allocation;
pub mod benchutil;
pub mod cli;
pub mod coding;
pub mod comm;
pub mod conf;
pub mod convergence;
pub mod coordinator;
pub mod data;
pub mod delay;
pub mod experiment;
pub mod io;
pub mod metrics;
pub mod numerics;
pub mod privacy;
pub mod rng;
pub mod runtime;
pub mod schemes;
pub mod sim;
pub mod tensor;
pub mod topology;

pub use coordinator::{
    CheckpointError, FedSetup, ResumeSpec, RoundEvent, RoundObserver, TrainOutcome,
};
pub use experiment::{ExperimentBuilder, Session};
pub use metrics::{OutcomeCounts, RoundOutcome};
pub use schemes::{Scheme, SchemeSpec};
pub use sim::fault::{DeadlineSpec, FaultSpec};
pub use sim::scenario::{Scenario, ScenarioSpec};
