//! Experiment configuration: a TOML-subset parser (serde/toml are not
//! available in this offline environment) plus the typed
//! [`ExperimentConfig`] all binaries and benches share.
//!
//! Supported syntax: `[section]` headers, `key = value` with integer,
//! float, boolean, `"string"` and flat `[v1, v2, …]` array values, `#`
//! comments. That covers every config this project ships.

mod parser;

pub use parser::{parse, ConfError, Value};

use std::collections::BTreeMap;
use std::path::Path;

use crate::coding::GeneratorKind;

/// Which aggregation scheme the coordinator runs (§V-A "Schemes").
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Scheme {
    /// Server waits for *all* client updates.
    NaiveUncoded,
    /// Server waits for the first `(1-ψ)·n` client updates.
    GreedyUncoded { psi: f64 },
    /// CodedFedL with redundancy `δ = u_max / m`.
    Coded { delta: f64 },
}

impl Scheme {
    pub fn label(&self) -> String {
        match self {
            Scheme::NaiveUncoded => "naive".into(),
            Scheme::GreedyUncoded { psi } => format!("greedy(psi={psi})"),
            Scheme::Coded { delta } => format!("coded(delta={delta})"),
        }
    }
}

/// Everything one training experiment needs; `Default` is the repo's
/// reduced "default" scale (see python/compile/shapes.py — the two must
/// agree; the artifact manifest is checked at runtime).
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Root RNG seed; every stochastic object derives from it.
    pub seed: u64,
    /// Number of clients n.
    pub clients: usize,
    /// Raw feature dim d.
    pub dim: usize,
    /// RFF dimension q.
    pub q: usize,
    /// Classes c.
    pub classes: usize,
    /// RBF kernel width σ.
    pub sigma: f64,
    /// Per-client local mini-batch rows ℓ_j.
    pub local_batch: usize,
    /// Global mini-batches per epoch (m = clients · local_batch per step).
    pub steps_per_epoch: usize,
    /// Total epochs.
    pub epochs: usize,
    /// Initial learning rate (paper: 6).
    pub lr: f64,
    /// Step-decay factor (paper: 0.8)…
    pub lr_decay: f64,
    /// …applied at these epochs (paper: 40, 65).
    pub lr_decay_epochs: Vec<usize>,
    /// L2 regularisation λ (paper: 9e-6).
    pub l2: f64,
    /// Max parity rows the server can process (u_max, AOT-compiled shape).
    pub u_max: usize,
    /// Generator matrix distribution.
    pub generator: GeneratorKind,
    /// Train set size (m_total = train points across all clients).
    pub train_size: usize,
    /// Test set size.
    pub test_size: usize,
    /// Artifacts directory.
    pub artifacts_dir: String,
    /// Dataset family: "mnist" | "fashion" (synthetic stand-ins unless IDX
    /// files are present under data/<family>/).
    pub dataset: String,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            seed: 0xC0DE_DFED,
            clients: 30,
            dim: 784,
            q: 512,
            classes: 10,
            sigma: 5.0,
            local_batch: 200,
            steps_per_epoch: 5,
            epochs: 70,
            lr: 6.0,
            lr_decay: 0.8,
            lr_decay_epochs: vec![40, 65],
            l2: 9e-6,
            u_max: 1536,
            generator: GeneratorKind::Normal,
            train_size: 30_000,
            test_size: 2_000,
            artifacts_dir: "artifacts".into(),
            dataset: "mnist".into(),
        }
    }
}

impl ExperimentConfig {
    /// The paper's full §V-A scale (requires `--preset paper` artifacts).
    pub fn paper() -> Self {
        ExperimentConfig {
            q: 2000,
            local_batch: 400,
            u_max: 3072,
            train_size: 60_000,
            test_size: 10_000,
            ..Default::default()
        }
    }

    /// Tiny smoke scale used by integration tests.
    pub fn tiny() -> Self {
        ExperimentConfig {
            clients: 5,
            dim: 32,
            q: 64,
            local_batch: 40,
            steps_per_epoch: 2,
            epochs: 4,
            lr_decay_epochs: vec![3],
            u_max: 128,
            train_size: 400,
            test_size: 200,
            dataset: "easy".into(),
            ..Default::default()
        }
    }

    /// Global mini-batch size m per step.
    pub fn global_batch(&self) -> usize {
        self.clients * self.local_batch
    }

    /// Total training iterations.
    pub fn total_iters(&self) -> usize {
        self.epochs * self.steps_per_epoch
    }

    /// Learning rate at (0-based) epoch `e` (step decay, §V-A).
    pub fn lr_at_epoch(&self, e: usize) -> f64 {
        let decays = self.lr_decay_epochs.iter().filter(|&&d| e >= d).count();
        self.lr * self.lr_decay.powi(decays as i32)
    }

    /// Load from a TOML-subset file, overriding defaults.
    pub fn from_file(path: &Path) -> Result<Self, ConfError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| ConfError::Io(format!("{path:?}: {e}")))?;
        Self::from_str_conf(&text)
    }

    /// Parse from config text, overriding defaults.
    pub fn from_str_conf(text: &str) -> Result<Self, ConfError> {
        let doc = parse(text)?;
        let mut c = ExperimentConfig::default();
        let empty = BTreeMap::new();
        let sec = |name: &str| doc.get(name).unwrap_or(&empty);

        let exp = sec("experiment");
        read_u64(exp, "seed", &mut c.seed)?;
        read_usize(exp, "clients", &mut c.clients)?;
        read_string(exp, "dataset", &mut c.dataset)?;
        read_string(exp, "artifacts_dir", &mut c.artifacts_dir)?;
        read_usize(exp, "train_size", &mut c.train_size)?;
        read_usize(exp, "test_size", &mut c.test_size)?;

        let model = sec("model");
        read_usize(model, "dim", &mut c.dim)?;
        read_usize(model, "q", &mut c.q)?;
        read_usize(model, "classes", &mut c.classes)?;
        read_f64(model, "sigma", &mut c.sigma)?;

        let tr = sec("training");
        read_usize(tr, "local_batch", &mut c.local_batch)?;
        read_usize(tr, "steps_per_epoch", &mut c.steps_per_epoch)?;
        read_usize(tr, "epochs", &mut c.epochs)?;
        read_f64(tr, "lr", &mut c.lr)?;
        read_f64(tr, "lr_decay", &mut c.lr_decay)?;
        read_f64(tr, "l2", &mut c.l2)?;
        if let Some(v) = tr.get("lr_decay_epochs") {
            c.lr_decay_epochs = v
                .as_array()
                .ok_or_else(|| bad("training.lr_decay_epochs", "array"))?
                .iter()
                .map(|x| {
                    x.as_int()
                        .map(|i| i as usize)
                        .ok_or_else(|| bad("training.lr_decay_epochs", "int array"))
                })
                .collect::<Result<_, _>>()?;
        }

        let cod = sec("coding");
        read_usize(cod, "u_max", &mut c.u_max)?;
        if let Some(v) = cod.get("generator") {
            let s = v.as_str().ok_or_else(|| bad("coding.generator", "string"))?;
            c.generator = s.parse().map_err(ConfError::Invalid)?;
        }
        c.validate()?;
        Ok(c)
    }

    pub fn validate(&self) -> Result<(), ConfError> {
        if self.clients == 0 {
            return Err(ConfError::Invalid("clients must be > 0".into()));
        }
        if self.train_size % self.clients != 0 {
            return Err(ConfError::Invalid(format!(
                "train_size {} must divide evenly across {} clients",
                self.train_size, self.clients
            )));
        }
        let per_client = self.train_size / self.clients;
        if per_client % self.local_batch != 0 {
            return Err(ConfError::Invalid(format!(
                "per-client shard {per_client} must be a multiple of local_batch {}",
                self.local_batch
            )));
        }
        if !(self.lr > 0.0) || !(self.lr_decay > 0.0) {
            return Err(ConfError::Invalid("lr and lr_decay must be > 0".into()));
        }
        if self.u_max == 0 {
            return Err(ConfError::Invalid(
                "u_max must be > 0 (coding redundancy provides feasibility slack)".into(),
            ));
        }
        Ok(())
    }
}

fn bad(key: &str, want: &str) -> ConfError {
    ConfError::Invalid(format!("{key}: expected {want}"))
}

fn read_u64(
    sec: &BTreeMap<String, Value>,
    key: &str,
    out: &mut u64,
) -> Result<(), ConfError> {
    if let Some(v) = sec.get(key) {
        *out = v.as_int().ok_or_else(|| bad(key, "int"))? as u64;
    }
    Ok(())
}

fn read_usize(
    sec: &BTreeMap<String, Value>,
    key: &str,
    out: &mut usize,
) -> Result<(), ConfError> {
    if let Some(v) = sec.get(key) {
        let i = v.as_int().ok_or_else(|| bad(key, "int"))?;
        if i < 0 {
            return Err(bad(key, "non-negative int"));
        }
        *out = i as usize;
    }
    Ok(())
}

fn read_f64(
    sec: &BTreeMap<String, Value>,
    key: &str,
    out: &mut f64,
) -> Result<(), ConfError> {
    if let Some(v) = sec.get(key) {
        *out = v.as_float().ok_or_else(|| bad(key, "float"))?;
    }
    Ok(())
}

fn read_string(
    sec: &BTreeMap<String, Value>,
    key: &str,
    out: &mut String,
) -> Result<(), ConfError> {
    if let Some(v) = sec.get(key) {
        *out = v.as_str().ok_or_else(|| bad(key, "string"))?.to_string();
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        ExperimentConfig::default().validate().unwrap();
        ExperimentConfig::tiny().validate().unwrap();
        ExperimentConfig::paper().validate().unwrap();
    }

    #[test]
    fn lr_schedule_matches_paper() {
        let c = ExperimentConfig::default();
        assert_eq!(c.lr_at_epoch(0), 6.0);
        assert_eq!(c.lr_at_epoch(39), 6.0);
        assert!((c.lr_at_epoch(40) - 4.8).abs() < 1e-12);
        assert!((c.lr_at_epoch(65) - 3.84).abs() < 1e-12);
    }

    #[test]
    fn parses_full_config() {
        let text = r#"
# experiment file
[experiment]
seed = 7
clients = 10
dataset = "fashion"
train_size = 2000
test_size = 500

[model]
dim = 64
q = 128
classes = 10
sigma = 3.5

[training]
local_batch = 100
steps_per_epoch = 2
epochs = 30
lr = 2.5
lr_decay = 0.5
lr_decay_epochs = [10, 20]
l2 = 0.001

[coding]
u_max = 256
generator = "rademacher"
"#;
        let c = ExperimentConfig::from_str_conf(text).unwrap();
        assert_eq!(c.seed, 7);
        assert_eq!(c.clients, 10);
        assert_eq!(c.dataset, "fashion");
        assert_eq!(c.q, 128);
        assert!((c.sigma - 3.5).abs() < 1e-12);
        assert_eq!(c.lr_decay_epochs, vec![10, 20]);
        assert_eq!(c.generator, GeneratorKind::Rademacher);
        assert_eq!(c.global_batch(), 1000);
        assert_eq!(c.total_iters(), 60);
    }

    #[test]
    fn rejects_inconsistent_partition() {
        let text = "[experiment]\nclients = 7\ntrain_size = 100\n";
        assert!(ExperimentConfig::from_str_conf(text).is_err());
    }

    #[test]
    fn rejects_bad_generator() {
        let text = "[coding]\ngenerator = \"foo\"\n";
        assert!(ExperimentConfig::from_str_conf(text).is_err());
    }

    #[test]
    fn scheme_labels() {
        assert_eq!(Scheme::NaiveUncoded.label(), "naive");
        assert_eq!(Scheme::GreedyUncoded { psi: 0.1 }.label(), "greedy(psi=0.1)");
        assert_eq!(Scheme::Coded { delta: 0.2 }.label(), "coded(delta=0.2)");
    }
}
