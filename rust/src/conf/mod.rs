//! Experiment configuration: a TOML-subset parser (serde/toml are not
//! available in this offline environment) plus the typed
//! [`ExperimentConfig`] all binaries and benches share.
//!
//! Supported syntax: `[section]` headers, `key = value` with integer,
//! float, boolean, `"string"` and flat `[v1, v2, …]` array values, `#`
//! comments. That covers every config this project ships (see
//! `configs/example.toml` for a fully commented reference file).
//!
//! Errors are first-class: an unknown section or key is rejected with the
//! offending name and the accepted names, and a mistyped value is reported
//! as `[section] key: expected T, got U` — so a typo in a sweep config
//! fails loudly instead of silently running the defaults.

mod parser;

pub use parser::{parse, ConfError, Doc, Value};

use std::collections::BTreeMap;
use std::path::Path;

use crate::coding::{CodeSpec, GeneratorKind, RecoveryMode};
use crate::comm::{CodecSpec, PayloadSpec};
use crate::coordinator::checkpoint::ResumeSpec;
use crate::sim::fault::{DeadlineSpec, FaultSpec};
use crate::sim::scenario::ScenarioSpec;
use crate::tensor::SimdPolicy;
use crate::topology::{AggregationMode, AsymLinkSpec, ParticipationSpec};

/// Back-compat alias for the pre-0.2 closed scheme enum. New code should
/// use the open [`crate::schemes::Scheme`] trait (or
/// [`crate::schemes::SchemeSpec`] where a serialisable description is
/// needed); the variant names and `label()` strings are unchanged.
pub use crate::schemes::SchemeSpec as Scheme;

/// Everything one training experiment needs; `Default` is the repo's
/// reduced "default" scale (see python/compile/shapes.py — the two must
/// agree; the artifact manifest is checked at runtime on the PJRT path).
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Root RNG seed; every stochastic object derives from it.
    pub seed: u64,
    /// Number of clients n.
    pub clients: usize,
    /// Raw feature dim d.
    pub dim: usize,
    /// RFF dimension q.
    pub q: usize,
    /// Classes c.
    pub classes: usize,
    /// RBF kernel width σ.
    pub sigma: f64,
    /// Per-client local mini-batch rows ℓ_j.
    pub local_batch: usize,
    /// Global mini-batches per epoch (m = clients · local_batch per step).
    pub steps_per_epoch: usize,
    /// Total epochs.
    pub epochs: usize,
    /// Initial learning rate (paper: 6).
    pub lr: f64,
    /// Step-decay factor (paper: 0.8)…
    pub lr_decay: f64,
    /// …applied at these epochs (paper: 40, 65).
    pub lr_decay_epochs: Vec<usize>,
    /// L2 regularisation λ (paper: 9e-6).
    pub l2: f64,
    /// Evaluate (full-test-set predict + loss probe) every `eval_every`
    /// rounds (≥ 1; the final round is always evaluated). Telemetry only —
    /// training math is unaffected.
    pub eval_every: usize,
    /// Coordinator deadline (`[training] deadline` / `--deadline`):
    /// `none` (default — bit-identical to the open-ended coordinator),
    /// `quantile:q=…` (close each round at the q-quantile of surviving
    /// arrivals) or `fixed:t=…` (a hard per-round wall-clock cut).
    /// Deadline-missing clients are resolved through the engine's
    /// degradation ladder (see `coordinator::engine`).
    pub deadline: DeadlineSpec,
    /// Native-backend worker threads (0 = available parallelism; capped
    /// at 512 by the runtime). Sizes the persistent worker pool spawned
    /// once per session — workers park between rounds, nothing spawns
    /// per call. Results are identical for every value; 1 reproduces the
    /// serial executor.
    pub threads: usize,
    /// Native-backend SIMD microkernel policy: `auto` (detect AVX2+FMA /
    /// NEON once at session construction; deterministic per ISA, ≤ 1e-4
    /// from scalar) or `scalar` (the bit-exact reproducibility anchor —
    /// identical to the pre-SIMD backend for every thread count).
    pub simd: SimdPolicy,
    /// Per-round network scenario applied to the fleet (`[scenario]`
    /// section / `--scenario`): `static` (default — bit-identical to the
    /// fixed-fleet behaviour), `dropout:rate=…`, `fading:depth=…,period=…`
    /// or `burst:slow=…,factor=…`. Every scheme on a session sees the
    /// same scenario realisation, so comparisons stay fair.
    pub scenario: ScenarioSpec,
    /// Fault injection (`[faults]` section / `--faults`): `none`
    /// (default — bit-identical to the fault-free engine),
    /// `crash:rate=…`, `link:rate=…,retry=…`, `parity:rate=…` or
    /// `mixed:crash=…,link=…,parity=…`. Faults compose with every
    /// scenario and draw from their own RNG stream, so fault-free
    /// histories are untouched.
    pub faults: FaultSpec,
    /// Asymmetric downlink/uplink link overrides (`[fleet]` section):
    /// per-leg multipliers on the §V-A τ ladder plus per-leg erasure
    /// probabilities. `None` (default) keeps the paper's reciprocal
    /// links. The exact per-leg model drives the round timeline; the
    /// load-allocation optimizer sees each client's reciprocal surrogate
    /// with matched mean communication delay.
    pub fleet_asym: Option<AsymLinkSpec>,
    /// Simulated fleet size N (`[fleet] n` / `--fleet-n`): `None`
    /// (default) keeps the fleet at `clients`; `Some(N ≥ clients)` runs
    /// a ladder-tiled mega-fleet of N clients whose data shards tile the
    /// `clients` training shards (`g % clients`). Pair with sampled
    /// participation — per-round cost scales with the roster, not N.
    pub fleet_n: Option<usize>,
    /// Per-round participation (`[fleet] participation` /
    /// `--participation`): `full` (default; bit-identical to the
    /// pre-participation engine) or `sample:k=…` — a fresh seeded,
    /// scheme-independent uniform sample of k clients per round.
    pub participation: ParticipationSpec,
    /// Clients per lazily-built fleet shard arena (`[fleet] shard_size`).
    /// Storage granularity only: the fleet's parameters are identical
    /// for every value.
    pub shard_size: usize,
    /// Gradient fold mode (`[fleet] aggregation` / `--aggregation`):
    /// `flat` (default; the historical sequential plan-order fold) or
    /// `hier:shard=…` — per-shard partial sums on the worker pool before
    /// the root fold, in a documented thread-invariant order.
    pub aggregation: AggregationMode,
    /// Max parity rows the server can process (u_max, AOT-compiled shape).
    pub u_max: usize,
    /// Generator matrix distribution.
    pub generator: GeneratorKind,
    /// Erasure code over client gradient shards (`[coding] code` /
    /// `--code`): `dense` (the paper's random generator, default) or
    /// `rateless[:overhead=ρ]` (systematic GF(256) fountain code). Only
    /// consulted by the coded scheme.
    pub code: CodeSpec,
    /// How the coded scheme recovers from stragglers (`[coding] recovery`
    /// / `--recovery`): `expectation` (the paper's parity-dataset
    /// gradient, default) or `exact` (stop at the first decodable arrival
    /// subset and reconstruct the full-fleet gradient bit-exactly).
    pub recovery: RecoveryMode,
    /// Write a crash-consistent checkpoint every this many rounds
    /// (`[checkpoint] every` / `--checkpoint-every`); 0 (default)
    /// disables periodic checkpointing. Any positive value also writes a
    /// final snapshot at graceful shutdown. Telemetry/durability only:
    /// the realized training history is identical for every value.
    pub checkpoint_every: usize,
    /// Checkpoint file path (`[checkpoint] path` / `--checkpoint-path`).
    /// `None` (default) derives `checkpoint_<scheme-tag>.ckpt` under
    /// `artifacts_dir`, so concurrent schemes never clobber each other.
    pub checkpoint_path: Option<String>,
    /// How a run starts relative to an existing checkpoint
    /// (`[checkpoint] resume` / `--resume`): `off` (default), `auto`
    /// (resume if the checkpoint file exists) or `path:<p>` (resume from
    /// exactly that file, failing if missing or invalid). A resumed run
    /// is bit-identical to the uninterrupted one.
    pub resume: ResumeSpec,
    /// Uplink gradient codec (`[comm] codec` / `--codec` / builder
    /// `.codec(...)`): `none` (default — 32-bit scalars, bit-identical
    /// to historical runs), `q8[:scale=auto|σ]` (per-row affine int8) or
    /// `bitpack` (4-bit nibble-packed). The engine transcodes each
    /// arrived gradient through the codec before the fold, and the
    /// payload model reprices the uplink accordingly.
    pub codec: CodecSpec,
    /// How modelled payload bytes follow the codec (`[comm] payload`):
    /// `auto` (default — per-leg byte scales derived from the codec),
    /// `fixed` (keep historical 32-bit pricing, isolating the codec's
    /// training effect) or `scale:down=…,up=…,parity=…` (explicit
    /// multipliers).
    pub payload: PayloadSpec,
    /// Train set size (m_total = train points across all clients).
    pub train_size: usize,
    /// Test set size.
    pub test_size: usize,
    /// Artifacts directory.
    pub artifacts_dir: String,
    /// Dataset family: "mnist" | "fashion" (synthetic stand-ins unless IDX
    /// files are present under data/<family>/).
    pub dataset: String,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            seed: 0xC0DE_DFED,
            clients: 30,
            dim: 784,
            q: 512,
            classes: 10,
            sigma: 5.0,
            local_batch: 200,
            steps_per_epoch: 5,
            epochs: 70,
            lr: 6.0,
            lr_decay: 0.8,
            lr_decay_epochs: vec![40, 65],
            l2: 9e-6,
            eval_every: 1,
            deadline: DeadlineSpec::None,
            threads: 0,
            simd: SimdPolicy::Auto,
            scenario: ScenarioSpec::Static,
            faults: FaultSpec::None,
            fleet_asym: None,
            fleet_n: None,
            participation: ParticipationSpec::Full,
            shard_size: 1024,
            aggregation: AggregationMode::Flat,
            u_max: 1536,
            generator: GeneratorKind::Normal,
            code: CodeSpec::Dense,
            recovery: RecoveryMode::Expectation,
            checkpoint_every: 0,
            checkpoint_path: None,
            resume: ResumeSpec::Off,
            codec: CodecSpec::None,
            payload: PayloadSpec::Auto,
            train_size: 30_000,
            test_size: 2_000,
            artifacts_dir: "artifacts".into(),
            dataset: "mnist".into(),
        }
    }
}

/// Accepted sections and keys — the single source of truth for unknown-key
/// rejection (and for `configs/example.toml`, which documents all of them).
const KNOWN_KEYS: &[(&str, &[&str])] = &[
    (
        "experiment",
        &["seed", "clients", "dataset", "artifacts_dir", "train_size", "test_size"],
    ),
    ("model", &["dim", "q", "classes", "sigma"]),
    (
        "training",
        &[
            "local_batch",
            "steps_per_epoch",
            "epochs",
            "lr",
            "lr_decay",
            "lr_decay_epochs",
            "l2",
            "eval_every",
            "deadline",
        ],
    ),
    ("coding", &["u_max", "generator", "code", "recovery"]),
    ("comm", &["codec", "payload"]),
    ("checkpoint", &["every", "path", "resume"]),
    ("runtime", &["threads", "simd"]),
    ("scenario", &["kind"]),
    ("faults", &["kind"]),
    (
        "fleet",
        &["tau_down", "tau_up", "p_down", "p_up", "n", "participation", "shard_size", "aggregation"],
    ),
];

impl ExperimentConfig {
    /// The paper's full §V-A scale (requires `--preset paper` artifacts).
    pub fn paper() -> Self {
        ExperimentConfig {
            q: 2000,
            local_batch: 400,
            u_max: 3072,
            train_size: 60_000,
            test_size: 10_000,
            ..Default::default()
        }
    }

    /// Tiny smoke scale used by integration tests.
    pub fn tiny() -> Self {
        ExperimentConfig {
            clients: 5,
            dim: 32,
            q: 64,
            local_batch: 40,
            steps_per_epoch: 2,
            epochs: 4,
            lr_decay_epochs: vec![3],
            u_max: 128,
            train_size: 400,
            test_size: 200,
            dataset: "easy".into(),
            ..Default::default()
        }
    }

    /// Resolve a named preset (`tiny` | `default` | `paper`).
    pub fn preset(name: &str) -> Result<Self, ConfError> {
        match name {
            "tiny" => Ok(Self::tiny()),
            "default" => Ok(Self::default()),
            "paper" => Ok(Self::paper()),
            other => Err(ConfError::Invalid(format!(
                "unknown preset {other:?} (expected tiny, default or paper)"
            ))),
        }
    }

    /// Global mini-batch size m per step.
    pub fn global_batch(&self) -> usize {
        self.clients * self.local_batch
    }

    /// Total training iterations.
    pub fn total_iters(&self) -> usize {
        self.epochs * self.steps_per_epoch
    }

    /// Simulated fleet size N (`fleet_n`, defaulting to `clients`).
    pub fn fleet_size(&self) -> usize {
        self.fleet_n.unwrap_or(self.clients)
    }

    /// Whether rounds run over a sampled/mega-fleet roster instead of the
    /// historical one-view-per-client path. `false` (the default config)
    /// keeps the engine on the exact pre-participation code path.
    pub fn roster_mode(&self) -> bool {
        self.fleet_n.is_some() || self.participation != ParticipationSpec::Full
    }

    /// Learning rate at (0-based) epoch `e` (step decay, §V-A).
    pub fn lr_at_epoch(&self, e: usize) -> f64 {
        let decays = self.lr_decay_epochs.iter().filter(|&&d| e >= d).count();
        self.lr * self.lr_decay.powi(decays as i32)
    }

    /// Load from a TOML-subset file, overriding defaults.
    pub fn from_file(path: &Path) -> Result<Self, ConfError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| ConfError::Io(format!("{path:?}: {e}")))?;
        Self::from_str_conf(&text)
    }

    /// Parse from config text, overriding defaults. Rejects unknown
    /// sections/keys and reports mistyped values as `[section] key: …`.
    pub fn from_str_conf(text: &str) -> Result<Self, ConfError> {
        let doc = parse(text)?;
        reject_unknown_keys(&doc)?;
        let mut c = ExperimentConfig::default();
        let empty = BTreeMap::new();
        let sect = |name: &'static str| Sect { name, map: doc.get(name).unwrap_or(&empty) };

        let exp = sect("experiment");
        exp.get_u64("seed", &mut c.seed)?;
        exp.get_usize("clients", &mut c.clients)?;
        exp.get_string("dataset", &mut c.dataset)?;
        exp.get_string("artifacts_dir", &mut c.artifacts_dir)?;
        exp.get_usize("train_size", &mut c.train_size)?;
        exp.get_usize("test_size", &mut c.test_size)?;

        let model = sect("model");
        model.get_usize("dim", &mut c.dim)?;
        model.get_usize("q", &mut c.q)?;
        model.get_usize("classes", &mut c.classes)?;
        model.get_f64("sigma", &mut c.sigma)?;

        let tr = sect("training");
        tr.get_usize("local_batch", &mut c.local_batch)?;
        tr.get_usize("steps_per_epoch", &mut c.steps_per_epoch)?;
        tr.get_usize("epochs", &mut c.epochs)?;
        tr.get_f64("lr", &mut c.lr)?;
        tr.get_f64("lr_decay", &mut c.lr_decay)?;
        tr.get_f64("l2", &mut c.l2)?;
        tr.get_usize("eval_every", &mut c.eval_every)?;
        tr.get_usize_array("lr_decay_epochs", &mut c.lr_decay_epochs)?;
        if let Some(v) = tr.map.get("deadline") {
            let s = v.as_str().ok_or_else(|| tr.bad("deadline", "string", v))?;
            c.deadline = s
                .parse()
                .map_err(|e: String| ConfError::Invalid(format!("[training] deadline: {e}")))?;
        }

        let cod = sect("coding");
        cod.get_usize("u_max", &mut c.u_max)?;
        if let Some(v) = cod.map.get("generator") {
            let s = v.as_str().ok_or_else(|| cod.bad("generator", "string", v))?;
            c.generator = s
                .parse()
                .map_err(|e: String| ConfError::Invalid(format!("[coding] generator: {e}")))?;
        }
        if let Some(v) = cod.map.get("code") {
            let s = v.as_str().ok_or_else(|| cod.bad("code", "string", v))?;
            c.code = s
                .parse()
                .map_err(|e: String| ConfError::Invalid(format!("[coding] code: {e}")))?;
        }
        if let Some(v) = cod.map.get("recovery") {
            let s = v.as_str().ok_or_else(|| cod.bad("recovery", "string", v))?;
            c.recovery = s
                .parse()
                .map_err(|e: String| ConfError::Invalid(format!("[coding] recovery: {e}")))?;
        }

        let cm = sect("comm");
        if let Some(v) = cm.map.get("codec") {
            let s = v.as_str().ok_or_else(|| cm.bad("codec", "string", v))?;
            c.codec = s
                .parse()
                .map_err(|e: String| ConfError::Invalid(format!("[comm] codec: {e}")))?;
        }
        if let Some(v) = cm.map.get("payload") {
            let s = v.as_str().ok_or_else(|| cm.bad("payload", "string", v))?;
            c.payload = s
                .parse()
                .map_err(|e: String| ConfError::Invalid(format!("[comm] payload: {e}")))?;
        }

        let ck = sect("checkpoint");
        ck.get_usize("every", &mut c.checkpoint_every)?;
        if let Some(v) = ck.map.get("path") {
            let s = v.as_str().ok_or_else(|| ck.bad("path", "string", v))?;
            c.checkpoint_path = Some(s.to_string());
        }
        if let Some(v) = ck.map.get("resume") {
            let s = v.as_str().ok_or_else(|| ck.bad("resume", "string", v))?;
            c.resume = s
                .parse()
                .map_err(|e: String| ConfError::Invalid(format!("[checkpoint] resume: {e}")))?;
        }

        let rtc = sect("runtime");
        rtc.get_usize("threads", &mut c.threads)?;
        if let Some(v) = rtc.map.get("simd") {
            let s = v.as_str().ok_or_else(|| rtc.bad("simd", "string", v))?;
            c.simd = s
                .parse()
                .map_err(|e: String| ConfError::Invalid(format!("[runtime] simd: {e}")))?;
        }

        let sc = sect("scenario");
        if let Some(v) = sc.map.get("kind") {
            let s = v.as_str().ok_or_else(|| sc.bad("kind", "string", v))?;
            c.scenario = s
                .parse()
                .map_err(|e: String| ConfError::Invalid(format!("[scenario] kind: {e}")))?;
        }

        let fa = sect("faults");
        if let Some(v) = fa.map.get("kind") {
            let s = v.as_str().ok_or_else(|| fa.bad("kind", "string", v))?;
            c.faults = s
                .parse()
                .map_err(|e: String| ConfError::Invalid(format!("[faults] kind: {e}")))?;
        }

        // Any asym [fleet] key switches the fleet to the asymmetric
        // per-leg link model; omitted keys keep the reciprocal-equivalent
        // defaults (unit τ multipliers, the paper's p = 0.1). The
        // scale-out keys (n, participation, shard_size, aggregation) do
        // NOT trigger the asym model.
        let fl = sect("fleet");
        if ["tau_down", "tau_up", "p_down", "p_up"]
            .iter()
            .any(|k| fl.map.contains_key(*k))
        {
            let mut a = AsymLinkSpec::default();
            fl.get_f64("tau_down", &mut a.tau_down)?;
            fl.get_f64("tau_up", &mut a.tau_up)?;
            fl.get_f64("p_down", &mut a.p_down)?;
            fl.get_f64("p_up", &mut a.p_up)?;
            c.fleet_asym = Some(a);
        }
        if let Some(i) = fl.get_nonneg("n")? {
            c.fleet_n = Some(i as usize);
        }
        if let Some(v) = fl.map.get("participation") {
            let s = v.as_str().ok_or_else(|| fl.bad("participation", "string", v))?;
            c.participation = s
                .parse()
                .map_err(|e: String| ConfError::Invalid(format!("[fleet] participation: {e}")))?;
        }
        fl.get_usize("shard_size", &mut c.shard_size)?;
        if let Some(v) = fl.map.get("aggregation") {
            let s = v.as_str().ok_or_else(|| fl.bad("aggregation", "string", v))?;
            c.aggregation = s
                .parse()
                .map_err(|e: String| ConfError::Invalid(format!("[fleet] aggregation: {e}")))?;
        }
        c.validate()?;
        Ok(c)
    }

    pub fn validate(&self) -> Result<(), ConfError> {
        if self.clients == 0 {
            return Err(ConfError::Invalid("clients must be > 0".into()));
        }
        if self.train_size % self.clients != 0 {
            return Err(ConfError::Invalid(format!(
                "train_size {} must divide evenly across {} clients",
                self.train_size, self.clients
            )));
        }
        let per_client = self.train_size / self.clients;
        if per_client % self.local_batch != 0 {
            return Err(ConfError::Invalid(format!(
                "per-client shard {per_client} must be a multiple of local_batch {}",
                self.local_batch
            )));
        }
        if !(self.lr > 0.0) || !(self.lr_decay > 0.0) {
            return Err(ConfError::Invalid("lr and lr_decay must be > 0".into()));
        }
        if self.u_max == 0 {
            return Err(ConfError::Invalid(
                "u_max must be > 0 (coding redundancy provides feasibility slack)".into(),
            ));
        }
        if self.eval_every == 0 {
            return Err(ConfError::Invalid(
                "eval_every must be >= 1 (1 = evaluate every round)".into(),
            ));
        }
        self.code
            .validate()
            .map_err(|e| ConfError::Invalid(format!("[coding] code: {e}")))?;
        self.scenario
            .validate()
            .map_err(|e| ConfError::Invalid(format!("[scenario] kind: {e}")))?;
        self.faults
            .validate()
            .map_err(|e| ConfError::Invalid(format!("[faults] kind: {e}")))?;
        self.deadline
            .validate()
            .map_err(|e| ConfError::Invalid(format!("[training] deadline: {e}")))?;
        self.codec
            .validate()
            .map_err(|e| ConfError::Invalid(format!("[comm] codec: {e}")))?;
        self.payload
            .validate()
            .map_err(|e| ConfError::Invalid(format!("[comm] payload: {e}")))?;
        if let Some(a) = &self.fleet_asym {
            a.validate().map_err(|e| ConfError::Invalid(format!("[fleet] {e}")))?;
        }
        if let Some(n) = self.fleet_n {
            if n < self.clients {
                return Err(ConfError::Invalid(format!(
                    "[fleet] n: fleet size {n} must be >= clients {} (data shards tile the \
                     training shards)",
                    self.clients
                )));
            }
        }
        if self.shard_size == 0 {
            return Err(ConfError::Invalid(
                "[fleet] shard_size: must be >= 1 client per shard".into(),
            ));
        }
        self.participation
            .validate(self.fleet_size())
            .map_err(|e| ConfError::Invalid(format!("[fleet] participation: {e}")))?;
        // Exact recovery packs every client's gradient as a code source
        // symbol — it is defined over the full fixed fleet, not a
        // per-round roster.
        if self.recovery == RecoveryMode::Exact && self.roster_mode() {
            return Err(ConfError::Invalid(format!(
                "[coding] recovery: exact recovery requires the full fixed fleet — drop \
                 [fleet] n / participation (got participation = \"{}\", fleet n = {})",
                self.participation.label(),
                self.fleet_size()
            )));
        }
        // Exact recovery erasure-decodes missing gradients from the
        // arrived symbols; a corrupted (excluded-as-zero) source symbol
        // would decode into the wrong full-fleet aggregate, silently.
        if self.recovery == RecoveryMode::Exact {
            if let FaultSpec::Corrupt { rate } = self.faults {
                if rate > 0.0 {
                    return Err(ConfError::Invalid(format!(
                        "[faults] kind: corrupt(rate={rate}) cannot combine with \
                         [coding] recovery = \"exact\" — exact decode would reconstruct \
                         from corrupted source symbols (expected one of recovery = \
                         \"expectation\" | faults without corrupt)"
                    )));
                }
            }
        }
        if let ResumeSpec::Path(p) = &self.resume {
            if p.trim().is_empty() {
                return Err(ConfError::Invalid(
                    "[checkpoint] resume: \"path:\" names no file (expected path:<file>)".into(),
                ));
            }
        }
        if let Some(p) = &self.checkpoint_path {
            if p.trim().is_empty() {
                return Err(ConfError::Invalid(
                    "[checkpoint] path: must name a file (or omit the key for the \
                     artifacts-dir default)"
                        .into(),
                ));
            }
        }
        Ok(())
    }
}

/// Fail on any section or key the schema does not know, naming both the
/// stray name and the accepted ones (SNIPPETS.md config pattern: a typo'd
/// key must error, not silently fall back to a default).
fn reject_unknown_keys(doc: &Doc) -> Result<(), ConfError> {
    for (section, keys) in doc {
        if section.is_empty() {
            let first = keys.keys().next().map(String::as_str).unwrap_or("?");
            return Err(ConfError::Invalid(format!(
                "key `{first}` appears before any [section] header \
                 (sections: experiment, model, training, coding, comm, checkpoint, \
                 runtime, scenario, faults, fleet)"
            )));
        }
        let Some((_, known)) = KNOWN_KEYS.iter().find(|(s, _)| s == section) else {
            return Err(ConfError::Invalid(format!(
                "unknown section [{section}] (expected one of: experiment, model, \
                 training, coding, comm, checkpoint, runtime, scenario, faults, fleet)"
            )));
        };
        for key in keys.keys() {
            if !known.contains(&key.as_str()) {
                return Err(ConfError::Invalid(format!(
                    "unknown key `{key}` in [{section}] (known keys: {})",
                    known.join(", ")
                )));
            }
        }
    }
    Ok(())
}

/// One section's typed readers; every error names `[section] key`.
struct Sect<'a> {
    name: &'static str,
    map: &'a BTreeMap<String, Value>,
}

impl Sect<'_> {
    fn bad(&self, key: &str, want: &str, got: &Value) -> ConfError {
        ConfError::Invalid(format!(
            "[{}] {key}: expected {want}, got {}",
            self.name,
            got.type_name()
        ))
    }

    /// The validated non-negative integer at `key`, if present.
    fn get_nonneg(&self, key: &str) -> Result<Option<i64>, ConfError> {
        match self.map.get(key) {
            None => Ok(None),
            Some(v) => {
                let i = v.as_int().ok_or_else(|| self.bad(key, "int", v))?;
                if i < 0 {
                    return Err(self.bad(key, "non-negative int", v));
                }
                Ok(Some(i))
            }
        }
    }

    fn get_u64(&self, key: &str, out: &mut u64) -> Result<(), ConfError> {
        if let Some(i) = self.get_nonneg(key)? {
            *out = i as u64;
        }
        Ok(())
    }

    fn get_usize(&self, key: &str, out: &mut usize) -> Result<(), ConfError> {
        if let Some(i) = self.get_nonneg(key)? {
            *out = i as usize;
        }
        Ok(())
    }

    fn get_f64(&self, key: &str, out: &mut f64) -> Result<(), ConfError> {
        if let Some(v) = self.map.get(key) {
            *out = v.as_float().ok_or_else(|| self.bad(key, "float", v))?;
        }
        Ok(())
    }

    fn get_string(&self, key: &str, out: &mut String) -> Result<(), ConfError> {
        if let Some(v) = self.map.get(key) {
            *out = v.as_str().ok_or_else(|| self.bad(key, "string", v))?.to_string();
        }
        Ok(())
    }

    fn get_usize_array(&self, key: &str, out: &mut Vec<usize>) -> Result<(), ConfError> {
        if let Some(v) = self.map.get(key) {
            let arr = v.as_array().ok_or_else(|| self.bad(key, "array", v))?;
            *out = arr
                .iter()
                .map(|x| {
                    x.as_int()
                        .filter(|&i| i >= 0)
                        .map(|i| i as usize)
                        .ok_or_else(|| self.bad(key, "array of non-negative ints", x))
                })
                .collect::<Result<_, _>>()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        ExperimentConfig::default().validate().unwrap();
        ExperimentConfig::tiny().validate().unwrap();
        ExperimentConfig::paper().validate().unwrap();
    }

    #[test]
    fn preset_lookup() {
        assert_eq!(ExperimentConfig::preset("tiny").unwrap().clients, 5);
        assert_eq!(ExperimentConfig::preset("paper").unwrap().q, 2000);
        let e = ExperimentConfig::preset("huge").unwrap_err().to_string();
        assert!(e.contains("huge") && e.contains("paper"), "{e}");
    }

    #[test]
    fn lr_schedule_matches_paper() {
        let c = ExperimentConfig::default();
        assert_eq!(c.lr_at_epoch(0), 6.0);
        assert_eq!(c.lr_at_epoch(39), 6.0);
        assert!((c.lr_at_epoch(40) - 4.8).abs() < 1e-12);
        assert!((c.lr_at_epoch(65) - 3.84).abs() < 1e-12);
    }

    #[test]
    fn parses_full_config() {
        let text = r#"
# experiment file
[experiment]
seed = 7
clients = 10
dataset = "fashion"
train_size = 2000
test_size = 500

[model]
dim = 64
q = 128
classes = 10
sigma = 3.5

[training]
local_batch = 100
steps_per_epoch = 2
epochs = 30
lr = 2.5
lr_decay = 0.5
lr_decay_epochs = [10, 20]
l2 = 0.001

[coding]
u_max = 256
generator = "rademacher"
"#;
        let c = ExperimentConfig::from_str_conf(text).unwrap();
        assert_eq!(c.seed, 7);
        assert_eq!(c.clients, 10);
        assert_eq!(c.dataset, "fashion");
        assert_eq!(c.q, 128);
        assert!((c.sigma - 3.5).abs() < 1e-12);
        assert_eq!(c.lr_decay_epochs, vec![10, 20]);
        assert_eq!(c.generator, GeneratorKind::Rademacher);
        assert_eq!(c.global_batch(), 1000);
        assert_eq!(c.total_iters(), 60);
    }

    #[test]
    fn eval_every_and_threads_parse_and_validate() {
        let c = ExperimentConfig::from_str_conf(
            "[training]\neval_every = 5\n\n[runtime]\nthreads = 4\n",
        )
        .unwrap();
        assert_eq!(c.eval_every, 5);
        assert_eq!(c.threads, 4);
        // defaults: evaluate every round, auto thread count
        let d = ExperimentConfig::default();
        assert_eq!(d.eval_every, 1);
        assert_eq!(d.threads, 0);
        // eval_every = 0 is rejected with its name
        let e = ExperimentConfig::from_str_conf("[training]\neval_every = 0\n")
            .unwrap_err()
            .to_string();
        assert!(e.contains("eval_every"), "{e}");
        // threads = 0 (auto) is valid
        assert!(ExperimentConfig::from_str_conf("[runtime]\nthreads = 0\n").is_ok());
    }

    #[test]
    fn simd_policy_parses_and_rejects_bad_values() {
        assert_eq!(ExperimentConfig::default().simd, SimdPolicy::Auto);
        let c = ExperimentConfig::from_str_conf("[runtime]\nsimd = \"scalar\"\n").unwrap();
        assert_eq!(c.simd, SimdPolicy::Scalar);
        let c = ExperimentConfig::from_str_conf("[runtime]\nsimd = \"auto\"\n").unwrap();
        assert_eq!(c.simd, SimdPolicy::Auto);
        // unknown policy names the key and lists the accepted values
        let e = ExperimentConfig::from_str_conf("[runtime]\nsimd = \"avx9\"\n")
            .unwrap_err()
            .to_string();
        assert!(e.contains("simd") && e.contains("avx9") && e.contains("scalar"), "{e}");
        // mistyped value names section and key
        let e = ExperimentConfig::from_str_conf("[runtime]\nsimd = 2\n").unwrap_err().to_string();
        assert!(e.contains("[runtime]") && e.contains("simd"), "{e}");
    }

    #[test]
    fn scenario_kind_parses_defaults_and_rejects_garbage() {
        assert_eq!(ExperimentConfig::default().scenario, ScenarioSpec::Static);
        let c = ExperimentConfig::from_str_conf("[scenario]\nkind = \"dropout:rate=0.2\"\n")
            .unwrap();
        assert_eq!(c.scenario, ScenarioSpec::Dropout { rate: 0.2 });
        let c = ExperimentConfig::from_str_conf(
            "[scenario]\nkind = \"fading:depth=0.4,period=16\"\n",
        )
        .unwrap();
        assert_eq!(c.scenario, ScenarioSpec::Fading { depth: 0.4, period: 16.0 });
        // unknown kind names the section and the offender
        let e = ExperimentConfig::from_str_conf("[scenario]\nkind = \"chaos\"\n")
            .unwrap_err()
            .to_string();
        assert!(e.contains("[scenario]") && e.contains("chaos"), "{e}");
        // out-of-range parameter is rejected with its name
        let e = ExperimentConfig::from_str_conf("[scenario]\nkind = \"dropout:rate=1.5\"\n")
            .unwrap_err()
            .to_string();
        assert!(e.contains("rate"), "{e}");
        // mistyped value names section and key
        let e = ExperimentConfig::from_str_conf("[scenario]\nkind = 3\n")
            .unwrap_err()
            .to_string();
        assert!(e.contains("[scenario]") && e.contains("kind"), "{e}");
        // unknown key in [scenario] is rejected
        let e = ExperimentConfig::from_str_conf("[scenario]\nmode = \"static\"\n")
            .unwrap_err()
            .to_string();
        assert!(e.contains("mode") && e.contains("kind"), "{e}");
    }

    #[test]
    fn faults_kind_parses_defaults_and_rejects_garbage() {
        assert_eq!(ExperimentConfig::default().faults, FaultSpec::None);
        let c = ExperimentConfig::from_str_conf("[faults]\nkind = \"crash:rate=0.3\"\n").unwrap();
        assert_eq!(c.faults, FaultSpec::Crash { rate: 0.3 });
        let c = ExperimentConfig::from_str_conf("[faults]\nkind = \"link:rate=0.2,retry=2\"\n")
            .unwrap();
        assert_eq!(c.faults, FaultSpec::Link { rate: 0.2, retry: 2 });
        // unknown kind names the section and the offender
        let e = ExperimentConfig::from_str_conf("[faults]\nkind = \"meteor\"\n")
            .unwrap_err()
            .to_string();
        assert!(e.contains("[faults]") && e.contains("meteor"), "{e}");
        assert!(e.contains("expected one of"), "{e}");
        // out-of-range rate is rejected at build time with its name
        let e = ExperimentConfig::from_str_conf("[faults]\nkind = \"crash:rate=1.5\"\n")
            .unwrap_err()
            .to_string();
        assert!(e.contains("rate") && e.contains("[faults]"), "{e}");
        // mistyped value names section and key
        let e = ExperimentConfig::from_str_conf("[faults]\nkind = 3\n").unwrap_err().to_string();
        assert!(e.contains("[faults]") && e.contains("kind"), "{e}");
    }

    #[test]
    fn deadline_parses_defaults_and_rejects_out_of_range() {
        assert_eq!(ExperimentConfig::default().deadline, DeadlineSpec::None);
        let c = ExperimentConfig::from_str_conf("[training]\ndeadline = \"quantile:q=0.8\"\n")
            .unwrap();
        assert_eq!(c.deadline, DeadlineSpec::Quantile { q: 0.8 });
        let c = ExperimentConfig::from_str_conf("[training]\ndeadline = \"fixed:t=12.5\"\n")
            .unwrap();
        assert_eq!(c.deadline, DeadlineSpec::Fixed { t: 12.5 });
        // q outside (0,1] is rejected at build time, naming section + key
        let e = ExperimentConfig::from_str_conf("[training]\ndeadline = \"quantile:q=1.5\"\n")
            .unwrap_err()
            .to_string();
        assert!(e.contains("[training] deadline") && e.contains("q=1.5"), "{e}");
        // t <= 0 likewise
        let e = ExperimentConfig::from_str_conf("[training]\ndeadline = \"fixed:t=0\"\n")
            .unwrap_err()
            .to_string();
        assert!(e.contains("[training] deadline") && e.contains('t'), "{e}");
        // unknown kind lists the accepted forms
        let e = ExperimentConfig::from_str_conf("[training]\ndeadline = \"soon\"\n")
            .unwrap_err()
            .to_string();
        assert!(e.contains("soon") && e.contains("expected one of"), "{e}");
    }

    #[test]
    fn fleet_asym_round_trips_through_config() {
        // Full [fleet] section round-trips into the typed spec…
        let text = "[fleet]\ntau_down = 1.5\ntau_up = 3.0\np_down = 0.05\np_up = 0.2\n";
        let a = ExperimentConfig::from_str_conf(text).unwrap().fleet_asym.unwrap();
        assert_eq!(a, AsymLinkSpec { tau_down: 1.5, tau_up: 3.0, p_down: 0.05, p_up: 0.2 });
        // …a partial section fills the reciprocal-equivalent defaults…
        let a = ExperimentConfig::from_str_conf("[fleet]\ntau_up = 2.0\n")
            .unwrap()
            .fleet_asym
            .unwrap();
        assert_eq!(a, AsymLinkSpec { tau_up: 2.0, ..AsymLinkSpec::default() });
        // …no [fleet] section keeps the symmetric model…
        assert!(ExperimentConfig::default().fleet_asym.is_none());
        assert!(ExperimentConfig::from_str_conf("").unwrap().fleet_asym.is_none());
        // …and invalid values are rejected naming the section.
        let e = ExperimentConfig::from_str_conf("[fleet]\np_up = 1.0\n")
            .unwrap_err()
            .to_string();
        assert!(e.contains("[fleet]") && e.contains("p_up"), "{e}");
        let e = ExperimentConfig::from_str_conf("[fleet]\ntau_down = 0.0\n")
            .unwrap_err()
            .to_string();
        assert!(e.contains("[fleet]") && e.contains("tau_down"), "{e}");
    }

    #[test]
    fn participation_round_trips_through_config() {
        // Defaults: full participation over the clients-sized fleet.
        let d = ExperimentConfig::default();
        assert_eq!(d.participation, ParticipationSpec::Full);
        assert_eq!(d.fleet_n, None);
        assert_eq!(d.fleet_size(), d.clients);
        assert!(!d.roster_mode());
        // Full [fleet] scale-out keys round-trip into the typed config…
        let text = "[fleet]\nn = 100000\nparticipation = \"sample:k=31\"\n\
                    shard_size = 4096\naggregation = \"hier:shard=8\"\n";
        let c = ExperimentConfig::from_str_conf(text).unwrap();
        assert_eq!(c.fleet_n, Some(100_000));
        assert_eq!(c.participation, ParticipationSpec::Sample { k: 31 });
        assert_eq!(c.shard_size, 4096);
        assert_eq!(c.aggregation, AggregationMode::Hier { shard: 8 });
        assert_eq!(c.fleet_size(), 100_000);
        assert!(c.roster_mode());
        // …and the scale-out keys do NOT trigger the asym link model.
        assert!(c.fleet_asym.is_none());
        // Sampling the base fleet needs no `n`.
        let c = ExperimentConfig::from_str_conf("[fleet]\nparticipation = \"sample:k=4\"\n")
            .unwrap();
        assert_eq!(c.fleet_n, None);
        assert!(c.roster_mode());
    }

    #[test]
    fn participation_rejects_bad_k_naming_the_fleet_section() {
        // k = 0 is rejected with the section name and the accepted range.
        let e = ExperimentConfig::from_str_conf("[fleet]\nparticipation = \"sample:k=0\"\n")
            .unwrap_err()
            .to_string();
        assert!(e.contains("[fleet] participation"), "{e}");
        assert!(e.contains("k=0") && e.contains("expected one of 1..=30"), "{e}");
        // k > N likewise (default fleet is 30 clients).
        let e = ExperimentConfig::from_str_conf("[fleet]\nparticipation = \"sample:k=31\"\n")
            .unwrap_err()
            .to_string();
        assert!(e.contains("[fleet] participation") && e.contains("k=31"), "{e}");
        // …but k = 31 is fine once the fleet is big enough.
        let ok = "[fleet]\nn = 1000\nparticipation = \"sample:k=31\"\n";
        assert!(ExperimentConfig::from_str_conf(ok).is_ok());
        // Unknown participation names list the accepted forms.
        let e = ExperimentConfig::from_str_conf("[fleet]\nparticipation = \"partial\"\n")
            .unwrap_err()
            .to_string();
        assert!(e.contains("expected one of full, sample:k="), "{e}");
        // Mistyped value names section and key.
        let e = ExperimentConfig::from_str_conf("[fleet]\nparticipation = 3\n")
            .unwrap_err()
            .to_string();
        assert!(e.contains("[fleet]") && e.contains("participation"), "{e}");
    }

    #[test]
    fn fleet_scale_out_keys_validate() {
        // fleet n below clients is rejected naming the constraint.
        let e = ExperimentConfig::from_str_conf("[fleet]\nn = 7\n").unwrap_err().to_string();
        assert!(e.contains("[fleet] n") && e.contains("clients"), "{e}");
        // shard_size = 0 is rejected.
        let e = ExperimentConfig::from_str_conf("[fleet]\nshard_size = 0\n")
            .unwrap_err()
            .to_string();
        assert!(e.contains("shard_size"), "{e}");
        // Bad aggregation specs are rejected naming the section.
        let e = ExperimentConfig::from_str_conf("[fleet]\naggregation = \"tree\"\n")
            .unwrap_err()
            .to_string();
        assert!(e.contains("[fleet] aggregation") && e.contains("expected one of"), "{e}");
        let e = ExperimentConfig::from_str_conf("[fleet]\naggregation = \"hier:shard=0\"\n")
            .unwrap_err()
            .to_string();
        assert!(e.contains("[fleet] aggregation"), "{e}");
    }

    #[test]
    fn exact_recovery_rejects_rosters() {
        // Exact recovery is defined over the full fixed fleet: sampled
        // participation and mega-fleets are both rejected, naming both
        // settings involved.
        let e = ExperimentConfig::from_str_conf(
            "[coding]\nrecovery = \"exact\"\n\n[fleet]\nparticipation = \"sample:k=4\"\n",
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("[coding] recovery") && e.contains("participation"), "{e}");
        let e = ExperimentConfig::from_str_conf(
            "[coding]\nrecovery = \"exact\"\n\n[fleet]\nn = 1000\n",
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("exact"), "{e}");
        // Exact over the full fixed fleet stays accepted.
        assert!(ExperimentConfig::from_str_conf("[coding]\nrecovery = \"exact\"\n").is_ok());
    }

    #[test]
    fn checkpoint_section_parses_defaults_and_rejects_garbage() {
        // Defaults: checkpointing off, derived path, fresh start.
        let d = ExperimentConfig::default();
        assert_eq!(d.checkpoint_every, 0);
        assert_eq!(d.checkpoint_path, None);
        assert_eq!(d.resume, ResumeSpec::Off);
        // Full section round-trips into the typed config.
        let c = ExperimentConfig::from_str_conf(
            "[checkpoint]\nevery = 25\npath = \"artifacts/run.ckpt\"\nresume = \"auto\"\n",
        )
        .unwrap();
        assert_eq!(c.checkpoint_every, 25);
        assert_eq!(c.checkpoint_path.as_deref(), Some("artifacts/run.ckpt"));
        assert_eq!(c.resume, ResumeSpec::Auto);
        let c = ExperimentConfig::from_str_conf("[checkpoint]\nresume = \"path:x.ckpt\"\n")
            .unwrap();
        assert_eq!(c.resume, ResumeSpec::Path("x.ckpt".into()));
        // Unknown resume modes name the section and list the options.
        let e = ExperimentConfig::from_str_conf("[checkpoint]\nresume = \"maybe\"\n")
            .unwrap_err()
            .to_string();
        assert!(e.contains("[checkpoint] resume") && e.contains("expected one of"), "{e}");
        // Empty path forms are rejected with their names.
        let e = ExperimentConfig::from_str_conf("[checkpoint]\nresume = \"path:\"\n")
            .unwrap_err()
            .to_string();
        assert!(e.contains("path:"), "{e}");
        let e = ExperimentConfig::from_str_conf("[checkpoint]\npath = \"\"\n")
            .unwrap_err()
            .to_string();
        assert!(e.contains("[checkpoint] path"), "{e}");
        // Mistyped values name section and key; unknown keys are listed.
        let e = ExperimentConfig::from_str_conf("[checkpoint]\nevery = \"often\"\n")
            .unwrap_err()
            .to_string();
        assert!(e.contains("[checkpoint]") && e.contains("every"), "{e}");
        let e = ExperimentConfig::from_str_conf("[checkpoint]\ninterval = 5\n")
            .unwrap_err()
            .to_string();
        assert!(e.contains("interval") && e.contains("every"), "{e}");
    }

    #[test]
    fn comm_section_parses_defaults_and_rejects_garbage() {
        use crate::comm::ScaleSpec;
        // Defaults: no codec, payload follows the codec (i.e. identity).
        let d = ExperimentConfig::default();
        assert_eq!(d.codec, CodecSpec::None);
        assert_eq!(d.payload, PayloadSpec::Auto);
        // Full section round-trips into the typed config.
        let c = ExperimentConfig::from_str_conf(
            "[comm]\ncodec = \"q8:scale=auto\"\npayload = \"auto\"\n",
        )
        .unwrap();
        assert_eq!(c.codec, CodecSpec::Q8 { scale: ScaleSpec::Auto });
        assert_eq!(c.payload, PayloadSpec::Auto);
        let c = ExperimentConfig::from_str_conf("[comm]\ncodec = \"bitpack\"\n").unwrap();
        assert_eq!(c.codec, CodecSpec::Bitpack);
        let c = ExperimentConfig::from_str_conf(
            "[comm]\npayload = \"scale:up=0.25,parity=0.5\"\n",
        )
        .unwrap();
        assert_eq!(c.payload, PayloadSpec::Scale { down: 1.0, up: 0.25, parity: 0.5 });
        // Unknown codec names the section and lists the accepted forms.
        let e = ExperimentConfig::from_str_conf("[comm]\ncodec = \"zstd\"\n")
            .unwrap_err()
            .to_string();
        assert!(e.contains("[comm] codec") && e.contains("zstd"), "{e}");
        assert!(e.contains("expected one of"), "{e}");
        // Out-of-range scale is rejected with the section name.
        let e = ExperimentConfig::from_str_conf("[comm]\ncodec = \"q8:scale=-2\"\n")
            .unwrap_err()
            .to_string();
        assert!(e.contains("[comm] codec") && e.contains("scale"), "{e}");
        // Unknown payload models likewise.
        let e = ExperimentConfig::from_str_conf("[comm]\npayload = \"tiny\"\n")
            .unwrap_err()
            .to_string();
        assert!(e.contains("[comm] payload") && e.contains("expected one of"), "{e}");
        // Mistyped value names section and key; unknown keys are listed.
        let e = ExperimentConfig::from_str_conf("[comm]\ncodec = 8\n").unwrap_err().to_string();
        assert!(e.contains("[comm]") && e.contains("codec"), "{e}");
        let e = ExperimentConfig::from_str_conf("[comm]\ncompression = \"q8\"\n")
            .unwrap_err()
            .to_string();
        assert!(e.contains("compression") && e.contains("codec"), "{e}");
    }

    #[test]
    fn corrupt_faults_cannot_combine_with_exact_recovery() {
        let e = ExperimentConfig::from_str_conf(
            "[coding]\nrecovery = \"exact\"\n\n[faults]\nkind = \"corrupt:rate=0.5\"\n",
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("corrupt") && e.contains("exact"), "{e}");
        // Each alone is fine.
        assert!(ExperimentConfig::from_str_conf("[faults]\nkind = \"corrupt:rate=0.5\"\n")
            .is_ok());
        assert!(ExperimentConfig::from_str_conf("[coding]\nrecovery = \"exact\"\n").is_ok());
        // server faults parse through the config path.
        let c = ExperimentConfig::from_str_conf("[faults]\nkind = \"server:rate=0.2\"\n")
            .unwrap();
        assert_eq!(c.faults, FaultSpec::Server { rate: 0.2 });
    }

    #[test]
    fn rejects_inconsistent_partition() {
        let text = "[experiment]\nclients = 7\ntrain_size = 100\n";
        assert!(ExperimentConfig::from_str_conf(text).is_err());
    }

    #[test]
    fn rejects_bad_generator() {
        let text = "[coding]\ngenerator = \"foo\"\n";
        let e = ExperimentConfig::from_str_conf(text).unwrap_err().to_string();
        assert!(e.contains("generator"), "{e}");
    }

    #[test]
    fn code_and_recovery_parse_defaults_and_reject_garbage() {
        let d = ExperimentConfig::default();
        assert_eq!(d.code, CodeSpec::Dense);
        assert_eq!(d.recovery, RecoveryMode::Expectation);
        let c = ExperimentConfig::from_str_conf(
            "[coding]\ncode = \"rateless:overhead=0.75\"\nrecovery = \"exact\"\n",
        )
        .unwrap();
        assert_eq!(c.code, CodeSpec::Rateless { overhead: 0.75 });
        assert_eq!(c.recovery, RecoveryMode::Exact);
        // Case variants parse like the other spec strings.
        let c = ExperimentConfig::from_str_conf(
            "[coding]\ncode = \"Dense\"\nrecovery = \"Expectation\"\n",
        )
        .unwrap();
        assert_eq!(c.code, CodeSpec::Dense);
        assert_eq!(c.recovery, RecoveryMode::Expectation);
        // Unknown values name the section/key and list the options.
        let e = ExperimentConfig::from_str_conf("[coding]\ncode = \"fountain\"\n")
            .unwrap_err()
            .to_string();
        assert!(e.contains("[coding] code") && e.contains("expected one of"), "{e}");
        let e = ExperimentConfig::from_str_conf("[coding]\nrecovery = \"precise\"\n")
            .unwrap_err()
            .to_string();
        assert!(e.contains("[coding] recovery") && e.contains("expectation"), "{e}");
        // Out-of-range overhead is rejected by validate, naming the key.
        let e = ExperimentConfig::from_str_conf("[coding]\ncode = \"rateless:overhead=0\"\n")
            .unwrap_err()
            .to_string();
        assert!(e.contains("overhead"), "{e}");
        // Mistyped value names section and key.
        let e = ExperimentConfig::from_str_conf("[coding]\ncode = 3\n").unwrap_err().to_string();
        assert!(e.contains("[coding]") && e.contains("code"), "{e}");
    }

    #[test]
    fn mistyped_value_names_section_and_key() {
        let e = ExperimentConfig::from_str_conf("[training]\nlr = \"high\"\n")
            .unwrap_err()
            .to_string();
        assert!(e.contains("[training]") && e.contains("lr"), "{e}");
        assert!(e.contains("expected float") && e.contains("got string"), "{e}");

        let e = ExperimentConfig::from_str_conf("[experiment]\nclients = 2.5\n")
            .unwrap_err()
            .to_string();
        assert!(e.contains("[experiment]") && e.contains("clients"), "{e}");
    }

    #[test]
    fn unknown_key_is_rejected_with_its_name() {
        let e = ExperimentConfig::from_str_conf("[experiment]\nclinets = 5\n")
            .unwrap_err()
            .to_string();
        assert!(e.contains("clinets"), "{e}");
        assert!(e.contains("clients"), "suggestion list missing: {e}");
    }

    #[test]
    fn unknown_section_is_rejected() {
        let e = ExperimentConfig::from_str_conf("[trainings]\nlr = 1.0\n")
            .unwrap_err()
            .to_string();
        assert!(e.contains("trainings"), "{e}");
    }

    #[test]
    fn top_level_keys_are_rejected() {
        let e = ExperimentConfig::from_str_conf("lr = 1.0\n").unwrap_err().to_string();
        assert!(e.contains("lr") && e.contains("section"), "{e}");
    }

    #[test]
    fn negative_int_keys_are_rejected() {
        let e = ExperimentConfig::from_str_conf("[model]\nq = -4\n").unwrap_err().to_string();
        assert!(e.contains("[model]") && e.contains('q'), "{e}");
    }
}
