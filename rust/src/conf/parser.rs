//! Minimal TOML-subset parser (see module docs in `conf`).

use std::collections::BTreeMap;

/// A parsed configuration value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// Floats accept integer literals too (`lr = 6` is fine).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }

    /// Human-readable type tag for "expected X, got Y" config errors.
    pub fn type_name(&self) -> &'static str {
        match self {
            Value::Int(_) => "int",
            Value::Float(_) => "float",
            Value::Bool(_) => "bool",
            Value::Str(_) => "string",
            Value::Array(_) => "array",
        }
    }
}

/// Parsed document: section name → key → value. Keys before any `[section]`
/// land in the `""` section.
pub type Doc = BTreeMap<String, BTreeMap<String, Value>>;

/// Configuration error (`thiserror` is unavailable offline, so `Display`
/// and `Error` are hand-implemented).
#[derive(Debug)]
pub enum ConfError {
    Io(String),
    Parse { line: usize, msg: String },
    Invalid(String),
}

impl std::fmt::Display for ConfError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfError::Io(msg) => write!(f, "config io error: {msg}"),
            ConfError::Parse { line, msg } => {
                write!(f, "config parse error at line {line}: {msg}")
            }
            ConfError::Invalid(msg) => write!(f, "invalid config: {msg}"),
        }
    }
}

impl std::error::Error for ConfError {}

fn perr(line: usize, msg: impl Into<String>) -> ConfError {
    ConfError::Parse { line, msg: msg.into() }
}

/// Parse config text into a [`Doc`].
pub fn parse(text: &str) -> Result<Doc, ConfError> {
    let mut doc: Doc = BTreeMap::new();
    let mut section = String::new();
    for (i, raw) in text.lines().enumerate() {
        let lineno = i + 1;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| perr(lineno, "unterminated section header"))?
                .trim();
            if name.is_empty() {
                return Err(perr(lineno, "empty section name"));
            }
            section = name.to_string();
            doc.entry(section.clone()).or_default();
            continue;
        }
        let (key, val) = line
            .split_once('=')
            .ok_or_else(|| perr(lineno, "expected `key = value`"))?;
        let key = key.trim();
        if key.is_empty() {
            return Err(perr(lineno, "empty key"));
        }
        let value = parse_value(val.trim(), lineno)?;
        doc.entry(section.clone()).or_default().insert(key.to_string(), value);
    }
    Ok(doc)
}

/// Remove a `#` comment, respecting string literals.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (idx, ch) in line.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..idx],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, line: usize) -> Result<Value, ConfError> {
    if s.is_empty() {
        return Err(perr(line, "missing value"));
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| perr(line, "unterminated string"))?;
        if inner.contains('"') {
            return Err(perr(line, "embedded quote in string"));
        }
        return Ok(Value::Str(inner.to_string()));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| perr(line, "unterminated array"))?
            .trim();
        if inner.is_empty() {
            return Ok(Value::Array(vec![]));
        }
        let items = inner
            .split(',')
            .map(|item| parse_value(item.trim(), line))
            .collect::<Result<Vec<_>, _>>()?;
        return Ok(Value::Array(items));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(perr(line, format!("cannot parse value {s:?}")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = parse(
            "top = 1\n[a]\nx = 2\ny = 3.5\nz = \"hi\"\nb = true\narr = [1, 2, 3]\n",
        )
        .unwrap();
        assert_eq!(doc[""]["top"], Value::Int(1));
        assert_eq!(doc["a"]["x"], Value::Int(2));
        assert_eq!(doc["a"]["y"], Value::Float(3.5));
        assert_eq!(doc["a"]["z"], Value::Str("hi".into()));
        assert_eq!(doc["a"]["b"], Value::Bool(true));
        assert_eq!(
            doc["a"]["arr"],
            Value::Array(vec![Value::Int(1), Value::Int(2), Value::Int(3)])
        );
    }

    #[test]
    fn comments_and_blanks_skipped() {
        let doc = parse("# header\n\nx = 1 # trailing\ns = \"a # not comment\"\n").unwrap();
        assert_eq!(doc[""]["x"], Value::Int(1));
        assert_eq!(doc[""]["s"], Value::Str("a # not comment".into()));
    }

    #[test]
    fn negative_and_scientific_numbers() {
        let doc = parse("a = -4\nb = 9e-6\nc = -1.5e3\n").unwrap();
        assert_eq!(doc[""]["a"], Value::Int(-4));
        assert_eq!(doc[""]["b"], Value::Float(9e-6));
        assert_eq!(doc[""]["c"], Value::Float(-1500.0));
    }

    #[test]
    fn float_accepts_int_literal() {
        assert_eq!(Value::Int(6).as_float(), Some(6.0));
    }

    #[test]
    fn errors_have_line_numbers() {
        let e = parse("x = 1\noops\n").unwrap_err();
        match e {
            ConfError::Parse { line, .. } => assert_eq!(line, 2),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn rejects_bad_values() {
        assert!(parse("x = \"unterminated\n").is_err());
        assert!(parse("x = [1, 2\n").is_err());
        assert!(parse("x = what\n").is_err());
        assert!(parse("[unclosed\n").is_err());
        assert!(parse("= 3\n").is_err());
    }

    #[test]
    fn later_keys_override() {
        let doc = parse("[s]\nx = 1\nx = 2\n").unwrap();
        assert_eq!(doc["s"]["x"], Value::Int(2));
    }
}
