//! Byte-accurate communication model + payload compression codecs.
//!
//! The paper prices every transfer as one fixed packet of `q·c` 32-bit
//! scalars plus 10% protocol overhead (§V-A). This module makes the
//! payload a first-class modelled quantity:
//!
//! * [`PayloadModel`] — the modelled bytes of the three wire transfers
//!   (θ downlink broadcast, gradient uplink, one-shot parity upload),
//!   derived from the experiment shape and the active codec. The fleet
//!   builder ([`crate::topology::FleetSpec`]) folds the model's per-leg
//!   byte scales into each client's per-packet times, so the round
//!   timeline and the allocation optimizer both price what the wire
//!   actually carries. The identity model (codec `none`, payload `auto`)
//!   leaves every τ bit-untouched — seeded histories are pinned on it.
//! * [`CodecSpec`] — the pluggable uplink codec (`[comm] codec`,
//!   `--codec`, builder `.codec(...)`): `none` (32-bit scalars,
//!   historical), `q8[:scale=auto|σ]` (per-row affine int8 quantization,
//!   8 bits/scalar), `bitpack` (per-row affine 4-bit codes packed two to
//!   a byte, 4 bits/scalar). Quantized codecs carry an 8-byte per-row
//!   header (`lo`, `step` as f32), amortised to `64/cols` bits/scalar.
//! * Quantize/dequantize row kernels with AVX2/NEON arms dispatched
//!   through the runtime [`Isa`] (the `tensor::gemm` / `coding::gf256`
//!   discipline: resolve once, branch on the copy, feature-guard the SIMD
//!   arms so a hand-constructed [`Isa`] degrades to scalar, never
//!   faults). Unlike GEMM, the quantize kernels are **bit-exact** across
//!   ISAs: codes are `floor((x − lo)·step⁻¹ + 0.5)` clamped, and
//!   subtract/multiply/add/floor round identically per element in every
//!   lane width (no FMA in these kernels, by construction).
//! * [`transcode_mat`] — the engine's uplink simulation: quantize each
//!   gradient row, (for `bitpack`) pack/unpack the nibble codes, then
//!   dequantize in place, so the fold trains on exactly what a receiver
//!   could reconstruct from the wire bytes. Zero-alloc on warm rounds via
//!   the caller-owned [`CodecScratch`].
//!
//! The MEC unit's parity gradient never crosses a wireless link (§III-C:
//! the server computes it locally from the parity data uploaded once),
//! so it is never transcoded — the one-shot parity *upload* is priced
//! through [`PayloadModel::parity_scale`] instead.

use crate::tensor::{Isa, Mat};

/// Scale selection for a quantizing codec.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ScaleSpec {
    /// Per-row affine range: `lo = min(row)`, `step = (max−min)/(L−1)`.
    Auto,
    /// Fixed symmetric step σ: `step = σ`, `lo = −(L/2)·σ`.
    Fixed(f64),
}

/// The pluggable uplink codec (`[comm] codec`).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum CodecSpec {
    /// 32-bit scalars, no transcoding — bit-identical to historical runs.
    #[default]
    None,
    /// Per-row affine int8 quantization (256 levels, 8 bits/scalar).
    Q8 { scale: ScaleSpec },
    /// Per-row affine 4-bit quantization, nibble-packed (16 levels,
    /// 4 bits/scalar, two codes per wire byte).
    Bitpack,
}

impl CodecSpec {
    /// Quantization level count (meaningless for `none`).
    pub fn levels(self) -> u32 {
        match self {
            CodecSpec::None => 0,
            CodecSpec::Q8 { .. } => 256,
            CodecSpec::Bitpack => 16,
        }
    }

    /// Modelled wire bits per gradient scalar, headers excluded.
    pub fn bits_per_scalar(self) -> f64 {
        match self {
            CodecSpec::None => 32.0,
            CodecSpec::Q8 { .. } => 8.0,
            CodecSpec::Bitpack => 4.0,
        }
    }

    /// Modelled per-row header bits (`lo` + `step` as f32).
    pub fn row_header_bits(self) -> f64 {
        match self {
            CodecSpec::None => 0.0,
            _ => 64.0,
        }
    }

    /// Byte-scale of a coded row of `cols` scalars relative to the
    /// historical 32-bit payload: `(bits/scalar + header/cols) / 32`.
    /// `none` is exactly 1.0 (the bit-identity anchor).
    pub fn byte_scale(self, cols: usize) -> f64 {
        match self {
            CodecSpec::None => 1.0,
            _ => (self.bits_per_scalar() + self.row_header_bits() / cols as f64) / 32.0,
        }
    }

    pub fn is_none(self) -> bool {
        matches!(self, CodecSpec::None)
    }

    /// Canonical spelling — what checkpoints fingerprint and logs print.
    pub fn label(self) -> String {
        match self {
            CodecSpec::None => "none".into(),
            CodecSpec::Q8 { scale: ScaleSpec::Auto } => "q8:scale=auto".into(),
            CodecSpec::Q8 { scale: ScaleSpec::Fixed(s) } => format!("q8:scale={s}"),
            CodecSpec::Bitpack => "bitpack".into(),
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        if let CodecSpec::Q8 { scale: ScaleSpec::Fixed(s) } = self {
            if !(s.is_finite() && *s > 0.0) {
                return Err(format!("q8 scale must be a finite value > 0, got {s}"));
            }
        }
        Ok(())
    }
}

impl std::str::FromStr for CodecSpec {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "none" => Ok(CodecSpec::None),
            "q8" => Ok(CodecSpec::Q8 { scale: ScaleSpec::Auto }),
            "bitpack" => Ok(CodecSpec::Bitpack),
            other => {
                if let Some(rest) = other.strip_prefix("q8:") {
                    let val = rest.strip_prefix("scale=").ok_or_else(|| {
                        format!(
                            "unknown q8 option {rest:?} (expected scale=auto or scale=<sigma>)"
                        )
                    })?;
                    if val == "auto" {
                        return Ok(CodecSpec::Q8 { scale: ScaleSpec::Auto });
                    }
                    let sigma: f64 = val.parse().map_err(|_| {
                        format!("q8 scale: expected auto or a number, got {val:?}")
                    })?;
                    let spec = CodecSpec::Q8 { scale: ScaleSpec::Fixed(sigma) };
                    spec.validate()?;
                    return Ok(spec);
                }
                Err(format!(
                    "unknown codec {other:?} (expected one of none | q8[:scale=auto|<sigma>] | bitpack)"
                ))
            }
        }
    }
}

impl std::fmt::Display for CodecSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.label())
    }
}

/// How modelled payload bytes follow the codec (`[comm] payload`).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum PayloadSpec {
    /// Derive the per-leg byte scales from the codec: downlink θ stays
    /// full precision, uplink gradient and parity upload shrink to the
    /// codec's wire bytes (the default).
    #[default]
    Auto,
    /// Keep the historical fixed 32-bit pricing on every leg even when a
    /// codec runs — isolates the codec's *training* effect from its
    /// communication benefit (an ablation control).
    Fixed,
    /// Explicit per-leg byte-scale multipliers.
    Scale { down: f64, up: f64, parity: f64 },
}

impl PayloadSpec {
    /// Canonical spelling for fingerprints and logs.
    pub fn label(self) -> String {
        match self {
            PayloadSpec::Auto => "auto".into(),
            PayloadSpec::Fixed => "fixed".into(),
            PayloadSpec::Scale { down, up, parity } => {
                format!("scale:down={down},up={up},parity={parity}")
            }
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        if let PayloadSpec::Scale { down, up, parity } = self {
            for (name, v) in [("down", down), ("up", up), ("parity", parity)] {
                if !(v.is_finite() && *v > 0.0) {
                    return Err(format!("payload {name} scale must be > 0, got {v}"));
                }
            }
        }
        Ok(())
    }
}

impl std::str::FromStr for PayloadSpec {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "auto" => Ok(PayloadSpec::Auto),
            "fixed" => Ok(PayloadSpec::Fixed),
            other => {
                if let Some(rest) = other.strip_prefix("scale:") {
                    let (mut down, mut up, mut parity) = (1.0f64, 1.0f64, 1.0f64);
                    for part in rest.split(',') {
                        let (key, val) = part.split_once('=').ok_or_else(|| {
                            format!("payload scale option {part:?} must be key=value")
                        })?;
                        let v: f64 = val.parse().map_err(|_| {
                            format!("payload {key}: expected a number, got {val:?}")
                        })?;
                        match key {
                            "down" => down = v,
                            "up" => up = v,
                            "parity" => parity = v,
                            other => {
                                return Err(format!(
                                    "unknown payload scale key {other:?} (expected one of down | up | parity)"
                                ))
                            }
                        }
                    }
                    let spec = PayloadSpec::Scale { down, up, parity };
                    spec.validate()?;
                    return Ok(spec);
                }
                Err(format!(
                    "unknown payload model {other:?} (expected one of auto | fixed | scale:down=..,up=..,parity=..)"
                ))
            }
        }
    }
}

impl std::fmt::Display for PayloadSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.label())
    }
}

/// Modelled bytes of the three wire transfers, resolved once per run from
/// the experiment shape `(q, c)`, the codec, and the payload spec.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PayloadModel {
    /// RFF dimension q (gradient rows).
    pub q: usize,
    /// Classes c (gradient columns).
    pub c: usize,
    /// Protocol overhead fraction (the paper's 10%).
    pub overhead: f64,
    /// Downlink θ byte scale relative to the 32-bit payload.
    pub down_scale: f64,
    /// Uplink gradient byte scale.
    pub up_scale: f64,
    /// One-shot parity upload byte scale (rows of width `q + c`).
    pub parity_scale: f64,
}

impl PayloadModel {
    pub fn new(q: usize, c: usize, codec: CodecSpec, payload: PayloadSpec, overhead: f64) -> Self {
        let (down_scale, up_scale, parity_scale) = match payload {
            PayloadSpec::Auto => (1.0, codec.byte_scale(c), codec.byte_scale(q + c)),
            PayloadSpec::Fixed => (1.0, 1.0, 1.0),
            PayloadSpec::Scale { down, up, parity } => (down, up, parity),
        };
        PayloadModel { q, c, overhead, down_scale, up_scale, parity_scale }
    }

    /// The historical fixed payload in bytes: `q·c` 32-bit scalars plus
    /// protocol overhead (the byte form of `FleetSpec::packet_bits`).
    fn base_bytes(&self) -> f64 {
        (self.q * self.c) as f64 * 4.0 * (1.0 + self.overhead)
    }

    /// Modelled bytes of one θ downlink broadcast to one client.
    pub fn theta_down_bytes(&self) -> f64 {
        self.base_bytes() * self.down_scale
    }

    /// Modelled bytes of one client's gradient uplink.
    pub fn grad_up_bytes(&self) -> f64 {
        self.base_bytes() * self.up_scale
    }

    /// Modelled bytes of the one-shot upload of `u` parity rows of width
    /// `q + c`.
    pub fn parity_upload_bytes(&self, u: usize) -> f64 {
        u as f64 * (self.q + self.c) as f64 * 4.0 * (1.0 + self.overhead) * self.parity_scale
    }

    /// Whether every leg keeps the historical pricing bit-for-bit.
    pub fn is_identity(&self) -> bool {
        self.down_scale == 1.0 && self.up_scale == 1.0 && self.parity_scale == 1.0
    }
}

/// Per-row affine quantization parameters — the modelled 8-byte row
/// header (`x ≈ lo + code·step`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RowQuant {
    pub lo: f32,
    pub step: f32,
}

/// Resolve one row's quantization parameters. Scalar min/max reduction —
/// exact (no rounding), so trivially ISA- and thread-invariant. A
/// constant row gets `step = 0` and dequantizes to `lo` exactly.
/// Panics for `CodecSpec::None`, which has no quantization grid.
pub fn quant_params(codec: CodecSpec, row: &[f32]) -> RowQuant {
    let levels = codec.levels();
    assert!(levels >= 2, "quant_params: codec {codec} does not quantize");
    match codec {
        CodecSpec::Q8 { scale: ScaleSpec::Fixed(s) } => {
            let step = s as f32;
            RowQuant { lo: -(levels as f32 / 2.0) * step, step }
        }
        _ => {
            let mut lo = f32::INFINITY;
            let mut hi = f32::NEG_INFINITY;
            for &x in row {
                lo = lo.min(x);
                hi = hi.max(x);
            }
            if row.is_empty() {
                return RowQuant { lo: 0.0, step: 0.0 };
            }
            RowQuant { lo, step: (hi - lo) / (levels - 1) as f32 }
        }
    }
}

/// Whether this host can run the AVX2 quantize lanes (cached CPUID probe
/// — the `coding::gf256` safety net against hand-constructed [`Isa`]s).
#[cfg(target_arch = "x86_64")]
#[inline]
fn avx2_available() -> bool {
    std::arch::is_x86_feature_detected!("avx2")
}

/// Whether this host can run the NEON quantize lanes (cached probe).
#[cfg(target_arch = "aarch64")]
#[inline]
fn neon_available() -> bool {
    std::arch::is_aarch64_feature_detected!("neon")
}

/// Quantize one row: `out[i] = clamp(⌊(src[i] − lo)·step⁻¹ + 0.5⌋, 0, L−1)`.
/// Bit-identical across ISAs: every arm performs the same
/// subtract/multiply/add/floor f32 sequence per element (no FMA).
/// `step = 0` (constant row) maps everything to code 0.
pub fn quantize_row(isa: Isa, codec: CodecSpec, src: &[f32], pq: RowQuant, out: &mut [u8]) {
    assert_eq!(src.len(), out.len(), "comm::quantize_row: length mismatch");
    let levels = codec.levels();
    assert!(levels >= 2, "comm::quantize_row: codec {codec} does not quantize");
    let step_inv = if pq.step > 0.0 { 1.0 / pq.step } else { 0.0 };
    let max_code = (levels - 1) as f32;
    match isa {
        Isa::Scalar => quantize_row_scalar(src, pq.lo, step_inv, max_code, out),
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2Fma if avx2_available() => {
            // Safety: lengths asserted equal above; the guard verified AVX2.
            unsafe { quantize_row_avx2(src, pq.lo, step_inv, max_code, out) }
        }
        #[cfg(target_arch = "aarch64")]
        Isa::Neon if neon_available() => {
            // Safety: lengths asserted equal above; the guard verified NEON.
            unsafe { quantize_row_neon(src, pq.lo, step_inv, max_code, out) }
        }
        // An ISA this build has no kernel for, or this host lacks: degrade
        // to the scalar oracle, never fault.
        #[allow(unreachable_patterns)]
        _ => quantize_row_scalar(src, pq.lo, step_inv, max_code, out),
    }
}

/// Dequantize one row: `dst[i] = lo + codes[i]·step` (multiply then add,
/// no FMA — bit-identical across ISAs).
pub fn dequantize_row(isa: Isa, codes: &[u8], pq: RowQuant, dst: &mut [f32]) {
    assert_eq!(codes.len(), dst.len(), "comm::dequantize_row: length mismatch");
    match isa {
        Isa::Scalar => dequantize_row_scalar(codes, pq.lo, pq.step, dst),
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2Fma if avx2_available() => {
            // Safety: lengths asserted equal above; the guard verified AVX2.
            unsafe { dequantize_row_avx2(codes, pq.lo, pq.step, dst) }
        }
        #[cfg(target_arch = "aarch64")]
        Isa::Neon if neon_available() => {
            // Safety: lengths asserted equal above; the guard verified NEON.
            unsafe { dequantize_row_neon(codes, pq.lo, pq.step, dst) }
        }
        #[allow(unreachable_patterns)]
        _ => dequantize_row_scalar(codes, pq.lo, pq.step, dst),
    }
}

fn quantize_row_scalar(src: &[f32], lo: f32, step_inv: f32, max_code: f32, out: &mut [u8]) {
    for (o, &x) in out.iter_mut().zip(src) {
        let code = ((x - lo) * step_inv + 0.5).floor().clamp(0.0, max_code);
        // Non-negative and floored, so the truncating cast is exact.
        *o = code as u8;
    }
}

fn dequantize_row_scalar(codes: &[u8], lo: f32, step: f32, dst: &mut [f32]) {
    for (d, &c) in dst.iter_mut().zip(codes) {
        *d = lo + c as f32 * step;
    }
}

/// Safety: caller guarantees `src.len() == out.len()` and AVX2 support.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn quantize_row_avx2(src: &[f32], lo: f32, step_inv: f32, max_code: f32, out: &mut [u8]) {
    use std::arch::x86_64::*;
    let n = src.len();
    let vlo = _mm256_set1_ps(lo);
    let vsi = _mm256_set1_ps(step_inv);
    let vhalf = _mm256_set1_ps(0.5);
    let vzero = _mm256_setzero_ps();
    let vmax = _mm256_set1_ps(max_code);
    let mut i = 0;
    while i + 8 <= n {
        let x = _mm256_loadu_ps(src.as_ptr().add(i));
        // sub → mul → add → floor: the scalar sequence, lane-wise (no FMA).
        let t = _mm256_add_ps(_mm256_mul_ps(_mm256_sub_ps(x, vlo), vsi), vhalf);
        let code = _mm256_min_ps(_mm256_max_ps(_mm256_floor_ps(t), vzero), vmax);
        let ints = _mm256_cvttps_epi32(code);
        let lo128 = _mm256_castsi256_si128(ints);
        let hi128 = _mm256_extracti128_si256(ints, 1);
        let words = _mm_packus_epi32(lo128, hi128);
        let bytes = _mm_packus_epi16(words, _mm_setzero_si128());
        _mm_storel_epi64(out.as_mut_ptr().add(i) as *mut __m128i, bytes);
        i += 8;
    }
    while i < n {
        let code = ((*src.get_unchecked(i) - lo) * step_inv + 0.5)
            .floor()
            .clamp(0.0, max_code);
        *out.get_unchecked_mut(i) = code as u8;
        i += 1;
    }
}

/// Safety: caller guarantees `codes.len() == dst.len()` and AVX2 support.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dequantize_row_avx2(codes: &[u8], lo: f32, step: f32, dst: &mut [f32]) {
    use std::arch::x86_64::*;
    let n = codes.len();
    let vlo = _mm256_set1_ps(lo);
    let vstep = _mm256_set1_ps(step);
    let mut i = 0;
    while i + 8 <= n {
        let bytes = _mm_loadl_epi64(codes.as_ptr().add(i) as *const __m128i);
        let ints = _mm256_cvtepu8_epi32(bytes);
        let x = _mm256_add_ps(_mm256_mul_ps(_mm256_cvtepi32_ps(ints), vstep), vlo);
        _mm256_storeu_ps(dst.as_mut_ptr().add(i), x);
        i += 8;
    }
    while i < n {
        *dst.get_unchecked_mut(i) = lo + *codes.get_unchecked(i) as f32 * step;
        i += 1;
    }
}

/// Safety: caller guarantees `src.len() == out.len()` and NEON support.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn quantize_row_neon(src: &[f32], lo: f32, step_inv: f32, max_code: f32, out: &mut [u8]) {
    use std::arch::aarch64::*;
    let n = src.len();
    let vlo = vdupq_n_f32(lo);
    let vsi = vdupq_n_f32(step_inv);
    let vhalf = vdupq_n_f32(0.5);
    let vzero = vdupq_n_f32(0.0);
    let vmax = vdupq_n_f32(max_code);
    let mut i = 0;
    while i + 8 <= n {
        let quant4 = |p: *const f32| {
            let x = vld1q_f32(p);
            // sub → mul → add → floor, lane-wise (vrndmq = floor; no FMA).
            let t = vaddq_f32(vmulq_f32(vsubq_f32(x, vlo), vsi), vhalf);
            let code = vminq_f32(vmaxq_f32(vrndmq_f32(t), vzero), vmax);
            vcvtq_u32_f32(code)
        };
        let a = quant4(src.as_ptr().add(i));
        let b = quant4(src.as_ptr().add(i + 4));
        let words = vcombine_u16(vmovn_u32(a), vmovn_u32(b));
        vst1_u8(out.as_mut_ptr().add(i), vmovn_u16(words));
        i += 8;
    }
    while i < n {
        let code = ((*src.get_unchecked(i) - lo) * step_inv + 0.5)
            .floor()
            .clamp(0.0, max_code);
        *out.get_unchecked_mut(i) = code as u8;
        i += 1;
    }
}

/// Safety: caller guarantees `codes.len() == dst.len()` and NEON support.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn dequantize_row_neon(codes: &[u8], lo: f32, step: f32, dst: &mut [f32]) {
    use std::arch::aarch64::*;
    let n = codes.len();
    let vlo = vdupq_n_f32(lo);
    let vstep = vdupq_n_f32(step);
    let mut i = 0;
    while i + 8 <= n {
        let bytes = vld1_u8(codes.as_ptr().add(i));
        let words = vmovl_u8(bytes);
        let a = vcvtq_f32_u32(vmovl_u16(vget_low_u16(words)));
        let b = vcvtq_f32_u32(vmovl_u16(vget_high_u16(words)));
        vst1q_f32(dst.as_mut_ptr().add(i), vaddq_f32(vmulq_f32(a, vstep), vlo));
        vst1q_f32(dst.as_mut_ptr().add(i + 4), vaddq_f32(vmulq_f32(b, vstep), vlo));
        i += 8;
    }
    while i < n {
        *dst.get_unchecked_mut(i) = lo + *codes.get_unchecked(i) as f32 * step;
        i += 1;
    }
}

/// Bytes one packed nibble row occupies: two 4-bit codes per byte, the
/// odd tail code alone in the last byte.
pub fn packed_len(n_codes: usize) -> usize {
    n_codes.div_ceil(2)
}

/// Pack 4-bit codes two to a byte (`out[i] = codes[2i] | codes[2i+1] « 4`).
/// Pure byte shuffles — exact on every ISA, and simple enough that the
/// autovectorizer already saturates memory bandwidth, so there is no
/// hand-written SIMD arm (the `isa` parameter keeps the call-site
/// discipline uniform with the quantize kernels).
pub fn pack_nibbles(_isa: Isa, codes: &[u8], out: &mut [u8]) {
    assert_eq!(out.len(), packed_len(codes.len()), "comm::pack_nibbles: length mismatch");
    let pairs = codes.len() / 2;
    for i in 0..pairs {
        debug_assert!(codes[2 * i] < 16 && codes[2 * i + 1] < 16);
        out[i] = codes[2 * i] | (codes[2 * i + 1] << 4);
    }
    if codes.len() % 2 == 1 {
        debug_assert!(codes[codes.len() - 1] < 16);
        out[pairs] = codes[codes.len() - 1];
    }
}

/// Unpack nibble-packed bytes back to one 4-bit code per byte — the exact
/// inverse of [`pack_nibbles`] for valid codes.
pub fn unpack_nibbles(_isa: Isa, packed: &[u8], codes: &mut [u8]) {
    assert_eq!(packed.len(), packed_len(codes.len()), "comm::unpack_nibbles: length mismatch");
    let pairs = codes.len() / 2;
    for i in 0..pairs {
        codes[2 * i] = packed[i] & 0x0F;
        codes[2 * i + 1] = packed[i] >> 4;
    }
    if codes.len() % 2 == 1 {
        codes[codes.len() - 1] = packed[pairs] & 0x0F;
    }
}

/// Caller-owned scratch for the transcode path: one row of codes and its
/// packed form. Reserve once at engine construction — warm rounds then
/// resize within capacity and allocate nothing.
#[derive(Debug, Default)]
pub struct CodecScratch {
    pub codes: Vec<u8>,
    pub packed: Vec<u8>,
}

impl CodecScratch {
    /// Pre-size for rows of up to `cols` scalars.
    pub fn reserve(&mut self, cols: usize) {
        if self.codes.capacity() < cols {
            self.codes.reserve(cols - self.codes.len());
        }
        let plen = packed_len(cols);
        if self.packed.capacity() < plen {
            self.packed.reserve(plen - self.packed.len());
        }
    }
}

/// Simulate one gradient's uplink through `codec`, in place: per row,
/// quantize → (`bitpack` only) pack + unpack the wire nibbles →
/// dequantize. After this the matrix holds exactly what a receiver could
/// reconstruct from the modelled wire bytes. `none` is a no-op.
/// Allocation-free once `scratch` is reserved for the matrix width.
pub fn transcode_mat(isa: Isa, codec: CodecSpec, mat: &mut Mat, scratch: &mut CodecScratch) {
    let cols = mat.cols();
    if codec.is_none() || cols == 0 {
        return;
    }
    scratch.codes.resize(cols, 0);
    if matches!(codec, CodecSpec::Bitpack) {
        scratch.packed.resize(packed_len(cols), 0);
    }
    for row in mat.as_mut_slice().chunks_exact_mut(cols) {
        let pq = quant_params(codec, row);
        quantize_row(isa, codec, row, pq, &mut scratch.codes);
        if matches!(codec, CodecSpec::Bitpack) {
            pack_nibbles(isa, &scratch.codes, &mut scratch.packed);
            unpack_nibbles(isa, &scratch.packed, &mut scratch.codes);
        }
        dequantize_row(isa, &scratch.codes, pq, row);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::tensor::SimdPolicy;

    #[test]
    fn codec_spec_parses_and_labels() {
        assert_eq!("none".parse::<CodecSpec>().unwrap(), CodecSpec::None);
        assert_eq!(
            "q8".parse::<CodecSpec>().unwrap(),
            CodecSpec::Q8 { scale: ScaleSpec::Auto }
        );
        assert_eq!(
            "q8:scale=auto".parse::<CodecSpec>().unwrap(),
            CodecSpec::Q8 { scale: ScaleSpec::Auto }
        );
        assert_eq!(
            "q8:scale=0.5".parse::<CodecSpec>().unwrap(),
            CodecSpec::Q8 { scale: ScaleSpec::Fixed(0.5) }
        );
        assert_eq!("bitpack".parse::<CodecSpec>().unwrap(), CodecSpec::Bitpack);
        for spec in ["none", "q8:scale=auto", "q8:scale=0.5", "bitpack"] {
            assert_eq!(spec.parse::<CodecSpec>().unwrap().label(), spec, "label round trip");
        }
        let err = "zstd".parse::<CodecSpec>().unwrap_err();
        assert!(err.contains("zstd") && err.contains("expected one of"), "{err}");
        assert!("q8:scale=-1".parse::<CodecSpec>().is_err());
        assert!("q8:scale=nope".parse::<CodecSpec>().is_err());
        assert!("q8:window=3".parse::<CodecSpec>().is_err());
    }

    #[test]
    fn payload_spec_parses_and_labels() {
        assert_eq!("auto".parse::<PayloadSpec>().unwrap(), PayloadSpec::Auto);
        assert_eq!("fixed".parse::<PayloadSpec>().unwrap(), PayloadSpec::Fixed);
        assert_eq!(
            "scale:up=0.25".parse::<PayloadSpec>().unwrap(),
            PayloadSpec::Scale { down: 1.0, up: 0.25, parity: 1.0 }
        );
        assert_eq!(
            "scale:down=0.5,up=0.25,parity=0.75".parse::<PayloadSpec>().unwrap(),
            PayloadSpec::Scale { down: 0.5, up: 0.25, parity: 0.75 }
        );
        let err = "shrink".parse::<PayloadSpec>().unwrap_err();
        assert!(err.contains("shrink") && err.contains("expected one of"), "{err}");
        assert!("scale:sideways=2".parse::<PayloadSpec>().is_err());
        assert!("scale:up=0".parse::<PayloadSpec>().is_err());
    }

    #[test]
    fn payload_model_scales_match_the_codec_arithmetic() {
        // q8 at c=10: (8 + 64/10)/32 = 0.45 of the 32-bit payload.
        let m = PayloadModel::new(
            2000,
            10,
            CodecSpec::Q8 { scale: ScaleSpec::Auto },
            PayloadSpec::Auto,
            0.1,
        );
        assert!((m.up_scale - 0.45).abs() < 1e-12);
        assert_eq!(m.down_scale, 1.0, "theta broadcast stays full precision");
        // bitpack at c=10: (4 + 6.4)/32 = 0.325.
        let b = PayloadModel::new(2000, 10, CodecSpec::Bitpack, PayloadSpec::Auto, 0.1);
        assert!((b.up_scale - 0.325).abs() < 1e-12);
        // The identity model reproduces packet_bits in byte form.
        let id = PayloadModel::new(2000, 10, CodecSpec::None, PayloadSpec::Auto, 0.1);
        assert!(id.is_identity());
        assert!((id.theta_down_bytes() - 704_000.0 / 8.0).abs() < 1e-6);
        assert_eq!(id.theta_down_bytes().to_bits(), id.grad_up_bytes().to_bits());
        // `fixed` pins every leg at 1.0 regardless of codec.
        let f = PayloadModel::new(2000, 10, CodecSpec::Bitpack, PayloadSpec::Fixed, 0.1);
        assert!(f.is_identity());
        // Parity rows are width q+c, so their header amortizes further.
        assert!(m.parity_scale < m.up_scale);
        assert!((m.parity_upload_bytes(100) / id.parity_upload_bytes(100) - m.parity_scale).abs() < 1e-12);
    }

    #[test]
    fn quantize_kernels_match_the_scalar_oracle_bitwise() {
        // 1031 is odd and > one SIMD lane, so body + tail are both hit.
        let mut rng = Rng::seed_from(40);
        let len = 1031;
        let src: Vec<f32> = (0..len).map(|_| (rng.next_f64() * 8.0 - 4.0) as f32).collect();
        let detected = Isa::detect(SimdPolicy::Auto);
        for codec in [
            CodecSpec::Q8 { scale: ScaleSpec::Auto },
            CodecSpec::Q8 { scale: ScaleSpec::Fixed(0.03125) },
            CodecSpec::Bitpack,
        ] {
            let pq = quant_params(codec, &src);
            let mut scalar = vec![0u8; len];
            let mut simd = vec![0u8; len];
            quantize_row(Isa::Scalar, codec, &src, pq, &mut scalar);
            quantize_row(detected, codec, &src, pq, &mut simd);
            assert_eq!(scalar, simd, "quantize diverged under {codec}");
            assert!(scalar.iter().all(|&c| (c as u32) < codec.levels()));
            let mut d_scalar = vec![0.0f32; len];
            let mut d_simd = vec![0.0f32; len];
            dequantize_row(Isa::Scalar, &scalar, pq, &mut d_scalar);
            dequantize_row(detected, &scalar, pq, &mut d_simd);
            for i in 0..len {
                assert_eq!(
                    d_scalar[i].to_bits(),
                    d_simd[i].to_bits(),
                    "dequantize diverged at {i} under {codec}"
                );
            }
        }
    }

    #[test]
    fn unsupported_isa_degrades_to_scalar_not_a_fault() {
        let src = vec![1.25f32; 97];
        let codec = CodecSpec::Q8 { scale: ScaleSpec::Auto };
        let pq = quant_params(codec, &src);
        for isa in [Isa::Avx2Fma, Isa::Neon] {
            let mut out = vec![0u8; 97];
            quantize_row(isa, codec, &src, pq, &mut out);
            assert!(out.iter().all(|&c| c == 0), "constant row must map to code 0");
            let mut back = vec![0.0f32; 97];
            dequantize_row(isa, &out, pq, &mut back);
            assert!(back.iter().all(|&x| x == 1.25), "constant row round-trips exactly");
        }
    }

    #[test]
    fn round_trip_error_is_bounded_by_half_a_step() {
        let mut rng = Rng::seed_from(41);
        let len = 513;
        let src: Vec<f32> = (0..len).map(|_| (rng.next_f64() * 2.0 - 1.0) as f32).collect();
        for codec in [CodecSpec::Q8 { scale: ScaleSpec::Auto }, CodecSpec::Bitpack] {
            let pq = quant_params(codec, &src);
            let mut codes = vec![0u8; len];
            quantize_row(Isa::Scalar, codec, &src, pq, &mut codes);
            let mut back = vec![0.0f32; len];
            dequantize_row(Isa::Scalar, &codes, pq, &mut back);
            // Half-step reconstruction bound, plus an f32-rounding margin.
            let bound = 0.5 * pq.step as f64 * (1.0 + 1e-5) + 1e-7;
            for i in 0..len {
                let err = (back[i] as f64 - src[i] as f64).abs();
                assert!(err <= bound, "{codec}: |{}-{}| = {err} > {bound}", back[i], src[i]);
            }
        }
    }

    #[test]
    fn nibble_pack_round_trips_even_and_odd_lengths() {
        let mut rng = Rng::seed_from(42);
        for len in [0usize, 1, 2, 9, 64, 1031] {
            let codes: Vec<u8> = (0..len).map(|_| rng.next_below(16) as u8).collect();
            let mut packed = vec![0u8; packed_len(len)];
            pack_nibbles(Isa::Scalar, &codes, &mut packed);
            assert_eq!(packed.len(), len.div_ceil(2));
            let mut back = vec![0u8; len];
            unpack_nibbles(Isa::Scalar, &packed, &mut back);
            assert_eq!(codes, back, "len={len}");
        }
    }

    #[test]
    fn transcode_none_is_identity_and_q8_stays_close() {
        let mut rng = Rng::seed_from(43);
        let mat = Mat::from_fn(7, 33, |_, _| (rng.next_f64() * 4.0 - 2.0) as f32);
        let mut scratch = CodecScratch::default();
        let mut none = mat.clone();
        transcode_mat(Isa::Scalar, CodecSpec::None, &mut none, &mut scratch);
        assert_eq!(none, mat, "codec none must not touch a single bit");
        let mut q8 = mat.clone();
        transcode_mat(Isa::Scalar, CodecSpec::Q8 { scale: ScaleSpec::Auto }, &mut q8, &mut scratch);
        assert_ne!(q8, mat, "q8 must actually quantize");
        for r in 0..mat.rows() {
            let pq = quant_params(CodecSpec::Q8 { scale: ScaleSpec::Auto }, mat.row(r));
            for (a, b) in q8.row(r).iter().zip(mat.row(r)) {
                assert!((a - b).abs() <= 0.5 * pq.step * 1.001 + 1e-7);
            }
        }
        // bitpack survives the pack/unpack wire simulation.
        let mut bp = mat.clone();
        transcode_mat(Isa::Scalar, CodecSpec::Bitpack, &mut bp, &mut scratch);
        for r in 0..mat.rows() {
            let pq = quant_params(CodecSpec::Bitpack, mat.row(r));
            for (a, b) in bp.row(r).iter().zip(mat.row(r)) {
                assert!((a - b).abs() <= 0.5 * pq.step * 1.001 + 1e-6);
            }
        }
    }

    #[test]
    fn transcode_is_isa_invariant_on_whole_matrices() {
        let mut rng = Rng::seed_from(44);
        let mat = Mat::from_fn(5, 257, |_, _| (rng.next_f64() * 6.0 - 3.0) as f32);
        let detected = Isa::detect(SimdPolicy::Auto);
        for codec in [CodecSpec::Q8 { scale: ScaleSpec::Auto }, CodecSpec::Bitpack] {
            let mut scratch_a = CodecScratch::default();
            let mut scratch_b = CodecScratch::default();
            let mut a = mat.clone();
            let mut b = mat.clone();
            transcode_mat(Isa::Scalar, codec, &mut a, &mut scratch_a);
            transcode_mat(detected, codec, &mut b, &mut scratch_b);
            for (x, y) in a.as_slice().iter().zip(b.as_slice()) {
                assert_eq!(x.to_bits(), y.to_bits(), "transcode diverged under {codec}");
            }
        }
    }
}
