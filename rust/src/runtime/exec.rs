//! Typed executors behind [`Runtime`]: embed / grad / encode / predict.
//!
//! Two interchangeable backends sit behind one shape-checked API:
//!
//! * **native** (default) — the blocked, multi-threaded pure-Rust kernels
//!   of [`super::native::NativeExec`], matching the jnp oracles in
//!   `python/compile/kernels/ref.py`. No artifacts, no external deps; the
//!   worker pool is spawned once at construction (`[runtime] threads`,
//!   `0` = available parallelism) and the count never changes results
//!   (see `rust/PERF.md`).
//! * **pjrt** (`--features pjrt`) — the AOT HLO-text artifacts compiled
//!   through the PJRT C API (`xla` bindings required), padding each
//!   workload to the compiled shape (exactly — zero rows contribute zero)
//!   and unpadding results.
//!
//! The shape contract (`RuntimeShapes`, padding limits) is enforced on
//! both paths so natively-developed code never breaks under PJRT.
//!
//! ## Allocation discipline
//!
//! Every kernel has an allocating form (`grad`, `predict`, `grad_batch`)
//! for tests and one-off calls, and an `_into` form (`grad_into`,
//! `predict_into`, `grad_batch_into`) that writes into caller-owned
//! buffers. Together with [`Runtime::prepare_theta_into`] (θ packed into a
//! caller-owned panel) the `_into` forms make a warm training round
//! allocate **zero** bytes on the native compute path — the contract
//! `tests/alloc_gate.rs` enforces with a counting global allocator. (The
//! PJRT path allocates per call for literal conversion; the contract is
//! native-only.)

use std::borrow::Cow;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, PoisonError};

use anyhow::Result;
#[cfg(feature = "pjrt")]
use anyhow::Context;

use super::native::NativeExec;
use super::pool::WorkerPool;
use crate::tensor::{pack_tile_panel, tile_padded_cols, Isa, Mat, SimdPolicy};

#[cfg(feature = "pjrt")]
use super::manifest::Manifest;
#[cfg(feature = "pjrt")]
use super::{literal_to_mat, mat_to_literal, vec_to_literal};

/// The AOT shapes one experiment needs (mirrors
/// `python/compile/shapes.py::ShapeSet`).
#[derive(Clone, Copy, Debug)]
pub struct RuntimeShapes {
    pub d: usize,
    pub q: usize,
    pub c: usize,
    pub l_client: usize,
    pub u_max: usize,
    pub b_embed: usize,
}

/// A θ matrix pre-converted for the backend (see
/// [`Runtime::prepare_theta`]): the coordinator issues ~n+1 grad calls
/// plus predict against the same θ each round, so the conversion is
/// hoisted off the per-call path. The native representation is a borrow
/// of θ plus a tile-aligned packed panel (`[q, c_pad]`, zero tail
/// columns) the register-tiled kernels read — built once per round,
/// shared by every call, and allocation-free when the caller supplies the
/// panel buffer ([`Runtime::prepare_theta_into`]). Only the PJRT path
/// materialises a device literal.
pub struct PreparedTheta<'a> {
    mat: &'a Mat,
    /// The packed panel; borrows θ itself when `c` is tile-aligned.
    packed: Cow<'a, [f32]>,
    c_pad: usize,
    #[cfg(feature = "pjrt")]
    lit: Option<xla::Literal>,
}

impl PreparedTheta<'_> {
    /// The underlying θ (`[q, c]`).
    pub fn theta(&self) -> &Mat {
        self.mat
    }

    /// The tile-aligned packed panel (`[q, padded_cols]`). Empty on the
    /// PJRT backend, which reads θ through its device literal instead.
    pub fn panel(&self) -> &[f32] {
        &self.packed
    }

    /// Panel columns: `c` rounded up to the matmul register tile.
    pub fn padded_cols(&self) -> usize {
        self.c_pad
    }
}

/// One gradient request of a round, executed by [`Runtime::grad_batch`].
/// All fields borrow the caller's buffers — assembling a batch allocates
/// nothing beyond the `Vec` of jobs.
#[derive(Clone, Copy)]
pub struct GradJob<'a> {
    pub xhat: &'a Mat,
    pub y: &'a Mat,
    pub mask: &'a [f32],
}

#[cfg(feature = "pjrt")]
struct Compiled {
    exe: xla::PjRtLoadedExecutable,
}

#[cfg(feature = "pjrt")]
impl Compiled {
    fn load(client: &xla::PjRtClient, path: &Path) -> Result<Compiled> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling {path:?}"))?;
        Ok(Compiled { exe })
    }

    /// Execute and return the single tuple element (graphs are lowered with
    /// `return_tuple=True`).
    fn run1(&self, inputs: &[xla::Literal]) -> Result<xla::Literal> {
        let bufs = self.exe.execute::<xla::Literal>(inputs)?;
        let lit = bufs[0][0].to_literal_sync()?;
        Ok(lit.to_tuple1()?)
    }

    /// Execute and return a 2-tuple (encode graph).
    fn run2(&self, inputs: &[xla::Literal]) -> Result<(xla::Literal, xla::Literal)> {
        let bufs = self.exe.execute::<xla::Literal>(inputs)?;
        let lit = bufs[0][0].to_literal_sync()?;
        Ok(lit.to_tuple2()?)
    }
}

/// One compiled executable per artifact the experiment uses; construction
/// compiles everything up front so the training loop never hits a compile
/// stall.
#[cfg(feature = "pjrt")]
struct PjrtExec {
    embed: Compiled,
    grad_client: Compiled,
    grad_server: Compiled,
    encode: Compiled,
    predict: Compiled,
}

enum Backend {
    Native(NativeExec),
    #[cfg(feature = "pjrt")]
    Pjrt(Box<PjrtExec>),
}

/// Owns the executor backend plus the experiment's shape set.
pub struct Runtime {
    shapes: RuntimeShapes,
    backend: Backend,
    /// Resolved worker-thread count of the native backend (1 on PJRT).
    threads: usize,
    /// Running count of executor invocations (telemetry for §Perf).
    exec_count: AtomicU64,
    /// Residual-panel scratch for single `grad_into` calls (grows once,
    /// then warm; batched grads use the pool workers' arenas instead).
    r_scratch: Mutex<Vec<f32>>,
}

impl Runtime {
    /// Build the runtime for `shapes` with automatic thread count.
    ///
    /// With the `pjrt` feature: loads `artifacts_dir/manifest.txt`,
    /// resolves the five artifacts the shape set needs and compiles them
    /// on the CPU PJRT client (failing fast if any is missing). Without
    /// it: returns the native executor and ignores `artifacts_dir`.
    pub fn load(artifacts_dir: &Path, shapes: RuntimeShapes) -> Result<Runtime> {
        Self::load_with(artifacts_dir, shapes, 0)
    }

    /// [`Runtime::load`] with an explicit native worker-thread count
    /// (`0` = available parallelism; ignored by the PJRT backend) and the
    /// default `auto` SIMD policy.
    pub fn load_with(
        artifacts_dir: &Path,
        shapes: RuntimeShapes,
        threads: usize,
    ) -> Result<Runtime> {
        Self::load_with_policy(artifacts_dir, shapes, threads, SimdPolicy::Auto)
    }

    /// [`Runtime::load_with`] plus an explicit SIMD policy for the native
    /// backend's GEMM microkernel (`auto` detects AVX2+FMA / NEON once at
    /// construction, `scalar` pins the bit-exact fallback; ignored by the
    /// PJRT backend, which executes compiled artifacts).
    pub fn load_with_policy(
        artifacts_dir: &Path,
        shapes: RuntimeShapes,
        threads: usize,
        simd: SimdPolicy,
    ) -> Result<Runtime> {
        #[cfg(feature = "pjrt")]
        {
            let _ = (threads, simd);
            Self::load_pjrt(artifacts_dir, shapes)
        }
        #[cfg(not(feature = "pjrt"))]
        {
            let _ = artifacts_dir;
            Ok(Self::native_with(shapes, threads, simd))
        }
    }

    /// The pure-Rust executor (always available), automatic thread count
    /// and `auto` SIMD policy.
    pub fn native(shapes: RuntimeShapes) -> Runtime {
        Self::native_with_threads(shapes, 0)
    }

    /// The pure-Rust executor with an explicit worker-thread count
    /// (`0` = available parallelism) and `auto` SIMD policy. The worker
    /// pool is spawned here, once. Results are identical for every
    /// count; `threads = 1` reproduces the serial executor bit-for-bit.
    pub fn native_with_threads(shapes: RuntimeShapes, threads: usize) -> Runtime {
        Self::native_with(shapes, threads, SimdPolicy::Auto)
    }

    /// [`Runtime::native_with_threads`] plus an explicit [`SimdPolicy`]
    /// — the resolved ISA ([`Runtime::isa`]) is fixed here, once, and
    /// every kernel call dispatches through it.
    pub fn native_with(shapes: RuntimeShapes, threads: usize, simd: SimdPolicy) -> Runtime {
        let exec = NativeExec::with_policy(threads, simd);
        Runtime {
            shapes,
            threads: exec.threads(),
            backend: Backend::Native(exec),
            exec_count: AtomicU64::new(0),
            r_scratch: Mutex::new(Vec::new()),
        }
    }

    #[cfg(feature = "pjrt")]
    fn load_pjrt(artifacts_dir: &Path, shapes: RuntimeShapes) -> Result<Runtime> {
        let man = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let RuntimeShapes { d, q, c, l_client, u_max, b_embed } = shapes;

        let find = |kind: &str, dims: &[(&str, usize)]| -> Result<Compiled> {
            let entry = man.require(kind, dims)?;
            Compiled::load(&client, &man.path(entry))
        };
        let exec = PjrtExec {
            embed: find("rff_embed", &[("b", b_embed), ("d", d), ("q", q)])?,
            grad_client: find("grad", &[("l", l_client), ("q", q), ("c", c)])?,
            grad_server: find("grad", &[("l", u_max), ("q", q), ("c", c)])?,
            encode: find("encode", &[("u", u_max), ("l", l_client), ("q", q), ("c", c)])?,
            predict: find("predict", &[("b", b_embed), ("q", q), ("c", c)])?,
        };
        Ok(Runtime {
            shapes,
            threads: 1,
            backend: Backend::Pjrt(Box::new(exec)),
            exec_count: AtomicU64::new(0),
            r_scratch: Mutex::new(Vec::new()),
        })
    }

    pub fn shapes(&self) -> RuntimeShapes {
        self.shapes
    }

    /// `"native"` or `"pjrt"` — which executor this runtime dispatches to.
    pub fn backend_name(&self) -> &'static str {
        match &self.backend {
            Backend::Native(_) => "native",
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(_) => "pjrt",
        }
    }

    /// Resolved worker-thread count (≥ 1; always 1 on the PJRT backend).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The GEMM instruction set the native backend resolved at
    /// construction (`None` on the PJRT backend, which runs compiled
    /// artifacts instead of the in-process microkernels).
    pub fn isa(&self) -> Option<Isa> {
        match &self.backend {
            Backend::Native(nb) => Some(nb.isa()),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(_) => None,
        }
    }

    /// Telemetry string for the selected microkernel ISA (`"scalar"`,
    /// `"avx2+fma"`, `"neon"`, or `"pjrt"` on the artifact backend) —
    /// recorded in `BENCH_hotpath.json` (schema 3).
    pub fn isa_name(&self) -> &'static str {
        match self.isa() {
            Some(isa) => isa.name(),
            None => "pjrt",
        }
    }

    /// The native backend's persistent worker pool (`None` on PJRT).
    /// Exposed for the worker-reuse tests and pool-level telemetry.
    pub fn worker_pool(&self) -> Option<&WorkerPool> {
        match &self.backend {
            Backend::Native(nb) => Some(nb.pool()),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(_) => None,
        }
    }

    /// Total executor invocations so far (telemetry for §Perf).
    pub fn exec_count(&self) -> u64 {
        self.exec_count.load(Ordering::Relaxed)
    }

    fn bump(&self) {
        self.exec_count.fetch_add(1, Ordering::Relaxed);
    }

    /// RFF-embed `x [n, d]`. `omega [d, q]`, `delta [q]`. On the PJRT path
    /// the input is chunked over the compiled row-block, the last chunk
    /// zero-padded and trimmed.
    pub fn embed(&self, x: &Mat, omega: &Mat, delta: &[f32]) -> Result<Mat> {
        let RuntimeShapes { d, q, .. } = self.shapes;
        anyhow::ensure!(x.cols() == d, "embed: x has d={}, compiled d={d}", x.cols());
        anyhow::ensure!(omega.rows() == d && omega.cols() == q, "embed: omega shape");
        anyhow::ensure!(delta.len() == q, "embed: delta len");
        match &self.backend {
            Backend::Native(nb) => {
                self.bump();
                Ok(nb.embed(x, omega, delta))
            }
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(p) => {
                let b_embed = self.shapes.b_embed;
                let omega_l = mat_to_literal(omega)?;
                let delta_l = vec_to_literal(delta);
                let n = x.rows();
                let mut out = Mat::zeros(n, q);
                let mut start = 0;
                while start < n {
                    let take = (n - start).min(b_embed);
                    let chunk = x.rows_slice(start, take).pad_rows(b_embed);
                    self.bump();
                    let lit = p.embed.run1(&[
                        mat_to_literal(&chunk)?,
                        omega_l.clone(),
                        delta_l.clone(),
                    ])?;
                    let res = literal_to_mat(&lit, b_embed, q)?;
                    out.as_mut_slice()[start * q..(start + take) * q]
                        .copy_from_slice(&res.as_slice()[..take * q]);
                    start += take;
                }
                Ok(out)
            }
        }
    }

    /// Pre-convert θ once per round (see [`PreparedTheta`]), allocating
    /// the packed panel when `c` is not tile-aligned. Hot loops should
    /// prefer [`Runtime::prepare_theta_into`], which reuses a caller
    /// buffer instead.
    pub fn prepare_theta<'a>(&self, theta: &'a Mat) -> Result<PreparedTheta<'a>> {
        self.prepare_theta_impl(theta, None)
    }

    /// [`Runtime::prepare_theta`] packing into a caller-owned panel buffer
    /// (capacity reused across rounds — zero allocation once warm).
    pub fn prepare_theta_into<'a>(
        &self,
        theta: &'a Mat,
        panel: &'a mut Vec<f32>,
    ) -> Result<PreparedTheta<'a>> {
        self.prepare_theta_impl(theta, Some(panel))
    }

    /// The one copy of the panel policy behind both `prepare_theta` entry
    /// points: skip on PJRT, borrow θ when tile-aligned, otherwise pack —
    /// into `buf` when the caller supplied one, into a fresh allocation
    /// otherwise.
    fn prepare_theta_impl<'a>(
        &self,
        theta: &'a Mat,
        buf: Option<&'a mut Vec<f32>>,
    ) -> Result<PreparedTheta<'a>> {
        let c = self.check_theta(theta)?;
        let (packed, c_pad) = if !self.packs_panels() {
            // PJRT reads θ through its device literal; no panel needed.
            (Cow::Borrowed(&[] as &[f32]), c)
        } else if tile_padded_cols(c) == c {
            (Cow::Borrowed(theta.as_slice()), c)
        } else {
            match buf {
                Some(buf) => {
                    let c_pad = pack_tile_panel(theta, buf);
                    (Cow::Borrowed(&buf[..]), c_pad)
                }
                None => {
                    let mut panel = Vec::new();
                    let c_pad = pack_tile_panel(theta, &mut panel);
                    (Cow::Owned(panel), c_pad)
                }
            }
        };
        Ok(PreparedTheta {
            mat: theta,
            packed,
            c_pad,
            #[cfg(feature = "pjrt")]
            lit: self.theta_literal(theta)?,
        })
    }

    /// Whether this backend reads θ through the packed tile panel (the
    /// native kernels do; PJRT reads the device literal instead, so
    /// packing would be dead per-round work there).
    fn packs_panels(&self) -> bool {
        match &self.backend {
            Backend::Native(_) => true,
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(_) => false,
        }
    }

    /// Shared θ shape check; returns `c`.
    fn check_theta(&self, theta: &Mat) -> Result<usize> {
        let RuntimeShapes { q, c, .. } = self.shapes;
        anyhow::ensure!(theta.rows() == q && theta.cols() == c, "theta shape");
        Ok(c)
    }

    #[cfg(feature = "pjrt")]
    fn theta_literal(&self, theta: &Mat) -> Result<Option<xla::Literal>> {
        Ok(match &self.backend {
            Backend::Pjrt(_) => Some(mat_to_literal(theta)?),
            _ => None,
        })
    }

    /// Shape checks shared by the grad entry points.
    fn check_grad_shapes(&self, xhat: &Mat, y: &Mat, mask: &[f32]) -> Result<()> {
        let RuntimeShapes { q, c, l_client, u_max, .. } = self.shapes;
        anyhow::ensure!(xhat.cols() == q && y.cols() == c, "grad: payload shape");
        anyhow::ensure!(xhat.rows() == y.rows() && mask.len() == xhat.rows(), "grad: rows");
        let n = xhat.rows();
        anyhow::ensure!(
            n <= u_max.max(l_client),
            "grad: {n} rows exceeds largest compiled shape {}",
            u_max.max(l_client)
        );
        Ok(())
    }

    /// Masked gradient `X̂ᵀ diag(mask) (X̂θ − Y)` over up to `l_client`
    /// (client) or `u_max` (server/parity) rows.
    pub fn grad(&self, xhat: &Mat, y: &Mat, theta: &Mat, mask: &[f32]) -> Result<Mat> {
        let prepared = self.prepare_theta(theta)?;
        self.grad_prepared(xhat, y, &prepared, mask)
    }

    /// [`Runtime::grad`] with a pre-converted θ.
    pub fn grad_prepared(
        &self,
        xhat: &Mat,
        y: &Mat,
        theta: &PreparedTheta,
        mask: &[f32],
    ) -> Result<Mat> {
        let RuntimeShapes { q, c, .. } = self.shapes;
        let mut out = Mat::zeros(q, c);
        self.grad_into(xhat, y, theta, mask, &mut out)?;
        Ok(out)
    }

    /// [`Runtime::grad_prepared`] into a caller-owned `out` (`[q, c]`,
    /// overwritten) — the allocation-free form the engine's round loop and
    /// schemes' held buffers use.
    pub fn grad_into(
        &self,
        xhat: &Mat,
        y: &Mat,
        theta: &PreparedTheta,
        mask: &[f32],
        out: &mut Mat,
    ) -> Result<()> {
        self.check_grad_shapes(xhat, y, mask)?;
        let RuntimeShapes { q, c, .. } = self.shapes;
        anyhow::ensure!(
            out.rows() == q && out.cols() == c,
            "grad: out must be [{q}, {c}], got [{}, {}]",
            out.rows(),
            out.cols()
        );
        self.bump();
        match &self.backend {
            Backend::Native(nb) => {
                let mut r = self.r_scratch.lock().unwrap_or_else(PoisonError::into_inner);
                nb.grad_into(xhat, y, theta.panel(), theta.padded_cols(), mask, &mut r, out);
                Ok(())
            }
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(p) => {
                let RuntimeShapes { l_client, u_max, .. } = self.shapes;
                let n = xhat.rows();
                let (l, exe) = if n <= l_client {
                    (l_client, &p.grad_client)
                } else {
                    (u_max, &p.grad_server)
                };
                let mut mask_p = mask.to_vec();
                mask_p.resize(l, 0.0);
                let lit = exe.run1(&[
                    mat_to_literal(&xhat.pad_rows(l))?,
                    mat_to_literal(&y.pad_rows(l))?,
                    theta.lit.as_ref().expect("pjrt theta literal").clone(),
                    vec_to_literal(&mask_p),
                ])?;
                let g = literal_to_mat(&lit, q, c)?;
                out.as_mut_slice().copy_from_slice(g.as_slice());
                Ok(())
            }
        }
    }

    /// Execute a round's independent gradient requests, in input order
    /// (allocating wrapper over [`Runtime::grad_batch_into`]).
    pub fn grad_batch(&self, jobs: &[GradJob<'_>], theta: &PreparedTheta) -> Result<Vec<Mat>> {
        let RuntimeShapes { q, c, .. } = self.shapes;
        let mut outs: Vec<Mat> = jobs.iter().map(|_| Mat::zeros(q, c)).collect();
        self.grad_batch_into(jobs, theta, &mut outs)?;
        Ok(outs)
    }

    /// Execute a round's independent gradient requests into caller-owned
    /// output slots (`outs[i] = grad(jobs[i])`, each `[q, c]`,
    /// overwritten), in input order.
    ///
    /// On the native backend the jobs are partitioned across the
    /// persistent worker pool (a single job instead runs the pool-parallel
    /// kernel). Outputs land in input order, so the caller's aggregation
    /// order — and therefore the aggregate's bits — do not depend on the
    /// thread count. The PJRT backend executes serially.
    pub fn grad_batch_into(
        &self,
        jobs: &[GradJob<'_>],
        theta: &PreparedTheta,
        outs: &mut [Mat],
    ) -> Result<()> {
        anyhow::ensure!(
            jobs.len() == outs.len(),
            "grad batch: {} jobs but {} output slots",
            jobs.len(),
            outs.len()
        );
        let RuntimeShapes { q, c, .. } = self.shapes;
        for (ji, job) in jobs.iter().enumerate() {
            self.check_grad_shapes(job.xhat, job.y, job.mask)
                .map_err(|e| e.context(format!("grad request {ji} of {}", jobs.len())))?;
        }
        for (ji, out) in outs.iter().enumerate() {
            anyhow::ensure!(
                out.rows() == q && out.cols() == c,
                "grad batch: output slot {ji} must be [{q}, {c}]"
            );
        }
        match &self.backend {
            Backend::Native(nb) => {
                self.exec_count.fetch_add(jobs.len() as u64, Ordering::Relaxed);
                let mut r = self.r_scratch.lock().unwrap_or_else(PoisonError::into_inner);
                nb.grad_batch_into(jobs, theta.panel(), theta.padded_cols(), &mut r, outs);
                Ok(())
            }
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(_) => {
                for (job, out) in jobs.iter().zip(outs.iter_mut()) {
                    let g = self.grad_prepared(job.xhat, job.y, theta, job.mask)?;
                    out.as_mut_slice().copy_from_slice(g.as_slice());
                }
                Ok(())
            }
        }
    }

    /// Parity encode: `G [u, l] (u ≤ u_max), w [l], X̂ [l, q], Y [l, c]` →
    /// `(X̌ [u_max, q], Y̌ [u_max, c])` (rows past `u` are zero).
    pub fn encode(&self, g: &Mat, w: &[f32], xhat: &Mat, y: &Mat) -> Result<(Mat, Mat)> {
        let RuntimeShapes { q, c, l_client, u_max, .. } = self.shapes;
        anyhow::ensure!(g.cols() == l_client, "encode: G cols {} != l {}", g.cols(), l_client);
        anyhow::ensure!(g.rows() <= u_max, "encode: u {} > u_max {}", g.rows(), u_max);
        anyhow::ensure!(w.len() == l_client, "encode: w len");
        anyhow::ensure!(
            xhat.rows() == l_client && xhat.cols() == q,
            "encode: xhat shape"
        );
        anyhow::ensure!(y.rows() == l_client && y.cols() == c, "encode: y shape");
        self.bump();
        match &self.backend {
            Backend::Native(nb) => Ok(nb.encode(g, w, xhat, y, u_max)),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(p) => {
                let (xp, yp) = p.encode.run2(&[
                    mat_to_literal(&g.pad_rows(u_max))?,
                    vec_to_literal(w),
                    mat_to_literal(xhat)?,
                    mat_to_literal(y)?,
                ])?;
                Ok((
                    literal_to_mat(&xp, u_max, q)?,
                    literal_to_mat(&yp, u_max, c)?,
                ))
            }
        }
    }

    /// Logits `X̂ θ` for `n` rows (allocating wrapper over
    /// [`Runtime::predict_into`]).
    pub fn predict(&self, xhat: &Mat, theta: &Mat) -> Result<Mat> {
        let prepared = self.prepare_theta(theta)?;
        let mut out = Mat::zeros(xhat.rows(), self.shapes.c);
        self.predict_into(xhat, &prepared, &mut out)?;
        Ok(out)
    }

    /// Logits `X̂ θ` into a caller-owned `out` (`[n, c]`, overwritten) —
    /// the allocation-free form the engine's evaluation probes hold
    /// buffers for. Chunked + padded like [`Runtime::embed`] on the PJRT
    /// path.
    pub fn predict_into(&self, xhat: &Mat, theta: &PreparedTheta, out: &mut Mat) -> Result<()> {
        let RuntimeShapes { q, c, .. } = self.shapes;
        anyhow::ensure!(xhat.cols() == q, "predict: xhat shape");
        anyhow::ensure!(
            out.rows() == xhat.rows() && out.cols() == c,
            "predict: out must be [{}, {c}]",
            xhat.rows()
        );
        match &self.backend {
            Backend::Native(nb) => {
                self.bump();
                nb.predict_into(xhat, theta.panel(), theta.padded_cols(), out);
                Ok(())
            }
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(p) => {
                let b_embed = self.shapes.b_embed;
                let theta_l = theta.lit.as_ref().expect("pjrt theta literal");
                let n = xhat.rows();
                let mut start = 0;
                while start < n {
                    let take = (n - start).min(b_embed);
                    let chunk = xhat.rows_slice(start, take).pad_rows(b_embed);
                    self.bump();
                    let lit = p.predict.run1(&[mat_to_literal(&chunk)?, theta_l.clone()])?;
                    let res = literal_to_mat(&lit, b_embed, c)?;
                    out.as_mut_slice()[start * c..(start + take) * c]
                        .copy_from_slice(&res.as_slice()[..take * c]);
                    start += take;
                }
                Ok(())
            }
        }
    }
}
