//! Typed executors behind [`Runtime`]: embed / grad / encode / predict.
//!
//! Two interchangeable backends sit behind one shape-checked API:
//!
//! * **native** (default) — pure-Rust kernels
//!   ([`super::native::NativeExec`]) matching the jnp oracles in
//!   `python/compile/kernels/ref.py`. No artifacts, no external deps.
//! * **pjrt** (`--features pjrt`) — the AOT HLO-text artifacts compiled
//!   through the PJRT C API (`xla` bindings required), padding each
//!   workload to the compiled shape (exactly — zero rows contribute zero)
//!   and unpadding results.
//!
//! The shape contract (`RuntimeShapes`, padding limits) is enforced on
//! both paths so natively-developed code never breaks under PJRT.

use std::path::Path;

use anyhow::Result;
#[cfg(feature = "pjrt")]
use anyhow::Context;

use super::native::NativeExec;
use crate::tensor::Mat;

#[cfg(feature = "pjrt")]
use super::manifest::Manifest;
#[cfg(feature = "pjrt")]
use super::{literal_to_mat, mat_to_literal, vec_to_literal};

/// The AOT shapes one experiment needs (mirrors
/// `python/compile/shapes.py::ShapeSet`).
#[derive(Clone, Copy, Debug)]
pub struct RuntimeShapes {
    pub d: usize,
    pub q: usize,
    pub c: usize,
    pub l_client: usize,
    pub u_max: usize,
    pub b_embed: usize,
}

/// A θ matrix pre-converted for the backend (see
/// [`Runtime::prepare_theta`]): the coordinator issues ~n+1 grad calls
/// against the same θ each round, so the conversion is hoisted off the
/// per-call path. Only the active backend's representation is
/// materialised.
pub struct PreparedTheta {
    mat: Option<Mat>,
    #[cfg(feature = "pjrt")]
    lit: Option<xla::Literal>,
}

#[cfg(feature = "pjrt")]
struct Compiled {
    exe: xla::PjRtLoadedExecutable,
}

#[cfg(feature = "pjrt")]
impl Compiled {
    fn load(client: &xla::PjRtClient, path: &Path) -> Result<Compiled> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling {path:?}"))?;
        Ok(Compiled { exe })
    }

    /// Execute and return the single tuple element (graphs are lowered with
    /// `return_tuple=True`).
    fn run1(&self, inputs: &[xla::Literal]) -> Result<xla::Literal> {
        let bufs = self.exe.execute::<xla::Literal>(inputs)?;
        let lit = bufs[0][0].to_literal_sync()?;
        Ok(lit.to_tuple1()?)
    }

    /// Execute and return a 2-tuple (encode graph).
    fn run2(&self, inputs: &[xla::Literal]) -> Result<(xla::Literal, xla::Literal)> {
        let bufs = self.exe.execute::<xla::Literal>(inputs)?;
        let lit = bufs[0][0].to_literal_sync()?;
        Ok(lit.to_tuple2()?)
    }
}

/// One compiled executable per artifact the experiment uses; construction
/// compiles everything up front so the training loop never hits a compile
/// stall.
#[cfg(feature = "pjrt")]
struct PjrtExec {
    embed: Compiled,
    grad_client: Compiled,
    grad_server: Compiled,
    encode: Compiled,
    predict: Compiled,
}

enum Backend {
    Native(NativeExec),
    #[cfg(feature = "pjrt")]
    Pjrt(Box<PjrtExec>),
}

/// Owns the executor backend plus the experiment's shape set.
pub struct Runtime {
    shapes: RuntimeShapes,
    backend: Backend,
    /// Running count of executor invocations (telemetry for §Perf).
    pub exec_count: std::cell::Cell<u64>,
}

impl Runtime {
    /// Build the runtime for `shapes`.
    ///
    /// With the `pjrt` feature: loads `artifacts_dir/manifest.txt`,
    /// resolves the five artifacts the shape set needs and compiles them
    /// on the CPU PJRT client (failing fast if any is missing). Without
    /// it: returns the native executor and ignores `artifacts_dir`.
    pub fn load(artifacts_dir: &Path, shapes: RuntimeShapes) -> Result<Runtime> {
        #[cfg(feature = "pjrt")]
        {
            Self::load_pjrt(artifacts_dir, shapes)
        }
        #[cfg(not(feature = "pjrt"))]
        {
            let _ = artifacts_dir;
            Ok(Self::native(shapes))
        }
    }

    /// The pure-Rust executor (always available).
    pub fn native(shapes: RuntimeShapes) -> Runtime {
        Runtime {
            shapes,
            backend: Backend::Native(NativeExec),
            exec_count: std::cell::Cell::new(0),
        }
    }

    #[cfg(feature = "pjrt")]
    fn load_pjrt(artifacts_dir: &Path, shapes: RuntimeShapes) -> Result<Runtime> {
        let man = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let RuntimeShapes { d, q, c, l_client, u_max, b_embed } = shapes;

        let find = |kind: &str, dims: &[(&str, usize)]| -> Result<Compiled> {
            let entry = man.require(kind, dims)?;
            Compiled::load(&client, &man.path(entry))
        };
        let exec = PjrtExec {
            embed: find("rff_embed", &[("b", b_embed), ("d", d), ("q", q)])?,
            grad_client: find("grad", &[("l", l_client), ("q", q), ("c", c)])?,
            grad_server: find("grad", &[("l", u_max), ("q", q), ("c", c)])?,
            encode: find("encode", &[("u", u_max), ("l", l_client), ("q", q), ("c", c)])?,
            predict: find("predict", &[("b", b_embed), ("q", q), ("c", c)])?,
        };
        Ok(Runtime {
            shapes,
            backend: Backend::Pjrt(Box::new(exec)),
            exec_count: std::cell::Cell::new(0),
        })
    }

    pub fn shapes(&self) -> RuntimeShapes {
        self.shapes
    }

    /// `"native"` or `"pjrt"` — which executor this runtime dispatches to.
    pub fn backend_name(&self) -> &'static str {
        match &self.backend {
            Backend::Native(_) => "native",
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(_) => "pjrt",
        }
    }

    fn bump(&self) {
        self.exec_count.set(self.exec_count.get() + 1);
    }

    /// RFF-embed `x [n, d]`. `omega [d, q]`, `delta [q]`. On the PJRT path
    /// the input is chunked over the compiled row-block, the last chunk
    /// zero-padded and trimmed.
    pub fn embed(&self, x: &Mat, omega: &Mat, delta: &[f32]) -> Result<Mat> {
        let RuntimeShapes { d, q, .. } = self.shapes;
        anyhow::ensure!(x.cols() == d, "embed: x has d={}, compiled d={d}", x.cols());
        anyhow::ensure!(omega.rows() == d && omega.cols() == q, "embed: omega shape");
        anyhow::ensure!(delta.len() == q, "embed: delta len");
        match &self.backend {
            Backend::Native(nb) => {
                self.bump();
                Ok(nb.embed(x, omega, delta))
            }
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(p) => {
                let b_embed = self.shapes.b_embed;
                let omega_l = mat_to_literal(omega)?;
                let delta_l = vec_to_literal(delta);
                let n = x.rows();
                let mut out = Mat::zeros(n, q);
                let mut start = 0;
                while start < n {
                    let take = (n - start).min(b_embed);
                    let chunk = x.rows_slice(start, take).pad_rows(b_embed);
                    self.bump();
                    let lit = p.embed.run1(&[
                        mat_to_literal(&chunk)?,
                        omega_l.clone(),
                        delta_l.clone(),
                    ])?;
                    let res = literal_to_mat(&lit, b_embed, q)?;
                    out.as_mut_slice()[start * q..(start + take) * q]
                        .copy_from_slice(&res.as_slice()[..take * q]);
                    start += take;
                }
                Ok(out)
            }
        }
    }

    /// Pre-convert θ once per round (see [`PreparedTheta`]).
    pub fn prepare_theta(&self, theta: &Mat) -> Result<PreparedTheta> {
        let RuntimeShapes { q, c, .. } = self.shapes;
        anyhow::ensure!(theta.rows() == q && theta.cols() == c, "theta shape");
        Ok(PreparedTheta {
            mat: match &self.backend {
                Backend::Native(_) => Some(theta.clone()),
                #[cfg(feature = "pjrt")]
                Backend::Pjrt(_) => None,
            },
            #[cfg(feature = "pjrt")]
            lit: match &self.backend {
                Backend::Pjrt(_) => Some(mat_to_literal(theta)?),
                _ => None,
            },
        })
    }

    /// Masked gradient `X̂ᵀ diag(mask) (X̂θ − Y)` over up to `l_client`
    /// (client) or `u_max` (server/parity) rows.
    pub fn grad(&self, xhat: &Mat, y: &Mat, theta: &Mat, mask: &[f32]) -> Result<Mat> {
        let prepared = self.prepare_theta(theta)?;
        self.grad_prepared(xhat, y, &prepared, mask)
    }

    /// [`Runtime::grad`] with a pre-converted θ.
    pub fn grad_prepared(
        &self,
        xhat: &Mat,
        y: &Mat,
        theta: &PreparedTheta,
        mask: &[f32],
    ) -> Result<Mat> {
        let RuntimeShapes { q, c, l_client, u_max, .. } = self.shapes;
        anyhow::ensure!(xhat.cols() == q && y.cols() == c, "grad: payload shape");
        anyhow::ensure!(xhat.rows() == y.rows() && mask.len() == xhat.rows(), "grad: rows");
        let n = xhat.rows();
        anyhow::ensure!(
            n <= u_max.max(l_client),
            "grad: {n} rows exceeds largest compiled shape {}",
            u_max.max(l_client)
        );
        self.bump();
        match &self.backend {
            Backend::Native(nb) => {
                let mat = theta.mat.as_ref().expect("native theta prepared");
                Ok(nb.grad(xhat, y, mat, mask))
            }
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(p) => {
                let (l, exe) = if n <= l_client {
                    (l_client, &p.grad_client)
                } else {
                    (u_max, &p.grad_server)
                };
                let mut mask_p = mask.to_vec();
                mask_p.resize(l, 0.0);
                let lit = exe.run1(&[
                    mat_to_literal(&xhat.pad_rows(l))?,
                    mat_to_literal(&y.pad_rows(l))?,
                    theta.lit.as_ref().expect("pjrt theta literal").clone(),
                    vec_to_literal(&mask_p),
                ])?;
                literal_to_mat(&lit, q, c)
            }
        }
    }

    /// Parity encode: `G [u, l] (u ≤ u_max), w [l], X̂ [l, q], Y [l, c]` →
    /// `(X̌ [u_max, q], Y̌ [u_max, c])` (rows past `u` are zero).
    pub fn encode(&self, g: &Mat, w: &[f32], xhat: &Mat, y: &Mat) -> Result<(Mat, Mat)> {
        let RuntimeShapes { q, c, l_client, u_max, .. } = self.shapes;
        anyhow::ensure!(g.cols() == l_client, "encode: G cols {} != l {}", g.cols(), l_client);
        anyhow::ensure!(g.rows() <= u_max, "encode: u {} > u_max {}", g.rows(), u_max);
        anyhow::ensure!(w.len() == l_client, "encode: w len");
        anyhow::ensure!(
            xhat.rows() == l_client && xhat.cols() == q,
            "encode: xhat shape"
        );
        anyhow::ensure!(y.rows() == l_client && y.cols() == c, "encode: y shape");
        self.bump();
        match &self.backend {
            Backend::Native(nb) => Ok(nb.encode(g, w, xhat, y, u_max)),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(p) => {
                let (xp, yp) = p.encode.run2(&[
                    mat_to_literal(&g.pad_rows(u_max))?,
                    vec_to_literal(w),
                    mat_to_literal(xhat)?,
                    mat_to_literal(y)?,
                ])?;
                Ok((
                    literal_to_mat(&xp, u_max, q)?,
                    literal_to_mat(&yp, u_max, c)?,
                ))
            }
        }
    }

    /// Logits `X̂ θ` for `n` rows (chunked + padded like [`Runtime::embed`]
    /// on the PJRT path).
    pub fn predict(&self, xhat: &Mat, theta: &Mat) -> Result<Mat> {
        let RuntimeShapes { q, c, .. } = self.shapes;
        anyhow::ensure!(xhat.cols() == q, "predict: xhat shape");
        anyhow::ensure!(theta.rows() == q && theta.cols() == c, "predict: theta shape");
        match &self.backend {
            Backend::Native(nb) => {
                self.bump();
                Ok(nb.predict(xhat, theta))
            }
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(p) => {
                let b_embed = self.shapes.b_embed;
                let theta_l = mat_to_literal(theta)?;
                let n = xhat.rows();
                let mut out = Mat::zeros(n, c);
                let mut start = 0;
                while start < n {
                    let take = (n - start).min(b_embed);
                    let chunk = xhat.rows_slice(start, take).pad_rows(b_embed);
                    self.bump();
                    let lit = p.predict.run1(&[mat_to_literal(&chunk)?, theta_l.clone()])?;
                    let res = literal_to_mat(&lit, b_embed, c)?;
                    out.as_mut_slice()[start * c..(start + take) * c]
                        .copy_from_slice(&res.as_slice()[..take * c]);
                    start += take;
                }
                Ok(out)
            }
        }
    }
}
