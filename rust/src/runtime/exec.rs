//! Typed executors over the compiled artifacts: embed / grad / encode /
//! predict, each padding its workload to the compiled shape (exactly —
//! zero rows contribute zero) and unpadding results.

use std::path::Path;

use anyhow::{Context, Result};

use super::manifest::Manifest;
use super::{literal_to_mat, mat_to_literal, vec_to_literal};
use crate::tensor::Mat;

/// The AOT shapes one experiment needs (mirrors
/// `python/compile/shapes.py::ShapeSet`).
#[derive(Clone, Copy, Debug)]
pub struct RuntimeShapes {
    pub d: usize,
    pub q: usize,
    pub c: usize,
    pub l_client: usize,
    pub u_max: usize,
    pub b_embed: usize,
}

/// A θ matrix pre-converted to an XLA literal (see
/// [`Runtime::prepare_theta`]).
pub struct PreparedTheta {
    lit: xla::Literal,
}

struct Compiled {
    exe: xla::PjRtLoadedExecutable,
}

impl Compiled {
    fn load(client: &xla::PjRtClient, path: &Path) -> Result<Compiled> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling {path:?}"))?;
        Ok(Compiled { exe })
    }

    /// Execute and return the single tuple element (graphs are lowered with
    /// `return_tuple=True`).
    fn run1(&self, inputs: &[xla::Literal]) -> Result<xla::Literal> {
        let bufs = self.exe.execute::<xla::Literal>(inputs)?;
        let lit = bufs[0][0].to_literal_sync()?;
        Ok(lit.to_tuple1()?)
    }

    /// Execute and return a 2-tuple (encode graph).
    fn run2(&self, inputs: &[xla::Literal]) -> Result<(xla::Literal, xla::Literal)> {
        let bufs = self.exe.execute::<xla::Literal>(inputs)?;
        let lit = bufs[0][0].to_literal_sync()?;
        Ok(lit.to_tuple2()?)
    }
}

/// Owns the PJRT client plus one compiled executable per artifact the
/// experiment uses. Construction compiles everything up front so the
/// training loop never hits a compile stall.
pub struct Runtime {
    shapes: RuntimeShapes,
    embed: Compiled,
    grad_client: Compiled,
    grad_server: Compiled,
    encode: Compiled,
    predict: Compiled,
    /// Running count of artifact executions (telemetry for §Perf).
    pub exec_count: std::cell::Cell<u64>,
}

impl Runtime {
    /// Load `artifacts_dir/manifest.txt`, resolve the five artifacts the
    /// shape set needs, and compile them on the CPU PJRT client.
    pub fn load(artifacts_dir: &Path, shapes: RuntimeShapes) -> Result<Runtime> {
        let man = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let RuntimeShapes { d, q, c, l_client, u_max, b_embed } = shapes;

        let find = |kind: &str, dims: &[(&str, usize)]| -> Result<Compiled> {
            let entry = man.require(kind, dims)?;
            Compiled::load(&client, &man.path(entry))
        };
        Ok(Runtime {
            shapes,
            embed: find("rff_embed", &[("b", b_embed), ("d", d), ("q", q)])?,
            grad_client: find("grad", &[("l", l_client), ("q", q), ("c", c)])?,
            grad_server: find("grad", &[("l", u_max), ("q", q), ("c", c)])?,
            encode: find("encode", &[("u", u_max), ("l", l_client), ("q", q), ("c", c)])?,
            predict: find("predict", &[("b", b_embed), ("q", q), ("c", c)])?,
            exec_count: std::cell::Cell::new(0),
        })
    }

    pub fn shapes(&self) -> RuntimeShapes {
        self.shapes
    }

    fn bump(&self) {
        self.exec_count.set(self.exec_count.get() + 1);
    }

    /// RFF-embed `x [n, d]` (chunked over the compiled row-block; the last
    /// chunk is zero-padded and trimmed). `omega [d, q]`, `delta [q]`.
    pub fn embed(&self, x: &Mat, omega: &Mat, delta: &[f32]) -> Result<Mat> {
        let RuntimeShapes { d, q, b_embed, .. } = self.shapes;
        anyhow::ensure!(x.cols() == d, "embed: x has d={}, compiled d={d}", x.cols());
        anyhow::ensure!(omega.rows() == d && omega.cols() == q, "embed: omega shape");
        anyhow::ensure!(delta.len() == q, "embed: delta len");
        let omega_l = mat_to_literal(omega)?;
        let delta_l = vec_to_literal(delta);
        let n = x.rows();
        let mut out = Mat::zeros(n, q);
        let mut start = 0;
        while start < n {
            let take = (n - start).min(b_embed);
            let chunk = x.rows_slice(start, take).pad_rows(b_embed);
            let res = self.run_embed(&chunk, &omega_l, &delta_l)?;
            out.as_mut_slice()[start * q..(start + take) * q]
                .copy_from_slice(&res.as_slice()[..take * q]);
            start += take;
        }
        Ok(out)
    }

    fn run_embed(
        &self,
        chunk: &Mat,
        omega_l: &xla::Literal,
        delta_l: &xla::Literal,
    ) -> Result<Mat> {
        self.bump();
        let lit = self.embed.run1(&[
            mat_to_literal(chunk)?,
            omega_l.clone(),
            delta_l.clone(),
        ])?;
        literal_to_mat(&lit, self.shapes.b_embed, self.shapes.q)
    }

    /// Pre-convert θ to an XLA literal once per round; the coordinator
    /// issues ~n+1 grad calls against the same θ each iteration, so
    /// hoisting the conversion off the per-call path is free speed
    /// (EXPERIMENTS.md §Perf iteration 2).
    pub fn prepare_theta(&self, theta: &Mat) -> Result<PreparedTheta> {
        let RuntimeShapes { q, c, .. } = self.shapes;
        anyhow::ensure!(theta.rows() == q && theta.cols() == c, "theta shape");
        Ok(PreparedTheta { lit: mat_to_literal(theta)? })
    }

    /// Masked gradient `X̂ᵀ diag(mask) (X̂θ − Y)` over up to `l_client`
    /// (client) or `u_max` (server/parity) rows; rows are zero-padded to
    /// the compiled shape, mask padded with 0.
    pub fn grad(&self, xhat: &Mat, y: &Mat, theta: &Mat, mask: &[f32]) -> Result<Mat> {
        let prepared = self.prepare_theta(theta)?;
        self.grad_prepared(xhat, y, &prepared, mask)
    }

    /// [`Runtime::grad`] with a pre-converted θ literal.
    pub fn grad_prepared(
        &self,
        xhat: &Mat,
        y: &Mat,
        theta: &PreparedTheta,
        mask: &[f32],
    ) -> Result<Mat> {
        let RuntimeShapes { q, c, l_client, u_max, .. } = self.shapes;
        anyhow::ensure!(xhat.cols() == q && y.cols() == c, "grad: payload shape");
        anyhow::ensure!(xhat.rows() == y.rows() && mask.len() == xhat.rows(), "grad: rows");
        let n = xhat.rows();
        let (l, exe) = if n <= l_client {
            (l_client, &self.grad_client)
        } else if n <= u_max {
            (u_max, &self.grad_server)
        } else {
            anyhow::bail!("grad: {n} rows exceeds largest compiled shape {u_max}");
        };
        let mut mask_p = mask.to_vec();
        mask_p.resize(l, 0.0);
        self.bump();
        let lit = exe.run1(&[
            mat_to_literal(&xhat.pad_rows(l))?,
            mat_to_literal(&y.pad_rows(l))?,
            theta.lit.clone(),
            vec_to_literal(&mask_p),
        ])?;
        literal_to_mat(&lit, q, c)
    }

    /// Parity encode: `G [u, l] (u ≤ u_max zero-padded), w [l], X̂ [l, q],
    /// Y [l, c]` → `(X̌ [u_max, q], Y̌ [u_max, c])`.
    pub fn encode(&self, g: &Mat, w: &[f32], xhat: &Mat, y: &Mat) -> Result<(Mat, Mat)> {
        let RuntimeShapes { q, c, l_client, u_max, .. } = self.shapes;
        anyhow::ensure!(g.cols() == l_client, "encode: G cols {} != l {}", g.cols(), l_client);
        anyhow::ensure!(g.rows() <= u_max, "encode: u {} > u_max {}", g.rows(), u_max);
        anyhow::ensure!(w.len() == l_client, "encode: w len");
        anyhow::ensure!(
            xhat.rows() == l_client && xhat.cols() == q,
            "encode: xhat shape"
        );
        anyhow::ensure!(y.rows() == l_client && y.cols() == c, "encode: y shape");
        self.bump();
        let (xp, yp) = self.encode.run2(&[
            mat_to_literal(&g.pad_rows(u_max))?,
            vec_to_literal(w),
            mat_to_literal(xhat)?,
            mat_to_literal(y)?,
        ])?;
        Ok((
            literal_to_mat(&xp, u_max, q)?,
            literal_to_mat(&yp, u_max, c)?,
        ))
    }

    /// Logits `X̂ θ` for `n` rows (chunked + padded like [`Runtime::embed`]).
    pub fn predict(&self, xhat: &Mat, theta: &Mat) -> Result<Mat> {
        let RuntimeShapes { q, c, b_embed, .. } = self.shapes;
        anyhow::ensure!(xhat.cols() == q, "predict: xhat shape");
        anyhow::ensure!(theta.rows() == q && theta.cols() == c, "predict: theta shape");
        let theta_l = mat_to_literal(theta)?;
        let n = xhat.rows();
        let mut out = Mat::zeros(n, c);
        let mut start = 0;
        while start < n {
            let take = (n - start).min(b_embed);
            let chunk = xhat.rows_slice(start, take).pad_rows(b_embed);
            self.bump();
            let lit = self.predict.run1(&[mat_to_literal(&chunk)?, theta_l.clone()])?;
            let res = literal_to_mat(&lit, b_embed, c)?;
            out.as_mut_slice()[start * c..(start + take) * c]
                .copy_from_slice(&res.as_slice()[..take * c]);
            start += take;
        }
        Ok(out)
    }
}
