//! Pure-Rust executor implementing the L1 kernel contracts — the *default*
//! training backend since 0.2.
//!
//! Each function mirrors its jnp oracle in `python/compile/kernels/ref.py`
//! — those oracles define what the kernels *mean*, so this backend and the
//! optional PJRT artifacts (`--features pjrt`) are interchangeable up to
//! f32 rounding. Unlike the first native port (a thin wrapper over
//! `Mat::matmul_ref`), this executor is built for throughput:
//!
//! * every matmul bottoms out in the cache-blocked, register-tiled kernel
//!   in [`crate::tensor`] (`matmul_ref` remains the test oracle);
//! * `grad` fuses the residual-mask pass into the prediction sweep and
//!   skips fully-masked rows before any arithmetic happens;
//! * `encode` hoists the duplicated `G[u,l]·w[l]` weight products into one
//!   per-row panel shared by the X̌ and Y̌ accumulations;
//! * `embed` computes the `x·Ω` panel and the `cos` transform in one fused
//!   pass per row block;
//! * all kernels run their *output rows* across a scoped thread pool
//!   ([`NativeExec::new`] picks the count; `0` = available parallelism).
//!
//! Determinism: threads partition disjoint output row blocks, and each
//! element accumulates its reduction terms in the same ascending order the
//! serial reference uses, so **every thread count produces bit-identical
//! results** — `threads = 1` and `threads = 64` match the pre-0.3 serial
//! executor exactly. This is what keeps training histories reproducible
//! across machines with different core counts (see `rust/PERF.md`).
//!
//! Shapes are unconstrained here (no compiled-shape padding needed), but
//! the [`super::Runtime`] wrappers still enforce the artifact shape
//! contract so code exercised natively keeps working on the PJRT path.

use crate::tensor::{matmul_rows_into, Mat};

/// Work (in multiply-adds) below which a kernel stays single-threaded —
/// spawning scoped threads costs tens of microseconds, which swamps tiny
/// kernels. Thresholding is safe because results are thread-count
/// invariant (see module docs).
const PAR_MIN_FLOPS: usize = 1 << 16;

/// Hard cap on worker threads. Every parallel kernel spawn is a real OS
/// thread, so a config typo like `threads = 100000` would otherwise turn
/// each call into a spawn storm (and `thread::scope` aborts if the OS
/// refuses a spawn). Results are thread-count invariant, so capping is
/// always safe.
const MAX_THREADS: usize = 512;

/// Balanced contiguous partition: `n` items into `t` runs whose lengths
/// differ by at most one (the first `n % t` runs take the extra item).
/// Shared by every parallel driver so no worker idles while another runs
/// a double-length chunk (the failure mode of `ceil`-sized chunking when
/// `n` is just above `t`).
pub(crate) fn run_lengths(n: usize, t: usize) -> impl Iterator<Item = usize> {
    let (base, extra) = (n / t, n % t);
    (0..t).map(move |bi| base + usize::from(bi < extra))
}

/// The native executor: stateless kernels plus a configured thread count.
#[derive(Clone, Copy, Debug)]
pub struct NativeExec {
    threads: usize,
}

impl Default for NativeExec {
    /// Defaults to all available parallelism (same as `NativeExec::new(0)`).
    fn default() -> Self {
        NativeExec::new(0)
    }
}

impl NativeExec {
    /// Executor with `threads` worker threads; `0` resolves to the
    /// machine's available parallelism. Capped at 512 (`MAX_THREADS`) —
    /// see the constant's docs.
    pub fn new(threads: usize) -> Self {
        let threads = if threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            threads
        };
        NativeExec { threads: threads.min(MAX_THREADS) }
    }

    /// Single-threaded executor (used per-job when a round's gradient
    /// requests are already being parallelised across jobs).
    pub fn single() -> Self {
        NativeExec { threads: 1 }
    }

    /// The resolved worker-thread count (≥ 1).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Thread count to use for a kernel costing `flops` multiply-adds.
    fn threads_for(&self, flops: usize) -> usize {
        if flops < PAR_MIN_FLOPS {
            1
        } else {
            self.threads
        }
    }

    /// RFF embedding (paper eq. 18): `sqrt(2/q) · cos(x Ω + δ)`.
    ///
    /// Fused per row block: the `x·Ω` panel is produced by the blocked
    /// matmul and transformed in place while still cache-hot.
    pub fn embed(&self, x: &Mat, omega: &Mat, delta: &[f32]) -> Mat {
        let (n, d) = (x.rows(), x.cols());
        let q = omega.cols();
        let mut out = Mat::zeros(n, q);
        if n == 0 || q == 0 {
            return out;
        }
        // The zip below would silently truncate on a short delta; the old
        // kernel's `delta[c]` indexing panicked instead. Keep it loud.
        debug_assert_eq!(delta.len(), q, "embed: delta len != q");
        let scale = (2.0f32 / q as f32).sqrt();
        let xs = x.as_slice();
        let os = omega.as_slice();
        par_row_blocks(
            self.threads_for(n * d.max(1) * q),
            n,
            q,
            out.as_mut_slice(),
            |r0, block| {
                let rows_here = block.len() / q;
                matmul_rows_into(&xs[r0 * d..(r0 + rows_here) * d], os, block, d, q);
                for row in block.chunks_exact_mut(q) {
                    for (v, &dl) in row.iter_mut().zip(delta) {
                        *v = scale * (*v + dl).cos();
                    }
                }
            },
        );
        out
    }

    /// Masked gradient (paper eqs. 7/10/28 numerator):
    /// `X̂ᵀ diag(mask) (X̂θ − Y)` → `[q, c]`, unnormalised.
    ///
    /// Pass 1 fuses prediction, residual and mask row-by-row (fully masked
    /// rows are skipped before any arithmetic); pass 2 forms `X̂ᵀ R` with
    /// the `q` output rows partitioned across threads, each accumulating
    /// over the data rows in ascending order.
    pub fn grad(&self, xhat: &Mat, y: &Mat, theta: &Mat, mask: &[f32]) -> Mat {
        let (l, q) = (xhat.rows(), xhat.cols());
        let c = y.cols();
        let mut g = Mat::zeros(q, c);
        if l == 0 || q == 0 || c == 0 {
            return g;
        }
        let xs = xhat.as_slice();
        let ts = theta.as_slice();
        // R = diag(mask)(X̂θ − Y), one fused sweep per row.
        let mut r = Mat::zeros(l, c);
        {
            let ys = y.as_slice();
            par_row_blocks(
                self.threads_for(l * q * c),
                l,
                c,
                r.as_mut_slice(),
                |i0, block| {
                    for (ii, rrow) in block.chunks_exact_mut(c).enumerate() {
                        let i = i0 + ii;
                        let m = mask[i];
                        if m == 0.0 {
                            continue; // row never enters the aggregate
                        }
                        matmul_rows_into(&xs[i * q..(i + 1) * q], ts, rrow, q, c);
                        for (rv, &yv) in rrow.iter_mut().zip(&ys[i * c..(i + 1) * c]) {
                            *rv = m * (*rv - yv);
                        }
                    }
                },
            );
        }
        // g = X̂ᵀ R: each thread owns a disjoint block of g's rows (a
        // contiguous k-range of X̂'s columns) and sweeps the data rows i in
        // ascending order — the serial reference's per-element order, so
        // the result is identical for every thread count.
        let rs = r.as_slice();
        par_row_blocks(
            self.threads_for(l * q * c),
            q,
            c,
            g.as_mut_slice(),
            |k0, gblock| {
                let kn = gblock.len() / c;
                for i in 0..l {
                    if mask[i] == 0.0 {
                        continue;
                    }
                    let xseg = &xs[i * q + k0..i * q + k0 + kn];
                    let rrow = &rs[i * c..(i + 1) * c];
                    for (kk, &xv) in xseg.iter().enumerate() {
                        let grow = &mut gblock[kk * c..(kk + 1) * c];
                        for (gv, &rv) in grow.iter_mut().zip(rrow) {
                            *gv += xv * rv;
                        }
                    }
                }
            },
        );
        g
    }

    /// Weighted random linear encode (paper eq. 19):
    /// `(G ⊙ w[None, :]) · D` for `D ∈ {X̂ [l, q], Y [l, c]}`, zero-padded
    /// to `u_max` output rows to match the compiled-artifact contract.
    ///
    /// The `G[u, l]·w[l]` products are computed once per output row into a
    /// per-thread scratch panel and shared by the X̌ and Y̌ accumulations
    /// (the first native port recomputed them for each).
    pub fn encode(&self, g: &Mat, w: &[f32], xhat: &Mat, y: &Mat, u_max: usize) -> (Mat, Mat) {
        let (u, l) = (g.rows(), g.cols());
        let (q, c) = (xhat.cols(), y.cols());
        let mut xp = Mat::zeros(u_max, q);
        let mut yp = Mat::zeros(u_max, c);
        if u == 0 || l == 0 {
            return (xp, yp);
        }
        debug_assert_eq!(w.len(), l, "encode: w len != l");
        let gs = g.as_slice();
        let xs = xhat.as_slice();
        let ys = y.as_slice();
        let worker = |u0: usize, rows_here: usize, xblock: &mut [f32], yblock: &mut [f32]| {
            let mut gw = vec![0.0f32; l]; // per-thread scratch panel
            for ui in 0..rows_here {
                let grow = &gs[(u0 + ui) * l..(u0 + ui + 1) * l];
                for (gv, (&ge, &we)) in gw.iter_mut().zip(grow.iter().zip(w)) {
                    *gv = ge * we;
                }
                if q > 0 {
                    let orow = &mut xblock[ui * q..(ui + 1) * q];
                    for (li, &gv) in gw.iter().enumerate() {
                        for (ov, &dv) in orow.iter_mut().zip(&xs[li * q..(li + 1) * q]) {
                            *ov += gv * dv;
                        }
                    }
                }
                if c > 0 {
                    let orow = &mut yblock[ui * c..(ui + 1) * c];
                    for (li, &gv) in gw.iter().enumerate() {
                        for (ov, &dv) in orow.iter_mut().zip(&ys[li * c..(li + 1) * c]) {
                            *ov += gv * dv;
                        }
                    }
                }
            }
        };
        // Only the live `u` rows are touched; rows `u..u_max` stay zero.
        let xp_live = &mut xp.as_mut_slice()[..u * q];
        let yp_live = &mut yp.as_mut_slice()[..u * c];
        let t = self.threads_for(u * l * (q + c)).min(u).max(1);
        if t == 1 || q == 0 || c == 0 {
            worker(0, u, xp_live, yp_live);
        } else {
            std::thread::scope(|s| {
                let mut xrest = xp_live;
                let mut yrest = yp_live;
                let mut u0 = 0;
                for rows_here in run_lengths(u, t) {
                    let (xchunk, xtail) =
                        std::mem::take(&mut xrest).split_at_mut(rows_here * q);
                    xrest = xtail;
                    let (ychunk, ytail) =
                        std::mem::take(&mut yrest).split_at_mut(rows_here * c);
                    yrest = ytail;
                    let worker = &worker;
                    s.spawn(move || worker(u0, rows_here, xchunk, ychunk));
                    u0 += rows_here;
                }
            });
        }
        (xp, yp)
    }

    /// Logits `X̂ θ` → `[n, c]` via the blocked matmul, rows across threads.
    pub fn predict(&self, xhat: &Mat, theta: &Mat) -> Mat {
        let (n, q) = (xhat.rows(), xhat.cols());
        let c = theta.cols();
        let mut out = Mat::zeros(n, c);
        if n == 0 || q == 0 || c == 0 {
            return out;
        }
        let xs = xhat.as_slice();
        let ts = theta.as_slice();
        par_row_blocks(
            self.threads_for(n * q * c),
            n,
            c,
            out.as_mut_slice(),
            |r0, block| {
                let rows_here = block.len() / c;
                matmul_rows_into(&xs[r0 * q..(r0 + rows_here) * q], ts, block, q, c);
            },
        );
        out
    }
}

/// Split `out` (a `rows × row_width` buffer) into contiguous row blocks and
/// run `f(first_row, block)` on each from its own scoped thread. Blocks are
/// disjoint, every element is written by exactly one thread, and `f` is
/// expected to preserve per-element accumulation order — together that
/// makes the result identical for every thread count.
fn par_row_blocks<F>(threads: usize, rows: usize, row_width: usize, out: &mut [f32], f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    debug_assert_eq!(out.len(), rows * row_width);
    let t = threads.min(rows).max(1);
    if t == 1 || row_width == 0 {
        f(0, out);
        return;
    }
    std::thread::scope(|s| {
        let mut rest = out;
        let mut row0 = 0;
        for rows_here in run_lengths(rows, t) {
            let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(rows_here * row_width);
            rest = tail;
            let f = &f;
            s.spawn(move || f(row0, chunk));
            row0 += rows_here;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn randn(rows: usize, cols: usize, rng: &mut Rng) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        rng.fill_normal_f32(m.as_mut_slice());
        m
    }

    #[test]
    fn grad_matches_elementwise_reference() {
        let mut rng = Rng::seed_from(7);
        let xhat = randn(6, 4, &mut rng);
        let y = randn(6, 3, &mut rng);
        let theta = randn(4, 3, &mut rng);
        let mask = [1.0, 0.0, 1.0, 0.5, 1.0, 0.0];
        let g = NativeExec::single().grad(&xhat, &y, &theta, &mask);
        // direct triple loop
        let mut want = Mat::zeros(4, 3);
        for i in 0..6 {
            for qc in 0..3 {
                let mut pred = 0.0f32;
                for k in 0..4 {
                    pred += xhat.get(i, k) * theta.get(k, qc);
                }
                let r = mask[i] * (pred - y.get(i, qc));
                for k in 0..4 {
                    want.set(k, qc, want.get(k, qc) + xhat.get(i, k) * r);
                }
            }
        }
        assert!(g.max_abs_diff(&want) < 1e-4, "diff {}", g.max_abs_diff(&want));
    }

    #[test]
    fn masked_rows_do_not_contribute() {
        let mut rng = Rng::seed_from(8);
        let xhat = randn(4, 3, &mut rng);
        let y = randn(4, 2, &mut rng);
        let theta = randn(3, 2, &mut rng);
        let ex = NativeExec::single();
        let g_masked = ex.grad(&xhat, &y, &theta, &[1.0, 1.0, 0.0, 0.0]);
        let g_sliced = ex.grad(
            &xhat.rows_slice(0, 2),
            &y.rows_slice(0, 2),
            &theta,
            &[1.0, 1.0],
        );
        assert!(g_masked.max_abs_diff(&g_sliced) < 1e-6);
    }

    #[test]
    fn encode_matches_reference_and_pads() {
        let mut rng = Rng::seed_from(9);
        let g = randn(3, 5, &mut rng);
        let w: Vec<f32> = (0..5).map(|i| 0.2 * i as f32).collect();
        let xhat = randn(5, 4, &mut rng);
        let y = randn(5, 2, &mut rng);
        let (xp, yp) = NativeExec::single().encode(&g, &w, &xhat, &y, 6);
        assert_eq!((xp.rows(), xp.cols()), (6, 4));
        assert_eq!((yp.rows(), yp.cols()), (6, 2));
        // padded rows are exactly zero
        assert!(xp.row(3).iter().chain(xp.row(5)).all(|&v| v == 0.0));
        // row 0 of xp = Σ_l g[0,l]·w[l]·xhat[l,:]
        for cc in 0..4 {
            let mut want = 0.0f32;
            for li in 0..5 {
                want += g.get(0, li) * w[li] * xhat.get(li, cc);
            }
            assert!((xp.get(0, cc) - want).abs() < 1e-4);
        }
    }

    #[test]
    fn embed_is_bounded_and_scaled() {
        let mut rng = Rng::seed_from(10);
        let x = randn(8, 5, &mut rng);
        let omega = randn(5, 16, &mut rng);
        let delta = vec![0.3f32; 16];
        let e = NativeExec::single().embed(&x, &omega, &delta);
        let bound = (2.0f32 / 16.0).sqrt() + 1e-6;
        assert!(e.as_slice().iter().all(|v| v.abs() <= bound));
    }

    #[test]
    fn thread_counts_are_bit_identical() {
        // Shapes chosen to clear PAR_MIN_FLOPS (128·128·8 = 131k madds) so
        // the scoped-thread path really runs.
        let mut rng = Rng::seed_from(11);
        let xhat = randn(128, 128, &mut rng);
        let y = randn(128, 8, &mut rng);
        let theta = randn(128, 8, &mut rng);
        let mask: Vec<f32> = (0..128).map(|i| if i % 7 == 0 { 0.0 } else { 1.0 }).collect();
        let base = NativeExec::single();
        for t in [2usize, 3, 8] {
            let ex = NativeExec::new(t);
            assert_eq!(
                base.grad(&xhat, &y, &theta, &mask).as_slice(),
                ex.grad(&xhat, &y, &theta, &mask).as_slice(),
                "grad diverged at {t} threads"
            );
            assert_eq!(
                base.predict(&xhat, &theta).as_slice(),
                ex.predict(&xhat, &theta).as_slice(),
                "predict diverged at {t} threads"
            );
        }
    }

    #[test]
    fn run_lengths_are_balanced_and_complete() {
        // n just above t is the case ceil-chunking got wrong (idle workers).
        for (n, t) in [(17usize, 16usize), (16, 16), (5, 2), (7, 3), (100, 7)] {
            let lens: Vec<usize> = run_lengths(n, t).collect();
            assert_eq!(lens.len(), t);
            assert_eq!(lens.iter().sum::<usize>(), n);
            let mn = *lens.iter().min().unwrap();
            let mx = *lens.iter().max().unwrap();
            assert!(mx - mn <= 1, "unbalanced: {lens:?}");
        }
    }

    #[test]
    fn thread_cap_is_applied() {
        assert_eq!(NativeExec::new(100_000).threads(), 512);
        assert_eq!(NativeExec::new(3).threads(), 3);
        assert!(NativeExec::new(0).threads() >= 1);
    }

    #[test]
    fn zero_row_inputs_are_handled() {
        let ex = NativeExec::new(4);
        let g = ex.grad(&Mat::zeros(0, 5), &Mat::zeros(0, 3), &Mat::zeros(5, 3), &[]);
        assert_eq!((g.rows(), g.cols()), (5, 3));
        assert!(g.as_slice().iter().all(|&v| v == 0.0));
        let (xp, yp) =
            ex.encode(&Mat::zeros(0, 4), &[0.5; 4], &Mat::zeros(4, 6), &Mat::zeros(4, 2), 8);
        assert_eq!((xp.rows(), yp.rows()), (8, 8));
        assert!(xp.as_slice().iter().all(|&v| v == 0.0));
    }
}
