//! Pure-Rust executor implementing the L1 kernel contracts.
//!
//! Each function mirrors its jnp oracle in `python/compile/kernels/ref.py`
//! — those oracles define what the kernels *mean*, so this backend and the
//! PJRT artifacts are interchangeable up to f32 rounding. It exists so the
//! whole crate builds, trains and tests in environments without the `xla`
//! bindings or the AOT artifacts (enable the `pjrt` feature to switch).
//!
//! Shapes are unconstrained here (no compiled-shape padding needed), but
//! the [`super::Runtime`] wrappers still enforce the artifact shape
//! contract so code exercised natively keeps working on the PJRT path.

use crate::tensor::Mat;

/// Marker struct: the native executor is stateless.
#[derive(Clone, Copy, Debug, Default)]
pub struct NativeExec;

impl NativeExec {
    /// RFF embedding (paper eq. 18): `sqrt(2/q) · cos(x Ω + δ)`.
    pub fn embed(&self, x: &Mat, omega: &Mat, delta: &[f32]) -> Mat {
        let q = omega.cols();
        let xo = x.matmul_ref(omega);
        let scale = (2.0f32 / q as f32).sqrt();
        Mat::from_fn(x.rows(), q, |r, c| scale * (xo.get(r, c) + delta[c]).cos())
    }

    /// Masked gradient (paper eqs. 7/10/28 numerator):
    /// `X̂ᵀ diag(mask) (X̂θ − Y)` → `[q, c]`, unnormalised.
    pub fn grad(&self, xhat: &Mat, y: &Mat, theta: &Mat, mask: &[f32]) -> Mat {
        let (l, q) = (xhat.rows(), xhat.cols());
        let c = y.cols();
        // R = diag(mask)(X̂θ − Y)
        let mut r = xhat.matmul_ref(theta);
        for i in 0..l {
            let m = mask[i];
            let rrow = &mut r.as_mut_slice()[i * c..(i + 1) * c];
            let yrow = y.row(i);
            for (rv, &yv) in rrow.iter_mut().zip(yrow) {
                *rv = m * (*rv - yv);
            }
        }
        // g = X̂ᵀ R, accumulated row-block by row-block ([q, c] stays hot).
        let mut g = Mat::zeros(q, c);
        for i in 0..l {
            if mask[i] == 0.0 {
                continue; // zero residual row contributes nothing
            }
            let xrow = xhat.row(i);
            let rrow = r.row(i);
            let gs = g.as_mut_slice();
            for (k, &xv) in xrow.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let grow = &mut gs[k * c..(k + 1) * c];
                for (gv, &rv) in grow.iter_mut().zip(rrow) {
                    *gv += xv * rv;
                }
            }
        }
        g
    }

    /// Weighted random linear encode (paper eq. 19):
    /// `(G ⊙ w[None, :]) · D` for `D ∈ {X̂ [l, q], Y [l, c]}`, zero-padded
    /// to `u_max` output rows to match the compiled-artifact contract.
    pub fn encode(
        &self,
        g: &Mat,
        w: &[f32],
        xhat: &Mat,
        y: &Mat,
        u_max: usize,
    ) -> (Mat, Mat) {
        let (u, l) = (g.rows(), g.cols());
        let (q, c) = (xhat.cols(), y.cols());
        let mut xp = Mat::zeros(u_max, q);
        let mut yp = Mat::zeros(u_max, c);
        for ui in 0..u {
            let grow = g.row(ui);
            let xrow_out = &mut xp.as_mut_slice()[ui * q..(ui + 1) * q];
            for li in 0..l {
                let gv = grow[li] * w[li];
                if gv == 0.0 {
                    continue;
                }
                for (ov, &dv) in xrow_out.iter_mut().zip(xhat.row(li)) {
                    *ov += gv * dv;
                }
            }
            let yrow_out = &mut yp.as_mut_slice()[ui * c..(ui + 1) * c];
            for li in 0..l {
                let gv = grow[li] * w[li];
                if gv == 0.0 {
                    continue;
                }
                for (ov, &dv) in yrow_out.iter_mut().zip(y.row(li)) {
                    *ov += gv * dv;
                }
            }
        }
        (xp, yp)
    }

    /// Logits `X̂ θ` → `[n, c]`.
    pub fn predict(&self, xhat: &Mat, theta: &Mat) -> Mat {
        xhat.matmul_ref(theta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn randn(rows: usize, cols: usize, rng: &mut Rng) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        rng.fill_normal_f32(m.as_mut_slice());
        m
    }

    #[test]
    fn grad_matches_elementwise_reference() {
        let mut rng = Rng::seed_from(7);
        let xhat = randn(6, 4, &mut rng);
        let y = randn(6, 3, &mut rng);
        let theta = randn(4, 3, &mut rng);
        let mask = [1.0, 0.0, 1.0, 0.5, 1.0, 0.0];
        let g = NativeExec.grad(&xhat, &y, &theta, &mask);
        // direct triple loop
        let mut want = Mat::zeros(4, 3);
        for i in 0..6 {
            for qc in 0..3 {
                let mut pred = 0.0f32;
                for k in 0..4 {
                    pred += xhat.get(i, k) * theta.get(k, qc);
                }
                let r = mask[i] * (pred - y.get(i, qc));
                for k in 0..4 {
                    want.set(k, qc, want.get(k, qc) + xhat.get(i, k) * r);
                }
            }
        }
        assert!(g.max_abs_diff(&want) < 1e-4, "diff {}", g.max_abs_diff(&want));
    }

    #[test]
    fn masked_rows_do_not_contribute() {
        let mut rng = Rng::seed_from(8);
        let xhat = randn(4, 3, &mut rng);
        let y = randn(4, 2, &mut rng);
        let theta = randn(3, 2, &mut rng);
        let g_masked = NativeExec.grad(&xhat, &y, &theta, &[1.0, 1.0, 0.0, 0.0]);
        let g_sliced = NativeExec.grad(
            &xhat.rows_slice(0, 2),
            &y.rows_slice(0, 2),
            &theta,
            &[1.0, 1.0],
        );
        assert!(g_masked.max_abs_diff(&g_sliced) < 1e-6);
    }

    #[test]
    fn encode_matches_reference_and_pads() {
        let mut rng = Rng::seed_from(9);
        let g = randn(3, 5, &mut rng);
        let w: Vec<f32> = (0..5).map(|i| 0.2 * i as f32).collect();
        let xhat = randn(5, 4, &mut rng);
        let y = randn(5, 2, &mut rng);
        let (xp, yp) = NativeExec.encode(&g, &w, &xhat, &y, 6);
        assert_eq!((xp.rows(), xp.cols()), (6, 4));
        assert_eq!((yp.rows(), yp.cols()), (6, 2));
        // padded rows are exactly zero
        assert!(xp.row(3).iter().chain(xp.row(5)).all(|&v| v == 0.0));
        // row 0 of xp = Σ_l g[0,l]·w[l]·xhat[l,:]
        for cc in 0..4 {
            let mut want = 0.0f32;
            for li in 0..5 {
                want += g.get(0, li) * w[li] * xhat.get(li, cc);
            }
            assert!((xp.get(0, cc) - want).abs() < 1e-4);
        }
    }

    #[test]
    fn embed_is_bounded_and_scaled() {
        let mut rng = Rng::seed_from(10);
        let x = randn(8, 5, &mut rng);
        let omega = randn(5, 16, &mut rng);
        let delta = vec![0.3f32; 16];
        let e = NativeExec.embed(&x, &omega, &delta);
        let bound = (2.0f32 / 16.0).sqrt() + 1e-6;
        assert!(e.as_slice().iter().all(|v| v.abs() <= bound));
    }
}
