//! Pure-Rust executor implementing the L1 kernel contracts — the *default*
//! training backend since 0.2.
//!
//! Each function mirrors its jnp oracle in `python/compile/kernels/ref.py`
//! — those oracles define what the kernels *mean*, so this backend and the
//! optional PJRT artifacts (`--features pjrt`) are interchangeable up to
//! f32 rounding. Unlike the first native port (a thin wrapper over
//! `Mat::matmul_ref`), this executor is built for throughput:
//!
//! * every matmul bottoms out in the ISA-dispatched GEMM microkernel of
//!   [`crate::tensor`] (`tensor::gemm_into`): an explicit AVX2+FMA
//!   (x86_64) or NEON (aarch64) 4×16 register-blocked kernel, selected
//!   **once** at executor construction from the configured
//!   [`SimdPolicy`] (`[runtime] simd`, CLI `--simd`) via runtime feature
//!   detection, with the scalar register-tile loop as the
//!   always-available fallback (`matmul_ref` remains the test oracle);
//!   SIMD row blocks pack the A-operand into the worker's persistent
//!   scratch arena, so dispatch stays allocation-free;
//! * `grad` fuses the residual-mask pass into the prediction sweep and
//!   skips fully-masked rows before any arithmetic happens;
//! * `grad` and `predict` read θ through a tile-aligned packed panel
//!   (built once per round by `Runtime::prepare_theta*` and shared by all
//!   n+1 grad calls plus predict), so the narrow class dimension runs as
//!   pure register tiles instead of the remainder path's per-`k` output
//!   row traffic;
//! * `encode` materialises each part's rows of the weighted generator
//!   `G ⊙ w` once into a panel in the worker's persistent scratch arena
//!   and runs both parity accumulations as register-blocked GEMMs over
//!   it;
//! * `embed` computes the `x·Ω` panel and the `cos` transform in one fused
//!   pass per row block;
//! * all kernels run their *output rows* across the persistent
//!   [`WorkerPool`] the executor owns — workers are spawned **once** (at
//!   `Session`/`Runtime` construction) and parked between jobs, so a
//!   parallel kernel call costs a targeted `unpark` per participating
//!   worker, not a `thread::scope` spawn/join (tens of microseconds,
//!   which used to swamp the per-client shapes of CodedFedL);
//! * the `*_into` variants write into caller-owned buffers, which is what
//!   lets `coordinator::engine` run steady-state rounds with **zero heap
//!   allocation** on the compute path (gated by `tests/alloc_gate.rs`).
//!
//! Determinism: threads partition disjoint output row blocks, and each
//! element accumulates its reduction terms in the same ascending order the
//! serial reference uses, so **every thread count produces bit-identical
//! results** under every ISA — with `simd = "scalar"`, `threads = 1` and
//! `threads = 64` match the pre-0.3 serial executor exactly, and the pool
//! path matches the pre-0.4 scoped-spawn path bit-for-bit (same
//! partitioning, same per-element order). A SIMD ISA changes the rounding
//! (fused multiply-adds; validated ≤ 1e-4 against the oracles) but not
//! the determinism: for a fixed ISA, results are reproducible run-to-run
//! and thread-count invariant, because an element's lane and op sequence
//! depend only on its position, never on the row partition. This is what
//! keeps training histories reproducible across machines with different
//! core counts (see `rust/PERF.md`).
//!
//! Shapes are unconstrained here (no compiled-shape padding needed), but
//! the [`super::Runtime`] wrappers still enforce the artifact shape
//! contract so code exercised natively keeps working on the PJRT path.

use std::fmt;
use std::sync::Arc;

use super::exec::GradJob;
use super::pool::WorkerPool;
use crate::tensor::{
    gemm_into, gemm_pack_len, pack_tile_panel, saxpy_into, tile_padded_cols, Isa, Mat, SimdPolicy,
};

/// Work (in multiply-adds) below which a kernel stays single-threaded —
/// even a parked-worker wakeup costs a few microseconds, which swamps tiny
/// kernels. Thresholding is safe because results are thread-count
/// invariant (see module docs).
const PAR_MIN_FLOPS: usize = 1 << 16;

/// Hard cap on worker threads. The pool spawns its workers exactly once,
/// but a config typo like `threads = 100000` would still try to park a
/// hundred thousand OS threads (and `WorkerPool::new` panics if the OS
/// refuses a spawn). Results are thread-count invariant, so capping is
/// always safe.
const MAX_THREADS: usize = 512;

/// Balanced contiguous partition: `n` items into `t` runs whose lengths
/// differ by at most one (the first `n % t` runs take the extra item).
/// The iterator form survives only as the test oracle for [`run_bounds`]
/// (its closed form), which every parallel driver now uses — no worker
/// idles while another runs a double-length chunk (the failure mode of
/// `ceil`-sized chunking when `n` is just above `t`).
#[cfg(test)]
fn run_lengths(n: usize, t: usize) -> impl Iterator<Item = usize> {
    let (base, extra) = (n / t, n % t);
    (0..t).map(move |bi| base + usize::from(bi < extra))
}

/// `(start, len)` of run `part` in the balanced contiguous partition of
/// `n` items into `t` runs (lengths differ by at most one; the first
/// `n % t` runs take the extra item). Pool tasks use this closed form to
/// locate their block without allocating a chunk list.
pub(crate) fn run_bounds(n: usize, t: usize, part: usize) -> (usize, usize) {
    let (base, extra) = (n / t, n % t);
    (part * base + part.min(extra), base + usize::from(part < extra))
}

/// Raw view of a caller-owned `&mut [f32]` that pool tasks carve into
/// disjoint blocks (a shared `Fn` task cannot capture `&mut` directly).
#[derive(Clone, Copy)]
struct OutPtr {
    ptr: *mut f32,
    len: usize,
}

// Safety: tasks only materialise disjoint subslices (checked by the
// callers' balanced-partition arithmetic), and the pool's latch keeps the
// underlying borrow alive until every task finished.
unsafe impl Send for OutPtr {}
unsafe impl Sync for OutPtr {}

impl OutPtr {
    fn new(s: &mut [f32]) -> OutPtr {
        OutPtr { ptr: s.as_mut_ptr(), len: s.len() }
    }

    /// Reborrow `[off, off + n)`.
    ///
    /// Safety: concurrent callers' ranges must be disjoint; bounds are
    /// checked for real (this guards raw-pointer writes, so it must not
    /// compile out in release builds).
    unsafe fn slice_mut<'a>(self, off: usize, n: usize) -> &'a mut [f32] {
        assert!(off + n <= self.len, "OutPtr: block [{off}, {}) out of bounds", off + n);
        std::slice::from_raw_parts_mut(self.ptr.add(off), n)
    }
}

/// Like [`OutPtr`] for a `&mut [Mat]` of per-job output slots.
#[derive(Clone, Copy)]
struct SlotPtr(*mut Mat);

// Safety: each slot index is written by exactly one pool task (jobs are
// partitioned into disjoint index ranges) within the pool latch's scope.
unsafe impl Send for SlotPtr {}
unsafe impl Sync for SlotPtr {}

/// The native executor: stateless kernels, the persistent worker pool
/// they dispatch onto, and the GEMM ISA resolved once at construction.
/// Cloning shares the pool (and copies the ISA).
#[derive(Clone)]
pub struct NativeExec {
    pool: Arc<WorkerPool>,
    /// The microkernel every matmul/saxpy in this executor dispatches to,
    /// resolved from the configured [`SimdPolicy`] exactly once — no
    /// per-call feature detection.
    isa: Isa,
}

impl fmt::Debug for NativeExec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "NativeExec[{} threads, {}]", self.threads(), self.isa.name())
    }
}

impl Default for NativeExec {
    /// Defaults to all available parallelism (same as `NativeExec::new(0)`).
    fn default() -> Self {
        NativeExec::new(0)
    }
}

impl NativeExec {
    /// Executor with `threads` worker threads and the `auto` SIMD policy
    /// (the config default — see [`NativeExec::with_policy`]); `0`
    /// resolves to the machine's available parallelism, capped at 512
    /// (`MAX_THREADS`). The pool (caller + `threads − 1` parked workers)
    /// is spawned here, once, and lives as long as the executor.
    pub fn new(threads: usize) -> Self {
        NativeExec::with_policy(threads, SimdPolicy::Auto)
    }

    /// [`NativeExec::new`] with an explicit SIMD policy: `Auto` detects
    /// the best ISA for this host once (AVX2+FMA / NEON / scalar),
    /// `Scalar` pins every kernel to the bit-exact fallback loop.
    pub fn with_policy(threads: usize, simd: SimdPolicy) -> Self {
        NativeExec {
            pool: Arc::new(WorkerPool::new(resolve_threads(threads))),
            isa: Isa::detect(simd),
        }
    }

    /// Single-threaded executor (no workers spawned; kernels run inline on
    /// the caller with the caller's scratch arena), `auto` SIMD policy.
    pub fn single() -> Self {
        NativeExec::with_policy(1, SimdPolicy::Auto)
    }

    /// The persistent pool kernels dispatch onto (exposed for the worker
    /// reuse tests and for callers that want to co-schedule work).
    pub fn pool(&self) -> &WorkerPool {
        &self.pool
    }

    /// The resolved GEMM instruction set every kernel dispatches to.
    pub fn isa(&self) -> Isa {
        self.isa
    }

    /// The resolved worker-thread count (≥ 1).
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// Thread count to use for a kernel costing `flops` multiply-adds.
    fn threads_for(&self, flops: usize) -> usize {
        if flops < PAR_MIN_FLOPS {
            1
        } else {
            self.threads()
        }
    }

    /// RFF embedding (paper eq. 18): `sqrt(2/q) · cos(x Ω + δ)`.
    ///
    /// Fused per row block: the `x·Ω` panel is produced by the blocked
    /// matmul directly in the output buffer and transformed in place while
    /// still cache-hot (no separate row panel exists to allocate).
    pub fn embed(&self, x: &Mat, omega: &Mat, delta: &[f32]) -> Mat {
        let (n, d) = (x.rows(), x.cols());
        let q = omega.cols();
        let mut out = Mat::zeros(n, q);
        if n == 0 || q == 0 {
            return out;
        }
        // The zip below would silently truncate on a short delta; the old
        // kernel's `delta[c]` indexing panicked instead. Keep it loud.
        debug_assert_eq!(delta.len(), q, "embed: delta len != q");
        let scale = (2.0f32 / q as f32).sqrt();
        let xs = x.as_slice();
        let os = omega.as_slice();
        let isa = self.isa;
        par_row_blocks(
            &self.pool,
            self.threads_for(n * d.max(1) * q),
            n,
            q,
            out.as_mut_slice(),
            |r0, block, scratch| {
                let rows_here = block.len() / q;
                let pack = gemm_pack_len(d);
                if scratch.len() < pack {
                    scratch.resize(pack, 0.0);
                }
                gemm_into(
                    isa,
                    &xs[r0 * d..(r0 + rows_here) * d],
                    os,
                    block,
                    d,
                    q,
                    &mut scratch[..pack],
                );
                for row in block.chunks_exact_mut(q) {
                    for (v, &dl) in row.iter_mut().zip(delta) {
                        *v = scale * (*v + dl).cos();
                    }
                }
            },
        );
        out
    }

    /// Masked gradient (paper eqs. 7/10/28 numerator):
    /// `X̂ᵀ diag(mask) (X̂θ − Y)` → `[q, c]`, unnormalised. Allocating
    /// wrapper over [`NativeExec::grad_into`] for tests and one-off calls.
    pub fn grad(&self, xhat: &Mat, y: &Mat, theta: &Mat, mask: &[f32]) -> Mat {
        let mut out = Mat::zeros(theta.rows(), theta.cols());
        let mut panel_buf = Vec::new();
        let (panel, c_pad) = panel_of(theta, &mut panel_buf);
        let mut r_buf = Vec::new();
        self.grad_into(xhat, y, panel, c_pad, mask, &mut r_buf, &mut out);
        out
    }

    /// [`NativeExec::grad`] into a caller-owned `out` (`[q, c]`,
    /// overwritten), reading θ through its tile-aligned `panel`
    /// (`[q, c_pad]`, see [`crate::tensor::pack_tile_panel`]) and using
    /// `r_buf` for the residual panel `R` (grown once, then reused).
    ///
    /// Pass 1 fuses prediction, residual and mask row-by-row (fully masked
    /// rows are skipped before any arithmetic); pass 2 forms `X̂ᵀ R` with
    /// the `q` output rows partitioned across the pool, each accumulating
    /// over the data rows in ascending order.
    #[allow(clippy::too_many_arguments)] // mirrors the kernel contract 1:1
    pub fn grad_into(
        &self,
        xhat: &Mat,
        y: &Mat,
        panel: &[f32],
        c_pad: usize,
        mask: &[f32],
        r_buf: &mut Vec<f32>,
        out: &mut Mat,
    ) {
        let (l, q) = (xhat.rows(), xhat.cols());
        let c = out.cols();
        out.as_mut_slice().fill(0.0);
        if l == 0 || q == 0 || c == 0 {
            return;
        }
        // Real asserts, not debug: these sizes feed the raw-pointer block
        // partitioning below, so a caller contract violation must panic in
        // release builds rather than write out of bounds.
        assert_eq!(out.rows(), q, "grad_into: out rows != q");
        assert_eq!(panel.len(), q * c_pad, "grad_into: panel shape");
        assert_eq!(mask.len(), l, "grad_into: mask len");
        let xs = xhat.as_slice();
        let flops = l * q * c;
        // R = diag(mask)(X̂θ − Y), one fused sweep per row. Stale rows from
        // earlier calls are harmless: pass 2 skips exactly the mask == 0
        // rows pass 1 skipped.
        if r_buf.len() < l * c {
            r_buf.resize(l * c, 0.0);
        }
        let (r_slice, _) = r_buf.split_at_mut(l * c);
        let isa = self.isa;
        {
            let ys = y.as_slice();
            par_row_blocks(
                &self.pool,
                self.threads_for(flops),
                l,
                c,
                r_slice,
                |i0, block, scratch| {
                    if scratch.len() < c_pad {
                        scratch.resize(c_pad, 0.0);
                    }
                    let row_pad = &mut scratch[..c_pad];
                    for (ii, rrow) in block.chunks_exact_mut(c).enumerate() {
                        let i = i0 + ii;
                        let m = mask[i];
                        if m == 0.0 {
                            continue; // row never enters the aggregate
                        }
                        // single-row GEMM: no A-pack needed
                        gemm_into(isa, &xs[i * q..(i + 1) * q], panel, row_pad, q, c_pad, &mut []);
                        for ((rv, &pv), &yv) in
                            rrow.iter_mut().zip(&row_pad[..c]).zip(&ys[i * c..(i + 1) * c])
                        {
                            *rv = m * (pv - yv);
                        }
                    }
                },
            );
        }
        // g = X̂ᵀ R: each thread owns a disjoint block of g's rows (a
        // contiguous k-range of X̂'s columns) and sweeps the data rows i in
        // ascending order — the serial reference's per-element order, so
        // the result is identical for every thread count. (Kept as a
        // saxpy accumulation rather than a GEMM: the mask-skipped rows
        // hold stale residuals that must never enter the product.)
        let rs: &[f32] = r_slice;
        par_row_blocks(
            &self.pool,
            self.threads_for(flops),
            q,
            c,
            out.as_mut_slice(),
            |k0, gblock, _scratch| {
                let kn = gblock.len() / c;
                for i in 0..l {
                    if mask[i] == 0.0 {
                        continue;
                    }
                    let xseg = &xs[i * q + k0..i * q + k0 + kn];
                    let rrow = &rs[i * c..(i + 1) * c];
                    for (kk, &xv) in xseg.iter().enumerate() {
                        saxpy_into(isa, xv, rrow, &mut gblock[kk * c..(kk + 1) * c]);
                    }
                }
            },
        );
    }

    /// Execute a round's independent gradient requests into caller-owned
    /// output slots, in input order.
    ///
    /// Scheduling: when jobs are scarce relative to the pool (fewer than
    /// half the threads), each job runs the pool-parallel
    /// [`NativeExec::grad_into`] kernel in turn; otherwise the jobs are
    /// partitioned across the pool's workers and each runs the serial
    /// kernel on its worker's persistent scratch arena. The serial and
    /// parallel kernels are bit-identical, so outputs (and the caller's
    /// fold order) never depend on the thread count or the crossover.
    pub fn grad_batch_into(
        &self,
        jobs: &[GradJob<'_>],
        panel: &[f32],
        c_pad: usize,
        r_buf: &mut Vec<f32>,
        outs: &mut [Mat],
    ) {
        assert_eq!(jobs.len(), outs.len(), "grad_batch_into: slot count");
        if jobs.is_empty() {
            return;
        }
        let t = self.threads().min(jobs.len());
        // With few jobs relative to the pool, one-worker-per-job would
        // idle most workers; per-job row parallelism (each job using the
        // whole pool in turn) recovers them. Both forms are bit-identical,
        // so the crossover is purely a scheduling choice.
        if t == 1 || jobs.len() * 2 <= self.threads() {
            for (j, out) in jobs.iter().zip(outs.iter_mut()) {
                self.grad_into(j.xhat, j.y, panel, c_pad, j.mask, r_buf, out);
            }
            return;
        }
        // Across-job parallelism: balanced contiguous job runs, one per
        // pool part, serial kernel per job (worker scratch holds the
        // packed prediction row and the residual panel).
        let n_jobs = jobs.len();
        let slots = SlotPtr(outs.as_mut_ptr());
        let isa = self.isa;
        self.pool.run(t, &|part, scratch| {
            let (j0, jn) = run_bounds(n_jobs, t, part);
            for ji in j0..j0 + jn {
                let job = &jobs[ji];
                // Safety: job index ranges are disjoint across parts.
                let out = unsafe { &mut *slots.0.add(ji) };
                grad_serial_packed(isa, job.xhat, job.y, panel, c_pad, job.mask, scratch, out);
            }
        });
    }

    /// Weighted random linear encode (paper eq. 19):
    /// `(G ⊙ w[None, :]) · D` for `D ∈ {X̂ [l, q], Y [l, c]}`, zero-padded
    /// to `u_max` output rows to match the compiled-artifact contract.
    ///
    /// Each pool part materialises its rows of the weighted generator
    /// `G ⊙ w` once into a panel in the worker's persistent scratch arena
    /// and runs the X̌ and Y̌ accumulations as GEMMs over it through the
    /// executor's ISA (the first native port recomputed the `G·w`
    /// products for each accumulation, and the second still swept them
    /// row by row). The wide X̌ side (`q`) vectorises; a sub-tile Y̌ side
    /// (`c < 16`) runs the kernel's scalar column tail.
    pub fn encode(&self, g: &Mat, w: &[f32], xhat: &Mat, y: &Mat, u_max: usize) -> (Mat, Mat) {
        let (u, l) = (g.rows(), g.cols());
        let (q, c) = (xhat.cols(), y.cols());
        let mut xp = Mat::zeros(u_max, q);
        let mut yp = Mat::zeros(u_max, c);
        if u == 0 || l == 0 {
            return (xp, yp);
        }
        debug_assert_eq!(w.len(), l, "encode: w len != l");
        let gs = g.as_slice();
        let xs = xhat.as_slice();
        let ys = y.as_slice();
        let isa = self.isa;
        // Only the live `u` rows are touched; rows `u..u_max` stay zero.
        let t = if q == 0 || c == 0 {
            1
        } else {
            self.threads_for(u * l * (q + c)).min(u).max(1)
        };
        let xp_ptr = OutPtr::new(&mut xp.as_mut_slice()[..u * q]);
        let yp_ptr = OutPtr::new(&mut yp.as_mut_slice()[..u * c]);
        self.pool.run(t, &|part, scratch| {
            let (u0, un) = run_bounds(u, t, part);
            if un == 0 {
                return;
            }
            // Safety: row ranges are disjoint across parts.
            let xblock = unsafe { xp_ptr.slice_mut(u0 * q, un * q) };
            let yblock = unsafe { yp_ptr.slice_mut(u0 * c, un * c) };
            // Scratch: the part's `G ⊙ w` panel rows, then the GEMM's
            // A-block pack area (grown once, then warm).
            let need = un * l + gemm_pack_len(l);
            if scratch.len() < need {
                scratch.resize(need, 0.0);
            }
            let (gw, pack) = scratch[..need].split_at_mut(un * l);
            let grows = gs[u0 * l..(u0 + un) * l].chunks_exact(l);
            for (gwrow, grow) in gw.chunks_exact_mut(l).zip(grows) {
                for (gv, (&ge, &we)) in gwrow.iter_mut().zip(grow.iter().zip(w)) {
                    *gv = ge * we;
                }
            }
            gemm_into(isa, gw, xs, xblock, l, q, pack);
            gemm_into(isa, gw, ys, yblock, l, c, pack);
        });
        (xp, yp)
    }

    /// Logits `X̂ θ` → `[n, c]`. Allocating wrapper over
    /// [`NativeExec::predict_into`].
    pub fn predict(&self, xhat: &Mat, theta: &Mat) -> Mat {
        let mut out = Mat::zeros(xhat.rows(), theta.cols());
        let mut panel_buf = Vec::new();
        let (panel, c_pad) = panel_of(theta, &mut panel_buf);
        self.predict_into(xhat, panel, c_pad, &mut out);
        out
    }

    /// Logits `X̂ θ` into a caller-owned `out` (`[n, c]`, overwritten),
    /// reading θ through its tile-aligned `panel` (`[q, c_pad]`). Rows
    /// run across the pool; with `c < c_pad` each row is computed as pure
    /// register tiles in the worker's scratch arena and its live prefix
    /// copied out.
    pub fn predict_into(&self, xhat: &Mat, panel: &[f32], c_pad: usize, out: &mut Mat) {
        let (n, q) = (xhat.rows(), xhat.cols());
        let c = out.cols();
        // Real asserts: these sizes feed the raw-pointer row partitioning.
        assert_eq!(out.rows(), n, "predict_into: out rows");
        assert_eq!(panel.len(), q * c_pad, "predict_into: panel shape");
        if n == 0 || q == 0 || c == 0 {
            out.as_mut_slice().fill(0.0);
            return;
        }
        let xs = xhat.as_slice();
        let threads = self.threads_for(n * q * c);
        let isa = self.isa;
        if c == c_pad {
            // θ itself is tile-aligned: write output rows directly.
            par_row_blocks(&self.pool, threads, n, c, out.as_mut_slice(), |r0, block, scratch| {
                let rows_here = block.len() / c;
                let pack = gemm_pack_len(q);
                if scratch.len() < pack {
                    scratch.resize(pack, 0.0);
                }
                block.fill(0.0);
                gemm_into(
                    isa,
                    &xs[r0 * q..(r0 + rows_here) * q],
                    panel,
                    block,
                    q,
                    c,
                    &mut scratch[..pack],
                );
            });
        } else {
            par_row_blocks(&self.pool, threads, n, c, out.as_mut_slice(), |r0, block, scratch| {
                if scratch.len() < c_pad {
                    scratch.resize(c_pad, 0.0);
                }
                let row_pad = &mut scratch[..c_pad];
                for (ii, orow) in block.chunks_exact_mut(c).enumerate() {
                    let i = r0 + ii;
                    // single-row GEMM: no A-pack needed
                    gemm_into(isa, &xs[i * q..(i + 1) * q], panel, row_pad, q, c_pad, &mut []);
                    orow.copy_from_slice(&row_pad[..c]);
                }
            });
        }
    }
}

/// Resolve a configured thread count to the pool size [`NativeExec::new`]
/// spawns: `0` → available parallelism, everything capped at
/// [`MAX_THREADS`]. Kept separate from the constructor so the clamp is
/// testable without actually parking 511 OS threads.
fn resolve_threads(threads: usize) -> usize {
    let threads = if threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        threads
    };
    threads.min(MAX_THREADS)
}

/// Borrow `theta` as a tile-aligned panel: zero-copy when the column
/// count is already tile-aligned, packed into `buf` otherwise.
pub(crate) fn panel_of<'a>(theta: &'a Mat, buf: &'a mut Vec<f32>) -> (&'a [f32], usize) {
    let c = theta.cols();
    if tile_padded_cols(c) == c {
        (theta.as_slice(), c)
    } else {
        let c_pad = pack_tile_panel(theta, buf);
        (&buf[..], c_pad)
    }
}

/// The serial masked gradient through the packed θ panel, into a
/// caller-owned `out` (`[q, c]`, overwritten). Bit-identical to the
/// parallel [`NativeExec::grad_into`] at the same ISA (same per-element
/// accumulation order); runs per-job on a pool worker inside
/// [`NativeExec::grad_batch_into`]. `scratch` holds the packed prediction
/// row followed by the residual panel `R` (grown once, then warm).
#[allow(clippy::too_many_arguments)] // mirrors the kernel contract 1:1
fn grad_serial_packed(
    isa: Isa,
    xhat: &Mat,
    y: &Mat,
    panel: &[f32],
    c_pad: usize,
    mask: &[f32],
    scratch: &mut Vec<f32>,
    out: &mut Mat,
) {
    let (l, q) = (xhat.rows(), xhat.cols());
    let c = out.cols();
    out.as_mut_slice().fill(0.0);
    if l == 0 || q == 0 || c == 0 {
        return;
    }
    debug_assert_eq!(mask.len(), l, "grad: mask len");
    let need = c_pad + l * c;
    if scratch.len() < need {
        scratch.resize(need, 0.0);
    }
    let (row_pad, rest) = scratch.split_at_mut(c_pad);
    let r = &mut rest[..l * c];
    let xs = xhat.as_slice();
    let ys = y.as_slice();
    for i in 0..l {
        let m = mask[i];
        if m == 0.0 {
            continue; // stale R row is fine: pass 2 skips it too
        }
        // single-row GEMM: no A-pack needed
        gemm_into(isa, &xs[i * q..(i + 1) * q], panel, row_pad, q, c_pad, &mut []);
        let rrow = &mut r[i * c..(i + 1) * c];
        for ((rv, &pv), &yv) in rrow.iter_mut().zip(&row_pad[..c]).zip(&ys[i * c..(i + 1) * c]) {
            *rv = m * (pv - yv);
        }
    }
    let gs = out.as_mut_slice();
    for i in 0..l {
        if mask[i] == 0.0 {
            continue;
        }
        let xrow = &xs[i * q..(i + 1) * q];
        let rrow = &r[i * c..(i + 1) * c];
        for (k, &xv) in xrow.iter().enumerate() {
            saxpy_into(isa, xv, rrow, &mut gs[k * c..(k + 1) * c]);
        }
    }
}

/// Split `out` (a `rows × row_width` buffer) into balanced contiguous row
/// blocks and run `f(first_row, block, scratch)` on each from its own pool
/// part (part 0 = the calling thread). Blocks are disjoint, every element
/// is written by exactly one thread, and `f` is expected to preserve
/// per-element accumulation order — together that makes the result
/// identical for every thread count. `scratch` is the part's persistent
/// arena (see [`WorkerPool`]).
fn par_row_blocks<F>(
    pool: &WorkerPool,
    threads: usize,
    rows: usize,
    row_width: usize,
    out: &mut [f32],
    f: F,
) where
    F: Fn(usize, &mut [f32], &mut Vec<f32>) + Sync,
{
    // Real assert: this length is what makes the raw-pointer row blocks
    // below in-bounds, so it must hold in release builds too.
    assert_eq!(out.len(), rows * row_width, "par_row_blocks: out len");
    let t = if row_width == 0 { 1 } else { threads.min(rows).max(1) };
    let out_ptr = OutPtr::new(out);
    let f = &f;
    pool.run(t, &move |part, scratch| {
        let (r0, rn) = run_bounds(rows, t, part);
        if rn * row_width == 0 {
            return;
        }
        // Safety: row ranges are disjoint across parts.
        let block = unsafe { out_ptr.slice_mut(r0 * row_width, rn * row_width) };
        f(r0, block, scratch);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn randn(rows: usize, cols: usize, rng: &mut Rng) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        rng.fill_normal_f32(m.as_mut_slice());
        m
    }

    #[test]
    fn grad_matches_elementwise_reference() {
        let mut rng = Rng::seed_from(7);
        let xhat = randn(6, 4, &mut rng);
        let y = randn(6, 3, &mut rng);
        let theta = randn(4, 3, &mut rng);
        let mask = [1.0, 0.0, 1.0, 0.5, 1.0, 0.0];
        let g = NativeExec::single().grad(&xhat, &y, &theta, &mask);
        // direct triple loop
        let mut want = Mat::zeros(4, 3);
        for i in 0..6 {
            for qc in 0..3 {
                let mut pred = 0.0f32;
                for k in 0..4 {
                    pred += xhat.get(i, k) * theta.get(k, qc);
                }
                let r = mask[i] * (pred - y.get(i, qc));
                for k in 0..4 {
                    want.set(k, qc, want.get(k, qc) + xhat.get(i, k) * r);
                }
            }
        }
        assert!(g.max_abs_diff(&want) < 1e-4, "diff {}", g.max_abs_diff(&want));
    }

    #[test]
    fn masked_rows_do_not_contribute() {
        let mut rng = Rng::seed_from(8);
        let xhat = randn(4, 3, &mut rng);
        let y = randn(4, 2, &mut rng);
        let theta = randn(3, 2, &mut rng);
        let ex = NativeExec::single();
        let g_masked = ex.grad(&xhat, &y, &theta, &[1.0, 1.0, 0.0, 0.0]);
        let g_sliced = ex.grad(
            &xhat.rows_slice(0, 2),
            &y.rows_slice(0, 2),
            &theta,
            &[1.0, 1.0],
        );
        assert!(g_masked.max_abs_diff(&g_sliced) < 1e-6);
    }

    #[test]
    fn grad_into_reuses_buffers_bit_for_bit() {
        let mut rng = Rng::seed_from(12);
        let xhat = randn(20, 17, &mut rng);
        let y = randn(20, 5, &mut rng);
        let theta = randn(17, 5, &mut rng);
        let mask: Vec<f32> = (0..20).map(|i| [1.0, 0.0, 0.5][i % 3]).collect();
        let ex = NativeExec::new(2);
        let want = ex.grad(&xhat, &y, &theta, &mask);
        let mut panel = Vec::new();
        let (p, c_pad) = panel_of(&theta, &mut panel);
        let mut out = Mat::zeros(17, 5);
        let mut r_buf = Vec::new();
        for _ in 0..3 {
            ex.grad_into(&xhat, &y, p, c_pad, &mask, &mut r_buf, &mut out);
            assert_eq!(out.as_slice(), want.as_slice());
        }
    }

    #[test]
    fn encode_matches_reference_and_pads() {
        let mut rng = Rng::seed_from(9);
        let g = randn(3, 5, &mut rng);
        let w: Vec<f32> = (0..5).map(|i| 0.2 * i as f32).collect();
        let xhat = randn(5, 4, &mut rng);
        let y = randn(5, 2, &mut rng);
        let (xp, yp) = NativeExec::single().encode(&g, &w, &xhat, &y, 6);
        assert_eq!((xp.rows(), xp.cols()), (6, 4));
        assert_eq!((yp.rows(), yp.cols()), (6, 2));
        // padded rows are exactly zero
        assert!(xp.row(3).iter().chain(xp.row(5)).all(|&v| v == 0.0));
        // row 0 of xp = Σ_l g[0,l]·w[l]·xhat[l,:]
        for cc in 0..4 {
            let mut want = 0.0f32;
            for li in 0..5 {
                want += g.get(0, li) * w[li] * xhat.get(li, cc);
            }
            assert!((xp.get(0, cc) - want).abs() < 1e-4);
        }
    }

    #[test]
    fn embed_is_bounded_and_scaled() {
        let mut rng = Rng::seed_from(10);
        let x = randn(8, 5, &mut rng);
        let omega = randn(5, 16, &mut rng);
        let delta = vec![0.3f32; 16];
        let e = NativeExec::single().embed(&x, &omega, &delta);
        let bound = (2.0f32 / 16.0).sqrt() + 1e-6;
        assert!(e.as_slice().iter().all(|v| v.abs() <= bound));
    }

    #[test]
    fn thread_counts_are_bit_identical() {
        // Shapes chosen to clear PAR_MIN_FLOPS (128·128·8 = 131k madds) so
        // the pooled parallel path really runs.
        let mut rng = Rng::seed_from(11);
        let xhat = randn(128, 128, &mut rng);
        let y = randn(128, 8, &mut rng);
        let theta = randn(128, 8, &mut rng);
        let mask: Vec<f32> = (0..128).map(|i| if i % 7 == 0 { 0.0 } else { 1.0 }).collect();
        let base = NativeExec::single();
        for t in [2usize, 3, 8] {
            let ex = NativeExec::new(t);
            assert_eq!(
                base.grad(&xhat, &y, &theta, &mask).as_slice(),
                ex.grad(&xhat, &y, &theta, &mask).as_slice(),
                "grad diverged at {t} threads"
            );
            assert_eq!(
                base.predict(&xhat, &theta).as_slice(),
                ex.predict(&xhat, &theta).as_slice(),
                "predict diverged at {t} threads"
            );
        }
    }

    #[test]
    fn run_lengths_are_balanced_and_complete() {
        // n just above t is the case ceil-chunking got wrong (idle workers).
        for (n, t) in [(17usize, 16usize), (16, 16), (5, 2), (7, 3), (100, 7)] {
            let lens: Vec<usize> = run_lengths(n, t).collect();
            assert_eq!(lens.len(), t);
            assert_eq!(lens.iter().sum::<usize>(), n);
            let mn = *lens.iter().min().unwrap();
            let mx = *lens.iter().max().unwrap();
            assert!(mx - mn <= 1, "unbalanced: {lens:?}");
            // the closed form agrees with the iterator
            let mut start = 0;
            for (part, len) in lens.iter().enumerate() {
                assert_eq!(run_bounds(n, t, part), (start, *len));
                start += len;
            }
        }
    }

    #[test]
    fn thread_cap_is_applied() {
        // The clamp is tested through resolve_threads — constructing a
        // NativeExec would really park MAX_THREADS − 1 workers.
        assert_eq!(resolve_threads(100_000), 512);
        assert_eq!(resolve_threads(512), 512);
        assert_eq!(resolve_threads(3), 3);
        assert!(resolve_threads(0) >= 1);
        assert_eq!(NativeExec::new(3).threads(), 3);
        assert!(NativeExec::new(0).threads() >= 1);
    }

    #[test]
    fn simd_policy_resolution_is_exposed_and_close() {
        let scalar = NativeExec::with_policy(1, SimdPolicy::Scalar);
        assert_eq!(scalar.isa(), Isa::Scalar);
        let auto = NativeExec::with_policy(1, SimdPolicy::Auto);
        assert!(!auto.isa().name().is_empty());
        // whatever auto resolved to stays within the documented 1e-4 of
        // the scalar path on a realistic gradient shape
        let mut rng = Rng::seed_from(13);
        let xhat = randn(33, 40, &mut rng);
        let y = randn(33, 6, &mut rng);
        let theta = randn(40, 6, &mut rng);
        let mask = vec![1.0f32; 33];
        let a = scalar.grad(&xhat, &y, &theta, &mask);
        let b = auto.grad(&xhat, &y, &theta, &mask);
        assert!(a.max_abs_diff(&b) <= 1e-4, "diff {}", a.max_abs_diff(&b));
    }

    #[test]
    fn zero_row_inputs_are_handled() {
        let ex = NativeExec::new(4);
        let g = ex.grad(&Mat::zeros(0, 5), &Mat::zeros(0, 3), &Mat::zeros(5, 3), &[]);
        assert_eq!((g.rows(), g.cols()), (5, 3));
        assert!(g.as_slice().iter().all(|&v| v == 0.0));
        let (xp, yp) =
            ex.encode(&Mat::zeros(0, 4), &[0.5; 4], &Mat::zeros(4, 6), &Mat::zeros(4, 2), 8);
        assert_eq!((xp.rows(), yp.rows()), (8, 8));
        assert!(xp.as_slice().iter().all(|&v| v == 0.0));
    }
}
