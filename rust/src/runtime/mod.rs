//! PJRT runtime: loads the AOT HLO-text artifacts and executes them on the
//! training hot path.
//!
//! Wiring (see /opt/xla-example and DESIGN.md §2): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` once per artifact → `execute` per call. HLO *text* is
//! the interchange format (jax ≥ 0.5 emits 64-bit-id protos that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids).
//!
//! Shape policy: artifacts are compiled for fixed shapes; smaller workloads
//! are zero-padded up to the compiled shape, which is *exact* for this
//! math (zero data/generator rows contribute zero gradient/parity — tested
//! in `python/tests/test_kernels_*.py` and `rust/tests/runtime_exec.rs`).

mod exec;
mod manifest;

pub use exec::{PreparedTheta, Runtime, RuntimeShapes};
pub use manifest::{Manifest, ManifestEntry};

use crate::tensor::Mat;

/// Convert a [`Mat`] into an XLA literal of the same `[rows, cols]` shape.
pub fn mat_to_literal(m: &Mat) -> anyhow::Result<xla::Literal> {
    Ok(xla::Literal::vec1(m.as_slice()).reshape(&[m.rows() as i64, m.cols() as i64])?)
}

/// Convert a 1-D slice into an XLA literal of shape `[len]`.
pub fn vec_to_literal(v: &[f32]) -> xla::Literal {
    xla::Literal::vec1(v)
}

/// Convert an XLA literal (known `[rows, cols]`) back into a [`Mat`].
pub fn literal_to_mat(lit: &xla::Literal, rows: usize, cols: usize) -> anyhow::Result<Mat> {
    let v = lit.to_vec::<f32>()?;
    anyhow::ensure!(
        v.len() == rows * cols,
        "literal has {} elements, expected {rows}x{cols}",
        v.len()
    );
    Ok(Mat::from_vec(rows, cols, v))
}
