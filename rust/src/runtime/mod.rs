//! Kernel-executor runtime behind the training hot path.
//!
//! Two backends, one API ([`Runtime`]):
//!
//! * **native** (default feature set, and the default training backend) —
//!   pure-Rust implementations of the four kernel contracts
//!   (`runtime::native`), numerically faithful to the jnp oracles in
//!   `python/compile/kernels/ref.py` and built for throughput: an
//!   ISA-dispatched GEMM microkernel (`tensor::gemm_into` — AVX2+FMA /
//!   NEON selected once at construction from `[runtime] simd`, scalar
//!   register-tile fallback), fused residual/mask and weight-product
//!   passes, and output-row parallelism across a *persistent* worker pool
//!   ([`pool::WorkerPool`], spawned once per runtime and parked between
//!   jobs) whose size comes from the experiment config (results are
//!   bit-identical for every thread count, at every ISA — see
//!   `rust/PERF.md`). A round's independent client gradients batch
//!   through [`Runtime::grad_batch`] / [`Runtime::grad_batch_into`], and
//!   the `_into` kernel forms keep warm rounds free of compute-path
//!   allocations (`tests/alloc_gate.rs`). Builds and runs with zero
//!   external dependencies.
//! * **pjrt** (`--features pjrt`) — loads the AOT HLO-text artifacts and
//!   executes them through the PJRT C API. Wiring (see DESIGN.md §2):
//!   `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//!   `XlaComputation::from_proto` → `client.compile` once per artifact →
//!   `execute` per call. HLO *text* is the interchange format (jax ≥ 0.5
//!   emits 64-bit-id protos that xla_extension 0.5.1 rejects; the text
//!   parser reassigns ids).
//!
//! Shape policy: artifacts are compiled for fixed shapes; smaller
//! workloads are zero-padded up to the compiled shape, which is *exact*
//! for this math (zero data/generator rows contribute zero
//! gradient/parity — tested in `python/tests/test_kernels_*.py` and
//! `rust/tests/runtime_exec.rs`). The native backend enforces the same
//! shape contract so either backend exercises the other's invariants.

mod exec;
mod manifest;
pub mod native;
pub mod pool;

pub use exec::{GradJob, PreparedTheta, Runtime, RuntimeShapes};
pub use manifest::{Manifest, ManifestEntry};

#[cfg(feature = "pjrt")]
use crate::tensor::Mat;

/// Convert a [`Mat`] into an XLA literal of the same `[rows, cols]` shape.
#[cfg(feature = "pjrt")]
pub fn mat_to_literal(m: &Mat) -> anyhow::Result<xla::Literal> {
    Ok(xla::Literal::vec1(m.as_slice()).reshape(&[m.rows() as i64, m.cols() as i64])?)
}

/// Convert a 1-D slice into an XLA literal of shape `[len]`.
#[cfg(feature = "pjrt")]
pub fn vec_to_literal(v: &[f32]) -> xla::Literal {
    xla::Literal::vec1(v)
}

/// Convert an XLA literal (known `[rows, cols]`) back into a [`Mat`].
#[cfg(feature = "pjrt")]
pub fn literal_to_mat(lit: &xla::Literal, rows: usize, cols: usize) -> anyhow::Result<Mat> {
    let v = lit.to_vec::<f32>()?;
    anyhow::ensure!(
        v.len() == rows * cols,
        "literal has {} elements, expected {rows}x{cols}",
        v.len()
    );
    Ok(Mat::from_vec(rows, cols, v))
}
