//! Persistent worker pool behind the native compute backend.
//!
//! The pre-0.4 kernels spawned fresh OS threads through
//! `std::thread::scope` on *every* parallel call — tens of microseconds of
//! spawn/join per kernel, paid n+1 times per training round, which swamped
//! the per-client shapes CodedFedL actually runs (200-row gradients take
//! ~100 µs of math). This module replaces those per-call spawns with one
//! pool per [`crate::runtime::Runtime`] (and therefore one per `Session`):
//! workers are spawned once, parked (`std::thread::park`) between jobs,
//! and woken *individually* — a job spanning `parts` threads unparks
//! exactly the `parts − 1` workers that participate, publishing a
//! pointer-sized job descriptor — so dispatching a job performs **zero
//! heap allocations** and idle workers on a wide pool never pay a
//! wake/re-park cycle for narrow jobs.
//!
//! ## Dispatch model
//!
//! [`WorkerPool::run`]`(parts, task)` executes `task(part, scratch)` once
//! for every `part in 0..parts`. The *calling thread runs part 0* and the
//! parked workers run parts `1..parts`, so a pool of `t` threads is the
//! caller plus `t − 1` spawned workers. The call returns only after every
//! part has finished (a latch counted under the pool mutex), which is what
//! makes the borrowed `task` reference sound to share with the workers.
//!
//! Callers split their output across parts themselves (disjoint row
//! blocks — see `runtime::native`); the pool guarantees only that each
//! part runs exactly once, on exactly one thread. Determinism is therefore
//! unchanged from the scoped-spawn era: identical partitioning + identical
//! per-element accumulation order ⇒ bit-identical results for every
//! thread count.
//!
//! ## Per-worker scratch arenas
//!
//! Each thread (the caller included) owns a `Vec<f32>` scratch arena that
//! persists across jobs — kernels `resize` it on first use and reuse the
//! warm capacity forever after. This is what absorbs the encode kernel's
//! `G·w` panel, the packed-θ row panels of `grad`/`predict`, and the
//! SIMD microkernels' A-operand pack blocks (`tensor::gemm_pack_len`)
//! without per-call allocation — the zero-alloc warm-round invariant
//! holds under every `[runtime] simd` policy (`tests/alloc_gate.rs` runs
//! under both). A part may only touch the scratch it is handed: part
//! `i`'s arena is owned by whichever thread runs part `i`, and jobs are
//! serialized, so the access is exclusive.

use std::cell::UnsafeCell;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;

/// A borrowed parallel job: `job(part, scratch)` runs once per part.
pub type Job = dyn Fn(usize, &mut Vec<f32>) + Sync;

/// Total worker threads ever spawned by pools in this process (telemetry
/// for the no-thread-leak contract: steady-state training must not move
/// this counter).
static SPAWNED_WORKERS: AtomicU64 = AtomicU64::new(0);

/// Worker threads spawned process-wide so far (monotonic).
pub fn spawned_workers_total() -> u64 {
    SPAWNED_WORKERS.load(Ordering::Relaxed)
}

/// Lock that shrugs off poisoning: pool state stays consistent even if a
/// job panicked on some thread (the panic is re-raised on the caller).
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Job slot + latch shared between the caller and the parked workers.
struct State {
    /// Monotonic job counter; a bump (under the mutex) publishes a job.
    epoch: u64,
    /// Parts of the current job. The caller runs part 0, worker `w` runs
    /// part `w` when `w < parts`.
    parts: usize,
    /// The published job. The `'static` is a lie told via `transmute`: the
    /// reference is only dereferenced between publication and the latch
    /// reaching zero, and `run` does not return (so the borrow does not
    /// end) until then.
    job: Option<&'static Job>,
    /// Workers still running the current job (the latch `run` blocks on).
    running: usize,
    /// A worker's job panicked; re-raised by the caller.
    panicked: bool,
    shutdown: bool,
}

/// One thread's scratch arena. `Sync` is sound because part `i` is run by
/// exactly one thread per job and jobs are serialized by the dispatch
/// mutex + latch, so each cell is accessed by one thread at a time.
#[repr(align(64))] // keep arenas off each other's cache lines
struct ScratchCell(UnsafeCell<Vec<f32>>);

unsafe impl Sync for ScratchCell {}

struct Shared {
    state: Mutex<State>,
    /// The caller parks here waiting for the latch.
    done_cv: Condvar,
    /// Scratch arenas, one per thread: `scratch[0]` is the caller's,
    /// `scratch[w]` belongs to spawned worker `w`.
    scratch: Vec<ScratchCell>,
}

/// A persistent pool of parked worker threads (see the module docs).
///
/// Created once per `Runtime` (sized by `[runtime] threads`); dropped
/// pools shut their workers down and join them.
pub struct WorkerPool {
    threads: usize,
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    /// Parked workers' thread handles (`workers[w - 1]` is worker `w`),
    /// for *targeted* wakeups: a job with `parts < threads` unparks only
    /// the workers that participate instead of broadcasting to the whole
    /// pool (a narrow job on a wide pool would otherwise pay a wasted
    /// wake/lock/re-park cycle per idle worker per dispatch).
    workers: Vec<std::thread::Thread>,
    /// Serializes dispatches: `run` takes `&self`, but the job slot and
    /// the caller scratch arena admit one dispatcher at a time.
    dispatch: Mutex<()>,
}

impl fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "WorkerPool[{} threads]", self.threads)
    }
}

impl WorkerPool {
    /// Pool of `threads` total threads: the caller plus `threads − 1`
    /// spawned workers, parked until the first [`WorkerPool::run`].
    pub fn new(threads: usize) -> WorkerPool {
        assert!(threads >= 1, "pool needs at least the calling thread");
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                epoch: 0,
                parts: 0,
                job: None,
                running: 0,
                panicked: false,
                shutdown: false,
            }),
            done_cv: Condvar::new(),
            scratch: (0..threads).map(|_| ScratchCell(UnsafeCell::new(Vec::new()))).collect(),
        });
        let handles: Vec<JoinHandle<()>> = (1..threads)
            .map(|w| {
                let shared = Arc::clone(&shared);
                SPAWNED_WORKERS.fetch_add(1, Ordering::Relaxed);
                std::thread::Builder::new()
                    .name(format!("codedfedl-pool-{w}"))
                    .spawn(move || worker_loop(&shared, w))
                    .expect("spawning pool worker thread")
            })
            .collect();
        let workers = handles.iter().map(|h| h.thread().clone()).collect();
        WorkerPool { threads, shared, handles, workers, dispatch: Mutex::new(()) }
    }

    /// Total threads (caller + parked workers) a job can span.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `task(part, scratch)` for every `part in 0..parts` and return
    /// once all parts finished. `parts = 0` runs as one part; asking for
    /// more parts than the pool has threads panics — a silent clamp would
    /// leave a caller's `parts`-sized output partition partially
    /// uncomputed with no error. The caller executes part 0 itself;
    /// parked workers take parts `1..`.
    ///
    /// The dispatch allocates nothing; scratch arenas persist across
    /// calls (warm after first use). If any part panics, the panic is
    /// re-raised here *after* every other part finished, so borrowed data
    /// never outlives its users.
    pub fn run(&self, parts: usize, task: &Job) {
        assert!(
            parts <= self.threads,
            "WorkerPool::run: {parts} parts on a {}-thread pool",
            self.threads
        );
        let parts = parts.max(1);
        let _dispatch = lock(&self.dispatch);
        if parts == 1 {
            // Job slot untouched: run inline on the caller's arena.
            let scratch = unsafe { &mut *self.shared.scratch[0].0.get() };
            task(0, scratch);
            return;
        }
        // Publish the job. Lifetime-erasing the borrow is sound because
        // this function only returns after the latch reaches zero (even on
        // panic), so `task` outlives every dereference.
        let job: &'static Job = unsafe { std::mem::transmute::<&Job, &'static Job>(task) };
        {
            let mut st = lock(&self.shared.state);
            st.epoch += 1;
            st.parts = parts;
            st.job = Some(job);
            st.running = parts - 1;
        }
        // Targeted wakeups: only the participating workers. An unpark
        // delivered before the worker parks is banked (the token), so the
        // publish-then-unpark order cannot lose a wakeup.
        for w in 1..parts {
            self.workers[w - 1].unpark();
        }
        // Part 0 runs here, on the caller's own arena.
        let scratch = unsafe { &mut *self.shared.scratch[0].0.get() };
        let caller = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| task(0, scratch)));
        // Wait out the latch no matter what happened above.
        let worker_panicked = {
            let mut st = lock(&self.shared.state);
            while st.running > 0 {
                st = self.shared.done_cv.wait(st).unwrap_or_else(PoisonError::into_inner);
            }
            st.job = None;
            std::mem::replace(&mut st.panicked, false)
        };
        if let Err(payload) = caller {
            std::panic::resume_unwind(payload);
        }
        if worker_panicked {
            panic!("worker pool job panicked on a worker thread");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = lock(&self.shared.state);
            st.shutdown = true;
        }
        for w in &self.workers {
            w.unpark();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// What parked workers do: park until an epoch bump that includes them
/// (the dispatcher unparks participants individually), run their part on
/// their own arena, count down the latch.
fn worker_loop(shared: &Shared, index: usize) {
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = lock(&shared.state);
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    seen = st.epoch;
                    if index < st.parts {
                        break;
                    }
                    // Not a participant this job (a stale banked unpark
                    // woke us); re-park. Safe to skip: the caller only
                    // needs parts 1..parts.
                }
                drop(st);
                std::thread::park();
                st = lock(&shared.state);
            }
            st.job.expect("published job")
        };
        let scratch = unsafe { &mut *shared.scratch[index].0.get() };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| job(index, scratch)));
        let mut st = lock(&shared.state);
        if result.is_err() {
            st.panicked = true;
        }
        st.running -= 1;
        if st.running == 0 {
            shared.done_cv.notify_one();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn every_part_runs_exactly_once() {
        let pool = WorkerPool::new(4);
        for _ in 0..50 {
            let ran = AtomicUsize::new(0);
            let seen = Mutex::new(HashSet::new());
            pool.run(4, &|part, _s| {
                ran.fetch_add(1, Ordering::SeqCst);
                seen.lock().unwrap().insert(part);
            });
            assert_eq!(ran.load(Ordering::SeqCst), 4);
            assert_eq!(seen.into_inner().unwrap(), (0..4).collect::<HashSet<_>>());
        }
    }

    #[test]
    fn zero_parts_runs_as_one() {
        let pool = WorkerPool::new(3);
        let ran = AtomicUsize::new(0);
        pool.run(0, &|_p, _s| {
            ran.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(ran.load(Ordering::SeqCst), 1);
    }

    #[test]
    #[should_panic(expected = "WorkerPool::run")]
    fn excess_parts_are_rejected_loudly() {
        // A silent clamp would leave a caller's larger partition silently
        // uncomputed; over-subscription must panic instead.
        let pool = WorkerPool::new(3);
        pool.run(4, &|_p, _s| {});
    }

    #[test]
    fn workers_are_reused_across_jobs() {
        let pool = WorkerPool::new(3);
        let ids = || {
            let set = Mutex::new(HashSet::new());
            pool.run(3, &|_p, _s| {
                set.lock().unwrap().insert(std::thread::current().id());
            });
            set.into_inner().unwrap()
        };
        let first = ids();
        assert_eq!(first.len(), 3, "3 parts must land on 3 distinct threads");
        let spawned = spawned_workers_total();
        for _ in 0..20 {
            assert_eq!(ids(), first, "jobs must reuse the same parked workers");
        }
        assert_eq!(spawned_workers_total(), spawned, "dispatch must never spawn");
    }

    #[test]
    fn scratch_arenas_persist_between_jobs() {
        let pool = WorkerPool::new(2);
        pool.run(2, &|part, scratch| {
            scratch.resize(128, part as f32 + 1.0);
        });
        let kept = Mutex::new(Vec::new());
        pool.run(2, &|part, scratch| {
            kept.lock().unwrap().push((part, scratch.len(), scratch[0]));
        });
        let mut kept = kept.into_inner().unwrap();
        kept.sort_by_key(|&(p, _, _)| p);
        assert_eq!(kept, vec![(0, 128, 1.0), (1, 128, 2.0)]);
    }

    #[test]
    fn single_thread_pool_runs_inline() {
        let pool = WorkerPool::new(1);
        let here = std::thread::current().id();
        let ok = Mutex::new(false);
        pool.run(1, &|part, _s| {
            assert_eq!(part, 0);
            assert_eq!(std::thread::current().id(), here);
            *ok.lock().unwrap() = true;
        });
        assert!(*ok.lock().unwrap());
    }

    #[test]
    fn worker_panic_is_propagated_not_deadlocked() {
        let pool = WorkerPool::new(2);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(2, &|part, _s| {
                if part == 1 {
                    panic!("boom");
                }
            });
        }));
        assert!(caught.is_err());
        // The pool is still serviceable after a job panicked.
        let ran = AtomicUsize::new(0);
        pool.run(2, &|_p, _s| {
            ran.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(ran.load(Ordering::SeqCst), 2);
    }
}
