//! Parser for `artifacts/manifest.txt` written by `python/compile/aot.py`.
//!
//! Line format: `<kind> file=<name> <dim>=<int> ...`. The manifest is the
//! contract between the Python compile path and this runtime: at startup
//! the runtime resolves every shape the experiment needs against it and
//! fails fast with an actionable message if an artifact is missing.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// One artifact record.
#[derive(Clone, Debug, PartialEq)]
pub struct ManifestEntry {
    pub kind: String,
    pub file: String,
    pub dims: BTreeMap<String, usize>,
}

impl ManifestEntry {
    pub fn dim(&self, name: &str) -> Option<usize> {
        self.dims.get(name).copied()
    }
}

/// Parsed manifest with lookup helpers.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub entries: Vec<ManifestEntry>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!("reading {path:?} — run `make artifacts` first")
        })?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (exposed for tests).
    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let mut entries = Vec::new();
        for (i, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut toks = line.split_whitespace();
            let kind = toks.next().unwrap().to_string();
            let mut file = None;
            let mut dims = BTreeMap::new();
            for tok in toks {
                let (k, v) = tok
                    .split_once('=')
                    .with_context(|| format!("manifest line {}: bad token {tok:?}", i + 1))?;
                if k == "file" {
                    file = Some(v.to_string());
                } else {
                    let n: usize = v.parse().with_context(|| {
                        format!("manifest line {}: dim {k}={v:?} not an int", i + 1)
                    })?;
                    dims.insert(k.to_string(), n);
                }
            }
            let Some(file) = file else {
                bail!("manifest line {}: missing file=", i + 1);
            };
            entries.push(ManifestEntry { kind, file, dims });
        }
        Ok(Manifest { entries, dir: dir.to_path_buf() })
    }

    /// Find the entry of `kind` whose dims contain all of `want`.
    pub fn find(&self, kind: &str, want: &[(&str, usize)]) -> Option<&ManifestEntry> {
        self.entries.iter().find(|e| {
            e.kind == kind && want.iter().all(|(k, v)| e.dim(k) == Some(*v))
        })
    }

    /// Like [`find`], but with a fail-fast error listing what exists.
    pub fn require(&self, kind: &str, want: &[(&str, usize)]) -> Result<&ManifestEntry> {
        self.find(kind, want).with_context(|| {
            let have: Vec<String> = self
                .entries
                .iter()
                .filter(|e| e.kind == kind)
                .map(|e| format!("{:?}", e.dims))
                .collect();
            format!(
                "no `{kind}` artifact with dims {want:?} in {:?}; available: [{}] — \
                 rebuild with `python -m compile.aot` and a preset matching the config",
                self.dir,
                have.join(", ")
            )
        })
    }

    /// Absolute path of an entry's HLO file.
    pub fn path(&self, e: &ManifestEntry) -> PathBuf {
        self.dir.join(&e.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
rff_embed file=rff_embed_40x32x64.hlo.txt b=40 d=32 q=64
grad file=grad_40x64x10.hlo.txt c=10 l=40 q=64
grad file=grad_128x64x10.hlo.txt c=10 l=128 q=64
encode file=encode_128x40x64x10.hlo.txt c=10 l=40 q=64 u=128
";

    #[test]
    fn parses_entries() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.entries.len(), 4);
        assert_eq!(m.entries[0].kind, "rff_embed");
        assert_eq!(m.entries[0].dim("q"), Some(64));
        assert_eq!(m.entries[0].file, "rff_embed_40x32x64.hlo.txt");
    }

    #[test]
    fn find_matches_all_dims() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        let e = m.find("grad", &[("l", 128), ("q", 64)]).unwrap();
        assert_eq!(e.file, "grad_128x64x10.hlo.txt");
        assert!(m.find("grad", &[("l", 999)]).is_none());
    }

    #[test]
    fn require_error_is_actionable() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        let err = m.require("grad", &[("l", 999)]).unwrap_err().to_string();
        assert!(err.contains("no `grad` artifact"));
        assert!(err.contains("available"));
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(Manifest::parse("grad l=10", Path::new("/x")).is_err()); // no file
        assert!(Manifest::parse("grad file=a l=ten", Path::new("/x")).is_err());
        assert!(Manifest::parse("grad file=a garbage", Path::new("/x")).is_err());
    }

    #[test]
    fn skips_comments_and_blanks() {
        let m = Manifest::parse("# hi\n\ngrad file=g.hlo.txt l=4\n", Path::new("/x")).unwrap();
        assert_eq!(m.entries.len(), 1);
    }

    #[test]
    fn path_joins_dir() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(
            m.path(&m.entries[0]),
            PathBuf::from("/tmp/a/rff_embed_40x32x64.hlo.txt")
        );
    }
}
