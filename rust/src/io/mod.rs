//! Durable file output: crash-consistent writes and the integrity
//! primitives the checkpoint format is built on.
//!
//! [`atomic_write`] is the one way any tracked artifact reaches disk —
//! checkpoints ([`crate::coordinator::checkpoint`]) and the bench report
//! ([`crate::benchutil::BenchReport::write_json`]) both route through it.
//! The sequence is the classic temp file → `fsync` → `rename`: a reader
//! (or a resumed run) either sees the complete previous contents or the
//! complete new contents, never a torn mix, even if the process dies
//! mid-write. [`crc32`] is the IEEE CRC-32 used to detect the remaining
//! failure mode — a checkpoint corrupted *after* it was durably written
//! (bit rot, partial copies between machines).

use std::fs::File;
use std::io::Write;
use std::path::Path;

/// IEEE CRC-32 (polynomial 0xEDB88320) lookup table, built at compile
/// time so integrity checks carry no startup cost.
const CRC32_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ 0xEDB8_8320 } else { crc >> 1 };
            bit += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
};

/// IEEE CRC-32 over `bytes` — the checksum guarding every checkpoint
/// payload against torn or corrupted files.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in bytes {
        crc = (crc >> 8) ^ CRC32_TABLE[((crc ^ b as u32) & 0xFF) as usize];
    }
    !crc
}

/// FNV-1a over `bytes` — the stable 64-bit hash used for config
/// fingerprints (and the default scheme RNG tag).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Write `bytes` to `path` crash-consistently: write a sibling temp file,
/// `fsync` it, then atomically rename it over `path`. A crash at any point
/// leaves either the old complete file or the new complete file — never a
/// truncated or interleaved one. The parent directory is created if
/// missing.
pub fn atomic_write(path: &Path, bytes: &[u8]) -> std::io::Result<()> {
    let dir = match path.parent() {
        Some(d) if !d.as_os_str().is_empty() => d.to_path_buf(),
        _ => std::path::PathBuf::from("."),
    };
    std::fs::create_dir_all(&dir)?;
    // The temp name embeds the target's file name so concurrent writers
    // to *different* targets in one directory never collide; concurrent
    // writers to the same target last-writer-wins atomically, which is
    // exactly rename's contract.
    let file_name = path.file_name().and_then(|n| n.to_str()).unwrap_or("out");
    let tmp = dir.join(format!(".{file_name}.tmp.{}", std::process::id()));
    let result = (|| {
        let mut f = File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()?;
        drop(f);
        std::fs::rename(&tmp, path)
    })();
    if result.is_err() {
        // Best-effort cleanup; the original target is untouched either way.
        let _ = std::fs::remove_file(&tmp);
    }
    result
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_matches_known_vectors() {
        // The canonical IEEE check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_ne!(crc32(b"abc"), crc32(b"abd"));
    }

    #[test]
    fn fnv1a_is_stable_and_input_dependent() {
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"naive"), fnv1a(b"naive"));
        assert_ne!(fnv1a(b"naive"), fnv1a(b"greedy"));
    }

    #[test]
    fn atomic_write_replaces_contents_completely() {
        let dir = std::env::temp_dir().join(format!("codedfedl_io_{}", std::process::id()));
        let path = dir.join("nested/report.json");
        atomic_write(&path, b"first").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"first");
        atomic_write(&path, b"second, longer contents").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"second, longer contents");
        // No temp litter left behind.
        let leftovers: Vec<_> = std::fs::read_dir(path.parent().unwrap())
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().contains(".tmp."))
            .collect();
        assert!(leftovers.is_empty(), "temp files left behind: {leftovers:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
