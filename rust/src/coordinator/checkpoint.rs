//! Crash-consistent checkpoint/resume for the training engine.
//!
//! A checkpoint is a versioned, CRC-guarded binary snapshot of the full
//! training state at a round boundary: θ, the simulated clock, the next
//! round index, the position of every sequential RNG stream (delay /
//! code / scenario / fault — the participation and server-fault streams
//! are counter-based and need only their bases, which the resumed run
//! re-derives), the degradation-ladder histogram
//! ([`crate::metrics::OutcomeCounts`]), the excluded-corrupt-update
//! count, the evaluated history so far, and a fingerprint of every
//! history-affecting config field. Scheme state (e.g. CodedFedL's parity
//! datasets and code coefficients) is *not* serialized: it is derived
//! deterministically by `Scheme::prepare` from the scheme's private
//! `code_rng` stream, so a resumed run re-runs `prepare` and then
//! restores the stream positions — cheaper, version-proof, and exact.
//!
//! Files are written via [`crate::io::atomic_write`] (temp + fsync +
//! rename), so a crash mid-write leaves the previous checkpoint intact.
//! Decoding rejects torn, truncated, corrupted, or mismatched files with
//! a named [`CheckpointError`] — never a panic. The house invariant
//! (proved by `tests/checkpoint_resume.rs`): a run interrupted at any
//! round and resumed from its checkpoint is **bit-identical** to the
//! uninterrupted run, for every scheme × scenario × fault × thread ×
//! SIMD combination.

use std::fmt;
use std::path::Path;

use crate::conf::ExperimentConfig;
use crate::io::{atomic_write, crc32, fnv1a};
use crate::metrics::Point;

/// File magic: the first 8 bytes of every checkpoint.
pub const MAGIC: [u8; 8] = *b"CFEDCKPT";

/// Current (and only) checkpoint format version. Version 2 added the
/// cumulative bytes-on-wire totals (modelled payload accounting); v1
/// files predate the communication model and are rejected rather than
/// silently resumed with zeroed byte counters.
pub const FORMAT_VERSION: u32 = 2;

/// Everything a decode/verify can reject with. Every variant renders a
/// named, actionable message — resume paths surface these, they never
/// panic.
#[derive(Clone, Debug, PartialEq)]
pub enum CheckpointError {
    /// Filesystem failure reading or writing `path`.
    Io { path: String, err: String },
    /// The file ends before `field` could be read — a torn or truncated
    /// checkpoint.
    Truncated { field: &'static str, needed: usize, have: usize },
    /// The file does not start with [`MAGIC`].
    BadMagic,
    /// The file's format version is not one this build can read.
    UnsupportedVersion(u32),
    /// The payload CRC does not match — bit rot or partial corruption.
    CrcMismatch { expected: u32, found: u32 },
    /// The checkpoint was taken under a different experiment config.
    ConfigMismatch { expected: u64, found: u64 },
    /// The checkpoint was taken by a different scheme.
    SchemeMismatch { expected: String, found: String },
    /// The checkpointed θ has the wrong shape for this model.
    ShapeMismatch { expected: (u32, u32), found: (u32, u32) },
}

impl fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CheckpointError::Io { path, err } => write!(f, "checkpoint io at {path:?}: {err}"),
            CheckpointError::Truncated { field, needed, have } => write!(
                f,
                "truncated checkpoint: reading {field} needs {needed} bytes, only {have} remain \
                 (torn or incomplete file)"
            ),
            CheckpointError::BadMagic => write!(
                f,
                "not a CodedFedL checkpoint (bad magic; expected one of {:?})",
                std::str::from_utf8(&MAGIC).unwrap_or("CFEDCKPT")
            ),
            CheckpointError::UnsupportedVersion(v) => write!(
                f,
                "unsupported checkpoint format version {v} (expected one of {FORMAT_VERSION})"
            ),
            CheckpointError::CrcMismatch { expected, found } => write!(
                f,
                "checkpoint CRC mismatch: payload hashes to {found:#010x}, file records \
                 {expected:#010x} (torn or corrupted file)"
            ),
            CheckpointError::ConfigMismatch { expected, found } => write!(
                f,
                "checkpoint config fingerprint {found:#018x} does not match this run's \
                 {expected:#018x} (the checkpoint was taken under a different experiment config)"
            ),
            CheckpointError::SchemeMismatch { expected, found } => write!(
                f,
                "checkpoint was taken by scheme {found:?}, this run is {expected:?}"
            ),
            CheckpointError::ShapeMismatch { expected, found } => write!(
                f,
                "checkpointed theta is {}x{}, this model needs {}x{}",
                found.0, found.1, expected.0, expected.1
            ),
        }
    }
}

impl std::error::Error for CheckpointError {}

/// How a run starts relative to an existing checkpoint (`[checkpoint]
/// resume` / `--resume` / `ExperimentBuilder::resume`).
#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub enum ResumeSpec {
    /// Start fresh, ignoring any checkpoint on disk (the default).
    #[default]
    Off,
    /// Resume from the run's checkpoint path if a checkpoint exists
    /// there; start fresh otherwise.
    Auto,
    /// Resume from exactly this file; fail if it is missing or invalid.
    Path(String),
}

impl ResumeSpec {
    /// Canonical spec string (round-trips through [`ResumeSpec::parse`]).
    pub fn label(&self) -> String {
        match self {
            ResumeSpec::Off => "off".into(),
            ResumeSpec::Auto => "auto".into(),
            ResumeSpec::Path(p) => format!("path:{p}"),
        }
    }

    /// Parse a resume mode: `off`, `auto`, or `path:<file>`.
    pub fn parse(s: &str) -> Result<ResumeSpec, String> {
        let t = s.trim();
        match t {
            "off" => Ok(ResumeSpec::Off),
            "auto" => Ok(ResumeSpec::Auto),
            _ => match t.split_once(':') {
                Some(("path", p)) if !p.trim().is_empty() => {
                    Ok(ResumeSpec::Path(p.trim().to_string()))
                }
                Some(("path", _)) => Err("resume mode \"path:\" names no file \
                     (expected path:<file>)"
                    .into()),
                _ => Err(format!(
                    "unknown resume mode {t:?} (expected one of off | auto | path:<file>)"
                )),
            },
        }
    }
}

impl std::str::FromStr for ResumeSpec {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        ResumeSpec::parse(s)
    }
}

/// The full resumable training state at a round boundary.
#[derive(Clone, Debug, PartialEq)]
pub struct Snapshot {
    /// Fingerprint of the history-affecting config (see [`fingerprint`]).
    pub config_fingerprint: u64,
    /// Label of the scheme that wrote the checkpoint.
    pub scheme_label: String,
    /// First round the resumed run executes (rounds `0..next_iter` are
    /// already folded into this snapshot).
    pub next_iter: u64,
    /// Simulated MEC clock, seconds.
    pub clock: f64,
    /// θ shape and row-major contents.
    pub theta_rows: u32,
    pub theta_cols: u32,
    pub theta: Vec<f32>,
    /// Sequential RNG stream positions.
    pub delay_rng: [u64; 4],
    pub code_rng: [u64; 4],
    pub scenario_rng: [u64; 4],
    pub fault_rng: [u64; 4],
    /// Degradation-ladder histogram so far (`OutcomeCounts::as_array`).
    pub outcomes: [u64; 5],
    /// Non-finite client updates excluded from folds so far.
    pub corrupted_total: u64,
    /// Cumulative modelled downlink bytes (θ broadcasts) so far.
    pub bytes_down_total: u64,
    /// Cumulative modelled uplink bytes (gradient uploads) so far.
    pub bytes_up_total: u64,
    /// Evaluated history points so far, bit-exact.
    pub history: Vec<Point>,
}

impl Snapshot {
    /// Serialize to the on-disk format: `MAGIC ∥ version ∥ payload ∥
    /// crc32(payload)`, all little-endian.
    pub fn encode(&self) -> Vec<u8> {
        let mut payload = Vec::with_capacity(128 + self.theta.len() * 4 + self.history.len() * 32);
        payload.extend_from_slice(&self.config_fingerprint.to_le_bytes());
        payload.extend_from_slice(&(self.scheme_label.len() as u32).to_le_bytes());
        payload.extend_from_slice(self.scheme_label.as_bytes());
        payload.extend_from_slice(&self.next_iter.to_le_bytes());
        payload.extend_from_slice(&self.clock.to_bits().to_le_bytes());
        payload.extend_from_slice(&self.theta_rows.to_le_bytes());
        payload.extend_from_slice(&self.theta_cols.to_le_bytes());
        for &v in &self.theta {
            payload.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        for state in [&self.delay_rng, &self.code_rng, &self.scenario_rng, &self.fault_rng] {
            for &w in state.iter() {
                payload.extend_from_slice(&w.to_le_bytes());
            }
        }
        for &c in &self.outcomes {
            payload.extend_from_slice(&c.to_le_bytes());
        }
        payload.extend_from_slice(&self.corrupted_total.to_le_bytes());
        payload.extend_from_slice(&self.bytes_down_total.to_le_bytes());
        payload.extend_from_slice(&self.bytes_up_total.to_le_bytes());
        payload.extend_from_slice(&(self.history.len() as u32).to_le_bytes());
        for p in &self.history {
            payload.extend_from_slice(&(p.iter as u64).to_le_bytes());
            payload.extend_from_slice(&p.sim_time.to_bits().to_le_bytes());
            payload.extend_from_slice(&p.accuracy.to_bits().to_le_bytes());
            payload.extend_from_slice(&p.train_loss.to_bits().to_le_bytes());
        }

        let mut out = Vec::with_capacity(MAGIC.len() + 4 + payload.len() + 4);
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
        out.extend_from_slice(&payload);
        out.extend_from_slice(&crc32(&payload).to_le_bytes());
        out
    }

    /// Parse and integrity-check a checkpoint. Magic, version and CRC are
    /// validated before any field is trusted; every failure is a named
    /// [`CheckpointError`].
    pub fn decode(bytes: &[u8]) -> Result<Snapshot, CheckpointError> {
        let header = MAGIC.len() + 4;
        if bytes.len() < header + 4 {
            return Err(CheckpointError::Truncated {
                field: "header",
                needed: header + 4,
                have: bytes.len(),
            });
        }
        if bytes[..MAGIC.len()] != MAGIC {
            return Err(CheckpointError::BadMagic);
        }
        let version = u32::from_le_bytes(bytes[MAGIC.len()..header].try_into().unwrap());
        if version != FORMAT_VERSION {
            return Err(CheckpointError::UnsupportedVersion(version));
        }
        let payload = &bytes[header..bytes.len() - 4];
        let expected = u32::from_le_bytes(bytes[bytes.len() - 4..].try_into().unwrap());
        let found = crc32(payload);
        if expected != found {
            return Err(CheckpointError::CrcMismatch { expected, found });
        }

        let mut cur = Cursor { bytes: payload, pos: 0 };
        let config_fingerprint = cur.u64("config_fingerprint")?;
        let label_len = cur.u32("scheme_label length")? as usize;
        let label_bytes = cur.take(label_len, "scheme_label")?;
        let scheme_label = String::from_utf8_lossy(label_bytes).into_owned();
        let next_iter = cur.u64("next_iter")?;
        let clock = f64::from_bits(cur.u64("clock")?);
        let theta_rows = cur.u32("theta_rows")?;
        let theta_cols = cur.u32("theta_cols")?;
        let n_theta = theta_rows as usize * theta_cols as usize;
        let mut theta = Vec::with_capacity(n_theta);
        for _ in 0..n_theta {
            theta.push(f32::from_bits(cur.u32("theta")?));
        }
        let mut states = [[0u64; 4]; 4];
        for state in states.iter_mut() {
            for w in state.iter_mut() {
                *w = cur.u64("rng state")?;
            }
        }
        let mut outcomes = [0u64; 5];
        for c in outcomes.iter_mut() {
            *c = cur.u64("outcome counts")?;
        }
        let corrupted_total = cur.u64("corrupted_total")?;
        let bytes_down_total = cur.u64("bytes_down_total")?;
        let bytes_up_total = cur.u64("bytes_up_total")?;
        let n_points = cur.u32("history length")? as usize;
        let mut history = Vec::with_capacity(n_points);
        for _ in 0..n_points {
            history.push(Point {
                iter: cur.u64("history iter")? as usize,
                sim_time: f64::from_bits(cur.u64("history sim_time")?),
                accuracy: f64::from_bits(cur.u64("history accuracy")?),
                train_loss: f64::from_bits(cur.u64("history train_loss")?),
            });
        }

        Ok(Snapshot {
            config_fingerprint,
            scheme_label,
            next_iter,
            clock,
            theta_rows,
            theta_cols,
            theta,
            delay_rng: states[0],
            code_rng: states[1],
            scenario_rng: states[2],
            fault_rng: states[3],
            outcomes,
            corrupted_total,
            bytes_down_total,
            bytes_up_total,
            history,
        })
    }

    /// Reject a snapshot that does not belong to this run: wrong config
    /// fingerprint, wrong scheme, or wrong θ shape.
    pub fn verify(
        &self,
        config_fingerprint: u64,
        scheme_label: &str,
        theta_rows: usize,
        theta_cols: usize,
    ) -> Result<(), CheckpointError> {
        if self.config_fingerprint != config_fingerprint {
            return Err(CheckpointError::ConfigMismatch {
                expected: config_fingerprint,
                found: self.config_fingerprint,
            });
        }
        if self.scheme_label != scheme_label {
            return Err(CheckpointError::SchemeMismatch {
                expected: scheme_label.to_string(),
                found: self.scheme_label.clone(),
            });
        }
        let expected = (theta_rows as u32, theta_cols as u32);
        let found = (self.theta_rows, self.theta_cols);
        if expected != found || self.theta.len() != theta_rows * theta_cols {
            return Err(CheckpointError::ShapeMismatch { expected, found });
        }
        Ok(())
    }
}

/// Bounds-checked little-endian reader over a CRC-validated payload.
struct Cursor<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize, field: &'static str) -> Result<&'a [u8], CheckpointError> {
        let have = self.bytes.len() - self.pos;
        if have < n {
            return Err(CheckpointError::Truncated { field, needed: n, have });
        }
        let out = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    fn u32(&mut self, field: &'static str) -> Result<u32, CheckpointError> {
        Ok(u32::from_le_bytes(self.take(4, field)?.try_into().unwrap()))
    }

    fn u64(&mut self, field: &'static str) -> Result<u64, CheckpointError> {
        Ok(u64::from_le_bytes(self.take(8, field)?.try_into().unwrap()))
    }
}

/// Atomically write `snap` to `path` (temp + fsync + rename).
pub fn write(path: &Path, snap: &Snapshot) -> Result<(), CheckpointError> {
    atomic_write(path, &snap.encode()).map_err(|e| CheckpointError::Io {
        path: path.display().to_string(),
        err: e.to_string(),
    })
}

/// Read and decode the checkpoint at `path`.
pub fn load(path: &Path) -> Result<Snapshot, CheckpointError> {
    let bytes = std::fs::read(path).map_err(|e| CheckpointError::Io {
        path: path.display().to_string(),
        err: e.to_string(),
    })?;
    Snapshot::decode(&bytes)
}

/// The run's default checkpoint path when `[checkpoint] path` is unset:
/// scoped by the scheme's RNG tag so concurrent schemes on one artifacts
/// dir never clobber each other's state.
pub fn default_path(artifacts_dir: &str, scheme_tag: u64) -> String {
    format!("{artifacts_dir}/checkpoint_{scheme_tag:016x}.ckpt")
}

/// FNV-1a fingerprint over every config field that shapes the realized
/// training history. Deliberately **excluded**: `epochs` (a checkpoint
/// from a shorter run may resume into a longer schedule — the per-round
/// math is epoch-schedule-driven, not total-length-driven), `threads`
/// (histories are thread-invariant by contract), `shard_size` (bitwise
/// inert by contract), `artifacts_dir` and the `[checkpoint]` keys
/// themselves (where state lives cannot change what the state is).
pub fn fingerprint(cfg: &ExperimentConfig) -> u64 {
    let canon = format!(
        "seed={};clients={};dim={};q={};classes={};sigma={:016x};local_batch={};\
         steps_per_epoch={};lr={:016x};lr_decay={:016x};lr_decay_epochs={:?};l2={:016x};\
         eval_every={};deadline={:?};simd={:?};scenario={:?};faults={:?};fleet_asym={:?};\
         fleet_n={:?};participation={:?};aggregation={:?};u_max={};generator={:?};code={:?};\
         recovery={:?};train_size={};test_size={};dataset={};codec={};payload={}",
        cfg.seed,
        cfg.clients,
        cfg.dim,
        cfg.q,
        cfg.classes,
        cfg.sigma.to_bits(),
        cfg.local_batch,
        cfg.steps_per_epoch,
        cfg.lr.to_bits(),
        cfg.lr_decay.to_bits(),
        cfg.lr_decay_epochs,
        cfg.l2.to_bits(),
        cfg.eval_every,
        cfg.deadline,
        cfg.simd,
        cfg.scenario,
        cfg.faults,
        cfg.fleet_asym,
        cfg.fleet_n,
        cfg.participation,
        cfg.aggregation,
        cfg.u_max,
        cfg.generator,
        cfg.code,
        cfg.recovery,
        cfg.train_size,
        cfg.test_size,
        cfg.dataset,
        cfg.codec.label(),
        cfg.payload.label(),
    );
    fnv1a(canon.as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Snapshot {
        Snapshot {
            config_fingerprint: 0xABCD_EF01_2345_6789,
            scheme_label: "coded(delta=0.3)".into(),
            next_iter: 7,
            clock: 123.456,
            theta_rows: 3,
            theta_cols: 2,
            theta: vec![1.0, -2.5, 0.0, f32::MIN_POSITIVE, 3.25, -0.125],
            delay_rng: [1, 2, 3, 4],
            code_rng: [5, 6, 7, 8],
            scenario_rng: [9, 10, 11, 12],
            fault_rng: [13, 14, 15, 16],
            outcomes: [4, 0, 2, 1, 0],
            corrupted_total: 3,
            bytes_down_total: 123_456_789,
            bytes_up_total: 98_765_432,
            history: vec![
                Point { iter: 1, sim_time: 10.0, accuracy: 0.5, train_loss: 1.25 },
                Point { iter: 2, sim_time: 20.5, accuracy: 0.625, train_loss: 0.75 },
            ],
        }
    }

    #[test]
    fn encode_decode_roundtrips_bit_exactly() {
        let snap = sample();
        let decoded = Snapshot::decode(&snap.encode()).unwrap();
        assert_eq!(snap, decoded);
    }

    #[test]
    fn truncation_at_every_length_is_rejected_never_panics() {
        let bytes = sample().encode();
        for cut in 0..bytes.len() {
            let err = Snapshot::decode(&bytes[..cut])
                .expect_err("a strict prefix must never decode");
            // Either detected structurally or by the CRC; both are named.
            assert!(
                matches!(
                    err,
                    CheckpointError::Truncated { .. } | CheckpointError::CrcMismatch { .. }
                ),
                "cut at {cut}: unexpected error {err}"
            );
        }
    }

    #[test]
    fn every_single_bit_flip_is_rejected() {
        let bytes = sample().encode();
        for byte in 0..bytes.len() {
            let mut bad = bytes.clone();
            bad[byte] ^= 0x40;
            assert!(
                Snapshot::decode(&bad).is_err(),
                "flip in byte {byte} decoded successfully"
            );
        }
    }

    #[test]
    fn wrong_magic_and_version_name_the_expectation() {
        let mut bytes = sample().encode();
        bytes[0] = b'X';
        assert_eq!(Snapshot::decode(&bytes), Err(CheckpointError::BadMagic));

        let mut bytes = sample().encode();
        bytes[8..12].copy_from_slice(&99u32.to_le_bytes());
        let err = Snapshot::decode(&bytes).unwrap_err();
        assert_eq!(err, CheckpointError::UnsupportedVersion(99));
        let msg = err.to_string();
        assert!(msg.contains("expected one of 2"), "{msg}");
    }

    #[test]
    fn verify_rejects_mismatches_by_name() {
        let snap = sample();
        snap.verify(snap.config_fingerprint, "coded(delta=0.3)", 3, 2).unwrap();
        assert!(matches!(
            snap.verify(1, "coded(delta=0.3)", 3, 2),
            Err(CheckpointError::ConfigMismatch { .. })
        ));
        assert!(matches!(
            snap.verify(snap.config_fingerprint, "naive", 3, 2),
            Err(CheckpointError::SchemeMismatch { .. })
        ));
        assert!(matches!(
            snap.verify(snap.config_fingerprint, "coded(delta=0.3)", 2, 3),
            Err(CheckpointError::ShapeMismatch { .. })
        ));
    }

    #[test]
    fn resume_spec_parses_and_roundtrips() {
        assert_eq!(ResumeSpec::parse("off").unwrap(), ResumeSpec::Off);
        assert_eq!(ResumeSpec::parse("auto").unwrap(), ResumeSpec::Auto);
        assert_eq!(
            ResumeSpec::parse("path:/tmp/x.ckpt").unwrap(),
            ResumeSpec::Path("/tmp/x.ckpt".into())
        );
        for spec in [
            ResumeSpec::Off,
            ResumeSpec::Auto,
            ResumeSpec::Path("artifacts/run.ckpt".into()),
        ] {
            assert_eq!(ResumeSpec::parse(&spec.label()).unwrap(), spec);
        }
        let e = ResumeSpec::parse("sometimes").unwrap_err();
        assert!(e.contains("expected one of off | auto | path:<file>"), "{e}");
        assert!(ResumeSpec::parse("path:").is_err());
    }

    #[test]
    fn fingerprint_tracks_history_affecting_fields_only() {
        let base = ExperimentConfig::tiny();
        let f0 = fingerprint(&base);
        assert_eq!(f0, fingerprint(&base.clone()));

        // Epochs, threads and checkpoint placement do NOT change the
        // fingerprint — they are exactly the knobs a resume may vary.
        let mut longer = base.clone();
        longer.epochs += 10;
        longer.threads = 4;
        longer.checkpoint_every = 2;
        longer.resume = ResumeSpec::Auto;
        assert_eq!(f0, fingerprint(&longer));

        // Seed, lr and the communication model DO.
        let mut reseeded = base.clone();
        reseeded.seed ^= 1;
        assert_ne!(f0, fingerprint(&reseeded));
        let mut quantized = base.clone();
        quantized.codec = crate::comm::CodecSpec::Bitpack;
        assert_ne!(f0, fingerprint(&quantized));
        let mut repriced = base.clone();
        repriced.payload = crate::comm::PayloadSpec::Fixed;
        assert_ne!(f0, fingerprint(&repriced));
        let mut hotter = base;
        hotter.lr *= 2.0;
        assert_ne!(f0, fingerprint(&hotter));
    }
}
