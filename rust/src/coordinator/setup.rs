//! One-time experiment setup shared by all schemes (fair comparison):
//! dataset → non-IID shards → distributed RFF embedding → per-client
//! mini-batches, plus the embedded test set and the fleet.

use std::path::Path;

use anyhow::{Context, Result};

use crate::conf::ExperimentConfig;
use crate::data::{self, synth, Dataset};
use crate::delay::asymmetric::AsymNodeParams;
use crate::delay::NodeParams;
use crate::rng::Rng;
use crate::runtime::Runtime;
use crate::tensor::Mat;
use crate::topology::FleetSpec;

/// One client's embedded data, partitioned into per-step mini-batches.
#[derive(Clone, Debug)]
pub struct ClientData {
    /// Embedded features per mini-batch: `steps × [local_batch, q]`.
    pub xhat: Vec<Mat>,
    /// One-hot labels per mini-batch: `steps × [local_batch, c]`.
    pub y: Vec<Mat>,
}

/// Everything schemes share for one experiment.
pub struct FedSetup {
    pub cfg: ExperimentConfig,
    /// Per-client reciprocal-model parameters — what the load-allocation
    /// optimizer and CDF layer consume. Under a `[fleet]`-configured
    /// asymmetric fleet these are each client's
    /// [`AsymNodeParams::reciprocal_surrogate`] (matched mean
    /// communication delay); otherwise the §V-A fleet unchanged.
    pub clients: Vec<NodeParams>,
    /// Per-client per-leg link models — what the round timeline samples
    /// (scenario-modulated through a [`crate::topology::FleetView`]).
    /// Reciprocal fleets carry `AsymNodeParams::symmetric(clients[j])`,
    /// which samples bit-identically to the base model.
    pub client_links: Vec<AsymNodeParams>,
    pub server: NodeParams,
    pub fleet_spec: FleetSpec,
    pub client_data: Vec<ClientData>,
    /// Embedded test features `[test_size, q]` + labels.
    pub test_xhat: Mat,
    pub test_labels: Vec<u8>,
    /// Root RNG streams for schemes (delays, generators) are derived from
    /// this seed so each scheme sees i.i.d. but reproducible draws.
    pub seed: u64,
    /// Smoothness constant `L = (1/m) Σ_j σ_max(X̂^(j))²` of the per-step
    /// objective (paper eq. 59), measured on the first mini-batch. Used to
    /// clamp the learning rate to the stable region (App. E prescribes
    /// `μ = 1/(L + 1/γ)`; the paper's literal `lr = 6` diverges on data
    /// whose kernel spectrum is more concentrated than MNIST's).
    pub smoothness: f64,
}

impl FedSetup {
    /// Build the experiment: generate/load data, build the fleet, shard
    /// non-IID, embed through the runtime (this is the paper's
    /// "distributed kernel embedding" — all clients share the
    /// server-broadcast seed for Ω, δ, Remark 2).
    pub fn build(cfg: &ExperimentConfig, rt: &Runtime) -> Result<FedSetup> {
        cfg.validate().map_err(|e| anyhow::anyhow!(e.to_string()))?;
        let mut root = Rng::seed_from(cfg.seed);
        let mut data_rng = root.split(1);
        let mut topo_rng = root.split(2);
        let mut rff_rng = root.split(3);

        // --- dataset (real IDX files if present, synthetic otherwise) ---
        let (train, test) = load_dataset(cfg, &mut data_rng)?;

        // --- fleet (§V-A LTE setting; [fleet] may make links asymmetric,
        //     [comm] may reprice legs by modelled payload bytes) ---
        let mut fleet_spec = FleetSpec::paper(cfg.clients, cfg.q, cfg.classes);
        fleet_spec.asym = cfg.fleet_asym;
        let payload_model = crate::comm::PayloadModel::new(
            cfg.q,
            cfg.classes,
            cfg.codec,
            cfg.payload,
            fleet_spec.overhead,
        );
        fleet_spec.apply_payload(&payload_model);
        let base_clients = fleet_spec.build_clients(&mut topo_rng);
        let client_links = fleet_spec.build_links(&base_clients);
        // The allocation/CDF layer speaks the reciprocal model: under
        // asymmetric links — configured per-leg overrides OR a payload
        // model that prices the two legs differently — each client is
        // represented there by a surrogate with matched mean
        // communication delay, while the round timeline samples the exact
        // per-leg model. This is how uplink bytes reach the optimizer: a
        // codec that shrinks the uplink lowers the surrogate's τ, which
        // shifts the optimal (load, redundancy) split. The symmetric
        // identity fleet passes through untouched (bit-identity).
        let clients: Vec<NodeParams> = if fleet_spec.asym.is_some() || fleet_spec.payload_scaled()
        {
            client_links.iter().map(AsymNodeParams::reciprocal_surrogate).collect()
        } else {
            base_clients
        };
        let server = fleet_spec.build_server();

        // --- non-IID shards, assigned in expected-delay order (§V-A) ---
        let shards = data::shard::non_iid_shards(&train, &clients, cfg.local_batch as f64);

        // --- distributed RFF embedding (eq. 18, Remark 2) ---
        // Ω columns ~ N(0, I/σ²), δ ~ U(0, 2π]; one shared stream = the
        // shared pseudo-random seed of Remark 2.
        let mut omega = Mat::zeros(cfg.dim, cfg.q);
        rff_rng.fill_normal_scaled_f32(omega.as_mut_slice(), 1.0 / cfg.sigma);
        let mut delta = vec![0.0f32; cfg.q];
        rff_rng.fill_uniform_phase_f32(&mut delta);

        let steps = cfg.steps_per_epoch;
        let mut client_data = Vec::with_capacity(cfg.clients);
        for shard in &shards {
            let xhat = rt
                .embed(&shard.x, &omega, &delta)
                .context("embedding client shard")?;
            let mut xb = Vec::with_capacity(steps);
            let mut yb = Vec::with_capacity(steps);
            for s in 0..steps {
                xb.push(xhat.rows_slice(s * cfg.local_batch, cfg.local_batch));
                yb.push(shard.y.rows_slice(s * cfg.local_batch, cfg.local_batch));
            }
            client_data.push(ClientData { xhat: xb, y: yb });
        }

        let test_xhat = rt.embed(&test.x, &omega, &delta).context("embedding test set")?;

        // Smoothness of the per-step objective: the *exact* top eigenvalue
        // of H = (1/m) X̂ᵀX̂ over one global mini-batch (power iteration on
        // the stacked client mini-batches). Eq. 59's Σσ_j²/m bound is up
        // to n× looser and over-clamps the learning rate.
        let stacked: Vec<&Mat> = client_data.iter().map(|cd| &cd.xhat[0]).collect();
        let stacked = Mat::vstack(&stacked);
        let sigma = crate::convergence::max_singular_value(&stacked, 40);
        let smoothness = sigma * sigma / cfg.global_batch() as f64;

        Ok(FedSetup {
            cfg: cfg.clone(),
            clients,
            client_links,
            server,
            fleet_spec,
            client_data,
            test_xhat,
            test_labels: test.labels,
            seed: cfg.seed,
            smoothness,
        })
    }

    /// Effective learning rate at `epoch`: the configured schedule clamped
    /// into the gradient-descent stability region `lr < 2/(L+λ)` (we use
    /// a 1.8 safety numerator). All schemes share the clamp, so the
    /// comparison stays fair.
    pub fn effective_lr(&self, epoch: usize) -> f64 {
        // 0.12/(L+λ) rather than the full stable 2/(L+λ): mirrors the
        // paper's empirically-chosen lr=6, which sits well inside the
        // stability region and spreads convergence over O(100) iterations
        // (the regime where per-round wall-clock differences, not round-1
        // cost, decide time-to-accuracy).
        let clamp = 0.12 / (self.smoothness + self.cfg.l2);
        self.cfg.lr_at_epoch(epoch).min(clamp * (self.cfg.lr_decay.powi(
            self.cfg.lr_decay_epochs.iter().filter(|&&d| epoch >= d).count() as i32,
        )))
    }

    /// Global mini-batch size m (the allocation target).
    pub fn m(&self) -> usize {
        self.cfg.global_batch()
    }
}

/// Real IDX files if present under `data/<family>/`, else the seeded
/// synthetic family (DESIGN.md §Substitutions).
fn load_dataset(cfg: &ExperimentConfig, rng: &mut Rng) -> Result<(Dataset, Dataset)> {
    let dir = Path::new("data").join(&cfg.dataset);
    let train_images = dir.join("train-images-idx3-ubyte");
    if train_images.exists() {
        let mut train = data::idx::load_pair(
            &train_images,
            &dir.join("train-labels-idx1-ubyte"),
            cfg.classes,
        )?;
        let mut test = data::idx::load_pair(
            &dir.join("t10k-images-idx3-ubyte"),
            &dir.join("t10k-labels-idx1-ubyte"),
            cfg.classes,
        )?;
        anyhow::ensure!(
            train.feature_dim() == cfg.dim,
            "IDX feature dim {} != config dim {}",
            train.feature_dim(),
            cfg.dim
        );
        train.normalize_01();
        test.normalize_01();
        let train = train.slice(0, cfg.train_size.min(train.len()));
        let test = test.slice(0, cfg.test_size.min(test.len()));
        return Ok((train, test));
    }
    let spec = match cfg.dataset.as_str() {
        "fashion" => synth::fashion_like(cfg.dim),
        "easy" => synth::easy(cfg.dim),
        _ => synth::mnist_like(cfg.dim),
    };
    // One generator pass so train/test share prototypes.
    let all = synth::generate(&spec, cfg.train_size + cfg.test_size, rng);
    let train = all.slice(0, cfg.train_size);
    let test = all.slice(cfg.train_size, cfg.test_size);
    Ok((train, test))
}
