//! Back-compat shim over [`super::engine`].
//!
//! The pre-0.2 API ran one closed-enum scheme through a monolithic
//! `run_scheme`; the guts now live in the scheme-agnostic
//! [`engine`](super::engine) behind the open [`crate::schemes::Scheme`]
//! trait, and sessions are built with [`crate::ExperimentBuilder`]. This
//! wrapper keeps old call sites compiling.

use anyhow::Result;

use super::engine::{self, TrainOutcome};
use super::setup::FedSetup;
use crate::runtime::Runtime;
use crate::schemes::SchemeSpec;

/// Run `scheme` to completion over `setup`, computing gradients with `rt`.
#[deprecated(
    since = "0.2.0",
    note = "build a Session with ExperimentBuilder and call Session::run \
            (or coordinator::engine::run) with a schemes::Scheme"
)]
pub fn run_scheme(setup: &FedSetup, rt: &Runtime, scheme: SchemeSpec) -> Result<TrainOutcome> {
    let mut built = scheme.build();
    engine::run(setup, rt, built.as_mut(), &mut [])
}
